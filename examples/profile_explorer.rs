//! Profile explorer (paper Fig. 3 + Sect. 4.3): sweep all execution
//! profiles through the design flow, print the accuracy/power trade-off,
//! report which pairs are good merge candidates for the adaptive engine
//! (shared layers under MDC signatures) — then go beyond the hand-exported
//! table: run the approximation explorer on the most accurate profile and
//! auto-generate a Pareto ladder of derived profiles the adaptive server
//! could serve directly (`ProfileManager::from_frontier`).
//!
//! Run: `cargo run --release --example profile_explorer`

use anyhow::Result;
use onnx2hw::approx::{CalibSet, Explorer, ExplorerConfig};
use onnx2hw::flow::{self, FlowConfig};
use onnx2hw::hls::Calibration;
use onnx2hw::mdc;
use onnx2hw::runtime::ArtifactStore;

fn main() -> Result<()> {
    let store = ArtifactStore::discover()?;
    let cfg = FlowConfig::default();
    let profiles = store.profiles()?;
    println!("profiles in artifact store: {profiles:?}\n");

    // --- Fig. 3 series ---
    let refs: Vec<&str> = profiles.iter().map(String::as_str).collect();
    let rows = flow::table1(&store, &refs, &cfg)?;
    println!("{:<10} {:>10} {:>10} {:>8} {:>8}", "profile", "power_mW", "acc_%", "LUT_%", "BRAM_%");
    for r in &rows {
        println!(
            "{:<10} {:>10.1} {:>10.2} {:>8.1} {:>8.1}",
            r.profile, r.power_mw, r.accuracy_pct, r.lut_pct, r.bram_pct
        );
    }

    // --- Pareto front (power up, accuracy up) ---
    let mut pareto: Vec<&flow::ProfileReport> = Vec::new();
    for r in &rows {
        if !rows
            .iter()
            .any(|o| o.power_mw < r.power_mw && o.accuracy_pct >= r.accuracy_pct)
        {
            pareto.push(r);
        }
    }
    println!(
        "\nPareto-optimal profiles: {:?}",
        pareto.iter().map(|r| r.profile.as_str()).collect::<Vec<_>>()
    );

    // --- merge candidates: count shared actor slots per pair ---
    println!("\nmerge candidates (shared slots / total, sbox LUT overhead):");
    let nets: Vec<mdc::Network> = profiles
        .iter()
        .map(|p| Ok(mdc::build_network(&store.qonnx(p)?, &cfg.fold)))
        .collect::<Result<_>>()?;
    let cal = Calibration::default();
    let mut best: Option<(String, usize, u64)> = None;
    for i in 0..nets.len() {
        for j in i + 1..nets.len() {
            let md = mdc::merge(&[nets[i].clone(), nets[j].clone()])?;
            let cost = mdc::merged_estimate(&md, &cal);
            let label = format!("{} + {}", nets[i].profile, nets[j].profile);
            println!(
                "  {label:<20} {}/{} shared, sbox {} LUTs",
                md.n_shared(),
                md.instances.len(),
                cost.sbox_luts
            );
            let better = best
                .as_ref()
                .is_none_or(|(_, s, ov)| md.n_shared() > *s
                    || (md.n_shared() == *s && cost.sbox_luts < *ov));
            if better {
                best = Some((label, md.n_shared(), cost.sbox_luts));
            }
        }
    }
    if let Some((label, shared, _)) = best {
        println!("\nbest adaptive-engine candidate: {label} ({shared} shared slots)");
        println!("(the paper selects A8-W8 + Mixed — Sect. 4.3)");
    }

    // --- auto-generate a ladder instead of hand-picking one ---
    // The hand-exported profiles above were trained offline; the
    // approximation explorer derives new per-layer bit-width variants from
    // the most accurate one and searches out the accuracy/energy frontier.
    let seed_row = rows
        .iter()
        .max_by(|a, b| a.accuracy_pct.total_cmp(&b.accuracy_pct))
        .expect("at least one profile");
    let base = store.qonnx(&seed_row.profile)?;
    let testset = store.testset()?;
    let calib = CalibSet::from_testset(&testset, 64);
    let mut explorer = Explorer::new(
        &base,
        &calib,
        ExplorerConfig {
            power_images: 1,
            max_rungs: 6,
            ..Default::default()
        },
    );
    let frontier = explorer.explore();
    println!(
        "\nauto-generated ladder from {} ({} candidates evaluated):",
        base.profile,
        explorer.evaluations()
    );
    for (i, p) in frontier.points.iter().enumerate() {
        println!(
            "  rung {i}: {:<12} [{}] acc {:>5.1}% power {:>6.1} mW energy {:>6.2} uJ",
            p.name,
            p.model.precision_signature(),
            p.accuracy * 100.0,
            p.power_mw,
            p.energy_uj
        );
    }
    let baseline = explorer.uniform_baseline();
    let strict = baseline
        .iter()
        .filter(|b| frontier.strictly_dominates(b.accuracy, b.energy_uj, b.latency_us))
        .count();
    println!(
        "ladder strictly dominates {strict}/{} uniform-precision rungs \
         (serve it via ProfileManager::from_frontier)",
        baseline.len()
    );
    Ok(())
}
