//! End-to-end adaptive CPS scenario (paper Fig. 4): the sharded adaptive
//! inference engine serves a continuous classification workload from a
//! battery; the Profile Manager switches from the accurate profile (A8-W8)
//! to the low-power one (Mixed) when the battery crosses the threshold.
//! Compares against the non-adaptive engine that always runs A8-W8.
//!
//! This is the end-to-end validation driver recorded in EXPERIMENTS.md: it
//! exercises coordinator + batcher + profile manager + worker shards +
//! backend (PJRT by default; pass `sim` to use the integer dataflow engine).
//!
//! Run: `cargo run --release --example adaptive_engine -- [pjrt|sim] [requests] [workers]
//!       [clients] [recharge_mw]`
//!
//! A nonzero `recharge_mw` attaches a constant harvest source to every
//! shard's battery (integrated on virtual batch time), so degraded shards
//! recover and the Profile Manager's hysteresis upswitch fires.

use std::sync::Arc;

use anyhow::Result;
use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec,
    ServerConfig,
};
use onnx2hw::flow::{self, FlowConfig};
use onnx2hw::power::{
    run_fixed, simulate_battery, AdaptivePolicy, BatteryModel, BatteryPack, EnergySource,
};
use onnx2hw::runtime::ArtifactStore;

const PAIR: [&str; 2] = ["A8-W8", "Mixed"];

fn main() -> Result<()> {
    let mut backend_kind = std::env::args().nth(1).unwrap_or_else(|| "pjrt".into());
    // The PJRT runtime is optional (e.g. offline builds vendor an xla
    // stub); fall back to the bit-exact Sim backend rather than failing
    // the default invocation at startup.
    if backend_kind == "pjrt" {
        if let Err(e) = onnx2hw::runtime::PjrtEngine::new() {
            eprintln!("note: PJRT unavailable ({e}); falling back to sim backend");
            backend_kind = "sim".into();
        }
    }
    let n_requests: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(512);
    let workers: usize = std::env::args().nth(3).and_then(|s| s.parse().ok()).unwrap_or(2);
    let clients: usize = std::env::args().nth(4).and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
    let recharge_mw: f64 = std::env::args().nth(5).and_then(|s| s.parse().ok()).unwrap_or(0.0);
    let recharge = if recharge_mw > 0.0 {
        EnergySource::constant(recharge_mw)
    } else {
        EnergySource::None
    };

    let store = ArtifactStore::discover()?;
    let testset = Arc::new(store.testset()?);
    let cfg = FlowConfig::default();

    // Profile characteristics from the design flow (Table-1 machinery).
    let rows = flow::table1(&store, &PAIR, &cfg)?;
    let specs: Vec<ProfileSpec> = rows
        .iter()
        .map(|r| ProfileSpec {
            name: r.profile.clone(),
            accuracy: r.accuracy_pct / 100.0,
            power_mw: r.power_mw,
            latency_us: r.latency_us,
        })
        .collect();
    for s in &specs {
        println!(
            "profile {:<8} acc {:.2}% power {:.1} mW latency {:.0} us",
            s.name,
            s.accuracy * 100.0,
            s.power_mw,
            s.latency_us
        );
    }

    // Battery sized so the threshold crossing happens mid-run; the server
    // splits it into one cell per shard (per-accelerator batteries).
    let per_classification_j = specs[0].power_mw * 1e-3 * specs[0].latency_us * 1e-6;
    let battery_j = per_classification_j * n_requests as f64 * 0.9;
    println!(
        "\nbattery: {:.3} mJ (~90% of what {} requests need on {}), \
         {:.3} mJ per shard",
        battery_j * 1e3,
        n_requests,
        specs[0].name,
        battery_j * 1e3 / workers.max(1) as f64
    );

    let manager = ProfileManager::new(ManagerConfig::default(), specs.clone());
    let energy = EnergyMonitor::new(battery_j);
    let store2 = store.clone();
    let kind = backend_kind.clone();
    // No Arc needed: client threads hold detached ClientHandles, not the
    // server value.
    let srv = AdaptiveServer::start(
        ServerConfig {
            workers,
            recharge: recharge.clone(),
            ..Default::default()
        },
        move || match kind.as_str() {
            "sim" => Backend::sim(&store2, &PAIR),
            _ => Backend::pjrt(&store2, &PAIR),
        },
        manager,
        energy,
    )?;
    println!(
        "adaptive server up ({backend_kind} backend, {} worker shards, {clients} clients)\n",
        srv.workers()
    );

    #[allow(clippy::disallowed_methods)] // wall-clock: measured serving throughput
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        // Async client API: pipelined submission keeps a window of
        // requests in flight so they overlap instead of paying one RTT
        // each.
        let client = srv.client();
        let testset = testset.clone();
        handles.push(std::thread::spawn(move || {
            let idxs: Vec<usize> = (c..n_requests)
                .step_by(clients)
                .map(|i| i % testset.len())
                .collect();
            let replies = client
                .classify_pipelined(idxs.iter().map(|&i| testset.image(i).to_vec()), 16);
            let mut correct = 0usize;
            let mut served_by = std::collections::BTreeMap::<String, usize>::new();
            for (&idx, reply) in idxs.iter().zip(replies) {
                let resp = reply.expect("reply lost");
                if resp.pred == testset.labels[idx] as usize {
                    correct += 1;
                }
                *served_by.entry(resp.profile).or_default() += 1;
            }
            (correct, served_by)
        }));
    }
    let mut correct = 0usize;
    let mut served_by = std::collections::BTreeMap::<String, usize>::new();
    for h in handles {
        let (c, by) = h.join().expect("client thread panicked");
        correct += c;
        for (p, n) in by {
            *served_by.entry(p).or_default() += n;
        }
    }
    let wall = t0.elapsed();

    println!("== live run ==");
    println!(
        "served {} requests in {:.2}s ({:.0} req/s) | accuracy {:.2}%",
        n_requests,
        wall.as_secs_f64(),
        n_requests as f64 / wall.as_secs_f64(),
        100.0 * correct as f64 / n_requests as f64
    );
    for (p, n) in &served_by {
        println!("  {p}: {n} requests");
    }
    println!(
        "profile switches: {} | p50 latency {} us | p95 {} us | mean battery left {:.1}%",
        srv.stats.switches.get(),
        srv.stats.latency.quantile_us(0.5),
        srv.stats.latency.quantile_us(0.95),
        srv.battery_fraction() * 100.0
    );
    if recharge != EnergySource::None {
        println!("recharge source per shard: {}", recharge.label());
    }
    for (i, e) in srv.shard_energy.iter().enumerate() {
        println!(
            "  shard {i}: {} batches ({} stolen) | battery {:.1}% | recharged {:.3} mJ",
            srv.stats.worker_batches[i].get(),
            srv.stats.worker_steals[i].get(),
            e.remaining_fraction() * 100.0,
            srv.stats.shard_recharged_j[i].get() * 1e3
        );
    }
    for ev in srv.stats.events.snapshot() {
        println!("  event: {ev}");
    }

    // --- the paper's 10 Ah projection (Fig. 4 right) ---
    let bat = BatteryModel::default();
    let a = &rows[0];
    let l = &rows[1];
    let fixed = run_fixed(&a.profile, &bat, a.power_mw, a.latency_us, a.accuracy_pct / 100.0);
    let adaptive = simulate_battery(
        &bat,
        &AdaptivePolicy::default(),
        (&a.profile, a.power_mw, a.latency_us, a.accuracy_pct / 100.0),
        (&l.profile, l.power_mw, l.latency_us, l.accuracy_pct / 100.0),
    );
    println!("\n== 10 Ah projection (paper Fig. 4 right) ==");
    for run in [&fixed, &adaptive] {
        println!(
            "  {:<24} {:>7.1} h {:>13} classifications (mean acc {:.2}%)",
            run.label, run.duration_h, run.classifications, run.mean_accuracy * 100.0
        );
    }
    // Same projection battery, deployed as the sharded server would see
    // it: one cell per accelerator replica (not the mJ-scale demo battery
    // the live run above used).
    let pack = BatteryPack::split(&bat, workers.max(1));
    println!(
        "  the 10 Ah budget as a per-shard pack: {} cells of {:.0} J each \
         ({:.0} J total)",
        pack.cells.len(),
        pack.cell_energy_j()[0],
        pack.total_energy_j()
    );
    srv.shutdown();
    Ok(())
}
