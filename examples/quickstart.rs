//! Quickstart: load one AOT-compiled profile and classify test images on
//! the PJRT runtime — the minimal end-to-end path through the three layers
//! (Pallas kernels -> jax graph -> HLO text -> rust PJRT).
//!
//! Run: `cargo run --release --example quickstart` (after `make artifacts`).

use anyhow::Result;
use onnx2hw::dataflow::exec;
use onnx2hw::runtime::{ArtifactStore, PjrtEngine};

fn main() -> Result<()> {
    let store = ArtifactStore::discover()?;
    let testset = store.testset()?;
    let profile = "A8-W8";

    // 1. Load + compile the AOT artifact (HLO text produced by python/compile/aot.py).
    let mut engine = PjrtEngine::new()?;
    let dt = engine.load(&store, profile, 1)?;
    println!("PJRT platform: {} | compiled {profile} in {dt:?}", engine.platform());

    // 2. Classify a few test images.
    let n = 32.min(testset.len());
    let mut correct = 0;
    #[allow(clippy::disallowed_methods)] // wall-clock: per-image timing demo
    let t0 = std::time::Instant::now();
    for i in 0..n {
        let (_logits, pred) = engine.classify_one(profile, testset.image(i))?;
        if pred == testset.labels[i] as usize {
            correct += 1;
        }
    }
    let per_image = t0.elapsed() / n as u32;
    println!("PJRT runtime:   {correct}/{n} correct | {per_image:?}/image");

    // 3. Cross-check with the integer dataflow engine (what the FPGA fabric
    //    computes, bit-exact vs python's intref).
    let model = store.qonnx(profile)?;
    let mut ex = onnx2hw::dataflow::Executor::new(&model);
    let mut agree = 0;
    for i in 0..n {
        let logits = ex.run(testset.image(i));
        let (_l, pjrt_pred) = engine.classify_one(profile, testset.image(i))?;
        if exec::argmax(&logits) == pjrt_pred {
            agree += 1;
        }
    }
    println!("dataflow agrees with PJRT on {agree}/{n} predictions");

    // 4. Where Table 1 comes from: the python-side full-testset accuracy.
    let eval = store.eval(profile)?;
    println!(
        "full-testset accuracy ({} images): {:.2}%",
        eval.n_test,
        eval.int_accuracy * 100.0
    );
    Ok(())
}
