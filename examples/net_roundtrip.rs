//! Wire-protocol round trip on a loopback socket: a self-hosted adaptive
//! server behind the TCP front end, driven by a Poisson arrival schedule
//! through [`NetClient`].
//!
//! The synthetic model needs no artifacts, so this runs anywhere:
//!
//! 1. start the spine (`AdaptiveServer`, Sim backend) + [`NetServer`] on
//!    `127.0.0.1:0`;
//! 2. generate a seeded Poisson schedule (`loadgen::poisson_arrivals`) and
//!    pace it on the wall clock, keeping a bounded window in flight;
//! 3. print exact client-side latency quantiles, an ASCII log2-bucket
//!    histogram, and a per-request span breakdown from the shared
//!    [`TraceCollector`] (see `docs/observability.md`), then drain
//!    gracefully and check the gauges read zero.
//!
//! Run: `cargo run --release --example net_roundtrip -- [requests]
//!       [rate_per_s] [shards]`

use std::collections::{BTreeMap, VecDeque};
use std::sync::Arc;

use anyhow::Result;
use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec,
    ServerConfig,
};
use onnx2hw::loadgen;
use onnx2hw::metrics::exact_quantile_us;
use onnx2hw::net::{NetClient, NetReply, NetServer, NetServerConfig};
use onnx2hw::qonnx::{read_str, test_model_json, QonnxModel};
use onnx2hw::trace::{SpanKind, TraceCollector};

const SEED: u64 = 7;
const WINDOW: usize = 16;

fn arg<T: std::str::FromStr>(n: usize, default: T) -> T {
    std::env::args()
        .nth(n)
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

fn histogram(latencies: &[u64]) -> String {
    // log2 buckets, rendered like the metrics::Histogram but from the
    // exact per-request samples this example retains.
    let mut buckets = [0usize; 24];
    for &us in latencies {
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(buckets.len() - 1);
        buckets[idx] += 1;
    }
    let peak = buckets.iter().copied().max().unwrap_or(1).max(1);
    let mut out = String::new();
    for (i, &n) in buckets.iter().enumerate() {
        if n == 0 {
            continue;
        }
        let bar = "#".repeat((n * 40).div_ceil(peak));
        out.push_str(&format!(
            "  {:>9}us..{:<9}us {:>6}  {bar}\n",
            1u64 << i,
            1u64 << (i + 1),
            n
        ));
    }
    out
}

#[allow(clippy::disallowed_methods)] // wall-clock: a live paced demo, not a gated number
fn main() -> Result<()> {
    use std::time::{Duration, Instant};

    let requests: usize = arg(1, 512);
    let rate_per_s: f64 = arg(2, 4000.0);
    let shards: usize = arg(3, 2).max(1);

    // --- spine + front end on a loopback port ---
    let model = read_str(&test_model_json(1, 2)).map_err(|e| anyhow::anyhow!("{e}"))?;
    let elems = model.input_shape.elems();
    let models: BTreeMap<String, QonnxModel> = [
        ("hi".to_string(), model.clone()),
        ("lo".to_string(), model.clone()),
    ]
    .into_iter()
    .collect();
    let specs = vec![
        ProfileSpec {
            name: "hi".into(),
            accuracy: 0.96,
            power_mw: 142.0,
            latency_us: 329.0,
        },
        ProfileSpec {
            name: "lo".into(),
            accuracy: 0.94,
            power_mw: 76.0,
            latency_us: 329.0,
        },
    ];
    // One collector shared by the spine and the front end: wire spans land
    // on the wire-tick clock, shard spans on the batch clock.
    let trace = Arc::new(TraceCollector::new(shards));
    let srv = AdaptiveServer::start(
        ServerConfig {
            workers: shards,
            trace: Some(trace.clone()),
            ..Default::default()
        },
        move || Ok(Backend::sim_from_models(models.clone())),
        ProfileManager::new(ManagerConfig::default(), specs),
        EnergyMonitor::new(10.0),
    )?;
    let net = NetServer::start(
        NetServerConfig {
            expected_image_len: Some(elems),
            trace: Some(trace.clone()),
            ..Default::default()
        },
        srv.client(),
    )?;
    println!(
        "serving on {} | {shards} shard(s) | image payload {elems} bytes",
        net.addr()
    );

    // --- paced open-loop client ---
    let arrivals = loadgen::poisson_arrivals(rate_per_s, requests, SEED);
    let images: Vec<Vec<u8>> = (0..8)
        .map(|k| (0..elems).map(|i| ((i * 31 + k * 17) % 256) as u8).collect())
        .collect();
    let mut client = NetClient::connect(&net.addr().to_string())?;
    let mut send_times: VecDeque<Instant> = VecDeque::new();
    let mut latencies: Vec<u64> = Vec::with_capacity(requests);
    let mut denied = 0usize;
    let drain_one = |client: &mut NetClient,
                     send_times: &mut VecDeque<Instant>,
                     latencies: &mut Vec<u64>,
                     denied: &mut usize|
     -> Result<()> {
        let sent = send_times.pop_front().expect("a reply implies a send");
        match client.recv()? {
            NetReply::Response(_) => latencies.push(sent.elapsed().as_micros() as u64),
            NetReply::Denied { .. } => *denied += 1,
        }
        Ok(())
    };
    let t0 = Instant::now();
    for (i, &at) in arrivals.iter().enumerate() {
        let target = t0 + Duration::from_secs_f64(at);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        while send_times.len() >= WINDOW {
            drain_one(&mut client, &mut send_times, &mut latencies, &mut denied)?;
        }
        client.submit(&images[i % images.len()])?;
        send_times.push_back(Instant::now());
    }
    while !send_times.is_empty() {
        drain_one(&mut client, &mut send_times, &mut latencies, &mut denied)?;
    }
    let wall = t0.elapsed().as_secs_f64();

    // --- report ---
    latencies.sort_unstable();
    println!(
        "\n{} requests at {rate_per_s:.0}/s offered over {wall:.2}s wall \
         ({:.0} req/s achieved) | served {} | denied {denied}",
        requests,
        requests as f64 / wall.max(1e-9),
        latencies.len()
    );
    println!(
        "client-side latency: p50 {}us p90 {}us p99 {}us p999 {}us max {}us",
        exact_quantile_us(&latencies, 0.50),
        exact_quantile_us(&latencies, 0.90),
        exact_quantile_us(&latencies, 0.99),
        exact_quantile_us(&latencies, 0.999),
        latencies.last().copied().unwrap_or(0)
    );
    println!("\nlatency histogram (log2 buckets):\n{}", histogram(&latencies));

    // --- graceful drain: gauges must read zero ---
    drop(client);
    let stats = net.stats.clone();
    net.shutdown();
    println!(
        "drained: served {} | shed {} | in-flight {} | open connections {}",
        stats.served.get(),
        stats.shed.get(),
        stats.inflight.get(),
        stats.open_connections.get()
    );
    assert_eq!(stats.inflight.get(), 0);
    assert_eq!(stats.open_connections.get(), 0);
    srv.shutdown();

    // --- per-request span breakdown from the shared trace collector ---
    let snap = trace.snapshot();
    let mut served_ids: Vec<u64> = snap
        .spans
        .iter()
        .filter(|s| s.kind == SpanKind::ShardExec)
        .map(|s| s.req)
        .collect();
    served_ids.sort_unstable();
    served_ids.dedup();
    println!(
        "\ntrace: {} spans / {} events across {} served requests ({} records dropped)",
        snap.spans.len(),
        snap.events.len(),
        served_ids.len(),
        snap.dropped
    );
    for &req in served_ids.iter().take(3) {
        println!("  request {req} (wire spans on wire ticks, shard spans on the batch clock):");
        for s in snap.spans_for(req) {
            let label = match s.layer {
                Some(l) => format!("{}.{}.{}", s.kind.as_str(), l, s.detail),
                None if s.detail.is_empty() => s.kind.as_str().to_string(),
                None => format!("{} ({})", s.kind.as_str(), s.detail),
            };
            println!("    lane {:>2}  [{:>5}..{:<5}]  {label}", s.lane, s.start, s.end);
        }
    }
    Ok(())
}
