//! The full design flow for one profile (paper Fig. 2): QONNX -> Reader ->
//! HLS Writer (C++/TCL emission) -> HLS estimate -> streaming simulation ->
//! power model. Prints the Vitis-style report and the profile's Table-1 row.
//!
//! Run: `cargo run --release --example design_flow -- [profile]`

use anyhow::Result;
use onnx2hw::dataflow::FoldingConfig;
use onnx2hw::flow::{self, FlowConfig};
use onnx2hw::runtime::ArtifactStore;
use onnx2hw::writer;

fn main() -> Result<()> {
    let profile = std::env::args().nth(1).unwrap_or_else(|| "A8-W8".to_string());
    let store = ArtifactStore::discover()?;
    let cfg = FlowConfig::default();

    // --- Reader: QONNX JSON -> validated IR ---
    let model = store.qonnx(&profile)?;
    println!(
        "parsed QONNX profile {} | {} layers | {} parameters | {} MACs/classification",
        model.profile,
        model.layers.len(),
        model.param_count(),
        model.total_macs()
    );

    // --- HLS Writer: C++ actor instantiations + TCL ---
    let out = writer::write_engine(&model, &FoldingConfig::default());
    println!("\n--- generated {}_engine.cpp (first 25 lines) ---", profile);
    for line in out.cpp.lines().take(25) {
        println!("{line}");
    }
    println!("--- (+ engine.h {} bytes, build TCL {} bytes) ---", out.header.len(), out.tcl.len());

    // --- Vitis-style utilization/schedule report ---
    let rep = flow::utilization_report(&store, &profile, &cfg)?;
    println!("\n{}", rep.render());

    // --- Table-1 row (accuracy from python eval, latency/power from sim) ---
    let row = flow::profile_report(&store, &profile, &cfg)?;
    println!(
        "Table-1 row: {} | acc {:.1}% | latency {:.0} us | LUT {:.0}% | BRAM {:.0}% | power {:.0} mW",
        row.profile, row.accuracy_pct, row.latency_us, row.lut_pct, row.bram_pct, row.power_mw
    );

    // --- cross-check: rust integer engine accuracy == python eval ---
    let testset = store.testset()?;
    let acc = flow::measure_accuracy(&model, &testset, 256);
    println!(
        "rust dataflow accuracy on 256 images: {:.2}% (python full-set: {:.2}%)",
        acc * 100.0,
        store.eval(&profile)?.int_accuracy * 100.0
    );
    Ok(())
}
