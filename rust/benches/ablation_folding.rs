//! Ablation bench: the HLS folding design space (DESIGN.md design choice).
//!
//! The paper fixes one folding; this ablation sweeps PE/SIMD to show the
//! latency/resource trade-off the flow navigates, and verifies the Table-1
//! invariant (latency set by folding, not precision) across the sweep. Also
//! retargets the device model (KV260 vs Zynq-7020) to show portability.

use onnx2hw::bench_harness::Table;
use onnx2hw::dataflow::{simulate_image, FoldingConfig};
use onnx2hw::flow::FlowConfig;
use onnx2hw::hls::{estimate_engine, Calibration, DeviceModel};
use onnx2hw::runtime::ArtifactStore;

fn main() {
    let store = match ArtifactStore::discover() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("ablation_folding: skipping ({e})");
            return;
        }
    };
    let cfg = FlowConfig::default();
    let model = store.qonnx("A8-W8").expect("qonnx");
    let model_w4 = store.qonnx("A4-W4").expect("qonnx");
    let testset = store.testset().expect("testset");
    let img = testset.image(0);
    let cal = Calibration::default();
    let dev = DeviceModel::kria_kv260();

    println!("== Ablation: folding (PE/SIMD) sweep on {} ==\n", model.profile);
    let mut t = Table::new(&[
        "folding (c1 pe,simd | c2 pe,simd)",
        "MAC units",
        "latency [us]",
        "LUT [%]",
        "lat x res",
    ]);
    let folds = [
        (1usize, 1usize, 1usize, 9usize),
        (4, 1, 4, 18),
        (8, 2, 8, 36),   // default
        (16, 3, 16, 72),
        (32, 9, 32, 144),
    ];
    for (p1, s1, p2, s2) in folds {
        let fold = FoldingConfig {
            conv1_pe: p1,
            conv1_simd: s1,
            conv2_pe: p2,
            conv2_simd: s2,
            ..FoldingConfig::default()
        };
        let est = estimate_engine(&model, &fold, &cal);
        let sim = simulate_image(&model, &fold, img);
        let lat_us = sim.cycles as f64 / dev.clock_mhz;
        let lut_pct = dev.lut_pct(est.luts);
        t.row(&[
            format!("{p1},{s1} | {p2},{s2}"),
            format!("{}", fold.mac_units(&model)),
            format!("{lat_us:.0}"),
            format!("{lut_pct:.1}"),
            format!("{:.0}", lat_us * lut_pct),
        ]);
        // Table-1 invariant at every folding: W4 engine has identical cycles.
        let sim_w4 = simulate_image(&model_w4, &fold, img);
        assert_eq!(sim.cycles, sim_w4.cycles, "latency must not depend on precision");
    }
    println!("{}", t.render());
    println!("invariant held: A8-W8 and A4-W4 cycles identical at every folding\n");

    println!("== Ablation: device retarget ==");
    let fold = FoldingConfig::default();
    let est = estimate_engine(&model, &fold, &cal);
    for dev in [DeviceModel::kria_kv260(), DeviceModel::zynq_7020()] {
        println!(
            "  {:<22} LUT {:>5.1}% | BRAM {:>5.1}% | fits: {}",
            dev.name,
            dev.lut_pct(est.luts),
            dev.bram_pct(est.bram36),
            est.luts < dev.luts && (est.bram36 as u64) < dev.bram36
        );
    }
    let _ = cfg;
}
