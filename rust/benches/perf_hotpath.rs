//! Bench: §Perf hot paths across the stack.
//!
//! * PJRT classification (batch 1 and 8) — the production request path
//! * integer dataflow executor — backs every accuracy sweep
//! * actor-level streaming simulation — backs every power number
//! * coordinator round trip (sim backend) — queue + batcher + reply overhead

use onnx2hw::bench_harness::{bench, fmt_dur, Table};
use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec,
    ServerConfig,
};
use onnx2hw::dataflow::{simulate_image, Executor, FoldingConfig};
use onnx2hw::runtime::{ArtifactStore, PjrtEngine};

fn main() {
    let store = match ArtifactStore::discover() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("perf_hotpath: skipping ({e})");
            return;
        }
    };
    let profile = "A8-W8";
    let testset = store.testset().expect("testset");
    let model = store.qonnx(profile).expect("qonnx");
    let img = testset.image(0);

    let mut t = Table::new(&["path", "mean", "p50", "p95", "throughput"]);

    // --- L3 hot path: PJRT batch 1 / batch 8 ---
    let mut engine = PjrtEngine::new().expect("pjrt");
    engine.load(&store, profile, 1).expect("load b1");
    let have_b8 = engine.load(&store, profile, 8).is_ok();
    let s = bench(10, 200, || engine.classify_one(profile, img).unwrap());
    t.row(&[
        "PJRT classify (batch 1)".into(),
        fmt_dur(s.mean),
        fmt_dur(s.p50),
        fmt_dur(s.p95),
        format!("{:.0} img/s", s.throughput_per_s()),
    ]);
    if have_b8 {
        let imgs: Vec<&[u8]> = (0..8).map(|i| testset.image(i)).collect();
        let s = bench(5, 100, || engine.classify_batch(profile, &imgs).unwrap());
        t.row(&[
            "PJRT classify (batch 8)".into(),
            fmt_dur(s.mean),
            fmt_dur(s.p50),
            fmt_dur(s.p95),
            format!("{:.0} img/s", 8.0 * s.throughput_per_s()),
        ]);
    }

    // --- integer dataflow executor ---
    let mut ex = Executor::new(&model);
    let s = bench(5, 100, || ex.run(img));
    t.row(&[
        "integer exec (1 img)".into(),
        fmt_dur(s.mean),
        fmt_dur(s.p50),
        fmt_dur(s.p95),
        format!("{:.0} img/s", s.throughput_per_s()),
    ]);

    // --- actor-level streaming sim ---
    let fold = FoldingConfig::default();
    let s = bench(2, 20, || simulate_image(&model, &fold, img));
    let rep = simulate_image(&model, &fold, img);
    let firings: u64 = rep.actors.iter().map(|a| a.firings).sum();
    t.row(&[
        "streaming sim (1 img)".into(),
        fmt_dur(s.mean),
        fmt_dur(s.p50),
        fmt_dur(s.p95),
        format!(
            "{:.2}M firings/s",
            firings as f64 / s.mean.as_secs_f64() / 1e6
        ),
    ]);

    // --- coordinator round trip on the sim backend ---
    let specs = vec![ProfileSpec {
        name: profile.to_string(),
        accuracy: 0.96,
        power_mw: 142.0,
        latency_us: 329.0,
    }];
    let manager = ProfileManager::new(ManagerConfig::default(), specs);
    let energy = EnergyMonitor::new(1e9);
    let store2 = store.clone();
    let srv = AdaptiveServer::start(
        ServerConfig::default(),
        move || Backend::sim(&store2, &["A8-W8"]),
        manager,
        energy,
    )
    .expect("server");
    let img_vec = img.to_vec();
    let s = bench(5, 100, || srv.classify(img_vec.clone()).unwrap());
    t.row(&[
        "coordinator RTT (sim)".into(),
        fmt_dur(s.mean),
        fmt_dur(s.p50),
        fmt_dur(s.p95),
        format!("{:.0} req/s", s.throughput_per_s()),
    ]);

    println!("== §Perf hot paths ==\n\n{}", t.render());
    println!("note: FPGA-projected latency is 329us/image — the PJRT path's job is to");
    println!("stay well under the request interarrival budget, not to match the fabric.");
    srv.shutdown();
}
