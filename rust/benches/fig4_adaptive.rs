//! Bench: regenerate Fig. 4 (top) — the MDC-merged adaptive engine's
//! resources and per-profile metrics, plus the switch-cost measurement
//! (profile switching is a config-word write: O(1), no re-synthesis).

use onnx2hw::bench_harness::{bench, fmt_dur};
use onnx2hw::coordinator::{EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec};
use onnx2hw::flow::{self, FlowConfig};
use onnx2hw::hls::Calibration;
use onnx2hw::mdc;
use onnx2hw::runtime::ArtifactStore;

const PAIR: [&str; 2] = ["A8-W8", "Mixed"];

fn main() {
    let store = match ArtifactStore::discover() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("fig4_adaptive: skipping ({e})");
            return;
        }
    };
    let cfg = FlowConfig::default();
    println!("== Fig. 4 (top): adaptive inference engine {} + {} ==\n", PAIR[0], PAIR[1]);

    let nets: Vec<mdc::Network> = PAIR
        .iter()
        .map(|p| mdc::build_network(&store.qonnx(p).unwrap(), &cfg.fold))
        .collect();
    let md = mdc::merge(&nets).expect("merge");
    let cal = Calibration::default();
    let merged = mdc::merged_estimate(&md, &cal);

    println!(
        "slots {} | shared {} | profile-specific instances {}",
        md.instances.len(),
        md.n_shared(),
        md.n_instances() - md.n_shared()
    );
    println!(
        "merged engine: {} LUTs ({:.1}%), {:.1} BRAM36 ({:.1}%), sbox overhead {} LUTs ({:.2}% of engine)",
        merged.luts,
        cfg.device.lut_pct(merged.luts),
        merged.bram36,
        cfg.device.bram_pct(merged.bram36),
        merged.sbox_luts,
        100.0 * merged.sbox_luts as f64 / merged.luts as f64
    );

    let rows = flow::table1(&store, &PAIR, &cfg).expect("rows");
    let mut specs = Vec::new();
    for r in &rows {
        println!(
            "profile {:<8}: accuracy {:.2}% | power {:.1} mW | latency {:.0} us",
            r.profile, r.accuracy_pct, r.power_mw, r.latency_us
        );
        specs.push(ProfileSpec {
            name: r.profile.clone(),
            accuracy: r.accuracy_pct / 100.0,
            power_mw: r.power_mw,
            latency_us: r.latency_us,
        });
    }
    let overhead = merged.luts as f64 / rows.iter().map(|r| r.luts).max().unwrap() as f64 - 1.0;
    println!(
        "\nadaptivity overhead vs largest non-adaptive engine: +{:.1}% LUTs (paper: 'limited overhead')",
        overhead * 100.0
    );
    println!(
        "switch saves {:.1}% power for {:.2} pp accuracy (paper: ~5% / ~1.5 pp)",
        (1.0 - rows[1].power_mw / rows[0].power_mw) * 100.0,
        rows[0].accuracy_pct - rows[1].accuracy_pct
    );

    // --- switch cost: ProfileManager.select + config swap ---
    let manager = ProfileManager::new(ManagerConfig::default(), specs);
    let energy = EnergyMonitor::new(1e9);
    let stats = bench(100, 10_000, || manager.select(&energy).name.clone());
    println!(
        "\nprofile-switch decision cost: {} mean (p95 {}) — config-word write, no re-synthesis",
        fmt_dur(stats.mean),
        fmt_dur(stats.p95)
    );
}
