//! Bench: open-loop offered load — p99 under a fixed rate, with admission
//! control gating the tail.
//!
//! Two deterministic layers, no wall clock in any gated number:
//!
//! 1. **Virtual-time model** (`loadgen::simulate`): seeded Poisson arrivals
//!    pushed through the M/D/c queue model at the serving spine's geometry
//!    (4 shards x 329 us service, admission depth 64). Two scenarios:
//!    *nominal* (6000 req/s — under the ~12158 req/s capacity; nothing may
//!    be shed and p99 must stay under the gate) and *overload* (30000 req/s
//!    — admission control must shed instead of letting the tail grow, so
//!    p99 stays below the closed-form bound
//!    `(depth/shards + 1) * service_us` no matter the offered rate).
//! 2. **Wire round trip**: the same spine behind the real TCP front end
//!    ([`onnx2hw::net::NetServer`]) on a loopback socket. A pipelined
//!    [`NetClient`] pushes requests through the framed protocol and every
//!    reply is asserted bit-exact against the scalar oracle
//!    (`exec::execute`) — the wire must never change the integers — and
//!    all queue/in-flight gauges must read zero after the drain.
//!
//! Run: `cargo bench --bench load_open_loop [-- <wire_requests>
//!       [--json <path>] [--assert-gate]]`
//!
//! `--json` writes one row per scenario for the CI artifact;
//! `--assert-gate` enforces the latency/shed gates above.

use std::collections::BTreeMap;

use onnx2hw::bench_harness::Table;
use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec,
    ServerConfig,
};
use onnx2hw::dataflow::exec;
use onnx2hw::json::{self, Value};
use onnx2hw::loadgen::{poisson_arrivals, simulate, OpenLoopConfig, OpenLoopReport};
use onnx2hw::metrics::exact_quantile_us;
use onnx2hw::net::{NetClient, NetReply, NetServer, NetServerConfig};
use onnx2hw::qonnx::{read_str, test_model_json, QonnxModel};

const N_IMAGES: usize = 8;
/// Queue-model geometry: matches the paper's per-inference latency on the
/// A8-W8 engine (329 us) across a 4-shard spine.
const SERVICE_US: f64 = 329.0;
const SHARDS: usize = 4;
const ADMISSION: usize = 64;
/// Closed-form worst case for an *admitted* request: it waits behind at
/// most `depth` others spread over `shards` servers, then runs.
const LATENCY_BOUND_US: u64 = ((ADMISSION as u64 / SHARDS as u64) + 1) * SERVICE_US as u64;
/// Nominal-scenario p99 gate: measured 647 us at seed 7; 3x margin.
const NOMINAL_P99_GATE_US: u64 = 2000;
const SEED: u64 = 7;

struct Scenario {
    name: &'static str,
    rate_per_s: f64,
    requests: usize,
}

const SCENARIOS: [Scenario; 2] = [
    // ~49% utilisation of the 4 x (1/329us) = ~12158 req/s capacity
    Scenario {
        name: "nominal",
        rate_per_s: 6000.0,
        requests: 4000,
    },
    // ~2.5x capacity: admission control must shed, the tail must not grow
    Scenario {
        name: "overload",
        rate_per_s: 30000.0,
        requests: 6000,
    },
];

struct WireResult {
    requests: usize,
    p50_us: u64,
    p99_us: u64,
    max_us: u64,
}

/// Serve `requests` images through the TCP front end and assert every reply
/// bit-exact against the scalar oracle. Returns the spine-side latency
/// quantiles (virtual service time, not wall clock).
fn run_wire_roundtrip(requests: usize) -> WireResult {
    let model = read_str(&test_model_json(1, 2)).expect("model");
    let elems = model.input_shape.elems();
    let models: BTreeMap<String, QonnxModel> = [
        ("hi".to_string(), model.clone()),
        ("lo".to_string(), model.clone()),
    ]
    .into_iter()
    .collect();
    let factory = move || Ok(Backend::sim_from_models(models.clone()));
    let specs = vec![
        ProfileSpec {
            name: "hi".into(),
            accuracy: 0.96,
            power_mw: 142.0,
            latency_us: SERVICE_US,
        },
        ProfileSpec {
            name: "lo".into(),
            accuracy: 0.94,
            power_mw: 76.0,
            latency_us: SERVICE_US,
        },
    ];
    let manager = ProfileManager::new(ManagerConfig::default(), specs);
    // Battery sized so the run never degrades: the oracle check is about
    // the wire, not adaptivity (energy_cycle covers that).
    let srv = AdaptiveServer::start(
        ServerConfig {
            workers: 2,
            ..Default::default()
        },
        factory,
        manager,
        EnergyMonitor::new(10.0),
    )
    .expect("server");
    let srv_stats = srv.stats.clone();
    let net = NetServer::start(
        NetServerConfig {
            expected_image_len: Some(elems),
            ..Default::default()
        },
        srv.client(),
    )
    .expect("net server");
    let net_stats = net.stats.clone();

    let patterns: Vec<Vec<u8>> = (0..N_IMAGES)
        .map(|k| (0..elems).map(|i| ((i * 31 + k * 17) % 256) as u8).collect())
        .collect();
    let expect: Vec<Vec<f32>> = patterns
        .iter()
        .map(|img| exec::execute(&model, img).iter().map(|&v| v as f32).collect())
        .collect();

    let mut client = NetClient::connect(&net.addr().to_string()).expect("connect");
    let replies = client
        .classify_pipelined((0..requests).map(|i| patterns[i % N_IMAGES].clone()), 16)
        .expect("pipelined run");
    assert_eq!(replies.len(), requests, "one reply per request");
    let mut latencies: Vec<u64> = Vec::with_capacity(requests);
    for (i, reply) in replies.iter().enumerate() {
        match reply {
            NetReply::Response(resp) => {
                assert_eq!(resp.id, i as u64, "replies keep submission order");
                assert_eq!(
                    resp.logits,
                    expect[i % N_IMAGES],
                    "request {i} on '{}' not bit-exact vs the scalar oracle",
                    resp.profile
                );
                latencies.push(resp.latency_us);
            }
            NetReply::Denied { id, code, message } => {
                panic!("request {id} denied under default admission: {code}: {message}")
            }
        }
    }

    // Drain: the client hangs up, the front end joins every thread, and
    // all gauges must be back at zero — nothing leaked on the happy path.
    drop(client);
    net.shutdown();
    assert_eq!(net_stats.served.get(), requests as u64);
    assert_eq!(net_stats.shed.get(), 0);
    assert_eq!(net_stats.failed.get(), 0);
    assert_eq!(net_stats.inflight.get(), 0, "in-flight gauge leaked");
    assert_eq!(net_stats.open_connections.get(), 0, "connection gauge leaked");
    assert!(srv_stats.drained(), "spine queue/shard gauges leaked");
    srv.shutdown();

    latencies.sort_unstable();
    WireResult {
        requests,
        p50_us: exact_quantile_us(&latencies, 0.50),
        p99_us: exact_quantile_us(&latencies, 0.99),
        max_us: latencies.last().copied().unwrap_or(0),
    }
}

fn report_row(s: &Scenario, r: &OpenLoopReport) -> Value {
    Value::obj(vec![
        ("scenario", s.name.into()),
        ("rate_per_s", s.rate_per_s.into()),
        ("seed", (SEED as i64).into()),
        ("shards", SHARDS.into()),
        ("service_us", SERVICE_US.into()),
        ("admission_depth", ADMISSION.into()),
        ("offered", r.offered.into()),
        ("served", r.served.into()),
        ("shed", r.shed.into()),
        ("shed_fraction", r.shed_fraction.into()),
        ("p50_us", (r.p50_us as i64).into()),
        ("p99_us", (r.p99_us as i64).into()),
        ("p999_us", (r.p999_us as i64).into()),
        ("max_us", (r.max_us as i64).into()),
        ("mean_us", r.mean_us.into()),
        ("horizon_s", r.horizon_s.into()),
        ("latency_bound_us", (LATENCY_BOUND_US as i64).into()),
        (
            "max_depth",
            Value::Array(r.max_depth.iter().map(|&d| d.into()).collect()),
        ),
    ])
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut wire_requests: usize = 96;
    let mut json_path: Option<String> = None;
    let mut assert_gate = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => {
                i += 1;
                json_path = Some(args.get(i).expect("--json needs a path").clone());
            }
            "--assert-gate" => assert_gate = true,
            other => {
                wire_requests = other.parse().unwrap_or_else(|_| {
                    panic!("unexpected argument '{other}' (want a wire request count)")
                });
            }
        }
        i += 1;
    }

    let cfg = OpenLoopConfig {
        shards: SHARDS,
        service_us: SERVICE_US,
        admission_depth: ADMISSION,
    };
    let mut table = Table::new(&[
        "scenario", "rate", "offered", "served", "shed", "p50", "p99", "p999", "max",
    ]);
    let mut reports = Vec::new();
    for s in &SCENARIOS {
        let arrivals = poisson_arrivals(s.rate_per_s, s.requests, SEED);
        let r = simulate(&arrivals, &cfg);
        table.row(&[
            s.name.to_string(),
            format!("{:.0}/s", s.rate_per_s),
            r.offered.to_string(),
            r.served.to_string(),
            format!("{} ({:.1}%)", r.shed, r.shed_fraction * 100.0),
            format!("{}us", r.p50_us),
            format!("{}us", r.p99_us),
            format!("{}us", r.p999_us),
            format!("{}us", r.max_us),
        ]);
        reports.push(r);
    }

    println!(
        "== open-loop offered load (seeded Poisson, virtual time; {SHARDS} shards x \
         {SERVICE_US:.0}us service, admission depth {ADMISSION}) ==\n"
    );
    println!("{}", table.render());
    println!(
        "admitted-latency bound: (depth/shards + 1) * service = {LATENCY_BOUND_US}us; \
         capacity ~{:.0} req/s",
        SHARDS as f64 * 1e6 / SERVICE_US
    );

    let wire = run_wire_roundtrip(wire_requests);
    println!(
        "\nwire round trip: {} framed requests through the TCP front end, every reply \
         bit-exact vs exec::execute; spine latency p50 {}us p99 {}us max {}us; all \
         gauges zero after drain",
        wire.requests, wire.p50_us, wire.p99_us, wire.max_us
    );

    if let Some(path) = &json_path {
        let mut rows: Vec<Value> = SCENARIOS
            .iter()
            .zip(&reports)
            .map(|(s, r)| report_row(s, r))
            .collect();
        rows.push(Value::obj(vec![
            ("scenario", "wire-roundtrip".into()),
            ("requests", wire.requests.into()),
            ("bit_exact", true.into()),
            ("p50_us", (wire.p50_us as i64).into()),
            ("p99_us", (wire.p99_us as i64).into()),
            ("max_us", (wire.max_us as i64).into()),
        ]));
        std::fs::write(path, json::to_string_pretty(&Value::Array(rows))).expect("write json");
        println!("wrote {} rows to {path}", reports.len() + 1);
    }

    if assert_gate {
        let nominal = &reports[0];
        assert_eq!(
            nominal.shed, 0,
            "nominal: shed {} requests below the admission threshold",
            nominal.shed
        );
        assert_eq!(nominal.served, nominal.offered, "nominal: lost requests");
        assert!(
            nominal.p99_us <= NOMINAL_P99_GATE_US,
            "nominal: p99 {}us exceeds the {NOMINAL_P99_GATE_US}us gate",
            nominal.p99_us
        );
        let overload = &reports[1];
        assert!(
            overload.shed_fraction >= 0.3,
            "overload: shed fraction {:.3} — admission control is not biting",
            overload.shed_fraction
        );
        assert!(
            overload.max_us <= LATENCY_BOUND_US,
            "overload: max latency {}us exceeds the admitted bound {LATENCY_BOUND_US}us \
             — the tail grew instead of shedding",
            overload.max_us
        );
        for (i, &d) in overload.max_depth.iter().enumerate() {
            assert!(
                d <= ADMISSION,
                "overload: shard {i} depth {d} exceeded the admission ceiling {ADMISSION}"
            );
        }
        println!(
            "\ngate passed: nominal p99 {}us <= {NOMINAL_P99_GATE_US}us with zero shed; \
             overload shed {:.1}% with max {}us <= bound {LATENCY_BOUND_US}us",
            nominal.p99_us,
            overload.shed_fraction * 100.0,
            overload.max_us
        );
    }
}
