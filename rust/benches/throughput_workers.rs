//! Bench: sharded-engine throughput scaling on the Sim backend.
//!
//! Needs no artifacts — two synthetic QONNX profiles ("hi" heavier, "lo"
//! lighter) are generated with the in-tree testgen. Three load shapes are
//! measured at 1/2/4 shards:
//!
//! * `uniform`        — dispatcher routes to the least-loaded shard;
//! * `skewed`         — every batch is pinned to shard 0; idle shards must
//!   steal from its deque to scale at all (the work-stealing hot path);
//! * `skewed-nosteal` — same pinning with stealing disabled: the control
//!   showing the skew really serializes on one shard without stealing.
//!
//! Before any number is reported each run must pass:
//!
//! * request conservation — every submit gets exactly one reply (ids
//!   unique, counters consistent, queues drained);
//! * bit-exactness — every reply's logits equal `exec::execute` on the
//!   same (profile, image), i.e. sharding + stealing + executor caching
//!   never change the integers the FPGA fabric would produce.
//!
//! Run: `cargo bench --bench throughput_workers [-- <requests>
//!       [--json <path>] [--assert-scaling <factor>]]`
//!
//! `--json` writes the rows as a JSON array (the CI bench-smoke job
//! uploads it as an artifact); each row carries end-to-end latency p50/p99
//! (`latency_p50_us`/`latency_p99_us`, log-bucket upper bounds from the
//! server histogram). `--assert-scaling F` additionally requires
//! skewed-mode 4-shard throughput >= F x 1-shard throughput.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use onnx2hw::bench_harness::Table;
use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec,
    ServerConfig,
};
use onnx2hw::dataflow::exec;
use onnx2hw::json::{self, Value};
use onnx2hw::qonnx::{self, read_str, QonnxModel, RandModelCfg};
use onnx2hw::testkit::Rng;

const CLIENTS: usize = 8;
const N_IMAGES: usize = 32;
const WINDOW: usize = 32;

/// Reference logits per profile name, per image index.
type ExpectMap = BTreeMap<String, Vec<Vec<f32>>>;

fn synthetic_pair() -> (QonnxModel, QonnxModel) {
    let mut rng = Rng::new(7);
    // "hi": 16x16x3 -> conv16 -> pool -> conv32 -> pool -> dense10
    let hi_cfg = RandModelCfg {
        side: 16,
        cin: 3,
        blocks: vec![(16, 8, 8), (32, 8, 8)],
        classes: 10,
    };
    // "lo": same input shape, half the filters at 4-bit weights
    let lo_cfg = RandModelCfg {
        blocks: vec![(8, 8, 4), (16, 8, 4)],
        ..hi_cfg.clone()
    };
    let hi = read_str(&qonnx::random_model_json(&hi_cfg, &mut rng)).expect("hi model");
    let lo = read_str(&qonnx::random_model_json(&lo_cfg, &mut rng)).expect("lo model");
    (hi, lo)
}

struct RunResult {
    mode: &'static str,
    workers: usize,
    wall_s: f64,
    rps: f64,
    speedup: f64,
    batches: u64,
    steals: u64,
    per_worker: Vec<u64>,
    /// End-to-end request latency percentiles (us) from the server's
    /// log-bucketed histogram.
    p50_us: u64,
    p99_us: u64,
}

#[allow(clippy::too_many_arguments)]
fn run_one(
    mode: &'static str,
    workers: usize,
    requests: usize,
    hi: &QonnxModel,
    lo: &QonnxModel,
    images: &Arc<Vec<Vec<u8>>>,
    expect: &Arc<ExpectMap>,
    specs: &[ProfileSpec],
    base_rps: Option<f64>,
) -> RunResult {
    let models: BTreeMap<String, QonnxModel> = [
        ("hi".to_string(), hi.clone()),
        ("lo".to_string(), lo.clone()),
    ]
    .into_iter()
    .collect();
    let factory = move || Ok(Backend::sim_from_models(models.clone()));
    let manager = ProfileManager::new(ManagerConfig::default(), specs.to_vec());
    // Effectively infinite battery: this bench isolates throughput; the
    // adaptation path is exercised by fig4_adaptive and the test suite.
    let energy = EnergyMonitor::new(1e9);
    let cfg = ServerConfig {
        workers,
        steal: mode != "skewed-nosteal",
        pin_dispatch_to: if mode == "uniform" { None } else { Some(0) },
        ..Default::default()
    };
    let srv = AdaptiveServer::start(cfg, factory, manager, energy).expect("server");

    let all_ids = Arc::new(Mutex::new(Vec::<u64>::new()));
    #[allow(clippy::disallowed_methods)] // wall-clock: measured throughput
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..CLIENTS {
        let client = srv.client();
        let images = images.clone();
        let expect = expect.clone();
        let all_ids = all_ids.clone();
        handles.push(std::thread::spawn(move || {
            let n_img = images.len();
            let ks: Vec<usize> = (c..requests).step_by(CLIENTS).map(|i| i % n_img).collect();
            let imgs = ks.iter().map(|&k| images[k].clone());
            let replies = client.classify_pipelined(imgs, WINDOW);
            let mut ids = Vec::new();
            for (&k, reply) in ks.iter().zip(replies) {
                let resp = reply.expect("reply lost");
                let want = &expect[&resp.profile][k];
                assert_eq!(
                    &resp.logits,
                    want,
                    "reply for image {k} on '{}' not bit-exact",
                    resp.profile
                );
                ids.push(resp.id);
            }
            all_ids.lock().unwrap().extend(ids);
        }));
    }
    for h in handles {
        h.join().expect("client thread panicked");
    }
    let wall = t0.elapsed();

    // conservation + counter consistency
    let mut ids = all_ids.lock().unwrap().clone();
    assert_eq!(ids.len(), requests, "dropped or duplicated replies");
    ids.sort_unstable();
    ids.dedup();
    assert_eq!(ids.len(), requests, "duplicate reply ids");
    assert_eq!(srv.stats.requests.get(), requests as u64);
    let per_worker: Vec<u64> = srv.stats.worker_batches.iter().map(|c| c.get()).collect();
    assert_eq!(
        per_worker.iter().sum::<u64>(),
        srv.stats.batches.get(),
        "per-worker batches {per_worker:?} do not sum to total"
    );
    assert_eq!(srv.stats.queue_depth.get(), 0, "work queue not drained");
    for (i, g) in srv.stats.shard_depth.iter().enumerate() {
        assert_eq!(g.get(), 0, "shard {i} deque not drained");
    }

    let rps = requests as f64 / wall.as_secs_f64();
    let result = RunResult {
        mode,
        workers,
        wall_s: wall.as_secs_f64(),
        rps,
        speedup: base_rps.map_or(1.0, |b| rps / b),
        batches: srv.stats.batches.get(),
        steals: srv.stats.worker_steals.iter().map(|c| c.get()).sum(),
        per_worker,
        p50_us: srv.stats.latency.quantile_us(0.5),
        p99_us: srv.stats.latency.quantile_us(0.99),
    };
    srv.shutdown();
    result
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut requests: usize = 512;
    let mut json_path: Option<String> = None;
    let mut assert_scaling: Option<f64> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => {
                i += 1;
                json_path = Some(args.get(i).expect("--json needs a path").clone());
            }
            "--assert-scaling" => {
                i += 1;
                assert_scaling = Some(
                    args.get(i)
                        .expect("--assert-scaling needs a factor")
                        .parse()
                        .expect("--assert-scaling: not a number"),
                );
            }
            other => {
                requests = other.parse().unwrap_or_else(|_| {
                    panic!("unexpected argument '{other}' (want a request count)")
                });
            }
        }
        i += 1;
    }

    let (hi, lo) = synthetic_pair();
    let elems = hi.input_shape.elems();
    assert_eq!(elems, lo.input_shape.elems());

    // Deterministic image set + per-(profile, image) reference logits from
    // the one-shot executor path.
    let images: Arc<Vec<Vec<u8>>> = Arc::new(
        (0..N_IMAGES)
            .map(|k| (0..elems).map(|i| ((i * 31 + k * 17) % 256) as u8).collect())
            .collect(),
    );
    let expect: Arc<ExpectMap> = Arc::new(
        [("hi", &hi), ("lo", &lo)]
            .into_iter()
            .map(|(name, model)| {
                let per_image = images
                    .iter()
                    .map(|img| {
                        exec::execute(model, img)
                            .iter()
                            .map(|&v| v as f32)
                            .collect::<Vec<f32>>()
                    })
                    .collect();
                (name.to_string(), per_image)
            })
            .collect(),
    );

    let specs = vec![
        ProfileSpec {
            name: "hi".into(),
            accuracy: 0.96,
            power_mw: 142.0,
            latency_us: 329.0,
        },
        ProfileSpec {
            name: "lo".into(),
            accuracy: 0.94,
            power_mw: 120.0,
            latency_us: 329.0,
        },
    ];

    let mut table = Table::new(&[
        "mode", "workers", "wall", "req/s", "speedup", "p50", "p99", "batches", "steals",
        "per-worker",
    ]);
    let mut results: Vec<RunResult> = Vec::new();
    for &mode in &["uniform", "skewed", "skewed-nosteal"] {
        let mut base_rps: Option<f64> = None;
        for &workers in &[1usize, 2, 4] {
            let r = run_one(
                mode, workers, requests, &hi, &lo, &images, &expect, &specs, base_rps,
            );
            if base_rps.is_none() {
                base_rps = Some(r.rps);
            }
            table.row(&[
                r.mode.to_string(),
                r.workers.to_string(),
                format!("{:.3}s", r.wall_s),
                format!("{:.0}", r.rps),
                format!("x{:.2}", r.speedup),
                format!("{}us", r.p50_us),
                format!("{}us", r.p99_us),
                r.batches.to_string(),
                r.steals.to_string(),
                format!("{:?}", r.per_worker),
            ]);
            results.push(r);
        }
    }

    println!(
        "== sharded engine throughput (Sim backend, {CLIENTS} async clients x \
         window {WINDOW}, {requests} requests) ==\n"
    );
    println!("{}", table.render());
    println!("conservation and bit-exactness vs exec::execute asserted on every");
    println!("reply before any row above was reported.");

    if let Some(path) = &json_path {
        let rows = Value::Array(
            results
                .iter()
                .map(|r| {
                    Value::obj(vec![
                        ("mode", r.mode.into()),
                        ("workers", r.workers.into()),
                        ("requests", requests.into()),
                        ("clients", CLIENTS.into()),
                        ("wall_s", r.wall_s.into()),
                        ("req_per_s", r.rps.into()),
                        ("speedup_vs_1_shard", r.speedup.into()),
                        ("latency_p50_us", (r.p50_us as i64).into()),
                        ("latency_p99_us", (r.p99_us as i64).into()),
                        ("batches", (r.batches as i64).into()),
                        ("steals", (r.steals as i64).into()),
                        (
                            "per_worker_batches",
                            Value::Array(
                                r.per_worker.iter().map(|&b| (b as i64).into()).collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        std::fs::write(path, json::to_string_pretty(&rows)).expect("write json");
        println!("wrote {} rows to {path}", results.len());
    }

    if let Some(factor) = assert_scaling {
        let rps_of = |mode: &str, workers: usize| {
            results
                .iter()
                .find(|r| r.mode == mode && r.workers == workers)
                .map(|r| r.rps)
                .expect("mode/worker row present")
        };
        let one = rps_of("skewed", 1);
        let four = rps_of("skewed", 4);
        assert!(
            four >= factor * one,
            "skewed 4-shard throughput {four:.0} req/s < {factor} x \
             1-shard {one:.0} req/s: work stealing failed to rebalance"
        );
        println!(
            "scaling gate passed: skewed 4-shard = x{:.2} of 1-shard (>= {factor})",
            four / one
        );
    }
}
