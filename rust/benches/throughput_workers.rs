//! Bench: sharded-engine throughput scaling on the Sim backend.
//!
//! Needs no artifacts — two synthetic QONNX profiles ("hi" heavier, "lo"
//! lighter) are generated with the in-tree testgen. For each shard count
//! the server is hammered from 8 client threads, and before any number is
//! reported the run must pass:
//!
//! * request conservation — every submit gets exactly one reply;
//! * counter consistency — per-worker batch counters sum to `batches`,
//!   and the queue-depth gauge drains back to 0;
//! * bit-exactness — every reply's logits equal `exec::execute` on the
//!   same (profile, image), i.e. sharding + executor caching never change
//!   the integers the FPGA fabric would produce.
//!
//! Run: `cargo bench --bench throughput_workers [-- <requests>]`

use std::collections::BTreeMap;
use std::sync::Arc;

use onnx2hw::bench_harness::Table;
use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec,
    ServerConfig,
};
use onnx2hw::dataflow::exec;
use onnx2hw::qonnx::{self, read_str, QonnxModel, RandModelCfg};
use onnx2hw::testkit::Rng;

const CLIENTS: usize = 8;
const N_IMAGES: usize = 32;

fn synthetic_pair() -> (QonnxModel, QonnxModel) {
    let mut rng = Rng::new(7);
    // "hi": 16x16x3 -> conv16 -> pool -> conv32 -> pool -> dense10
    let hi_cfg = RandModelCfg {
        side: 16,
        cin: 3,
        blocks: vec![(16, 8, 8), (32, 8, 8)],
        classes: 10,
    };
    // "lo": same input shape, half the filters at 4-bit weights
    let lo_cfg = RandModelCfg {
        blocks: vec![(8, 8, 4), (16, 8, 4)],
        ..hi_cfg.clone()
    };
    let hi = read_str(&qonnx::random_model_json(&hi_cfg, &mut rng)).expect("hi model");
    let lo = read_str(&qonnx::random_model_json(&lo_cfg, &mut rng)).expect("lo model");
    (hi, lo)
}

fn main() {
    let requests: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(512);

    let (hi, lo) = synthetic_pair();
    let elems = hi.input_shape.elems();
    assert_eq!(elems, lo.input_shape.elems());

    // Deterministic image set + per-(profile, image) reference logits from
    // the one-shot executor path.
    let images: Arc<Vec<Vec<u8>>> = Arc::new(
        (0..N_IMAGES)
            .map(|k| (0..elems).map(|i| ((i * 31 + k * 17) % 256) as u8).collect())
            .collect(),
    );
    let expect: Arc<BTreeMap<String, Vec<Vec<f32>>>> = Arc::new(
        [("hi", &hi), ("lo", &lo)]
            .into_iter()
            .map(|(name, model)| {
                let per_image = images
                    .iter()
                    .map(|img| {
                        exec::execute(model, img)
                            .iter()
                            .map(|&v| v as f32)
                            .collect::<Vec<f32>>()
                    })
                    .collect();
                (name.to_string(), per_image)
            })
            .collect(),
    );

    let specs = vec![
        ProfileSpec {
            name: "hi".into(),
            accuracy: 0.96,
            power_mw: 142.0,
            latency_us: 329.0,
        },
        ProfileSpec {
            name: "lo".into(),
            accuracy: 0.94,
            power_mw: 120.0,
            latency_us: 329.0,
        },
    ];

    let mut table = Table::new(&["workers", "wall", "req/s", "speedup", "batches", "per-worker"]);
    let mut base_rps: Option<f64> = None;
    for &workers in &[1usize, 2, 4] {
        let models: BTreeMap<String, QonnxModel> = [
            ("hi".to_string(), hi.clone()),
            ("lo".to_string(), lo.clone()),
        ]
        .into_iter()
        .collect();
        let factory = move || Ok(Backend::sim_from_models(models.clone()));
        let manager = ProfileManager::new(ManagerConfig::default(), specs.clone());
        // Effectively infinite battery: this bench isolates throughput; the
        // adaptation path is exercised by fig4_adaptive and the test suite.
        let energy = EnergyMonitor::new(1e9);
        let srv = Arc::new(
            AdaptiveServer::start(
                ServerConfig {
                    workers,
                    ..Default::default()
                },
                factory,
                manager,
                energy,
            )
            .expect("server"),
        );

        let t0 = std::time::Instant::now();
        let mut handles = Vec::new();
        for c in 0..CLIENTS {
            let srv = srv.clone();
            let images = images.clone();
            let expect = expect.clone();
            handles.push(std::thread::spawn(move || {
                let mut served = 0usize;
                let mut i = c;
                while i < requests {
                    let k = i % images.len();
                    let resp = srv.classify(images[k].clone()).expect("reply lost");
                    let want = &expect[&resp.profile][k];
                    assert_eq!(
                        &resp.logits, want,
                        "reply for image {k} on '{}' not bit-exact",
                        resp.profile
                    );
                    served += 1;
                    i += CLIENTS;
                }
                served
            }));
        }
        let served: usize = handles
            .into_iter()
            .map(|h| h.join().expect("client thread panicked"))
            .sum();
        let wall = t0.elapsed();

        // conservation + counter consistency
        assert_eq!(served, requests, "dropped or duplicated replies");
        assert_eq!(srv.stats.requests.get(), requests as u64);
        let per_worker: Vec<u64> =
            srv.stats.worker_batches.iter().map(|c| c.get()).collect();
        assert_eq!(
            per_worker.iter().sum::<u64>(),
            srv.stats.batches.get(),
            "per-worker batches {per_worker:?} do not sum to total"
        );
        assert_eq!(srv.stats.queue_depth.get(), 0, "work queue not drained");

        let rps = requests as f64 / wall.as_secs_f64();
        let speedup = match base_rps {
            None => {
                base_rps = Some(rps);
                1.0
            }
            Some(b) => rps / b,
        };
        table.row(&[
            workers.to_string(),
            format!("{:.3}s", wall.as_secs_f64()),
            format!("{rps:.0}"),
            format!("x{speedup:.2}"),
            srv.stats.batches.get().to_string(),
            format!("{per_worker:?}"),
        ]);

        let srv = Arc::try_unwrap(srv).ok().expect("clients joined");
        srv.shutdown();
    }

    println!(
        "== sharded engine throughput (Sim backend, {CLIENTS} clients, {requests} requests) ==\n"
    );
    println!("{}", table.render());
    println!("conservation, counter consistency, and bit-exactness vs exec::execute");
    println!("asserted on every reply before any row above was reported.");
}
