//! Bench: trace conservation — deterministic request tracing gated on
//! exact reconciliation against the metrics registry.
//!
//! Three phases, one gate (`--assert-conservation`):
//!
//! * **Live conservation** — a seeded [`FaultPlan`] (panics + brown-outs,
//!   no wire faults) runs against the full TCP stack with one shared
//!   [`TraceCollector`] plumbed through both the spine and the front end.
//!   Three [`ResilientClient`] drivers push through an admission ceiling
//!   *below* the driver count, so shed + retry paths fire under real
//!   contention. Afterwards the trace must reconcile **exactly** with the
//!   unified metrics registry: every wire span count equals
//!   `admitted + shed + bad_requests`, every served id carries a complete
//!   `net.read → admission → dispatch.enqueue → queue.wait → shard.exec
//!   (kernel.layer…) → net.write` tree, denied keys match sheds, and every
//!   instant event (steal / shed / brown-out / death / respawn / rung
//!   switch / client retry) matches its counter 1:1. Replies are asserted
//!   bit-exact vs `exec::execute` in flight.
//! * **Offline determinism** — the same seeded schedule through
//!   [`loadgen::simulate_traced`] twice must serialize to **byte-identical**
//!   Chrome trace JSON (the live phase cannot promise that across thread
//!   interleavings; the model can), and tracing must not perturb the model:
//!   the traced report equals the untraced one field for field.
//! * **Tracing overhead** — `BatchExecutor::run_batch` (observer off) vs
//!   `run_batch_observed` (observer on) on the conv-heavy model: the
//!   observed path must stay within 5% of the plain one, i.e. the
//!   per-layer hook is near-zero-cost and exactly zero when disabled.
//!
//! Run: `cargo bench --bench trace_conservation [-- <requests>
//!       [--json <path>] [--assert-conservation]]`
//!
//! `--json` rows hold only seed-derived values and gate booleans — no
//! measured numbers — so identical seeds yield byte-identical artifacts.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;
use std::time::Duration;

use onnx2hw::bench_harness::bench;
use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec,
    ServerConfig, ServerStats,
};
use onnx2hw::dataflow::{exec, BatchExecutor};
use onnx2hw::fault::{FaultPlan, FaultSpec, ServerFaultKind};
use onnx2hw::json::{self, Value};
use onnx2hw::loadgen::{self, OpenLoopConfig};
use onnx2hw::net::{NetClient, NetServer, NetServerConfig, ResilientClient, RetryPolicy};
use onnx2hw::qonnx::{self, read_str, QonnxModel, RandModelCfg};
use onnx2hw::testkit::Rng;
use onnx2hw::trace::{EventKind, SpanKind, TraceCollector, DENIED_KEY_OFFSET};

const N_IMAGES: usize = 8;
const SERVICE_US: f64 = 329.0;
const SHARDS: usize = 4;
const SEED: u64 = 7;
/// More drivers than admission slots: the surplus driver is what forces
/// the shed + client-retry paths to fire (and be reconciled) every run.
const DRIVERS: usize = 3;
const ADMISSION_DEPTH: usize = 2;
const DEADLINE: Duration = Duration::from_secs(10);
const WARMUP: usize = 3;
const OVERHEAD_ITERS: usize = 24;
const OVERHEAD_MAX: f64 = 0.05;
/// Offline schedule: ~2.5x the 4-shard capacity at 329us service, so the
/// deterministic trace contains both served and shed (denied-key) trees.
const OFFLINE_RATE: f64 = 30_000.0;
const OFFLINE_REQUESTS: usize = 1500;
const OFFLINE_DEPTH: usize = 32;

/// The conv-heavy synthetic from the kernel bench: packed envelope, so the
/// spine's executor reports per-layer steps and every served request grows
/// `kernel.layer` sub-spans.
fn conv_heavy_model() -> QonnxModel {
    let mut rng = Rng::new(23);
    let cfg = RandModelCfg {
        side: 16,
        cin: 3,
        blocks: vec![(32, 8, 8), (64, 8, 8)],
        classes: 10,
    };
    read_str(&qonnx::random_model_json(&cfg, &mut rng)).expect("conv-heavy model")
}

/// Shard deaths observed so far, read from the event log (each death logs
/// exactly one "shard marked dead" line).
fn count_deaths(stats: &ServerStats) -> usize {
    stats
        .events
        .snapshot()
        .iter()
        .filter(|e| e.contains("shard marked dead"))
        .count()
}

/// Wait (wall clock, unasserted content) for `cond`; panics after ~5 s so a
/// lost recovery fails loudly instead of hanging the bench.
#[allow(clippy::disallowed_methods)] // wall-clock: polling an async recovery
fn wait_until(what: &str, cond: impl Fn() -> bool) {
    for _ in 0..500 {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("timed out waiting for {what}");
}

/// Everything the live phase measured; the gates are computed in `main`.
struct LiveResult {
    offered: usize,
    oks: usize,
    errs: usize,
    retries: u64,
    admitted: u64,
    shed: u64,
    bad_requests: u64,
    served: u64,
    failed: u64,
    restarts: u64,
    switches: u64,
    steals: u64,
    n_net_read: usize,
    n_admission: usize,
    n_net_write: usize,
    n_kernel: usize,
    spine_keys: usize,
    denied_keys: usize,
    exec_ids: usize,
    trees_complete: bool,
    ev_shed: usize,
    ev_steal: usize,
    ev_death: usize,
    ev_respawn: usize,
    ev_brownout: usize,
    ev_rung: usize,
    ev_retry: usize,
    dropped: u64,
    stats_frame_ok: bool,
}

fn run_live(requests: usize, plan: &FaultPlan) -> LiveResult {
    let model = conv_heavy_model();
    let elems = model.input_shape.elems();
    let models: BTreeMap<String, QonnxModel> = [
        ("hi".to_string(), model.clone()),
        ("lo".to_string(), model.clone()),
    ]
    .into_iter()
    .collect();
    let factory = move || Ok(Backend::sim_from_models(models.clone()));
    let specs = vec![
        ProfileSpec {
            name: "hi".into(),
            accuracy: 0.96,
            power_mw: 142.0,
            latency_us: SERVICE_US,
        },
        ProfileSpec {
            name: "lo".into(),
            accuracy: 0.94,
            power_mw: 76.0,
            latency_us: SERVICE_US,
        },
    ];
    let manager = ProfileManager::new(ManagerConfig::default(), specs);
    let injector = Arc::new(plan.injector());
    // ONE collector shared by the spine and the front end: the whole point
    // is that both sides' records must reconcile in a single snapshot.
    let trace = Arc::new(TraceCollector::new(SHARDS));
    let srv = AdaptiveServer::start(
        ServerConfig {
            workers: SHARDS,
            restart_backoff_batches: 2,
            faults: Some(injector.clone()),
            trace: Some(trace.clone()),
            ..Default::default()
        },
        factory,
        manager,
        EnergyMonitor::new(10.0),
    )
    .expect("server");
    let srv_stats = srv.stats.clone();
    let net = NetServer::start(
        NetServerConfig {
            expected_image_len: Some(elems),
            admission_depth: ADMISSION_DEPTH,
            spine_registry: Some(srv_stats.registry.clone()),
            trace: Some(trace.clone()),
            ..Default::default()
        },
        srv.client(),
    )
    .expect("net server");
    let net_stats = net.stats.clone();
    let addr = net.addr().to_string();

    let patterns: Arc<Vec<Vec<u8>>> = Arc::new(
        (0..N_IMAGES)
            .map(|k| (0..elems).map(|i| ((i * 31 + k * 17) % 256) as u8).collect())
            .collect(),
    );
    let expect: Arc<Vec<Vec<f32>>> = Arc::new(
        patterns
            .iter()
            .map(|img| exec::execute(&model, img).iter().map(|&v| v as f32).collect())
            .collect(),
    );

    let mut drivers = Vec::new();
    for t in 0..DRIVERS {
        let addr = addr.clone();
        let patterns = patterns.clone();
        let expect = expect.clone();
        let trace = trace.clone();
        drivers.push(std::thread::spawn(move || {
            let mut client = ResilientClient::new(
                &addr,
                RetryPolicy {
                    max_attempts: 8,
                    base_backoff: Duration::from_millis(1),
                    max_backoff: Duration::from_millis(8),
                    seed: SEED + t as u64,
                },
            )
            .with_deadline(DEADLINE)
            .with_trace(trace);
            let mut oks = 0usize;
            let mut errs = 0usize;
            for i in (t..requests).step_by(DRIVERS) {
                match client.classify(&patterns[i % N_IMAGES]) {
                    Ok(resp) => {
                        assert_eq!(
                            resp.logits,
                            expect[i % N_IMAGES],
                            "request {i} on '{}' not bit-exact vs the scalar oracle",
                            resp.profile
                        );
                        oks += 1;
                    }
                    Err(_) => errs += 1,
                }
            }
            (oks, errs, client.retries())
        }));
    }
    let mut oks = 0usize;
    let mut errs = 0usize;
    let mut retries = 0u64;
    for d in drivers {
        let (o, e, r) = d.join().expect("driver thread");
        oks += o;
        errs += e;
        retries += r;
    }

    // Recovery probes keep the batch clock moving until every planned fault
    // has fired and every death has been respawned (their traffic is traced
    // too, so the books still balance to the request).
    let mut probe = ResilientClient::new(
        &addr,
        RetryPolicy {
            max_attempts: 8,
            seed: SEED + 100,
            ..Default::default()
        },
    )
    .with_deadline(DEADLINE)
    .with_trace(trace.clone());
    let mut probes = 0usize;
    loop {
        let settled = injector.remaining() == 0
            && srv_stats.restarts.get() == count_deaths(&srv_stats) as u64;
        if settled {
            break;
        }
        assert!(
            probes < 1000,
            "recovery did not settle: {} faults unfired, {} restarts vs {} deaths",
            injector.remaining(),
            srv_stats.restarts.get(),
            count_deaths(&srv_stats)
        );
        let _ = probe.classify(&patterns[probes % N_IMAGES]);
        probes += 1;
        #[allow(clippy::disallowed_methods)] // wall-clock: paced live probing
        std::thread::sleep(Duration::from_millis(10));
    }
    retries += probe.retries();
    drop(probe);

    // Exposition check: one `Stats` wire frame must answer with both the
    // front end's and the spine's registry snapshots, and the counter it
    // reports must agree with the handle this process already holds.
    let stats_frame_ok = match NetClient::connect(&addr).and_then(|mut c| c.stats()) {
        Ok(body) => match json::parse(&body) {
            Ok(v) => {
                let admitted = v
                    .get("net")
                    .and_then(|n| n.get("counters"))
                    .and_then(|c| c.get("net.admitted"))
                    .and_then(Value::as_i64);
                let spine_restarts = v
                    .get("serve")
                    .and_then(|s| s.get("counters"))
                    .and_then(|c| c.get("serve.restarts"))
                    .and_then(Value::as_i64);
                admitted == Some(net_stats.admitted.get() as i64)
                    && spine_restarts == Some(srv_stats.restarts.get() as i64)
            }
            Err(_) => false,
        },
        Err(_) => false,
    };

    net.shutdown();
    assert_eq!(net_stats.inflight.get(), 0, "in-flight gauge leaked");
    assert_eq!(net_stats.open_connections.get(), 0, "connection gauge leaked");
    wait_until("spine gauges to drain", || srv_stats.drained());
    srv.shutdown();

    let snap = trace.snapshot();
    let count_kind = |k: SpanKind| snap.spans.iter().filter(|s| s.kind == k).count();
    let spine_keys: BTreeSet<u64> = snap
        .spans
        .iter()
        .filter(|s| s.kind == SpanKind::NetRead && s.req < DENIED_KEY_OFFSET)
        .map(|s| s.req)
        .collect();
    let denied_keys: BTreeSet<u64> = snap
        .spans
        .iter()
        .filter(|s| s.req >= DENIED_KEY_OFFSET)
        .map(|s| s.req)
        .collect();
    let exec_ids: BTreeSet<u64> =
        snap.spans.iter().filter(|s| s.kind == SpanKind::ShardExec).map(|s| s.req).collect();
    // Every served id must carry the full lifecycle tree including at least
    // one kernel.layer sub-span; every denied key the wire-side tree.
    let served_trees = exec_ids
        .iter()
        .all(|&r| snap.served_tree_complete(r) && snap.has_span(r, SpanKind::KernelLayer));
    let denied_trees = denied_keys.iter().all(|&r| snap.denied_tree_complete(r));
    let trees_complete = served_trees && denied_trees;

    LiveResult {
        offered: requests,
        oks,
        errs,
        retries,
        admitted: net_stats.admitted.get(),
        shed: net_stats.shed.get(),
        bad_requests: net_stats.bad_requests.get(),
        served: net_stats.served.get(),
        failed: net_stats.failed.get(),
        restarts: srv_stats.restarts.get(),
        switches: srv_stats.switches.get(),
        steals: srv_stats.worker_steals.iter().map(|c| c.get()).sum(),
        n_net_read: count_kind(SpanKind::NetRead),
        n_admission: count_kind(SpanKind::Admission),
        n_net_write: count_kind(SpanKind::NetWrite),
        n_kernel: count_kind(SpanKind::KernelLayer),
        spine_keys: spine_keys.len(),
        denied_keys: denied_keys.len(),
        exec_ids: exec_ids.len(),
        trees_complete,
        ev_shed: snap.count_events(EventKind::Shed),
        ev_steal: snap.count_events(EventKind::Steal),
        ev_death: snap.count_events(EventKind::Death),
        ev_respawn: snap.count_events(EventKind::Respawn),
        ev_brownout: snap.count_events(EventKind::BrownOut),
        ev_rung: snap.count_events(EventKind::RungUp) + snap.count_events(EventKind::RungDown),
        ev_retry: snap.count_events(EventKind::ClientRetry),
        dropped: snap.dropped,
        stats_frame_ok,
    }
}

/// What the offline determinism phase produced. Every field is derived
/// from the seed alone, so all of it may appear in the JSON artifact.
struct OfflineResult {
    offered: usize,
    served: usize,
    shed: usize,
    spans: usize,
    events: usize,
    trace_bytes: usize,
    byte_identical: bool,
    model_invariant: bool,
    trees_complete: bool,
    dropped: u64,
}

fn run_offline() -> OfflineResult {
    let arrivals = loadgen::poisson_arrivals(OFFLINE_RATE, OFFLINE_REQUESTS, SEED);
    let cfg = OpenLoopConfig {
        shards: SHARDS,
        service_us: SERVICE_US,
        admission_depth: OFFLINE_DEPTH,
    };
    let t1 = TraceCollector::new(SHARDS);
    let r1 = loadgen::simulate_traced(&arrivals, &cfg, &t1);
    let s1 = t1.snapshot();
    let j1 = json::to_string(&s1.to_chrome_json());
    let t2 = TraceCollector::new(SHARDS);
    let r2 = loadgen::simulate_traced(&arrivals, &cfg, &t2);
    let j2 = json::to_string(&t2.snapshot().to_chrome_json());
    // Tracing must be invisible to the model: the untraced run agrees on
    // every reported number, down to each served latency.
    let plain = loadgen::simulate(&arrivals, &cfg);
    let model_invariant = r1.served == plain.served
        && r1.shed == plain.shed
        && r1.latencies_us == plain.latencies_us
        && r2.served == r1.served;

    let served_ids: BTreeSet<u64> =
        s1.spans.iter().filter(|s| s.req < DENIED_KEY_OFFSET).map(|s| s.req).collect();
    let denied_ids: BTreeSet<u64> =
        s1.spans.iter().filter(|s| s.req >= DENIED_KEY_OFFSET).map(|s| s.req).collect();
    let trees_complete = served_ids.len() == r1.served
        && denied_ids.len() == r1.shed
        && served_ids.iter().all(|&r| s1.served_tree_complete(r))
        && denied_ids.iter().all(|&r| s1.denied_tree_complete(r));

    OfflineResult {
        offered: r1.offered,
        served: r1.served,
        shed: r1.shed,
        spans: s1.spans.len(),
        events: s1.events.len(),
        trace_bytes: j1.len(),
        byte_identical: j1 == j2,
        model_invariant,
        trees_complete,
        dropped: s1.dropped,
    }
}

/// Observer-on vs observer-off on the packed batch path. Min-of-iters on
/// both arms keeps shared-runner noise out of the ratio.
fn run_overhead() -> (f64, bool) {
    let model = conv_heavy_model();
    let elems = model.input_shape.elems();
    let images: Vec<Vec<u8>> = (0..N_IMAGES)
        .map(|k| (0..elems).map(|i| ((i * 31 + k * 17) % 256) as u8).collect())
        .collect();
    let refs: Vec<&[u8]> = images.iter().map(Vec::as_slice).collect();
    let mut bex = BatchExecutor::from_model(&model);
    let mut steps: Vec<(u32, &'static str)> = Vec::new();
    bex.run_batch_observed(&refs, Some(&mut steps));
    let steps_observed = !steps.is_empty();

    let plain = bench(WARMUP, OVERHEAD_ITERS, || {
        bex.run_batch(&refs).iter().fold(0i64, |a, &v| a.wrapping_add(v))
    });
    let traced = bench(WARMUP, OVERHEAD_ITERS, || {
        steps.clear();
        bex.run_batch_observed(&refs, Some(&mut steps))
            .iter()
            .fold(0i64, |a, &v| a.wrapping_add(v))
    });
    let overhead = traced.min.as_secs_f64() / plain.min.as_secs_f64() - 1.0;
    (overhead, steps_observed)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut requests: usize = 400;
    let mut json_path: Option<String> = None;
    let mut assert_conservation = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => {
                i += 1;
                json_path = Some(args.get(i).expect("--json needs a path").clone());
            }
            "--assert-conservation" => assert_conservation = true,
            other => {
                requests = other.parse().unwrap_or_else(|_| {
                    panic!("unexpected argument '{other}' (want a request count)")
                });
            }
        }
        i += 1;
    }

    // Fault-injection panics are the plan doing its job; keep CI logs
    // readable by muting exactly those and forwarding everything else.
    let default_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let injected = info
            .payload()
            .downcast_ref::<String>()
            .is_some_and(|m| m.contains("fault injection"));
        if !injected {
            default_hook(info);
        }
    }));

    // Spine faults only: wire resets/corruptions would sever connections
    // with replies in flight, and this gate is about exact reconciliation,
    // not transport chaos (chaos_recovery covers that).
    let plan = FaultPlan::seeded(
        SEED,
        &FaultSpec {
            shards: SHARDS,
            horizon_batches: 24,
            horizon_requests: (requests as u64 / 4).max(1),
            resets: 0,
            corruptions: 0,
            ..FaultSpec::default()
        },
    );
    let planned_brownouts = plan
        .server
        .iter()
        .filter(|f| matches!(f.kind, ServerFaultKind::BrownOut))
        .count();
    println!(
        "== trace conservation: {requests} requests through {SHARDS} shards under seed {SEED} \
         ({} spine faults, admission depth {ADMISSION_DEPTH} vs {DRIVERS} drivers) ==",
        plan.server.len()
    );

    let r = run_live(requests, &plan);
    println!(
        "live: resolved {}/{} (ok {} | err {}) | admitted {} shed {} | spans {}r/{}a/{}w \
         +{} kernel | events: shed {} steal {} death {} respawn {} brown-out {} rung {} retry {}",
        r.oks + r.errs,
        r.offered,
        r.oks,
        r.errs,
        r.admitted,
        r.shed,
        r.n_net_read,
        r.n_admission,
        r.n_net_write,
        r.n_kernel,
        r.ev_shed,
        r.ev_steal,
        r.ev_death,
        r.ev_respawn,
        r.ev_brownout,
        r.ev_rung,
        r.ev_retry,
    );

    let wire_total = r.admitted + r.shed + r.bad_requests;
    let wire_spans_reconcile = r.n_net_read == r.n_admission
        && r.n_net_read == r.n_net_write
        && r.n_net_read as u64 == wire_total;
    let keys_partition =
        r.spine_keys as u64 == r.admitted && r.denied_keys as u64 == r.shed + r.bad_requests;
    let requests_resolve = r.oks + r.errs == r.offered && r.served + r.failed == r.admitted;
    let exec_matches_served = r.exec_ids as u64 == r.served;
    let events_reconcile = r.ev_shed as u64 == r.shed
        && r.ev_death == plan.server.len()
        && r.ev_death as u64 == r.restarts
        && r.ev_respawn as u64 == r.restarts
        && r.ev_brownout == planned_brownouts
        && r.ev_steal as u64 == r.steals
        && r.ev_rung as u64 == r.switches
        && r.ev_retry as u64 == r.retries;
    let faults_observed = r.ev_death >= 1 && r.shed >= 1;

    let o = run_offline();
    println!(
        "offline: {} offered -> {} served / {} shed | {} spans {} events ({} bytes) | \
         byte-identical {} | model untouched {}",
        o.offered,
        o.served,
        o.shed,
        o.spans,
        o.events,
        o.trace_bytes,
        o.byte_identical,
        o.model_invariant,
    );

    let (overhead, steps_observed) = run_overhead();
    let overhead_ok = overhead <= OVERHEAD_MAX;
    println!(
        "overhead: observer-on vs observer-off {:+.2}% (gate <= {:.0}%) | steps observed: {}",
        overhead * 100.0,
        OVERHEAD_MAX * 100.0,
        steps_observed,
    );

    if let Some(path) = &json_path {
        // Deterministic by construction: the plan is seed-derived, the
        // offline phase is a sequential model, and every live/overhead
        // entry is a gate boolean — identical seeds must yield
        // byte-identical artifacts.
        let rows = vec![
            Value::obj(vec![
                ("scenario", "plan".into()),
                ("plan", plan.to_json()),
                ("planned_spine_faults", plan.server.len().into()),
                ("planned_brownouts", planned_brownouts.into()),
            ]),
            Value::obj(vec![
                ("scenario", "live-conservation".into()),
                ("offered", r.offered.into()),
                ("wire_spans_reconcile", wire_spans_reconcile.into()),
                ("keys_partition", keys_partition.into()),
                ("requests_resolve", requests_resolve.into()),
                ("exec_matches_served", exec_matches_served.into()),
                ("span_trees_complete", r.trees_complete.into()),
                ("events_reconcile", events_reconcile.into()),
                ("faults_observed", faults_observed.into()),
                ("stats_frame_ok", r.stats_frame_ok.into()),
                ("zero_dropped", (r.dropped == 0).into()),
                ("bit_exact", true.into()), // asserted per reply in-run
            ]),
            Value::obj(vec![
                ("scenario", "offline-determinism".into()),
                ("offered", o.offered.into()),
                ("served", o.served.into()),
                ("shed", o.shed.into()),
                ("spans", o.spans.into()),
                ("events", o.events.into()),
                ("trace_bytes", o.trace_bytes.into()),
                ("byte_identical", o.byte_identical.into()),
                ("model_invariant", o.model_invariant.into()),
                ("span_trees_complete", o.trees_complete.into()),
                ("zero_dropped", (o.dropped == 0).into()),
            ]),
            Value::obj(vec![
                ("scenario", "overhead".into()),
                ("kernel_steps_observed", steps_observed.into()),
                ("overhead_max", OVERHEAD_MAX.into()),
                ("overhead_within_bound", overhead_ok.into()),
            ]),
        ];
        std::fs::write(path, json::to_string_pretty(&Value::Array(rows))).expect("write json");
        println!("wrote {} rows to {path}", 4);
    }

    if assert_conservation {
        assert!(
            wire_spans_reconcile,
            "wire spans out of balance: {}r/{}a/{}w vs {} admitted+shed+bad",
            r.n_net_read, r.n_admission, r.n_net_write, wire_total
        );
        assert!(
            keys_partition,
            "correlation keys do not partition: {} spine keys vs {} admitted, {} denied keys \
             vs {} shed+bad",
            r.spine_keys,
            r.admitted,
            r.denied_keys,
            r.shed + r.bad_requests
        );
        assert!(
            requests_resolve,
            "requests lost: {}+{} != {} offered or {}+{} != {} admitted",
            r.oks, r.errs, r.offered, r.served, r.failed, r.admitted
        );
        assert!(
            exec_matches_served,
            "{} distinct shard.exec ids vs {} served replies",
            r.exec_ids, r.served
        );
        assert!(r.trees_complete, "a request id lost part of its span tree");
        assert!(
            events_reconcile,
            "instant events out of balance: shed {}/{} death {}/{} respawn {}/{} brown-out \
             {}/{} steal {}/{} rung {}/{} retry {}/{}",
            r.ev_shed,
            r.shed,
            r.ev_death,
            plan.server.len(),
            r.ev_respawn,
            r.restarts,
            r.ev_brownout,
            planned_brownouts,
            r.ev_steal,
            r.steals,
            r.ev_rung,
            r.switches,
            r.ev_retry,
            r.retries
        );
        assert!(faults_observed, "the run exercised no death or no shed");
        assert!(r.stats_frame_ok, "the Stats wire frame did not reconcile");
        assert_eq!(r.dropped, 0, "the live collector dropped records");
        assert!(o.byte_identical, "offline trace JSON not byte-identical across runs");
        assert!(o.model_invariant, "tracing perturbed the load model");
        assert!(o.trees_complete, "offline span trees incomplete");
        assert_eq!(o.dropped, 0, "the offline collector dropped records");
        assert!(steps_observed, "the batch executor reported no kernel steps");
        assert!(
            overhead_ok,
            "observer-on overhead {:+.2}% exceeds the {:.0}% bound",
            overhead * 100.0,
            OVERHEAD_MAX * 100.0
        );
        println!(
            "\ngate passed: every span/event reconciled with the registry, trace JSON \
             byte-identical per seed, observer overhead {:+.2}% <= {:.0}%",
            overhead * 100.0,
            OVERHEAD_MAX * 100.0
        );
    }
}
