//! Bench: regenerate Fig. 4 (right) — battery duration and number of
//! classifications, adaptive engine vs non-adaptive (10 Ah budget), plus a
//! sweep over the switching threshold (the Profile Manager's knob).

use onnx2hw::bench_harness::Table;
use onnx2hw::flow::{self, FlowConfig};
use onnx2hw::power::{run_fixed, simulate_battery, AdaptivePolicy, BatteryModel};
use onnx2hw::runtime::ArtifactStore;

const PAIR: [&str; 2] = ["A8-W8", "Mixed"];

fn main() {
    let store = match ArtifactStore::discover() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("fig4_battery: skipping ({e})");
            return;
        }
    };
    let cfg = FlowConfig::default();
    let rows = flow::table1(&store, &PAIR, &cfg).expect("rows");
    let a = &rows[0];
    let l = &rows[1];
    let bat = BatteryModel::default(); // 10 Ah @ 5 V, as the paper assumes

    println!("== Fig. 4 (right): battery duration & classifications (10 Ah) ==\n");
    let fixed = run_fixed(&a.profile, &bat, a.power_mw, a.latency_us, a.accuracy_pct / 100.0);
    let mut t = Table::new(&["engine", "duration [h]", "classifications", "mean acc [%]"]);
    t.row(&[
        format!("non-adaptive ({})", a.profile),
        format!("{:.1}", fixed.duration_h),
        format!("{}", fixed.classifications),
        format!("{:.2}", fixed.mean_accuracy * 100.0),
    ]);
    let adaptive = simulate_battery(
        &bat,
        &AdaptivePolicy::default(),
        (&a.profile, a.power_mw, a.latency_us, a.accuracy_pct / 100.0),
        (&l.profile, l.power_mw, l.latency_us, l.accuracy_pct / 100.0),
    );
    t.row(&[
        adaptive.label.clone(),
        format!("{:.1}", adaptive.duration_h),
        format!("{}", adaptive.classifications),
        format!("{:.2}", adaptive.mean_accuracy * 100.0),
    ]);
    println!("{}", t.render());
    println!(
        "adaptive: +{:.1}% battery life, +{:.1}% classifications (paper: adaptive extends both)\n",
        (adaptive.duration_h / fixed.duration_h - 1.0) * 100.0,
        (adaptive.classifications as f64 / fixed.classifications as f64 - 1.0) * 100.0
    );

    // --- ablation: switch-threshold sweep ---
    println!("threshold sweep (fraction of battery at which the manager switches):");
    let mut sweep = Table::new(&["switch_at", "duration [h]", "classifications", "mean acc [%]"]);
    for pct in [0.0, 0.25, 0.5, 0.75, 1.0] {
        let run = simulate_battery(
            &bat,
            &AdaptivePolicy { switch_at_fraction: pct },
            (&a.profile, a.power_mw, a.latency_us, a.accuracy_pct / 100.0),
            (&l.profile, l.power_mw, l.latency_us, l.accuracy_pct / 100.0),
        );
        sweep.row(&[
            format!("{pct:.2}"),
            format!("{:.1}", run.duration_h),
            format!("{}", run.classifications),
            format!("{:.2}", run.mean_accuracy * 100.0),
        ]);
    }
    println!("{}", sweep.render());
}
