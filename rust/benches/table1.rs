//! Bench: regenerate the paper's Table 1.
//!
//! For each mixed-precision profile: accuracy (python QAT+integer eval),
//! latency (cycle-approximate streaming sim @ 100 MHz), LUT/BRAM %
//! (HLS estimator on the KV260 model), power (activity-based model over
//! real test images). Paper values printed alongside for comparison.

use onnx2hw::bench_harness::{bench, fmt_dur, Table};
use onnx2hw::flow::{self, FlowConfig};
use onnx2hw::runtime::ArtifactStore;

const PROFILES: [&str; 5] = ["A16-W8", "A16-W4", "A8-W8", "A8-W4", "A4-W4"];
// Paper Table 1 rows: (accuracy %, latency us, LUT %, BRAM %, power mW).
const PAPER: [(&str, f64, f64, f64, f64, f64); 5] = [
    ("A16-W8", 98.9, 329.0, 12.0, 18.0, 160.0),
    ("A16-W4", 95.3, 329.0, 7.0, 18.0, 134.0),
    ("A8-W8", 98.8, 329.0, 11.0, 17.0, 142.0),
    ("A8-W4", 95.3, 329.0, 6.0, 17.0, 132.0),
    ("A4-W4", 95.8, 329.0, 6.0, 17.0, 141.0),
];

fn main() {
    let store = match ArtifactStore::discover() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("table1: skipping ({e})");
            return;
        }
    };
    let cfg = FlowConfig::default();
    println!("== Table 1: data mixed-precision approximation ==\n");
    let mut t = Table::new(&[
        "Datatype",
        "Accuracy[%] (paper)",
        "Latency[us] (paper)",
        "LUT[%] (paper)",
        "BRAM[%] (paper)",
        "Power[mW] (paper)",
    ]);
    for (i, p) in PROFILES.iter().enumerate() {
        let r = flow::profile_report(&store, p, &cfg).expect("profile report");
        let paper = PAPER[i];
        t.row(&[
            r.profile.clone(),
            format!("{:.1} ({:.1})", r.accuracy_pct, paper.1),
            format!("{:.0} ({:.0})", r.latency_us, paper.2),
            format!("{:.0} ({:.0})", r.lut_pct, paper.3),
            format!("{:.0} ({:.0})", r.bram_pct, paper.4),
            format!("{:.0} ({:.0})", r.power_mw, paper.5),
        ]);
    }
    println!("{}", t.render());

    // timing of the table generation path itself (design-flow speed claim:
    // "the advantage of having a fast design flow")
    let stats = bench(1, 5, || {
        flow::profile_report(&store, "A8-W8", &cfg).unwrap()
    });
    println!(
        "flow speed: one full profile report (parse+estimate+sim+power) in {} (p95 {})",
        fmt_dur(stats.mean),
        fmt_dur(stats.p95)
    );
}
