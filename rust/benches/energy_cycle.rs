//! Bench: the adaptive engine's degrade → recover → upswitch energy cycle.
//!
//! Needs no artifacts — a tiny synthetic QONNX model is served under two
//! profile names ("hi": 1 W accurate, "lo": 0.2 W degraded) by a one-shard
//! server whose battery carries a recharge source *between* the two draws
//! (0.6 W average). Under continuous load the trajectory is forced:
//!
//! 1. **degrade** — "hi" nets −0.4 W, the battery falls through the
//!    downswitch threshold and the shard moves to "lo";
//! 2. **recover** — "lo" nets +0.4 W, the battery climbs back through the
//!    hysteresis band;
//! 3. **upswitch** — the Profile Manager restores "hi".
//!
//! Recharge is integrated on *virtual* time (accumulated per-batch
//! `latency_us`), so the whole trajectory is deterministic — no wall
//! clock, no retries needed in CI. Two sources are exercised: a constant
//! harvest and a 50 ms on/off duty cycle whose off-phases brown the shard
//! out entirely before the on-phase revives it. Every reply is asserted
//! bit-exact against the scalar oracle (`exec::execute`) before any row is
//! reported — adaptivity must never change the integers.
//!
//! Run: `cargo bench --bench energy_cycle [-- <requests> [--json <path>]
//!       [--assert-recovery]]`
//!
//! `--json` writes one row per scenario (switch events, battery extrema,
//! recharge totals) for the CI artifact; `--assert-recovery` gates that
//! each scenario degrades below the threshold AND switches back to the
//! accurate profile on a recovered battery.

use std::collections::BTreeMap;

use onnx2hw::bench_harness::Table;
use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec,
    ServerConfig,
};
use onnx2hw::dataflow::exec;
use onnx2hw::json::{self, Value};
use onnx2hw::power::EnergySource;
use onnx2hw::qonnx::{read_str, test_model_json, QonnxModel};

const N_IMAGES: usize = 8;
const THRESHOLD: f64 = 0.5;
const HYSTERESIS: f64 = 0.02;
/// Sized so "hi" (net −0.4 W x 329 us/request) crosses the downswitch
/// after ~60 requests.
const CAPACITY_J: f64 = 1.5e-2;

fn profile_specs() -> Vec<ProfileSpec> {
    vec![
        ProfileSpec {
            name: "hi".into(),
            accuracy: 0.96,
            power_mw: 1000.0,
            latency_us: 329.0,
        },
        ProfileSpec {
            name: "lo".into(),
            accuracy: 0.94,
            power_mw: 200.0,
            latency_us: 329.0,
        },
    ]
}

struct SwitchEvent {
    request: usize,
    from: String,
    to: String,
    /// Shard battery fraction right after the switching request.
    battery: f64,
}

struct ScenarioResult {
    name: &'static str,
    source: EnergySource,
    requests: usize,
    switches: Vec<SwitchEvent>,
    min_fraction: f64,
    final_fraction: f64,
    recharged_j: f64,
    drained_j: f64,
    virtual_s: f64,
    /// Request index of the first degraded ("lo") reply.
    degrade: Option<usize>,
    /// Request index of the first "hi" reply after the first degrade.
    upswitch: Option<usize>,
}

fn run_scenario(
    name: &'static str,
    source: EnergySource,
    requests: usize,
    model: &QonnxModel,
) -> ScenarioResult {
    let models: BTreeMap<String, QonnxModel> = [
        ("hi".to_string(), model.clone()),
        ("lo".to_string(), model.clone()),
    ]
    .into_iter()
    .collect();
    let factory = move || Ok(Backend::sim_from_models(models.clone()));
    let manager = ProfileManager::new(
        ManagerConfig {
            low_energy_threshold: THRESHOLD,
            hysteresis: HYSTERESIS,
            accuracy_floor: 0.0,
        },
        profile_specs(),
    );
    let cfg = ServerConfig {
        recharge: source.clone(),
        ..Default::default()
    };
    let srv = AdaptiveServer::start(cfg, factory, manager, EnergyMonitor::new(CAPACITY_J))
        .expect("server");

    let elems = model.input_shape.elems();
    let images: Vec<Vec<u8>> = (0..N_IMAGES)
        .map(|k| (0..elems).map(|i| ((i * 31 + k * 17) % 256) as u8).collect())
        .collect();
    let expect: Vec<Vec<f32>> = images
        .iter()
        .map(|img| exec::execute(model, img).iter().map(|&v| v as f32).collect())
        .collect();

    let mut switches = Vec::new();
    let mut prev = String::new();
    let mut min_fraction = 1.0_f64;
    let mut degrade = None;
    let mut upswitch = None;
    // One synchronous client -> one request per batch: the battery walk is
    // a pure function of the request index.
    for i in 0..requests {
        let k = i % N_IMAGES;
        let resp = srv.classify(images[k].clone()).expect("reply lost");
        assert_eq!(resp.shard, 0, "single-shard run");
        assert_eq!(
            resp.logits,
            expect[k],
            "request {i} on '{}' not bit-exact vs the scalar oracle",
            resp.profile
        );
        let frac = srv.shard_energy[0].remaining_fraction();
        min_fraction = min_fraction.min(frac);
        if degrade.is_none() && resp.profile == "lo" {
            degrade = Some(i);
        }
        if degrade.is_some() && upswitch.is_none() && resp.profile == "hi" {
            upswitch = Some(i);
        }
        if !prev.is_empty() && prev != resp.profile {
            switches.push(SwitchEvent {
                request: i,
                from: prev.clone(),
                to: resp.profile.clone(),
                battery: frac,
            });
        }
        prev = resp.profile;
    }

    let monitor = &srv.shard_energy[0];
    let result = ScenarioResult {
        name,
        source,
        requests,
        min_fraction,
        final_fraction: monitor.remaining_fraction(),
        recharged_j: monitor.recharged_j(),
        drained_j: monitor.drained_j(),
        virtual_s: monitor.virtual_time_s(),
        degrade,
        upswitch,
        switches,
    };
    // conservation on the shard monitor: remaining == cap - drained + in
    let lhs = monitor.remaining_j();
    let rhs = monitor.capacity_j() - monitor.drained_j() + monitor.recharged_j();
    assert!(
        (lhs - rhs).abs() < 1e-12,
        "energy books out of balance: remaining {lhs} != {rhs}"
    );
    srv.shutdown();
    result
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut requests: usize = 400;
    let mut json_path: Option<String> = None;
    let mut assert_recovery = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => {
                i += 1;
                json_path = Some(args.get(i).expect("--json needs a path").clone());
            }
            "--assert-recovery" => assert_recovery = true,
            other => {
                requests = other.parse().unwrap_or_else(|_| {
                    panic!("unexpected argument '{other}' (want a request count)")
                });
            }
        }
        i += 1;
    }

    let model = read_str(&test_model_json(1, 2)).expect("model");
    let scenarios: Vec<(&'static str, EnergySource)> = vec![
        // steady 0.6 W harvest between the 0.2 W and 1 W draws
        ("constant", EnergySource::constant(600.0)),
        // same average power, delivered 50 ms on / 50 ms off: the
        // off-phases brown the shard out before the on-phase revives it
        ("duty-cycle", EnergySource::duty_cycle(1200.0, 0.05, 0.05)),
    ];

    let mut table = Table::new(&[
        "scenario", "requests", "switches", "degrade@", "upswitch@", "min batt", "final batt",
        "recharged",
    ]);
    let mut results = Vec::new();
    for (name, source) in scenarios {
        let r = run_scenario(name, source, requests, &model);
        table.row(&[
            r.name.to_string(),
            r.requests.to_string(),
            r.switches.len().to_string(),
            r.degrade.map_or("-".into(), |i| i.to_string()),
            r.upswitch.map_or("-".into(), |i| i.to_string()),
            format!("{:.1}%", r.min_fraction * 100.0),
            format!("{:.1}%", r.final_fraction * 100.0),
            format!("{:.3} mJ", r.recharged_j * 1e3),
        ]);
        results.push(r);
    }

    println!(
        "== adaptive energy cycle (Sim backend, 1 shard, capacity {:.1} mJ, \
         threshold {THRESHOLD} +/- {HYSTERESIS}) ==\n",
        CAPACITY_J * 1e3
    );
    println!("{}", table.render());
    println!("bit-exactness vs exec::execute and energy conservation asserted on");
    println!("every reply before any row above was reported.");

    if let Some(path) = &json_path {
        let rows = Value::Array(
            results
                .iter()
                .map(|r| {
                    Value::obj(vec![
                        ("scenario", r.name.into()),
                        ("source", r.source.label().into()),
                        ("requests", r.requests.into()),
                        ("capacity_j", CAPACITY_J.into()),
                        ("threshold", THRESHOLD.into()),
                        ("hysteresis", HYSTERESIS.into()),
                        ("min_battery_fraction", r.min_fraction.into()),
                        ("final_battery_fraction", r.final_fraction.into()),
                        ("recharged_j", r.recharged_j.into()),
                        ("drained_j", r.drained_j.into()),
                        ("virtual_time_s", r.virtual_s.into()),
                        ("degrade_at", r.degrade.map_or(Value::Int(-1), Value::from)),
                        ("upswitch_at", r.upswitch.map_or(Value::Int(-1), Value::from)),
                        (
                            "switches",
                            Value::Array(
                                r.switches
                                    .iter()
                                    .map(|s| {
                                        Value::obj(vec![
                                            ("request", s.request.into()),
                                            ("from", s.from.clone().into()),
                                            ("to", s.to.clone().into()),
                                            ("battery_fraction", s.battery.into()),
                                        ])
                                    })
                                    .collect(),
                            ),
                        ),
                    ])
                })
                .collect(),
        );
        std::fs::write(path, json::to_string_pretty(&rows)).expect("write json");
        println!("wrote {} rows to {path}", results.len());
    }

    if assert_recovery {
        for r in &results {
            let degrade = r.degrade.unwrap_or_else(|| {
                panic!("{}: engine never degraded (min battery {:.3})", r.name, r.min_fraction)
            });
            assert!(
                r.min_fraction < THRESHOLD - HYSTERESIS,
                "{}: battery never fell below the downswitch threshold: {:.3}",
                r.name,
                r.min_fraction
            );
            let upswitch = r.upswitch.unwrap_or_else(|| {
                panic!(
                    "{}: degraded at request {degrade} but never switched back \
                     (final battery {:.3})",
                    r.name, r.final_fraction
                )
            });
            // the switch event carrying the upswitch must have happened on
            // a recovered battery
            let ev = r
                .switches
                .iter()
                .find(|s| s.request == upswitch && s.to == "hi")
                .expect("upswitch event recorded");
            assert!(
                ev.battery > THRESHOLD,
                "{}: upswitched at battery {:.3} <= threshold {THRESHOLD}",
                r.name,
                ev.battery
            );
            assert!(r.recharged_j > 0.0, "{}: recharge never banked energy", r.name);
        }
        println!(
            "recovery gate passed: every scenario degraded below {:.2} and \
             upswitched on a recovered battery",
            THRESHOLD - HYSTERESIS
        );
    }
}
