//! Bench: the approximation explorer's Pareto ladder vs the naive
//! uniform-precision baseline — and the ladder served end to end.
//!
//! Needs no artifacts: a deterministic synthetic two-conv model (seeded
//! generator) is explored against a seeded self-labelled calibration set,
//! so every number here is reproducible bit-for-bit — no wall clock, no
//! global RNG, no retries needed in CI. Three things are measured/gated:
//!
//! 1. **Frontier quality** — the explorer's per-layer search must emit a
//!    >= 4-rung Pareto ladder whose points cover every uniform-precision
//!    baseline rung (drop k bits everywhere — the allocation that ignores
//!    per-layer sensitivity) and strictly dominate the baseline.
//! 2. **Bit-exactness** — every candidate is evaluated on the packed batch
//!    kernels and cross-checked against the scalar oracle inside the
//!    explorer; this bench re-asserts it per frontier rung across batch
//!    sizes, and again on every serving reply below.
//! 3. **End-to-end serving** — the auto-generated ladder is loaded into an
//!    `AdaptiveServer` via `ProfileManager::from_frontier` +
//!    `Backend::sim_from_models`; under a draining battery the shard must
//!    walk down the ladder monotonically, serving >= 3 distinct rungs,
//!    with each reply bit-exact vs the scalar oracle *of its selected
//!    rung's derived model*.
//!
//! Run: `cargo bench --bench pareto_explore [-- [requests]
//!       [--json <path>] [--assert-dominates]]`

use std::collections::BTreeMap;
use std::time::Instant;

use onnx2hw::approx::{CalibSet, Explorer, ExplorerConfig, Frontier};
use onnx2hw::bench_harness::Table;
use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ServerConfig,
};
use onnx2hw::dataflow::{exec, BatchExecutor};
use onnx2hw::json::{self, Value};
use onnx2hw::qonnx::{
    bound_stress_model_json, prune_stress_model_json, random_model_json, read_str, QonnxModel,
    RandModelCfg,
};
use onnx2hw::testkit::Rng;

/// Seeds are the determinism contract: same seeds -> same model, same
/// calibration workload, same frontier. Cross-validated against an
/// independent Python port of the generator/executor/transform.
const MODEL_SEED: u64 = 0xA11CE;
const CALIB_SEED: u64 = 0x5EED5;
const CALIB_N: usize = 96;
const UNIFORM_RUNGS: usize = 4;
const MIN_FRONTIER_RUNGS: usize = 4;
const MIN_SERVED_RUNGS: usize = 3;

fn bench_model() -> QonnxModel {
    let cfg = RandModelCfg {
        side: 8,
        cin: 1,
        blocks: vec![(4, 8, 8), (8, 8, 8)],
        classes: 5,
    };
    read_str(&random_model_json(&cfg, &mut Rng::new(MODEL_SEED))).expect("bench model")
}

/// Re-assert packed-vs-oracle bit-exactness for one derived rung across
/// the batcher's envelope (the explorer already checked its first replies;
/// this covers partial and full batches too).
fn assert_rung_bit_exact(model: &QonnxModel, calib: &CalibSet) {
    let mut ex = BatchExecutor::from_model(model);
    let k = ex.out_features();
    for &batch in &[1usize, 3, 8] {
        let refs: Vec<&[u8]> = calib.images.iter().take(batch).map(Vec::as_slice).collect();
        let got = ex.run_batch(&refs).to_vec();
        for (i, img) in refs.iter().enumerate() {
            assert_eq!(
                &got[i * k..(i + 1) * k],
                exec::execute(model, img).as_slice(),
                "rung '{}' batch {batch} image {i} diverges from the scalar oracle",
                model.profile
            );
        }
    }
}

/// Static pre-pruning must be a pure speedup: on a model whose knob
/// lattice has a large illegal region (bit-drops that zero the dense
/// head), the pruned and unpruned explorers must emit byte-identical
/// frontier JSON while the pruned run evaluates strictly fewer
/// candidates — `evaluations() + pruned_static()` matches the unpruned
/// run's `evaluations()` exactly.
fn assert_pruning_equivalence() {
    let model = read_str(&prune_stress_model_json()).expect("stress model");
    let calib = CalibSet::self_labeled(&model, 16, CALIB_SEED);
    let run = |static_prune: bool| {
        let mut ex = Explorer::new(
            &model,
            &calib,
            ExplorerConfig {
                power_images: 1,
                uniform_rungs: 2,
                static_prune,
                ..Default::default()
            },
        );
        let f = ex.explore();
        (json::to_string_pretty(&f.to_json()), ex.evaluations(), ex.pruned_static())
    };
    let (pruned_json, pruned_evals, pruned_n) = run(true);
    let (full_json, full_evals, full_n) = run(false);
    assert_eq!(pruned_json, full_json, "static pruning changed the frontier");
    assert_eq!(full_n, 0, "the unpruned run must not prune anything");
    assert!(pruned_n > 0, "the stress lattice must exercise the pruner");
    assert!(
        pruned_evals < full_evals,
        "pruning must skip evaluations ({pruned_evals} vs {full_evals})"
    );
    assert_eq!(
        pruned_evals + pruned_n,
        full_evals,
        "pruned evaluations + pruned configs must equal the unpruned evaluations"
    );
    println!(
        "static pruning gate: {pruned_evals} evaluations + {pruned_n} pruned == \
         {full_evals} unpruned, frontier byte-identical"
    );
}

/// Error-bound triage must be a pure speedup: on a model whose lattice has
/// certified-exact weight drops (skip the accuracy pass, reuse the root's
/// accuracy) and large-proven-deviation drops (rejected by the logit-bound
/// tolerance before evaluation), the triaged and untriaged explorers must
/// emit byte-identical frontier JSON while the triaged run pays strictly
/// fewer packed-executor accuracy passes — with every skip and rejection
/// accounted for by the counters.
fn assert_bound_triage_equivalence() -> (usize, usize) {
    let model = read_str(&bound_stress_model_json()).expect("bound-stress model");
    let calib = CalibSet::self_labeled(&model, 16, CALIB_SEED);
    let run = |bound_triage: bool| {
        let mut ex = Explorer::new(
            &model,
            &calib,
            ExplorerConfig {
                power_images: 1,
                uniform_rungs: 2,
                logit_bound_tolerance: Some(8),
                bound_triage,
                ..Default::default()
            },
        );
        let f = ex.explore();
        (
            json::to_string_pretty(&f.to_json()),
            ex.evaluations(),
            ex.accuracy_evaluations(),
            ex.skipped_by_bounds(),
            ex.rejected_by_bounds(),
        )
    };
    let (triaged_json, t_evals, t_acc, t_skipped, t_rejected) = run(true);
    let (full_json, f_evals, f_acc, f_skipped, f_rejected) = run(false);
    assert_eq!(triaged_json, full_json, "bound triage changed the frontier");
    assert_eq!(f_skipped, 0, "the untriaged run must not skip");
    assert_eq!(f_rejected, 0, "the untriaged run must not reject");
    assert_eq!(f_acc, f_evals, "untriaged evaluations are all measured");
    assert!(t_skipped > 0, "certified weight drops must skip the accuracy pass");
    assert!(t_rejected > 0, "the tolerance must reject over-bound candidates");
    assert!(
        t_acc < f_acc,
        "triage must skip accuracy passes ({t_acc} vs {f_acc})"
    );
    assert_eq!(
        t_evals,
        t_acc + t_skipped,
        "every evaluation is either measured or certificate-skipped"
    );
    assert_eq!(
        t_evals + t_rejected,
        f_evals,
        "triaged evaluations + rejections must equal the untriaged evaluations"
    );
    println!(
        "bound triage gate: {t_acc} accuracy passes + {t_skipped} certified skips + \
         {t_rejected} tolerance rejections == {f_evals} untriaged evaluations, \
         frontier byte-identical"
    );
    (t_skipped, t_rejected)
}

struct ServeResult {
    requests: usize,
    served_rungs: Vec<String>,
    switches: u64,
}

/// Serve the auto-generated ladder end to end and prove the walk.
fn serve_ladder(frontier: &Frontier, calib: &CalibSet, requests: usize) -> ServeResult {
    let models = frontier.models();
    let oracle: BTreeMap<String, QonnxModel> = models.clone();
    let manager = ProfileManager::from_frontier(
        ManagerConfig {
            low_energy_threshold: 0.6,
            hysteresis: 0.01,
            accuracy_floor: 0.0,
        },
        frontier,
    );
    let factory = move || Ok(Backend::sim_from_models(models.clone()));
    // Battery sized so the top rung alone would drain it well before the
    // run ends: the shard is forced through every band down to the
    // cheapest rung (drain-only, so the walk must be monotone).
    let top = &frontier.points[0];
    let per_request_j = top.power_mw * 1e-3 * top.latency_us * 1e-6;
    let capacity_j = per_request_j * requests as f64 / 4.0;
    let srv = AdaptiveServer::start(
        ServerConfig::default(),
        factory,
        manager,
        EnergyMonitor::new(capacity_j),
    )
    .expect("server");

    let rung_of = |name: &str| -> usize {
        frontier
            .points
            .iter()
            .position(|p| p.name == name)
            .expect("reply profile must be a frontier rung")
    };
    let mut served = Vec::new();
    let mut prev_rung = 0usize;
    for i in 0..requests {
        let img = &calib.images[i % calib.images.len()];
        let resp = srv.classify(img.clone()).expect("reply lost");
        let want: Vec<f32> = exec::execute(&oracle[&resp.profile], img)
            .iter()
            .map(|&v| v as f32)
            .collect();
        assert_eq!(
            resp.logits, want,
            "request {i} not bit-exact vs the oracle of rung '{}'",
            resp.profile
        );
        let rung = rung_of(&resp.profile);
        assert!(
            rung >= prev_rung,
            "drain-only battery walked back up the ladder: {prev_rung} -> {rung}"
        );
        prev_rung = rung;
        if served.last() != Some(&resp.profile) {
            served.push(resp.profile);
        }
    }
    let switches = srv.stats.switches.get();
    srv.shutdown();
    ServeResult {
        requests,
        served_rungs: served,
        switches,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut requests: usize = 1200;
    let mut json_path: Option<String> = None;
    let mut assert_dominates = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => {
                i += 1;
                json_path = Some(args.get(i).expect("--json needs a path").clone());
            }
            "--assert-dominates" => assert_dominates = true,
            other => {
                requests = other.parse().unwrap_or_else(|_| {
                    panic!("unexpected argument '{other}' (want a request count)")
                });
            }
        }
        i += 1;
    }

    let model = bench_model();
    let calib = CalibSet::self_labeled(&model, CALIB_N, CALIB_SEED);
    let mut explorer = Explorer::new(
        &model,
        &calib,
        ExplorerConfig {
            power_images: 1,
            uniform_rungs: UNIFORM_RUNGS,
            ..Default::default()
        },
    );
    #[allow(clippy::disallowed_methods)] // wall-clock: reported explore time
    let t0 = Instant::now();
    let frontier = explorer.explore();
    let explore_s = t0.elapsed().as_secs_f64();
    let baseline = explorer.uniform_baseline();

    println!(
        "== pareto_explore: {} ({}) | {} calib images | {} candidates in {:.2}s ==\n",
        model.profile,
        model.precision_signature(),
        calib.len(),
        explorer.evaluations(),
        explore_s
    );
    let mut table =
        Table::new(&["rung", "profile", "precisions", "accuracy", "power", "energy/inf"]);
    for (i, p) in frontier.points.iter().enumerate() {
        assert_rung_bit_exact(&p.model, &calib);
        table.row(&[
            i.to_string(),
            p.name.clone(),
            p.model.precision_signature(),
            format!("{:.1}%", p.accuracy * 100.0),
            format!("{:.1} mW", p.power_mw),
            format!("{:.3} uJ", p.energy_uj),
        ]);
    }
    println!("{}", table.render());

    let mut strict = 0usize;
    let mut covered = 0usize;
    let mut baseline_rows = Vec::new();
    for (k, b) in baseline.iter().enumerate() {
        let weak = frontier.weakly_dominates(b.accuracy, b.energy_uj, b.latency_us);
        let beats = frontier.strictly_dominates(b.accuracy, b.energy_uj, b.latency_us);
        covered += weak as usize;
        strict += beats as usize;
        println!(
            "uniform rung {}: acc {:>5.1}% energy {:.3} uJ -> {}",
            k + 1,
            b.accuracy * 100.0,
            b.energy_uj,
            if beats { "strictly dominated" } else { "covered" }
        );
        baseline_rows.push(Value::obj(vec![
            ("rung", (k + 1).into()),
            ("accuracy", b.accuracy.into()),
            ("energy_uj", b.energy_uj.into()),
            ("weakly_dominated", weak.into()),
            ("strictly_dominated", beats.into()),
        ]));
    }

    // JSON schema round trip through the vendored module before writing.
    let frontier_json = frontier.to_json();
    let reparsed = json::parse(&json::to_string_pretty(&frontier_json)).expect("round trip parse");
    let back = Frontier::from_json(&reparsed, &model).expect("round trip load");
    assert_eq!(back.len(), frontier.len(), "frontier JSON round trip lost rungs");

    assert_pruning_equivalence();
    let (triage_skipped, triage_rejected) = assert_bound_triage_equivalence();

    let serve = serve_ladder(&frontier, &calib, requests);
    println!(
        "\nserved {} requests on the auto-generated ladder: rung walk {:?} \
         ({} switches), every reply bit-exact vs its rung's oracle",
        serve.requests, serve.served_rungs, serve.switches
    );

    if let Some(path) = &json_path {
        let doc = Value::obj(vec![
            ("bench", "pareto_explore".into()),
            ("calib_images", CALIB_N.into()),
            ("evaluations", explorer.evaluations().into()),
            ("accuracy_evaluations", explorer.accuracy_evaluations().into()),
            ("candidates_pruned_static", explorer.pruned_static().into()),
            // Counters from the bound-triage equivalence gate (the main
            // random model has no certified drops and no tolerance set, so
            // its own counters are structurally zero).
            ("candidates_skipped_by_bounds", triage_skipped.into()),
            ("candidates_rejected_by_bounds", triage_rejected.into()),
            ("explore_seconds", explore_s.into()),
            ("frontier", frontier_json),
            ("baseline", Value::Array(baseline_rows)),
            (
                "serving",
                Value::obj(vec![
                    ("requests", serve.requests.into()),
                    (
                        "served_rungs",
                        Value::Array(
                            serve.served_rungs.iter().map(|s| s.as_str().into()).collect(),
                        ),
                    ),
                    ("switches", (serve.switches as i64).into()),
                ]),
            ),
        ]);
        std::fs::write(path, json::to_string_pretty(&doc)).expect("write json");
        println!("wrote frontier + gates to {path}");
    }

    if assert_dominates {
        assert!(
            frontier.len() >= MIN_FRONTIER_RUNGS,
            "frontier has {} rungs, need >= {MIN_FRONTIER_RUNGS}",
            frontier.len()
        );
        assert_eq!(
            covered,
            baseline.len(),
            "every uniform baseline rung must be weakly dominated"
        );
        assert_eq!(
            strict,
            baseline.len(),
            "every uniform baseline rung must be strictly dominated \
             (got {strict}/{})",
            baseline.len()
        );
        assert!(
            serve.served_rungs.len() >= MIN_SERVED_RUNGS,
            "ladder walk served {} distinct rungs, need >= {MIN_SERVED_RUNGS}: {:?}",
            serve.served_rungs.len(),
            serve.served_rungs
        );
        println!(
            "\ndominance gate passed: {}-rung frontier, {strict}/{} baseline rungs \
             strictly dominated, {} rungs served end-to-end",
            frontier.len(),
            baseline.len(),
            serve.served_rungs.len()
        );
    }
}
