//! Bench: packed batched kernels vs the scalar reference executor.
//!
//! Needs no artifacts — two synthetic QONNX models bracket the envelope:
//!
//! * `conv-heavy` — 16x16x3 -> conv32 -> pool -> conv64 -> pool -> dense10
//!   (~1.4 MMAC/image, the serving-shaped load the CI gate measures);
//! * `conv-light` — the 8/16-filter, 4-bit-weight variant.
//!
//! For each model the *scalar baseline* pushes the image set one image at a
//! time through the cached reference [`Executor`] (the oracle path every
//! accuracy sweep uses); the *packed path* hands the same images to
//! [`BatchExecutor::run_batch`] at batch sizes 1/3/8 (pre-packed weight
//! tiles, batch-major/layer-major order, warm arenas). Before any number is
//! reported, every (model, batch) pairing is asserted bit-exact against
//! `exec::execute` — packing and tiling must never change an integer.
//!
//! Run: `cargo bench --bench kernel_batch [-- <iters> [--json <path>]
//!       [--assert-speedup <factor>]]`
//!
//! `--json` writes the rows (imgs/s, speedup vs scalar, per-iteration
//! p50/p99 latency) for the CI artifact; `--assert-speedup F` requires the
//! conv-heavy packed batch-8 throughput >= F x the scalar per-image
//! baseline — the kernel-level gate beneath the serving-level scaling gate.

use onnx2hw::bench_harness::{bench, Table};
use onnx2hw::dataflow::{exec, BatchExecutor, Executor};
use onnx2hw::json::{self, Value};
use onnx2hw::qonnx::{self, read_str, QonnxModel, RandModelCfg};
use onnx2hw::testkit::Rng;

const WARMUP: usize = 3;
const BATCHES: [usize; 3] = [1, 3, 8];
const N_IMAGES: usize = 8;

fn synthetic_models() -> Vec<(&'static str, QonnxModel)> {
    let mut rng = Rng::new(23);
    let heavy_cfg = RandModelCfg {
        side: 16,
        cin: 3,
        blocks: vec![(32, 8, 8), (64, 8, 8)],
        classes: 10,
    };
    let light_cfg = RandModelCfg {
        blocks: vec![(8, 8, 4), (16, 8, 4)],
        ..heavy_cfg.clone()
    };
    let heavy = read_str(&qonnx::random_model_json(&heavy_cfg, &mut rng)).expect("heavy");
    let light = read_str(&qonnx::random_model_json(&light_cfg, &mut rng)).expect("light");
    vec![("conv-heavy", heavy), ("conv-light", light)]
}

fn images_for(model: &QonnxModel) -> Vec<Vec<u8>> {
    let elems = model.input_shape.elems();
    (0..N_IMAGES)
        .map(|k| (0..elems).map(|i| ((i * 31 + k * 17) % 256) as u8).collect())
        .collect()
}

/// Every batch size must reproduce the oracle's integers exactly before
/// any throughput number is trusted (this also warms the arenas).
fn assert_bit_exact(model: &QonnxModel, bex: &mut BatchExecutor, images: &[Vec<u8>]) {
    let k = bex.out_features();
    for &b in &BATCHES {
        let refs: Vec<&[u8]> = images[..b].iter().map(Vec::as_slice).collect();
        let got = bex.run_batch(&refs).to_vec();
        for (i, img) in refs.iter().enumerate() {
            let want = exec::execute(model, img);
            assert_eq!(
                &got[i * k..(i + 1) * k],
                want.as_slice(),
                "batch {b} image {i} not bit-exact vs the scalar oracle"
            );
        }
    }
}

struct Row {
    model: &'static str,
    path: &'static str,
    batch: usize,
    imgs_per_s: f64,
    speedup: f64,
    p50_us: f64,
    p99_us: f64,
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut iters: usize = 24;
    let mut json_path: Option<String> = None;
    let mut assert_speedup: Option<f64> = None;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => {
                i += 1;
                json_path = Some(args.get(i).expect("--json needs a path").clone());
            }
            "--assert-speedup" => {
                i += 1;
                assert_speedup = Some(
                    args.get(i)
                        .expect("--assert-speedup needs a factor")
                        .parse()
                        .expect("--assert-speedup: not a number"),
                );
            }
            other => {
                iters = other.parse().unwrap_or_else(|_| {
                    panic!("unexpected argument '{other}' (want an iteration count)")
                });
            }
        }
        i += 1;
    }

    let mut table = Table::new(&["model", "path", "batch", "imgs/s", "speedup", "p50", "p99"]);
    let mut rows: Vec<Row> = Vec::new();
    for (name, model) in synthetic_models() {
        let images = images_for(&model);
        let mut bex = BatchExecutor::from_model(&model);
        assert_bit_exact(&model, &mut bex, &images);

        // Scalar per-image baseline: one iteration = the whole image set
        // through the cached reference executor, image by image.
        let mut scalar_ex = Executor::new(&model);
        let s = bench(WARMUP, iters, || {
            let mut sink = 0i64;
            for img in &images {
                sink = sink.wrapping_add(scalar_ex.run(img)[0]);
            }
            sink
        });
        let scalar_imgs_per_s = N_IMAGES as f64 / s.mean.as_secs_f64();
        rows.push(Row {
            model: name,
            path: "scalar",
            batch: 1,
            imgs_per_s: scalar_imgs_per_s,
            speedup: 1.0,
            p50_us: s.p50.as_secs_f64() * 1e6,
            p99_us: s.p99.as_secs_f64() * 1e6,
        });

        for &b in &BATCHES {
            let refs: Vec<&[u8]> = images[..b].iter().map(Vec::as_slice).collect();
            let s = bench(WARMUP, iters, || {
                bex.run_batch(&refs).iter().fold(0i64, |a, &v| a.wrapping_add(v))
            });
            rows.push(Row {
                model: name,
                path: "packed",
                batch: b,
                imgs_per_s: b as f64 / s.mean.as_secs_f64(),
                speedup: (b as f64 / s.mean.as_secs_f64()) / scalar_imgs_per_s,
                p50_us: s.p50.as_secs_f64() * 1e6,
                p99_us: s.p99.as_secs_f64() * 1e6,
            });
        }
    }

    for r in &rows {
        table.row(&[
            r.model.to_string(),
            r.path.to_string(),
            r.batch.to_string(),
            format!("{:.0}", r.imgs_per_s),
            format!("x{:.2}", r.speedup),
            format!("{:.0}us", r.p50_us),
            format!("{:.0}us", r.p99_us),
        ]);
    }
    println!(
        "== packed batched kernels vs scalar oracle ({iters} iters, \
         {N_IMAGES}-image set) ==\n"
    );
    println!("{}", table.render());
    println!("bit-exactness vs exec::execute asserted for every (model, batch)");
    println!("before any row above was timed. p50/p99 are per-iteration wall");
    println!("times (scalar iteration = {N_IMAGES} images; packed = its batch).");

    if let Some(path) = &json_path {
        let json_rows = Value::Array(
            rows.iter()
                .map(|r| {
                    Value::obj(vec![
                        ("model", r.model.into()),
                        ("path", r.path.into()),
                        ("batch", r.batch.into()),
                        ("iters", iters.into()),
                        ("imgs_per_s", r.imgs_per_s.into()),
                        ("speedup_vs_scalar", r.speedup.into()),
                        ("p50_us", r.p50_us.into()),
                        ("p99_us", r.p99_us.into()),
                    ])
                })
                .collect(),
        );
        std::fs::write(path, json::to_string_pretty(&json_rows)).expect("write json");
        println!("wrote {} rows to {path}", rows.len());
    }

    if let Some(factor) = assert_speedup {
        let gate = rows
            .iter()
            .find(|r| r.model == "conv-heavy" && r.path == "packed" && r.batch == 8)
            .expect("gate row present");
        assert!(
            gate.speedup >= factor,
            "packed batch-8 throughput {:.0} imgs/s is x{:.2} of the scalar \
             baseline, below the required x{factor}",
            gate.imgs_per_s,
            gate.speedup
        );
        println!(
            "kernel gate passed: conv-heavy packed batch-8 = x{:.2} of scalar \
             (>= {factor}), {} vs {} imgs/s",
            gate.speedup,
            gate.imgs_per_s as u64,
            (gate.imgs_per_s / gate.speedup) as u64
        );
    }
}
