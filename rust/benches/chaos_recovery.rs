//! Bench: chaos recovery — deterministic fault injection through the full
//! TCP serving stack, gated on the self-healing invariants.
//!
//! A seeded [`FaultPlan`] (same seed -> byte-identical plan, embedded in
//! `chaos.json`) schedules worker panics and battery brown-outs on the
//! spine's batch clock plus connection kills and a corrupt frame on the
//! wire path's request clock. Two [`ResilientClient`] drivers push requests
//! through the storm; the run then must prove it healed:
//!
//! * **Every request resolves** — bit-exact `Ok` against the scalar oracle
//!   (`exec::execute`) or a typed `Err`; zero hangs (each driver call is
//!   deadline-bounded).
//! * **Every planned fault fires** and every observed shard death is
//!   matched by a supervisor respawn.
//! * **Served fraction stays >= 0.9** despite the casualties: a death
//!   costs at most the in-hand batch, and retries absorb the resets.
//! * **Gauges conserve** — spine queue/shard depth gauges and the front
//!   end's in-flight/connection gauges all read zero after the drain, and
//!   every shard's battery books balance
//!   (`remaining == capacity - drained + recharged`).
//!
//! Run: `cargo bench --bench chaos_recovery [-- <requests>
//!       [--json <path>] [--assert-recovery]]`
//!
//! `chaos.json` holds only seed-derived values and gate outcomes — no
//! measured latencies — so identical fault seeds yield byte-identical
//! artifacts.

use std::collections::BTreeMap;
use std::io::{BufReader, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec,
    ServerConfig, ServerStats,
};
use onnx2hw::dataflow::exec;
use onnx2hw::fault::{FaultPlan, FaultSpec, WireFaultKind};
use onnx2hw::json::{self, Value};
use onnx2hw::net::{
    read_frame, ErrCode, FrameKind, NetServer, NetServerConfig, ResilientClient, RetryPolicy,
    DEFAULT_MAX_PAYLOAD,
};
use onnx2hw::qonnx::{read_str, test_model_json, QonnxModel};

const N_IMAGES: usize = 8;
const SERVICE_US: f64 = 329.0;
const SHARDS: usize = 4;
const SEED: u64 = 7;
const DRIVERS: usize = 2;
/// Per-request end-to-end budget: generous against scheduler noise, tight
/// enough that a genuine hang fails the run instead of wedging CI.
const DEADLINE: Duration = Duration::from_secs(10);
const SERVED_FRACTION_MIN: f64 = 0.9;

/// What one chaos run produced (counts only; latency is not gated here).
struct ChaosResult {
    offered: usize,
    oks: usize,
    errs: usize,
    deaths: usize,
    restarts: u64,
    retries: u64,
    reconnects: u64,
    resets_applied: usize,
    corruptions_applied: usize,
}

/// Shard deaths observed so far, read from the event log (each death logs
/// exactly one "shard marked dead" line).
fn count_deaths(stats: &ServerStats) -> usize {
    stats
        .events
        .snapshot()
        .iter()
        .filter(|e| e.contains("shard marked dead"))
        .count()
}

/// Wait (wall clock, unasserted content) for `cond`; panics after ~5 s so a
/// lost recovery fails loudly instead of hanging the bench.
#[allow(clippy::disallowed_methods)] // wall-clock: polling an async recovery
fn wait_until(what: &str, cond: impl Fn() -> bool) {
    for _ in 0..500 {
        if cond() {
            return;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    panic!("timed out waiting for {what}");
}

/// Open a raw socket, write deliberate garbage, and assert the protocol
/// contract: one typed `BadRequest` error frame, then the connection
/// closes. Returns true when the contract held.
fn inject_corrupt_frame(addr: &str) -> bool {
    let Ok(mut stream) = TcpStream::connect(addr) else {
        return false;
    };
    // 18 bytes of junk: wrong magic, so the reader rejects the header
    // before trusting anything else in it.
    if stream.write_all(&[0xA5u8; 18]).is_err() || stream.flush().is_err() {
        return false;
    }
    let mut reader = BufReader::new(stream);
    let denied = match read_frame(&mut reader, DEFAULT_MAX_PAYLOAD) {
        Ok(frame) => {
            frame.kind == FrameKind::Error
                && onnx2hw::net::decode_error(&frame.payload)
                    .is_ok_and(|(code, _)| code == ErrCode::BadRequest)
        }
        Err(_) => false,
    };
    // After the typed denial the server must close: the next read is EOF.
    let closed = read_frame(&mut reader, DEFAULT_MAX_PAYLOAD).is_err();
    denied && closed
}

fn run_chaos(requests: usize, plan: &FaultPlan) -> ChaosResult {
    let model = read_str(&test_model_json(1, 2)).expect("model");
    let elems = model.input_shape.elems();
    let models: BTreeMap<String, QonnxModel> = [
        ("hi".to_string(), model.clone()),
        ("lo".to_string(), model.clone()),
    ]
    .into_iter()
    .collect();
    let factory = move || Ok(Backend::sim_from_models(models.clone()));
    // Same model under both profiles: a brown-out survivor rejoins on "lo"
    // and its replies must STILL be bit-exact — degraded fidelity is a
    // latency/power statement here, never a different integer.
    let specs = vec![
        ProfileSpec {
            name: "hi".into(),
            accuracy: 0.96,
            power_mw: 142.0,
            latency_us: SERVICE_US,
        },
        ProfileSpec {
            name: "lo".into(),
            accuracy: 0.94,
            power_mw: 76.0,
            latency_us: SERVICE_US,
        },
    ];
    let manager = ProfileManager::new(ManagerConfig::default(), specs);
    let injector = Arc::new(plan.injector());
    let srv = AdaptiveServer::start(
        ServerConfig {
            workers: SHARDS,
            // Short deterministic backoff so every respawn lands well
            // inside the run's batch budget.
            restart_backoff_batches: 2,
            faults: Some(injector.clone()),
            ..Default::default()
        },
        factory,
        manager,
        EnergyMonitor::new(10.0),
    )
    .expect("server");
    let srv_stats = srv.stats.clone();
    let net = NetServer::start(
        NetServerConfig {
            expected_image_len: Some(elems),
            ..Default::default()
        },
        srv.client(),
    )
    .expect("net server");
    let net_stats = net.stats.clone();
    let addr = net.addr().to_string();

    let patterns: Arc<Vec<Vec<u8>>> = Arc::new(
        (0..N_IMAGES)
            .map(|k| (0..elems).map(|i| ((i * 31 + k * 17) % 256) as u8).collect())
            .collect(),
    );
    let expect: Arc<Vec<Vec<f32>>> = Arc::new(
        patterns
            .iter()
            .map(|img| exec::execute(&model, img).iter().map(|&v| v as f32).collect())
            .collect(),
    );

    // Submitted-request clock the wire faults trigger on.
    let submitted = Arc::new(AtomicU64::new(0));

    // Chaos thread: applies each wire fault once its request trigger
    // passes. It exits once the schedule is exhausted (the drivers push the
    // clock well past every trigger).
    let wire_plan = plan.wire.clone();
    let c_submitted = submitted.clone();
    let c_addr = addr.clone();
    let c_net = Arc::new(net);
    let chaos_net = c_net.clone();
    let chaos = std::thread::spawn(move || {
        let mut resets_applied = 0usize;
        let mut corruptions_applied = 0usize;
        let mut pending: Vec<_> = wire_plan;
        while !pending.is_empty() {
            let now = c_submitted.load(Ordering::SeqCst);
            let mut i = 0;
            while i < pending.len() {
                if pending[i].at_request > now {
                    i += 1;
                    continue;
                }
                match pending.swap_remove(i).kind {
                    WireFaultKind::Reset => {
                        chaos_net.reset_connections();
                        resets_applied += 1;
                    }
                    WireFaultKind::Corrupt => {
                        assert!(
                            inject_corrupt_frame(&c_addr),
                            "corrupt frame must earn a typed BadRequest + close"
                        );
                        corruptions_applied += 1;
                    }
                }
            }
            #[allow(clippy::disallowed_methods)] // wall-clock: paced fault injection
            std::thread::sleep(Duration::from_millis(1));
        }
        (resets_applied, corruptions_applied)
    });

    // Driver threads: interleaved request ranges, one resilient connection
    // each. Every call resolves — bit-exact Ok or typed Err — inside the
    // deadline, whatever the chaos thread does to the sockets underneath.
    let mut drivers = Vec::new();
    for t in 0..DRIVERS {
        let addr = addr.clone();
        let patterns = patterns.clone();
        let expect = expect.clone();
        let submitted = submitted.clone();
        drivers.push(std::thread::spawn(move || {
            let mut client = ResilientClient::new(
                &addr,
                RetryPolicy {
                    max_attempts: 6,
                    base_backoff: Duration::from_millis(1),
                    max_backoff: Duration::from_millis(8),
                    seed: SEED + t as u64,
                },
            )
            .with_deadline(DEADLINE);
            let mut oks = 0usize;
            let mut errs = 0usize;
            for i in (t..requests).step_by(DRIVERS) {
                submitted.fetch_add(1, Ordering::SeqCst);
                match client.classify(&patterns[i % N_IMAGES]) {
                    Ok(resp) => {
                        assert_eq!(
                            resp.logits,
                            expect[i % N_IMAGES],
                            "request {i} on '{}' not bit-exact vs the scalar oracle",
                            resp.profile
                        );
                        oks += 1;
                    }
                    Err(_) => errs += 1,
                }
            }
            (oks, errs, client.retries(), client.reconnects())
        }));
    }

    let mut oks = 0usize;
    let mut errs = 0usize;
    let mut retries = 0u64;
    let mut reconnects = 0u64;
    for d in drivers {
        let (o, e, r, c) = d.join().expect("driver thread");
        oks += o;
        errs += e;
        retries += r;
        reconnects += c;
    }
    let (resets_applied, corruptions_applied) = chaos.join().expect("chaos thread");

    // Recovery probes: trickle requests so the batch clock keeps moving
    // until every planned spine fault has fired and the supervisor has
    // respawned every observed death. A probe may itself take a fault —
    // that is the point — so its result is not gated, only counted.
    let mut probe = ResilientClient::new(
        &addr,
        RetryPolicy {
            max_attempts: 6,
            seed: SEED + 100,
            ..Default::default()
        },
    )
    .with_deadline(DEADLINE);
    let mut probes = 0usize;
    loop {
        let settled = injector.remaining() == 0
            && srv_stats.restarts.get() == count_deaths(&srv_stats) as u64;
        if settled {
            break;
        }
        assert!(
            probes < 1000,
            "recovery did not settle: {} faults unfired, {} restarts vs {} deaths",
            injector.remaining(),
            srv_stats.restarts.get(),
            count_deaths(&srv_stats)
        );
        let _ = probe.classify(&patterns[probes % N_IMAGES]);
        probes += 1;
        #[allow(clippy::disallowed_methods)] // wall-clock: paced live probing
        std::thread::sleep(Duration::from_millis(10));
    }
    drop(probe);

    let deaths = count_deaths(&srv_stats);
    let restarts = srv_stats.restarts.get();

    // Drain: both gauge families must conserve after everything the plan
    // threw at the stack.
    let net = Arc::into_inner(c_net).expect("sole NetServer handle");
    net.shutdown();
    assert_eq!(net_stats.inflight.get(), 0, "in-flight gauge leaked");
    assert_eq!(net_stats.open_connections.get(), 0, "connection gauge leaked");
    wait_until("spine gauges to drain", || srv_stats.drained());
    for (i, monitor) in srv.shard_energy.iter().enumerate() {
        let expect_j = monitor.capacity_j() - monitor.drained_j() + monitor.recharged_j();
        assert!(
            (monitor.remaining_j() - expect_j).abs() < 1e-6,
            "shard {i}: battery books do not balance: remaining {} vs {}",
            monitor.remaining_j(),
            expect_j
        );
    }
    srv.shutdown();

    ChaosResult {
        offered: requests,
        oks,
        errs,
        deaths,
        restarts,
        retries,
        reconnects,
        resets_applied,
        corruptions_applied,
    }
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let mut requests: usize = 600;
    let mut json_path: Option<String> = None;
    let mut assert_recovery = false;
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => {
                i += 1;
                json_path = Some(args.get(i).expect("--json needs a path").clone());
            }
            "--assert-recovery" => assert_recovery = true,
            other => {
                requests = other.parse().unwrap_or_else(|_| {
                    panic!("unexpected argument '{other}' (want a request count)")
                });
            }
        }
        i += 1;
    }

    // Fault-injection panics are the plan doing its job; keep CI logs
    // readable by muting exactly those and forwarding everything else.
    let default_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let injected = info
            .payload()
            .downcast_ref::<String>()
            .is_some_and(|m| m.contains("fault injection"));
        if !injected {
            default_hook(info);
        }
    }));

    let plan = FaultPlan::seeded(
        SEED,
        &FaultSpec {
            shards: SHARDS,
            // Triggers land in the first ~24 batches / first quarter of the
            // requests, so every fault fires mid-flight with plenty of
            // traffic left to recover under (and the wire schedule always
            // completes, whatever request count was asked for).
            horizon_batches: 24,
            horizon_requests: (requests as u64 / 4).max(1),
            ..FaultSpec::default()
        },
    );
    println!(
        "== chaos recovery: {requests} requests through {SHARDS} shards under seed {SEED} \
         ({} spine faults, {} wire faults) ==",
        plan.server.len(),
        plan.wire.len()
    );

    let r = run_chaos(requests, &plan);
    let served_fraction = r.oks as f64 / r.offered as f64;
    println!(
        "resolved {}/{} (ok {} | err {}) | served fraction {:.3} | deaths {} restarts {} | \
         client retries {} reconnects {} | resets {} corruptions {}",
        r.oks + r.errs,
        r.offered,
        r.oks,
        r.errs,
        served_fraction,
        r.deaths,
        r.restarts,
        r.retries,
        r.reconnects,
        r.resets_applied,
        r.corruptions_applied,
    );

    let every_request_resolved = r.oks + r.errs == r.offered;
    let all_faults_fired = r.resets_applied + r.corruptions_applied == plan.wire.len();
    let restarts_match_deaths = r.restarts == r.deaths as u64;
    let served_fraction_ok = served_fraction >= SERVED_FRACTION_MIN;

    if let Some(path) = &json_path {
        // Deterministic by construction: the plan is seed-derived, the
        // planned counts are exact, and the gate outcomes are booleans.
        // No measured latencies or fractions — identical seeds must yield
        // byte-identical artifacts.
        let rows = vec![
            Value::obj(vec![
                ("scenario", "plan".into()),
                ("plan", plan.to_json()),
                ("planned_spine_faults", plan.server.len().into()),
                ("planned_wire_faults", plan.wire.len().into()),
            ]),
            Value::obj(vec![
                ("scenario", "recovery".into()),
                ("offered", r.offered.into()),
                ("served_fraction_min", SERVED_FRACTION_MIN.into()),
                ("every_request_resolved", every_request_resolved.into()),
                ("all_wire_faults_fired", all_faults_fired.into()),
                ("all_spine_faults_fired", true.into()), // run_chaos waits on it
                ("restarts_match_deaths", restarts_match_deaths.into()),
                ("served_fraction_ok", served_fraction_ok.into()),
                ("bit_exact", true.into()), // asserted per reply in-run
                ("gauges_conserved", true.into()), // asserted in-run
            ]),
        ];
        std::fs::write(path, json::to_string_pretty(&Value::Array(rows))).expect("write json");
        println!("wrote {} rows to {path}", 2);
    }

    if assert_recovery {
        assert!(every_request_resolved, "lost tickets: {}+{} != {}", r.oks, r.errs, r.offered);
        assert!(all_faults_fired, "wire faults unapplied");
        assert!(
            restarts_match_deaths,
            "{} deaths but {} respawns",
            r.deaths, r.restarts
        );
        assert!(r.deaths >= 1, "the plan injected no observable spine death");
        assert!(
            served_fraction_ok,
            "served fraction {served_fraction:.3} below the {SERVED_FRACTION_MIN} gate"
        );
        println!(
            "\ngate passed: all {} spine + {} wire faults fired, {} respawns matched {} \
             deaths, served fraction {:.3} >= {SERVED_FRACTION_MIN}, zero lost tickets",
            plan.server.len(),
            plan.wire.len(),
            r.restarts,
            r.deaths,
            served_fraction
        );
    }
}
