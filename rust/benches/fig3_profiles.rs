//! Bench: regenerate the paper's Fig. 3 — accuracy-vs-power points for the
//! five Table-1 profiles plus the Mixed profile (Sect. 4.3), and identify
//! the two merge candidates the paper selects.

use onnx2hw::flow::{self, FlowConfig};
use onnx2hw::runtime::ArtifactStore;

const PROFILES: [&str; 6] = ["A16-W8", "A16-W4", "A8-W8", "A8-W4", "A4-W4", "Mixed"];

fn main() {
    let store = match ArtifactStore::discover() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("fig3: skipping ({e})");
            return;
        }
    };
    let cfg = FlowConfig::default();
    println!("== Fig. 3: accuracy vs power chart ==");
    println!("{:<10} {:>10} {:>10}", "profile", "power_mW", "accuracy_%");
    let mut rows = Vec::new();
    for p in PROFILES {
        match flow::profile_report(&store, p, &cfg) {
            Ok(r) => {
                println!("{:<10} {:>10.1} {:>10.2}", r.profile, r.power_mw, r.accuracy_pct);
                rows.push(r);
            }
            Err(e) => println!("{p:<10} unavailable ({e})"),
        }
    }
    // the paper's selection argument: Mixed sits between A8-W8 and A4-W4 on
    // power while keeping most of A8-W8's accuracy, and shares layers with
    // A8-W8 (same outer precision).
    let get = |n: &str| rows.iter().find(|r| r.profile == n);
    if let (Some(a88), Some(mixed), Some(a44)) = (get("A8-W8"), get("Mixed"), get("A4-W4")) {
        println!(
            "\nMixed check: power {:.1} mW within [{:.1}, {:.1}]; accuracy drop vs A8-W8: {:.2} pp",
            mixed.power_mw,
            a44.power_mw.min(a88.power_mw),
            a44.power_mw.max(a88.power_mw),
            a88.accuracy_pct - mixed.accuracy_pct
        );
        println!(
            "paper: switch saves ~5% power for ~1.5pp accuracy -> ours: {:.1}% power, {:.2} pp",
            (1.0 - mixed.power_mw / a88.power_mw) * 100.0,
            a88.accuracy_pct - mixed.accuracy_pct
        );
    }
}
