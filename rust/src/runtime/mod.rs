//! PJRT runtime: load and execute the AOT-compiled model artifacts.
//!
//! The three-layer contract (DESIGN.md §3): python/jax lowers each profile's
//! inference graph (through the Pallas kernels) to HLO *text* once at build
//! time (`make artifacts`); this module loads `artifacts/model_<p>.hlo.txt`,
//! compiles it on the PJRT CPU client and executes classifications from the
//! rust hot path. Python never runs at request time.

mod artifacts;
mod engine;

pub use artifacts::{ArtifactStore, EvalRecord, TestSet, VectorSet};
pub use engine::{PjrtEngine, ProfileExecutable};
