//! Artifact discovery + loading (QONNX JSON, test set, eval records,
//! bit-exact vectors).

use std::collections::BTreeMap;
use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use crate::json::{self, Value};
use crate::qonnx::QonnxModel;

/// The shared test set exported by python (u8 input codes + labels).
#[derive(Debug, Clone)]
pub struct TestSet {
    pub height: usize,
    pub width: usize,
    pub channels: usize,
    /// n images, HWC u8 codes, contiguous.
    pub images: Vec<u8>,
    pub labels: Vec<u8>,
}

impl TestSet {
    pub fn len(&self) -> usize {
        self.labels.len()
    }

    pub fn is_empty(&self) -> bool {
        self.labels.is_empty()
    }

    pub fn image(&self, i: usize) -> &[u8] {
        let sz = self.height * self.width * self.channels;
        &self.images[i * sz..(i + 1) * sz]
    }
}

/// eval_<profile>.json: the python-side integer-pipeline accuracy.
#[derive(Debug, Clone)]
pub struct EvalRecord {
    pub profile: String,
    pub int_accuracy: f64,
    pub qat_accuracy: f64,
    pub n_test: usize,
}

/// vectors_<profile>.json: bit-exact logits for the first K test images.
#[derive(Debug, Clone)]
pub struct VectorSet {
    pub profile: String,
    pub logits: Vec<Vec<i64>>,
    pub pred: Vec<usize>,
}

/// Root handle over the artifacts directory.
#[derive(Debug, Clone)]
pub struct ArtifactStore {
    pub root: PathBuf,
}

impl ArtifactStore {
    /// Locate artifacts: `$ONNX2HW_ARTIFACTS`, else `./artifacts`, walking up
    /// from the current dir (so examples work from any workspace subdir).
    pub fn discover() -> Result<Self> {
        if let Ok(p) = std::env::var("ONNX2HW_ARTIFACTS") {
            let root = PathBuf::from(p);
            if root.is_dir() {
                return Ok(ArtifactStore { root });
            }
            bail!("ONNX2HW_ARTIFACTS={root:?} is not a directory");
        }
        let mut dir = std::env::current_dir()?;
        loop {
            let cand = dir.join("artifacts");
            if cand.is_dir() {
                return Ok(ArtifactStore { root: cand });
            }
            if !dir.pop() {
                bail!(
                    "no artifacts/ directory found — run `make artifacts` first \
                     (or set ONNX2HW_ARTIFACTS)"
                );
            }
        }
    }

    pub fn at(root: impl Into<PathBuf>) -> Self {
        ArtifactStore { root: root.into() }
    }

    fn read_json(&self, name: &str) -> Result<Value> {
        let path = self.root.join(name);
        let text = std::fs::read_to_string(&path).with_context(|| format!("reading {path:?}"))?;
        json::parse(&text).with_context(|| format!("parsing {path:?}"))
    }

    /// Profiles with a QONNX model present, sorted.
    pub fn profiles(&self) -> Result<Vec<String>> {
        let mut out = Vec::new();
        for entry in std::fs::read_dir(&self.root)? {
            let name = entry?.file_name().to_string_lossy().into_owned();
            if let Some(rest) = name
                .strip_prefix("model_")
                .and_then(|r| r.strip_suffix(".qonnx.json"))
            {
                out.push(rest.to_string());
            }
        }
        out.sort();
        Ok(out)
    }

    pub fn qonnx(&self, profile: &str) -> Result<QonnxModel> {
        let path = self.root.join(format!("model_{profile}.qonnx.json"));
        crate::qonnx::read_file(&path)
            .map_err(|e| anyhow::anyhow!("loading {path:?}: {e}"))
    }

    pub fn hlo_path(&self, profile: &str, batch: usize) -> PathBuf {
        if batch == 1 {
            self.root.join(format!("model_{profile}.hlo.txt"))
        } else {
            self.root.join(format!("model_{profile}_b{batch}.hlo.txt"))
        }
    }

    pub fn testset(&self) -> Result<TestSet> {
        let meta = self.read_json("testset.json")?;
        let n = meta.get("n").and_then(Value::as_i64).context("testset n")? as usize;
        let height = meta.get("height").and_then(Value::as_i64).context("h")? as usize;
        let width = meta.get("width").and_then(Value::as_i64).context("w")? as usize;
        let channels = meta.get("channels").and_then(Value::as_i64).context("c")? as usize;
        let labels: Vec<u8> = meta
            .get("labels")
            .and_then(Value::to_i64_vec)
            .context("labels")?
            .into_iter()
            .map(|l| l as u8)
            .collect();
        let images = std::fs::read(self.root.join("testset.bin"))?;
        if images.len() != n * height * width * channels || labels.len() != n {
            bail!("testset.bin size mismatch");
        }
        Ok(TestSet {
            height,
            width,
            channels,
            images,
            labels,
        })
    }

    pub fn eval(&self, profile: &str) -> Result<EvalRecord> {
        let v = self.read_json(&format!("eval_{profile}.json"))?;
        Ok(EvalRecord {
            profile: profile.to_string(),
            int_accuracy: v
                .get("int_accuracy")
                .and_then(Value::as_f64)
                .context("int_accuracy")?,
            qat_accuracy: v
                .get("qat_accuracy")
                .and_then(Value::as_f64)
                .unwrap_or(0.0),
            n_test: v.get("n_test").and_then(Value::as_i64).unwrap_or(0) as usize,
        })
    }

    pub fn evals(&self) -> Result<BTreeMap<String, EvalRecord>> {
        let mut out = BTreeMap::new();
        for p in self.profiles()? {
            out.insert(p.clone(), self.eval(&p)?);
        }
        Ok(out)
    }

    pub fn vectors(&self, profile: &str) -> Result<VectorSet> {
        let v = self.read_json(&format!("vectors_{profile}.json"))?;
        let logits = v
            .get("logits")
            .and_then(Value::as_array)
            .context("logits")?
            .iter()
            .map(|row| row.to_i64_vec().context("logit row"))
            .collect::<Result<Vec<_>>>()?;
        let pred = v
            .get("pred")
            .and_then(Value::to_i64_vec)
            .context("pred")?
            .into_iter()
            .map(|p| p as usize)
            .collect();
        Ok(VectorSet {
            profile: profile.to_string(),
            logits,
            pred,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn discover_fails_cleanly_without_artifacts() {
        // In a scratch dir with no artifacts anywhere up the tree, discover
        // must error with the actionable message.
        let store = ArtifactStore::at("/definitely/not/a/real/path");
        assert!(store.qonnx("A8-W8").is_err());
    }

    #[test]
    fn hlo_path_naming() {
        let store = ArtifactStore::at("/tmp/x");
        assert!(store.hlo_path("A8-W8", 1).ends_with("model_A8-W8.hlo.txt"));
        assert!(store.hlo_path("A8-W8", 8).ends_with("model_A8-W8_b8.hlo.txt"));
    }
}
