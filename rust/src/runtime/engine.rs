//! PJRT execution engine: one compiled executable per (profile, batch).

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::artifacts::ArtifactStore;

/// One compiled (profile, batch) variant.
pub struct ProfileExecutable {
    pub profile: String,
    pub batch: usize,
    exe: xla::PjRtLoadedExecutable,
}

impl ProfileExecutable {
    /// Classify `batch` images (u8 HWC codes, concatenated). Returns the
    /// (batch, 10) logits row-major. Input codes are dequantized to the
    /// q/256 grid the lowered graph expects.
    pub fn run(&self, images: &[u8], pixels_per_image: usize) -> Result<Vec<f32>> {
        if images.len() != self.batch * pixels_per_image {
            bail!(
                "batch size mismatch: got {} pixels, expected {} x {}",
                images.len(),
                self.batch,
                pixels_per_image
            );
        }
        let floats: Vec<f32> = images.iter().map(|&q| q as f32 / 256.0).collect();
        let lit = xla::Literal::vec1(&floats).reshape(&[
            self.batch as i64,
            28,
            28,
            1,
        ])?;
        let result = self.exe.execute::<xla::Literal>(&[lit])?;
        let out = result[0][0]
            .to_literal_sync()?
            .to_tuple1()
            .context("unwrapping 1-tuple output")?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// Runtime engine holding the PJRT client and all compiled variants.
pub struct PjrtEngine {
    client: xla::PjRtClient,
    exes: BTreeMap<(String, usize), ProfileExecutable>,
    pub pixels_per_image: usize,
}

impl PjrtEngine {
    pub fn new() -> Result<Self> {
        Ok(PjrtEngine {
            client: xla::PjRtClient::cpu().context("creating PJRT CPU client")?,
            exes: BTreeMap::new(),
            pixels_per_image: 28 * 28,
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one (profile, batch) artifact. Idempotent.
    /// Returns compile wall time.
    pub fn load(
        &mut self,
        store: &ArtifactStore,
        profile: &str,
        batch: usize,
    ) -> Result<std::time::Duration> {
        let key = (profile.to_string(), batch);
        if self.exes.contains_key(&key) {
            return Ok(std::time::Duration::ZERO);
        }
        let path = store.hlo_path(profile, batch);
        #[allow(clippy::disallowed_methods)] // wall-clock: reported compile time
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("loading HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        self.exes.insert(
            key,
            ProfileExecutable {
                profile: profile.to_string(),
                batch,
                exe,
            },
        );
        Ok(t0.elapsed())
    }

    pub fn get(&self, profile: &str, batch: usize) -> Option<&ProfileExecutable> {
        self.exes.get(&(profile.to_string(), batch))
    }

    pub fn loaded(&self) -> Vec<(String, usize)> {
        self.exes.keys().cloned().collect()
    }

    /// Classify one image; returns (logits[10], argmax).
    pub fn classify_one(&self, profile: &str, image: &[u8]) -> Result<(Vec<f32>, usize)> {
        let exe = self
            .get(profile, 1)
            .with_context(|| format!("profile '{profile}' (batch 1) not loaded"))?;
        let logits = exe.run(image, self.pixels_per_image)?;
        let pred = argmax_f32(&logits);
        Ok((logits, pred))
    }

    /// Classify a batch with the best-fitting variant (pads the tail).
    pub fn classify_batch(
        &self,
        profile: &str,
        images: &[&[u8]],
    ) -> Result<Vec<(Vec<f32>, usize)>> {
        let mut out = Vec::with_capacity(images.len());
        let mut i = 0;
        // Use the largest loaded batch variant that fits; fall back to 1.
        let mut batches: Vec<usize> = self
            .exes
            .keys()
            .filter(|(p, _)| p == profile)
            .map(|&(_, b)| b)
            .collect();
        batches.sort_unstable_by(|a, b| b.cmp(a));
        if batches.is_empty() {
            bail!("profile '{profile}' not loaded");
        }
        // One staging buffer reused across chunks (cleared, never shrunk),
        // mirroring the Sim path's allocation discipline.
        let mut flat: Vec<u8> = Vec::new();
        while i < images.len() {
            let remaining = images.len() - i;
            let b = *batches
                .iter()
                .find(|&&b| b <= remaining)
                .unwrap_or(batches.last().unwrap());
            let exe = self.get(profile, b).unwrap();
            // Pad with the last image if the variant is larger than remaining.
            flat.clear();
            flat.reserve(b * self.pixels_per_image);
            for j in 0..b {
                let img = images[(i + j).min(images.len() - 1)];
                flat.extend_from_slice(img);
            }
            let logits = exe.run(&flat, self.pixels_per_image)?;
            for j in 0..b.min(remaining) {
                let row = logits[j * 10..(j + 1) * 10].to_vec();
                let pred = argmax_f32(&row);
                out.push((row, pred));
            }
            i += b.min(remaining);
        }
        Ok(out)
    }
}

pub fn argmax_f32(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in xs.iter().enumerate() {
        if v > xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn argmax_basic() {
        assert_eq!(argmax_f32(&[0.0, 3.0, -1.0]), 1);
        assert_eq!(argmax_f32(&[5.0]), 0);
        // ties break to the first index
        assert_eq!(argmax_f32(&[2.0, 2.0]), 0);
    }

    // PJRT-backed tests live in rust/tests/runtime_integration.rs (they need
    // built artifacts).
}
