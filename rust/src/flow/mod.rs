//! End-to-end design-flow driver: QONNX artifact -> Table-1 row.
//!
//! Composes the substrates exactly as the paper's Fig. 2 flow does:
//! Reader (qonnx) -> Writer (writer) -> HLS estimate (hls) -> streaming
//! simulation (dataflow) -> power model (power), plus the python-side
//! accuracy record. Every bench and example builds on these entry points so
//! the numbers in EXPERIMENTS.md all come from one code path.

use anyhow::{Context, Result};

use crate::dataflow::{simulate_image, FoldingConfig, SimReport};
use crate::hls::{estimate_engine, Calibration, DeviceModel, UtilizationReport};
use crate::power::{estimate_power, PowerBreakdown};
use crate::qonnx::QonnxModel;
use crate::runtime::{ArtifactStore, TestSet};

/// One row of Table 1 (plus diagnostics).
#[derive(Debug, Clone)]
pub struct ProfileReport {
    pub profile: String,
    pub accuracy_pct: f64,
    pub latency_us: f64,
    pub lut_pct: f64,
    pub bram_pct: f64,
    pub power_mw: f64,
    // diagnostics
    pub luts: u64,
    pub bram36: f64,
    pub cycles: u64,
    pub toggle_rate: f64,
    pub power: PowerBreakdown,
}

/// Configuration of the flow run.
#[derive(Debug, Clone)]
pub struct FlowConfig {
    pub fold: FoldingConfig,
    pub cal: Calibration,
    pub device: DeviceModel,
    /// Images simulated for the activity-based power estimate.
    pub power_images: usize,
}

impl Default for FlowConfig {
    fn default() -> Self {
        FlowConfig {
            fold: FoldingConfig::default(),
            cal: Calibration::default(),
            device: DeviceModel::kria_kv260(),
            power_images: 4,
        }
    }
}

/// Simulate `n` test images through the streaming engine (round-robin over
/// the test set for value-dependent power).
pub fn simulate_testset(
    model: &QonnxModel,
    fold: &FoldingConfig,
    testset: &TestSet,
    n: usize,
) -> Vec<SimReport> {
    (0..n.max(1))
        .map(|i| simulate_image(model, fold, testset.image(i % testset.len())))
        .collect()
}

/// Produce the Table-1 row for one profile.
pub fn profile_report(
    store: &ArtifactStore,
    profile: &str,
    cfg: &FlowConfig,
) -> Result<ProfileReport> {
    let model = store.qonnx(profile)?;
    let eval = store.eval(profile)?;
    let testset = store.testset()?;
    let est = estimate_engine(&model, &cfg.fold, &cfg.cal);
    let sims = simulate_testset(&model, &cfg.fold, &testset, cfg.power_images);
    let power = estimate_power(&model, &est, &sims, &cfg.cal, &cfg.device);
    let cycles = sims.iter().map(|s| s.cycles).sum::<u64>() / sims.len() as u64;
    Ok(ProfileReport {
        profile: profile.to_string(),
        accuracy_pct: eval.int_accuracy * 100.0,
        latency_us: cycles as f64 / cfg.device.clock_mhz,
        lut_pct: cfg.device.lut_pct(est.luts),
        bram_pct: cfg.device.bram_pct(est.bram36),
        power_mw: power.total_mw,
        luts: est.luts,
        bram36: est.bram36,
        cycles,
        toggle_rate: power.toggle_rate,
        power,
    })
}

/// All Table-1 rows (the five mixed-precision profiles by default).
pub fn table1(
    store: &ArtifactStore,
    profiles: &[&str],
    cfg: &FlowConfig,
) -> Result<Vec<ProfileReport>> {
    profiles
        .iter()
        .map(|p| profile_report(store, p, cfg).with_context(|| format!("profile {p}")))
        .collect()
}

/// The Vitis-style utilization report for one profile.
pub fn utilization_report(
    store: &ArtifactStore,
    profile: &str,
    cfg: &FlowConfig,
) -> Result<UtilizationReport> {
    let model = store.qonnx(profile)?;
    let est = estimate_engine(&model, &cfg.fold, &cfg.cal);
    Ok(UtilizationReport::new(profile, &est, &cfg.device))
}

/// Measure accuracy of the rust integer engine over the exported test set
/// (must agree with the python-side eval record — integration-tested).
pub fn measure_accuracy(model: &QonnxModel, testset: &TestSet, limit: usize) -> f64 {
    let mut ex = crate::dataflow::Executor::new(model);
    let n = testset.len().min(limit);
    let mut correct = 0usize;
    for i in 0..n {
        let logits = ex.run(testset.image(i));
        if crate::dataflow::exec::argmax(&logits) == testset.labels[i] as usize {
            correct += 1;
        }
    }
    correct as f64 / n as f64
}

#[cfg(test)]
mod tests {
    // flow functions over real artifacts are exercised by
    // rust/tests/flow_integration.rs; unit coverage for the composed pieces
    // lives in their own modules.
}
