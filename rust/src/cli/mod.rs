//! Tiny declarative CLI substrate (clap is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--opt value` / `--opt=value`, positional
//! arguments, defaults, and auto-generated `--help` text.

use std::collections::BTreeMap;
use std::fmt;

/// Argument specification for one (sub)command.
#[derive(Debug, Clone, Default)]
pub struct Spec {
    pub name: String,
    pub about: String,
    opts: Vec<OptSpec>,
    positionals: Vec<PosSpec>,
}

#[derive(Debug, Clone)]
struct OptSpec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

#[derive(Debug, Clone)]
struct PosSpec {
    name: String,
    help: String,
    required: bool,
}

/// Parsed arguments.
#[derive(Debug, Clone, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: BTreeMap<String, bool>,
    positionals: Vec<String>,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CliError(pub String);

impl fmt::Display for CliError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for CliError {}

impl Spec {
    pub fn new(name: &str, about: &str) -> Self {
        Spec {
            name: name.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// `--name <value>` option with a default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// `--name <value>` option with no default (optional).
    pub fn opt_req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Boolean `--name` flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(OptSpec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Positional argument.
    pub fn pos(mut self, name: &str, required: bool, help: &str) -> Self {
        self.positionals.push(PosSpec {
            name: name.to_string(),
            help: help.to_string(),
            required,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {}", self.name, self.about, self.name);
        for p in &self.positionals {
            if p.required {
                s.push_str(&format!(" <{}>", p.name));
            } else {
                s.push_str(&format!(" [{}]", p.name));
            }
        }
        s.push_str(" [OPTIONS]\n\nOPTIONS:\n");
        for o in &self.opts {
            let head = if o.is_flag {
                format!("  --{}", o.name)
            } else {
                format!("  --{} <v>", o.name)
            };
            let def = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_default();
            s.push_str(&format!("{head:<28}{}{def}\n", o.help));
        }
        for p in &self.positionals {
            s.push_str(&format!("  <{}>{:<22}{}\n", p.name, "", p.help));
        }
        s
    }

    /// Parse a raw argv slice (not including the program/subcommand name).
    pub fn parse(&self, argv: &[String]) -> Result<Args, CliError> {
        let mut args = Args::default();
        for o in &self.opts {
            if let Some(d) = &o.default {
                args.values.insert(o.name.clone(), d.clone());
            }
            if o.is_flag {
                args.flags.insert(o.name.clone(), false);
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                return Err(CliError(self.usage()));
            }
            if let Some(body) = a.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|o| o.name == name)
                    .ok_or_else(|| CliError(format!("unknown option --{name}")))?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(CliError(format!("--{name} takes no value")));
                    }
                    args.flags.insert(name, true);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .cloned()
                                .ok_or_else(|| CliError(format!("--{name} needs a value")))?
                        }
                    };
                    args.values.insert(name, val);
                }
            } else {
                if args.positionals.len() >= self.positionals.len() {
                    return Err(CliError(format!("unexpected argument '{a}'")));
                }
                args.positionals.push(a.clone());
            }
            i += 1;
        }
        for (idx, p) in self.positionals.iter().enumerate() {
            if p.required && args.positionals.len() <= idx {
                return Err(CliError(format!("missing required argument <{}>", p.name)));
            }
        }
        Ok(args)
    }
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Option value with the empty string treated as absent — the idiom
    /// for optional options whose declared default is `""`.
    pub fn opt_str(&self, name: &str) -> Option<&str> {
        self.get(name).filter(|s| !s.is_empty())
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.get(name).copied().unwrap_or(false)
    }

    pub fn parse_num<T: std::str::FromStr>(&self, name: &str) -> Result<T, CliError> {
        let raw = self
            .get(name)
            .ok_or_else(|| CliError(format!("missing --{name}")))?;
        raw.parse()
            .map_err(|_| CliError(format!("--{name}: cannot parse '{raw}'")))
    }

    pub fn pos(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Spec {
        Spec::new("t", "test")
            .opt("batch", "8", "batch size")
            .opt_req("model", "model path")
            .flag("verbose", "chatty")
            .pos("input", false, "input file")
    }

    fn sv(args: &[&str]) -> Vec<String> {
        args.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = spec().parse(&sv(&[])).unwrap();
        assert_eq!(a.get("batch"), Some("8"));
        assert!(!a.flag("verbose"));
        let a = spec()
            .parse(&sv(&["--batch", "16", "--verbose", "file.bin"]))
            .unwrap();
        assert_eq!(a.parse_num::<usize>("batch").unwrap(), 16);
        assert!(a.flag("verbose"));
        assert_eq!(a.pos(0), Some("file.bin"));
    }

    #[test]
    fn opt_str_treats_empty_default_as_absent() {
        let spec = Spec::new("t", "test").opt("out", "", "output path");
        let a = spec.parse(&sv(&[])).unwrap();
        assert_eq!(a.opt_str("out"), None);
        assert_eq!(a.opt_str("missing"), None);
        let a = spec.parse(&sv(&["--out", "x.json"])).unwrap();
        assert_eq!(a.opt_str("out"), Some("x.json"));
    }

    #[test]
    fn equals_syntax() {
        let a = spec().parse(&sv(&["--batch=32"])).unwrap();
        assert_eq!(a.get("batch"), Some("32"));
    }

    #[test]
    fn errors() {
        assert!(spec().parse(&sv(&["--nope"])).is_err());
        assert!(spec().parse(&sv(&["--batch"])).is_err());
        assert!(spec().parse(&sv(&["--verbose=1"])).is_err());
        assert!(spec().parse(&sv(&["a", "b"])).is_err());
        assert!(spec().parse(&sv(&["--batch", "x"])).unwrap().parse_num::<usize>("batch").is_err());
    }
}
