//! Bench harness substrate (criterion is unavailable offline).
//!
//! `cargo bench` targets are `harness = false` binaries built on this:
//! warmup, timed iterations, robust statistics, and aligned text tables so
//! each bench regenerates its paper table/figure as rows on stdout.

use std::time::{Duration, Instant};

/// Timing statistics over N iterations.
#[derive(Debug, Clone)]
pub struct Stats {
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
    pub p99: Duration,
    pub min: Duration,
    pub max: Duration,
}

impl Stats {
    pub fn throughput_per_s(&self) -> f64 {
        if self.mean.as_secs_f64() == 0.0 {
            0.0
        } else {
            1.0 / self.mean.as_secs_f64()
        }
    }
}

/// Time `f` with `warmup` + `iters` runs. The closure's return value is
/// black-boxed to keep the optimizer honest.
#[allow(clippy::disallowed_methods)] // wall-clock: this IS the timing harness
pub fn bench<T>(warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> Stats {
    assert!(iters > 0);
    for _ in 0..warmup {
        black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        black_box(f());
        samples.push(t0.elapsed());
    }
    samples.sort_unstable();
    let sum: Duration = samples.iter().sum();
    Stats {
        iters,
        mean: sum / iters as u32,
        p50: samples[iters / 2],
        p95: samples[((iters as f64 * 0.95) as usize).min(iters - 1)],
        p99: samples[((iters as f64 * 0.99) as usize).min(iters - 1)],
        min: samples[0],
        max: samples[iters - 1],
    }
}

/// `std::hint::black_box` shim (stable since 1.66).
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Fixed-width table printer for bench outputs.
pub struct Table {
    headers: Vec<String>,
    widths: Vec<usize>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table {
            widths: headers.iter().map(|h| h.len().max(10)).collect(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        for (w, c) in self.widths.iter_mut().zip(cells) {
            *w = (*w).max(c.len());
        }
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        let line = |cells: &[String], widths: &[usize]| {
            let mut l = String::new();
            for (c, w) in cells.iter().zip(widths) {
                l.push_str(&format!("| {c:<w$} "));
            }
            l.push_str("|\n");
            l
        };
        s.push_str(&line(&self.headers, &self.widths));
        let sep: Vec<String> = self.widths.iter().map(|w| "-".repeat(*w)).collect();
        s.push_str(&line(&sep, &self.widths));
        for r in &self.rows {
            s.push_str(&line(r, &self.widths));
        }
        s
    }
}

/// Format a Duration human-readably (us/ms/s).
pub fn fmt_dur(d: Duration) -> String {
    let us = d.as_secs_f64() * 1e6;
    if us < 1000.0 {
        format!("{us:.1}us")
    } else if us < 1e6 {
        format!("{:.2}ms", us / 1e3)
    } else {
        format!("{:.3}s", us / 1e6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    #[allow(clippy::disallowed_methods)] // wall-clock: the workload under test is a sleep
    fn bench_produces_ordered_stats() {
        let s = bench(2, 20, || std::thread::sleep(Duration::from_micros(50)));
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.p99);
        assert!(s.p99 <= s.max);
        assert!(s.mean >= Duration::from_micros(40));
        assert!(s.throughput_per_s() > 0.0);
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer-name".into(), "2".into()]);
        let out = t.render();
        assert!(out.contains("longer-name"));
        assert_eq!(out.lines().count(), 4);
    }

    #[test]
    fn fmt_dur_scales() {
        assert!(fmt_dur(Duration::from_micros(5)).ends_with("us"));
        assert!(fmt_dur(Duration::from_millis(5)).ends_with("ms"));
        assert!(fmt_dur(Duration::from_secs(5)).ends_with('s'));
    }
}
