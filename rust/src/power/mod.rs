//! Power model + battery simulation (Table 1 power column, Fig. 4 right).
//!
//! The board-power substitution (DESIGN.md §2): activity-based estimation
//!
//!   P = P_static(leakage ~ LUTs used) + P_dynamic
//!   P_dynamic = f_clk * [ sum_fifo toggle_bits/image * E_toggle
//!                       + sum_mac  executed_macs/image * (a+w bits) * E_mac
//!                       + bram accesses * E_bram ] / cycles_per_image
//!
//! where the toggle counts come from the *dataflow simulation of real
//! images*, so the estimate is value-dependent — reproducing the paper's
//! observation that power does not track precision proportionally (switching
//! activity depends on the trained weights and the data being processed).

mod battery;
mod cost;
mod model;
mod source;

pub use battery::{
    run_fixed, simulate_battery, simulate_battery_cycles, AdaptivePolicy, BatteryModel,
    BatteryPack, BatteryRun, CycleSimConfig, IDLE_PHASE,
};
pub use cost::{estimate_inference_cost, InferenceCost};
pub use model::{estimate_power, PowerBreakdown};
pub use source::EnergySource;
