//! Battery simulation (paper Fig. 4, right): adaptive vs non-adaptive
//! engines under a fixed energy budget.
//!
//! The paper assumes a 10 Ah battery; the non-adaptive engine always runs
//! the most accurate profile, while the adaptive engine's Profile Manager
//! switches to the low-power profile once the remaining charge falls below
//! a threshold. The outputs are battery duration and the total number of
//! classifications executed — the adaptive engine extends both.

/// Battery parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct BatteryModel {
    /// Capacity in ampere-hours.
    pub capacity_ah: f64,
    /// Supply voltage (energy = Ah * V * 3600 joules).
    pub voltage_v: f64,
}

impl Default for BatteryModel {
    fn default() -> Self {
        // Paper: "supposing a 10Ah energy budget"; KRIA rails are 5 V.
        BatteryModel {
            capacity_ah: 10.0,
            voltage_v: 5.0,
        }
    }
}

impl BatteryModel {
    pub fn energy_j(&self) -> f64 {
        self.capacity_ah * self.voltage_v * 3600.0
    }
}

/// A pack of per-shard batteries: the sharded server gives every
/// accelerator replica its own cell instead of draining one global budget,
/// so a hot shard degrades alone. `split` conserves the total energy and
/// mirrors the even joule split `AdaptiveServer::start` applies to a
/// global `EnergyMonitor` — change the policy in both places together.
#[derive(Debug, Clone, PartialEq)]
pub struct BatteryPack {
    pub cells: Vec<BatteryModel>,
}

impl BatteryPack {
    /// Split `total` evenly into `shards` cells (clamped to at least 1).
    pub fn split(total: &BatteryModel, shards: usize) -> Self {
        let n = shards.max(1);
        BatteryPack {
            cells: vec![
                BatteryModel {
                    capacity_ah: total.capacity_ah / n as f64,
                    voltage_v: total.voltage_v,
                };
                n
            ],
        }
    }

    /// Energy of each cell in joules (what each shard's monitor is seeded
    /// with).
    pub fn cell_energy_j(&self) -> Vec<f64> {
        self.cells.iter().map(|c| c.energy_j()).collect()
    }

    pub fn total_energy_j(&self) -> f64 {
        self.cells.iter().map(|c| c.energy_j()).sum()
    }
}

/// Threshold policy of the Profile Manager (paper Fig. 4 left): run the
/// accurate profile while charge >= `switch_at_fraction`, then drop to the
/// low-power profile.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptivePolicy {
    /// Remaining-energy fraction at which to switch (e.g. 0.5).
    pub switch_at_fraction: f64,
}

impl Default for AdaptivePolicy {
    fn default() -> Self {
        AdaptivePolicy {
            switch_at_fraction: 0.5,
        }
    }
}

/// Result of draining the battery with one engine configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct BatteryRun {
    pub label: String,
    pub duration_h: f64,
    pub classifications: u64,
    /// (profile, hours spent, classifications) per phase.
    pub phases: Vec<(String, f64, u64)>,
    /// Classification-weighted mean accuracy over the whole run.
    pub mean_accuracy: f64,
}

/// Drain the battery running one fixed profile continuously.
///
/// `power_mw` is the average engine power, `latency_us` the per-image
/// latency (images are classified back-to-back, as in the paper's
/// "running at full performance").
pub fn run_fixed(
    label: &str,
    battery: &BatteryModel,
    power_mw: f64,
    latency_us: f64,
    accuracy: f64,
) -> BatteryRun {
    let seconds = battery.energy_j() / (power_mw * 1e-3);
    let classifications = (seconds / (latency_us * 1e-6)) as u64;
    BatteryRun {
        label: label.to_string(),
        duration_h: seconds / 3600.0,
        classifications,
        phases: vec![(label.to_string(), seconds / 3600.0, classifications)],
        mean_accuracy: accuracy,
    }
}

/// Drain the battery with the adaptive engine: phase 1 on the accurate
/// profile until the threshold, phase 2 on the low-power profile.
#[allow(clippy::too_many_arguments)]
pub fn simulate_battery(
    battery: &BatteryModel,
    policy: &AdaptivePolicy,
    accurate: (&str, f64, f64, f64),  // (name, power_mw, latency_us, accuracy)
    low_power: (&str, f64, f64, f64),
) -> BatteryRun {
    let total_j = battery.energy_j();
    let phase1_j = total_j * (1.0 - policy.switch_at_fraction);
    let phase2_j = total_j - phase1_j;

    let (a_name, a_mw, a_lat, a_acc) = accurate;
    let (l_name, l_mw, l_lat, l_acc) = low_power;

    let s1 = phase1_j / (a_mw * 1e-3);
    let c1 = (s1 / (a_lat * 1e-6)) as u64;
    let s2 = phase2_j / (l_mw * 1e-3);
    let c2 = (s2 / (l_lat * 1e-6)) as u64;

    let total_c = c1 + c2;
    BatteryRun {
        label: format!("adaptive({a_name}->{l_name})"),
        duration_h: (s1 + s2) / 3600.0,
        classifications: total_c,
        phases: vec![
            (a_name.to_string(), s1 / 3600.0, c1),
            (l_name.to_string(), s2 / 3600.0, c2),
        ],
        mean_accuracy: if total_c == 0 {
            0.0
        } else {
            (a_acc * c1 as f64 + l_acc * c2 as f64) / total_c as f64
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    const A: (&str, f64, f64, f64) = ("A8-W8", 142.0, 329.0, 0.96);
    const L: (&str, f64, f64, f64) = ("Mixed", 135.0, 329.0, 0.945);

    #[test]
    fn adaptive_outlasts_nonadaptive() {
        let bat = BatteryModel::default();
        let fixed = run_fixed(A.0, &bat, A.1, A.2, A.3);
        let adaptive = simulate_battery(&bat, &AdaptivePolicy::default(), A, L);
        assert!(adaptive.duration_h > fixed.duration_h);
        assert!(adaptive.classifications > fixed.classifications);
        assert!(adaptive.mean_accuracy < fixed.mean_accuracy);
        assert!(adaptive.mean_accuracy > L.3);
    }

    #[test]
    fn energy_accounting_is_exact() {
        let bat = BatteryModel {
            capacity_ah: 1.0,
            voltage_v: 5.0,
        };
        // 18000 J at 1000 mW -> 18000 s -> 5 h
        let run = run_fixed("x", &bat, 1000.0, 1e6, 1.0); // 1 s per image
        assert!((run.duration_h - 5.0).abs() < 1e-9);
        assert_eq!(run.classifications, 18000);
    }

    #[test]
    fn pack_split_conserves_energy() {
        let bat = BatteryModel::default();
        for shards in [1usize, 2, 4, 7] {
            let pack = BatteryPack::split(&bat, shards);
            assert_eq!(pack.cells.len(), shards);
            assert!((pack.total_energy_j() - bat.energy_j()).abs() < 1e-6);
            let per_cell = pack.cell_energy_j();
            assert!(per_cell
                .iter()
                .all(|&j| (j - bat.energy_j() / shards as f64).abs() < 1e-6));
        }
        // degenerate shard count clamps instead of dividing by zero
        assert_eq!(BatteryPack::split(&bat, 0).cells.len(), 1);
    }

    #[test]
    fn threshold_zero_equals_low_power_only() {
        let bat = BatteryModel::default();
        let adaptive = simulate_battery(
            &bat,
            &AdaptivePolicy {
                switch_at_fraction: 1.0,
            },
            A,
            L,
        );
        let fixed_low = run_fixed(L.0, &bat, L.1, L.2, L.3);
        assert!((adaptive.duration_h - fixed_low.duration_h).abs() < 1e-6);
    }

    #[test]
    fn duration_monotone_in_switch_threshold() {
        testkit::check("earlier switch -> longer life", |rng| {
            let bat = BatteryModel::default();
            let t1 = rng.f64(0.0, 1.0);
            let t2 = rng.f64(0.0, 1.0);
            let (lo, hi) = if t1 < t2 { (t1, t2) } else { (t2, t1) };
            let r_lo = simulate_battery(&bat, &AdaptivePolicy { switch_at_fraction: lo }, A, L);
            let r_hi = simulate_battery(&bat, &AdaptivePolicy { switch_at_fraction: hi }, A, L);
            crate::prop_assert!(
                r_hi.duration_h >= r_lo.duration_h - 1e-9,
                "threshold {hi} gave {} < {} at {lo}",
                r_hi.duration_h,
                r_lo.duration_h
            );
            Ok(())
        });
    }
}
