//! Battery simulation (paper Fig. 4, right): adaptive vs non-adaptive
//! engines under a fixed energy budget.
//!
//! The paper assumes a 10 Ah battery; the non-adaptive engine always runs
//! the most accurate profile, while the adaptive engine's Profile Manager
//! switches to the low-power profile once the remaining charge falls below
//! a threshold. The outputs are battery duration and the total number of
//! classifications executed — the adaptive engine extends both.
//!
//! [`simulate_battery`] keeps the paper's drain-only two-phase setup;
//! [`simulate_battery_cycles`] generalizes it to an arbitrary
//! [`EnergySource`] (harvesting / duty-cycled recharge), stepping through
//! as many drain/recharge threshold crossings as the horizon contains —
//! including brown-out (depleted, engine idle) and restart phases.

use super::source::EnergySource;

/// Battery parameters.
#[derive(Debug, Clone, PartialEq)]
pub struct BatteryModel {
    /// Capacity in ampere-hours.
    pub capacity_ah: f64,
    /// Supply voltage (energy = Ah * V * 3600 joules).
    pub voltage_v: f64,
}

impl Default for BatteryModel {
    fn default() -> Self {
        // Paper: "supposing a 10Ah energy budget"; KRIA rails are 5 V.
        BatteryModel {
            capacity_ah: 10.0,
            voltage_v: 5.0,
        }
    }
}

impl BatteryModel {
    pub fn energy_j(&self) -> f64 {
        self.capacity_ah * self.voltage_v * 3600.0
    }
}

/// A pack of per-shard batteries: the sharded server gives every
/// accelerator replica its own cell instead of draining one global budget,
/// so a hot shard degrades alone. `split` conserves the total energy and
/// mirrors the even joule split `AdaptiveServer::start` applies to a
/// global `EnergyMonitor` — change the policy in both places together.
#[derive(Debug, Clone, PartialEq)]
pub struct BatteryPack {
    pub cells: Vec<BatteryModel>,
}

impl BatteryPack {
    /// Split `total` evenly into `shards` cells (clamped to at least 1).
    pub fn split(total: &BatteryModel, shards: usize) -> Self {
        let n = shards.max(1);
        BatteryPack {
            cells: vec![
                BatteryModel {
                    capacity_ah: total.capacity_ah / n as f64,
                    voltage_v: total.voltage_v,
                };
                n
            ],
        }
    }

    /// Energy of each cell in joules (what each shard's monitor is seeded
    /// with).
    pub fn cell_energy_j(&self) -> Vec<f64> {
        self.cells.iter().map(|c| c.energy_j()).collect()
    }

    pub fn total_energy_j(&self) -> f64 {
        self.cells.iter().map(|c| c.energy_j()).sum()
    }
}

/// Threshold policy of the Profile Manager (paper Fig. 4 left): run the
/// accurate profile while charge >= `switch_at_fraction`, then drop to the
/// low-power profile.
#[derive(Debug, Clone, PartialEq)]
pub struct AdaptivePolicy {
    /// *Remaining*-energy fraction below which the low-power profile runs
    /// (e.g. `0.5` switches once half the charge is gone). The two
    /// extremes: `0.0` never switches — the accurate profile runs until
    /// the battery dies — and `1.0` serves the low-power profile from the
    /// very start.
    pub switch_at_fraction: f64,
}

impl Default for AdaptivePolicy {
    fn default() -> Self {
        AdaptivePolicy {
            switch_at_fraction: 0.5,
        }
    }
}

/// Result of draining the battery with one engine configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct BatteryRun {
    pub label: String,
    pub duration_h: f64,
    pub classifications: u64,
    /// (profile, hours spent, classifications) per phase.
    pub phases: Vec<(String, f64, u64)>,
    /// Classification-weighted mean accuracy over the whole run.
    pub mean_accuracy: f64,
}

/// Drain the battery running one fixed profile continuously.
///
/// `power_mw` is the average engine power, `latency_us` the per-image
/// latency (images are classified back-to-back, as in the paper's
/// "running at full performance").
pub fn run_fixed(
    label: &str,
    battery: &BatteryModel,
    power_mw: f64,
    latency_us: f64,
    accuracy: f64,
) -> BatteryRun {
    let seconds = battery.energy_j() / (power_mw * 1e-3);
    let classifications = (seconds / (latency_us * 1e-6)) as u64;
    BatteryRun {
        label: label.to_string(),
        duration_h: seconds / 3600.0,
        classifications,
        phases: vec![(label.to_string(), seconds / 3600.0, classifications)],
        mean_accuracy: accuracy,
    }
}

/// Drain the battery with the adaptive engine: phase 1 on the accurate
/// profile until the threshold, phase 2 on the low-power profile.
#[allow(clippy::too_many_arguments)]
pub fn simulate_battery(
    battery: &BatteryModel,
    policy: &AdaptivePolicy,
    accurate: (&str, f64, f64, f64),  // (name, power_mw, latency_us, accuracy)
    low_power: (&str, f64, f64, f64),
) -> BatteryRun {
    let total_j = battery.energy_j();
    let phase1_j = total_j * (1.0 - policy.switch_at_fraction);
    let phase2_j = total_j - phase1_j;

    let (a_name, a_mw, a_lat, a_acc) = accurate;
    let (l_name, l_mw, l_lat, l_acc) = low_power;

    let s1 = phase1_j / (a_mw * 1e-3);
    let c1 = (s1 / (a_lat * 1e-6)) as u64;
    let s2 = phase2_j / (l_mw * 1e-3);
    let c2 = (s2 / (l_lat * 1e-6)) as u64;

    let total_c = c1 + c2;
    BatteryRun {
        label: format!("adaptive({a_name}->{l_name})"),
        duration_h: (s1 + s2) / 3600.0,
        classifications: total_c,
        phases: vec![
            (a_name.to_string(), s1 / 3600.0, c1),
            (l_name.to_string(), s2 / 3600.0, c2),
        ],
        mean_accuracy: if total_c == 0 {
            0.0
        } else {
            (a_acc * c1 as f64 + l_acc * c2 as f64) / total_c as f64
        },
    }
}

/// Options for the phase-stepped battery/recharge simulator
/// ([`simulate_battery_cycles`]).
#[derive(Debug, Clone, PartialEq)]
pub struct CycleSimConfig {
    /// Stop after this much simulated time (seconds). A recharging battery
    /// can cycle forever, so the horizon bounds the walk; drain-only runs
    /// (a source that never delivers again) also stop at depletion.
    pub horizon_s: f64,
    /// Hysteresis band (remaining fraction) around the switch threshold,
    /// mirroring `ManagerConfig::hysteresis`: downswitch below
    /// `switch_at_fraction - hysteresis`, upswitch above
    /// `switch_at_fraction + hysteresis`. With `0.0` and a source whose
    /// power sits between the two profiles' draws, the trajectory pins at
    /// the threshold and is served as low-power (the online manager needs
    /// the band to upswitch cleanly for the same reason).
    pub hysteresis: f64,
    /// Remaining fraction at which a browned-out (fully depleted, idle)
    /// engine restarts once the source has recharged it that far.
    pub restart_fraction: f64,
    /// Safety cap on recorded phases.
    pub max_phases: usize,
}

impl Default for CycleSimConfig {
    fn default() -> Self {
        CycleSimConfig {
            horizon_s: 24.0 * 3600.0,
            hysteresis: 0.0,
            restart_fraction: 0.05,
            max_phases: 10_000,
        }
    }
}

/// Engine state between phase boundaries of the cycle simulator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Mode {
    Accurate,
    LowPower,
    /// Browned out: battery fully depleted, engine off, only the source
    /// moves the energy level.
    Idle,
}

/// Label used for brown-out phases in [`BatteryRun::phases`].
pub const IDLE_PHASE: &str = "idle";

fn close_phase(
    phases: &mut Vec<(String, f64, u64)>,
    total_c: &mut u64,
    acc_weighted: &mut f64,
    label: &str,
    latency_us: f64,
    accuracy: f64,
    seconds: f64,
) {
    if seconds <= 0.0 {
        return;
    }
    let c = if latency_us > 0.0 {
        (seconds / (latency_us * 1e-6)) as u64
    } else {
        0
    };
    *total_c += c;
    *acc_weighted += accuracy * c as f64;
    phases.push((label.to_string(), seconds / 3600.0, c));
}

/// Drain *and recharge* the battery with the adaptive engine: an N-phase
/// generalization of [`simulate_battery`].
///
/// The walk is event-driven, not fixed-step: within each constant-power
/// segment of `source` the net rate is constant, so the next threshold /
/// depletion / restart crossing is closed-form and energy accounting is
/// exact. Phases alternate between the accurate profile, the low-power
/// profile, and [`IDLE_PHASE`] brown-outs; with `source ==
/// EnergySource::None` and an infinite horizon the result reduces to the
/// paper's two-phase [`simulate_battery`].
pub fn simulate_battery_cycles(
    battery: &BatteryModel,
    policy: &AdaptivePolicy,
    accurate: (&str, f64, f64, f64), // (name, power_mw, latency_us, accuracy)
    low_power: (&str, f64, f64, f64),
    source: &EnergySource,
    cfg: &CycleSimConfig,
) -> BatteryRun {
    let cap_j = battery.energy_j();
    let (a_name, a_mw, a_lat, a_acc) = accurate;
    let (l_name, l_mw, l_lat, l_acc) = low_power;
    let thr_j = cap_j * policy.switch_at_fraction;
    let h_j = cap_j * cfg.hysteresis;
    let down_j = (thr_j - h_j).max(0.0);
    let up_j = (thr_j + h_j).min(cap_j);
    let restart_j = (cap_j * cfg.restart_fraction).clamp(0.0, cap_j);
    let mode_info = |m: Mode| match m {
        Mode::Accurate => (a_name, a_lat, a_acc),
        Mode::LowPower => (l_name, l_lat, l_acc),
        Mode::Idle => (IDLE_PHASE, 0.0, 0.0),
    };

    let mut e = cap_j;
    let mut t = 0.0_f64;
    let mut mode = if e < thr_j { Mode::LowPower } else { Mode::Accurate };
    let mut phases: Vec<(String, f64, u64)> = Vec::new();
    let mut phase_start = 0.0_f64;
    let mut total_c = 0_u64;
    let mut acc_weighted = 0.0_f64;
    let mut zero_streak = 0_u32;
    // Hard step bound: a short-period duty cycle over a long horizon walks
    // one iteration per segment even with no phase changes.
    let mut steps = 0_u64;

    while t < cfg.horizon_s && phases.len() < cfg.max_phases && steps < 20_000_000 {
        steps += 1;
        let (seg_end, s_mw) = source.segment_at(t);
        if mode == Mode::Idle && e <= 0.0 && s_mw <= 0.0 && seg_end.is_infinite() {
            break; // dead battery and the source will never deliver again
        }
        // Out-of-band correction (no time passes): a pinned or saturating
        // engine can leave a segment strictly outside the hysteresis band
        // when the source strength changes; re-select like the online
        // manager would. Strict comparisons keep the threshold-pinned
        // equilibrium (e == up_j) stable.
        let corrected = match mode {
            Mode::LowPower if e > up_j => Some(Mode::Accurate),
            Mode::Accurate if e < down_j => Some(Mode::LowPower),
            _ => None,
        };
        if let Some(next) = corrected {
            let (label, lat, acc) = mode_info(mode);
            close_phase(
                &mut phases,
                &mut total_c,
                &mut acc_weighted,
                label,
                lat,
                acc,
                t - phase_start,
            );
            phase_start = t;
            mode = next;
        }
        let draw_mw = match mode {
            Mode::Accurate => a_mw,
            Mode::LowPower => l_mw,
            Mode::Idle => 0.0,
        };
        let net_w = (s_mw - draw_mw) * 1e-3;
        let t_seg = seg_end.min(cfg.horizon_s);

        // The energy level that would change the mode next, given the slope.
        let target_j = if net_w < 0.0 {
            match mode {
                // >= so an engine starting exactly on the boundary (e.g.
                // switch_at_fraction 1.0 on a full battery) downswitches
                // in a zero-length crossing instead of draining to empty.
                Mode::Accurate if e >= down_j && down_j > 0.0 => Some(down_j),
                _ => Some(0.0),
            }
        } else if net_w > 0.0 {
            match mode {
                Mode::LowPower if e < up_j => Some(up_j),
                Mode::Idle => Some(restart_j),
                _ => None, // charging with no boundary above: saturate at cap
            }
        } else {
            None
        };

        let t_cross = target_j.map(|tj| t + (tj - e) / net_w);
        let (t_next, crossed) = match t_cross {
            Some(tc) if tc <= t_seg => (tc.max(t), true),
            _ => (t_seg, false),
        };
        let dt = t_next - t;

        // Zeno guard: crossings can alternate with zero elapsed time —
        // zero hysteresis with a source between the two draws (pinned at
        // the threshold), or restart_fraction 0 with a source weaker than
        // the low-power draw (pinned at depletion). Hold the boundary
        // until the segment ends instead of flapping forever. At a
        // positive boundary the engine serves low-power along it; at
        // depletion it stays browned out — counting full-rate service on
        // a dead battery would create energy from nothing.
        zero_streak = if crossed && dt <= 1e-12 { zero_streak + 1 } else { 0 };
        if zero_streak >= 2 {
            let pinned = if e > 0.0 { Mode::LowPower } else { Mode::Idle };
            if mode != pinned {
                let (label, lat, acc) = mode_info(mode);
                close_phase(
                    &mut phases,
                    &mut total_c,
                    &mut acc_weighted,
                    label,
                    lat,
                    acc,
                    t - phase_start,
                );
                phase_start = t;
                mode = pinned;
            }
            zero_streak = 0;
            t = t_seg; // energy pinned at the boundary
            continue;
        }

        let e_next = if crossed {
            target_j.unwrap()
        } else {
            (e + net_w * dt).clamp(0.0, cap_j)
        };
        if crossed {
            let (label, lat, acc) = mode_info(mode);
            close_phase(
                &mut phases,
                &mut total_c,
                &mut acc_weighted,
                label,
                lat,
                acc,
                t_next - phase_start,
            );
            phase_start = t_next;
            let tj = target_j.unwrap();
            mode = match mode {
                Mode::Accurate | Mode::LowPower if tj <= 0.0 => Mode::Idle,
                Mode::Accurate => Mode::LowPower,
                Mode::LowPower => Mode::Accurate,
                Mode::Idle if restart_j < thr_j => Mode::LowPower,
                Mode::Idle => Mode::Accurate,
            };
        }
        e = e_next;
        t = t_next;
    }

    let (label, lat, acc) = mode_info(mode);
    close_phase(
        &mut phases,
        &mut total_c,
        &mut acc_weighted,
        label,
        lat,
        acc,
        t - phase_start,
    );

    BatteryRun {
        label: format!("cycles({a_name}<->{l_name}, {})", source.label()),
        duration_h: t / 3600.0,
        classifications: total_c,
        phases,
        mean_accuracy: if total_c == 0 { 0.0 } else { acc_weighted / total_c as f64 },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    const A: (&str, f64, f64, f64) = ("A8-W8", 142.0, 329.0, 0.96);
    const L: (&str, f64, f64, f64) = ("Mixed", 135.0, 329.0, 0.945);

    #[test]
    fn adaptive_outlasts_nonadaptive() {
        let bat = BatteryModel::default();
        let fixed = run_fixed(A.0, &bat, A.1, A.2, A.3);
        let adaptive = simulate_battery(&bat, &AdaptivePolicy::default(), A, L);
        assert!(adaptive.duration_h > fixed.duration_h);
        assert!(adaptive.classifications > fixed.classifications);
        assert!(adaptive.mean_accuracy < fixed.mean_accuracy);
        assert!(adaptive.mean_accuracy > L.3);
    }

    #[test]
    fn energy_accounting_is_exact() {
        let bat = BatteryModel {
            capacity_ah: 1.0,
            voltage_v: 5.0,
        };
        // 18000 J at 1000 mW -> 18000 s -> 5 h
        let run = run_fixed("x", &bat, 1000.0, 1e6, 1.0); // 1 s per image
        assert!((run.duration_h - 5.0).abs() < 1e-9);
        assert_eq!(run.classifications, 18000);
    }

    #[test]
    fn pack_split_conserves_energy() {
        let bat = BatteryModel::default();
        for shards in [1usize, 2, 4, 7] {
            let pack = BatteryPack::split(&bat, shards);
            assert_eq!(pack.cells.len(), shards);
            assert!((pack.total_energy_j() - bat.energy_j()).abs() < 1e-6);
            let per_cell = pack.cell_energy_j();
            assert!(per_cell
                .iter()
                .all(|&j| (j - bat.energy_j() / shards as f64).abs() < 1e-6));
        }
        // degenerate shard count clamps instead of dividing by zero
        assert_eq!(BatteryPack::split(&bat, 0).cells.len(), 1);
    }

    #[test]
    fn threshold_one_equals_low_power_only() {
        // switch_at_fraction is the REMAINING fraction below which the
        // low-power profile runs: 1.0 means the battery is "low" from the
        // first instant, so the whole budget is served low-power. (This
        // test was previously misnamed `threshold_zero_...`.)
        let bat = BatteryModel::default();
        let adaptive = simulate_battery(
            &bat,
            &AdaptivePolicy {
                switch_at_fraction: 1.0,
            },
            A,
            L,
        );
        let fixed_low = run_fixed(L.0, &bat, L.1, L.2, L.3);
        assert!((adaptive.duration_h - fixed_low.duration_h).abs() < 1e-6);
    }

    #[test]
    fn threshold_zero_never_switches_equals_fixed_accurate() {
        // The true threshold-zero case: the battery is never "low", so the
        // adaptive engine is indistinguishable from the fixed accurate one.
        let bat = BatteryModel::default();
        let adaptive = simulate_battery(
            &bat,
            &AdaptivePolicy {
                switch_at_fraction: 0.0,
            },
            A,
            L,
        );
        let fixed_acc = run_fixed(A.0, &bat, A.1, A.2, A.3);
        assert!((adaptive.duration_h - fixed_acc.duration_h).abs() < 1e-6);
        assert_eq!(adaptive.classifications, fixed_acc.classifications);
        assert!((adaptive.mean_accuracy - fixed_acc.mean_accuracy).abs() < 1e-12);
    }

    #[test]
    fn duration_monotone_in_switch_threshold() {
        testkit::check("earlier switch -> longer life", |rng| {
            let bat = BatteryModel::default();
            let t1 = rng.f64(0.0, 1.0);
            let t2 = rng.f64(0.0, 1.0);
            let (lo, hi) = if t1 < t2 { (t1, t2) } else { (t2, t1) };
            let r_lo = simulate_battery(&bat, &AdaptivePolicy { switch_at_fraction: lo }, A, L);
            let r_hi = simulate_battery(&bat, &AdaptivePolicy { switch_at_fraction: hi }, A, L);
            crate::prop_assert!(
                r_hi.duration_h >= r_lo.duration_h - 1e-9,
                "threshold {hi} gave {} < {} at {lo}",
                r_hi.duration_h,
                r_lo.duration_h
            );
            Ok(())
        });
    }

    #[test]
    fn cycles_with_no_source_match_two_phase_sim() {
        // With no recharge and an unbounded horizon the N-phase simulator
        // must reduce exactly to the paper's two-phase one.
        let bat = BatteryModel::default();
        let policy = AdaptivePolicy::default();
        let two = simulate_battery(&bat, &policy, A, L);
        let n = simulate_battery_cycles(
            &bat,
            &policy,
            A,
            L,
            &EnergySource::None,
            &CycleSimConfig {
                horizon_s: f64::INFINITY,
                ..Default::default()
            },
        );
        assert!((n.duration_h - two.duration_h).abs() < 1e-9);
        assert_eq!(n.classifications, two.classifications);
        assert!((n.mean_accuracy - two.mean_accuracy).abs() < 1e-12);
        assert_eq!(n.phases.len(), 2);
        assert_eq!(n.phases[0].0, A.0);
        assert_eq!(n.phases[1].0, L.0);
    }

    #[test]
    fn constant_recharge_between_draws_cycles_and_upswitches() {
        // A source stronger than the low-power draw but weaker than the
        // accurate draw: the engine oscillates across the hysteresis band —
        // degrade, recover, upswitch, repeat — for the whole horizon.
        let bat = BatteryModel {
            capacity_ah: 1e-4, // 1.8 J
            voltage_v: 5.0,
        };
        let src = EnergySource::constant(138.5); // between L (135) and A (142)
        let cfg = CycleSimConfig {
            horizon_s: 2000.0,
            hysteresis: 0.05,
            ..Default::default()
        };
        let run = simulate_battery_cycles(&bat, &AdaptivePolicy::default(), A, L, &src, &cfg);
        assert!(
            (run.duration_h - cfg.horizon_s / 3600.0).abs() < 1e-9,
            "recharging battery must survive to the horizon"
        );
        assert!(
            run.phases.len() > 2,
            "expected repeated drain/recharge crossings, got {:?}",
            run.phases
        );
        // at least one recovery upswitch: a low-power phase followed by an
        // accurate phase
        let upswitch = run.phases.windows(2).any(|w| w[0].0 == L.0 && w[1].0 == A.0);
        assert!(upswitch, "no upswitch in {:?}", run.phases);
        assert!(run.phases.iter().all(|p| p.0 != IDLE_PHASE));
        assert!(run.classifications > 0);
        assert!(run.mean_accuracy > L.3 && run.mean_accuracy < A.3);
    }

    #[test]
    fn duty_cycle_browns_out_and_restarts() {
        // A strong but mostly-off source: the battery dies during the off
        // phase (idle brown-out), recharges when the source returns, and
        // the engine restarts.
        let bat = BatteryModel {
            capacity_ah: 0.2 / (5.0 * 3600.0), // 0.2 J
            voltage_v: 5.0,
        };
        let src = EnergySource::duty_cycle(1000.0, 1.0, 10.0);
        let cfg = CycleSimConfig {
            horizon_s: 30.0,
            hysteresis: 0.02,
            ..Default::default()
        };
        let run = simulate_battery_cycles(&bat, &AdaptivePolicy::default(), A, L, &src, &cfg);
        let idle = run.phases.iter().position(|p| p.0 == IDLE_PHASE);
        assert!(idle.is_some(), "no brown-out phase in {:?}", run.phases);
        let idle = idle.unwrap();
        assert!(
            run.phases[idle + 1..].iter().any(|p| p.0 != IDLE_PHASE),
            "engine never restarted after brown-out: {:?}",
            run.phases
        );
        assert_eq!(run.phases[idle].2, 0, "idle phases classify nothing");
    }

    #[test]
    fn zero_hysteresis_pinning_terminates() {
        // Source between the two draws with no hysteresis: the trajectory
        // pins at the threshold instead of flapping forever, served as
        // low-power, and the walk still reaches the horizon.
        let bat = BatteryModel {
            capacity_ah: 1e-4,
            voltage_v: 5.0,
        };
        let src = EnergySource::constant(138.5);
        let cfg = CycleSimConfig {
            horizon_s: 600.0,
            hysteresis: 0.0,
            ..Default::default()
        };
        let run = simulate_battery_cycles(&bat, &AdaptivePolicy::default(), A, L, &src, &cfg);
        assert!((run.duration_h - cfg.horizon_s / 3600.0).abs() < 1e-9);
        assert!(run.phases.len() <= 3, "pinning must not spray phases: {:?}", run.phases);
        // the pinned tail serves the low-power profile
        assert_eq!(run.phases.last().unwrap().0, L.0);
    }

    #[test]
    fn zero_restart_with_weak_source_stays_browned_out() {
        // Regression: with restart_fraction 0 and a source weaker than the
        // low-power draw, the depleted engine must stay browned out (an
        // idle tail), not get pinned into serving low-power at full rate
        // on harvest it does not have.
        let bat = BatteryModel {
            capacity_ah: 1e-4, // 1.8 J
            voltage_v: 5.0,
        };
        let src = EnergySource::constant(50.0); // well below L's 135 mW
        let cfg = CycleSimConfig {
            horizon_s: 4000.0,
            restart_fraction: 0.0,
            ..Default::default()
        };
        let run = simulate_battery_cycles(&bat, &AdaptivePolicy::default(), A, L, &src, &cfg);
        let last = run.phases.last().unwrap();
        assert_eq!(last.0, IDLE_PHASE, "expected an idle tail: {:?}", run.phases);
        assert_eq!(last.2, 0);
        assert!((run.duration_h - cfg.horizon_s / 3600.0).abs() < 1e-9);
        // Energy actually served never exceeds capacity + harvest banked
        // before death (conservation: no service on a dead battery).
        let served_j: f64 = run
            .phases
            .iter()
            .map(|p| match p.0.as_str() {
                s if s == A.0 => p.1 * 3600.0 * A.1 * 1e-3,
                s if s == L.0 => p.1 * 3600.0 * L.1 * 1e-3,
                _ => 0.0,
            })
            .sum();
        let alive_s: f64 = run
            .phases
            .iter()
            .filter(|p| p.0 != IDLE_PHASE)
            .map(|p| p.1 * 3600.0)
            .sum();
        let budget_j = bat.energy_j() + 50.0 * 1e-3 * alive_s;
        assert!(served_j <= budget_j + 1e-6, "served {served_j} J > budget {budget_j} J");
    }

    #[test]
    fn cycle_phase_durations_sum_to_run_duration_property() {
        testkit::check("cycle phases partition the run", |rng| {
            let bat = BatteryModel {
                capacity_ah: rng.f64(0.5e-4, 3e-4),
                voltage_v: 5.0,
            };
            let src = match rng.u64(0, 2) {
                0 => EnergySource::None,
                1 => EnergySource::constant(rng.f64(0.0, 300.0)),
                _ => EnergySource::duty_cycle(
                    rng.f64(50.0, 500.0),
                    rng.f64(0.5, 5.0),
                    rng.f64(0.5, 5.0),
                ),
            };
            let cfg = CycleSimConfig {
                horizon_s: rng.f64(10.0, 1000.0),
                hysteresis: rng.f64(0.0, 0.1),
                ..Default::default()
            };
            let policy = AdaptivePolicy {
                switch_at_fraction: rng.f64(0.0, 1.0),
            };
            let run = simulate_battery_cycles(&bat, &policy, A, L, &src, &cfg);
            let phase_sum_h: f64 = run.phases.iter().map(|p| p.1).sum();
            crate::prop_assert!(
                (phase_sum_h - run.duration_h).abs() < 1e-9,
                "phases sum to {phase_sum_h} h but run lasted {} h ({:?})",
                run.duration_h,
                run.phases
            );
            crate::prop_assert!(
                run.duration_h * 3600.0 <= cfg.horizon_s + 1e-9,
                "run overshot the horizon: {} h vs {} s",
                run.duration_h,
                cfg.horizon_s
            );
            Ok(())
        });
    }
}
