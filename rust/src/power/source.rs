//! Energy *sources*: the recharge side of the battery model.
//!
//! The paper's Fig. 4 setup only ever drains a fixed budget, but the
//! sustainable-edge scenarios the abstract targets (harvesting, duty-cycled
//! supplies) need the battery to recover so the Profile Manager's upswitch
//! path can fire. An [`EnergySource`] describes the power delivered to one
//! battery as a function of *virtual* time — the coordinator advances it on
//! accumulated per-batch latency, never wall clock, so every run is
//! deterministic.
//!
//! Three shapes cover the common deployments:
//!
//! * [`EnergySource::Constant`] — a regulated harvest rail (TEG, tether);
//! * [`EnergySource::DutyCycle`] — an on/off schedule (relay-switched
//!   charger, duty-cycled harvester);
//! * [`EnergySource::Piecewise`] — a periodic piecewise-linear profile
//!   (solar-like diurnal curve), linearly interpolated between points.
//!
//! `energy_between` integrates the source analytically (trapezoids for the
//! piecewise shape), so accounting is exact: no step-size error can leak
//! into the conservation invariants the energy tests assert.

/// Slices per full period used when a [`EnergySource::Piecewise`] profile is
/// staircased for the phase-stepped battery simulator. Each slice carries
/// its *exact* mean power, so slicing never changes total energy — only the
/// sub-slice timing of threshold crossings.
const PIECEWISE_SLICES_MIN: usize = 8;
const PIECEWISE_SLICES_PER_POINT: usize = 8;

/// A recharge source feeding one battery (power in mW over virtual time).
#[derive(Debug, Clone, PartialEq, Default)]
pub enum EnergySource {
    /// No recharge: the battery only drains (the paper's Fig. 4 setup).
    #[default]
    None,
    /// Constant harvest power.
    Constant { power_mw: f64 },
    /// `power_mw` for `on_s` seconds, then 0 for `off_s`, repeating.
    /// The schedule is anchored at virtual time 0 (on-phase first).
    DutyCycle { power_mw: f64, on_s: f64, off_s: f64 },
    /// Periodic piecewise-linear profile: `points` are `(t_s, power_mw)`
    /// samples inside `[0, period_s)`, strictly increasing in time, with
    /// linear interpolation between consecutive points and across the
    /// period wrap (last point back to the first).
    Piecewise { period_s: f64, points: Vec<(f64, f64)> },
}

impl EnergySource {
    /// Constant harvest source (`power_mw >= 0`).
    pub fn constant(power_mw: f64) -> Self {
        assert!(
            power_mw.is_finite() && power_mw >= 0.0,
            "constant source power must be finite and >= 0, got {power_mw}"
        );
        EnergySource::Constant { power_mw }
    }

    /// Duty-cycled source: `power_mw` for `on_s`, 0 for `off_s`, repeating.
    pub fn duty_cycle(power_mw: f64, on_s: f64, off_s: f64) -> Self {
        assert!(
            power_mw.is_finite() && power_mw >= 0.0,
            "duty-cycle power must be finite and >= 0, got {power_mw}"
        );
        assert!(
            on_s >= 0.0 && off_s >= 0.0 && on_s + off_s > 0.0,
            "duty-cycle needs on_s, off_s >= 0 with a positive period, got on={on_s} off={off_s}"
        );
        EnergySource::DutyCycle { power_mw, on_s, off_s }
    }

    /// Periodic piecewise-linear ("solar-like") source.
    pub fn piecewise(period_s: f64, points: Vec<(f64, f64)>) -> Self {
        assert!(period_s > 0.0, "piecewise source needs period_s > 0");
        assert!(!points.is_empty(), "piecewise source needs >= 1 point");
        for w in points.windows(2) {
            assert!(
                w[0].0 < w[1].0,
                "piecewise points must be strictly increasing in time: {} then {}",
                w[0].0,
                w[1].0
            );
        }
        for &(t, p) in &points {
            assert!(
                (0.0..period_s).contains(&t),
                "piecewise point time {t} outside [0, {period_s})"
            );
            assert!(p.is_finite() && p >= 0.0, "piecewise power must be finite and >= 0, got {p}");
        }
        EnergySource::Piecewise { period_s, points }
    }

    /// Instantaneous power (mW) delivered at virtual time `t_s`.
    pub fn power_at(&self, t_s: f64) -> f64 {
        match self {
            EnergySource::None => 0.0,
            EnergySource::Constant { power_mw } => *power_mw,
            EnergySource::DutyCycle { power_mw, on_s, off_s } => {
                if *on_s <= 0.0 {
                    return 0.0;
                }
                let period = on_s + off_s;
                let phase = t_s.rem_euclid(period);
                if phase < *on_s {
                    *power_mw
                } else {
                    0.0
                }
            }
            EnergySource::Piecewise { period_s, points } => {
                let phase = t_s.rem_euclid(*period_s);
                let n = points.len();
                // Find the segment containing `phase`; segments run between
                // consecutive points, plus the wrap segment (last -> first).
                let (t0, p0, t1, p1) = if phase < points[0].0 {
                    // before the first point: inside the wrap segment,
                    // shifted down one period
                    let (tl, pl) = points[n - 1];
                    (tl - period_s, pl, points[0].0, points[0].1)
                } else {
                    match points.windows(2).find(|w| phase < w[1].0) {
                        Some(w) => (w[0].0, w[0].1, w[1].0, w[1].1),
                        // past the last point: wrap segment toward the first
                        None => {
                            let (tl, pl) = points[n - 1];
                            (tl, pl, points[0].0 + period_s, points[0].1)
                        }
                    }
                };
                if t1 <= t0 {
                    // single point degenerates to a constant source
                    return p0;
                }
                p0 + (p1 - p0) * (phase - t0) / (t1 - t0)
            }
        }
    }

    /// Joules delivered over the virtual-time interval `[t0_s, t1_s]`.
    ///
    /// Exact for every variant: closed-form for constant and duty-cycled
    /// sources, trapezoid integration (exact for a piecewise-linear
    /// integrand) for the piecewise shape. Additive:
    /// `energy_between(a, b) + energy_between(b, c) == energy_between(a, c)`
    /// up to float rounding.
    pub fn energy_between(&self, t0_s: f64, t1_s: f64) -> f64 {
        if t1_s <= t0_s {
            return 0.0;
        }
        match self {
            EnergySource::None => 0.0,
            EnergySource::Constant { power_mw } => power_mw * 1e-3 * (t1_s - t0_s),
            EnergySource::DutyCycle { .. } => self.duty_cum_j(t1_s) - self.duty_cum_j(t0_s),
            EnergySource::Piecewise { .. } => {
                self.piecewise_cum_j(t1_s) - self.piecewise_cum_j(t0_s)
            }
        }
    }

    /// Cumulative joules of a duty-cycled source over `[0, t_s]`.
    fn duty_cum_j(&self, t_s: f64) -> f64 {
        let EnergySource::DutyCycle { power_mw, on_s, off_s } = self else {
            unreachable!("duty_cum_j on a non-duty-cycle source");
        };
        let period = on_s + off_s;
        let full = (t_s / period).floor();
        let rem = t_s - full * period;
        power_mw * 1e-3 * (full * on_s + rem.min(*on_s))
    }

    /// Cumulative joules of a piecewise source over `[0, t_s]`.
    fn piecewise_cum_j(&self, t_s: f64) -> f64 {
        let EnergySource::Piecewise { period_s, points } = self else {
            unreachable!("piecewise_cum_j on a non-piecewise source");
        };
        let full = (t_s / period_s).floor();
        let rem = t_s - full * period_s;
        full * self.piecewise_partial_j(*period_s, points) + self.piecewise_partial_j(rem, points)
    }

    /// Integral of the piecewise profile over `[0, phase]`, `phase` within
    /// one period. Trapezoids between breakpoints are exact because the
    /// integrand is linear there.
    fn piecewise_partial_j(&self, phase: f64, points: &[(f64, f64)]) -> f64 {
        if phase <= 0.0 {
            return 0.0;
        }
        let mut ts = vec![0.0];
        for &(t, _) in points {
            if t > 0.0 && t < phase {
                ts.push(t);
            }
        }
        ts.push(phase);
        let mut j = 0.0;
        for w in ts.windows(2) {
            let (a, b) = (w[0], w[1]);
            j += (b - a) * (self.power_at(a) + self.power_at(b)) * 0.5 * 1e-3;
        }
        j
    }

    /// The piecewise-constant segment containing virtual time `t_s`:
    /// `(segment_end_s, mean_power_mw)` with mean power exact over
    /// `[t_s, segment_end_s)`.
    ///
    /// This is the stepping interface of the phase-stepped battery
    /// simulator: within a segment the net drain rate is constant, so
    /// threshold/depletion crossing times are closed-form. A piecewise
    /// profile is staircased into energy-exact slices (see
    /// [`PIECEWISE_SLICES_PER_POINT`]); the other variants are already
    /// piecewise-constant and step on their true edges.
    pub fn segment_at(&self, t_s: f64) -> (f64, f64) {
        match self {
            EnergySource::None => (f64::INFINITY, 0.0),
            EnergySource::Constant { power_mw } => (f64::INFINITY, *power_mw),
            EnergySource::DutyCycle { power_mw, on_s, off_s } => {
                if *on_s <= 0.0 {
                    return (f64::INFINITY, 0.0);
                }
                if *off_s <= 0.0 {
                    return (f64::INFINITY, *power_mw);
                }
                let period = on_s + off_s;
                let cycle = (t_s / period).floor();
                let phase = t_s - cycle * period;
                // Boundary snap: `t_s % period` can land a few ULPs *before*
                // an edge it has already crossed, which would hand back a
                // segment ending microscopically after `t_s` and stall an
                // event-stepped caller in ULP-sized steps. Positions within
                // `eps` of an edge belong to the segment *after* it (the
                // sliver of mis-attributed power is O(eps) and negligible).
                let eps = period * 1e-9;
                if phase < on_s - eps {
                    (cycle * period + on_s, *power_mw)
                } else if phase < period - eps {
                    ((cycle + 1.0) * period, 0.0)
                } else {
                    ((cycle + 1.0) * period + on_s, *power_mw)
                }
            }
            EnergySource::Piecewise { period_s, points } => {
                let slices = (points.len() * PIECEWISE_SLICES_PER_POINT).max(PIECEWISE_SLICES_MIN);
                let w = period_s / slices as f64;
                let k = (t_s / w).floor();
                // Same boundary snap as the duty-cycle arm: a slice end
                // within `eps` of `t_s` is already behind us.
                let mut end = (k + 1.0) * w;
                if end - t_s <= w * 1e-9 {
                    end = (k + 2.0) * w;
                }
                let mean_mw = self.energy_between(t_s, end) / (end - t_s) * 1e3;
                (end, mean_mw)
            }
        }
    }

    /// Human-readable label for tables/logs.
    pub fn label(&self) -> String {
        match self {
            EnergySource::None => "none".to_string(),
            EnergySource::Constant { power_mw } => format!("constant {power_mw} mW"),
            EnergySource::DutyCycle { power_mw, on_s, off_s } => {
                format!("duty {power_mw} mW {on_s}s/{off_s}s")
            }
            EnergySource::Piecewise { period_s, points } => {
                format!("piecewise {} pts / {period_s}s", points.len())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    #[test]
    fn none_and_constant_integrate_trivially() {
        assert_eq!(EnergySource::None.power_at(3.0), 0.0);
        assert_eq!(EnergySource::None.energy_between(0.0, 100.0), 0.0);
        let c = EnergySource::constant(500.0); // 0.5 W
        assert_eq!(c.power_at(42.0), 500.0);
        assert!((c.energy_between(10.0, 20.0) - 5.0).abs() < 1e-12);
        // reversed/empty intervals deliver nothing
        assert_eq!(c.energy_between(20.0, 10.0), 0.0);
    }

    #[test]
    fn duty_cycle_power_and_energy() {
        // 1 W, 2 s on / 3 s off: period 5 s, 2 J per period
        let d = EnergySource::duty_cycle(1000.0, 2.0, 3.0);
        assert_eq!(d.power_at(0.0), 1000.0);
        assert_eq!(d.power_at(1.9), 1000.0);
        assert_eq!(d.power_at(2.1), 0.0);
        assert_eq!(d.power_at(5.0), 1000.0); // wraps
        assert!((d.energy_between(0.0, 5.0) - 2.0).abs() < 1e-12);
        assert!((d.energy_between(0.0, 50.0) - 20.0).abs() < 1e-12);
        // partial on-phase, then straddling an edge
        assert!((d.energy_between(0.0, 1.0) - 1.0).abs() < 1e-12);
        assert!((d.energy_between(1.0, 3.0) - 1.0).abs() < 1e-12);
        // off-phase only
        assert_eq!(d.energy_between(2.0, 5.0), 0.0);
    }

    #[test]
    fn piecewise_interpolates_and_integrates_exactly() {
        // triangle: 0 mW at t=0, 1000 mW at t=50, back to 0 at t=100 (wrap)
        let s = EnergySource::piecewise(100.0, vec![(0.0, 0.0), (50.0, 1000.0)]);
        assert_eq!(s.power_at(0.0), 0.0);
        assert!((s.power_at(25.0) - 500.0).abs() < 1e-9);
        assert_eq!(s.power_at(50.0), 1000.0);
        assert!((s.power_at(75.0) - 500.0).abs() < 1e-9);
        // mean power 500 mW -> 0.5 J/s * 100 s = 50 J per period
        assert!((s.energy_between(0.0, 100.0) - 50.0).abs() < 1e-9);
        assert!((s.energy_between(0.0, 1000.0) - 500.0).abs() < 1e-9);
        // first quarter: triangle area = 0.5 * 25 s * 500 mW = 6.25 J
        assert!((s.energy_between(0.0, 25.0) - 6.25).abs() < 1e-9);
    }

    #[test]
    fn energy_between_is_additive_property() {
        testkit::check("energy integral additivity", |rng| {
            let src = match rng.u64(0, 2) {
                0 => EnergySource::constant(rng.f64(0.0, 2000.0)),
                1 => EnergySource::duty_cycle(
                    rng.f64(1.0, 2000.0),
                    rng.f64(0.01, 10.0),
                    rng.f64(0.01, 10.0),
                ),
                _ => EnergySource::piecewise(
                    100.0,
                    vec![
                        (0.0, rng.f64(0.0, 1000.0)),
                        (30.0, rng.f64(0.0, 1000.0)),
                        (70.0, rng.f64(0.0, 1000.0)),
                    ],
                ),
            };
            let mut ts = [rng.f64(0.0, 500.0), rng.f64(0.0, 500.0), rng.f64(0.0, 500.0)];
            ts.sort_by(f64::total_cmp);
            let [a, b, c] = ts;
            let whole = src.energy_between(a, c);
            let split = src.energy_between(a, b) + src.energy_between(b, c);
            crate::prop_assert!(
                (whole - split).abs() < 1e-9 * (1.0 + whole.abs()),
                "non-additive: [{a},{c}] = {whole} but split sum = {split} ({src:?})"
            );
            Ok(())
        });
    }

    #[test]
    fn segments_cover_time_and_conserve_energy_property() {
        // Walking segment_at across whole segments and summing
        // mean_power * dt must reproduce energy_between — the staircase
        // never creates or destroys joules. (A segment's mean power is
        // exact over the *full* `[t, end)` interval, so the walk stops on
        // a boundary rather than truncating mid-segment; event-stepped
        // consumers that stop early re-query from the stop point.)
        testkit::check("segment staircase conserves energy", |rng| {
            let src = match rng.u64(0, 2) {
                0 => EnergySource::constant(rng.f64(0.0, 2000.0)),
                1 => EnergySource::duty_cycle(
                    rng.f64(1.0, 2000.0),
                    rng.f64(0.05, 5.0),
                    rng.f64(0.05, 5.0),
                ),
                _ => EnergySource::piecewise(
                    60.0,
                    vec![(5.0, rng.f64(0.0, 800.0)), (40.0, rng.f64(0.0, 800.0))],
                ),
            };
            let t0 = rng.f64(0.0, 100.0);
            let t1 = t0 + rng.f64(0.1, 200.0);
            let mut t = t0;
            let mut j = 0.0;
            let mut guard = 0;
            while t < t1 {
                let (end, p_mw) = src.segment_at(t);
                crate::prop_assert!(end > t, "segment must make progress at {t} ({src:?})");
                let stop = if end.is_finite() { end } else { t1 };
                j += p_mw * 1e-3 * (stop - t);
                t = stop;
                guard += 1;
                crate::prop_assert!(guard < 100_000, "segment walk did not terminate");
            }
            let want = src.energy_between(t0, t);
            crate::prop_assert!(
                (j - want).abs() < 1e-6 * (1.0 + want.abs()),
                "staircase {j} J != integral {want} J over [{t0},{t}] ({src:?})"
            );
            Ok(())
        });
    }

    #[test]
    fn degenerate_duty_cycles() {
        // always-off and always-on degenerate cleanly
        let off = EnergySource::duty_cycle(1000.0, 0.0, 5.0);
        assert_eq!(off.power_at(1.0), 0.0);
        assert_eq!(off.energy_between(0.0, 100.0), 0.0);
        assert_eq!(off.segment_at(3.0).1, 0.0);
        let on = EnergySource::duty_cycle(1000.0, 5.0, 0.0);
        assert_eq!(on.power_at(7.0), 1000.0);
        assert!((on.energy_between(0.0, 10.0) - 10.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "strictly increasing")]
    fn piecewise_rejects_unsorted_points() {
        let _ = EnergySource::piecewise(10.0, vec![(5.0, 1.0), (2.0, 1.0)]);
    }

    #[test]
    #[should_panic(expected = "positive period")]
    fn duty_cycle_rejects_zero_period() {
        let _ = EnergySource::duty_cycle(100.0, 0.0, 0.0);
    }

    #[test]
    fn labels_are_stable() {
        assert_eq!(EnergySource::None.label(), "none");
        assert_eq!(EnergySource::constant(250.0).label(), "constant 250 mW");
        assert_eq!(EnergySource::duty_cycle(100.0, 1.0, 2.0).label(), "duty 100 mW 1s/2s");
    }
}
