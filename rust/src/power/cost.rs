//! Per-inference cost of one model variant: the Table-1 code path
//! (HLS estimate + actor-level simulation + activity-based power) folded
//! into a single number the approximation explorer can rank candidates by.

use crate::dataflow::{simulate_image, FoldingConfig, SimReport};
use crate::hls::{estimate_engine, Calibration, DeviceModel};
use crate::qonnx::QonnxModel;

use super::estimate_power;

/// What one classification costs on a given engine configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct InferenceCost {
    pub power_mw: f64,
    pub latency_us: f64,
    /// Energy per inference in microjoules (`power_mw * latency_us * 1e-3`).
    pub energy_uj: f64,
}

/// Cost `model` on `images` (representative inputs — the power model is
/// value-dependent): runs the HLS resource estimate once and one streaming
/// simulation per image, then averages. Deterministic for fixed inputs; no
/// wall clock anywhere.
pub fn estimate_inference_cost(
    model: &QonnxModel,
    fold: &FoldingConfig,
    cal: &Calibration,
    dev: &DeviceModel,
    images: &[&[u8]],
) -> InferenceCost {
    assert!(!images.is_empty(), "need at least one image to cost");
    let est = estimate_engine(model, fold, cal);
    let sims: Vec<SimReport> = images.iter().map(|img| simulate_image(model, fold, img)).collect();
    let power = estimate_power(model, &est, &sims, cal, dev);
    let cycles = sims.iter().map(|s| s.cycles as f64).sum::<f64>() / sims.len() as f64;
    let latency_us = cycles / dev.clock_mhz;
    InferenceCost {
        power_mw: power.total_mw,
        latency_us,
        energy_uj: power.total_mw * latency_us * 1e-3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::{read_str, test_model_json};

    #[test]
    fn cost_is_positive_and_consistent() {
        let m = read_str(&test_model_json(2, 4)).unwrap();
        let img: Vec<u8> = (0..m.input_shape.elems()).map(|i| (i * 31 % 256) as u8).collect();
        let cost = estimate_inference_cost(
            &m,
            &FoldingConfig::default(),
            &Calibration::default(),
            &DeviceModel::kria_kv260(),
            &[&img],
        );
        assert!(cost.power_mw > 0.0);
        assert!(cost.latency_us > 0.0);
        let want = cost.power_mw * cost.latency_us * 1e-3;
        assert!((cost.energy_uj - want).abs() < 1e-12);
    }

    #[test]
    fn more_images_average_deterministically() {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let a: Vec<u8> = vec![0; m.input_shape.elems()];
        let b: Vec<u8> = (0..m.input_shape.elems()).map(|i| (i % 256) as u8).collect();
        let fold = FoldingConfig::default();
        let cal = Calibration::default();
        let dev = DeviceModel::kria_kv260();
        let once = estimate_inference_cost(&m, &fold, &cal, &dev, &[&a, &b]);
        let again = estimate_inference_cost(&m, &fold, &cal, &dev, &[&a, &b]);
        assert_eq!(once, again, "costing must be deterministic");
        // latency is shape/folding-bound: identical across inputs
        let solo = estimate_inference_cost(&m, &fold, &cal, &dev, &[&a]);
        assert_eq!(solo.latency_us, once.latency_us);
    }
}
