//! Activity-based power estimation for one engine + workload.

use crate::dataflow::SimReport;
use crate::hls::{Calibration, DeviceModel, EngineEstimate};
use crate::qonnx::QonnxModel;

/// Power estimate breakdown (mW).
#[derive(Debug, Clone, PartialEq)]
pub struct PowerBreakdown {
    pub static_mw: f64,
    pub toggle_mw: f64,
    pub mac_mw: f64,
    pub bram_mw: f64,
    pub total_mw: f64,
    /// Mean toggle rate over the engine's streams (diagnostic).
    pub toggle_rate: f64,
}

/// Estimate average power while classifying continuously.
///
/// `sims` are dataflow simulations of representative images (their toggle /
/// MAC statistics are averaged); `est` provides the resource-dependent
/// leakage; `model` provides bit-widths for the MAC energy term.
pub fn estimate_power(
    model: &QonnxModel,
    est: &EngineEstimate,
    sims: &[SimReport],
    cal: &Calibration,
    dev: &DeviceModel,
) -> PowerBreakdown {
    assert!(!sims.is_empty(), "need at least one simulated image");
    let n = sims.len() as f64;
    let cycles = sims.iter().map(|s| s.cycles as f64).sum::<f64>() / n;
    let f_hz = dev.clock_mhz * 1e6;
    let seconds_per_image = cycles / f_hz;

    // --- toggles on the streaming fabric ---
    let toggle_bits: f64 = sims
        .iter()
        .map(|s| s.fifos.iter().map(|f| f.toggle_bits as f64).sum::<f64>())
        .sum::<f64>()
        / n;
    let toggle_mw = toggle_bits * cal.e_toggle_pj * 1e-12 / seconds_per_image * 1e3;

    // --- MAC switching energy (executed MACs are value-dependent: the
    // simulator skips zero activations, as clock-gated MAC lanes do) ---
    let mut mac_pj = 0.0;
    for sim in sims {
        for actor in &sim.actors {
            if actor.macs == 0 {
                continue;
            }
            let (a_bits, w_bits) = model
                .conv_layers()
                .find(|c| c.name == actor.name)
                .map(|c| (c.act_bits, c.weight_bits))
                .or_else(|| {
                    model
                        .dense()
                        .filter(|d| d.name == actor.name)
                        .map(|d| (8, d.weight_bits))
                })
                .unwrap_or((8, 8));
            mac_pj += actor.macs as f64 * (a_bits + w_bits) as f64 * cal.e_mac_bit_pj;
        }
    }
    let mac_mw = (mac_pj / n) * 1e-12 / seconds_per_image * 1e3;

    // --- BRAM accesses: one weight fetch per MAC group + line buffer traffic ---
    let bram_accesses: f64 = sims
        .iter()
        .map(|s| s.total_macs as f64 / 8.0) // 8 weights per 18Kb-word fetch
        .sum::<f64>()
        / n;
    let bram_mw = bram_accesses * cal.e_bram_pj * 1e-12 / seconds_per_image * 1e3;

    // --- leakage scaled by utilized logic ---
    let lut_pct = dev.lut_pct(est.luts);
    let static_mw = cal.p_static_mw + cal.p_leak_per_lut_pct * lut_pct;

    let toggle_rate = sims.iter().map(SimReport::mean_toggle_rate).sum::<f64>() / n;

    PowerBreakdown {
        static_mw,
        toggle_mw,
        mac_mw,
        bram_mw,
        total_mw: static_mw + toggle_mw + mac_mw + bram_mw,
        toggle_rate,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::{simulate_image, FoldingConfig};
    use crate::hls::estimate_engine;
    use crate::qonnx::{read_str, test_model_json};

    fn setup() -> (QonnxModel, EngineEstimate, Vec<SimReport>) {
        let m = read_str(&test_model_json(2, 4)).unwrap();
        let f = FoldingConfig::default();
        let est = estimate_engine(&m, &f, &Calibration::default());
        let img: Vec<u8> = (0..m.input_shape.elems()).map(|i| (i * 31 % 256) as u8).collect();
        let sims = vec![simulate_image(&m, &f, &img)];
        (m, est, sims)
    }

    #[test]
    fn power_is_positive_and_decomposes() {
        let (m, est, sims) = setup();
        let p = estimate_power(
            &m,
            &est,
            &sims,
            &Calibration::default(),
            &DeviceModel::kria_kv260(),
        );
        assert!(p.total_mw > 0.0);
        let sum = p.static_mw + p.toggle_mw + p.mac_mw + p.bram_mw;
        assert!((p.total_mw - sum).abs() < 1e-9);
        assert!(p.static_mw > 0.0 && p.toggle_mw >= 0.0);
    }

    #[test]
    fn busier_data_means_more_dynamic_power() {
        let m = read_str(&test_model_json(2, 4)).unwrap();
        let f = FoldingConfig::default();
        let cal = Calibration::default();
        let dev = DeviceModel::kria_kv260();
        let est = estimate_engine(&m, &f, &cal);
        let quiet = vec![simulate_image(&m, &f, &vec![0u8; m.input_shape.elems()])];
        let noisy: Vec<u8> = (0..m.input_shape.elems())
            .map(|i| if i % 2 == 0 { 255 } else { 0 })
            .collect();
        let busy = vec![simulate_image(&m, &f, &noisy)];
        let p_quiet = estimate_power(&m, &est, &quiet, &cal, &dev);
        let p_busy = estimate_power(&m, &est, &busy, &cal, &dev);
        assert!(
            p_busy.total_mw > p_quiet.total_mw,
            "busy {} <= quiet {}",
            p_busy.total_mw,
            p_quiet.total_mw
        );
    }
}
