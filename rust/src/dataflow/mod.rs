//! Streaming dataflow engine: the FPGA inference fabric, simulated.
//!
//! The paper's hardware is a *streaming architecture*: one hardware block
//! per CNN layer (line buffer -> conv MAC array -> pool ... -> dense), all
//! layers connected by on-chip FIFOs and running concurrently. This module
//! is the substitution for that fabric (DESIGN.md §2):
//!
//! * [`exec`] — the functional reference path: executes the integer
//!   pipeline of a [`crate::qonnx::QonnxModel`] bit-exactly (i64
//!   accumulators, TFLite-style per-channel requantization). Pinned against
//!   `python/compile/intref.py` via exported test vectors. Used for
//!   accuracy sweeps and as the bit-exactness oracle for the packed engine.
//! * [`kernels`] — the serving hot path: per-profile [`CompiledModel`]s
//!   pre-pack conv/dense weights into output-channel tiles with fused
//!   bias/requant params, and [`BatchExecutor`] runs whole batches
//!   batch-major and layer-major from a per-executor arena (zero
//!   allocations after warm-up). Asserted bit-exact vs [`exec`] by the
//!   property suite and on every bench reply.
//! * [`actors`] + [`sim`] — the cycle-approximate actor/FIFO simulation of
//!   the streaming template (Fig. 2 right in the paper): line-buffer,
//!   conv-MAC (with PE/SIMD folding), max-pool, and gemm actors exchanging
//!   pixel tokens through bounded FIFOs. It computes the *same* integers as
//!   [`exec`] while additionally producing latency (cycles), FIFO occupancy,
//!   firing counts, and value-dependent toggle statistics — the inputs to
//!   the power model (`crate::power`), which the paper notes depends on
//!   "the actual values of weights and the data being processed".

pub mod actors;
pub mod exec;
pub mod fifo;
pub mod kernels;
pub mod sim;

pub use exec::{execute, execute_batch, Executor};
pub use fifo::Fifo;
pub use kernels::{BatchExecutor, ChannelParams, CompiledModel, PackedConv, PackedDense};
pub use sim::{simulate_image, FoldingConfig, SimReport};
