//! Actors of the streaming template (paper Fig. 2, right side).
//!
//! Each conv layer maps to the template  LineBuffer -> ConvMac(Weights/Bias)
//! with the pool, and the head to a Gemm actor. Actors fire under dataflow
//! rules (inputs available + output FIFO has room); `ConvMac`/`Gemm` model
//! HLS folding with an initiation interval II derived from (PE, SIMD): one
//! output needs `ceil(Cout/PE) * ceil(taps/SIMD)` cycles, during which the
//! actor is busy. Firing one actor round = one clock cycle in `sim`.

use super::fifo::Fifo;
use crate::qonnx::{ConvLayer, DenseLayer};

/// Outcome of offering an actor one clock cycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Fired {
    /// Did useful work this cycle (consumed/produced/progressed).
    Busy,
    /// Nothing to do this cycle.
    Idle,
    /// Produced the final output token (sink-side completion signal).
    Done,
}

pub trait Actor {
    fn name(&self) -> &str;
    /// Offer one clock cycle. `fifos` is the global FIFO table; the actor
    /// addresses its ports by the indices given at construction.
    fn tick(&mut self, fifos: &mut [Fifo]) -> Fired;
    /// Total useful firings (for utilization reports).
    fn firings(&self) -> u64;
    /// Total MAC operations executed (conv/gemm only).
    fn macs(&self) -> u64 {
        0
    }
}

// ---------------------------------------------------------------------------
// Source: streams input pixels (one per cycle) into the pipeline.
// ---------------------------------------------------------------------------

pub struct Source {
    name: String,
    out: usize,
    pixels: Vec<Box<[i64]>>,
    next: usize,
    fired: u64,
}

impl Source {
    /// `image`: HWC codes; emits H*W tokens of C channels each.
    pub fn new(name: &str, out: usize, image: &[u8], h: usize, w: usize, c: usize) -> Self {
        assert_eq!(image.len(), h * w * c);
        let pixels = (0..h * w)
            .map(|p| {
                image[p * c..(p + 1) * c]
                    .iter()
                    .map(|&v| v as i64)
                    .collect::<Vec<_>>()
                    .into_boxed_slice()
            })
            .collect();
        Source {
            name: name.to_string(),
            out,
            pixels,
            next: 0,
            fired: 0,
        }
    }
}

impl Actor for Source {
    fn name(&self) -> &str {
        &self.name
    }

    fn tick(&mut self, fifos: &mut [Fifo]) -> Fired {
        if self.next >= self.pixels.len() || !fifos[self.out].has_room() {
            return Fired::Idle;
        }
        fifos[self.out].push(self.pixels[self.next].clone());
        self.next += 1;
        self.fired += 1;
        Fired::Busy
    }

    fn firings(&self) -> u64 {
        self.fired
    }
}

// ---------------------------------------------------------------------------
// LineBuffer: stores incoming rows, emits 3x3 SAME windows in raster order.
// ---------------------------------------------------------------------------

pub struct LineBuffer {
    name: String,
    inp: usize,
    out: usize,
    h: usize,
    w: usize,
    c: usize,
    /// Rows received so far (each row: w*c codes). Functionally we keep all
    /// rows; the hardware needs only 2 line BRAMs + window regs (the HLS
    /// estimator models that, not this).
    rows: Vec<i64>,
    pixels_in: usize,
    next_window: usize, // raster index of next window to emit
    fired: u64,
}

impl LineBuffer {
    pub fn new(name: &str, inp: usize, out: usize, h: usize, w: usize, c: usize) -> Self {
        LineBuffer {
            name: name.to_string(),
            inp,
            out,
            h,
            w,
            c,
            rows: Vec::with_capacity(h * w * c),
            pixels_in: 0,
            next_window: 0,
            fired: 0,
        }
    }

    fn window_ready(&self) -> bool {
        if self.next_window >= self.h * self.w {
            return false;
        }
        let y = self.next_window / self.w;
        // need all rows up to min(y+1, h-1) fully received
        let need_row = (y + 1).min(self.h - 1);
        self.pixels_in >= (need_row + 1) * self.w
    }

    fn emit_window(&self) -> Box<[i64]> {
        let (y, x) = (self.next_window / self.w, self.next_window % self.w);
        let mut win = vec![0i64; 9 * self.c];
        for dy in 0..3isize {
            let sy = y as isize + dy - 1;
            if sy < 0 || sy >= self.h as isize {
                continue;
            }
            for dx in 0..3isize {
                let sx = x as isize + dx - 1;
                if sx < 0 || sx >= self.w as isize {
                    continue;
                }
                let src = ((sy as usize) * self.w + sx as usize) * self.c;
                let dst = ((dy as usize * 3) + dx as usize) * self.c;
                win[dst..dst + self.c].copy_from_slice(&self.rows[src..src + self.c]);
            }
        }
        win.into_boxed_slice()
    }
}

impl Actor for LineBuffer {
    fn name(&self) -> &str {
        &self.name
    }

    fn tick(&mut self, fifos: &mut [Fifo]) -> Fired {
        let mut did = false;
        // Ingest up to one pixel per cycle.
        if self.pixels_in < self.h * self.w {
            if let Some(tok) = fifos[self.inp].pop() {
                debug_assert_eq!(tok.len(), self.c);
                self.rows.extend_from_slice(&tok);
                self.pixels_in += 1;
                did = true;
            }
        }
        // Emit up to one window per cycle.
        if self.window_ready() && fifos[self.out].has_room() {
            let win = self.emit_window();
            fifos[self.out].push(win);
            self.next_window += 1;
            did = true;
        }
        if did {
            self.fired += 1;
            Fired::Busy
        } else {
            Fired::Idle
        }
    }

    fn firings(&self) -> u64 {
        self.fired
    }
}

// ---------------------------------------------------------------------------
// ConvMac: 3x3 window -> Cout pixel, with PE/SIMD folding (II cycles/output).
// ---------------------------------------------------------------------------

pub struct ConvMac {
    name: String,
    inp: usize,
    out: usize,
    layer: ConvLayer,
    /// Initiation interval: cycles needed per output pixel.
    pub ii: u64,
    busy: u64,
    pending: Option<Box<[i64]>>,
    fired: u64,
    macs: u64,
}

impl ConvMac {
    pub fn new(
        name: &str,
        inp: usize,
        out: usize,
        layer: ConvLayer,
        pe: usize,
        simd: usize,
    ) -> Self {
        let taps = 9 * layer.cin;
        let ii = (layer.cout.div_ceil(pe) * taps.div_ceil(simd)) as u64;
        ConvMac {
            name: name.to_string(),
            inp,
            out,
            layer,
            ii: ii.max(1),
            busy: 0,
            pending: None,
            fired: 0,
            macs: 0,
        }
    }

    fn compute(&mut self, win: &[i64]) -> Box<[i64]> {
        let l = &self.layer;
        let mut acc = l.b_codes.clone();
        for t in 0..9 * l.cin {
            let xv = win[t];
            if xv == 0 {
                continue;
            }
            let wrow = &l.w_codes[t * l.cout..(t + 1) * l.cout];
            for (a, &wv) in acc.iter_mut().zip(wrow) {
                *a += xv * wv as i64;
            }
        }
        self.macs += (9 * l.cin * l.cout) as u64;
        acc.iter()
            .enumerate()
            .map(|(c, &a)| super::exec::requant(a, l.mult[c], l.shift[c], l.act_bits))
            .collect::<Vec<_>>()
            .into_boxed_slice()
    }
}

impl Actor for ConvMac {
    fn name(&self) -> &str {
        &self.name
    }

    fn tick(&mut self, fifos: &mut [Fifo]) -> Fired {
        // Finish an in-flight computation first (II modeling).
        if self.busy > 0 {
            self.busy -= 1;
            if self.busy == 0 {
                if let Some(tok) = self.pending.take() {
                    if fifos[self.out].has_room() {
                        fifos[self.out].push(tok);
                    } else {
                        // output stalled: hold the token, stay "busy"
                        self.pending = Some(tok);
                        self.busy = 1;
                    }
                }
            }
            return Fired::Busy;
        }
        if let Some(win) = {
            let f = &mut fifos[self.inp];
            if !f.is_empty() { f.pop() } else { None }
        } {
            let out_tok = self.compute(&win);
            self.fired += 1;
            if self.ii <= 1 {
                if fifos[self.out].has_room() {
                    fifos[self.out].push(out_tok);
                } else {
                    self.pending = Some(out_tok);
                    self.busy = 1;
                }
            } else {
                self.pending = Some(out_tok);
                self.busy = self.ii - 1;
            }
            Fired::Busy
        } else {
            Fired::Idle
        }
    }

    fn firings(&self) -> u64 {
        self.fired
    }

    fn macs(&self) -> u64 {
        self.macs
    }
}

// ---------------------------------------------------------------------------
// MaxPool: 2x2 stride-2 over the incoming raster pixel stream.
// ---------------------------------------------------------------------------

pub struct MaxPool {
    name: String,
    inp: usize,
    out: usize,
    w: usize,
    c: usize,
    /// Partial row of pooled maxima (w/2 tokens of c channels).
    row: Vec<i64>,
    x: usize,
    y: usize,
    fired: u64,
}

impl MaxPool {
    pub fn new(name: &str, inp: usize, out: usize, w: usize, c: usize) -> Self {
        MaxPool {
            name: name.to_string(),
            inp,
            out,
            w,
            c,
            row: vec![i64::MIN; (w / 2) * c],
            x: 0,
            y: 0,
            fired: 0,
        }
    }
}

impl Actor for MaxPool {
    fn name(&self) -> &str {
        &self.name
    }

    fn tick(&mut self, fifos: &mut [Fifo]) -> Fired {
        // Emitting happens in the same cycle a completing pixel arrives; we
        // need room when (y odd, x odd). Check before consuming.
        let completes = self.y % 2 == 1 && self.x % 2 == 1;
        if completes && !fifos[self.out].has_room() {
            return Fired::Idle;
        }
        let Some(tok) = fifos[self.inp].pop() else {
            return Fired::Idle;
        };
        let slot = (self.x / 2) * self.c;
        for (i, &v) in tok.iter().enumerate() {
            let cur = &mut self.row[slot + i];
            *cur = (*cur).max(v);
        }
        if completes {
            let pooled: Box<[i64]> = self.row[slot..slot + self.c].into();
            fifos[self.out].push(pooled);
        }
        self.x += 1;
        if self.x == self.w {
            self.x = 0;
            self.y += 1;
            if self.y % 2 == 0 {
                self.row.fill(i64::MIN);
            }
        }
        self.fired += 1;
        Fired::Busy
    }

    fn firings(&self) -> u64 {
        self.fired
    }
}

// ---------------------------------------------------------------------------
// Gemm: accumulates the flattened pixel stream, emits logits at the end.
// ---------------------------------------------------------------------------

pub struct Gemm {
    name: String,
    inp: usize,
    out: usize,
    layer: DenseLayer,
    /// Cycles per consumed input token (folding).
    pub ii: u64,
    busy: u64,
    acc: Vec<i64>,
    consumed: usize,
    n_tokens: usize,
    c_per_token: usize,
    emitted: bool,
    fired: u64,
    macs: u64,
}

impl Gemm {
    pub fn new(
        name: &str,
        inp: usize,
        out: usize,
        layer: DenseLayer,
        c_per_token: usize,
        pe: usize,
        simd: usize,
    ) -> Self {
        assert_eq!(layer.in_features % c_per_token, 0);
        let n_tokens = layer.in_features / c_per_token;
        let k = layer.out_features;
        let ii = (c_per_token.div_ceil(simd) * k.div_ceil(pe)) as u64;
        let acc = layer.b_codes.clone();
        Gemm {
            name: name.to_string(),
            inp,
            out,
            layer,
            ii: ii.max(1),
            busy: 0,
            acc,
            consumed: 0,
            n_tokens,
            c_per_token,
            emitted: false,
            fired: 0,
            macs: 0,
        }
    }
}

impl Actor for Gemm {
    fn name(&self) -> &str {
        &self.name
    }

    fn tick(&mut self, fifos: &mut [Fifo]) -> Fired {
        if self.busy > 0 {
            self.busy -= 1;
            return Fired::Busy;
        }
        if self.emitted {
            return Fired::Idle;
        }
        if self.consumed == self.n_tokens {
            if fifos[self.out].has_room() {
                fifos[self.out].push(self.acc.clone().into_boxed_slice());
                self.emitted = true;
                return Fired::Done;
            }
            return Fired::Idle;
        }
        let Some(tok) = fifos[self.inp].pop() else {
            return Fired::Idle;
        };
        debug_assert_eq!(tok.len(), self.c_per_token);
        let k = self.layer.out_features;
        let base = self.consumed * self.c_per_token;
        for (i, &xv) in tok.iter().enumerate() {
            if xv == 0 {
                continue;
            }
            let f = base + i;
            let wrow = &self.layer.w_codes[f * k..(f + 1) * k];
            for (a, &wv) in self.acc.iter_mut().zip(wrow) {
                *a += xv * wv as i64;
            }
        }
        self.macs += (self.c_per_token * k) as u64;
        self.consumed += 1;
        self.fired += 1;
        self.busy = self.ii - 1;
        Fired::Busy
    }

    fn firings(&self) -> u64 {
        self.fired
    }

    fn macs(&self) -> u64 {
        self.macs
    }
}
