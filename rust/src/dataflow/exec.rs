//! Functional integer executor (bit-exact vs python intref.py).
//!
//! Semantics per conv layer (see intref.py for the derivation):
//!   acc_c = sum_{dy,dx,ci} qx * qw + qb_c                 (i64)
//!   qy_c  = clamp((acc_c * M_c + round_half) >> sh_c, 0, 2^act_bits - 1)
//! Max-pool on codes; dense emits raw i64 accumulators (logits).
//!
//! This scalar path is the *reference oracle*: deliberately close to the
//! Python derivation, one image at a time. The serving hot path is the
//! packed, batch-major engine in [`super::kernels`], which is asserted
//! bit-exact against this module by the property suite and on every bench
//! reply.

use std::sync::Arc;

use crate::qonnx::{ConvLayer, DenseLayer, Layer, QonnxModel, TensorShape};

/// Reusable execution state: inferred shapes + activation scratch (avoids
/// re-running shape inference and re-allocating buffers per image on the
/// hot path). Self-contained — the model is held by `Arc`, so executors can
/// be cached (e.g. per profile inside a backend) and moved across threads
/// without tying them to a borrowed model's lifetime.
pub struct Executor {
    model: Arc<QonnxModel>,
    shapes: Vec<TensorShape>,
    /// Double-buffered activation planes (codes).
    buf_a: Vec<i64>,
    buf_b: Vec<i64>,
    /// Conv accumulator scratch (max `cout` lanes), reused across runs.
    acc: Vec<i64>,
}

impl Executor {
    /// Clones the model into shared ownership — fine for long-lived
    /// executors. One-shot callers should use [`execute`]/[`execute_batch`]
    /// (borrow-only, no weight copy); callers already holding an
    /// `Arc<QonnxModel>` should use [`Executor::from_arc`].
    pub fn new(model: &QonnxModel) -> Self {
        Self::from_arc(Arc::new(model.clone()))
    }

    /// Construct without cloning the model weights (the cheap path for
    /// executor caches that already hold the model in an `Arc`).
    pub fn from_arc(model: Arc<QonnxModel>) -> Self {
        let (shapes, buf_a, buf_b) = scratch_for(&model);
        let max_cout = model.conv_layers().map(|c| c.cout).max().unwrap_or(0);
        Executor {
            model,
            shapes,
            buf_a,
            buf_b,
            acc: vec![0; max_cout],
        }
    }

    pub fn model(&self) -> &QonnxModel {
        &self.model
    }

    /// Run one image (u8 codes, HWC layout, shape = model.input_shape) and
    /// return the 10 logits (raw dense accumulators).
    pub fn run(&mut self, input: &[u8]) -> Vec<i64> {
        run_layers(
            &self.model,
            &self.shapes,
            &mut self.buf_a,
            &mut self.buf_b,
            &mut self.acc,
            input,
        )
    }
}

/// The layer pipeline over pre-allocated double buffers. Shared by the
/// owned [`Executor`] and the borrow-only one-shot paths below, so neither
/// has to clone the model weights.
fn run_layers(
    model: &QonnxModel,
    shapes: &[TensorShape],
    buf_a: &mut [i64],
    buf_b: &mut [i64],
    acc: &mut Vec<i64>,
    input: &[u8],
) -> Vec<i64> {
    let in_shape = model.input_shape;
    assert_eq!(input.len(), in_shape.elems(), "input size mismatch");
    for (dst, &src) in buf_a.iter_mut().zip(input) {
        *dst = src as i64;
    }
    let mut cur_shape = in_shape;
    let mut in_a = true; // which buffer currently holds the activation
    let mut logits = Vec::new();
    for (i, layer) in model.layers.iter().enumerate() {
        let out_shape = shapes[i + 1];
        let (src, dst): (&[i64], &mut [i64]) = if in_a {
            (&*buf_a, &mut *buf_b)
        } else {
            (&*buf_b, &mut *buf_a)
        };
        match layer {
            Layer::Conv(c) => {
                if acc.len() < c.cout {
                    acc.resize(c.cout, 0);
                }
                conv_forward(c, src, cur_shape, dst, &mut acc[..c.cout]);
                in_a = !in_a;
            }
            Layer::Pool(_) => {
                pool_forward(&src[..cur_shape.elems()], cur_shape, dst);
                in_a = !in_a;
            }
            Layer::Flatten { .. } => { /* layout already flat (HWC) */ }
            Layer::Dense(d) => {
                let out = &mut dst[..d.out_features];
                dense_forward(d, &src[..cur_shape.elems()], out);
                logits = out.to_vec();
                in_a = !in_a;
            }
        }
        cur_shape = out_shape;
    }
    logits
}

/// Scratch sizing shared by the scalar executor and the batched
/// [`super::kernels::CompiledModel`]: delegates to the analysis module's
/// [`crate::analysis::ArenaPlan`] liveness walk, the single source of truth
/// for where each activation lives and how big each ping/pong buffer must
/// be.
pub(crate) fn scratch_plan(model: &QonnxModel) -> (Vec<TensorShape>, usize, usize) {
    let plan = crate::analysis::ArenaPlan::of(model);
    (plan.shapes, plan.a_elems, plan.b_elems)
}

fn scratch_for(model: &QonnxModel) -> (Vec<TensorShape>, Vec<i64>, Vec<i64>) {
    let (shapes, a_elems, b_elems) = scratch_plan(model);
    (shapes, vec![0; a_elems], vec![0; b_elems])
}

/// One-shot execution. Borrows the model — no weight cloning.
pub fn execute(model: &QonnxModel, input: &[u8]) -> Vec<i64> {
    let (shapes, mut buf_a, mut buf_b) = scratch_for(model);
    let mut acc = Vec::new();
    run_layers(model, &shapes, &mut buf_a, &mut buf_b, &mut acc, input)
}

/// Classify a batch; returns (logits per image, argmax per image).
/// Borrows the model and reuses one scratch allocation across the batch.
pub fn execute_batch(model: &QonnxModel, inputs: &[&[u8]]) -> (Vec<Vec<i64>>, Vec<usize>) {
    let (shapes, mut buf_a, mut buf_b) = scratch_for(model);
    let mut acc = Vec::new();
    let mut all = Vec::with_capacity(inputs.len());
    let mut preds = Vec::with_capacity(inputs.len());
    for &img in inputs {
        let logits = run_layers(model, &shapes, &mut buf_a, &mut buf_b, &mut acc, img);
        preds.push(argmax(&logits));
        all.push(logits);
    }
    (all, preds)
}

/// Per-layer extremes actually observed by the scalar oracle on one image —
/// the measurement side of the analysis soundness property (every observed
/// value must lie inside the [`crate::analysis`] interval of its layer).
#[derive(Debug, Clone)]
pub struct LayerTrace {
    pub name: String,
    /// (min, max) raw pre-requant conv accumulator / dense logit observed.
    pub acc: Option<(i64, i64)>,
    /// (min, max) of the layer's output activations (None for flatten,
    /// which writes nothing).
    pub act: Option<(i64, i64)>,
}

/// [`execute`] with per-layer observation. Runs the same kernels as the
/// plain oracle (bit-exactness is asserted by the property suite), but
/// records the accumulator and activation extremes of every layer.
pub fn execute_traced(model: &QonnxModel, input: &[u8]) -> (Vec<i64>, Vec<LayerTrace>) {
    let (shapes, mut buf_a, mut buf_b) = scratch_for(model);
    let in_shape = model.input_shape;
    assert_eq!(input.len(), in_shape.elems(), "input size mismatch");
    for (dst, &src) in buf_a.iter_mut().zip(input) {
        *dst = src as i64;
    }
    let mut acc: Vec<i64> = Vec::new();
    let mut cur_shape = in_shape;
    let mut in_a = true;
    let mut logits = Vec::new();
    let mut traces = Vec::with_capacity(model.layers.len());
    for (i, layer) in model.layers.iter().enumerate() {
        let out_shape = shapes[i + 1];
        let (src, dst): (&[i64], &mut [i64]) = if in_a {
            (&*buf_a, &mut *buf_b)
        } else {
            (&*buf_b, &mut *buf_a)
        };
        let mut acc_seen: Option<(i64, i64)> = None;
        let mut act_seen: Option<(i64, i64)> = None;
        match layer {
            Layer::Conv(c) => {
                if acc.len() < c.cout {
                    acc.resize(c.cout, 0);
                }
                conv_forward_obs(c, src, cur_shape, dst, &mut acc[..c.cout], |lanes| {
                    observe_extremes(&mut acc_seen, lanes);
                });
                observe_extremes(&mut act_seen, &dst[..out_shape.elems()]);
                in_a = !in_a;
            }
            Layer::Pool(_) => {
                pool_forward(&src[..cur_shape.elems()], cur_shape, dst);
                observe_extremes(&mut act_seen, &dst[..out_shape.elems()]);
                in_a = !in_a;
            }
            Layer::Flatten { .. } => { /* layout already flat (HWC) */ }
            Layer::Dense(d) => {
                let out = &mut dst[..d.out_features];
                dense_forward(d, &src[..cur_shape.elems()], out);
                observe_extremes(&mut acc_seen, out);
                observe_extremes(&mut act_seen, out);
                logits = out.to_vec();
                in_a = !in_a;
            }
        }
        traces.push(LayerTrace {
            name: layer.name().to_string(),
            acc: acc_seen,
            act: act_seen,
        });
        cur_shape = out_shape;
    }
    (logits, traces)
}

/// Full per-layer snapshots from one scalar-oracle run — the element-wise
/// measurement side of the *error-bound* soundness property (the traced
/// oracle's extremes are too coarse to check per-channel deviations).
#[derive(Debug, Clone)]
pub struct LayerCapture {
    pub name: String,
    /// Every raw pre-requant conv accumulator (pixel-major, `cout` lanes
    /// per pixel) / every dense logit; empty for pool and flatten.
    pub acc: Vec<i64>,
    /// The layer's full output activation plane (HWC codes); empty for
    /// flatten, which writes nothing.
    pub act: Vec<i64>,
}

/// [`execute`] with full per-layer capture. Same kernels as the plain
/// oracle (bit-exactness asserted in tests); element `e` of a conv capture
/// belongs to channel `e % cout`, matching the analyzers' per-channel
/// layout.
pub fn execute_captured(model: &QonnxModel, input: &[u8]) -> (Vec<i64>, Vec<LayerCapture>) {
    let (shapes, mut buf_a, mut buf_b) = scratch_for(model);
    let in_shape = model.input_shape;
    assert_eq!(input.len(), in_shape.elems(), "input size mismatch");
    for (dst, &src) in buf_a.iter_mut().zip(input) {
        *dst = src as i64;
    }
    let mut acc: Vec<i64> = Vec::new();
    let mut cur_shape = in_shape;
    let mut in_a = true;
    let mut logits = Vec::new();
    let mut captures = Vec::with_capacity(model.layers.len());
    for (i, layer) in model.layers.iter().enumerate() {
        let out_shape = shapes[i + 1];
        let (src, dst): (&[i64], &mut [i64]) = if in_a {
            (&*buf_a, &mut *buf_b)
        } else {
            (&*buf_b, &mut *buf_a)
        };
        let mut acc_snap = Vec::new();
        let mut act_snap = Vec::new();
        match layer {
            Layer::Conv(c) => {
                if acc.len() < c.cout {
                    acc.resize(c.cout, 0);
                }
                conv_forward_obs(c, src, cur_shape, dst, &mut acc[..c.cout], |lanes| {
                    acc_snap.extend_from_slice(lanes);
                });
                act_snap.extend_from_slice(&dst[..out_shape.elems()]);
                in_a = !in_a;
            }
            Layer::Pool(_) => {
                pool_forward(&src[..cur_shape.elems()], cur_shape, dst);
                act_snap.extend_from_slice(&dst[..out_shape.elems()]);
                in_a = !in_a;
            }
            Layer::Flatten { .. } => { /* layout already flat (HWC) */ }
            Layer::Dense(d) => {
                let out = &mut dst[..d.out_features];
                dense_forward(d, &src[..cur_shape.elems()], out);
                acc_snap.extend_from_slice(out);
                act_snap.extend_from_slice(out);
                logits = out.to_vec();
                in_a = !in_a;
            }
        }
        captures.push(LayerCapture {
            name: layer.name().to_string(),
            acc: acc_snap,
            act: act_snap,
        });
        cur_shape = out_shape;
    }
    (logits, captures)
}

fn observe_extremes(seen: &mut Option<(i64, i64)>, values: &[i64]) {
    for &v in values {
        let e = seen.get_or_insert((v, v));
        e.0 = e.0.min(v);
        e.1 = e.1.max(v);
    }
}

pub fn argmax(xs: &[i64]) -> usize {
    xs.iter()
        .enumerate()
        .max_by_key(|&(i, &v)| (v, std::cmp::Reverse(i)))
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Requantize one accumulator: (acc * M + half) >> sh, clamped to the
/// unsigned activation range. Shared with the actor-level simulator so the
/// two paths cannot diverge.
#[inline]
pub fn requant(acc: i64, mult: i64, shift: i64, act_bits: u32) -> i64 {
    let half = if shift > 0 { 1i64 << (shift - 1) } else { 0 };
    let q = (acc * mult + half) >> shift;
    let qmax = (1i64 << act_bits) - 1;
    q.clamp(0, qmax)
}

/// `acc` is caller-provided scratch of exactly `cout` lanes (the executor
/// reuses one allocation across runs instead of allocating per layer).
fn conv_forward(c: &ConvLayer, src: &[i64], shape: TensorShape, dst: &mut [i64], acc: &mut [i64]) {
    conv_forward_obs(c, src, shape, dst, acc, |_| {});
}

/// [`conv_forward`] with an accumulator observer: `observe` sees every
/// pixel's raw accumulator lanes *before* requantization. The plain path
/// passes a no-op closure (monomorphized away); the traced oracle uses it
/// to record the extremes the analysis intervals must contain.
fn conv_forward_obs(
    c: &ConvLayer,
    src: &[i64],
    shape: TensorShape,
    dst: &mut [i64],
    acc: &mut [i64],
    mut observe: impl FnMut(&[i64]),
) {
    let (h, w, cin, cout) = (shape.h, shape.w, c.cin, c.cout);
    debug_assert_eq!(shape.c, cin);
    debug_assert_eq!(acc.len(), cout);
    for y in 0..h {
        for x in 0..w {
            acc.copy_from_slice(&c.b_codes);
            for dy in 0..3usize {
                let sy = y as isize + dy as isize - 1;
                if sy < 0 || sy >= h as isize {
                    continue;
                }
                for dx in 0..3usize {
                    let sx = x as isize + dx as isize - 1;
                    if sx < 0 || sx >= w as isize {
                        continue;
                    }
                    let base = (sy as usize * w + sx as usize) * cin;
                    let wbase = ((dy * 3 + dx) * cin) * cout;
                    for ci in 0..cin {
                        let xv = src[base + ci];
                        if xv == 0 {
                            continue; // ReLU-sparse activations: skip zero MACs
                        }
                        let wrow = &c.w_codes[wbase + ci * cout..wbase + ci * cout + cout];
                        for (a, &wv) in acc.iter_mut().zip(wrow) {
                            *a += xv * wv as i64;
                        }
                    }
                }
            }
            observe(&acc[..cout]);
            let obase = (y * w + x) * cout;
            for co in 0..cout {
                dst[obase + co] = requant(acc[co], c.mult[co], c.shift[co], c.act_bits);
            }
        }
    }
}

/// 2x2 stride-2 max-pool on codes. Generic over the cell type so the
/// batched engine (i32 arenas) and this oracle (i64 planes) share one
/// implementation and cannot diverge.
pub(crate) fn pool_forward<T: Copy + Ord>(src: &[T], shape: TensorShape, dst: &mut [T]) {
    let (h, w, ch) = (shape.h, shape.w, shape.c);
    let (oh, ow) = (h / 2, w / 2);
    for y in 0..oh {
        for x in 0..ow {
            let obase = (y * ow + x) * ch;
            for c in 0..ch {
                let i00 = ((2 * y) * w + 2 * x) * ch + c;
                let i01 = ((2 * y) * w + 2 * x + 1) * ch + c;
                let i10 = ((2 * y + 1) * w + 2 * x) * ch + c;
                let i11 = ((2 * y + 1) * w + 2 * x + 1) * ch + c;
                dst[obase + c] = src[i00].max(src[i01]).max(src[i10]).max(src[i11]);
            }
        }
    }
}

/// Accumulate raw logits into `out` (len = `out_features`), starting from
/// the bias codes — no intermediate allocation (the old implementation
/// cloned `b_codes` per image).
fn dense_forward(d: &DenseLayer, src: &[i64], out: &mut [i64]) {
    let k = d.out_features;
    out.copy_from_slice(&d.b_codes);
    for (f, &xv) in src.iter().enumerate() {
        if xv == 0 {
            continue;
        }
        let wrow = &d.w_codes[f * k..f * k + k];
        for (a, &wv) in out.iter_mut().zip(wrow) {
            *a += xv * wv as i64;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::read_str;

    fn tiny() -> QonnxModel {
        read_str(&crate::qonnx::test_model_json(1, 2)).unwrap()
    }

    #[test]
    fn requant_rounds_half_up() {
        // acc=3, M=1, sh=1 -> (3*1+1)>>1 = 2
        assert_eq!(requant(3, 1, 1, 8), 2);
        // negative accs clamp to 0 (fused ReLU)
        assert_eq!(requant(-100, 1 << 10, 10, 8), 0);
        // saturation at qmax
        assert_eq!(requant(i32::MAX as i64, 1 << 14, 2, 4), 15);
        // shift 0 edge case: no rounding bias added
        assert_eq!(requant(5, 3, 0, 8), 15);
    }

    #[test]
    fn executes_tiny_model_deterministically() {
        let m = tiny();
        let input: Vec<u8> = (0..m.input_shape.elems()).map(|i| (i * 13 % 256) as u8).collect();
        let a = execute(&m, &input);
        let b = execute(&m, &input);
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn zero_input_gives_bias_logits() {
        let m = tiny();
        let input = vec![0u8; m.input_shape.elems()];
        let logits = execute(&m, &input);
        // All activations zero except via conv bias -> requant; with zero
        // input the dense output is a pure function of biases; just assert
        // it is finite and stable.
        assert_eq!(logits.len(), 3);
    }

    #[test]
    fn reused_executor_matches_fresh_executor() {
        // The coordinator caches one Executor per profile; reuse across
        // images must stay bit-exact vs a cold run (stale scratch must
        // never leak into a later image).
        let m = tiny();
        let imgs: Vec<Vec<u8>> = (0..4)
            .map(|k| {
                (0..m.input_shape.elems()).map(|i| ((i * 31 + k * 7) % 256) as u8).collect()
            })
            .collect();
        let mut cached = Executor::new(&m);
        for img in &imgs {
            assert_eq!(cached.run(img), execute(&m, img));
        }
        // and again in reverse order, same instance
        for img in imgs.iter().rev() {
            assert_eq!(cached.run(img), execute(&m, img));
        }
    }

    #[test]
    fn traced_execution_matches_the_plain_oracle() {
        let m = tiny();
        let input: Vec<u8> =
            (0..m.input_shape.elems()).map(|i| (i * 13 % 256) as u8).collect();
        let (logits, traces) = execute_traced(&m, &input);
        assert_eq!(logits, execute(&m, &input));
        assert_eq!(traces.len(), m.layers.len());
        assert!(traces[0].acc.is_some(), "conv must trace accumulators");
        assert!(traces[2].acc.is_none() && traces[2].act.is_none(), "flatten writes nothing");
        let (lo, hi) = traces[3].acc.unwrap();
        assert!(logits.iter().all(|&v| lo <= v && v <= hi));
    }

    #[test]
    fn captured_execution_matches_the_plain_and_traced_oracles() {
        let m = tiny();
        let input: Vec<u8> =
            (0..m.input_shape.elems()).map(|i| (i * 13 % 256) as u8).collect();
        let (logits, caps) = execute_captured(&m, &input);
        assert_eq!(logits, execute(&m, &input));
        assert_eq!(caps.len(), m.layers.len());
        // conv: one accumulator per pixel per lane, full act plane
        assert_eq!(caps[0].acc.len(), 4 * 4 * 2);
        assert_eq!(caps[0].act.len(), 4 * 4 * 2);
        assert!(caps[2].acc.is_empty() && caps[2].act.is_empty(), "flatten writes nothing");
        assert_eq!(caps[3].acc, logits);
        // the captured extremes are exactly what the traced oracle reports
        let (_, traces) = execute_traced(&m, &input);
        for (cap, tr) in caps.iter().zip(&traces) {
            let ext = |xs: &[i64]| {
                xs.iter()
                    .fold(None, |s: Option<(i64, i64)>, &v| match s {
                        None => Some((v, v)),
                        Some((lo, hi)) => Some((lo.min(v), hi.max(v))),
                    })
            };
            assert_eq!(ext(&cap.acc), tr.acc);
            assert_eq!(ext(&cap.act), tr.act);
        }
    }

    #[test]
    fn argmax_ties_break_low_index() {
        assert_eq!(argmax(&[3, 5, 5, 1]), 1);
        assert_eq!(argmax(&[-2]), 0);
    }

    #[test]
    fn scratch_plan_sizes_buffers_from_the_shape_walk() {
        // tiny(1, 2) pipeline: input 4x4x1 (16, buffer A) -> conv 4x4x2
        // (32, B) -> pool 2x2x2 (8, A) -> flatten -> dense 3 (B). The walk
        // must size A by 16 (not the global max 32, which the old plan used
        // for both buffers) and B by 32.
        let m = tiny();
        let (shapes, a_elems, b_elems) = scratch_plan(&m);
        assert_eq!(shapes.len(), m.layers.len() + 1);
        assert_eq!(a_elems, 16);
        assert_eq!(b_elems, 32);
    }
}
