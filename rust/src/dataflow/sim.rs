//! Cycle-approximate simulation of the streaming pipeline.
//!
//! Builds the actor network for a [`QonnxModel`] (Source -> [LineBuffer ->
//! ConvMac -> MaxPool]* -> Gemm -> sink FIFO), then ticks every actor once
//! per clock cycle until the logits token lands. Produces the logits (which
//! must match `exec::execute` bit-for-bit — property-tested) plus the
//! statistics that feed the HLS report and the power model.

use super::actors::{Actor, ConvMac, Fired, Gemm, LineBuffer, MaxPool, Source};
use super::fifo::Fifo;
use crate::qonnx::{Layer, QonnxModel};

/// HLS folding parameters per parametric layer (PE = output-channel
/// parallelism, SIMD = input-tap parallelism), mirroring FINN's folding.
/// The defaults are chosen so the simulated latency of the paper's tiny CNN
/// lands at the paper's 329 us @ 100 MHz (Table 1) — see DESIGN.md §4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FoldingConfig {
    pub conv1_pe: usize,
    pub conv1_simd: usize,
    pub conv2_pe: usize,
    pub conv2_simd: usize,
    pub dense_pe: usize,
    pub dense_simd: usize,
    /// FIFO depth between actors.
    pub fifo_depth: usize,
}

impl Default for FoldingConfig {
    fn default() -> Self {
        FoldingConfig {
            conv1_pe: 8,
            conv1_simd: 2,
            conv2_pe: 8,
            conv2_simd: 36,
            dense_pe: 2,
            dense_simd: 64,
            fifo_depth: 8,
        }
    }
}

impl FoldingConfig {
    /// (pe, simd) for the i-th conv layer (0-based).
    fn conv(&self, idx: usize) -> (usize, usize) {
        if idx == 0 {
            (self.conv1_pe, self.conv1_simd)
        } else {
            (self.conv2_pe, self.conv2_simd)
        }
    }

    /// Total MAC units instantiated for a model (resource model input).
    pub fn mac_units(&self, model: &QonnxModel) -> usize {
        let mut units = 0;
        let mut conv_idx = 0;
        for layer in &model.layers {
            match layer {
                Layer::Conv(_) => {
                    let (pe, simd) = self.conv(conv_idx);
                    units += pe * simd;
                    conv_idx += 1;
                }
                Layer::Dense(_) => units += self.dense_pe * self.dense_simd,
                _ => {}
            }
        }
        units
    }
}

/// Per-FIFO statistics snapshot.
#[derive(Debug, Clone)]
pub struct FifoStats {
    pub name: String,
    pub bits: u32,
    pub pushes: u64,
    pub max_occupancy: usize,
    pub capacity: usize,
    pub toggle_rate: f64,
    pub toggle_bits: u64,
}

/// Per-actor statistics snapshot.
#[derive(Debug, Clone)]
pub struct ActorStats {
    pub name: String,
    pub firings: u64,
    pub macs: u64,
}

/// Result of simulating one image through the streaming engine.
#[derive(Debug, Clone)]
pub struct SimReport {
    pub logits: Vec<i64>,
    /// Clock cycles until the logits token was produced.
    pub cycles: u64,
    pub fifos: Vec<FifoStats>,
    pub actors: Vec<ActorStats>,
    /// Total MACs executed (value-dependent: zero activations are skipped in
    /// hardware terms this is the switching workload, not the static array).
    pub total_macs: u64,
}

impl SimReport {
    /// Latency in microseconds at `clock_mhz`.
    pub fn latency_us(&self, clock_mhz: f64) -> f64 {
        self.cycles as f64 / clock_mhz
    }

    /// Mean toggle rate over all FIFOs weighted by traffic (power input).
    pub fn mean_toggle_rate(&self) -> f64 {
        let (mut num, mut den) = (0.0, 0.0);
        for f in &self.fifos {
            let w = (f.pushes as f64) * f.bits as f64;
            num += f.toggle_rate * w;
            den += w;
        }
        if den == 0.0 {
            0.0
        } else {
            num / den
        }
    }
}

/// Simulate one image (u8 HWC codes) through the streaming engine.
///
/// Panics if the model violates the template (enforced by the QONNX reader).
pub fn simulate_image(model: &QonnxModel, cfg: &FoldingConfig, image: &[u8]) -> SimReport {
    let shapes = crate::qonnx::infer_shapes(model);
    let in_shape = model.input_shape;
    assert_eq!(image.len(), in_shape.elems());

    let mut fifos: Vec<Fifo> = Vec::new();
    let mut actors: Vec<Box<dyn Actor>> = Vec::new();

    // input FIFO
    fifos.push(Fifo::new("fifo_input", model.input_bits, cfg.fifo_depth));
    actors.push(Box::new(Source::new(
        "source", 0, image, in_shape.h, in_shape.w, in_shape.c,
    )));

    let mut cur_fifo = 0usize;
    let mut cur_bits = model.input_bits;
    let mut conv_idx = 0usize;
    // Channel count of the physical token stream (unchanged by Flatten —
    // the gemm actor consumes the pooled pixel stream directly).
    let mut stream_c = in_shape.c;
    for (i, layer) in model.layers.iter().enumerate() {
        let in_shape_i = shapes[i];
        match layer {
            Layer::Conv(c) => {
                // line buffer -> window fifo -> convmac -> pixel fifo
                let win_fifo = fifos.len();
                fifos.push(Fifo::new(
                    format!("fifo_{}_win", c.name),
                    cur_bits,
                    cfg.fifo_depth,
                ));
                actors.push(Box::new(LineBuffer::new(
                    &format!("{}_linebuf", c.name),
                    cur_fifo,
                    win_fifo,
                    in_shape_i.h,
                    in_shape_i.w,
                    in_shape_i.c,
                )));
                let out_fifo = fifos.len();
                fifos.push(Fifo::new(
                    format!("fifo_{}_out", c.name),
                    c.act_bits,
                    cfg.fifo_depth,
                ));
                let (pe, simd) = cfg.conv(conv_idx);
                actors.push(Box::new(ConvMac::new(
                    &c.name,
                    win_fifo,
                    out_fifo,
                    c.clone(),
                    pe,
                    simd,
                )));
                cur_fifo = out_fifo;
                cur_bits = c.act_bits;
                stream_c = c.cout;
                conv_idx += 1;
            }
            Layer::Pool(p) => {
                let out_fifo = fifos.len();
                fifos.push(Fifo::new(
                    format!("fifo_{}_out", p.name),
                    cur_bits,
                    cfg.fifo_depth,
                ));
                actors.push(Box::new(MaxPool::new(
                    &p.name,
                    cur_fifo,
                    out_fifo,
                    in_shape_i.w,
                    in_shape_i.c,
                )));
                cur_fifo = out_fifo;
            }
            Layer::Flatten { .. } => { /* stream is already flat */ }
            Layer::Dense(d) => {
                let out_fifo = fifos.len();
                fifos.push(Fifo::new("fifo_logits", 32, 2));
                actors.push(Box::new(Gemm::new(
                    &d.name,
                    cur_fifo,
                    out_fifo,
                    d.clone(),
                    stream_c,
                    cfg.dense_pe,
                    cfg.dense_simd,
                )));
                cur_fifo = out_fifo;
            }
        }
    }
    let logits_fifo = cur_fifo;

    // --- clock loop ---
    let mut cycles: u64 = 0;
    let max_cycles: u64 = 200_000_000; // runaway guard
    let logits;
    loop {
        cycles += 1;
        let mut any = false;
        let mut done = false;
        for a in actors.iter_mut() {
            match a.tick(&mut fifos) {
                Fired::Busy => any = true,
                Fired::Done => {
                    any = true;
                    done = true;
                }
                Fired::Idle => {}
            }
        }
        if done || !fifos[logits_fifo].is_empty() {
            logits = fifos[logits_fifo].pop().expect("logits token missing").to_vec();
            break;
        }
        assert!(any, "deadlock: no actor could fire at cycle {cycles}");
        assert!(cycles < max_cycles, "simulation runaway");
    }

    let total_macs = actors.iter().map(|a| a.macs()).sum();
    SimReport {
        logits,
        cycles,
        fifos: fifos
            .iter()
            .map(|f| FifoStats {
                name: f.name.clone(),
                bits: f.bits,
                pushes: f.pushes,
                max_occupancy: f.max_occupancy,
                capacity: f.capacity(),
                toggle_rate: f.toggle_rate(),
                toggle_bits: f.toggle_bits,
            })
            .collect(),
        actors: actors
            .iter()
            .map(|a| ActorStats {
                name: a.name().to_string(),
                firings: a.firings(),
                macs: a.macs(),
            })
            .collect(),
        total_macs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::exec;
    use crate::qonnx::{read_str, test_model_json};
    use crate::testkit::{self, Rng};

    fn fast_fold() -> FoldingConfig {
        FoldingConfig {
            conv1_pe: 64,
            conv1_simd: 64,
            conv2_pe: 64,
            conv2_simd: 576,
            dense_pe: 16,
            dense_simd: 64,
            fifo_depth: 8,
        }
    }

    #[test]
    fn sim_matches_exec_on_tiny_model() {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let img: Vec<u8> = (0..m.input_shape.elems()).map(|i| (i * 37 % 251) as u8).collect();
        let want = exec::execute(&m, &img);
        let rep = simulate_image(&m, &fast_fold(), &img);
        assert_eq!(rep.logits, want);
        assert!(rep.cycles > 0);
    }

    #[test]
    fn sim_matches_exec_on_random_models() {
        testkit::check("sim == exec on random models", |rng| {
            let cfg = crate::qonnx::RandModelCfg::gen(rng);
            let json = crate::qonnx::random_model_json(&cfg, rng);
            let m = read_str(&json).map_err(|e| e.to_string())?;
            let elems = m.input_shape.elems();
            let img: Vec<u8> = (0..elems).map(|_| rng.u64(0, 255) as u8).collect();
            let want = exec::execute(&m, &img);
            let fold = random_fold(rng);
            let rep = simulate_image(&m, &fold, &img);
            crate::prop_assert!(
                rep.logits == want,
                "sim {:?} != exec {:?} (fold {fold:?})",
                rep.logits,
                want
            );
            Ok(())
        });
    }

    fn random_fold(rng: &mut Rng) -> FoldingConfig {
        FoldingConfig {
            conv1_pe: rng.usize(1, 8),
            conv1_simd: rng.usize(1, 9),
            conv2_pe: rng.usize(1, 8),
            conv2_simd: rng.usize(1, 16),
            dense_pe: rng.usize(1, 4),
            dense_simd: rng.usize(1, 8),
            fifo_depth: rng.usize(2, 16),
        }
    }

    #[test]
    fn latency_independent_of_weight_values() {
        // Table-1 invariant: cycles depend on shapes/folding, not on data
        // precision or values. Same model, two different inputs.
        let m = read_str(&test_model_json(2, 3)).unwrap();
        let img_a = vec![0u8; m.input_shape.elems()];
        let img_b: Vec<u8> = (0..m.input_shape.elems()).map(|i| (i % 256) as u8).collect();
        let cfg = FoldingConfig::default();
        let ra = simulate_image(&m, &cfg, &img_a);
        let rb = simulate_image(&m, &cfg, &img_b);
        assert_eq!(ra.cycles, rb.cycles);
    }

    #[test]
    fn fifo_occupancy_within_capacity() {
        let m = read_str(&test_model_json(1, 4)).unwrap();
        let img: Vec<u8> = (0..m.input_shape.elems()).map(|i| (i * 7 % 256) as u8).collect();
        let rep = simulate_image(&m, &FoldingConfig::default(), &img);
        for f in &rep.fifos {
            assert!(f.max_occupancy <= f.capacity, "{} exceeded capacity", f.name);
        }
    }

    #[test]
    fn higher_folding_means_fewer_cycles() {
        let m = read_str(&test_model_json(2, 4)).unwrap();
        let img: Vec<u8> = (0..m.input_shape.elems()).map(|i| (i * 11 % 256) as u8).collect();
        let slow = FoldingConfig {
            conv1_pe: 1,
            conv1_simd: 1,
            conv2_pe: 1,
            conv2_simd: 1,
            dense_pe: 1,
            dense_simd: 1,
            fifo_depth: 8,
        };
        let r_slow = simulate_image(&m, &slow, &img);
        let r_fast = simulate_image(&m, &fast_fold(), &img);
        assert!(r_slow.cycles > r_fast.cycles);
        assert_eq!(r_slow.logits, r_fast.logits);
    }
}
