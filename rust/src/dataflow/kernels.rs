//! Batched, pre-packed, allocation-free inference kernels — the Sim
//! backend's serving hot path.
//!
//! [`super::exec`] stays the bit-exactness *reference oracle*: scalar,
//! per-image, structured like `python/compile/intref.py`. This module is
//! what the server shards actually run:
//!
//! * **Pre-packing** — at profile-load time a [`CompiledModel`] repacks
//!   every conv/dense weight tensor into output-channel tiles of
//!   [`CO_TILE`] lanes ((dy,dx,ci)-major within a tile, zero-padded on the
//!   last tile) and fuses bias + requant multiplier/shift into per-channel
//!   [`ChannelParams`]. The fixed tile width keeps the inner MAC loop
//!   branch-free with compile-time trip count, so the compiler unrolls and
//!   vectorizes it; padded lanes are computed but never written back.
//! * **Batch-major, layer-major execution** — [`BatchExecutor::run_batch`]
//!   pushes the whole batch through one layer before the next, with the
//!   tile loop outermost: one packed weight tile stays cache-resident
//!   across every image of the batch instead of being re-streamed per
//!   image (the software analogue of the streaming fabric's weight reuse).
//! * **Arena scratch** — activations live in two ping/pong arenas sized by
//!   the analysis module's liveness walk ([`crate::analysis::ArenaPlan`])
//!   times the batch, plus one logits arena. Arenas only grow, so once
//!   warmed for a batch size the executor performs zero heap allocations
//!   per batch.
//! * **Narrow arithmetic** — activation codes are stored as `i32` (the
//!   requant clamp bounds them by `2^act_bits - 1`); a conv layer runs
//!   32-bit MACs (SIMD-friendly) when the abstract-interpretation pass
//!   ([`crate::analysis::analyze`]) proves every product and partial sum
//!   fits `i32`, and falls back to 64-bit accumulators otherwise. Both
//!   paths accumulate in the oracle's per-channel order and the narrow one
//!   is selected only when it provably cannot overflow, so the integers
//!   match the oracle exactly.
//!
//! Models outside the packable envelope (activations wider than 31 bits,
//! or a dense layer that is not terminal) compile to a scalar-fallback
//! plan that loops the oracle per image — correct, just not fast-pathed.

use std::sync::Arc;

use crate::qonnx::{ConvLayer, DenseLayer, Layer, QonnxModel, TensorShape};

use super::exec::{self, Executor};

/// Output channels per packed weight tile (lanes of the inner MAC loop).
pub const CO_TILE: usize = 8;

/// Per-output-channel parameters: bias at accumulator scale fused with the
/// TFLite-style requantization multiplier and right shift, so the whole
/// epilogue of a channel is one struct read away from its weight tile.
#[derive(Debug, Clone, Copy, Default)]
pub struct ChannelParams {
    pub bias: i64,
    pub mult: i64,
    pub shift: i64,
}

/// A 3x3 SAME conv layer repacked into output-channel tiles.
#[derive(Debug, Clone)]
pub struct PackedConv {
    cin: usize,
    cout: usize,
    act_bits: u32,
    n_tiles: usize,
    /// Weight codes, `[tile][(dy*3+dx)*cin + ci][CO_TILE]` flattened; lanes
    /// past `cout` on the last tile are zero.
    w: Vec<i32>,
    /// Fused per-channel params, `[tile][CO_TILE]`, default-padded.
    params: Vec<ChannelParams>,
    /// 32-bit accumulators are provably overflow-free for this layer.
    narrow: bool,
}

impl PackedConv {
    /// Repack `c` for tiled execution. `narrow` is the accumulator-width
    /// verdict proven by [`crate::analysis::analyze`] for this layer: `true`
    /// selects the 32-bit MAC kernel, and the caller is responsible for
    /// passing a verdict the analysis actually proved (every per-tap product
    /// interval and every partial sum fits `i32`).
    pub fn pack(c: &ConvLayer, narrow: bool) -> Self {
        let n_tiles = c.cout.div_ceil(CO_TILE);
        let mut w = vec![0i32; n_tiles * 9 * c.cin * CO_TILE];
        let mut params = vec![ChannelParams::default(); n_tiles * CO_TILE];
        for co in 0..c.cout {
            let (tile, lane) = (co / CO_TILE, co % CO_TILE);
            params[tile * CO_TILE + lane] = ChannelParams {
                bias: c.b_codes[co],
                mult: c.mult[co],
                shift: c.shift[co],
            };
            for tap in 0..9 * c.cin {
                w[(tile * 9 * c.cin + tap) * CO_TILE + lane] = c.w_codes[tap * c.cout + co];
            }
        }
        PackedConv {
            cin: c.cin,
            cout: c.cout,
            act_bits: c.act_bits,
            n_tiles,
            w,
            params,
            narrow,
        }
    }

    /// Run the layer over the whole batch, tile loop outermost: one packed
    /// weight tile is reused across every image before the next tile is
    /// touched. `src`/`dst` are batch-major arenas with the given per-image
    /// strides.
    pub fn forward_batch(
        &self,
        batch: usize,
        src: &[i32],
        src_stride: usize,
        dst: &mut [i32],
        dst_stride: usize,
        shape: TensorShape,
    ) {
        debug_assert_eq!(shape.c, self.cin);
        let in_elems = shape.elems();
        let out_elems = shape.h * shape.w * self.cout;
        for tile in 0..self.n_tiles {
            for img in 0..batch {
                let s = &src[img * src_stride..][..in_elems];
                let d = &mut dst[img * dst_stride..][..out_elems];
                if self.narrow {
                    self.tile_forward_narrow(tile, s, shape, d);
                } else {
                    self.tile_forward_wide(tile, s, shape, d);
                }
            }
        }
    }

    /// 32-bit accumulator kernel (proven overflow-free by the static
    /// analysis pass, hence bit-exact vs the oracle's 64-bit accumulation).
    fn tile_forward_narrow(&self, tile: usize, src: &[i32], shape: TensorShape, dst: &mut [i32]) {
        let (h, w, cin, cout) = (shape.h, shape.w, self.cin, self.cout);
        let tw = &self.w[tile * 9 * cin * CO_TILE..][..9 * cin * CO_TILE];
        let tp = &self.params[tile * CO_TILE..][..CO_TILE];
        let co0 = tile * CO_TILE;
        let lanes = CO_TILE.min(cout - co0);
        for y in 0..h {
            for x in 0..w {
                let mut acc = [0i32; CO_TILE];
                for (a, p) in acc.iter_mut().zip(tp) {
                    *a = p.bias as i32;
                }
                for dy in 0..3usize {
                    let sy = y as isize + dy as isize - 1;
                    if sy < 0 || sy >= h as isize {
                        continue;
                    }
                    for dx in 0..3usize {
                        let sx = x as isize + dx as isize - 1;
                        if sx < 0 || sx >= w as isize {
                            continue;
                        }
                        let base = (sy as usize * w + sx as usize) * cin;
                        let wbase = (dy * 3 + dx) * cin * CO_TILE;
                        for ci in 0..cin {
                            let xv = src[base + ci];
                            if xv == 0 {
                                continue; // ReLU-sparse activations: skip zero MACs
                            }
                            let wrow = &tw[wbase + ci * CO_TILE..][..CO_TILE];
                            for (a, &wv) in acc.iter_mut().zip(wrow) {
                                *a += xv * wv;
                            }
                        }
                    }
                }
                let obase = (y * w + x) * cout + co0;
                for k in 0..lanes {
                    let p = tp[k];
                    let q = exec::requant(acc[k] as i64, p.mult, p.shift, self.act_bits);
                    dst[obase + k] = q as i32;
                }
            }
        }
    }

    /// 64-bit accumulator kernel for layers whose bounds exceed `i32`
    /// (same tiling and accumulation order, wider lanes).
    fn tile_forward_wide(&self, tile: usize, src: &[i32], shape: TensorShape, dst: &mut [i32]) {
        let (h, w, cin, cout) = (shape.h, shape.w, self.cin, self.cout);
        let tw = &self.w[tile * 9 * cin * CO_TILE..][..9 * cin * CO_TILE];
        let tp = &self.params[tile * CO_TILE..][..CO_TILE];
        let co0 = tile * CO_TILE;
        let lanes = CO_TILE.min(cout - co0);
        for y in 0..h {
            for x in 0..w {
                let mut acc = [0i64; CO_TILE];
                for (a, p) in acc.iter_mut().zip(tp) {
                    *a = p.bias;
                }
                for dy in 0..3usize {
                    let sy = y as isize + dy as isize - 1;
                    if sy < 0 || sy >= h as isize {
                        continue;
                    }
                    for dx in 0..3usize {
                        let sx = x as isize + dx as isize - 1;
                        if sx < 0 || sx >= w as isize {
                            continue;
                        }
                        let base = (sy as usize * w + sx as usize) * cin;
                        let wbase = (dy * 3 + dx) * cin * CO_TILE;
                        for ci in 0..cin {
                            let xv = src[base + ci] as i64;
                            if xv == 0 {
                                continue;
                            }
                            let wrow = &tw[wbase + ci * CO_TILE..][..CO_TILE];
                            for (a, &wv) in acc.iter_mut().zip(wrow) {
                                *a += xv * wv as i64;
                            }
                        }
                    }
                }
                let obase = (y * w + x) * cout + co0;
                for k in 0..lanes {
                    let p = tp[k];
                    let q = exec::requant(acc[k], p.mult, p.shift, self.act_bits);
                    dst[obase + k] = q as i32;
                }
            }
        }
    }
}

/// A dense head repacked into output tiles (raw i64 logits, no requant).
#[derive(Debug, Clone)]
pub struct PackedDense {
    in_features: usize,
    out_features: usize,
    n_tiles: usize,
    /// Weight codes, `[tile][f][CO_TILE]` flattened, zero-padded lanes.
    w: Vec<i32>,
    /// Bias codes, `[tile][CO_TILE]`, zero-padded.
    bias: Vec<i64>,
}

impl PackedDense {
    pub fn pack(d: &DenseLayer) -> Self {
        let n_tiles = d.out_features.div_ceil(CO_TILE);
        let mut w = vec![0i32; n_tiles * d.in_features * CO_TILE];
        let mut bias = vec![0i64; n_tiles * CO_TILE];
        for k in 0..d.out_features {
            let (tile, lane) = (k / CO_TILE, k % CO_TILE);
            bias[tile * CO_TILE + lane] = d.b_codes[k];
            for f in 0..d.in_features {
                w[(tile * d.in_features + f) * CO_TILE + lane] = d.w_codes[f * d.out_features + k];
            }
        }
        PackedDense {
            in_features: d.in_features,
            out_features: d.out_features,
            n_tiles,
            w,
            bias,
        }
    }

    /// Accumulate raw i64 logits rows (`out_features` per image) into
    /// `dst`, tile loop outermost. Dense always accumulates in i64: its
    /// output *is* the raw accumulator the FPGA head would emit.
    pub fn forward_batch(&self, batch: usize, src: &[i32], src_stride: usize, dst: &mut [i64]) {
        let fcount = self.in_features;
        let k_total = self.out_features;
        for tile in 0..self.n_tiles {
            let tw = &self.w[tile * fcount * CO_TILE..][..fcount * CO_TILE];
            let tb = &self.bias[tile * CO_TILE..][..CO_TILE];
            let k0 = tile * CO_TILE;
            let lanes = CO_TILE.min(k_total - k0);
            for img in 0..batch {
                let s = &src[img * src_stride..][..fcount];
                let mut acc = [0i64; CO_TILE];
                acc.copy_from_slice(tb);
                for (f, &xv) in s.iter().enumerate() {
                    if xv == 0 {
                        continue;
                    }
                    let xv = xv as i64;
                    let wrow = &tw[f * CO_TILE..][..CO_TILE];
                    for (a, &wv) in acc.iter_mut().zip(wrow) {
                        *a += xv * wv as i64;
                    }
                }
                let obase = img * k_total + k0;
                dst[obase..obase + lanes].copy_from_slice(&acc[..lanes]);
            }
        }
    }
}

/// One stage of the packed execution plan.
enum CompiledStep {
    Conv(PackedConv),
    Pool,
    Flatten,
    Dense(PackedDense),
}

/// A model pre-packed for batched execution: built once per profile at
/// load time (the MDC "configuration write" analogue), shared across
/// executors via `Arc`.
pub struct CompiledModel {
    model: Arc<QonnxModel>,
    shapes: Vec<TensorShape>,
    /// `None` => outside the packable envelope; executors fall back to
    /// looping the scalar oracle per image.
    steps: Option<Vec<CompiledStep>>,
    /// Per-image ping/pong arena sizes from the shape walk.
    a_elems: usize,
    b_elems: usize,
    out_features: usize,
}

impl CompiledModel {
    pub fn compile(model: Arc<QonnxModel>) -> Self {
        // One analysis pass is the single source of truth for both the
        // arena plan and the per-conv accumulator-width verdicts.
        let analysis = crate::analysis::analyze(&model);
        let out_features = model.dense().map(|d| d.out_features).unwrap_or(0);
        let steps = Self::pack_steps(&model, &analysis.conv_narrow);
        CompiledModel {
            model,
            shapes: analysis.arena.shapes,
            steps,
            a_elems: analysis.arena.a_elems,
            b_elems: analysis.arena.b_elems,
            out_features,
        }
    }

    /// Convenience for callers not holding an `Arc` yet (clones weights).
    pub fn from_model(model: &QonnxModel) -> Self {
        Self::compile(Arc::new(model.clone()))
    }

    /// Activation arenas hold i32 codes, so every producer must stay within
    /// 31 bits; dense emits raw i64 accumulators, so it must be terminal.
    /// `narrow` is the analysis verdict per conv layer, in layer order.
    fn pack_steps(model: &QonnxModel, narrow: &[bool]) -> Option<Vec<CompiledStep>> {
        let mut conv_idx = 0usize;
        let mut steps = Vec::with_capacity(model.layers.len());
        for (i, layer) in model.layers.iter().enumerate() {
            match layer {
                Layer::Conv(c) => {
                    if c.act_bits > 31 {
                        return None;
                    }
                    steps.push(CompiledStep::Conv(PackedConv::pack(c, narrow[conv_idx])));
                    conv_idx += 1;
                }
                Layer::Pool(_) => steps.push(CompiledStep::Pool),
                Layer::Flatten { .. } => steps.push(CompiledStep::Flatten),
                Layer::Dense(d) => {
                    if i + 1 != model.layers.len() {
                        return None;
                    }
                    steps.push(CompiledStep::Dense(PackedDense::pack(d)));
                }
            }
        }
        Some(steps)
    }

    pub fn model(&self) -> &Arc<QonnxModel> {
        &self.model
    }

    pub fn out_features(&self) -> usize {
        self.out_features
    }

    /// Whether the fast packed plan applies (false = scalar fallback).
    pub fn is_packed(&self) -> bool {
        self.steps.is_some()
    }

    /// Accumulator width the packed plan proved per conv layer, in layer
    /// order: `true` = the 32-bit narrow (SIMD-friendly) path, `false` =
    /// the 64-bit fallback. Empty for scalar-fallback plans. Surfaced so
    /// the approximation explorer can report which rungs of a bit-width
    /// ladder unlock the narrow kernels as precisions shrink.
    pub fn conv_acc_narrow(&self) -> Vec<bool> {
        self.steps
            .as_ref()
            .map(|steps| {
                steps
                    .iter()
                    .filter_map(|s| match s {
                        CompiledStep::Conv(pc) => Some(pc.narrow),
                        _ => None,
                    })
                    .collect()
            })
            .unwrap_or_default()
    }
}

/// Batched executor over a [`CompiledModel`]: owns the activation/logits
/// arenas and runs batch-major, layer-major. One per (worker shard,
/// profile); not shared across threads.
pub struct BatchExecutor {
    compiled: Arc<CompiledModel>,
    /// Ping/pong activation arenas (i32 codes), `capacity * {a,b}_elems`.
    buf_a: Vec<i32>,
    buf_b: Vec<i32>,
    /// Logits arena, `capacity * out_features` raw i64 accumulators.
    out: Vec<i64>,
    /// Images the arenas currently accommodate. Grows monotonically: a
    /// warmed executor allocates nothing per batch.
    capacity: usize,
    /// Scalar oracle, used only when the model is outside the packed
    /// envelope.
    fallback: Option<Executor>,
}

impl BatchExecutor {
    pub fn new(compiled: Arc<CompiledModel>) -> Self {
        let fallback = if compiled.is_packed() {
            None
        } else {
            Some(Executor::from_arc(compiled.model().clone()))
        };
        BatchExecutor {
            compiled,
            buf_a: Vec::new(),
            buf_b: Vec::new(),
            out: Vec::new(),
            capacity: 0,
            fallback,
        }
    }

    /// Convenience: compile + wrap in one step (tests/benches).
    pub fn from_model(model: &QonnxModel) -> Self {
        Self::new(Arc::new(CompiledModel::from_model(model)))
    }

    pub fn compiled(&self) -> &Arc<CompiledModel> {
        &self.compiled
    }

    pub fn out_features(&self) -> usize {
        self.compiled.out_features
    }

    /// Grow (never shrink) the arenas to hold `batch` images. The
    /// scalar-fallback plan only uses the logits arena (the oracle owns its
    /// own scratch), so the activation arenas stay empty there.
    fn reserve(&mut self, batch: usize) {
        if batch <= self.capacity {
            return;
        }
        if self.fallback.is_none() {
            self.buf_a.resize(batch * self.compiled.a_elems, 0);
            self.buf_b.resize(batch * self.compiled.b_elems, 0);
        }
        self.out.resize(batch * self.compiled.out_features, 0);
        self.capacity = batch;
    }

    /// Classify a batch. Returns the raw logits rows ([`Self::out_features`]
    /// per image, submission order) — the same i64 accumulators
    /// [`exec::execute`] returns for each image. The slice borrows the
    /// executor's arena until the next call; copy out what must outlive it.
    pub fn run_batch(&mut self, images: &[&[u8]]) -> &[i64] {
        self.run_batch_observed(images, None)
    }

    /// [`Self::run_batch`], optionally reporting each executed compiled step
    /// to `observer` as a `(layer_index, op)` pair — the hook behind the
    /// tracer's per-layer `kernel.layer` sub-spans. `None` is the hot path
    /// and costs nothing. The scalar fallback has no compiled plan, so it
    /// reports no steps.
    pub fn run_batch_observed(
        &mut self,
        images: &[&[u8]],
        mut observer: Option<&mut Vec<(u32, &'static str)>>,
    ) -> &[i64] {
        let n = images.len();
        let in_elems = self.compiled.shapes[0].elems();
        for img in images {
            assert_eq!(img.len(), in_elems, "input size mismatch");
        }
        self.reserve(n);
        if self.fallback.is_some() {
            return self.run_batch_scalar(images);
        }
        let CompiledModel {
            shapes,
            steps,
            a_elems,
            b_elems,
            out_features,
            ..
        } = &*self.compiled;
        let (a_stride, b_stride) = (*a_elems, *b_elems);
        let steps = steps.as_ref().expect("packed plan");
        for (img, &data) in images.iter().enumerate() {
            let dst = &mut self.buf_a[img * a_stride..][..in_elems];
            for (d, &s) in dst.iter_mut().zip(data) {
                *d = s as i32;
            }
        }
        let mut cur_shape = shapes[0];
        let mut in_a = true;
        for (i, step) in steps.iter().enumerate() {
            if let Some(obs) = observer.as_deref_mut() {
                let op = match step {
                    CompiledStep::Conv(_) => "conv",
                    CompiledStep::Pool => "pool",
                    CompiledStep::Flatten => "flatten",
                    CompiledStep::Dense(_) => "dense",
                };
                obs.push((i as u32, op));
            }
            let out_shape = shapes[i + 1];
            let (src, dst, src_stride, dst_stride) = if in_a {
                (&self.buf_a[..], &mut self.buf_b[..], a_stride, b_stride)
            } else {
                (&self.buf_b[..], &mut self.buf_a[..], b_stride, a_stride)
            };
            match step {
                CompiledStep::Conv(pc) => {
                    pc.forward_batch(n, src, src_stride, dst, dst_stride, cur_shape);
                    in_a = !in_a;
                }
                CompiledStep::Pool => {
                    for img in 0..n {
                        let s = &src[img * src_stride..][..cur_shape.elems()];
                        let d = &mut dst[img * dst_stride..][..out_shape.elems()];
                        exec::pool_forward(s, cur_shape, d);
                    }
                    in_a = !in_a;
                }
                CompiledStep::Flatten => {}
                CompiledStep::Dense(pd) => {
                    pd.forward_batch(n, src, src_stride, &mut self.out);
                    in_a = !in_a;
                }
            }
            cur_shape = out_shape;
        }
        &self.out[..n * out_features]
    }

    /// Scalar-fallback plan: loop the oracle per image into the logits
    /// arena (exotic bit-widths only — correctness over speed).
    fn run_batch_scalar(&mut self, images: &[&[u8]]) -> &[i64] {
        let k = self.compiled.out_features;
        let ex = self.fallback.as_mut().expect("scalar fallback");
        for (img, &data) in images.iter().enumerate() {
            let logits = ex.run(data);
            self.out[img * k..][..k].copy_from_slice(&logits);
        }
        &self.out[..images.len() * k]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::{random_model_json, read_str, test_model_json, RandModelCfg};
    use crate::testkit::Rng;

    fn imgs_for(m: &QonnxModel, n: usize, salt: usize) -> Vec<Vec<u8>> {
        let elems = m.input_shape.elems();
        (0..n)
            .map(|k| (0..elems).map(|i| ((i * 31 + k * 17 + salt) % 256) as u8).collect())
            .collect()
    }

    fn assert_matches_oracle(m: &QonnxModel, batches: &[usize]) {
        let mut ex = BatchExecutor::from_model(m);
        let k = ex.out_features();
        for (bi, &b) in batches.iter().enumerate() {
            let imgs = imgs_for(m, b, bi * 97);
            let refs: Vec<&[u8]> = imgs.iter().map(Vec::as_slice).collect();
            let got = ex.run_batch(&refs).to_vec();
            assert_eq!(got.len(), b * k);
            for (i, img) in imgs.iter().enumerate() {
                let want = exec::execute(m, img);
                assert_eq!(
                    &got[i * k..(i + 1) * k],
                    want.as_slice(),
                    "batch {b} image {i} diverges from the scalar oracle"
                );
            }
        }
    }

    #[test]
    fn packed_matches_oracle_on_tiny_models() {
        // cout exercises exact tiles (8, 16), remainder lanes (2, 3, 11),
        // and multi-tile remainders; the dense head (3 classes) is always a
        // remainder tile.
        for (cin, cout) in [(1, 2), (2, 3), (3, 8), (1, 11), (2, 16)] {
            let m = read_str(&test_model_json(cin, cout)).unwrap();
            assert_matches_oracle(&m, &[1, 3, 8]);
        }
    }

    #[test]
    fn observed_run_reports_plan_steps_and_matches_unobserved() {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let mut ex = BatchExecutor::from_model(&m);
        let imgs = imgs_for(&m, 3, 0);
        let refs: Vec<&[u8]> = imgs.iter().map(Vec::as_slice).collect();
        let plain = ex.run_batch(&refs).to_vec();
        let mut steps: Vec<(u32, &'static str)> = Vec::new();
        let observed = ex.run_batch_observed(&refs, Some(&mut steps)).to_vec();
        assert_eq!(plain, observed, "observer must not perturb the logits");
        let expect: Vec<(u32, &'static str)> = ex
            .compiled()
            .steps
            .as_ref()
            .unwrap()
            .iter()
            .enumerate()
            .map(|(i, s)| {
                let op = match s {
                    CompiledStep::Conv(_) => "conv",
                    CompiledStep::Pool => "pool",
                    CompiledStep::Flatten => "flatten",
                    CompiledStep::Dense(_) => "dense",
                };
                (i as u32, op)
            })
            .collect();
        assert_eq!(steps, expect, "observer must walk the compiled plan in order");
        assert!(steps.iter().any(|(_, op)| *op == "conv"));
        assert!(steps.iter().any(|(_, op)| *op == "dense"));
    }

    #[test]
    fn packed_matches_oracle_on_random_models() {
        let mut rng = Rng::new(0xC0FFEE);
        for _ in 0..8 {
            let cfg = RandModelCfg::gen(&mut rng);
            let m = read_str(&random_model_json(&cfg, &mut rng)).unwrap();
            assert_matches_oracle(&m, &[1, 3, 8]);
        }
    }

    #[test]
    fn conv_packing_places_every_code_in_its_lane() {
        let m = read_str(&test_model_json(2, 11)).unwrap();
        let c = m.conv_layers().next().unwrap();
        let narrow = crate::analysis::analyze(&m).conv_narrow[0];
        let pc = PackedConv::pack(c, narrow);
        assert_eq!(pc.n_tiles, 2);
        assert!(pc.narrow, "tiny model bounds fit 32-bit accumulators");
        for dy in 0..3 {
            for dx in 0..3 {
                for ci in 0..c.cin {
                    for co in 0..c.cout {
                        let (tile, lane) = (co / CO_TILE, co % CO_TILE);
                        let tap = (dy * 3 + dx) * c.cin + ci;
                        let idx = (tile * 9 * c.cin + tap) * CO_TILE + lane;
                        assert_eq!(pc.w[idx], c.w(dy, dx, ci, co));
                    }
                }
            }
        }
        // padded lanes of the last tile are zero, and their params inert
        for tap in 0..9 * c.cin {
            for lane in (c.cout % CO_TILE)..CO_TILE {
                assert_eq!(pc.w[(9 * c.cin + tap) * CO_TILE + lane], 0);
            }
        }
        assert_eq!(pc.params[CO_TILE + c.cout % CO_TILE].bias, 0);
    }

    #[test]
    fn wide_bias_takes_the_i64_path_and_matches() {
        // 3e9 exceeds i32: the layer must pick 64-bit accumulators and
        // still agree with the oracle.
        let wide = "\"b_codes\":[3000000000,1]";
        let json = test_model_json(1, 2).replace("\"b_codes\":[1,1]", wide);
        let m = read_str(&json).unwrap();
        let compiled = CompiledModel::from_model(&m);
        assert!(compiled.is_packed());
        match compiled.steps.as_ref().unwrap().first() {
            Some(CompiledStep::Conv(pc)) => assert!(!pc.narrow, "must widen"),
            _ => panic!("first step should be conv"),
        }
        assert_eq!(compiled.conv_acc_narrow(), vec![false]);
        assert_matches_oracle(&m, &[1, 4]);
    }

    #[test]
    fn act_bits_over_31_fall_back_to_scalar_plan() {
        let json = test_model_json(1, 2).replace("\"act_bits\":8", "\"act_bits\":32");
        let m = read_str(&json).unwrap();
        let compiled = CompiledModel::from_model(&m);
        assert!(!compiled.is_packed(), "32-bit activations exceed i32 codes");
        assert!(compiled.conv_acc_narrow().is_empty(), "no packed plan, no widths");
        assert_matches_oracle(&m, &[2]);
    }

    #[test]
    fn acc_width_report_lists_conv_layers_in_order() {
        let m = read_str(&test_model_json(2, 11)).unwrap();
        let compiled = CompiledModel::from_model(&m);
        // one conv layer in the tiny pipeline, provably narrow
        assert_eq!(compiled.conv_acc_narrow(), vec![true]);
    }

    #[test]
    fn acc_width_verdict_is_the_analysis_verdict() {
        // The packed plan and the static analysis must never disagree about
        // accumulator widths — the former is now derived from the latter,
        // and this pins the wiring on the kernel-test model family.
        for (cin, cout) in [(1, 2), (2, 3), (3, 8), (1, 11), (2, 16)] {
            let m = read_str(&test_model_json(cin, cout)).unwrap();
            let compiled = CompiledModel::from_model(&m);
            assert_eq!(
                compiled.conv_acc_narrow(),
                crate::analysis::analyze(&m).conv_narrow,
                "tiny({cin}, {cout})"
            );
        }
    }

    #[test]
    fn arena_grows_monotonically_and_stays_bit_exact() {
        let m = read_str(&test_model_json(2, 5)).unwrap();
        let mut ex = BatchExecutor::from_model(&m);
        let k = ex.out_features();
        let mut max_seen = 0usize;
        for &b in &[2usize, 8, 1, 5, 8] {
            max_seen = max_seen.max(b);
            let imgs = imgs_for(&m, b, b * 13);
            let refs: Vec<&[u8]> = imgs.iter().map(Vec::as_slice).collect();
            let got = ex.run_batch(&refs).to_vec();
            for (i, img) in imgs.iter().enumerate() {
                assert_eq!(&got[i * k..(i + 1) * k], exec::execute(&m, img).as_slice());
            }
            assert_eq!(
                ex.capacity,
                max_seen,
                "arena must grow to the high-water mark and never shrink"
            );
        }
    }

    #[test]
    fn zero_batch_is_a_no_op() {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let mut ex = BatchExecutor::from_model(&m);
        assert!(ex.run_batch(&[]).is_empty());
    }
}
