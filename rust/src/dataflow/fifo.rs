//! Bounded token FIFOs with occupancy + switching-activity statistics.
//!
//! Tokens are channel vectors (`Box<[i64]>`): one token = one pixel (all
//! channels) or one conv window. The FIFO records the statistics the power
//! model consumes: pushes, max occupancy, and *toggle bits* — the Hamming
//! distance between consecutive tokens masked to the port bit-width. This
//! is what makes the simulated power value-dependent, matching the paper's
//! observation that power depends on the actual weights/data.

/// Bounded FIFO of fixed-width integer tokens.
#[derive(Debug)]
pub struct Fifo {
    pub name: String,
    /// Port width in bits (each token element is masked to this width when
    /// counting toggles).
    pub bits: u32,
    capacity: usize,
    queue: std::collections::VecDeque<Box<[i64]>>,
    last: Option<Box<[i64]>>,
    // --- statistics ---
    pub pushes: u64,
    pub pops: u64,
    pub max_occupancy: usize,
    /// Total Hamming toggle bits observed across consecutive pushed tokens.
    pub toggle_bits: u64,
    /// Total element slots pushed (tokens * token_len) — toggle denominator.
    pub elems_pushed: u64,
}

impl Fifo {
    pub fn new(name: impl Into<String>, bits: u32, capacity: usize) -> Self {
        Fifo {
            name: name.into(),
            bits,
            capacity,
            queue: std::collections::VecDeque::new(),
            last: None,
            pushes: 0,
            pops: 0,
            max_occupancy: 0,
            toggle_bits: 0,
            elems_pushed: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    pub fn has_room(&self) -> bool {
        self.queue.len() < self.capacity
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Push a token. Panics if full — actors must check `has_room` first
    /// (firing rules enforce back-pressure; a panic is a scheduler bug).
    pub fn push(&mut self, token: Box<[i64]>) {
        assert!(self.has_room(), "FIFO '{}' overflow (capacity {})", self.name, self.capacity);
        self.record_toggles(&token);
        self.queue.push_back(token);
        self.pushes += 1;
        self.max_occupancy = self.max_occupancy.max(self.queue.len());
    }

    pub fn pop(&mut self) -> Option<Box<[i64]>> {
        let t = self.queue.pop_front();
        if t.is_some() {
            self.pops += 1;
        }
        t
    }

    pub fn front(&self) -> Option<&[i64]> {
        self.queue.front().map(|t| &t[..])
    }

    fn record_toggles(&mut self, token: &[i64]) {
        let mask: u64 = if self.bits >= 64 {
            u64::MAX
        } else {
            (1u64 << self.bits) - 1
        };
        if let Some(prev) = &self.last {
            let n = prev.len().min(token.len());
            for i in 0..n {
                let a = (prev[i] as u64) & mask;
                let b = (token[i] as u64) & mask;
                self.toggle_bits += (a ^ b).count_ones() as u64;
            }
        } else {
            // First token: toggles from the all-zero reset state.
            for &v in token {
                self.toggle_bits += ((v as u64) & mask).count_ones() as u64;
            }
        }
        self.elems_pushed += token.len() as u64;
        self.last = Some(token.to_vec().into_boxed_slice());
    }

    /// Mean fraction of port bits toggling per pushed element (0..=1).
    pub fn toggle_rate(&self) -> f64 {
        if self.elems_pushed == 0 || self.bits == 0 {
            return 0.0;
        }
        self.toggle_bits as f64 / (self.elems_pushed as f64 * self.bits as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tok(xs: &[i64]) -> Box<[i64]> {
        xs.to_vec().into_boxed_slice()
    }

    #[test]
    fn push_pop_order() {
        let mut f = Fifo::new("t", 8, 4);
        f.push(tok(&[1]));
        f.push(tok(&[2]));
        assert_eq!(f.pop().unwrap()[0], 1);
        assert_eq!(f.pop().unwrap()[0], 2);
        assert!(f.pop().is_none());
        assert_eq!(f.pushes, 2);
        assert_eq!(f.pops, 2);
    }

    #[test]
    fn backpressure_has_room() {
        let mut f = Fifo::new("t", 8, 2);
        f.push(tok(&[0]));
        f.push(tok(&[0]));
        assert!(!f.has_room());
        f.pop();
        assert!(f.has_room());
    }

    #[test]
    #[should_panic(expected = "overflow")]
    fn overflow_panics() {
        let mut f = Fifo::new("t", 8, 1);
        f.push(tok(&[0]));
        f.push(tok(&[0]));
    }

    #[test]
    fn toggle_counting_masks_to_port_width() {
        let mut f = Fifo::new("t", 4, 8);
        f.push(tok(&[0b0000])); // from reset: 0 toggles
        f.push(tok(&[0b1111])); // 4 toggles
        f.push(tok(&[0b1110])); // 1 toggle
        // value beyond port width: upper bits masked away
        f.push(tok(&[0b1111_1110])); // vs 0b1110 -> masked to 1110 -> 0 toggles
        assert_eq!(f.toggle_bits, 5);
        assert_eq!(f.elems_pushed, 4);
        assert!((f.toggle_rate() - 5.0 / 16.0).abs() < 1e-12);
    }

    #[test]
    fn occupancy_tracks_peak() {
        let mut f = Fifo::new("t", 8, 8);
        for i in 0..5 {
            f.push(tok(&[i]));
        }
        f.pop();
        f.pop();
        assert_eq!(f.max_occupancy, 5);
        assert_eq!(f.len(), 3);
    }
}
