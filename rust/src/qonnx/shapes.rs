//! Shape inference / structural validation over the streaming pipeline.

use super::ir::{Layer, QonnxModel, TensorShape};

/// Shapes at each pipeline stage: `shapes[0]` is the input, `shapes[i+1]` is
/// the output of `layers[i]`. Flatten/Dense stages use (1, 1, features).
pub fn infer_shapes(model: &QonnxModel) -> Vec<TensorShape> {
    let mut shapes = vec![model.input_shape];
    let mut cur = model.input_shape;
    for layer in &model.layers {
        cur = match layer {
            Layer::Conv(c) => TensorShape {
                h: cur.h,
                w: cur.w,
                c: c.cout,
            },
            Layer::Pool(_) => TensorShape {
                h: cur.h / 2,
                w: cur.w / 2,
                c: cur.c,
            },
            Layer::Flatten { .. } => TensorShape {
                h: 1,
                w: 1,
                c: cur.elems(),
            },
            Layer::Dense(d) => TensorShape {
                h: 1,
                w: 1,
                c: d.out_features,
            },
        };
        shapes.push(cur);
    }
    shapes
}

/// Structural checks that need shapes (called by the reader).
pub fn check(model: &QonnxModel) -> Result<(), String> {
    let mut cur = model.input_shape;
    for layer in &model.layers {
        match layer {
            Layer::Conv(c) => {
                if c.cin != cur.c {
                    return Err(format!(
                        "{}: declared Cin {} != incoming channels {}",
                        c.name, c.cin, cur.c
                    ));
                }
                cur = TensorShape { h: cur.h, w: cur.w, c: c.cout };
            }
            Layer::Pool(p) => {
                if cur.h % 2 != 0 || cur.w % 2 != 0 {
                    return Err(format!(
                        "{}: 2x2 pool needs even spatial dims, got {}x{}",
                        p.name, cur.h, cur.w
                    ));
                }
                cur = TensorShape { h: cur.h / 2, w: cur.w / 2, c: cur.c };
            }
            Layer::Flatten { .. } => {
                cur = TensorShape { h: 1, w: 1, c: cur.elems() };
            }
            Layer::Dense(d) => {
                if d.in_features != cur.c || cur.h != 1 || cur.w != 1 {
                    return Err(format!(
                        "{}: in_features {} != flattened input {} (shape {}x{}x{})",
                        d.name, d.in_features, cur.elems(), cur.h, cur.w, cur.c
                    ));
                }
                cur = TensorShape { h: 1, w: 1, c: d.out_features };
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::super::reader::read_str;
    use super::*;

    #[test]
    fn shapes_follow_pipeline() {
        let json = super::super::reader::tests::tiny_model_json(1, 2);
        let m = read_str(&json).unwrap();
        let shapes = infer_shapes(&m);
        assert_eq!(shapes[0], TensorShape { h: 4, w: 4, c: 1 });
        assert_eq!(shapes[1], TensorShape { h: 4, w: 4, c: 2 }); // conv
        assert_eq!(shapes[2], TensorShape { h: 2, w: 2, c: 2 }); // pool
        assert_eq!(shapes[3], TensorShape { h: 1, w: 1, c: 8 }); // flatten
        assert_eq!(shapes[4], TensorShape { h: 1, w: 1, c: 3 }); // dense
    }

    #[test]
    fn dense_mismatch_rejected() {
        let json = super::super::reader::tests::tiny_model_json(1, 2)
            .replace(r#""in_features":8"#, r#""in_features":9"#)
            .replace(r#""w_shape":[8,3]"#, r#""w_shape":[9,3]"#);
        // w_codes length now wrong too; fix length error first by keeping
        // original codes -> expect *some* schema error either way.
        assert!(read_str(&json).is_err());
    }
}
