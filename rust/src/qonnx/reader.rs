//! QONNX-JSON Reader: JSON -> validated IR.
//!
//! Validation performed here (DESIGN.md §7):
//!   * schema version check;
//!   * every node's inputs are produced earlier (topological order, DAG);
//!   * streaming single-consumer edges (each tensor feeds exactly one node);
//!   * weight array lengths match the declared shapes;
//!   * requant metadata present for every conv output channel;
//!   * bit-widths within the supported arbitrary-precision range (1..=32).

use std::fmt;
use std::path::Path;

use crate::json::{self, Value};

use super::ir::*;

#[derive(Debug)]
pub enum ReadError {
    Io(std::io::Error),
    Json(json::ParseError),
    Schema(String),
}

impl fmt::Display for ReadError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ReadError::Io(e) => write!(f, "qonnx read: io: {e}"),
            ReadError::Json(e) => write!(f, "qonnx read: {e}"),
            ReadError::Schema(m) => write!(f, "qonnx schema: {m}"),
        }
    }
}

impl std::error::Error for ReadError {}

impl From<std::io::Error> for ReadError {
    fn from(e: std::io::Error) -> Self {
        ReadError::Io(e)
    }
}

impl From<json::ParseError> for ReadError {
    fn from(e: json::ParseError) -> Self {
        ReadError::Json(e)
    }
}

fn schema(msg: impl Into<String>) -> ReadError {
    ReadError::Schema(msg.into())
}

pub fn read_file(path: impl AsRef<Path>) -> Result<QonnxModel, ReadError> {
    let text = std::fs::read_to_string(path)?;
    read_str(&text)
}

pub fn read_str(text: &str) -> Result<QonnxModel, ReadError> {
    let root = json::parse(text)?;
    let version = root
        .get("qonnx_version")
        .and_then(Value::as_i64)
        .ok_or_else(|| schema("missing qonnx_version"))?;
    if version != 1 {
        return Err(schema(format!("unsupported qonnx_version {version}")));
    }
    let profile = root
        .get("profile")
        .and_then(Value::as_str)
        .ok_or_else(|| schema("missing profile"))?
        .to_string();

    let input = root.get("input").ok_or_else(|| schema("missing input"))?;
    let ishape = input
        .get("shape")
        .and_then(Value::to_i64_vec)
        .ok_or_else(|| schema("input.shape"))?;
    if ishape.len() != 4 {
        return Err(schema("input.shape must be [N,H,W,C]"));
    }
    let input_shape = TensorShape {
        h: ishape[1] as usize,
        w: ishape[2] as usize,
        c: ishape[3] as usize,
    };
    let input_bits = get_u32(input, "bits")?;
    let input_int_bits = get_u32(input, "int_bits")?;

    let nodes = root
        .get("nodes")
        .and_then(Value::as_array)
        .ok_or_else(|| schema("missing nodes"))?;
    let output_name = root
        .get("output")
        .and_then(Value::as_str)
        .ok_or_else(|| schema("missing output"))?;

    // Topology validation: tensors produced so far; streaming = each consumed
    // at most once.
    let mut produced: Vec<String> = vec!["input".to_string()];
    let mut consumed: Vec<String> = Vec::new();

    let mut layers = Vec::new();
    for node in nodes {
        let name = req_str(node, "name")?;
        let op = req_str(node, "op")?;
        let inputs = node
            .get("inputs")
            .and_then(Value::as_array)
            .ok_or_else(|| schema(format!("{name}: inputs")))?;
        for inp in inputs {
            let t = inp
                .as_str()
                .ok_or_else(|| schema(format!("{name}: input not a string")))?;
            if !produced.iter().any(|p| p == t) {
                return Err(schema(format!(
                    "{name}: input tensor '{t}' not produced by an earlier node (not a DAG in topo order)"
                )));
            }
            if consumed.iter().any(|c| c == t) {
                return Err(schema(format!(
                    "{name}: tensor '{t}' consumed twice (streaming edges are single-consumer)"
                )));
            }
            consumed.push(t.to_string());
        }
        let outputs = node
            .get("outputs")
            .and_then(Value::as_array)
            .ok_or_else(|| schema(format!("{name}: outputs")))?;
        for out in outputs {
            let t = out
                .as_str()
                .ok_or_else(|| schema(format!("{name}: output not a string")))?;
            if produced.iter().any(|p| p == t) {
                return Err(schema(format!("{name}: tensor '{t}' produced twice")));
            }
            produced.push(t.to_string());
        }

        let layer = match op.as_str() {
            "QConv2d" => Layer::Conv(parse_conv(node, &name)?),
            "MaxPool2" => Layer::Pool(PoolLayer { name: name.clone() }),
            "Flatten" => Layer::Flatten { name: name.clone() },
            "QGemm" => Layer::Dense(parse_dense(node, &name)?),
            other => return Err(schema(format!("{name}: unknown op '{other}'"))),
        };
        layers.push(layer);
    }

    if !produced.iter().any(|p| p == output_name) {
        return Err(schema(format!("graph output '{output_name}' never produced")));
    }

    let model = QonnxModel {
        profile,
        input_shape,
        input_bits,
        input_int_bits,
        layers,
    };
    // Shape inference doubles as structural validation (dims must divide,
    // dense in_features must match the flattened conv output, ...).
    super::shapes::check(&model).map_err(schema)?;
    Ok(model)
}

fn req_str(node: &Value, key: &str) -> Result<String, ReadError> {
    node.get(key)
        .and_then(Value::as_str)
        .map(str::to_string)
        .ok_or_else(|| schema(format!("missing string field '{key}'")))
}

fn get_u32(v: &Value, key: &str) -> Result<u32, ReadError> {
    v.get(key)
        .and_then(Value::as_i64)
        .filter(|&x| (0..=64).contains(&x))
        .map(|x| x as u32)
        .ok_or_else(|| schema(format!("missing/invalid '{key}'")))
}

fn attr_u32(node: &Value, key: &str, name: &str) -> Result<u32, ReadError> {
    node.get("attrs")
        .and_then(|a| a.get(key))
        .and_then(Value::as_i64)
        .filter(|&x| (0..=1 << 20).contains(&x))
        .map(|x| x as u32)
        .ok_or_else(|| schema(format!("{name}: missing attr '{key}'")))
}

fn bits_in_range(bits: u32, what: &str, name: &str) -> Result<(), ReadError> {
    if !(1..=32).contains(&bits) {
        return Err(schema(format!(
            "{name}: {what} bits {bits} outside supported arbitrary-precision range 1..=32"
        )));
    }
    Ok(())
}

fn weights<'a>(node: &'a Value, name: &str) -> Result<&'a Value, ReadError> {
    node.get("weights")
        .ok_or_else(|| schema(format!("{name}: missing weights")))
}

fn parse_conv(node: &Value, name: &str) -> Result<ConvLayer, ReadError> {
    let act_bits = attr_u32(node, "act_bits", name)?;
    let act_int_bits = attr_u32(node, "act_int_bits", name)?;
    let weight_bits = attr_u32(node, "weight_bits", name)?;
    bits_in_range(act_bits, "activation", name)?;
    bits_in_range(weight_bits, "weight", name)?;
    let w = weights(node, name)?;

    let w_shape = w
        .get("w_shape")
        .and_then(Value::to_i64_vec)
        .ok_or_else(|| schema(format!("{name}: w_shape")))?;
    if w_shape.len() != 4 || w_shape[0] != 3 || w_shape[1] != 3 {
        return Err(schema(format!("{name}: conv w_shape must be [3,3,Cin,Cout]")));
    }
    let cin = w_shape[2] as usize;
    let cout = w_shape[3] as usize;

    let w_codes_i64 = w
        .get("w_codes")
        .and_then(Value::to_i64_vec)
        .ok_or_else(|| schema(format!("{name}: w_codes")))?;
    if w_codes_i64.len() != 9 * cin * cout {
        return Err(schema(format!(
            "{name}: w_codes length {} != 9*{cin}*{cout}",
            w_codes_i64.len()
        )));
    }
    let qmax = (1i64 << (weight_bits - 1)) - 1;
    if let Some(bad) = w_codes_i64.iter().find(|&&c| c.abs() > qmax) {
        return Err(schema(format!(
            "{name}: weight code {bad} exceeds {weight_bits}-bit symmetric range ±{qmax}"
        )));
    }
    let w_codes: Vec<i32> = w_codes_i64.iter().map(|&c| c as i32).collect();

    let b_codes = w
        .get("b_codes")
        .and_then(Value::to_i64_vec)
        .ok_or_else(|| schema(format!("{name}: b_codes")))?;
    let mult = w
        .get("mult")
        .and_then(Value::to_i64_vec)
        .ok_or_else(|| schema(format!("{name}: mult")))?;
    let shift = w
        .get("shift")
        .and_then(Value::to_i64_vec)
        .ok_or_else(|| schema(format!("{name}: shift")))?;
    for (field, len) in [("b_codes", b_codes.len()), ("mult", mult.len()), ("shift", shift.len())] {
        if len != cout {
            return Err(schema(format!("{name}: {field} length {len} != Cout {cout}")));
        }
    }
    if let Some(s) = shift.iter().find(|&&s| !(0..=62).contains(&s)) {
        return Err(schema(format!("{name}: requant shift {s} out of range 0..=62")));
    }
    if let Some(m) = mult.iter().find(|&&m| !(0..=1 << 20).contains(&m)) {
        return Err(schema(format!("{name}: requant multiplier {m} out of range")));
    }

    let in_step = w.get("in_step").and_then(Value::as_f64).unwrap_or(0.0);
    let out_step = w.get("out_step").and_then(Value::as_f64).unwrap_or(0.0);

    Ok(ConvLayer {
        name: name.to_string(),
        w_codes,
        cin,
        cout,
        b_codes,
        mult,
        shift,
        act_bits,
        act_int_bits,
        weight_bits,
        in_step,
        out_step,
    })
}

fn parse_dense(node: &Value, name: &str) -> Result<DenseLayer, ReadError> {
    let weight_bits = attr_u32(node, "weight_bits", name)?;
    bits_in_range(weight_bits, "weight", name)?;
    let w = weights(node, name)?;
    let w_shape = w
        .get("w_shape")
        .and_then(Value::to_i64_vec)
        .ok_or_else(|| schema(format!("{name}: w_shape")))?;
    if w_shape.len() != 2 {
        return Err(schema(format!("{name}: gemm w_shape must be [F,K]")));
    }
    let in_features = w_shape[0] as usize;
    let out_features = w_shape[1] as usize;
    let w_codes_i64 = w
        .get("w_codes")
        .and_then(Value::to_i64_vec)
        .ok_or_else(|| schema(format!("{name}: w_codes")))?;
    if w_codes_i64.len() != in_features * out_features {
        return Err(schema(format!("{name}: w_codes length mismatch")));
    }
    let qmax = (1i64 << (weight_bits - 1)) - 1;
    if w_codes_i64.iter().any(|&c| c.abs() > qmax) {
        return Err(schema(format!("{name}: weight code exceeds {weight_bits}-bit range")));
    }
    let b_codes = w
        .get("b_codes")
        .and_then(Value::to_i64_vec)
        .ok_or_else(|| schema(format!("{name}: b_codes")))?;
    if b_codes.len() != out_features {
        return Err(schema(format!("{name}: b_codes length mismatch")));
    }
    Ok(DenseLayer {
        name: name.to_string(),
        w_codes: w_codes_i64.iter().map(|&c| c as i32).collect(),
        in_features,
        out_features,
        b_codes,
        weight_bits,
        in_step: w.get("in_step").and_then(Value::as_f64).unwrap_or(0.0),
        w_step: w.get("w_step").and_then(Value::as_f64).unwrap_or(0.0),
    })
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;

    pub(crate) fn tiny_model_json(cin: usize, cout: usize) -> String {
        super::super::testgen::tiny_model_json(cin, cout)
    }

    #[allow(dead_code)]
    fn unused_generator(cin: usize, cout: usize) -> String {
        let w_codes: Vec<i64> = (0..9 * cin * cout).map(|i| (i as i64 % 5) - 2).collect();
        let dense_in = (4 / 2) * (4 / 2) * cout;
        let dw: Vec<i64> = (0..dense_in * 3).map(|i| (i as i64 % 3) - 1).collect();
        format!(
            r#"{{
  "qonnx_version": 1,
  "profile": "T",
  "input": {{"shape": [1,4,4,{cin}], "bits": 8, "int_bits": 0}},
  "nodes": [
    {{"name":"conv1","op":"QConv2d","inputs":["input"],"outputs":["c1"],
      "attrs":{{"kernel":[3,3],"stride":[1,1],"pad":"SAME","filters":{cout},
               "in_channels":{cin},"act_bits":8,"act_int_bits":2,"weight_bits":4}},
      "weights":{{"w_shape":[3,3,{cin},{cout}],"w_codes":{w},
                 "b_codes":{b},"mult":{m},"shift":{s},
                 "in_step":0.00390625,"out_step":0.015625}}}},
    {{"name":"pool1","op":"MaxPool2","inputs":["c1"],"outputs":["p1"],
      "attrs":{{"kernel":[2,2],"stride":[2,2]}}}},
    {{"name":"flatten","op":"Flatten","inputs":["p1"],"outputs":["f"],"attrs":{{}}}},
    {{"name":"dense","op":"QGemm","inputs":["f"],"outputs":["logits"],
      "attrs":{{"in_features":{din},"out_features":3,"weight_bits":4,
               "act_bits":0,"act_int_bits":0}},
      "weights":{{"w_shape":[{din},3],"w_codes":{dw},
                 "b_codes":[0,1,-1],"w_step":0.1,"in_step":0.015625}}}}
  ],
  "output": "logits"
}}"#,
            w = fmt_vec(&w_codes),
            b = fmt_vec(&vec![1i64; cout]),
            m = fmt_vec(&vec![16384i64; cout]),
            s = fmt_vec(&vec![15i64; cout]),
            din = dense_in,
            dw = fmt_vec(&dw),
        )
    }

    fn fmt_vec(xs: &[i64]) -> String {
        let inner: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
        format!("[{}]", inner.join(","))
    }

    #[test]
    fn parses_tiny_model() {
        let m = read_str(&tiny_model_json(1, 2)).unwrap();
        assert_eq!(m.profile, "T");
        assert_eq!(m.layers.len(), 4);
        let conv = m.conv_layers().next().unwrap();
        assert_eq!(conv.cin, 1);
        assert_eq!(conv.cout, 2);
        assert_eq!(conv.w(0, 0, 0, 0), -2);
        assert_eq!(m.dense().unwrap().out_features, 3);
    }

    #[test]
    fn rejects_bad_version() {
        let bad = tiny_model_json(1, 2).replace("\"qonnx_version\": 1", "\"qonnx_version\": 9");
        assert!(matches!(read_str(&bad), Err(ReadError::Schema(_))));
    }

    #[test]
    fn rejects_unknown_tensor_ref() {
        let bad = tiny_model_json(1, 2).replace(r#""inputs":["c1"]"#, r#""inputs":["nope"]"#);
        let err = read_str(&bad).unwrap_err();
        assert!(err.to_string().contains("not produced"), "{err}");
    }

    #[test]
    fn rejects_weight_code_overflow() {
        // weight_bits=4 -> |code| <= 7; inject an 8.
        let good = tiny_model_json(1, 2);
        let bad = good.replacen("-2,", "8,", 1);
        let err = read_str(&bad).unwrap_err();
        assert!(err.to_string().contains("exceeds"), "{err}");
    }

    #[test]
    fn rejects_double_consumption() {
        let bad = tiny_model_json(1, 2).replace(
            r#""name":"flatten","op":"Flatten","inputs":["p1"]"#,
            r#""name":"flatten","op":"Flatten","inputs":["c1"]"#,
        );
        let err = read_str(&bad).unwrap_err();
        // 'c1' already consumed by pool1.
        assert!(err.to_string().contains("consumed twice"), "{err}");
    }

    #[test]
    fn rejects_wrong_weight_len() {
        let bad = tiny_model_json(1, 2).replace(
            r#""w_shape":[3,3,1,2]"#,
            r#""w_shape":[3,3,1,3]"#,
        );
        assert!(read_str(&bad).is_err());
    }
}
