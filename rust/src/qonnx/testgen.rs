//! Synthetic QONNX-JSON generators for tests, property tests, and benches.
//!
//! Kept out of `#[cfg(test)]` so integration tests and bench binaries (which
//! compile as separate crates) can use them; hidden from docs.

use crate::testkit::Rng;

fn fmt_vec(xs: &[i64]) -> String {
    let inner: Vec<String> = xs.iter().map(|x| x.to_string()).collect();
    format!("[{}]", inner.join(","))
}

/// A minimal valid model: 4x4xCin input, one conv(Cout), pool, dense(3).
pub fn tiny_model_json(cin: usize, cout: usize) -> String {
    let w_codes: Vec<i64> = (0..9 * cin * cout).map(|i| (i as i64 % 5) - 2).collect();
    let dense_in = (4 / 2) * (4 / 2) * cout;
    let dw: Vec<i64> = (0..dense_in * 3).map(|i| (i as i64 % 3) - 1).collect();
    format!(
        r#"{{
  "qonnx_version": 1,
  "profile": "T",
  "input": {{"shape": [1,4,4,{cin}], "bits": 8, "int_bits": 0}},
  "nodes": [
    {{"name":"conv1","op":"QConv2d","inputs":["input"],"outputs":["c1"],
      "attrs":{{"kernel":[3,3],"stride":[1,1],"pad":"SAME","filters":{cout},
               "in_channels":{cin},"act_bits":8,"act_int_bits":2,"weight_bits":4}},
      "weights":{{"w_shape":[3,3,{cin},{cout}],"w_codes":{w},
                 "b_codes":{b},"mult":{m},"shift":{s},
                 "in_step":0.00390625,"out_step":0.015625}}}},
    {{"name":"pool1","op":"MaxPool2","inputs":["c1"],"outputs":["p1"],
      "attrs":{{"kernel":[2,2],"stride":[2,2]}}}},
    {{"name":"flatten","op":"Flatten","inputs":["p1"],"outputs":["f"],"attrs":{{}}}},
    {{"name":"dense","op":"QGemm","inputs":["f"],"outputs":["logits"],
      "attrs":{{"in_features":{din},"out_features":3,"weight_bits":4,
               "act_bits":0,"act_int_bits":0}},
      "weights":{{"w_shape":[{din},3],"w_codes":{dw},
                 "b_codes":[0,1,-1],"w_step":0.1,"in_step":0.015625}}}}
  ],
  "output": "logits"
}}"#,
        w = fmt_vec(&w_codes),
        b = fmt_vec(&vec![1i64; cout]),
        m = fmt_vec(&vec![16384i64; cout]),
        s = fmt_vec(&vec![15i64; cout]),
        din = dense_in,
        dw = fmt_vec(&dw),
    )
}

/// A model whose knob lattice has a large *statically illegal* region, for
/// exercising the explorer's analysis-based pre-pruning: the conv carries 8
/// weight bits of headroom but its small codes (all 3) round to zero after
/// a 3-bit drop, and the low-magnitude requant (`mult 1, shift 11`) starves
/// the dense head under deep activation drops — so a verified majority of
/// the 7x7x3 lattice fails the `const-output` rule while the root and the
/// uniform(1) rung stay legal.
pub fn prune_stress_model_json() -> String {
    let w_codes: Vec<i64> = vec![3; 9 * 2];
    let dw: Vec<i64> = (0..8 * 3).map(|i| (i as i64 % 3) - 1).collect();
    format!(
        r#"{{
  "qonnx_version": 1,
  "profile": "stress",
  "input": {{"shape": [1,4,4,1], "bits": 8, "int_bits": 0}},
  "nodes": [
    {{"name":"conv1","op":"QConv2d","inputs":["input"],"outputs":["c1"],
      "attrs":{{"kernel":[3,3],"stride":[1,1],"pad":"SAME","filters":2,
               "in_channels":1,"act_bits":8,"act_int_bits":2,"weight_bits":8}},
      "weights":{{"w_shape":[3,3,1,2],"w_codes":{w},
                 "b_codes":[0,0],"mult":[1,1],"shift":[11,11],
                 "in_step":0.00390625,"out_step":0.015625}}}},
    {{"name":"pool1","op":"MaxPool2","inputs":["c1"],"outputs":["p1"],
      "attrs":{{"kernel":[2,2],"stride":[2,2]}}}},
    {{"name":"flatten","op":"Flatten","inputs":["p1"],"outputs":["f"],"attrs":{{}}}},
    {{"name":"dense","op":"QGemm","inputs":["f"],"outputs":["logits"],
      "attrs":{{"in_features":8,"out_features":3,"weight_bits":4,
               "act_bits":0,"act_int_bits":0}},
      "weights":{{"w_shape":[8,3],"w_codes":{dw},
                 "b_codes":[0,1,-1],"w_step":0.1,"in_step":0.015625}}}}
  ],
  "output": "logits"
}}"#,
        w = fmt_vec(&w_codes),
        dw = fmt_vec(&dw),
    )
}

/// A model built for the error-bound analyzer's certification paths: the
/// conv weights are all even multiples of 4 with zero biases, so one- and
/// two-bit weight drops rescale *exactly* (round-half-up is lossless on
/// even codes, no clamping at 8 weight bits) and the variant is provably
/// bit-identical — while deeper weight drops, any activation drop, and
/// dense drops all incur real rounding error with large proven bounds.
/// Gives the triage gates a lattice with both certified-exact and
/// reject-by-tolerance regions.
pub fn bound_stress_model_json() -> String {
    let w_codes: Vec<i64> = (0..9 * 2).map(|i| [4, 0, -4][i % 3]).collect();
    let dw: Vec<i64> = (0..8 * 3).map(|i| (i as i64 % 3) - 1).collect();
    format!(
        r#"{{
  "qonnx_version": 1,
  "profile": "bound-stress",
  "input": {{"shape": [1,4,4,1], "bits": 8, "int_bits": 0}},
  "nodes": [
    {{"name":"conv1","op":"QConv2d","inputs":["input"],"outputs":["c1"],
      "attrs":{{"kernel":[3,3],"stride":[1,1],"pad":"SAME","filters":2,
               "in_channels":1,"act_bits":8,"act_int_bits":2,"weight_bits":8}},
      "weights":{{"w_shape":[3,3,1,2],"w_codes":{w},
                 "b_codes":[0,0],"mult":[16384,16384],"shift":[15,15],
                 "in_step":0.00390625,"out_step":0.015625}}}},
    {{"name":"pool1","op":"MaxPool2","inputs":["c1"],"outputs":["p1"],
      "attrs":{{"kernel":[2,2],"stride":[2,2]}}}},
    {{"name":"flatten","op":"Flatten","inputs":["p1"],"outputs":["f"],"attrs":{{}}}},
    {{"name":"dense","op":"QGemm","inputs":["f"],"outputs":["logits"],
      "attrs":{{"in_features":8,"out_features":3,"weight_bits":4,
               "act_bits":0,"act_int_bits":0}},
      "weights":{{"w_shape":[8,3],"w_codes":{dw},
                 "b_codes":[0,1,-1],"w_step":0.1,"in_step":0.015625}}}}
  ],
  "output": "logits"
}}"#,
        w = fmt_vec(&w_codes),
        dw = fmt_vec(&dw),
    )
}

/// Parameters of a randomly generated conv-pool pipeline.
#[derive(Debug, Clone)]
pub struct RandModelCfg {
    /// Input spatial side (must be divisible by 2^blocks).
    pub side: usize,
    pub cin: usize,
    /// (filters, act_bits, weight_bits) per conv block.
    pub blocks: Vec<(usize, u32, u32)>,
    pub classes: usize,
}

impl RandModelCfg {
    /// Random small-but-varied pipeline (1..=2 blocks, sides 4/8/12).
    pub fn gen(rng: &mut Rng) -> Self {
        let n_blocks = rng.usize(1, 2);
        let side = *rng.pick(&[4usize, 8, 12]);
        let blocks = (0..n_blocks)
            .map(|_| {
                (
                    rng.usize(1, 6),
                    *rng.pick(&[4u32, 8, 16]),
                    *rng.pick(&[4u32, 8]),
                )
            })
            .collect();
        RandModelCfg {
            side,
            cin: rng.usize(1, 3),
            blocks,
            classes: rng.usize(2, 10),
        }
    }
}

/// Generate a random valid QONNX-JSON model with integer weights.
pub fn random_model_json(cfg: &RandModelCfg, rng: &mut Rng) -> String {
    let mut nodes = Vec::new();
    let mut cin = cfg.cin;
    let mut side = cfg.side;
    let mut prev = "input".to_string();
    let mut in_step = 1.0 / 256.0;
    for (i, &(cout, act_bits, weight_bits)) in cfg.blocks.iter().enumerate() {
        let qmax = (1i64 << (weight_bits - 1)) - 1;
        let w: Vec<i64> = rng.i64_vec(9 * cin * cout, -qmax, qmax);
        let b: Vec<i64> = rng.i64_vec(cout, -1000, 1000);
        let mult: Vec<i64> = rng.i64_vec(cout, 1, 1 << 15);
        let shift: Vec<i64> = rng.i64_vec(cout, 8, 24);
        let out_step = 2f64.powi(2 - act_bits as i32);
        nodes.push(format!(
            r#"{{"name":"conv{i}","op":"QConv2d","inputs":["{prev}"],"outputs":["c{i}"],
  "attrs":{{"kernel":[3,3],"stride":[1,1],"pad":"SAME","filters":{cout},
           "in_channels":{cin},"act_bits":{act_bits},"act_int_bits":2,"weight_bits":{weight_bits}}},
  "weights":{{"w_shape":[3,3,{cin},{cout}],"w_codes":{w},"b_codes":{b},
             "mult":{m},"shift":{s},"in_step":{in_step},"out_step":{out_step}}}}}"#,
            w = fmt_vec(&w),
            b = fmt_vec(&b),
            m = fmt_vec(&mult),
            s = fmt_vec(&shift),
        ));
        nodes.push(format!(
            r#"{{"name":"pool{i}","op":"MaxPool2","inputs":["c{i}"],"outputs":["p{i}"],
  "attrs":{{"kernel":[2,2],"stride":[2,2]}}}}"#
        ));
        prev = format!("p{i}");
        cin = cout;
        side /= 2;
        in_step = out_step;
    }
    let din = side * side * cin;
    let k = cfg.classes;
    let dw: Vec<i64> = rng.i64_vec(din * k, -7, 7);
    let db: Vec<i64> = rng.i64_vec(k, -50, 50);
    nodes.push(format!(
        r#"{{"name":"flatten","op":"Flatten","inputs":["{prev}"],"outputs":["f"],"attrs":{{}}}}"#
    ));
    nodes.push(format!(
        r#"{{"name":"dense","op":"QGemm","inputs":["f"],"outputs":["logits"],
  "attrs":{{"in_features":{din},"out_features":{k},"weight_bits":4,"act_bits":0,"act_int_bits":0}},
  "weights":{{"w_shape":[{din},{k}],"w_codes":{dw},"b_codes":{db},"w_step":0.125,"in_step":{in_step}}}}}"#,
        dw = fmt_vec(&dw),
        db = fmt_vec(&db),
    ));
    format!(
        r#"{{"qonnx_version": 1, "profile": "rand",
  "input": {{"shape": [1,{side0},{side0},{cin0}], "bits": 8, "int_bits": 0}},
  "nodes": [{nodes}],
  "output": "logits"}}"#,
        side0 = cfg.side,
        cin0 = cfg.cin,
        nodes = nodes.join(",\n")
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::read_str;
    use crate::testkit;

    #[test]
    fn tiny_model_parses() {
        assert!(read_str(&tiny_model_json(1, 2)).is_ok());
    }

    #[test]
    fn prune_stress_model_parses() {
        assert!(read_str(&prune_stress_model_json()).is_ok());
    }

    #[test]
    fn bound_stress_model_parses_with_even_conv_codes() {
        let m = read_str(&bound_stress_model_json()).unwrap();
        // The certification tests rely on every conv code being an even
        // multiple of 4 (exact under 1- and 2-bit round-half-up drops).
        let conv = m.conv_layers().next().unwrap();
        assert!(conv.w_codes.iter().all(|w| w % 4 == 0));
        assert_eq!(conv.weight_bits, 8);
    }

    #[test]
    fn random_models_parse() {
        testkit::check("random qonnx models parse", |rng| {
            let cfg = RandModelCfg::gen(rng);
            let json = random_model_json(&cfg, rng);
            match read_str(&json) {
                Ok(_) => Ok(()),
                Err(e) => Err(format!("cfg {cfg:?}: {e}")),
            }
        });
    }
}
