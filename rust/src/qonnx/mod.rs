//! QONNX front end: the rust port of the paper's extended ONNXParser.
//!
//! The paper's flow starts from a QONNX model (ONNX + arbitrary-precision
//! quantization). Our interchange is QONNX-as-JSON (schema documented in
//! `python/compile/export.py` and DESIGN.md §2); this module is the
//! *Reader*: it parses the JSON, validates the graph (DAG, single-consumer
//! streaming edges, shape inference) and produces the intermediate
//! representation — a list of typed layer objects with hyper-parameters —
//! that the HLS Writer (`crate::writer`) and the MDC front end
//! (`crate::mdc`) consume.

mod ir;
mod reader;
mod shapes;
#[doc(hidden)]
pub mod testgen;

pub use ir::{ConvLayer, DenseLayer, Layer, LayerKind, PoolLayer, QonnxModel, TensorShape};
pub use reader::{read_file, read_str, ReadError};
pub use shapes::infer_shapes;

#[doc(hidden)]
pub use testgen::{
    bound_stress_model_json, prune_stress_model_json, random_model_json,
    tiny_model_json as test_model_json, RandModelCfg,
};
