//! Intermediate representation: typed layer objects (the ONNXParser
//! "list of objects describing the layers' hyperparameters and connections").

/// NHWC tensor shape (batch dim excluded — the streaming engine is
/// per-sample; batching happens in the coordinator).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TensorShape {
    pub h: usize,
    pub w: usize,
    pub c: usize,
}

impl TensorShape {
    pub fn elems(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// A quantized 3x3 SAME conv layer, BN folded, with fused ReLU+requant.
#[derive(Debug, Clone, PartialEq)]
pub struct ConvLayer {
    pub name: String,
    /// (3,3,Cin,Cout) integer weight codes, flattened row-major (dy,dx,ci,co).
    pub w_codes: Vec<i32>,
    pub cin: usize,
    pub cout: usize,
    /// Bias codes at accumulator scale (per out-channel).
    pub b_codes: Vec<i64>,
    /// Per-channel requant multiplier / right shift (TFLite-style).
    pub mult: Vec<i64>,
    pub shift: Vec<i64>,
    /// Output activation precision (ufixed<act_bits, act_int_bits>).
    pub act_bits: u32,
    pub act_int_bits: u32,
    pub weight_bits: u32,
    /// Float scales (power model + reports).
    pub in_step: f64,
    pub out_step: f64,
}

impl ConvLayer {
    /// Weight code at (dy, dx, ci, co).
    #[inline]
    pub fn w(&self, dy: usize, dx: usize, ci: usize, co: usize) -> i32 {
        self.w_codes[((dy * 3 + dx) * self.cin + ci) * self.cout + co]
    }

    /// Number of MAC operations per output pixel.
    pub fn macs_per_pixel(&self) -> usize {
        9 * self.cin * self.cout
    }
}

/// 2x2 stride-2 max-pool layer (operates on integer codes).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PoolLayer {
    pub name: String,
}

/// Quantized fully-connected head; emits raw i64 accumulators (logits).
#[derive(Debug, Clone, PartialEq)]
pub struct DenseLayer {
    pub name: String,
    /// (F, K) weight codes, row-major.
    pub w_codes: Vec<i32>,
    pub in_features: usize,
    pub out_features: usize,
    pub b_codes: Vec<i64>,
    pub weight_bits: u32,
    pub in_step: f64,
    pub w_step: f64,
}

/// One layer of the streaming pipeline.
#[derive(Debug, Clone, PartialEq)]
pub enum Layer {
    Conv(ConvLayer),
    Pool(PoolLayer),
    Flatten { name: String },
    Dense(DenseLayer),
}

/// Discriminant used for actor-sharing decisions in MDC.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerKind {
    Conv,
    Pool,
    Flatten,
    Dense,
}

impl LayerKind {
    /// Lower-case op name, as rendered in diagnostics ("conv", "pool", ...).
    pub fn as_str(self) -> &'static str {
        match self {
            LayerKind::Conv => "conv",
            LayerKind::Pool => "pool",
            LayerKind::Flatten => "flatten",
            LayerKind::Dense => "dense",
        }
    }
}

impl Layer {
    pub fn name(&self) -> &str {
        match self {
            Layer::Conv(l) => &l.name,
            Layer::Pool(l) => &l.name,
            Layer::Flatten { name } => name,
            Layer::Dense(l) => &l.name,
        }
    }

    pub fn kind(&self) -> LayerKind {
        match self {
            Layer::Conv(_) => LayerKind::Conv,
            Layer::Pool(_) => LayerKind::Pool,
            Layer::Flatten { .. } => LayerKind::Flatten,
            Layer::Dense(_) => LayerKind::Dense,
        }
    }
}

/// A parsed, validated QONNX model: a linear streaming pipeline (the paper's
/// CNNs are single-path dataflows; see reader.rs for the DAG validation that
/// enforces this).
#[derive(Debug, Clone, PartialEq)]
pub struct QonnxModel {
    pub profile: String,
    pub input_shape: TensorShape,
    pub input_bits: u32,
    pub input_int_bits: u32,
    pub layers: Vec<Layer>,
}

impl QonnxModel {
    pub fn conv_layers(&self) -> impl Iterator<Item = &ConvLayer> {
        self.layers.iter().filter_map(|l| match l {
            Layer::Conv(c) => Some(c),
            _ => None,
        })
    }

    pub fn dense(&self) -> Option<&DenseLayer> {
        self.layers.iter().find_map(|l| match l {
            Layer::Dense(d) => Some(d),
            _ => None,
        })
    }

    /// Total number of weight parameters.
    pub fn param_count(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Conv(c) => c.w_codes.len() + c.b_codes.len(),
                Layer::Dense(d) => d.w_codes.len() + d.b_codes.len(),
                _ => 0,
            })
            .sum()
    }

    /// Compact per-layer precision signature, e.g. `a8w8-a8w4-w4` (conv
    /// layers as `a<act_bits>w<weight_bits>`, the dense head as
    /// `w<weight_bits>`). Used by the approximation explorer's reports to
    /// show what a derived profile actually runs.
    pub fn precision_signature(&self) -> String {
        let parts: Vec<String> = self
            .layers
            .iter()
            .filter_map(|l| match l {
                Layer::Conv(c) => Some(format!("a{}w{}", c.act_bits, c.weight_bits)),
                Layer::Dense(d) => Some(format!("w{}", d.weight_bits)),
                _ => None,
            })
            .collect();
        parts.join("-")
    }

    /// Total MACs for one classification (28x28 input assumed by caller's
    /// shapes; computed from inferred shapes).
    pub fn total_macs(&self) -> usize {
        let shapes = super::infer_shapes(self);
        let mut total = 0;
        for (i, l) in self.layers.iter().enumerate() {
            if let Layer::Conv(c) = l {
                let out = shapes[i + 1];
                total += out.h * out.w * c.macs_per_pixel();
            }
            if let Layer::Dense(d) = l {
                total += d.in_features * d.out_features;
            }
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use crate::qonnx::{read_str, test_model_json};

    #[test]
    fn precision_signature_names_every_parametric_layer() {
        // tiny model: one conv (act 8, weight 4) + dense head (weight 4);
        // pool/flatten carry no precision and are skipped.
        let m = read_str(&test_model_json(1, 2)).unwrap();
        assert_eq!(m.precision_signature(), "a8w4-w4");
    }
}
