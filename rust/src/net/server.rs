//! The network front end: acceptor + per-connection reader/writer threads
//! bridging TCP frames onto the in-process serving spine.
//!
//! ```text
//!                 accept           frames            tickets
//! TcpListener --------------> reader thread ----+--> ClientHandle/mpsc
//!    |                            |  bounded    |        (spine)
//!    |  (one pair per conn)       v  channel    |
//!    +----------------------> writer thread <---+  awaits tickets, writes
//!                                                  Response/Error frames
//! ```
//!
//! Each connection gets a **reader** (decodes frames, runs admission
//! control, submits admitted images to the dispatcher) and a **writer**
//! (awaits the resulting tickets and writes reply frames). They are joined
//! by a *bounded* channel of [`Outcome`]s sized by
//! [`NetServerConfig::window`]: when a client pipelines more requests than
//! the window, the reader blocks on the channel — per-connection
//! backpressure that stops a single socket from flooding the spine. All
//! outcomes (replies *and* typed denials) flow through the one writer, so
//! replies keep per-connection submission order.
//!
//! **Admission control** is aggregate: when [`NetStats::inflight`] (admitted
//! but not yet answered, summed over every connection) reaches
//! [`NetServerConfig::admission_depth`], the request is shed with a typed
//! [`ErrCode::Overloaded`] frame and *never touches the dispatcher* — the
//! spine's `queue_depth`/`shard_depth` gauges cannot leak on the shed path
//! (regression-tested in `rust/tests/net_protocol.rs`, mirroring the
//! dead-pool drop accounting in `coordinator/server.rs`).
//!
//! **Graceful drain** ([`NetServer::shutdown`]): set the closed flag, wake
//! the acceptor, and half-close (`Shutdown::Read`) every connection. Readers
//! fall out of their loop and drop the channel sender; writers drain every
//! queued ticket, flush the replies, and exit; then all threads are joined.
//! In-flight requests are answered — only *new* work is refused.
//!
//! Framing violations (bad magic, oversize length, truncation) earn a typed
//! error frame and a close: a desynced byte stream cannot be re-framed.
//! Well-framed invalid requests (wrong image size) are denied without
//! closing. Nothing on this path panics on wire input.

use std::io::{BufReader, BufWriter};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use super::frame::{read_frame, write_frame, ErrCode, Frame, FrameError, FrameKind, WireResponse};
use crate::coordinator::{ClientHandle, Ticket};
use crate::json::Value;
use crate::metrics::{Counter, Gauge, MetricsRegistry};
use crate::trace::{EventKind, SpanKind, TraceCollector};

#[derive(Debug, Clone)]
pub struct NetServerConfig {
    /// Bind address; use port 0 to let the OS pick (read it back via
    /// [`NetServer::addr`]).
    pub addr: String,
    /// Payload ceiling per frame; larger length prefixes are rejected
    /// before allocation.
    pub max_payload: usize,
    /// Aggregate admitted-but-unanswered ceiling: at this depth new
    /// requests are shed with [`ErrCode::Overloaded`]. 0 sheds everything
    /// (useful in tests).
    pub admission_depth: usize,
    /// Per-connection in-flight window (bounded reader->writer channel).
    pub window: usize,
    /// When set, request payloads of any other size are denied with
    /// [`ErrCode::BadRequest`] (without closing the connection) instead of
    /// reaching the backend.
    pub expected_image_len: Option<usize>,
    /// The serving spine's metrics registry; when set, `Stats` frame
    /// answers include its snapshot under `"serve"` next to the front end's
    /// own under `"net"`.
    pub spine_registry: Option<Arc<MetricsRegistry>>,
    /// Request tracing: records `net.read`/`admission`/`net.write` spans
    /// and `shed` events on the collector's network lane (share the same
    /// collector with [`crate::coordinator::ServerConfig::trace`] to get
    /// whole-lifecycle trees). `None` records nothing.
    pub trace: Option<Arc<TraceCollector>>,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig {
            addr: "127.0.0.1:0".into(),
            max_payload: super::frame::DEFAULT_MAX_PAYLOAD,
            admission_depth: 256,
            window: 32,
            expected_image_len: None,
            spine_registry: None,
            trace: None,
        }
    }
}

/// Observable front-end state (all lock-free). Every instrument is a named
/// handle in `registry` (`net.*`), so the front end snapshots to JSON
/// through the same exposition path as the spine — that snapshot is what a
/// `Stats` wire frame is answered with.
#[derive(Debug)]
pub struct NetStats {
    /// Connections ever accepted.
    pub connections: Arc<Counter>,
    /// Connections currently open.
    pub open_connections: Arc<Gauge>,
    /// Requests past admission control and submitted to the spine.
    pub admitted: Arc<Counter>,
    /// Admitted but not yet answered (the admission-control signal).
    pub inflight: Arc<Gauge>,
    /// Replies written with a Response frame.
    pub served: Arc<Counter>,
    /// Admitted requests whose ticket resolved Err (spine dropped them).
    pub failed: Arc<Counter>,
    /// Requests shed by admission control (Overloaded).
    pub shed: Arc<Counter>,
    /// Well-framed requests denied as BadRequest (e.g. wrong image size).
    pub bad_requests: Arc<Counter>,
    /// Framing/protocol violations (each closes its connection).
    pub frame_errors: Arc<Counter>,
    /// The registry every handle above lives in.
    pub registry: Arc<MetricsRegistry>,
}

impl Default for NetStats {
    fn default() -> Self {
        let registry = Arc::new(MetricsRegistry::default());
        NetStats {
            connections: registry.counter("net.connections"),
            open_connections: registry.gauge("net.open_connections"),
            admitted: registry.counter("net.admitted"),
            inflight: registry.gauge("net.inflight"),
            served: registry.counter("net.served"),
            failed: registry.counter("net.failed"),
            shed: registry.counter("net.shed"),
            bad_requests: registry.counter("net.bad_requests"),
            frame_errors: registry.counter("net.frame_errors"),
            registry,
        }
    }
}

/// What the reader hands the writer, in per-connection request order.
/// `key` is the trace correlation id (`None` when tracing is off or the
/// outcome has no request behind it): admitted requests reuse their spine
/// request id, denied ones draw from the collector's denied-key range, and
/// the writer closes every keyed tree with a `net.write` span.
enum Outcome {
    /// Admitted: await the ticket, write Response (or Internal error).
    Reply {
        wire_id: u64,
        ticket: Ticket,
        key: Option<u64>,
    },
    /// Denied without touching the spine: write a typed error frame.
    Deny {
        wire_id: u64,
        code: ErrCode,
        message: String,
        key: Option<u64>,
    },
    /// Stats exchange: write the registry snapshot(s) back as JSON.
    Stats { wire_id: u64 },
}

/// Handle to the running front end. Dropping it (or calling
/// [`shutdown`](NetServer::shutdown)) drains gracefully.
pub struct NetServer {
    local_addr: SocketAddr,
    closed: Arc<AtomicBool>,
    /// Read-half clones used to interrupt blocked readers on drain.
    conns: Arc<Mutex<Vec<TcpStream>>>,
    acceptor: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
    pub stats: Arc<NetStats>,
}

impl NetServer {
    /// Bind `cfg.addr` and start accepting. `client` is the spine handle
    /// every connection submits through (`AdaptiveServer::client()`).
    pub fn start(cfg: NetServerConfig, client: ClientHandle) -> Result<NetServer> {
        let listener =
            TcpListener::bind(&cfg.addr).with_context(|| format!("bind {}", cfg.addr))?;
        let local_addr = listener.local_addr()?;
        let closed = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let stats = Arc::new(NetStats::default());
        let cfg = Arc::new(cfg);

        let a_closed = closed.clone();
        let a_conns = conns.clone();
        let a_handlers = handlers.clone();
        let a_stats = stats.clone();
        let acceptor = std::thread::Builder::new()
            .name("net-acceptor".into())
            .spawn(move || {
                for conn in listener.incoming() {
                    // The drain path connects once to unblock this accept;
                    // check the flag before serving whatever arrived.
                    if a_closed.load(Ordering::SeqCst) {
                        break;
                    }
                    let stream = match conn {
                        Ok(s) => s,
                        Err(_) => continue,
                    };
                    let _ = stream.set_nodelay(true);
                    if let Ok(clone) = stream.try_clone() {
                        a_conns.lock().unwrap().push(clone);
                    }
                    let h_client = client.clone();
                    let h_cfg = cfg.clone();
                    let h_stats = a_stats.clone();
                    let h_closed = a_closed.clone();
                    match std::thread::Builder::new().name("net-conn".into()).spawn(
                        move || handle_conn(stream, h_client, h_cfg, h_stats, h_closed),
                    ) {
                        Ok(h) => a_handlers.lock().unwrap().push(h),
                        Err(_) => continue, // thread exhaustion: drop the conn
                    }
                }
            })?;

        Ok(NetServer {
            local_addr,
            closed,
            conns,
            acceptor: Some(acceptor),
            handlers,
            stats,
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Chaos hook: hard-kill every currently open connection (both
    /// directions) without stopping the server — the socket-level fault the
    /// chaos-recovery gate injects mid-flight. Peers see a reset/EOF on
    /// their next read; in-flight tickets still resolve on the server side
    /// (the writer drains them against the dead socket, so the `inflight`
    /// gauge cannot leak). Returns how many sockets were torn down;
    /// already-closed clones are skipped.
    pub fn reset_connections(&self) -> usize {
        let conns = self.conns.lock().unwrap();
        let mut n = 0;
        for c in conns.iter() {
            if c.shutdown(Shutdown::Both).is_ok() {
                n += 1;
            }
        }
        n
    }

    /// Graceful drain: stop accepting, flush in-flight tickets, close.
    pub fn shutdown(mut self) {
        self.close();
    }

    fn close(&mut self) {
        let Some(acceptor) = self.acceptor.take() else {
            return; // already drained
        };
        self.closed.store(true, Ordering::SeqCst);
        // Wake the blocked accept() with one throwaway connection; the
        // acceptor re-checks the flag and exits.
        let _ = TcpStream::connect(self.local_addr);
        let _ = acceptor.join();
        // Half-close every connection's read side: readers unblock and fall
        // out of their loop; writers still own the write side, so queued
        // replies flush before the close.
        for s in self.conns.lock().unwrap().iter() {
            let _ = s.shutdown(Shutdown::Read);
        }
        let hs: Vec<JoinHandle<()>> = self.handlers.lock().unwrap().drain(..).collect();
        for h in hs {
            let _ = h.join();
        }
        self.conns.lock().unwrap().clear();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.close();
    }
}

/// One connection: read loop here, write loop in a sibling thread, joined
/// by a bounded outcome channel (the per-connection backpressure window).
fn handle_conn(
    stream: TcpStream,
    client: ClientHandle,
    cfg: Arc<NetServerConfig>,
    stats: Arc<NetStats>,
    closed: Arc<AtomicBool>,
) {
    stats.connections.inc();
    stats.open_connections.inc();
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            stats.open_connections.dec();
            return;
        }
    };

    let (tx, rx) = mpsc::sync_channel::<Outcome>(cfg.window.max(1));
    let w_stats = stats.clone();
    let w_trace = cfg.trace.clone();
    let w_spine = cfg.spine_registry.clone();
    let writer = std::thread::Builder::new()
        .name("net-conn-writer".into())
        .spawn(move || {
            let mut w = BufWriter::new(write_half);
            while let Ok(outcome) = rx.recv() {
                let (frame, key) = match outcome {
                    Outcome::Reply {
                        wire_id,
                        ticket,
                        key,
                    } => {
                        let frame = match ticket.await_reply() {
                            Ok(resp) => {
                                w_stats.served.inc();
                                Frame::response(&WireResponse {
                                    id: wire_id,
                                    pred: resp.pred as u32,
                                    shard: resp.shard as u32,
                                    latency_us: resp.latency_us,
                                    profile: resp.profile,
                                    logits: resp.logits,
                                })
                            }
                            Err(_) => {
                                w_stats.failed.inc();
                                Frame::error(
                                    wire_id,
                                    ErrCode::Internal,
                                    "request dropped by the serving spine",
                                )
                            }
                        };
                        // The reply left the in-flight set whether or not
                        // the peer is still there to read it.
                        w_stats.inflight.dec();
                        (frame, key)
                    }
                    Outcome::Deny {
                        wire_id,
                        code,
                        message,
                        key,
                    } => (Frame::error(wire_id, code, &message), key),
                    Outcome::Stats { wire_id } => {
                        let mut fields = vec![("net", w_stats.registry.snapshot())];
                        if let Some(spine) = &w_spine {
                            fields.push(("serve", spine.snapshot()));
                        }
                        let json = Value::obj(fields).to_string();
                        (Frame::stats_response(wire_id, json), None)
                    }
                };
                // A gone peer must not abort the drain: later outcomes may
                // hold tickets whose inflight accounting still has to run.
                let _ = write_frame(&mut w, &frame);
                // Every traced request tree terminates in a net.write span,
                // reply and denial alike — the conservation gate counts on
                // it to prove no request id vanished between the lanes.
                if let (Some(t), Some(key)) = (&w_trace, key) {
                    let tick = t.next_wire_tick();
                    t.span(t.net_lane(), key, SpanKind::NetWrite, tick, tick);
                }
            }
        });
    let writer = match writer {
        Ok(w) => w,
        Err(_) => {
            stats.open_connections.dec();
            return;
        }
    };

    let mut reader = BufReader::new(stream);
    loop {
        match read_frame(&mut reader, cfg.max_payload) {
            Ok(frame) if frame.kind == FrameKind::Request => {
                // One wire tick marks the frame read, the next marks the
                // admission verdict — the virtual timeline of the net lane.
                let ticks = cfg.trace.as_ref().map(|t| (t.next_wire_tick(), t.next_wire_tick()));
                if closed.load(Ordering::SeqCst) {
                    let key = trace_denied(&cfg.trace, ticks, "draining", false);
                    let _ = tx.send(Outcome::Deny {
                        wire_id: frame.id,
                        code: ErrCode::Draining,
                        message: "server is draining".into(),
                        key,
                    });
                    continue;
                }
                if let Some(want) = cfg.expected_image_len {
                    if frame.payload.len() != want {
                        stats.bad_requests.inc();
                        let key = trace_denied(&cfg.trace, ticks, "bad-request", false);
                        let _ = tx.send(Outcome::Deny {
                            wire_id: frame.id,
                            code: ErrCode::BadRequest,
                            message: format!(
                                "image must be {want} bytes, got {}",
                                frame.payload.len()
                            ),
                            key,
                        });
                        continue;
                    }
                }
                // Admission control BEFORE the spine sees the request: a
                // shed request leaves no queue_depth/shard_depth trace.
                if stats.inflight.get() >= cfg.admission_depth as i64 {
                    stats.shed.inc();
                    let key = trace_denied(&cfg.trace, ticks, "shed", true);
                    let _ = tx.send(Outcome::Deny {
                        wire_id: frame.id,
                        code: ErrCode::Overloaded,
                        message: format!(
                            "in-flight depth at the admission limit {}",
                            cfg.admission_depth
                        ),
                        key,
                    });
                    continue;
                }
                stats.admitted.inc();
                stats.inflight.inc();
                let ticket = client.submit(frame.payload);
                // Retroactive: an admitted request's wire spans are keyed by
                // the spine id the submit just assigned, so its net-lane and
                // shard-lane spans land in one tree.
                let key = match (&cfg.trace, ticks) {
                    (Some(t), Some((read_tick, adm_tick))) => {
                        let lane = t.net_lane();
                        t.span(lane, ticket.id(), SpanKind::NetRead, read_tick, read_tick);
                        t.span_detail(
                            lane,
                            ticket.id(),
                            SpanKind::Admission,
                            read_tick,
                            adm_tick,
                            "admitted".to_string(),
                        );
                        Some(ticket.id())
                    }
                    _ => None,
                };
                // Blocks once `window` outcomes are queued: backpressure.
                if tx
                    .send(Outcome::Reply {
                        wire_id: frame.id,
                        ticket,
                        key,
                    })
                    .is_err()
                {
                    break;
                }
            }
            Ok(frame) if frame.kind == FrameKind::Stats => {
                // Read-only metrics exchange: answered even while draining,
                // never admitted, never counted against the window's
                // request accounting.
                if tx.send(Outcome::Stats { wire_id: frame.id }).is_err() {
                    break;
                }
            }
            Ok(frame) => {
                // Clients may only send requests (and stats probes).
                stats.frame_errors.inc();
                let _ = tx.send(Outcome::Deny {
                    wire_id: frame.id,
                    code: ErrCode::BadRequest,
                    message: format!("clients may not send {:?} frames", frame.kind),
                    key: None,
                });
                break;
            }
            Err(FrameError::Closed) | Err(FrameError::Io(_)) => break,
            Err(e) => {
                // Bad magic/version/kind, oversize, truncated, malformed: a
                // typed error frame, then close — the stream cannot be
                // re-framed. Id 0: framing errors have no request to echo.
                stats.frame_errors.inc();
                let _ = tx.send(Outcome::Deny {
                    wire_id: 0,
                    code: ErrCode::BadRequest,
                    message: e.to_string(),
                    key: None,
                });
                break;
            }
        }
    }
    // Dropping our sender ends the writer's recv loop *after* it drains
    // every queued outcome — in-flight tickets resolve and flush.
    drop(tx);
    let _ = writer.join();
    stats.open_connections.dec();
}

/// Wire-side spans for a request denied before admission: `net.read` +
/// `admission` (detail = the verdict) under a fresh denied-range key, plus
/// a `shed` instant event when admission control dropped it. Returns the
/// correlation key the writer closes with a `net.write` span, or `None`
/// when tracing is off.
fn trace_denied(
    trace: &Option<Arc<TraceCollector>>,
    ticks: Option<(u64, u64)>,
    verdict: &str,
    shed: bool,
) -> Option<u64> {
    let (t, (read_tick, adm_tick)) = match (trace, ticks) {
        (Some(t), Some(ticks)) => (t, ticks),
        _ => return None,
    };
    let key = t.denied_key();
    let lane = t.net_lane();
    t.span(lane, key, SpanKind::NetRead, read_tick, read_tick);
    t.span_detail(lane, key, SpanKind::Admission, read_tick, adm_tick, verdict.to_string());
    if shed {
        t.event(lane, EventKind::Shed, adm_tick, Some(key), verdict.to_string());
    }
    Some(key)
}
