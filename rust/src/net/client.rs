//! Blocking wire client for the `net` protocol.
//!
//! [`NetClient`] is the socket twin of the in-process
//! `coordinator::ClientHandle`: `submit` writes a request frame (ids are
//! allocated per connection), `recv` blocks for the next reply frame, and
//! [`classify_pipelined`](NetClient::classify_pipelined) keeps a window of
//! requests in flight like `ClientHandle::classify_pipelined` does over the
//! mpsc spine. Replies arrive in submission order (the server guarantees
//! per-connection ordering); denials surface as typed
//! [`NetReply::Denied`] values, not errors — shedding is an expected
//! response under load, and callers decide how to react.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;

use anyhow::{bail, Context, Result};

use super::frame::{
    decode_error, decode_response, read_frame, write_frame, ErrCode, Frame, FrameError, FrameKind,
    WireResponse, DEFAULT_MAX_PAYLOAD,
};

/// One reply frame, decoded.
#[derive(Debug, Clone, PartialEq)]
pub enum NetReply {
    Response(WireResponse),
    /// The server denied the request with a typed error frame (id echoes
    /// the request; framing-level errors carry id 0).
    Denied {
        id: u64,
        code: ErrCode,
        message: String,
    },
}

/// A blocking connection to a [`super::NetServer`].
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl NetClient {
    pub fn connect(addr: &str) -> Result<NetClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone().context("clone stream")?);
        Ok(NetClient {
            reader,
            writer: BufWriter::new(stream),
            next_id: 0,
        })
    }

    /// Write one request frame; returns the id the reply will echo.
    pub fn submit(&mut self, image: &[u8]) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        write_frame(&mut self.writer, &Frame::request(id, image.to_vec()))
            .context("write request frame")?;
        Ok(id)
    }

    /// Block for the next reply frame (response or typed denial).
    pub fn recv(&mut self) -> Result<NetReply, FrameError> {
        let frame = read_frame(&mut self.reader, DEFAULT_MAX_PAYLOAD)?;
        match frame.kind {
            FrameKind::Response => Ok(NetReply::Response(decode_response(
                frame.id,
                &frame.payload,
            )?)),
            FrameKind::Error => {
                let (code, message) = decode_error(&frame.payload)?;
                Ok(NetReply::Denied {
                    id: frame.id,
                    code,
                    message,
                })
            }
            FrameKind::Request => Err(FrameError::Malformed(
                "server sent a request frame".into(),
            )),
        }
    }

    /// Synchronous convenience: one request, one reply; denials become
    /// errors. Requires no other submissions in flight on this connection.
    pub fn classify(&mut self, image: &[u8]) -> Result<WireResponse> {
        let id = self.submit(image)?;
        match self.recv()? {
            NetReply::Response(resp) if resp.id == id => Ok(resp),
            NetReply::Response(resp) => bail!(
                "reply id {} does not match request {id} (pipelined submissions pending?)",
                resp.id
            ),
            NetReply::Denied { code, message, .. } => {
                bail!("request {id} denied: {code}: {message}")
            }
        }
    }

    /// Pipelined classify: keep up to `window` requests in flight, reading
    /// the oldest reply as new requests are written. Replies come back in
    /// submission order, one per input (denials included in place).
    pub fn classify_pipelined(
        &mut self,
        images: impl IntoIterator<Item = Vec<u8>>,
        window: usize,
    ) -> Result<Vec<NetReply>> {
        let window = window.max(1);
        let mut out = Vec::new();
        let mut inflight = 0usize;
        for img in images {
            self.submit(&img)?;
            inflight += 1;
            if inflight >= window {
                out.push(self.recv()?);
                inflight -= 1;
            }
        }
        for _ in 0..inflight {
            out.push(self.recv()?);
        }
        Ok(out)
    }
}
