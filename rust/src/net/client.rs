//! Blocking wire client for the `net` protocol.
//!
//! [`NetClient`] is the socket twin of the in-process
//! `coordinator::ClientHandle`: `submit` writes a request frame (ids are
//! allocated per connection), `recv` blocks for the next reply frame, and
//! [`classify_pipelined`](NetClient::classify_pipelined) keeps a window of
//! requests in flight like `ClientHandle::classify_pipelined` does over the
//! mpsc spine. Replies arrive in submission order (the server guarantees
//! per-connection ordering); denials surface as typed
//! [`NetReply::Denied`] values, not errors — shedding is an expected
//! response under load, and callers decide how to react.
//!
//! [`ResilientClient`] wraps a `NetClient` with the failure policy a real
//! caller wants (see `docs/robustness.md` for the retry taxonomy):
//!
//! * **Retryable** — `Overloaded`, `Draining`, connection reset/refused:
//!   bounded retries with deterministic exponential backoff + jitter from
//!   `testkit::rng` (same seed, same schedule). Draining and transport
//!   errors also drop the connection and redial — a half-read frame
//!   desynchronizes the stream, so it must never be reused.
//! * **Fatal** — `BadRequest`, `Internal`, and an expired end-to-end
//!   deadline: surfaced immediately as typed errors.
//!
//! [`NetClientPool`] rounds a handful of resilient connections over one
//! address so a driver thread gets reconnect-on-failure without managing
//! sockets itself.

use std::io::{BufReader, BufWriter};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use anyhow::{anyhow, bail, Context, Result};

use super::frame::{
    decode_error, decode_response, decode_stats, read_frame, write_frame, ErrCode, Frame,
    FrameError, FrameKind, WireResponse, DEFAULT_MAX_PAYLOAD,
};
use crate::testkit::Rng;
use crate::trace::{EventKind, TraceCollector};

/// One reply frame, decoded.
#[derive(Debug, Clone, PartialEq)]
pub enum NetReply {
    Response(WireResponse),
    /// The server denied the request with a typed error frame (id echoes
    /// the request; framing-level errors carry id 0).
    Denied {
        id: u64,
        code: ErrCode,
        message: String,
    },
}

/// A blocking connection to a [`super::NetServer`].
pub struct NetClient {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    next_id: u64,
}

impl NetClient {
    pub fn connect(addr: &str) -> Result<NetClient> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        let _ = stream.set_nodelay(true);
        let reader = BufReader::new(stream.try_clone().context("clone stream")?);
        Ok(NetClient {
            reader,
            writer: BufWriter::new(stream),
            next_id: 0,
        })
    }

    /// Set (or clear) the socket read deadline `recv` honors. An expired
    /// deadline surfaces as [`FrameError::TimedOut`] and leaves the stream
    /// possibly mid-frame: drop the connection, do not reuse it.
    pub fn set_read_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        // A zero Duration is rejected by the OS; clamp to the smallest
        // meaningful deadline instead.
        let timeout = timeout.map(|t| t.max(Duration::from_millis(1)));
        self.reader
            .get_ref()
            .set_read_timeout(timeout)
            .context("set read timeout")
    }

    /// Write one request frame; returns the id the reply will echo.
    pub fn submit(&mut self, image: &[u8]) -> Result<u64> {
        let id = self.next_id;
        self.next_id += 1;
        write_frame(&mut self.writer, &Frame::request(id, image.to_vec()))
            .context("write request frame")?;
        Ok(id)
    }

    /// Block for the next reply frame (response or typed denial).
    pub fn recv(&mut self) -> Result<NetReply, FrameError> {
        let frame = read_frame(&mut self.reader, DEFAULT_MAX_PAYLOAD)?;
        match frame.kind {
            FrameKind::Response => Ok(NetReply::Response(decode_response(
                frame.id,
                &frame.payload,
            )?)),
            FrameKind::Error => {
                let (code, message) = decode_error(&frame.payload)?;
                Ok(NetReply::Denied {
                    id: frame.id,
                    code,
                    message,
                })
            }
            FrameKind::Request => Err(FrameError::Malformed(
                "server sent a request frame".into(),
            )),
            FrameKind::Stats => Err(FrameError::Malformed(
                "unexpected stats frame while awaiting a classify reply".into(),
            )),
        }
    }

    /// Query the server's metrics exposition (a `Stats` frame exchange):
    /// returns the JSON snapshot string. Requires no classify submissions
    /// in flight on this connection — the next frame must be our reply.
    pub fn stats(&mut self) -> Result<String> {
        let id = self.next_id;
        self.next_id += 1;
        write_frame(&mut self.writer, &Frame::stats_request(id)).context("write stats frame")?;
        let frame = read_frame(&mut self.reader, DEFAULT_MAX_PAYLOAD)?;
        if frame.kind != FrameKind::Stats || frame.id != id {
            bail!("expected stats reply {id}, got {:?} id {}", frame.kind, frame.id);
        }
        Ok(decode_stats(&frame.payload)?)
    }

    /// Synchronous convenience: one request, one reply; denials become
    /// errors. Requires no other submissions in flight on this connection.
    pub fn classify(&mut self, image: &[u8]) -> Result<WireResponse> {
        let id = self.submit(image)?;
        match self.recv()? {
            NetReply::Response(resp) if resp.id == id => Ok(resp),
            NetReply::Response(resp) => bail!(
                "reply id {} does not match request {id} (pipelined submissions pending?)",
                resp.id
            ),
            NetReply::Denied { code, message, .. } => {
                bail!("request {id} denied: {code}: {message}")
            }
        }
    }

    /// Pipelined classify: keep up to `window` requests in flight, reading
    /// the oldest reply as new requests are written. Replies come back in
    /// submission order, one per input (denials included in place).
    pub fn classify_pipelined(
        &mut self,
        images: impl IntoIterator<Item = Vec<u8>>,
        window: usize,
    ) -> Result<Vec<NetReply>> {
        let window = window.max(1);
        let mut out = Vec::new();
        let mut inflight = 0usize;
        for img in images {
            self.submit(&img)?;
            inflight += 1;
            if inflight >= window {
                out.push(self.recv()?);
                inflight -= 1;
            }
        }
        for _ in 0..inflight {
            out.push(self.recv()?);
        }
        Ok(out)
    }
}

/// Bounded-retry policy for [`ResilientClient`]. The backoff schedule is
/// fully determined by `seed`: attempt `k` sleeps a jittered
/// `base_backoff * 2^k` capped at `max_backoff`, with the jitter drawn from
/// `testkit::rng` so two clients with the same seed back off identically
/// (and two with different seeds do not stampede in phase).
#[derive(Debug, Clone)]
pub struct RetryPolicy {
    /// Total tries per request (first attempt included); at least 1.
    pub max_attempts: u32,
    /// Backoff before the first retry.
    pub base_backoff: Duration,
    /// Ceiling the exponential schedule saturates at.
    pub max_backoff: Duration,
    /// Seed for the jitter stream.
    pub seed: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy {
            max_attempts: 4,
            base_backoff: Duration::from_millis(1),
            max_backoff: Duration::from_millis(100),
            seed: 7,
        }
    }
}

/// Classification of one failed attempt: retry (after backoff) or give up.
enum TryError {
    Retry(anyhow::Error),
    Fatal(anyhow::Error),
}

/// A self-healing connection: lazily dials on first use, redials after
/// resets/draining, retries `Overloaded` with deterministic backoff, and
/// enforces an optional end-to-end deadline per `classify` call (submit +
/// recv + every retry and backoff in between), so a caller never hangs on
/// a wedged server. See the module docs for the full retry taxonomy.
pub struct ResilientClient {
    addr: String,
    policy: RetryPolicy,
    deadline: Option<Duration>,
    conn: Option<NetClient>,
    rng: Rng,
    connected_once: bool,
    retries: u64,
    reconnects: u64,
    trace: Option<Arc<TraceCollector>>,
}

impl ResilientClient {
    /// No I/O happens here: the first `classify` dials.
    pub fn new(addr: &str, policy: RetryPolicy) -> ResilientClient {
        let seed = policy.seed;
        ResilientClient {
            addr: addr.to_string(),
            policy,
            deadline: None,
            conn: None,
            rng: Rng::new(seed),
            connected_once: false,
            retries: 0,
            reconnects: 0,
            trace: None,
        }
    }

    /// End-to-end budget per `classify` call; expiry is a *fatal* typed
    /// error (retrying past a blown deadline helps nobody).
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        self.deadline = Some(deadline);
        self
    }

    /// Attach a trace collector: each retry records a `client_retry`
    /// instant event on the collector's network lane. Share the server's
    /// collector to see retries interleaved with the spans they re-drive.
    pub fn with_trace(mut self, trace: Arc<TraceCollector>) -> Self {
        self.trace = Some(trace);
        self
    }

    /// Retries performed so far (attempts beyond each request's first).
    pub fn retries(&self) -> u64 {
        self.retries
    }

    /// Times the connection was re-established after being lost.
    pub fn reconnects(&self) -> u64 {
        self.reconnects
    }

    /// Chaos/test hook: drop the current connection as if the peer reset
    /// it. The next `classify` transparently redials.
    pub fn break_connection(&mut self) {
        self.conn = None;
    }

    /// Jittered exponential backoff before retry number `attempt` (0-based):
    /// uniformly in `[d/2, d]` for `d = min(base * 2^attempt, cap)`.
    fn backoff(&mut self, attempt: u32) -> Duration {
        let base = self.policy.base_backoff.as_secs_f64();
        let cap = self.policy.max_backoff.as_secs_f64();
        let d = (base * 2f64.powi(attempt.min(30) as i32)).min(cap);
        Duration::from_secs_f64(d / 2.0 + self.rng.f64_unit() * (d / 2.0))
    }

    fn ensure_connected(&mut self) -> Result<(), TryError> {
        if self.conn.is_some() {
            return Ok(());
        }
        match NetClient::connect(&self.addr) {
            Ok(c) => {
                if self.connected_once {
                    self.reconnects += 1;
                }
                self.connected_once = true;
                self.conn = Some(c);
                Ok(())
            }
            // Refused/unreachable is retryable: the server may be mid-
            // restart (the supervision story on the spine side).
            Err(e) => Err(TryError::Retry(e)),
        }
    }

    fn try_once(
        &mut self,
        image: &[u8],
        time_left: Option<Duration>,
    ) -> Result<WireResponse, TryError> {
        self.ensure_connected()?;
        let conn = self.conn.as_mut().expect("ensured above");
        if conn.set_read_timeout(time_left).is_err() {
            // A socket we cannot arm a deadline on cannot honor the
            // contract; treat it like a reset.
            self.conn = None;
            return Err(TryError::Retry(anyhow!("could not set read deadline")));
        }
        let id = match conn.submit(image) {
            Ok(id) => id,
            Err(e) => {
                self.conn = None;
                return Err(TryError::Retry(e.context("submit")));
            }
        };
        match conn.recv() {
            Ok(NetReply::Response(resp)) if resp.id == id => Ok(resp),
            Ok(NetReply::Response(resp)) => {
                // Stream delivered somebody else's reply: state bug, not a
                // transient; drop the connection and give up.
                self.conn = None;
                Err(TryError::Fatal(anyhow!(
                    "reply id {} does not match request {id}",
                    resp.id
                )))
            }
            Ok(NetReply::Denied { code, message, .. }) => match code {
                // Shed at admission: nothing enqueued, connection fine.
                ErrCode::Overloaded => {
                    Err(TryError::Retry(anyhow!("request {id} denied: {code}: {message}")))
                }
                // The server is going away; redial (possibly its restart).
                ErrCode::Draining => {
                    self.conn = None;
                    Err(TryError::Retry(anyhow!("request {id} denied: {code}: {message}")))
                }
                ErrCode::BadRequest | ErrCode::Internal => {
                    Err(TryError::Fatal(anyhow!("request {id} denied: {code}: {message}")))
                }
            },
            Err(FrameError::TimedOut) => {
                // The read deadline expired mid-wait: the end-to-end budget
                // is gone, and the stream may hold a half-read frame.
                self.conn = None;
                Err(TryError::Fatal(anyhow!("request {id}: deadline exceeded")))
            }
            Err(e) => {
                // Closed / reset / truncated mid-flight: redial and retry.
                self.conn = None;
                Err(TryError::Retry(anyhow::Error::from(e).context("recv")))
            }
        }
    }

    /// Submit one image and wait for its reply, healing transient failures
    /// along the way. Never hangs: with a deadline set, the call returns a
    /// typed error once the budget is spent; without one, it returns after
    /// `max_attempts` tries.
    pub fn classify(&mut self, image: &[u8]) -> Result<WireResponse> {
        #[allow(clippy::disallowed_methods)] // wall-clock: end-to-end request deadline
        let started = std::time::Instant::now();
        let budget = |started: std::time::Instant, deadline: Option<Duration>| match deadline {
            None => Some(None),
            Some(d) => {
                let left = d.saturating_sub(started.elapsed());
                if left.is_zero() {
                    None // spent
                } else {
                    Some(Some(left))
                }
            }
        };
        let mut last_err = None;
        for attempt in 0..self.policy.max_attempts.max(1) {
            if attempt > 0 {
                self.retries += 1;
                if let Some(t) = &self.trace {
                    let tick = t.next_wire_tick();
                    let detail = format!("attempt {attempt}");
                    t.event(t.net_lane(), EventKind::ClientRetry, tick, None, detail);
                }
                let delay = self.backoff(attempt - 1);
                #[allow(clippy::disallowed_methods)] // wall-clock: retry backoff delay
                match budget(started, self.deadline) {
                    None => break,
                    Some(None) => std::thread::sleep(delay),
                    // Never sleep past the deadline.
                    Some(Some(left)) => std::thread::sleep(delay.min(left)),
                }
            }
            let time_left = match budget(started, self.deadline) {
                None => break,
                Some(t) => t,
            };
            match self.try_once(image, time_left) {
                Ok(resp) => return Ok(resp),
                Err(TryError::Fatal(e)) => return Err(e),
                Err(TryError::Retry(e)) => last_err = Some(e),
            }
        }
        match last_err {
            Some(e) => Err(e.context(format!(
                "request failed after {} attempt(s)",
                self.policy.max_attempts.max(1)
            ))),
            None => Err(anyhow!(
                "deadline {:?} exceeded before the first attempt",
                self.deadline.unwrap_or_default()
            )),
        }
    }
}

/// A round-robin pool of [`ResilientClient`]s over one address: `classify`
/// rotates through the members, each healing its own connection. Member
/// jitter streams are derived from the base seed so the pool's backoff
/// schedule is deterministic yet decorrelated across connections.
pub struct NetClientPool {
    clients: Vec<ResilientClient>,
    next: usize,
}

impl NetClientPool {
    pub fn new(addr: &str, size: usize, policy: RetryPolicy) -> NetClientPool {
        let clients = (0..size.max(1))
            .map(|i| {
                let member = RetryPolicy {
                    seed: policy
                        .seed
                        .wrapping_add((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)),
                    ..policy.clone()
                };
                ResilientClient::new(addr, member)
            })
            .collect();
        NetClientPool { clients, next: 0 }
    }

    /// Apply one end-to-end deadline to every member.
    pub fn with_deadline(mut self, deadline: Duration) -> Self {
        for c in &mut self.clients {
            c.deadline = Some(deadline);
        }
        self
    }

    /// Share one trace collector across every member (see
    /// [`ResilientClient::with_trace`]).
    pub fn with_trace(mut self, trace: Arc<TraceCollector>) -> Self {
        for c in &mut self.clients {
            c.trace = Some(trace.clone());
        }
        self
    }

    pub fn size(&self) -> usize {
        self.clients.len()
    }

    /// Total retries across the pool.
    pub fn retries(&self) -> u64 {
        self.clients.iter().map(|c| c.retries()).sum()
    }

    /// Total reconnects across the pool.
    pub fn reconnects(&self) -> u64 {
        self.clients.iter().map(|c| c.reconnects()).sum()
    }

    /// Chaos/test hook: drop every member's connection.
    pub fn break_connections(&mut self) {
        for c in &mut self.clients {
            c.break_connection();
        }
    }

    /// Classify on the next member in rotation.
    pub fn classify(&mut self, image: &[u8]) -> Result<WireResponse> {
        let i = self.next;
        self.next = (self.next + 1) % self.clients.len();
        self.clients[i].classify(image)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_deterministic_jittered_and_capped() {
        let mut a = ResilientClient::new("127.0.0.1:1", RetryPolicy::default());
        let mut b = ResilientClient::new("127.0.0.1:1", RetryPolicy::default());
        let da: Vec<Duration> = (0..8).map(|i| a.backoff(i)).collect();
        let db: Vec<Duration> = (0..8).map(|i| b.backoff(i)).collect();
        assert_eq!(da, db, "same seed must yield the same schedule");
        let pol = RetryPolicy::default();
        for (i, d) in da.iter().enumerate() {
            let nominal = pol
                .base_backoff
                .mul_f64(2f64.powi(i as i32))
                .min(pol.max_backoff);
            assert!(*d <= nominal, "jitter only shrinks the delay: {d:?} vs {nominal:?}");
            assert!(
                *d >= nominal.mul_f64(0.5),
                "jitter floor is half the nominal delay"
            );
            assert!(*d <= pol.max_backoff, "cap must hold");
        }
        let mut c = ResilientClient::new(
            "127.0.0.1:1",
            RetryPolicy {
                seed: 8,
                ..Default::default()
            },
        );
        let dc: Vec<Duration> = (0..8).map(|i| c.backoff(i)).collect();
        assert_ne!(da, dc, "different seeds must decorrelate");
    }

    #[test]
    fn pool_members_get_distinct_jitter_seeds() {
        let pool = NetClientPool::new("127.0.0.1:1", 3, RetryPolicy::default());
        assert_eq!(pool.size(), 3);
        let seeds: Vec<u64> = pool.clients.iter().map(|c| c.policy.seed).collect();
        let mut deduped = seeds.clone();
        deduped.sort_unstable();
        deduped.dedup();
        assert_eq!(deduped.len(), 3, "seeds must differ: {seeds:?}");
        assert_eq!(seeds[0], RetryPolicy::default().seed, "member 0 keeps the base seed");
    }

    #[test]
    fn unreachable_address_fails_bounded_not_hanging() {
        // Port 1 on loopback refuses immediately; every attempt is a
        // retryable connect failure, so classify returns Err after
        // max_attempts instead of hanging.
        let mut c = ResilientClient::new(
            "127.0.0.1:1",
            RetryPolicy {
                max_attempts: 2,
                base_backoff: Duration::from_micros(100),
                max_backoff: Duration::from_micros(200),
                ..Default::default()
            },
        );
        assert!(c.classify(&[0u8; 4]).is_err());
        assert_eq!(c.retries(), 1, "one retry after the first failed attempt");
        assert_eq!(c.reconnects(), 0, "never connected, so nothing re-connected");
    }
}
