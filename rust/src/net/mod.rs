//! Network front end: a hand-rolled length-prefixed wire protocol over TCP
//! that puts a socket in front of the in-process serving spine.
//!
//! The serving stack's layers, outermost first:
//!
//! * [`frame`] — the wire format: 18-byte header (magic, version, kind,
//!   request id, length) + payload; typed [`FrameError`]s, never panics on
//!   wire input.
//! * [`NetServer`] — acceptor + per-connection reader/writer threads with
//!   aggregate admission control (shed-at-depth with a typed
//!   [`ErrCode::Overloaded`] reply), bounded per-connection in-flight
//!   windows, and graceful drain on shutdown.
//! * [`NetClient`] — the blocking client twin: submit/recv, pipelined
//!   classify, typed [`NetReply::Denied`] surfaces for shed requests.
//! * [`ResilientClient`] / [`NetClientPool`] — the failure-policy layer on
//!   top of `NetClient`: bounded retries with deterministic backoff +
//!   jitter ([`RetryPolicy`]), redial after resets/draining, and an
//!   end-to-end per-request deadline (see `docs/robustness.md`).
//!
//! Everything is `std`-only (vendored-offline: no tokio/serde); see
//! `docs/networking.md` for the protocol contract and
//! `rust/src/loadgen/` for the open-loop load model that drives it.

pub mod client;
pub mod frame;
pub mod server;

pub use client::{NetClient, NetClientPool, NetReply, ResilientClient, RetryPolicy};
pub use frame::{
    decode_error, decode_response, decode_stats, encode_error, encode_response, read_frame,
    write_frame, ErrCode, Frame, FrameError, FrameKind, WireResponse, DEFAULT_MAX_PAYLOAD,
    HEADER_LEN, MAGIC, VERSION,
};
pub use server::{NetServer, NetServerConfig, NetStats};
