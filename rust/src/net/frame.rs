//! Wire format: length-prefixed binary frames (hand-rolled — offline, no
//! serde/tokio).
//!
//! Every frame is an 18-byte header followed by `len` payload bytes, all
//! integers big-endian:
//!
//! ```text
//! +-------+---------+------+--------+--------+-----------------+
//! | magic | version | kind |   id   |  len   |     payload     |
//! | 4B    | 1B      | 1B   | 8B BE  | 4B BE  |    len bytes    |
//! +-------+---------+------+--------+--------+-----------------+
//! ```
//!
//! * magic is `b"O2HW"`; version is [`VERSION`]. Anything else is a typed
//!   [`FrameError`], never a panic — garbage on the socket must not take a
//!   serving thread down.
//! * `id` is chosen by the client and echoed verbatim on the reply, so a
//!   pipelined client can match responses to requests (per-connection
//!   ordering is also guaranteed by the server, but ids survive reordering
//!   across future transports).
//! * `kind` selects the payload codec: [`FrameKind::Request`] carries raw
//!   HWC u8 image codes, [`FrameKind::Response`] a [`WireResponse`], and
//!   [`FrameKind::Error`] an [`ErrCode`] + UTF-8 message.
//!
//! [`read_frame`] distinguishes a clean close (EOF *between* frames →
//! [`FrameError::Closed`]) from a truncated one (EOF *inside* a frame →
//! [`FrameError::Truncated`]); oversize length prefixes are rejected before
//! any allocation. See `docs/networking.md` for the full protocol contract.

use std::fmt;
use std::io::{Read, Write};

/// Frame preamble: "O2HW".
pub const MAGIC: [u8; 4] = *b"O2HW";
/// Protocol version carried in every frame header.
pub const VERSION: u8 = 1;
/// Fixed header size: magic(4) + version(1) + kind(1) + id(8) + len(4).
pub const HEADER_LEN: usize = 18;
/// Default payload ceiling (1 MiB) — far above any model input; a length
/// prefix beyond the limit is rejected before allocating.
pub const DEFAULT_MAX_PAYLOAD: usize = 1 << 20;

/// What a frame carries; the `kind` byte on the wire.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Client -> server: payload is the raw image bytes.
    Request,
    /// Server -> client: payload is an encoded [`WireResponse`].
    Response,
    /// Server -> client: payload is an [`ErrCode`] + UTF-8 message.
    Error,
    /// Stats exchange: a client sends a `Stats` frame with an empty payload
    /// and the server echoes the id back with the unified metrics-registry
    /// snapshot as UTF-8 JSON (see `docs/observability.md`).
    Stats,
}

impl FrameKind {
    fn code(self) -> u8 {
        match self {
            FrameKind::Request => 1,
            FrameKind::Response => 2,
            FrameKind::Error => 3,
            FrameKind::Stats => 4,
        }
    }

    fn from_code(code: u8) -> Option<FrameKind> {
        match code {
            1 => Some(FrameKind::Request),
            2 => Some(FrameKind::Response),
            3 => Some(FrameKind::Error),
            4 => Some(FrameKind::Stats),
            _ => None,
        }
    }
}

/// One decoded frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Frame {
    pub kind: FrameKind,
    pub id: u64,
    pub payload: Vec<u8>,
}

impl Frame {
    pub fn request(id: u64, image: Vec<u8>) -> Frame {
        Frame {
            kind: FrameKind::Request,
            id,
            payload: image,
        }
    }

    pub fn response(resp: &WireResponse) -> Frame {
        Frame {
            kind: FrameKind::Response,
            id: resp.id,
            payload: encode_response(resp),
        }
    }

    pub fn error(id: u64, code: ErrCode, message: &str) -> Frame {
        Frame {
            kind: FrameKind::Error,
            id,
            payload: encode_error(code, message),
        }
    }

    /// A client's stats query: empty payload, answered in kind.
    pub fn stats_request(id: u64) -> Frame {
        Frame {
            kind: FrameKind::Stats,
            id,
            payload: Vec::new(),
        }
    }

    /// The server's stats answer: the metrics snapshot as UTF-8 JSON.
    pub fn stats_response(id: u64, json: String) -> Frame {
        Frame {
            kind: FrameKind::Stats,
            id,
            payload: json.into_bytes(),
        }
    }
}

/// Decode a stats answer's payload (UTF-8 JSON text).
pub fn decode_stats(payload: &[u8]) -> Result<String, FrameError> {
    std::str::from_utf8(payload)
        .map(str::to_string)
        .map_err(|e| FrameError::Malformed(format!("stats not UTF-8: {e}")))
}

/// Typed error reply codes (the first two payload bytes of an error frame).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ErrCode {
    /// Admission control shed the request: the aggregate in-flight depth is
    /// at the configured limit. Back off and retry; nothing was enqueued.
    Overloaded,
    /// The frame or payload was malformed (bad magic/kind, wrong image
    /// size, unparsable payload).
    BadRequest,
    /// The server is draining for shutdown and no longer admits work.
    Draining,
    /// The request was admitted but the serving spine dropped it (e.g.
    /// shutdown raced the in-flight batch).
    Internal,
}

impl ErrCode {
    fn code(self) -> u16 {
        match self {
            ErrCode::Overloaded => 1,
            ErrCode::BadRequest => 2,
            ErrCode::Draining => 3,
            ErrCode::Internal => 4,
        }
    }

    fn from_code(code: u16) -> Option<ErrCode> {
        match code {
            1 => Some(ErrCode::Overloaded),
            2 => Some(ErrCode::BadRequest),
            3 => Some(ErrCode::Draining),
            4 => Some(ErrCode::Internal),
            _ => None,
        }
    }
}

impl fmt::Display for ErrCode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            ErrCode::Overloaded => "overloaded",
            ErrCode::BadRequest => "bad-request",
            ErrCode::Draining => "draining",
            ErrCode::Internal => "internal",
        };
        write!(f, "{s}")
    }
}

/// Typed framing/decoding failures. Every variant is an expected,
/// recoverable condition for the peer that observes it — the protocol
/// layer never panics on wire input.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameError {
    /// Clean EOF at a frame boundary: the peer closed the connection.
    Closed,
    /// The 4 preamble bytes were not `b"O2HW"`.
    BadMagic([u8; 4]),
    /// Unknown protocol version.
    BadVersion(u8),
    /// Unknown `kind` byte.
    UnknownKind(u8),
    /// The length prefix exceeds the receiver's payload ceiling; rejected
    /// before allocating.
    Oversize { len: usize, max: usize },
    /// EOF in the middle of a frame (header or payload).
    Truncated { wanted: usize, got: usize },
    /// A payload codec found structurally invalid bytes.
    Malformed(String),
    /// A read deadline (socket read timeout) expired mid-frame. The stream
    /// may be desynchronized — a header or payload could be half-read — so
    /// the connection must be dropped and redialed, never reused.
    TimedOut,
    /// Transport-level I/O failure (reset, broken pipe, ...).
    Io(String),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Closed => write!(f, "connection closed"),
            FrameError::BadMagic(m) => write!(f, "bad magic {m:?} (want {MAGIC:?})"),
            FrameError::BadVersion(v) => write!(f, "unsupported protocol version {v}"),
            FrameError::UnknownKind(k) => write!(f, "unknown frame kind {k}"),
            FrameError::Oversize { len, max } => {
                write!(f, "payload length {len} exceeds the {max}-byte limit")
            }
            FrameError::Truncated { wanted, got } => {
                write!(f, "truncated frame: wanted {wanted} bytes, got {got}")
            }
            FrameError::Malformed(msg) => write!(f, "malformed payload: {msg}"),
            FrameError::TimedOut => write!(f, "read timed out"),
            FrameError::Io(msg) => write!(f, "i/o error: {msg}"),
        }
    }
}

impl std::error::Error for FrameError {}

impl From<std::io::Error> for FrameError {
    fn from(e: std::io::Error) -> Self {
        match e.kind() {
            // Both kinds signal an expired socket read deadline (which one
            // depends on the platform); surface them as the typed variant
            // so the resilient client can tell "deadline" from "reset".
            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut => FrameError::TimedOut,
            _ => FrameError::Io(e.to_string()),
        }
    }
}

/// Fill `buf`, tolerating short reads; returns the bytes actually read
/// (short only on EOF). Interrupted reads are retried.
fn read_full(r: &mut impl Read, buf: &mut [u8]) -> Result<usize, FrameError> {
    let mut got = 0;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => break,
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(e.into()),
        }
    }
    Ok(got)
}

/// Read and validate one frame. EOF before the first header byte is the
/// clean [`FrameError::Closed`]; EOF anywhere inside a frame is
/// [`FrameError::Truncated`]. A frame whose length prefix exceeds
/// `max_payload` errs without allocating.
pub fn read_frame(r: &mut impl Read, max_payload: usize) -> Result<Frame, FrameError> {
    let mut header = [0u8; HEADER_LEN];
    let got = read_full(r, &mut header)?;
    if got == 0 {
        return Err(FrameError::Closed);
    }
    if got < HEADER_LEN {
        return Err(FrameError::Truncated {
            wanted: HEADER_LEN,
            got,
        });
    }
    if header[0..4] != MAGIC {
        let mut m = [0u8; 4];
        m.copy_from_slice(&header[0..4]);
        return Err(FrameError::BadMagic(m));
    }
    if header[4] != VERSION {
        return Err(FrameError::BadVersion(header[4]));
    }
    let kind = FrameKind::from_code(header[5]).ok_or(FrameError::UnknownKind(header[5]))?;
    let id = u64::from_be_bytes(header[6..14].try_into().expect("8-byte slice"));
    let len = u32::from_be_bytes(header[14..18].try_into().expect("4-byte slice")) as usize;
    if len > max_payload {
        return Err(FrameError::Oversize {
            len,
            max: max_payload,
        });
    }
    let mut payload = vec![0u8; len];
    let got = read_full(r, &mut payload)?;
    if got < len {
        return Err(FrameError::Truncated { wanted: len, got });
    }
    Ok(Frame { kind, id, payload })
}

/// Write one frame and flush it onto the wire.
pub fn write_frame(w: &mut impl Write, frame: &Frame) -> std::io::Result<()> {
    let mut header = [0u8; HEADER_LEN];
    header[0..4].copy_from_slice(&MAGIC);
    header[4] = VERSION;
    header[5] = frame.kind.code();
    header[6..14].copy_from_slice(&frame.id.to_be_bytes());
    header[14..18].copy_from_slice(&(frame.payload.len() as u32).to_be_bytes());
    w.write_all(&header)?;
    w.write_all(&frame.payload)?;
    w.flush()
}

/// A classification reply as carried on the wire (mirror of the in-process
/// `ClassifyResponse`, minus the reply channel).
#[derive(Debug, Clone, PartialEq)]
pub struct WireResponse {
    /// Echo of the request frame's id.
    pub id: u64,
    pub pred: u32,
    /// Worker shard that executed the batch.
    pub shard: u32,
    /// End-to-end server-side latency (queue + batch + execute).
    pub latency_us: u64,
    /// Profile that served the request.
    pub profile: String,
    /// Raw logits; f32 bit patterns travel verbatim so the bit-exactness
    /// contract survives the wire.
    pub logits: Vec<f32>,
}

/// Response payload: pred u32 | shard u32 | latency_us u64 | profile_len
/// u16 + UTF-8 | n_logits u32 | f32 bit patterns (u32 each), all BE.
pub fn encode_response(resp: &WireResponse) -> Vec<u8> {
    let mut p = Vec::with_capacity(22 + resp.profile.len() + 4 * resp.logits.len());
    p.extend_from_slice(&resp.pred.to_be_bytes());
    p.extend_from_slice(&resp.shard.to_be_bytes());
    p.extend_from_slice(&resp.latency_us.to_be_bytes());
    p.extend_from_slice(&(resp.profile.len() as u16).to_be_bytes());
    p.extend_from_slice(resp.profile.as_bytes());
    p.extend_from_slice(&(resp.logits.len() as u32).to_be_bytes());
    for l in &resp.logits {
        p.extend_from_slice(&l.to_bits().to_be_bytes());
    }
    p
}

/// Bounds-checked cursor step for the payload decoders.
fn take<'a>(p: &mut &'a [u8], n: usize, what: &str) -> Result<&'a [u8], FrameError> {
    if p.len() < n {
        return Err(FrameError::Malformed(format!(
            "{what}: need {n} bytes, have {}",
            p.len()
        )));
    }
    let (head, rest) = p.split_at(n);
    *p = rest;
    Ok(head)
}

pub fn decode_response(id: u64, payload: &[u8]) -> Result<WireResponse, FrameError> {
    let mut p = payload;
    let pred = u32::from_be_bytes(take(&mut p, 4, "pred")?.try_into().expect("4B"));
    let shard = u32::from_be_bytes(take(&mut p, 4, "shard")?.try_into().expect("4B"));
    let latency_us = u64::from_be_bytes(take(&mut p, 8, "latency")?.try_into().expect("8B"));
    let plen = u16::from_be_bytes(take(&mut p, 2, "profile len")?.try_into().expect("2B"));
    let profile = std::str::from_utf8(take(&mut p, plen as usize, "profile")?)
        .map_err(|e| FrameError::Malformed(format!("profile not UTF-8: {e}")))?
        .to_string();
    let n = u32::from_be_bytes(take(&mut p, 4, "logit count")?.try_into().expect("4B")) as usize;
    if p.len() != 4 * n {
        return Err(FrameError::Malformed(format!(
            "logits: {n} declared but {} payload bytes remain",
            p.len()
        )));
    }
    let mut logits = Vec::with_capacity(n);
    for _ in 0..n {
        let bits = u32::from_be_bytes(take(&mut p, 4, "logit")?.try_into().expect("4B"));
        logits.push(f32::from_bits(bits));
    }
    Ok(WireResponse {
        id,
        pred,
        shard,
        latency_us,
        profile,
        logits,
    })
}

/// Error payload: code u16 | msg_len u16 | UTF-8 message, BE.
pub fn encode_error(code: ErrCode, message: &str) -> Vec<u8> {
    // Truncate over-long messages on a char boundary so the bytes stay
    // valid UTF-8 for the decoder.
    let mut cut = message.len().min(u16::MAX as usize);
    while !message.is_char_boundary(cut) {
        cut -= 1;
    }
    let msg = &message.as_bytes()[..cut];
    let mut p = Vec::with_capacity(4 + msg.len());
    p.extend_from_slice(&code.code().to_be_bytes());
    p.extend_from_slice(&(msg.len() as u16).to_be_bytes());
    p.extend_from_slice(msg);
    p
}

pub fn decode_error(payload: &[u8]) -> Result<(ErrCode, String), FrameError> {
    let mut p = payload;
    let raw = u16::from_be_bytes(take(&mut p, 2, "error code")?.try_into().expect("2B"));
    let code = ErrCode::from_code(raw)
        .ok_or_else(|| FrameError::Malformed(format!("unknown error code {raw}")))?;
    let mlen = u16::from_be_bytes(take(&mut p, 2, "message len")?.try_into().expect("2B"));
    let message = std::str::from_utf8(take(&mut p, mlen as usize, "message")?)
        .map_err(|e| FrameError::Malformed(format!("message not UTF-8: {e}")))?
        .to_string();
    Ok((code, message))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        read_frame(&mut Cursor::new(buf), DEFAULT_MAX_PAYLOAD).unwrap()
    }

    #[test]
    fn request_frame_round_trips() {
        let f = Frame::request(42, vec![1, 2, 3, 255, 0]);
        assert_eq!(roundtrip(&f), f);
    }

    #[test]
    fn response_payload_round_trips_bit_exact() {
        let resp = WireResponse {
            id: 7,
            pred: 3,
            shard: 1,
            latency_us: 1234,
            profile: "A8-W8".into(),
            // includes values that would not survive a text round-trip
            logits: vec![0.1, -0.0, f32::MIN_POSITIVE, 1.0e30, -42.5],
        };
        let f = roundtrip(&Frame::response(&resp));
        assert_eq!(f.kind, FrameKind::Response);
        let back = decode_response(f.id, &f.payload).unwrap();
        assert_eq!(back, resp);
        for (a, b) in back.logits.iter().zip(&resp.logits) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn stats_frames_round_trip() {
        let q = roundtrip(&Frame::stats_request(11));
        assert_eq!(q.kind, FrameKind::Stats);
        assert_eq!(q.id, 11);
        assert!(q.payload.is_empty());
        let a = roundtrip(&Frame::stats_response(11, "{\"counters\":{}}".into()));
        assert_eq!(a.kind, FrameKind::Stats);
        assert_eq!(decode_stats(&a.payload).unwrap(), "{\"counters\":{}}");
        assert!(matches!(
            decode_stats(&[0xFF, 0xFE]),
            Err(FrameError::Malformed(_))
        ));
    }

    #[test]
    fn error_payload_round_trips() {
        let f = roundtrip(&Frame::error(9, ErrCode::Overloaded, "queue full"));
        assert_eq!(f.kind, FrameKind::Error);
        let (code, msg) = decode_error(&f.payload).unwrap();
        assert_eq!(code, ErrCode::Overloaded);
        assert_eq!(msg, "queue full");
    }

    #[test]
    fn clean_eof_is_closed_mid_frame_is_truncated() {
        let err = read_frame(&mut Cursor::new(Vec::new()), 64).unwrap_err();
        assert_eq!(err, FrameError::Closed);

        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::request(1, vec![0; 16])).unwrap();
        // header cut short
        let err = read_frame(&mut Cursor::new(&buf[..9]), 64).unwrap_err();
        assert_eq!(
            err,
            FrameError::Truncated {
                wanted: HEADER_LEN,
                got: 9
            }
        );
        // payload cut short
        let err = read_frame(&mut Cursor::new(&buf[..HEADER_LEN + 5]), 64).unwrap_err();
        assert_eq!(err, FrameError::Truncated { wanted: 16, got: 5 });
    }

    #[test]
    fn garbage_magic_version_kind_are_typed() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::request(1, vec![7; 4])).unwrap();

        let mut bad = buf.clone();
        bad[0..4].copy_from_slice(b"HTTP");
        let err = read_frame(&mut Cursor::new(bad), 64).unwrap_err();
        assert_eq!(err, FrameError::BadMagic(*b"HTTP"));

        let mut bad = buf.clone();
        bad[4] = 99;
        let err = read_frame(&mut Cursor::new(bad), 64).unwrap_err();
        assert_eq!(err, FrameError::BadVersion(99));

        let mut bad = buf.clone();
        bad[5] = 0;
        let err = read_frame(&mut Cursor::new(bad), 64).unwrap_err();
        assert_eq!(err, FrameError::UnknownKind(0));
    }

    #[test]
    fn oversize_length_prefix_rejected_before_allocation() {
        let mut header = [0u8; HEADER_LEN];
        header[0..4].copy_from_slice(&MAGIC);
        header[4] = VERSION;
        header[5] = 1;
        header[14..18].copy_from_slice(&u32::MAX.to_be_bytes());
        let err = read_frame(&mut Cursor::new(header.to_vec()), 1 << 20).unwrap_err();
        assert_eq!(
            err,
            FrameError::Oversize {
                len: u32::MAX as usize,
                max: 1 << 20
            }
        );
    }

    #[test]
    fn malformed_payloads_are_typed_not_panics() {
        assert!(matches!(
            decode_response(0, &[1, 2, 3]),
            Err(FrameError::Malformed(_))
        ));
        assert!(matches!(decode_error(&[9]), Err(FrameError::Malformed(_))));
        // declared logit count larger than the remaining bytes
        let resp = WireResponse {
            id: 0,
            pred: 0,
            shard: 0,
            latency_us: 0,
            profile: "p".into(),
            logits: vec![1.0],
        };
        let mut p = encode_response(&resp);
        let cnt_at = 4 + 4 + 8 + 2 + 1;
        p[cnt_at..cnt_at + 4].copy_from_slice(&100u32.to_be_bytes());
        assert!(matches!(
            decode_response(0, &p),
            Err(FrameError::Malformed(_))
        ));
        // non-UTF-8 profile bytes
        let mut p = encode_response(&resp);
        p[4 + 4 + 8 + 2] = 0xFF;
        assert!(matches!(
            decode_response(0, &p),
            Err(FrameError::Malformed(_))
        ));
    }

    #[test]
    fn io_timeouts_map_to_the_typed_variant() {
        let t = std::io::Error::new(std::io::ErrorKind::TimedOut, "t");
        assert_eq!(FrameError::from(t), FrameError::TimedOut);
        let w = std::io::Error::new(std::io::ErrorKind::WouldBlock, "w");
        assert_eq!(FrameError::from(w), FrameError::TimedOut);
        let r = std::io::Error::new(std::io::ErrorKind::ConnectionReset, "r");
        assert!(matches!(FrameError::from(r), FrameError::Io(_)));
    }

    #[test]
    fn error_message_truncates_at_u16() {
        let long = "x".repeat(80_000);
        let payload = encode_error(ErrCode::Internal, &long);
        let (code, msg) = decode_error(&payload).unwrap();
        assert_eq!(code, ErrCode::Internal);
        assert_eq!(msg.len(), u16::MAX as usize);
    }
}
