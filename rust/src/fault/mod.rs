//! Deterministic fault injection: seeded, wall-clock-free chaos plans.
//!
//! A [`FaultPlan`] is generated once from a seed and describes every fault a
//! chaos run will inject, on two clocks that both advance with *work*, never
//! wall time:
//!
//! * **Server faults** trigger on the pool-wide batch counter: the worker
//!   loop calls [`FaultInjector::on_batch`] once per popped batch, and when
//!   the counter passes an event's `at_batch` the event fires on its target
//!   shard — a [`ServerFaultKind::Panic`] (the worker thread panics with the
//!   in-hand batch, exercising the shard-death + supervision path in
//!   `coordinator/server.rs`) or a [`ServerFaultKind::BrownOut`] (the
//!   shard's battery is force-drained to empty *and then* the worker dies,
//!   modelling a power-loss reset; the supervisor revives it at
//!   `restart_fraction`, mirroring `power::CycleSimConfig`).
//! * **Wire faults** trigger on the client-side request index: the chaos
//!   driver consults [`FaultPlan::wire`] and, at the event's `at_request`,
//!   hard-kills every open connection ([`WireFaultKind::Reset`], via
//!   `NetServer::reset_connections`) or writes a deliberately corrupt frame
//!   on a fresh socket ([`WireFaultKind::Corrupt`]) and asserts the typed
//!   `BadRequest` + close contract.
//!
//! Because both clocks are virtual, the *plan* is byte-for-byte
//! reproducible: the same seed always yields the same events in the same
//! order ([`FaultPlan::to_json`] is what the `chaos_recovery` bench embeds
//! in `chaos.json`). Which *requests* a panic happens to take down still
//! depends on scheduling, so recovery gates assert seed-independent
//! invariants (every request resolves, gauges conserved, served fraction
//! above threshold) rather than exact casualty lists. See
//! `docs/robustness.md` for the full fault model.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::json::Value;
use crate::testkit::Rng;

/// A fault injected inside the serving spine, on the batch clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServerFaultKind {
    /// The worker thread panics mid-loop; the in-hand batch's tickets
    /// resolve `Err` and the shard goes through death + respawn.
    Panic,
    /// The shard's battery is force-drained to 0 J and the worker dies
    /// (power loss). On respawn the supervisor refills the cell to
    /// `ServerConfig::restart_fraction`, so the shard rejoins degraded.
    BrownOut,
}

/// A fault injected on the wire path, on the client request-index clock.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireFaultKind {
    /// Hard-kill every open connection mid-flight (both directions).
    Reset,
    /// Send a deliberately corrupt frame; the server must answer with a
    /// typed `BadRequest` and close only that connection.
    Corrupt,
}

/// One spine-side fault: fires once, on `shard`, when the pool-wide batch
/// counter reaches `at_batch`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ServerFaultEvent {
    pub at_batch: u64,
    pub shard: usize,
    pub kind: ServerFaultKind,
}

/// One wire-side fault: fires once, when the driver has submitted
/// `at_request` requests.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct WireFaultEvent {
    pub at_request: u64,
    pub kind: WireFaultKind,
}

/// Shape of a seeded plan: how many of each fault to scatter over the
/// batch/request horizons.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    /// Shards available as panic/brown-out targets.
    pub shards: usize,
    /// Server events trigger uniformly in `[1, horizon_batches]`.
    pub horizon_batches: u64,
    /// Wire events trigger uniformly in `[1, horizon_requests]`.
    pub horizon_requests: u64,
    pub panics: usize,
    pub brownouts: usize,
    pub resets: usize,
    pub corruptions: usize,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            shards: 4,
            horizon_batches: 32,
            horizon_requests: 256,
            panics: 2,
            brownouts: 2,
            resets: 2,
            corruptions: 1,
        }
    }
}

/// The full, deterministic chaos schedule for one run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FaultPlan {
    pub seed: u64,
    /// Spine faults, sorted by `(at_batch, shard)`.
    pub server: Vec<ServerFaultEvent>,
    /// Wire faults, sorted by `at_request`.
    pub wire: Vec<WireFaultEvent>,
}

impl FaultPlan {
    /// An empty plan (no faults): a chaos harness run as a plain load run.
    pub fn none(seed: u64) -> FaultPlan {
        FaultPlan {
            seed,
            server: Vec::new(),
            wire: Vec::new(),
        }
    }

    /// Scatter `spec`'s fault counts over the horizons with the seeded
    /// `testkit` RNG. Same seed + spec -> identical plan, always.
    pub fn seeded(seed: u64, spec: &FaultSpec) -> FaultPlan {
        assert!(spec.shards > 0, "fault plan needs at least one shard");
        let mut rng = Rng::new(seed);
        let mut server = Vec::with_capacity(spec.panics + spec.brownouts);
        for _ in 0..spec.panics {
            server.push(ServerFaultEvent {
                at_batch: rng.u64(1, spec.horizon_batches.max(1)),
                shard: rng.usize(0, spec.shards - 1),
                kind: ServerFaultKind::Panic,
            });
        }
        for _ in 0..spec.brownouts {
            server.push(ServerFaultEvent {
                at_batch: rng.u64(1, spec.horizon_batches.max(1)),
                shard: rng.usize(0, spec.shards - 1),
                kind: ServerFaultKind::BrownOut,
            });
        }
        server.sort_by_key(|e| (e.at_batch, e.shard));
        let mut wire = Vec::with_capacity(spec.resets + spec.corruptions);
        for _ in 0..spec.resets {
            wire.push(WireFaultEvent {
                at_request: rng.u64(1, spec.horizon_requests.max(1)),
                kind: WireFaultKind::Reset,
            });
        }
        for _ in 0..spec.corruptions {
            wire.push(WireFaultEvent {
                at_request: rng.u64(1, spec.horizon_requests.max(1)),
                kind: WireFaultKind::Corrupt,
            });
        }
        wire.sort_by_key(|e| e.at_request);
        FaultPlan { seed, server, wire }
    }

    /// The shards targeted by at least one brown-out (deduped, sorted).
    pub fn brownout_shards(&self) -> Vec<usize> {
        let mut s: Vec<usize> = self
            .server
            .iter()
            .filter(|e| e.kind == ServerFaultKind::BrownOut)
            .map(|e| e.shard)
            .collect();
        s.sort_unstable();
        s.dedup();
        s
    }

    /// Deterministic JSON description of the plan (embedded in
    /// `chaos.json`; same seed -> byte-identical output).
    pub fn to_json(&self) -> Value {
        let server: Vec<Value> = self
            .server
            .iter()
            .map(|e| {
                Value::obj(vec![
                    ("at_batch", (e.at_batch as i64).into()),
                    ("shard", e.shard.into()),
                    (
                        "kind",
                        match e.kind {
                            ServerFaultKind::Panic => "panic",
                            ServerFaultKind::BrownOut => "brownout",
                        }
                        .into(),
                    ),
                ])
            })
            .collect();
        let wire: Vec<Value> = self
            .wire
            .iter()
            .map(|e| {
                Value::obj(vec![
                    ("at_request", (e.at_request as i64).into()),
                    (
                        "kind",
                        match e.kind {
                            WireFaultKind::Reset => "reset",
                            WireFaultKind::Corrupt => "corrupt",
                        }
                        .into(),
                    ),
                ])
            })
            .collect();
        Value::obj(vec![
            ("seed", (self.seed as i64).into()),
            ("server", Value::Array(server)),
            ("wire", Value::Array(wire)),
        ])
    }

    /// Build the shared injector the serving spine consults per batch.
    pub fn injector(&self) -> FaultInjector {
        FaultInjector {
            events: Mutex::new(self.server.iter().map(|&e| (e, false)).collect()),
            batches: AtomicU64::new(0),
        }
    }
}

/// Shared trigger state for a plan's spine faults. One instance is handed
/// to `ServerConfig::faults`; every worker calls [`on_batch`] once per
/// popped batch and applies whatever fires. Each event fires exactly once.
///
/// [`on_batch`]: FaultInjector::on_batch
#[derive(Debug)]
pub struct FaultInjector {
    events: Mutex<Vec<(ServerFaultEvent, bool)>>,
    batches: AtomicU64,
}

impl FaultInjector {
    /// Advance the pool-wide batch clock and return the faults due on
    /// `shard`. An event whose trigger passed while its shard was dead
    /// fires on the shard's first batch after respawn.
    pub fn on_batch(&self, shard: usize) -> Vec<ServerFaultKind> {
        let now = self.batches.fetch_add(1, Ordering::SeqCst) + 1;
        let mut due = Vec::new();
        let mut events = self.events.lock().unwrap();
        for (e, fired) in events.iter_mut() {
            if !*fired && e.shard == shard && e.at_batch <= now {
                *fired = true;
                due.push(e.kind);
            }
        }
        due
    }

    /// Batches observed so far (the virtual chaos clock).
    pub fn batches(&self) -> u64 {
        self.batches.load(Ordering::SeqCst)
    }

    /// Events that have not fired yet.
    pub fn remaining(&self) -> usize {
        self.events.lock().unwrap().iter().filter(|(_, f)| !*f).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plan_is_deterministic_and_bounded() {
        let spec = FaultSpec::default();
        let a = FaultPlan::seeded(42, &spec);
        let b = FaultPlan::seeded(42, &spec);
        assert_eq!(a, b, "same seed must yield an identical plan");
        assert_eq!(a.server.len(), spec.panics + spec.brownouts);
        assert_eq!(a.wire.len(), spec.resets + spec.corruptions);
        for e in &a.server {
            assert!(e.shard < spec.shards);
            assert!((1..=spec.horizon_batches).contains(&e.at_batch));
        }
        for e in &a.wire {
            assert!((1..=spec.horizon_requests).contains(&e.at_request));
        }
        let c = FaultPlan::seeded(43, &spec);
        assert_ne!(a, c, "different seeds should differ");
        assert_eq!(
            crate::json::to_string(&a.to_json()),
            crate::json::to_string(&b.to_json()),
            "plan JSON must be byte-identical per seed"
        );
    }

    #[test]
    fn injector_fires_each_event_once_on_its_shard() {
        let plan = FaultPlan {
            seed: 0,
            server: vec![
                ServerFaultEvent {
                    at_batch: 2,
                    shard: 0,
                    kind: ServerFaultKind::Panic,
                },
                ServerFaultEvent {
                    at_batch: 3,
                    shard: 1,
                    kind: ServerFaultKind::BrownOut,
                },
            ],
            wire: vec![],
        };
        let inj = plan.injector();
        assert!(inj.on_batch(0).is_empty(), "batch 1: before the trigger");
        assert_eq!(inj.on_batch(0), vec![ServerFaultKind::Panic], "batch 2");
        assert!(inj.on_batch(0).is_empty(), "already fired");
        // shard 1's event triggered at batch 3 <= 4: fires on its next pop.
        assert_eq!(inj.on_batch(1), vec![ServerFaultKind::BrownOut]);
        assert_eq!(inj.remaining(), 0);
        assert_eq!(inj.batches(), 4);
    }

    #[test]
    fn late_trigger_fires_on_first_batch_after_respawn() {
        let plan = FaultPlan {
            seed: 0,
            server: vec![ServerFaultEvent {
                at_batch: 1,
                shard: 2,
                kind: ServerFaultKind::Panic,
            }],
            wire: vec![],
        };
        let inj = plan.injector();
        // Other shards advance the clock well past the trigger first.
        for _ in 0..10 {
            assert!(inj.on_batch(0).is_empty());
        }
        assert_eq!(inj.on_batch(2), vec![ServerFaultKind::Panic]);
    }

    #[test]
    fn brownout_shards_are_deduped_and_sorted() {
        let plan = FaultPlan {
            seed: 0,
            server: vec![
                ServerFaultEvent {
                    at_batch: 1,
                    shard: 3,
                    kind: ServerFaultKind::BrownOut,
                },
                ServerFaultEvent {
                    at_batch: 2,
                    shard: 1,
                    kind: ServerFaultKind::BrownOut,
                },
                ServerFaultEvent {
                    at_batch: 3,
                    shard: 3,
                    kind: ServerFaultKind::BrownOut,
                },
                ServerFaultEvent {
                    at_batch: 4,
                    shard: 0,
                    kind: ServerFaultKind::Panic,
                },
            ],
            wire: vec![],
        };
        assert_eq!(plan.brownout_shards(), vec![1, 3]);
    }
}
