//! Approximation explorer: auto-generate the Pareto profile ladder the
//! adaptive server runs on.
//!
//! The paper's adaptivity rests on a table of execution profiles trading
//! accuracy for energy (Table 1 / Fig. 3) — but someone has to *make* that
//! table. This subsystem searches the per-layer quantization design space
//! of a [`crate::qonnx::QonnxModel`] (weight and activation bit-widths per
//! layer, following NN2CAM-style automated multi-precision mapping) and
//! emits an epsilon-pruned Pareto frontier of auto-generated
//! [`crate::coordinator::ProfileSpec`]s:
//!
//! * [`quant`] — the bit-slicing transform: a knob vector -> a derived
//!   reduced-precision model with the requantization rebased so the
//!   pipeline stays consistent ([`derive_model`]);
//! * [`search`] — the deterministic explorer ([`Explorer`]): greedy
//!   per-layer descent plus local refinement, accuracy measured on the
//!   packed batch kernels (bit-exact vs the scalar oracle), cost from the
//!   activity-based power model, epsilon-dominance pruning;
//! * [`frontier`] — the resulting [`Frontier`]: JSON round-trip through
//!   the vendored `json` module, `ProfileManager::from_frontier`, and
//!   per-rung derived models for `Backend::sim_from_models`.
//!
//! End-to-end wiring: `onnx2hw explore` (CLI), the `pareto_explore` bench
//! (CI gate: the frontier must strictly dominate the naive
//! uniform-precision baseline), and the multi-rung ladder walk in
//! `coordinator::manager`. See `docs/approximation.md`.

mod frontier;
mod quant;
mod search;

pub use frontier::{Frontier, FrontierPoint};
pub use quant::{
    config_name, derive_model, knobs_for, layer_drops, Knob, KnobKind, LayerDrops, MIN_BITS,
};
pub use search::{dominates, CalibSet, Candidate, Explorer, ExplorerConfig};
