//! The explorer's output: a Pareto ladder of auto-generated profiles, plus
//! its JSON interchange (round-trips through the in-repo `json` module, so
//! the artifact stays vendored-offline) and the bridge into the serving
//! stack (`ProfileManager::from_frontier`, `Frontier::models`).

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::analysis::{
    analyze_error, check_config, Diagnostic, ErrorReport, Severity, RULE_ACC_NARROW_STALE,
    RULE_ERROR_BOUND, RULE_MARGIN_UNSOUND,
};
use crate::coordinator::{ManagerConfig, ProfileManager, ProfileSpec};
use crate::json::Value;
use crate::qonnx::{Layer, QonnxModel};

use super::quant::derive_model;

/// One rung of the auto-generated ladder: the knob vector, its measured
/// objectives, and the derived model ready to serve.
#[derive(Debug, Clone)]
pub struct FrontierPoint {
    /// Deterministic profile name ([`super::config_name`]).
    pub name: String,
    pub config: Vec<u32>,
    pub accuracy: f64,
    pub power_mw: f64,
    pub latency_us: f64,
    pub energy_uj: f64,
    /// Per conv layer: the packed plan proved the 32-bit accumulator path.
    pub acc_narrow: Vec<bool>,
    /// Proven worst-case absolute logit deviation of this rung versus the
    /// base model, from the affine error-bound analyzer
    /// ([`crate::analysis::analyze_error`]).
    pub logit_bound: i64,
    /// Proven stability margin: `0` certifies the rung's top-1 prediction
    /// equals the base model's on *every* input.
    pub stable_margin: i64,
    pub model: QonnxModel,
}

impl FrontierPoint {
    /// The [`ProfileSpec`] the Profile Manager selects on.
    pub fn spec(&self) -> ProfileSpec {
        ProfileSpec {
            name: self.name.clone(),
            accuracy: self.accuracy,
            power_mw: self.power_mw,
            latency_us: self.latency_us,
        }
    }
}

/// An epsilon-pruned Pareto ladder, most accurate rung first.
#[derive(Debug, Clone)]
pub struct Frontier {
    /// Profile name of the base model the ladder was derived from.
    pub base_profile: String,
    pub points: Vec<FrontierPoint>,
}

impl Frontier {
    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Profile table for [`ProfileManager::new`] / `from_frontier`.
    pub fn specs(&self) -> Vec<ProfileSpec> {
        self.points.iter().map(FrontierPoint::spec).collect()
    }

    /// The derived models keyed by profile name — drop-in input for
    /// `Backend::sim_from_models`, so the coordinator shards serve the
    /// auto-generated ladder exactly like hand-exported artifacts.
    pub fn models(&self) -> BTreeMap<String, QonnxModel> {
        self.points
            .iter()
            .map(|p| (p.name.clone(), p.model.clone()))
            .collect()
    }

    /// Some rung is at least as good as `(accuracy, energy, latency)` on
    /// every objective.
    pub fn weakly_dominates(&self, accuracy: f64, energy_uj: f64, latency_us: f64) -> bool {
        self.points.iter().any(|p| {
            p.accuracy >= accuracy && p.energy_uj <= energy_uj && p.latency_us <= latency_us
        })
    }

    /// Some rung weakly dominates `(accuracy, energy, latency)` and is
    /// strictly better on at least one objective.
    pub fn strictly_dominates(&self, accuracy: f64, energy_uj: f64, latency_us: f64) -> bool {
        self.points.iter().any(|p| {
            p.accuracy >= accuracy
                && p.energy_uj <= energy_uj
                && p.latency_us <= latency_us
                && (p.accuracy > accuracy || p.energy_uj < energy_uj || p.latency_us < latency_us)
        })
    }

    /// Serialize (schema `pareto-frontier/v1`). The derived models are
    /// *not* embedded — a rung is reproducible from the base model plus its
    /// knob vector, which is what [`Frontier::from_json`] re-derives.
    pub fn to_json(&self) -> Value {
        let points = self
            .points
            .iter()
            .map(|p| {
                let config: Vec<i64> = p.config.iter().map(|&v| v as i64).collect();
                Value::obj(vec![
                    ("name", p.name.as_str().into()),
                    ("config", Value::from_i64_slice(&config)),
                    ("accuracy", p.accuracy.into()),
                    ("power_mw", p.power_mw.into()),
                    ("latency_us", p.latency_us.into()),
                    ("energy_uj", p.energy_uj.into()),
                    (
                        "acc_narrow",
                        Value::Array(p.acc_narrow.iter().map(|&b| Value::Bool(b)).collect()),
                    ),
                    ("logit_bound", p.logit_bound.into()),
                    ("stable_margin", p.stable_margin.into()),
                ])
            })
            .collect();
        Value::obj(vec![
            ("schema", "pareto-frontier/v1".into()),
            ("base_profile", self.base_profile.as_str().into()),
            ("points", Value::Array(points)),
        ])
    }

    /// Rebuild a frontier from its JSON form, re-deriving each rung's model
    /// from `base` (which must be the model the frontier was explored on).
    /// Every stored config goes through the static checker
    /// ([`crate::analysis::check_config`]), and every stored certificate
    /// (`acc_narrow`, `logit_bound`, `stable_margin`) is re-proven by the
    /// error-bound analyzer; the first error diagnostic fails the load with
    /// a message naming the point, its index, the offending layer, and the
    /// rule code. `logit_bound`/`stable_margin` are optional on read so
    /// pre-certificate frontier documents still load — absent fields
    /// default to the freshly proven values.
    pub fn from_json(v: &Value, base: &QonnxModel) -> Result<Frontier> {
        match v.get("schema").and_then(Value::as_str) {
            Some("pareto-frontier/v1") => {}
            other => bail!("unsupported frontier schema {other:?}"),
        }
        let base_profile = v
            .get("base_profile")
            .and_then(Value::as_str)
            .context("frontier base_profile")?
            .to_string();
        let rows = v.get("points").and_then(Value::as_array).context("frontier points")?;
        let mut points = Vec::with_capacity(rows.len());
        for (idx, row) in rows.iter().enumerate() {
            let (name, config) = Self::point_identity(row)?;
            let diags = check_config(base, &config);
            if let Some(err) = diags.iter().find(|d| d.severity == Severity::Error) {
                bail!("point '{name}' (index {idx}): {err}");
            }
            let acc_narrow = row
                .get("acc_narrow")
                .and_then(Value::as_array)
                .context("point acc_narrow")?
                .iter()
                .map(|b| b.as_bool().context("acc_narrow flag"))
                .collect::<Result<Vec<bool>>>()?;
            let num = |key: &str| -> Result<f64> {
                row.get(key).and_then(Value::as_f64).with_context(|| format!("point {key}"))
            };
            let stored_bound = row.get("logit_bound").and_then(Value::as_i64);
            let stored_margin = row.get("stable_margin").and_then(Value::as_i64);
            let report = analyze_error(base, &config);
            let bound_diags =
                Self::verify_point(base, &report, Some(&acc_narrow), stored_bound, stored_margin);
            if let Some(err) = bound_diags.iter().find(|d| d.severity == Severity::Error) {
                bail!("point '{name}' (index {idx}): {err}");
            }
            points.push(FrontierPoint {
                model: derive_model(base, &config, &name),
                name,
                config,
                accuracy: num("accuracy")?,
                power_mw: num("power_mw")?,
                latency_us: num("latency_us")?,
                energy_uj: num("energy_uj")?,
                acc_narrow,
                logit_bound: stored_bound.unwrap_or(report.logit_bound),
                stable_margin: stored_margin.unwrap_or(report.stable_margin),
            });
        }
        Ok(Frontier {
            base_profile,
            points,
        })
    }

    /// Structural parse of one stored point: its name and checked `u32`
    /// knob vector (an out-of-u32 stored value must fail the load, not
    /// truncate its way past the checker's knob-range rule).
    fn point_identity(row: &Value) -> Result<(String, Vec<u32>)> {
        let name = row.get("name").and_then(Value::as_str).context("point name")?;
        let config: Vec<u32> = row
            .get("config")
            .and_then(Value::to_i64_vec)
            .context("point config")?
            .into_iter()
            .map(|x| u32::try_from(x).ok().context("point config value out of range"))
            .collect::<Result<Vec<u32>>>()?;
        Ok((name.to_string(), config))
    }

    /// Re-prove one stored point's certificates against the error-bound
    /// analyzer. The stored `acc_narrow` verdicts must equal the interval
    /// engine's proof for the derived variant
    /// ([`RULE_ACC_NARROW_STALE`]); the stored logit-deviation bound and
    /// stability margin must be at least as large as what
    /// [`analyze_error`] proves — a *looser* stored value is merely
    /// conservative and accepted, a tighter one is a falsified certificate
    /// ([`RULE_ERROR_BOUND`], [`RULE_MARGIN_UNSOUND`]). `None` for a field
    /// means the document predates certificates and is checked against
    /// nothing. Only call with a config the static checker already passed.
    fn verify_point(
        base: &QonnxModel,
        report: &ErrorReport,
        acc_narrow: Option<&[bool]>,
        logit_bound: Option<i64>,
        stable_margin: Option<i64>,
    ) -> Vec<Diagnostic> {
        let mut diags = Vec::new();
        if let Some(stored) = acc_narrow {
            let conv_at: Vec<(usize, &str)> = base
                .layers
                .iter()
                .enumerate()
                .filter_map(|(i, l)| match l {
                    Layer::Conv(c) => Some((i, c.name.as_str())),
                    _ => None,
                })
                .collect();
            if stored.len() != report.conv_narrow.len() {
                diags.push(Diagnostic {
                    severity: Severity::Error,
                    rule: RULE_ACC_NARROW_STALE,
                    layer: None,
                    op: "conv",
                    layer_name: String::new(),
                    message: format!(
                        "stored acc_narrow carries {} verdicts, the variant has {} conv layers",
                        stored.len(),
                        report.conv_narrow.len()
                    ),
                });
            } else {
                for (k, (&s, &p)) in stored.iter().zip(&report.conv_narrow).enumerate() {
                    if s != p {
                        let (layer, lname) =
                            conv_at.get(k).map_or((None, ""), |&(i, n)| (Some(i), n));
                        diags.push(Diagnostic {
                            severity: Severity::Error,
                            rule: RULE_ACC_NARROW_STALE,
                            layer,
                            op: "conv",
                            layer_name: lname.to_string(),
                            message: format!(
                                "stored narrow-accumulator verdict {s} disagrees with the \
                                 proven verdict {p}"
                            ),
                        });
                    }
                }
            }
        }
        // Bound rules anchor to the classifier head producing the logits.
        let (head, head_op, head_name) = base
            .layers
            .iter()
            .enumerate()
            .rev()
            .find_map(|(i, l)| match l {
                Layer::Dense(d) => Some((Some(i), "dense", d.name.as_str())),
                _ => None,
            })
            .unwrap_or((None, "", ""));
        if let Some(stored) = logit_bound {
            if stored < report.logit_bound {
                diags.push(Diagnostic {
                    severity: Severity::Error,
                    rule: RULE_ERROR_BOUND,
                    layer: head,
                    op: head_op,
                    layer_name: head_name.to_string(),
                    message: format!(
                        "stored logit bound {stored} is tighter than the proven worst-case \
                         deviation {}",
                        report.logit_bound
                    ),
                });
            }
        }
        if let Some(stored) = stable_margin {
            if stored < report.stable_margin {
                diags.push(Diagnostic {
                    severity: Severity::Error,
                    rule: RULE_MARGIN_UNSOUND,
                    layer: head,
                    op: head_op,
                    layer_name: head_name.to_string(),
                    message: format!(
                        "stored stability margin {stored} claims more top-1 stability than \
                         the proven margin {}",
                        report.stable_margin
                    ),
                });
            }
        }
        diags
    }

    /// Run the static checker over every point of a frontier JSON document
    /// *without* failing fast: returns `(point name, diagnostics)` per
    /// point, so `onnx2hw check` can print every finding instead of just
    /// the first. Legal configs additionally get their stored certificates
    /// re-proven ([`Self::verify_point`]); fields a row does not carry are
    /// skipped, so certificate-free documents stay checkable. Structural
    /// problems (wrong schema, unparseable points) still error.
    pub fn check_json(v: &Value, base: &QonnxModel) -> Result<Vec<(String, Vec<Diagnostic>)>> {
        match v.get("schema").and_then(Value::as_str) {
            Some("pareto-frontier/v1") => {}
            other => bail!("unsupported frontier schema {other:?}"),
        }
        let rows = v.get("points").and_then(Value::as_array).context("frontier points")?;
        let mut out = Vec::with_capacity(rows.len());
        for row in rows {
            let (name, config) = Self::point_identity(row)?;
            let mut diags = check_config(base, &config);
            if !diags.iter().any(|d| d.severity == Severity::Error) {
                let acc_narrow: Option<Vec<bool>> = row
                    .get("acc_narrow")
                    .and_then(Value::as_array)
                    .map(|a| a.iter().filter_map(Value::as_bool).collect());
                let report = analyze_error(base, &config);
                diags.extend(Self::verify_point(
                    base,
                    &report,
                    acc_narrow.as_deref(),
                    row.get("logit_bound").and_then(Value::as_i64),
                    row.get("stable_margin").and_then(Value::as_i64),
                ));
            }
            out.push((name, diags));
        }
        Ok(out)
    }
}

impl ProfileManager {
    /// Serve an auto-generated ladder: build the Profile Manager straight
    /// from an explorer frontier. Construction sorts the rungs by accuracy
    /// (see [`ProfileManager::new`]), so the frontier's own ordering is
    /// irrelevant.
    pub fn from_frontier(cfg: ManagerConfig, frontier: &Frontier) -> ProfileManager {
        assert!(!frontier.is_empty(), "cannot serve an empty frontier");
        ProfileManager::new(cfg, frontier.specs())
    }
}

#[cfg(test)]
mod tests {
    use super::super::quant::config_name;
    use super::*;
    use crate::json;
    use crate::qonnx::{read_str, test_model_json};

    fn sample() -> (QonnxModel, Frontier) {
        let base = read_str(&test_model_json(1, 2)).unwrap();
        let mk = |config: Vec<u32>, accuracy: f64, energy_uj: f64| {
            let name = config_name(&config);
            // Stored certificates come from the analyzer itself, exactly as
            // the explorer emits them — so every sample frontier is sound
            // by construction and survives the load-time re-proof.
            let report = analyze_error(&base, &config);
            FrontierPoint {
                model: derive_model(&base, &config, &name),
                name,
                config,
                accuracy,
                power_mw: energy_uj / 3.29e-4,
                latency_us: 329.0,
                energy_uj,
                acc_narrow: report.conv_narrow.clone(),
                logit_bound: report.logit_bound,
                stable_margin: report.stable_margin,
            }
        };
        let frontier = Frontier {
            base_profile: base.profile.clone(),
            points: vec![mk(vec![0, 0, 0], 1.0, 50.0), mk(vec![1, 2, 1], 0.75, 40.0)],
        };
        (base, frontier)
    }

    #[test]
    fn json_round_trips_through_the_vendored_module() {
        let (base, frontier) = sample();
        let text = json::to_string_pretty(&frontier.to_json());
        let parsed = json::parse(&text).expect("frontier JSON parses");
        let back = Frontier::from_json(&parsed, &base).expect("frontier JSON loads");
        assert_eq!(back.base_profile, frontier.base_profile);
        assert_eq!(back.len(), frontier.len());
        for (a, b) in frontier.points.iter().zip(&back.points) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.config, b.config);
            assert_eq!(a.accuracy, b.accuracy, "floats survive the writer exactly");
            assert_eq!(a.power_mw, b.power_mw);
            assert_eq!(a.latency_us, b.latency_us);
            assert_eq!(a.energy_uj, b.energy_uj);
            assert_eq!(a.acc_narrow, b.acc_narrow);
            assert_eq!(a.logit_bound, b.logit_bound);
            assert_eq!(a.stable_margin, b.stable_margin);
            assert_eq!(a.model, b.model, "models re-derive identically");
        }
    }

    /// Build one stored-point row for the degraded `[1, 2, 1]` rung with
    /// the given certificate fields, wrapped in a single-point frontier doc.
    fn doc_121(acc_narrow: &[bool], logit_bound: i64, stable_margin: i64) -> String {
        let narrow: Vec<String> = acc_narrow.iter().map(bool::to_string).collect();
        format!(
            r#"{{"schema":"pareto-frontier/v1","base_profile":"T","points":[
                {{"name":"apx-121","config":[1,2,1],"accuracy":1.0,"power_mw":1.0,
                 "latency_us":1.0,"energy_uj":1.0,"acc_narrow":[{}],
                 "logit_bound":{logit_bound},"stable_margin":{stable_margin}}}]}}"#,
            narrow.join(",")
        )
    }

    #[test]
    fn from_json_rejects_falsified_logit_bound_certificates() {
        let (base, _) = sample();
        let report = analyze_error(&base, &[1, 2, 1]);
        assert!(report.logit_bound > 0, "premise: the lossy rung deviates");
        // A stored bound of 0 claims bit-exactness the analyzer refutes.
        let text = doc_121(&report.conv_narrow, 0, report.stable_margin);
        let err = Frontier::from_json(&json::parse(&text).unwrap(), &base)
            .expect_err("falsified bound must fail the load");
        let msg = format!("{err:#}");
        assert!(msg.contains("apx-121"), "must name the point: {msg}");
        assert!(msg.contains("error-bound"), "must carry the rule code: {msg}");
        assert!(msg.contains("dense"), "must name the classifier head: {msg}");
        // A looser-than-proven stored bound is conservative, not falsified.
        let text = doc_121(&report.conv_narrow, report.logit_bound + 5, report.stable_margin);
        let back = Frontier::from_json(&json::parse(&text).unwrap(), &base)
            .expect("conservative bound loads");
        assert_eq!(back.points[0].logit_bound, report.logit_bound + 5);
    }

    #[test]
    fn from_json_rejects_unsound_stability_margins() {
        let (base, _) = sample();
        let report = analyze_error(&base, &[1, 2, 1]);
        // A negative margin claims impossible stability: always below the
        // proven margin, which is >= 0 by construction.
        let text = doc_121(&report.conv_narrow, report.logit_bound, -1);
        let err = Frontier::from_json(&json::parse(&text).unwrap(), &base)
            .expect_err("unsound margin must fail the load");
        let msg = format!("{err:#}");
        assert!(msg.contains("margin-unsound"), "must carry the rule code: {msg}");
    }

    #[test]
    fn from_json_rejects_stale_acc_narrow_verdicts() {
        let (base, _) = sample();
        let report = analyze_error(&base, &[1, 2, 1]);
        let flipped: Vec<bool> = report.conv_narrow.iter().map(|b| !b).collect();
        let text = doc_121(&flipped, report.logit_bound, report.stable_margin);
        let err = Frontier::from_json(&json::parse(&text).unwrap(), &base)
            .expect_err("stale narrow verdict must fail the load");
        let msg = format!("{err:#}");
        assert!(msg.contains("acc-narrow-stale"), "must carry the rule code: {msg}");
        assert!(msg.contains("conv"), "must name the offending layer: {msg}");
    }

    #[test]
    fn from_json_defaults_missing_bounds_to_the_proven_values() {
        // Pre-certificate documents carry no logit_bound/stable_margin:
        // they must still load, with the fields re-proven on the spot.
        let (base, _) = sample();
        let report = analyze_error(&base, &[1, 2, 1]);
        let narrow: Vec<String> = report.conv_narrow.iter().map(bool::to_string).collect();
        let text = format!(
            r#"{{"schema":"pareto-frontier/v1","base_profile":"T","points":[
                {{"name":"apx-121","config":[1,2,1],"accuracy":1.0,"power_mw":1.0,
                 "latency_us":1.0,"energy_uj":1.0,"acc_narrow":[{}]}}]}}"#,
            narrow.join(",")
        );
        let back = Frontier::from_json(&json::parse(&text).unwrap(), &base)
            .expect("legacy document loads");
        assert_eq!(back.points[0].logit_bound, report.logit_bound);
        assert_eq!(back.points[0].stable_margin, report.stable_margin);
    }

    #[test]
    fn from_json_rejects_foreign_schemas() {
        let (base, _) = sample();
        let bogus = json::parse(r#"{"schema": "something-else", "points": []}"#).unwrap();
        assert!(Frontier::from_json(&bogus, &base).is_err());
    }

    #[test]
    fn from_json_rejects_configs_that_do_not_fit_the_base() {
        // conv weight headroom on the tiny model is 2: a stored drop of 9
        // must error cleanly instead of panicking inside derive_model —
        // and the diagnostic must name the point, the offending layer, and
        // the rule code (the checker-backed replacement for the old
        // generic "does not fit" message).
        let (base, _) = sample();
        let text = r#"{"schema":"pareto-frontier/v1","base_profile":"T","points":[
            {"name":"apx-900","config":[9,0,0],"accuracy":1.0,"power_mw":1.0,
             "latency_us":1.0,"energy_uj":1.0,"acc_narrow":[true]}]}"#;
        let err = Frontier::from_json(&json::parse(text).unwrap(), &base)
            .expect_err("out-of-range knob must fail the load");
        let msg = format!("{err:#}");
        assert!(msg.contains("apx-900"), "must name the point: {msg}");
        assert!(msg.contains("conv1"), "must name the offending layer: {msg}");
        assert!(msg.contains("config-range"), "must carry the rule code: {msg}");
    }

    #[test]
    fn from_json_rejects_semantically_illegal_configs_with_rule_codes() {
        // [0, 0, 2] is in-range on every knob but zeroes the tiny model's
        // dense weights: only the abstract-interpretation pass catches it.
        let (base, _) = sample();
        let text = r#"{"schema":"pareto-frontier/v1","base_profile":"T","points":[
            {"name":"apx-002","config":[0,0,2],"accuracy":1.0,"power_mw":1.0,
             "latency_us":1.0,"energy_uj":1.0,"acc_narrow":[true]}]}"#;
        let err = Frontier::from_json(&json::parse(text).unwrap(), &base)
            .expect_err("const-output config must fail the load");
        let msg = format!("{err:#}");
        assert!(msg.contains("const-output"), "must carry the rule code: {msg}");
        assert!(msg.contains("dense"), "must name the offending layer: {msg}");
    }

    #[test]
    fn check_json_reports_every_point_without_failing_fast() {
        let (base, frontier) = sample();
        let bad = r#"{"schema":"pareto-frontier/v1","base_profile":"T","points":[
            {"name":"apx-000","config":[0,0,0]},
            {"name":"apx-900","config":[9,0,0]},
            {"name":"apx-002","config":[0,0,2]}]}"#;
        let report = Frontier::check_json(&json::parse(bad).unwrap(), &base).unwrap();
        assert_eq!(report.len(), 3);
        assert!(report[0].1.is_empty(), "the root config is clean");
        assert!(report[1].1.iter().any(|d| d.rule == crate::analysis::RULE_CONFIG_RANGE));
        assert!(report[2].1.iter().any(|d| d.rule == crate::analysis::RULE_CONST_OUTPUT));
        // a fully legal frontier reports no errors on any point
        let clean = Frontier::check_json(&frontier.to_json(), &base).unwrap();
        assert!(clean
            .iter()
            .all(|(_, diags)| diags.iter().all(|d| d.severity != Severity::Error)));
        // a falsified certificate surfaces as a finding, not a hard error
        let proven = analyze_error(&base, &[1, 2, 1]);
        let falsified = doc_121(&proven.conv_narrow, 0, proven.stable_margin);
        let report = Frontier::check_json(&json::parse(&falsified).unwrap(), &base).unwrap();
        assert!(report[0].1.iter().any(|d| d.rule == RULE_ERROR_BOUND));
    }

    #[test]
    fn specs_and_models_mirror_the_points() {
        let (_, frontier) = sample();
        let specs = frontier.specs();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].name, "apx-000");
        assert_eq!(specs[1].name, "apx-121");
        let models = frontier.models();
        assert_eq!(models.len(), 2);
        assert!(models.contains_key("apx-000") && models.contains_key("apx-121"));
        assert_eq!(models["apx-121"].profile, "apx-121");
    }

    #[test]
    fn dominance_predicates_cover_the_ladder() {
        let (_, frontier) = sample();
        // a point worse than the degraded rung on energy alone
        assert!(frontier.weakly_dominates(0.75, 45.0, 329.0));
        assert!(frontier.strictly_dominates(0.75, 45.0, 329.0));
        // the rung itself: weakly covered, not strictly beaten
        assert!(frontier.weakly_dominates(1.0, 50.0, 329.0));
        assert!(!frontier.strictly_dominates(1.0, 50.0, 329.0));
        // better than anything on the ladder
        assert!(!frontier.weakly_dominates(1.0, 30.0, 329.0));
    }

    #[test]
    fn manager_builds_from_a_frontier() {
        let (_, frontier) = sample();
        let mgr = ProfileManager::from_frontier(ManagerConfig::default(), &frontier);
        assert_eq!(mgr.profiles().len(), 2);
        // sorted most accurate first; startup selects the top rung
        assert_eq!(mgr.profiles()[0].name, "apx-000");
        assert_eq!(mgr.current().name, "apx-000");
    }
}
