//! Post-training bit-slicing: derive a reduced-precision variant of a
//! [`QonnxModel`] from a per-layer knob vector.
//!
//! The paper's execution profiles (A8-W8 ... A4-W4, Mixed) are per-layer
//! precision assignments baked in during QAT. The explorer needs the same
//! family of variants *without retraining*, so this module slices bits off
//! an existing integer model the way A8-W8 -> A8-W4 drops weight LSBs:
//!
//! * **Weight drop `k`** — weight codes are rescaled `w' = round(w / 2^k)`
//!   (round-half-up, the oracle's requant rounding) and clamped to the
//!   narrower signed range; the lost factor is folded back into the
//!   requantization so the layer's output scale is unchanged.
//! * **Activation drop `j`** — the layer emits codes one step coarser per
//!   dropped bit (`out_step * 2^j`, clamp range `2^(act_bits-j) - 1`); the
//!   *next* layer's bias and requant are rebased so the coarser stream is
//!   consumed consistently.
//!
//! Both rebasings act on the TFLite-style `(mult, shift)` pair: the new
//! effective shift is `shift + j - k - j_in`; when that underflows zero the
//! remainder is folded into `mult` instead (exact — a left shift).
//!
//! The derived model is a plain [`QonnxModel`]: it runs on the scalar
//! oracle, the packed batch kernels, the actor-level simulator, and the HLS
//! + power estimators like any hand-exported profile. A zero knob vector
//! reproduces the base model bit-for-bit (property-tested).

use crate::qonnx::{ConvLayer, DenseLayer, Layer, QonnxModel};

/// Narrowest precision the slicer will leave on any tensor.
pub const MIN_BITS: u32 = 2;

/// Which precision a knob controls.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KnobKind {
    WeightBits,
    ActBits,
}

/// One searchable dimension: drop `0..=max` bits from one tensor of one
/// layer.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Knob {
    pub layer: String,
    pub kind: KnobKind,
    /// Largest legal drop (keeps at least [`MIN_BITS`] bits; capped at 15
    /// so a knob value always fits one hex digit of [`config_name`]).
    pub max: u32,
}

fn headroom(bits: u32) -> u32 {
    bits.saturating_sub(MIN_BITS).min(15)
}

/// Enumerate the search space of `model`: per conv layer a weight-bit and
/// an activation-bit knob (in layer order, weight first), plus a weight-bit
/// knob for the dense head. Pool/flatten stages operate on codes and have
/// nothing to drop.
pub fn knobs_for(model: &QonnxModel) -> Vec<Knob> {
    let mut knobs = Vec::new();
    for layer in &model.layers {
        match layer {
            Layer::Conv(c) => {
                knobs.push(Knob {
                    layer: c.name.clone(),
                    kind: KnobKind::WeightBits,
                    max: headroom(c.weight_bits),
                });
                knobs.push(Knob {
                    layer: c.name.clone(),
                    kind: KnobKind::ActBits,
                    max: headroom(c.act_bits),
                });
            }
            Layer::Dense(d) => knobs.push(Knob {
                layer: d.name.clone(),
                kind: KnobKind::WeightBits,
                max: headroom(d.weight_bits),
            }),
            _ => {}
        }
    }
    knobs
}

/// Deterministic profile name for a knob vector: one hex digit per knob
/// (`[0, 2, 10]` -> `"apx-02a"`). Unique per config, stable across runs.
pub fn config_name(config: &[u32]) -> String {
    let digits: String = config
        .iter()
        .map(|&v| char::from_digit(v, 16).unwrap_or('f'))
        .collect();
    format!("apx-{digits}")
}

/// The effective bit drops one layer sees under a knob vector: its own
/// weight drop `k`, its own activation drop `j` (0 for dense — logits are
/// raw accumulators), and the incoming stream's activation drop `j_in`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LayerDrops {
    pub k: u32,
    pub j: u32,
    pub j_in: u32,
}

/// Resolve `config` to per-layer [`LayerDrops`], aligned with
/// `base.layers` (`None` for pool/flatten). The cursor walk is the same
/// one [`derive_model`] performs — this is the read-only view the
/// error-bound analyzer uses to align a variant against its base.
///
/// Panics on an arity mismatch, like [`derive_model`].
pub fn layer_drops(base: &QonnxModel, config: &[u32]) -> Vec<Option<LayerDrops>> {
    assert_eq!(
        config.len(),
        knobs_for(base).len(),
        "config/knob arity mismatch"
    );
    let mut cursor = 0usize;
    let mut j_in = 0u32;
    base.layers
        .iter()
        .map(|layer| match layer {
            Layer::Conv(_) => {
                let (k, j) = (config[cursor], config[cursor + 1]);
                cursor += 2;
                let out = LayerDrops { k, j, j_in };
                j_in = j;
                Some(out)
            }
            Layer::Dense(_) => {
                let k = config[cursor];
                cursor += 1;
                Some(LayerDrops { k, j: 0, j_in })
            }
            _ => None,
        })
        .collect()
}

/// Round-half-up rescale by `2^s` (the oracle's requant rounding, applied
/// to weight/bias codes).
fn qscale(x: i64, s: u32) -> i64 {
    if s == 0 {
        x
    } else {
        (x + (1i64 << (s - 1))) >> s
    }
}

/// Rebase a TFLite-style `(mult, shift)` pair by `delta` effective shift
/// steps; negative remainders fold into `mult` (exact).
fn rebase(mult: i64, shift: i64, delta: i64) -> (i64, i64) {
    let s = shift + delta;
    if s < 0 {
        (mult << (-s) as u32, 0)
    } else {
        (mult, s)
    }
}

fn quantize_conv(c: &ConvLayer, k: u32, j: u32, j_in: u32) -> ConvLayer {
    let weight_bits = c.weight_bits - k;
    let wmax = (1i64 << (weight_bits - 1)) - 1;
    let w_codes = c
        .w_codes
        .iter()
        .map(|&w| qscale(w as i64, k).clamp(-wmax, wmax) as i32)
        .collect();
    let b_codes = c.b_codes.iter().map(|&b| qscale(b, k + j_in)).collect();
    let delta = j as i64 - k as i64 - j_in as i64;
    let (mult, shift): (Vec<i64>, Vec<i64>) = c
        .mult
        .iter()
        .zip(&c.shift)
        .map(|(&m, &s)| rebase(m, s, delta))
        .unzip();
    ConvLayer {
        name: c.name.clone(),
        w_codes,
        cin: c.cin,
        cout: c.cout,
        b_codes,
        mult,
        shift,
        act_bits: c.act_bits - j,
        act_int_bits: c.act_int_bits,
        weight_bits,
        in_step: c.in_step * f64::powi(2.0, j_in as i32),
        out_step: c.out_step * f64::powi(2.0, j as i32),
    }
}

fn quantize_dense(d: &DenseLayer, k: u32, j_in: u32) -> DenseLayer {
    let weight_bits = d.weight_bits - k;
    let wmax = (1i64 << (weight_bits - 1)) - 1;
    let w_codes = d
        .w_codes
        .iter()
        .map(|&w| qscale(w as i64, k).clamp(-wmax, wmax) as i32)
        .collect();
    // Logits are raw accumulators: rescaling weights and bias by the same
    // factor preserves the argmax ordering up to rounding (the intended
    // approximation).
    let b_codes = d.b_codes.iter().map(|&b| qscale(b, k + j_in)).collect();
    DenseLayer {
        name: d.name.clone(),
        w_codes,
        in_features: d.in_features,
        out_features: d.out_features,
        b_codes,
        weight_bits,
        in_step: d.in_step * f64::powi(2.0, j_in as i32),
        w_step: d.w_step * f64::powi(2.0, k as i32),
    }
}

/// Derive the reduced-precision variant of `base` described by `config`
/// (one entry per [`knobs_for`] knob, in the same order), named `name`.
///
/// Panics on an arity mismatch or an out-of-range knob value — configs are
/// produced by the explorer, never parsed from untrusted input.
pub fn derive_model(base: &QonnxModel, config: &[u32], name: &str) -> QonnxModel {
    let knobs = knobs_for(base);
    assert_eq!(config.len(), knobs.len(), "config/knob arity mismatch");
    for (v, knob) in config.iter().zip(&knobs) {
        assert!(
            *v <= knob.max,
            "knob {} ({:?}) out of range: {v} > {}",
            knob.layer,
            knob.kind,
            knob.max
        );
    }
    let mut cursor = 0usize;
    // Activation-bit drop of the incoming stream (input codes stay u8).
    let mut j_in = 0u32;
    let layers = base
        .layers
        .iter()
        .map(|layer| match layer {
            Layer::Conv(c) => {
                let (k, j) = (config[cursor], config[cursor + 1]);
                cursor += 2;
                let out = Layer::Conv(quantize_conv(c, k, j, j_in));
                j_in = j;
                out
            }
            Layer::Dense(d) => {
                let k = config[cursor];
                cursor += 1;
                Layer::Dense(quantize_dense(d, k, j_in))
            }
            other => other.clone(),
        })
        .collect();
    QonnxModel {
        profile: name.to_string(),
        input_shape: base.input_shape,
        input_bits: base.input_bits,
        input_int_bits: base.input_int_bits,
        layers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::exec;
    use crate::qonnx::{read_str, test_model_json};

    fn tiny() -> QonnxModel {
        read_str(&test_model_json(1, 2)).unwrap()
    }

    #[test]
    fn qscale_rounds_half_up() {
        assert_eq!(qscale(3, 1), 2);
        assert_eq!(qscale(-3, 1), -1);
        assert_eq!(qscale(7, 0), 7);
        assert_eq!(qscale(-8, 2), -2);
        assert_eq!(qscale(5, 2), 1); // (5+2)>>2
    }

    #[test]
    fn knob_enumeration_matches_layer_order() {
        // tiny model: conv (act 8, weight 4), pool, flatten, dense (weight 4)
        let knobs = knobs_for(&tiny());
        assert_eq!(knobs.len(), 3);
        assert_eq!(knobs[0].kind, KnobKind::WeightBits);
        assert_eq!(knobs[0].max, 2);
        assert_eq!(knobs[1].kind, KnobKind::ActBits);
        assert_eq!(knobs[1].max, 6);
        assert_eq!(knobs[2].kind, KnobKind::WeightBits);
        assert_eq!(knobs[2].max, 2);
        assert_eq!(knobs[0].layer, "conv1");
        assert_eq!(knobs[2].layer, "dense");
    }

    #[test]
    fn config_names_are_hex_digits() {
        assert_eq!(config_name(&[0, 1, 2]), "apx-012");
        assert_eq!(config_name(&[10, 15, 0]), "apx-af0");
        assert_ne!(config_name(&[1, 0, 0]), config_name(&[0, 1, 0]));
    }

    #[test]
    fn layer_drops_mirror_the_derive_cursor_walk() {
        // tiny layers: conv, pool, flatten, dense; config [k, j, dk].
        let drops = layer_drops(&tiny(), &[1, 2, 1]);
        assert_eq!(drops.len(), 4);
        assert_eq!(drops[0], Some(LayerDrops { k: 1, j: 2, j_in: 0 }));
        assert_eq!(drops[1], None);
        assert_eq!(drops[2], None);
        // the dense head consumes the conv's coarsened stream
        assert_eq!(drops[3], Some(LayerDrops { k: 1, j: 0, j_in: 2 }));
    }

    #[test]
    fn zero_config_is_the_identity() {
        let base = tiny();
        let derived = derive_model(&base, &[0, 0, 0], "apx-000");
        assert_eq!(derived.profile, "apx-000");
        assert_eq!(derived.layers, base.layers);
        assert_eq!(derived.input_shape, base.input_shape);
    }

    #[test]
    fn weight_drop_rescales_codes_and_rebases_requant() {
        let base = tiny();
        let derived = derive_model(&base, &[1, 0, 0], "apx-100");
        let (c0, c1) = match (&base.layers[0], &derived.layers[0]) {
            (Layer::Conv(a), Layer::Conv(b)) => (a, b),
            _ => panic!("first layer must be conv"),
        };
        assert_eq!(c1.weight_bits, c0.weight_bits - 1);
        let wmax = (1i64 << (c1.weight_bits - 1)) - 1;
        for (&w0, &w1) in c0.w_codes.iter().zip(&c1.w_codes) {
            assert_eq!(w1 as i64, qscale(w0 as i64, 1).clamp(-wmax, wmax));
        }
        for (&b0, &b1) in c0.b_codes.iter().zip(&c1.b_codes) {
            assert_eq!(b1, qscale(b0, 1));
        }
        // shift absorbs the lost factor: shift' = shift - 1
        for (&s0, &s1) in c0.shift.iter().zip(&c1.shift) {
            assert_eq!(s1, s0 - 1);
        }
        assert_eq!(c1.act_bits, c0.act_bits, "weight drop leaves activations");
    }

    #[test]
    fn act_drop_narrows_the_stream_and_rebases_downstream() {
        let base = tiny();
        let derived = derive_model(&base, &[0, 2, 0], "apx-020");
        let (c0, c1) = match (&base.layers[0], &derived.layers[0]) {
            (Layer::Conv(a), Layer::Conv(b)) => (a, b),
            _ => panic!("first layer must be conv"),
        };
        assert_eq!(c1.act_bits, c0.act_bits - 2);
        assert_eq!(c1.out_step, c0.out_step * 4.0);
        // producing layer shifts 2 further right to emit coarser codes
        assert_eq!(c1.shift[0], c0.shift[0] + 2);
        // downstream dense consumes the coarser stream
        let (d0, d1) = match (&base.layers[3], &derived.layers[3]) {
            (Layer::Dense(a), Layer::Dense(b)) => (a, b),
            _ => panic!("last layer must be dense"),
        };
        assert_eq!(d1.in_step, d0.in_step * 4.0);
        assert_eq!(d1.w_codes, d0.w_codes, "dense weights untouched");
    }

    #[test]
    fn negative_shift_folds_into_mult_exactly() {
        // delta pushes the shift below zero: the remainder must move into
        // mult as an exact left shift.
        assert_eq!(rebase(5, 3, -3), (5, 0));
        assert_eq!(rebase(5, 3, -5), (20, 0));
        assert_eq!(rebase(5, 3, 2), (5, 5));
    }

    #[test]
    fn derived_models_execute_and_degrade_gracefully() {
        let base = tiny();
        let img: Vec<u8> = (0..base.input_shape.elems()).map(|i| (i * 37 % 256) as u8).collect();
        let want = exec::execute(&base, &img);
        // identity config: bit-for-bit equal
        let same = derive_model(&base, &[0, 0, 0], "apx-000");
        assert_eq!(exec::execute(&same, &img), want);
        // every legal config still runs the pipeline end to end
        for cfg in [[1, 0, 0], [0, 3, 0], [0, 0, 2], [2, 6, 2]] {
            let m = derive_model(&base, &cfg, "t");
            let logits = exec::execute(&m, &img);
            assert_eq!(logits.len(), want.len());
        }
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn over_range_knob_is_rejected() {
        let base = tiny();
        derive_model(&base, &[3, 0, 0], "bad"); // conv weight headroom is 2
    }
}
