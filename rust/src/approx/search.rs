//! Deterministic design-space exploration over per-layer bit-width knobs.
//!
//! The explorer walks the knob lattice of [`knobs_for`] with a seeded,
//! wall-clock-free strategy — greedy per-layer descent plus local
//! refinement — evaluating every candidate the same way the serving stack
//! would run it:
//!
//! * **accuracy** on the calibration set via the packed batch kernels
//!   ([`BatchExecutor`]), with the first replies of every candidate asserted
//!   bit-exact against the scalar oracle (`exec::execute`) — an approximate
//!   *profile* may change predictions, an approximate *kernel* may not;
//! * **power / latency / energy-per-inference** through the activity-based
//!   `power` model (actor-level simulation of calibration images + the HLS
//!   resource estimate), exactly the Table-1 code path.
//!
//! Determinism contract: no wall clock, no global RNG. The only entropy is
//! the calibration-set seed ([`CalibSet::self_labeled`]); given the same
//! base model and calibration set, every run evaluates the same candidates
//! in the same order and emits the same frontier. Greedy ties break on the
//! lowest knob index; candidate bookkeeping lives in a `BTreeMap` so
//! iteration order is the config order, never hash order.

use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use crate::dataflow::{exec, BatchExecutor, CompiledModel, FoldingConfig};
use crate::hls::{Calibration, DeviceModel};
use crate::power::estimate_inference_cost;
use crate::qonnx::QonnxModel;
use crate::runtime::TestSet;
use crate::testkit::Rng;

use super::frontier::{Frontier, FrontierPoint};
use super::quant::{config_name, derive_model, knobs_for, Knob};

/// Images to score candidates on, plus ground-truth labels.
#[derive(Debug, Clone)]
pub struct CalibSet {
    pub images: Vec<Vec<u8>>,
    pub labels: Vec<usize>,
}

impl CalibSet {
    /// Calibrate on the exported test set (real labels).
    pub fn from_testset(ts: &TestSet, limit: usize) -> CalibSet {
        assert!(!ts.is_empty(), "test set is empty");
        let n = ts.len().min(limit.max(1));
        CalibSet {
            images: (0..n).map(|i| ts.image(i).to_vec()).collect(),
            labels: (0..n).map(|i| ts.labels[i] as usize).collect(),
        }
    }

    /// Synthetic calibration workload labelled by the base model itself
    /// (fidelity labels): the full-precision model scores 1.0 by
    /// construction and every approximation is measured against it. Seeded
    /// and deterministic — benches and tests need no artifacts.
    pub fn self_labeled(model: &QonnxModel, n: usize, seed: u64) -> CalibSet {
        let elems = model.input_shape.elems();
        let mut rng = Rng::new(seed);
        let images: Vec<Vec<u8>> = (0..n.max(1))
            .map(|_| (0..elems).map(|_| rng.u64(0, 255) as u8).collect())
            .collect();
        let labels = images
            .iter()
            .map(|img| exec::argmax(&exec::execute(model, img)))
            .collect();
        CalibSet { images, labels }
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }
}

/// One evaluated point of the design space.
#[derive(Debug, Clone, PartialEq)]
pub struct Candidate {
    /// Knob vector (see [`knobs_for`] for the order).
    pub config: Vec<u32>,
    /// Fraction of calibration images classified to their label.
    pub accuracy: f64,
    pub power_mw: f64,
    pub latency_us: f64,
    /// Energy per inference (power x latency), the frontier's cost axis.
    pub energy_uj: f64,
    /// Per conv layer: did the packed plan prove the 32-bit accumulator
    /// path for this variant? (Dropping bits widens the narrow envelope.)
    pub acc_narrow: Vec<bool>,
}

/// `p` (weakly) dominates `q` and is strictly better on >= 1 objective.
/// Objectives: accuracy up, energy down, latency down.
pub fn dominates(p: &Candidate, q: &Candidate) -> bool {
    p.accuracy >= q.accuracy
        && p.energy_uj <= q.energy_uj
        && p.latency_us <= q.latency_us
        && (p.accuracy > q.accuracy || p.energy_uj < q.energy_uj || p.latency_us < q.latency_us)
}

/// Explorer knobs (the search's own, not the model's).
#[derive(Debug, Clone)]
pub struct ExplorerConfig {
    pub fold: FoldingConfig,
    pub cal: Calibration,
    pub device: DeviceModel,
    /// Calibration images fed to the actor-level power simulation per
    /// candidate (the accuracy pass always uses the whole set).
    pub power_images: usize,
    /// Replies per candidate cross-checked bit-exact vs the scalar oracle.
    pub oracle_checks: usize,
    /// Stop the greedy descent once accuracy falls below this.
    pub min_accuracy: f64,
    /// Epsilon-dominance band: adjacent frontier rungs closer than this in
    /// accuracy are merged (0 keeps every Pareto point).
    pub eps_accuracy: f64,
    /// Cap the emitted ladder length (0 = unlimited). Thinning keeps the
    /// endpoints and samples evenly between them.
    pub max_rungs: usize,
    /// Rungs of the uniform-precision baseline ladder that are seeded into
    /// the archive and reported by [`Explorer::uniform_baseline`].
    pub uniform_rungs: usize,
    /// Statically reject illegal knob vectors via [`crate::analysis`]
    /// before paying packed-executor + cost-model evaluation. The frontier
    /// is identical either way (illegal candidates are never selected or
    /// emitted); pruning only skips their evaluations — see
    /// [`Explorer::pruned_static`].
    pub static_prune: bool,
    /// Use [`crate::analysis::analyze_error`] certificates to triage
    /// candidates: skip the packed-executor accuracy pass when the bounds
    /// prove the variant's predictions are bit-identical to the root's
    /// (the candidate still pays model derivation and the cost model), and
    /// — when [`Self::logit_bound_tolerance`] is set — skip evaluating
    /// candidates the tolerance already rejects. Trajectory-neutral like
    /// `static_prune`: the emitted frontier is byte-identical either way.
    pub bound_triage: bool,
    /// Reject any candidate whose *proven* worst-case logit deviation from
    /// the reference exceeds this many base logit codes. Applied in both
    /// triage modes (rejected candidates are never selected or emitted);
    /// `bound_triage` only decides whether their accuracy evaluation is
    /// skipped. `None` disables the gate.
    pub logit_bound_tolerance: Option<i64>,
}

impl Default for ExplorerConfig {
    fn default() -> Self {
        ExplorerConfig {
            fold: FoldingConfig::default(),
            cal: Calibration::default(),
            device: DeviceModel::kria_kv260(),
            power_images: 2,
            oracle_checks: 2,
            min_accuracy: 0.0,
            eps_accuracy: 0.0,
            max_rungs: 0,
            uniform_rungs: 4,
            static_prune: true,
            bound_triage: true,
            logit_bound_tolerance: None,
        }
    }
}

/// Memoized error-bound facts for one knob vector (the subset of
/// [`crate::analysis::ErrorReport`] the explorer consumes).
#[derive(Debug, Clone)]
struct BoundInfo {
    logit_bound: i64,
    stable_margin: i64,
    certified_exact: bool,
    conv_narrow: Vec<bool>,
}

/// The design-space explorer. Owns the candidate archive (memoized by knob
/// vector); borrow it mutably, call [`Explorer::explore`], read the
/// [`Frontier`].
pub struct Explorer<'a> {
    base: &'a QonnxModel,
    calib: &'a CalibSet,
    cfg: ExplorerConfig,
    knobs: Vec<Knob>,
    cache: BTreeMap<Vec<u32>, Candidate>,
    evals: usize,
    /// Packed-executor accuracy passes actually run (`evals` minus the
    /// certificate skips).
    acc_evals: usize,
    /// Evaluations whose accuracy pass was skipped on a proven
    /// certified-exact bound.
    skipped: usize,
    /// Memoized static-checker verdicts per knob vector.
    legal: BTreeMap<Vec<u32>, bool>,
    /// Memoized error-bound certificates per knob vector.
    bounds: BTreeMap<Vec<u32>, BoundInfo>,
    /// Unique configs statically rejected before evaluation (counted like
    /// `evals`: one entry per config, however often it is re-proposed).
    pruned: BTreeSet<Vec<u32>>,
    /// Unique configs the logit-bound tolerance rejected before evaluation
    /// (triage mode only — without triage they are still evaluated, just
    /// never selected or emitted).
    rejected: BTreeSet<Vec<u32>>,
}

/// Accuracy batch size: bounds the executor arena while amortizing packing.
const EVAL_BATCH: usize = 32;

impl<'a> Explorer<'a> {
    pub fn new(base: &'a QonnxModel, calib: &'a CalibSet, cfg: ExplorerConfig) -> Self {
        assert!(!calib.is_empty(), "calibration set must not be empty");
        assert_eq!(calib.images.len(), calib.labels.len(), "images/labels mismatch");
        for img in &calib.images {
            assert_eq!(img.len(), base.input_shape.elems(), "calibration image size mismatch");
        }
        let knobs = knobs_for(base);
        assert!(!knobs.is_empty(), "model has no quantizable layers");
        Explorer {
            base,
            calib,
            cfg,
            knobs,
            cache: BTreeMap::new(),
            evals: 0,
            acc_evals: 0,
            skipped: 0,
            legal: BTreeMap::new(),
            bounds: BTreeMap::new(),
            pruned: BTreeSet::new(),
            rejected: BTreeSet::new(),
        }
    }

    pub fn knobs(&self) -> &[Knob] {
        &self.knobs
    }

    /// Candidates evaluated so far (cache hits excluded).
    pub fn evaluations(&self) -> usize {
        self.evals
    }

    /// Packed-executor accuracy passes actually run:
    /// `evaluations() - skipped_by_bounds()`. A certificate-skipped
    /// candidate still counts as an evaluation (it is derived, costed, and
    /// archived) — only its accuracy measurement is proven redundant.
    pub fn accuracy_evaluations(&self) -> usize {
        self.acc_evals
    }

    /// Evaluations that reused the root's accuracy on a proven
    /// certified-exact error bound instead of running the packed executor.
    pub fn skipped_by_bounds(&self) -> usize {
        self.skipped
    }

    /// Search proposals the logit-bound tolerance rejected before
    /// evaluation (triage mode; `evaluations() + rejected_by_bounds()`
    /// equals the untriaged run's `evaluations()` on the same seeds and
    /// tolerance).
    pub fn rejected_by_bounds(&self) -> usize {
        self.rejected.len()
    }

    /// Search proposals the static checker rejected before evaluation —
    /// the explorer's speedup (`evaluations() + pruned_static()` equals the
    /// unpruned run's `evaluations()` on the same seeds).
    pub fn pruned_static(&self) -> usize {
        self.pruned.len()
    }

    /// Memoized [`crate::analysis::check_config`] verdict for one knob
    /// vector: `true` iff the checker reports no error diagnostics.
    pub fn config_legal(&mut self, config: &[u32]) -> bool {
        if let Some(&v) = self.legal.get(config) {
            return v;
        }
        let v = crate::analysis::config_is_legal(self.base, config);
        self.legal.insert(config.to_vec(), v);
        v
    }

    /// Memoized [`crate::analysis::analyze_error`] certificate for one
    /// knob vector. Only called on range-legal configs (the analyzer
    /// derives the variant, which panics on out-of-range knobs).
    fn bound_info(&mut self, config: &[u32]) -> BoundInfo {
        if let Some(info) = self.bounds.get(config) {
            return info.clone();
        }
        let report = crate::analysis::analyze_error(self.base, config);
        let info = BoundInfo {
            logit_bound: report.logit_bound,
            stable_margin: report.stable_margin,
            certified_exact: report.certified_exact,
            conv_narrow: report.conv_narrow,
        };
        self.bounds.insert(config.to_vec(), info.clone());
        info
    }

    /// `true` unless a [`ExplorerConfig::logit_bound_tolerance`] is set
    /// and this config's *proven* worst-case logit deviation exceeds it.
    /// Caller must have established legality first.
    fn within_tolerance(&mut self, config: &[u32]) -> bool {
        match self.cfg.logit_bound_tolerance {
            None => true,
            Some(tol) => self.bound_info(config).logit_bound <= tol,
        }
    }

    /// The uniform-precision config at rung `k`: every knob dropped by `k`
    /// bits (clamped to its own headroom) — the naive baseline that ignores
    /// per-layer sensitivity.
    pub fn uniform(&self, k: u32) -> Vec<u32> {
        self.knobs.iter().map(|kn| k.min(kn.max)).collect()
    }

    /// Evaluate (memoized) the uniform ladder `1..=uniform_rungs`.
    pub fn uniform_baseline(&mut self) -> Vec<Candidate> {
        (1..=self.cfg.uniform_rungs)
            .map(|k| {
                let cfg = self.uniform(k as u32);
                self.evaluate(&cfg)
            })
            .collect()
    }

    /// Evaluate one knob vector: derive the variant, run the calibration
    /// set on the packed kernels (cross-checking the first replies against
    /// the scalar oracle), and cost it with the power model. Memoized.
    pub fn evaluate(&mut self, config: &[u32]) -> Candidate {
        if let Some(hit) = self.cache.get(config) {
            return hit.clone();
        }
        // Certificate triage: a legal non-root variant whose error bounds
        // prove bit-identical predictions on *all* inputs scores exactly
        // the root's accuracy on any calibration set — measuring it again
        // on the packed executor is redundant. The candidate is still
        // derived and costed (precision changes power), and still counts
        // as an evaluation; only the accuracy pass is skipped. The all-zero
        // root itself always takes the measured path below (also keeps the
        // recursive root lookup here terminating).
        if self.cfg.bound_triage && config.iter().any(|&v| v != 0) && self.config_legal(config) {
            let info = self.bound_info(config);
            if info.certified_exact {
                let root = self.evaluate(&vec![0u32; self.knobs.len()]);
                let name = config_name(config);
                let model = derive_model(self.base, config, &name);
                let sim_imgs: Vec<&[u8]> = self
                    .calib
                    .images
                    .iter()
                    .take(self.cfg.power_images.max(1))
                    .map(Vec::as_slice)
                    .collect();
                let ExplorerConfig { fold, cal, device, .. } = &self.cfg;
                let cost = estimate_inference_cost(&model, fold, cal, device, &sim_imgs);
                let cand = Candidate {
                    config: config.to_vec(),
                    accuracy: root.accuracy,
                    power_mw: cost.power_mw,
                    latency_us: cost.latency_us,
                    energy_uj: cost.energy_uj,
                    // the variant analysis inside analyze_error is the same
                    // verdict CompiledModel::conv_acc_narrow would report
                    acc_narrow: info.conv_narrow,
                };
                self.cache.insert(config.to_vec(), cand.clone());
                self.evals += 1;
                self.skipped += 1;
                return cand;
            }
        }
        let name = config_name(config);
        let model = derive_model(self.base, config, &name);
        let compiled = CompiledModel::compile(Arc::new(model.clone()));
        let acc_narrow = compiled.conv_acc_narrow();
        let mut ex = BatchExecutor::new(Arc::new(compiled));
        let k = ex.out_features();
        let mut correct = 0usize;
        let mut checked = 0usize;
        for (ci, chunk) in self.calib.images.chunks(EVAL_BATCH).enumerate() {
            let refs: Vec<&[u8]> = chunk.iter().map(Vec::as_slice).collect();
            let logits = ex.run_batch(&refs);
            for (i, img) in chunk.iter().enumerate() {
                let row = &logits[i * k..(i + 1) * k];
                if checked < self.cfg.oracle_checks {
                    let want = exec::execute(&model, img);
                    assert_eq!(
                        row,
                        want.as_slice(),
                        "packed kernels diverge from the scalar oracle on '{name}'"
                    );
                    checked += 1;
                }
                if exec::argmax(row) == self.calib.labels[ci * EVAL_BATCH + i] {
                    correct += 1;
                }
            }
        }
        let accuracy = correct as f64 / self.calib.images.len() as f64;
        let sim_imgs: Vec<&[u8]> = self
            .calib
            .images
            .iter()
            .take(self.cfg.power_images.max(1))
            .map(Vec::as_slice)
            .collect();
        let ExplorerConfig { fold, cal, device, .. } = &self.cfg;
        let cost = estimate_inference_cost(&model, fold, cal, device, &sim_imgs);
        let cand = Candidate {
            config: config.to_vec(),
            accuracy,
            power_mw: cost.power_mw,
            latency_us: cost.latency_us,
            energy_uj: cost.energy_uj,
            acc_narrow,
        };
        self.cache.insert(config.to_vec(), cand.clone());
        self.evals += 1;
        self.acc_evals += 1;
        cand
    }

    /// Gate one search proposal through the static checker, then the
    /// proven logit-bound tolerance. Candidates passing both are evaluated
    /// (memoized) and returned; the rest return `None` and are never
    /// selected or emitted in either mode — with `static_prune` /
    /// `bound_triage` their evaluation is skipped entirely (and counted in
    /// [`Self::pruned_static`] / [`Self::rejected_by_bounds`]), without it
    /// the candidate is still evaluated into the archive. All modes
    /// therefore walk the same trajectory and emit the same frontier;
    /// pruning and triage only save work.
    fn probe(&mut self, config: &[u32]) -> Option<Candidate> {
        if self.config_legal(config) {
            if self.within_tolerance(config) {
                return Some(self.evaluate(config));
            }
            if self.cfg.bound_triage {
                if !self.cache.contains_key(config) {
                    self.rejected.insert(config.to_vec());
                }
            } else {
                self.evaluate(config);
            }
            return None;
        }
        if self.cfg.static_prune {
            if !self.cache.contains_key(config) {
                self.pruned.insert(config.to_vec());
            }
        } else {
            self.evaluate(config);
        }
        None
    }

    /// Run the full search and return the Pareto ladder.
    ///
    /// 1. seed the uniform baseline (so the frontier always covers its
    ///    legal rungs);
    /// 2. greedy per-layer descent from full precision: at each step take
    ///    the single-knob drop with the best energy-saved per
    ///    accuracy-lost ratio (every probed move joins the archive; moves
    ///    the static checker rejects are skipped — or, with pruning off,
    ///    evaluated but never selected);
    /// 3. local refinement around each uniform rung: single deeper drops
    ///    and pairwise exchanges, hunting configs that dominate the naive
    ///    allocation;
    /// 4. Pareto-filter the statically legal archive, thin by
    ///    epsilon-dominance, and emit the ladder sorted by accuracy (most
    ///    accurate first).
    pub fn explore(&mut self) -> Frontier {
        let mut cur = vec![0u32; self.knobs.len()];
        let mut cur_eval = self.evaluate(&cur);
        for k in 1..=self.cfg.uniform_rungs {
            let cfg = self.uniform(k as u32);
            self.probe(&cfg);
        }
        // Half a calibration sample: moves that lose nothing rank by pure
        // energy savings without dividing by zero.
        let acc_floor = 0.5 / self.calib.images.len() as f64;
        loop {
            let moves = self.single_drops(&cur);
            if moves.is_empty() {
                break;
            }
            let mut best: Option<(Vec<u32>, Candidate, f64)> = None;
            for m in moves {
                let Some(cand) = self.probe(&m) else { continue };
                let saved = cur_eval.energy_uj - cand.energy_uj;
                let lost = (cur_eval.accuracy - cand.accuracy).max(acc_floor);
                let score = saved / lost;
                if best.as_ref().is_none_or(|(_, _, s)| score > *s) {
                    best = Some((m, cand, score));
                }
            }
            // every remaining drop is statically illegal: the descent ends
            let Some((m, cand, _)) = best else { break };
            cur = m;
            cur_eval = cand;
            if cur_eval.accuracy < self.cfg.min_accuracy {
                break;
            }
        }
        for k in 1..=self.cfg.uniform_rungs {
            let u = self.uniform(k as u32);
            self.refine(&u);
        }
        self.emit()
    }

    /// All single-knob one-bit-deeper drops from `from`.
    fn single_drops(&self, from: &[u32]) -> Vec<Vec<u32>> {
        self.knobs
            .iter()
            .enumerate()
            .filter(|(i, kn)| from[*i] < kn.max)
            .map(|(i, _)| {
                let mut c = from.to_vec();
                c[i] += 1;
                c
            })
            .collect()
    }

    /// Neighborhood pass around `from`: every single deeper drop, plus
    /// every pairwise exchange (one bit deeper on knob `i`, one bit
    /// restored on knob `j`) — the reallocation moves that beat a uniform
    /// assignment at equal-or-less energy.
    fn refine(&mut self, from: &[u32]) {
        for m in self.single_drops(from) {
            self.probe(&m);
        }
        for i in 0..from.len() {
            if from[i] >= self.knobs[i].max {
                continue;
            }
            for j in 0..from.len() {
                if j == i || from[j] == 0 {
                    continue;
                }
                let mut c = from.to_vec();
                c[i] += 1;
                c[j] -= 1;
                self.probe(&c);
            }
        }
    }

    /// Pareto filter + dedup + epsilon thinning + ladder cap over the
    /// statically legal, within-tolerance archive. Illegal and
    /// over-tolerance candidates (possible in the unpruned/untriaged
    /// modes, or via direct [`Explorer::evaluate`] calls) are dropped
    /// *before* the Pareto filter so they can neither appear on the ladder
    /// nor suppress legal points as dominators.
    fn emit(&mut self) -> Frontier {
        let keys: Vec<Vec<u32>> = self.cache.keys().cloned().collect();
        let mut survivors: Vec<Candidate> = Vec::with_capacity(keys.len());
        for key in keys {
            if self.config_legal(&key) && self.within_tolerance(&key) {
                survivors.push(self.cache[&key].clone());
            }
        }
        let all: Vec<&Candidate> = survivors.iter().collect();
        let mut front: Vec<Candidate> = Vec::new();
        for &p in &all {
            if !all.iter().any(|&q| dominates(q, p)) {
                front.push(p.clone());
            }
        }
        front.sort_by(|a, b| {
            b.accuracy
                .total_cmp(&a.accuracy)
                .then(a.energy_uj.total_cmp(&b.energy_uj))
                .then(a.config.cmp(&b.config))
        });
        // Objective-identical twins both survive strict dominance; keep the
        // first in config order.
        front.dedup_by(|b, a| {
            a.accuracy == b.accuracy && a.energy_uj == b.energy_uj && a.latency_us == b.latency_us
        });
        if self.cfg.eps_accuracy > 0.0 {
            let eps = self.cfg.eps_accuracy;
            let mut kept: Vec<Candidate> = Vec::new();
            for c in front {
                if kept.last().is_none_or(|l: &Candidate| l.accuracy - c.accuracy >= eps) {
                    kept.push(c);
                }
            }
            front = kept;
        }
        if self.cfg.max_rungs > 0 && front.len() > self.cfg.max_rungs {
            let (n, m) = (front.len(), self.cfg.max_rungs);
            front = if m == 1 {
                vec![front[0].clone()]
            } else {
                (0..m).map(|i| front[i * (n - 1) / (m - 1)].clone()).collect()
            };
        }
        let points = front
            .into_iter()
            .map(|c| {
                let name = config_name(&c.config);
                let model = derive_model(self.base, &c.config, &name);
                let info = self.bound_info(&c.config);
                FrontierPoint {
                    name,
                    config: c.config,
                    accuracy: c.accuracy,
                    power_mw: c.power_mw,
                    latency_us: c.latency_us,
                    energy_uj: c.energy_uj,
                    acc_narrow: c.acc_narrow,
                    logit_bound: info.logit_bound,
                    stable_margin: info.stable_margin,
                    model,
                }
            })
            .collect();
        Frontier {
            base_profile: self.base.profile.clone(),
            points,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::{read_str, test_model_json};

    fn fast_cfg() -> ExplorerConfig {
        ExplorerConfig {
            // high parallelism keeps the per-candidate actor sim cheap
            fold: FoldingConfig {
                conv1_pe: 64,
                conv1_simd: 64,
                conv2_pe: 64,
                conv2_simd: 576,
                dense_pe: 16,
                dense_simd: 64,
                fifo_depth: 8,
            },
            power_images: 1,
            uniform_rungs: 2,
            ..Default::default()
        }
    }

    fn setup() -> (QonnxModel, CalibSet) {
        let m = read_str(&test_model_json(2, 3)).unwrap();
        let calib = CalibSet::self_labeled(&m, 24, 0xCAFE);
        (m, calib)
    }

    #[test]
    fn self_labeled_calib_scores_the_base_at_one() {
        let (m, calib) = setup();
        assert_eq!(calib.len(), 24);
        let mut ex = Explorer::new(&m, &calib, fast_cfg());
        let root = ex.evaluate(&vec![0; ex.knobs().len()]);
        assert_eq!(root.accuracy, 1.0, "fidelity labels make the root exact");
        assert!(root.power_mw > 0.0 && root.latency_us > 0.0 && root.energy_uj > 0.0);
        assert_eq!(ex.evaluations(), 1);
        // memoized: re-evaluating costs nothing
        let again = ex.evaluate(&vec![0; ex.knobs().len()]);
        assert_eq!(again, root);
        assert_eq!(ex.evaluations(), 1);
    }

    #[test]
    fn deeper_uniform_config_costs_less_energy() {
        let (m, calib) = setup();
        let mut ex = Explorer::new(&m, &calib, fast_cfg());
        let root = ex.evaluate(&vec![0; ex.knobs().len()]);
        let deep = ex.uniform(2);
        let deep_eval = ex.evaluate(&deep);
        assert!(
            deep_eval.energy_uj < root.energy_uj,
            "2-bit uniform drop must cost less: {} vs {}",
            deep_eval.energy_uj,
            root.energy_uj
        );
        assert!(deep_eval.power_mw < root.power_mw);
        // latency is folding-bound, not precision-bound (Table-1 invariant)
        assert_eq!(deep_eval.latency_us, root.latency_us);
    }

    #[test]
    fn frontier_is_sorted_covers_baseline_and_keeps_the_root() {
        let (m, calib) = setup();
        let mut ex = Explorer::new(&m, &calib, fast_cfg());
        let frontier = ex.explore();
        assert!(!frontier.is_empty());
        for w in frontier.points.windows(2) {
            assert!(w[0].accuracy > w[1].accuracy, "ladder must be sorted, strictly");
            assert!(w[0].energy_uj > w[1].energy_uj, "cheaper rungs must be cheaper");
        }
        // most accurate rung matches the best archive accuracy (the root)
        assert_eq!(frontier.points[0].accuracy, 1.0);
        // the seeded uniform baseline's *legal* rungs are always weakly
        // covered (on tiny(2, 3) the uniform(2) rung zeroes the dense
        // weights, fails the const-output rule, and is excluded by design)
        for b in ex.uniform_baseline() {
            if !ex.config_legal(&b.config) {
                continue;
            }
            assert!(
                frontier.weakly_dominates(b.accuracy, b.energy_uj, b.latency_us),
                "uniform rung (acc {}, energy {}) escaped the frontier",
                b.accuracy,
                b.energy_uj
            );
        }
        // every frontier model re-derives to the stored name
        for p in &frontier.points {
            assert_eq!(p.model.profile, p.name);
            assert_eq!(p.name, super::config_name(&p.config));
        }
    }

    #[test]
    fn static_pruning_keeps_the_frontier_and_skips_evaluations() {
        // The acceptance property: pruned and unpruned runs emit
        // byte-identical frontier JSON, and the pruned run pays strictly
        // fewer evaluations — the difference being exactly the configs the
        // static checker rejected. tiny(2, 3)'s lattice guarantees pruning
        // fires: the whole dense-drop-2 slice (incl. uniform(2)) is
        // const-output illegal.
        let (m, calib) = setup();
        let mut pruned = Explorer::new(&m, &calib, fast_cfg());
        let f_pruned = pruned.explore();
        let mut unpruned = Explorer::new(
            &m,
            &calib,
            ExplorerConfig {
                static_prune: false,
                ..fast_cfg()
            },
        );
        let f_unpruned = unpruned.explore();
        assert_eq!(
            crate::json::to_string_pretty(&f_pruned.to_json()),
            crate::json::to_string_pretty(&f_unpruned.to_json()),
            "pruning must not change the frontier"
        );
        assert_eq!(unpruned.pruned_static(), 0);
        assert!(pruned.pruned_static() > 0, "the illegal slice must be pruned");
        assert!(pruned.evaluations() < unpruned.evaluations());
        assert_eq!(
            pruned.evaluations() + pruned.pruned_static(),
            unpruned.evaluations(),
            "every skipped evaluation must be accounted for"
        );
    }

    #[test]
    fn certificate_triage_skips_accuracy_passes_and_keeps_the_frontier() {
        // bound_stress model: 1- and 2-bit conv weight drops are proven
        // bit-identical (accuracy pass provably redundant), while every
        // activation/dense drop carries a proven logit deviation >= 32 —
        // so a tolerance of 8 rejects them before evaluation. The triaged
        // and untriaged runs must emit byte-identical frontier JSON; the
        // triaged run pays strictly fewer packed-executor passes.
        let m = read_str(&crate::qonnx::bound_stress_model_json()).unwrap();
        let calib = CalibSet::self_labeled(&m, 16, 0xB0B);
        let cfg = |bound_triage: bool| ExplorerConfig {
            power_images: 1,
            uniform_rungs: 2,
            logit_bound_tolerance: Some(8),
            bound_triage,
            ..Default::default()
        };
        let mut triaged = Explorer::new(&m, &calib, cfg(true));
        let f_triaged = triaged.explore();
        let mut untriaged = Explorer::new(&m, &calib, cfg(false));
        let f_untriaged = untriaged.explore();
        assert_eq!(
            crate::json::to_string_pretty(&f_triaged.to_json()),
            crate::json::to_string_pretty(&f_untriaged.to_json()),
            "bound triage must not change the frontier"
        );
        assert!(triaged.skipped_by_bounds() > 0, "certified drops must skip");
        assert!(triaged.rejected_by_bounds() > 0, "tolerance must reject");
        assert_eq!(untriaged.skipped_by_bounds(), 0);
        assert_eq!(untriaged.rejected_by_bounds(), 0);
        assert!(triaged.accuracy_evaluations() < untriaged.accuracy_evaluations());
        assert_eq!(
            triaged.evaluations(),
            triaged.accuracy_evaluations() + triaged.skipped_by_bounds(),
            "every evaluation is either measured or certificate-skipped"
        );
        assert_eq!(
            triaged.evaluations() + triaged.rejected_by_bounds(),
            untriaged.evaluations(),
            "every skipped evaluation must be accounted for"
        );
        // certified rungs carry a zero bound and margin in the frontier
        for p in &f_triaged.points {
            if p.config.iter().all(|&v| v == 0) {
                continue;
            }
            assert_eq!(p.accuracy, f_triaged.points[0].accuracy);
            assert_eq!((p.logit_bound, p.stable_margin), (0, 0));
        }
    }

    #[test]
    fn dominance_is_strict_on_at_least_one_axis() {
        let a = Candidate {
            config: vec![0],
            accuracy: 0.9,
            power_mw: 1.0,
            latency_us: 1.0,
            energy_uj: 1.0,
            acc_narrow: vec![],
        };
        let mut b = a.clone();
        assert!(!dominates(&a, &b), "equal points never dominate");
        b.energy_uj = 2.0;
        assert!(dominates(&a, &b));
        assert!(!dominates(&b, &a));
        b.energy_uj = 0.5;
        b.accuracy = 0.95;
        assert!(!dominates(&a, &b) && !dominates(&b, &a), "trade-offs are incomparable");
    }

    #[test]
    fn max_rungs_caps_the_ladder_keeping_endpoints() {
        let (m, calib) = setup();
        let mut full = Explorer::new(&m, &calib, fast_cfg());
        let frontier = full.explore();
        if frontier.len() < 3 {
            return; // nothing to thin on this tiny model
        }
        let mut capped = Explorer::new(
            &m,
            &calib,
            ExplorerConfig {
                max_rungs: 3,
                ..fast_cfg()
            },
        );
        let thin = capped.explore();
        assert_eq!(thin.len(), 3);
        assert_eq!(thin.points[0].config, frontier.points[0].config);
        assert_eq!(
            thin.points[2].config,
            frontier.points[frontier.len() - 1].config
        );
    }
}
