//! Per-shard work deques with work stealing.
//!
//! Replaces the single mutex-guarded work queue the sharded server used to
//! fan batches out: every shard owns its own deque + condvar, the
//! dispatcher pushes to the least-loaded shard, and an idle shard steals
//! from the back of the busiest one. Shards therefore contend only when
//! (a) the dispatcher targets them or (b) they are out of local work —
//! never on a global lock while the pool is busy.
//!
//! Locking discipline: no thread ever holds two deque locks at once.
//! Routing and victim selection read lock-free per-shard length mirrors
//! (updated under the deque lock), then lock only the chosen shard; losing
//! the race to the victim's owner just means coming away empty-handed and
//! retrying.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::Duration;

/// Fallback poll for idle thieves. Pushes that create stealable backlog
/// (or target a busy/dead owner) nudge the other shards' condvars
/// directly, so this only bounds the rare lost-nudge race — a thief
/// between its scan and its wait when the nudge fires. Long enough not to
/// burn idle CPU, short enough to cap worst-case steal latency.
const STEAL_FALLBACK_POLL: Duration = Duration::from_millis(50);

struct Shard<T> {
    deque: Mutex<VecDeque<T>>,
    cv: Condvar,
    /// Lock-free mirror of `deque.len()`, updated under the deque lock.
    /// Routing and victim selection read it without touching the mutex.
    len: AtomicUsize,
    /// Owner worker died abnormally (panic). Routing skips dead shards;
    /// with stealing disabled their deques also reject new work.
    dead: AtomicBool,
    /// Owner worker is currently executing a batch (set by `pop`). Lets
    /// routing prefer a genuinely idle shard over a busy one whose deque
    /// merely happens to be empty.
    busy: AtomicBool,
}

/// N per-owner deques plus the closed flag that drives shutdown.
pub(crate) struct ShardDeques<T> {
    shards: Vec<Shard<T>>,
    steal: bool,
    closed: AtomicBool,
}

/// Where a dead shard's stranded backlog went (see
/// [`ShardDeques::mark_dead`]): `moved[i]` items were re-routed onto shard
/// `i`'s deque, `dropped` items had nowhere live to go. The caller uses it
/// to move its depth gauges so conservation holds through a death.
#[derive(Debug, Clone, PartialEq, Eq)]
pub(crate) struct DeathReport {
    pub moved: Vec<usize>,
    pub dropped: usize,
}

impl DeathReport {
    /// Total items taken off the dead shard's deque.
    pub fn total(&self) -> usize {
        self.moved.iter().sum::<usize>() + self.dropped
    }
}

impl<T> ShardDeques<T> {
    pub fn new(n: usize, steal: bool) -> Self {
        ShardDeques {
            shards: (0..n.max(1))
                .map(|_| Shard {
                    deque: Mutex::new(VecDeque::new()),
                    cv: Condvar::new(),
                    len: AtomicUsize::new(0),
                    dead: AtomicBool::new(false),
                    busy: AtomicBool::new(false),
                })
                .collect(),
            steal,
            closed: AtomicBool::new(false),
        }
    }

    /// Index of the live shard with the lightest load, ties broken by the
    /// largest `richness(i)` (the dispatcher passes each shard's remaining
    /// battery fraction, so an equally idle pool routes to the fullest
    /// cell), then lowest index. Load counts the queued backlog plus one
    /// for a batch currently executing, so an idle shard beats a busy one
    /// whose deque is momentarily empty. The deque side stays lock-free
    /// (length mirrors + flags) and the snapshot is racy by design —
    /// routing only needs to be roughly right; `richness` may take its own
    /// locks (the battery fraction reads one), so it is evaluated lazily:
    /// only load *ties* pay for it. Falls back to shard 0 if every shard
    /// is dead.
    pub fn least_loaded_by(&self, richness: impl Fn(usize) -> f64) -> usize {
        let mut best: Option<(usize, usize)> = None; // (index, load)
        let mut best_rich: Option<f64> = None; // filled on the first tie
        for (i, s) in self.shards.iter().enumerate() {
            if s.dead.load(Ordering::SeqCst) {
                continue;
            }
            let load = s.len.load(Ordering::SeqCst) + s.busy.load(Ordering::SeqCst) as usize;
            match best {
                None => {
                    best = Some((i, load));
                }
                Some((_, bl)) if load < bl => {
                    best = Some((i, load));
                    best_rich = None;
                }
                Some((bi, bl)) if load == bl => {
                    let held = *best_rich.get_or_insert_with(|| richness(bi));
                    let rich = richness(i);
                    if rich > held {
                        best = Some((i, load));
                        best_rich = Some(rich);
                    }
                }
                Some(_) => {}
            }
        }
        best.map_or(0, |(i, _)| i)
    }

    /// Enqueue onto `target`'s deque and wake it. When the owner already
    /// has a backlog, also nudge the other shards so thieves wake early
    /// instead of riding out their poll interval. Returns `false` — with
    /// the item dropped, releasing any channels it holds — when nobody
    /// would ever drain it: the pool is closed/failed, or the target's
    /// owner died and stealing is off.
    pub fn push(&self, target: usize, item: T) -> bool {
        let target = target.min(self.shards.len() - 1);
        let shard = &self.shards[target];
        let mut q = shard.deque.lock().unwrap();
        if self.closed.load(Ordering::SeqCst)
            || (!self.steal && shard.dead.load(Ordering::SeqCst))
        {
            return false; // drops `item`
        }
        q.push_back(item);
        let backlog = q.len();
        shard.len.store(backlog, Ordering::SeqCst);
        drop(q);
        shard.cv.notify_one();
        // Nudge thieves whenever the owner cannot take this item right
        // now — it has a backlog, is mid-batch, or is dead — so idle
        // shards wake immediately instead of riding out the fallback poll.
        let owner_stuck = backlog > 1
            || shard.busy.load(Ordering::SeqCst)
            || shard.dead.load(Ordering::SeqCst);
        if self.steal && owner_stuck {
            for (i, s) in self.shards.iter().enumerate() {
                if i != target {
                    s.cv.notify_one();
                }
            }
        }
        true
    }

    /// Close the pool gracefully: no pushes may follow; queued items stay
    /// for their owners to drain. Wakes every shard so each can drain what
    /// is left and exit.
    pub fn close(&self) {
        self.closed.store(true, Ordering::SeqCst);
        for s in &self.shards {
            // Taking the lock orders the store against an owner that just
            // checked `closed` under this lock and is about to wait: the
            // notify below cannot be lost.
            drop(s.deque.lock().unwrap());
            s.cv.notify_all();
        }
    }

    /// Mark the pool failed: close it AND drop whatever is still queued,
    /// releasing any channels the items hold, so producers' clients read a
    /// clean disconnect instead of hanging on work nobody will drain. Used
    /// when every consumer has died; prefer [`close`](Self::close) for
    /// graceful shutdown. Returns how many items each shard's deque held,
    /// so the caller can reconcile its depth gauges.
    pub fn fail(&self) -> Vec<usize> {
        self.closed.store(true, Ordering::SeqCst);
        self.shards
            .iter()
            .map(|s| {
                let dropped = {
                    let mut q = s.deque.lock().unwrap();
                    let n = q.len();
                    q.clear();
                    s.len.store(0, Ordering::SeqCst);
                    n
                };
                s.cv.notify_all();
                dropped
            })
            .collect()
    }

    /// Record that shard `wid`'s owner died abnormally. Routing will skip
    /// it from now on, and its stranded backlog is **eagerly re-routed** to
    /// the live shards (least-loaded first) instead of waiting on the
    /// opportunistic steal poll — with stealing off this is the only way
    /// the work survives at all. Items that cannot be placed (pool closed,
    /// or no live shard remains) are dropped, releasing any channels they
    /// hold so producers read a clean disconnect. The returned
    /// [`DeathReport`] says where every item went, for gauge
    /// reconciliation.
    pub fn mark_dead(&self, wid: usize) -> DeathReport {
        self.shards[wid].dead.store(true, Ordering::SeqCst);
        let stranded: Vec<T> = {
            let mut q = self.shards[wid].deque.lock().unwrap();
            let items = q.drain(..).collect();
            self.shards[wid].len.store(0, Ordering::SeqCst);
            items
        };
        let mut moved = vec![0usize; self.shards.len()];
        let mut dropped = 0usize;
        for item in stranded {
            // least_loaded_by skips dead shards but falls back to 0 when
            // every shard is dead — re-check before handing work to a
            // corpse. A concurrent death can still race the push; the item
            // then sits on the newly dead shard and that shard's own
            // mark_dead (or the pool-wide fail) accounts for it.
            let target = self.least_loaded_by(|_| 0.0);
            if !self.shards[target].dead.load(Ordering::SeqCst) && self.push(target, item) {
                moved[target] += 1;
            } else {
                dropped += 1; // drops `item`
            }
        }
        // wake everyone: re-routed work may now sit anywhere, and waiting
        // dispatcher-side invariants re-evaluate
        for s in &self.shards {
            s.cv.notify_all();
        }
        DeathReport { moved, dropped }
    }

    /// Bring a respawned shard back into service: routing targets it again
    /// and (with stealing off) its deque accepts pushes. The supervisor
    /// calls this immediately before spawning the replacement worker.
    pub fn revive(&self, wid: usize) {
        self.shards[wid].busy.store(false, Ordering::SeqCst);
        self.shards[wid].dead.store(false, Ordering::SeqCst);
        self.shards[wid].cv.notify_all();
    }

    /// Whether the pool has been closed (graceful) or failed.
    pub fn is_closed(&self) -> bool {
        self.closed.load(Ordering::SeqCst)
    }

    /// One non-blocking acquisition attempt for shard `wid`: own deque
    /// front first, then (if stealing is on) the back of the busiest other
    /// shard. Returns the item and the shard it came from.
    fn try_take(&self, wid: usize) -> Option<(T, usize)> {
        {
            let mut q = self.shards[wid].deque.lock().unwrap();
            if let Some(item) = q.pop_front() {
                self.shards[wid].len.store(q.len(), Ordering::SeqCst);
                return Some((item, wid));
            }
        }
        if self.steal {
            // victim = busiest other shard by its lock-free length mirror
            let mut victim: Option<(usize, usize)> = None; // (index, len)
            for (i, s) in self.shards.iter().enumerate() {
                if i == wid {
                    continue;
                }
                let len = s.len.load(Ordering::SeqCst);
                if len > 0 && victim.is_none_or(|(_, l)| len > l) {
                    victim = Some((i, len));
                }
            }
            if let Some((v, _)) = victim {
                let mut q = self.shards[v].deque.lock().unwrap();
                if let Some(item) = q.pop_back() {
                    self.shards[v].len.store(q.len(), Ordering::SeqCst);
                    return Some((item, v));
                }
            }
        }
        None
    }

    /// Block until work is available for shard `wid` or the pool is closed
    /// and drained. Returns `(item, source_shard)`; `source_shard != wid`
    /// means the item was stolen. The shard's `busy` flag is true exactly
    /// while its owner is outside this call executing a batch.
    pub fn pop(&self, wid: usize) -> Option<(T, usize)> {
        self.shards[wid].busy.store(false, Ordering::SeqCst);
        loop {
            if let Some(hit) = self.try_take(wid) {
                self.shards[wid].busy.store(true, Ordering::SeqCst);
                return Some(hit);
            }
            if self.closed.load(Ordering::SeqCst) {
                // `close` happens after the last push, so one final sweep
                // (taken after observing the flag) sees anything enqueued
                // just before it flipped.
                return self.try_take(wid);
            }
            let guard = self.shards[wid].deque.lock().unwrap();
            // Re-check `closed` under the lock: close() locks this mutex
            // before notifying, so either we see the flag here or the
            // notify arrives after we wait — never a lost wakeup.
            if guard.is_empty() && !self.closed.load(Ordering::SeqCst) {
                let guard = if self.steal {
                    // bounded wait: push nudges us for stealable work, but
                    // a nudge can race a thief between scan and wait, so a
                    // coarse fallback poll re-scans eventually
                    self.shards[wid]
                        .cv
                        .wait_timeout(guard, STEAL_FALLBACK_POLL)
                        .unwrap()
                        .0
                } else {
                    // no stealing: every push targeting us and close() both
                    // signal this condvar, so sleep untimed
                    self.shards[wid].cv.wait(guard).unwrap()
                };
                drop(guard);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn own_deque_is_fifo() {
        let q: ShardDeques<u32> = ShardDeques::new(2, true);
        q.push(0, 1);
        q.push(0, 2);
        assert_eq!(q.pop(0), Some((1, 0)));
        assert_eq!(q.pop(0), Some((2, 0)));
    }

    #[test]
    fn idle_shard_steals_from_busiest_back() {
        let q: ShardDeques<u32> = ShardDeques::new(3, true);
        q.push(0, 10); // shard 0: backlog of 2
        q.push(0, 11);
        q.push(1, 20); // shard 1: backlog of 1
        // shard 2 owns nothing -> steals from shard 0 (busiest), back end
        assert_eq!(q.pop(2), Some((11, 0)));
        // shard 0 still drains its front in order
        assert_eq!(q.pop(0), Some((10, 0)));
    }

    #[test]
    fn steal_disabled_leaves_other_deques_alone() {
        let q: ShardDeques<u32> = ShardDeques::new(2, false);
        q.push(0, 1);
        q.close();
        // shard 1 finds nothing (no stealing) and exits on the closed flag
        assert_eq!(q.pop(1), None);
        // shard 0 drains its own item, then exits
        assert_eq!(q.pop(0), Some((1, 0)));
        assert_eq!(q.pop(0), None);
    }

    #[test]
    fn least_loaded_prefers_shortest_backlog() {
        let q: ShardDeques<u32> = ShardDeques::new(3, true);
        assert_eq!(q.least_loaded_by(|_| 0.0), 0); // all empty -> lowest index
        q.push(0, 1);
        assert_eq!(q.least_loaded_by(|_| 0.0), 1);
        q.push(1, 2);
        q.push(1, 3);
        assert_eq!(q.least_loaded_by(|_| 0.0), 2);
    }

    #[test]
    fn battery_tiebreak_prefers_richest_on_equal_load() {
        let q: ShardDeques<u32> = ShardDeques::new(3, true);
        let cells = [0.0, 0.9, 0.4]; // shard 0 drained, shard 1 fullest
        assert_eq!(q.least_loaded_by(|i| cells[i]), 1);
        // load always beats richness: one queued item demotes the full cell
        q.push(1, 7);
        assert_eq!(q.least_loaded_by(|i| cells[i]), 2);
        // equal richness falls back to the lowest index
        assert_eq!(q.least_loaded_by(|_| 1.0), 0);
        // and the plain variant is the all-equal special case
        assert_eq!(q.least_loaded_by(|_| 0.0), 0);
    }

    #[test]
    fn battery_tiebreak_skips_dead_shards() {
        let q: ShardDeques<u32> = ShardDeques::new(3, true);
        let cells = [0.2, 0.9, 0.4];
        q.mark_dead(1); // the fullest cell is dead: next-fullest wins
        assert_eq!(q.least_loaded_by(|i| cells[i]), 2);
    }

    #[test]
    fn close_drains_then_ends() {
        let q: ShardDeques<u32> = ShardDeques::new(1, true);
        q.push(0, 7);
        q.close();
        assert_eq!(q.pop(0), Some((7, 0)));
        assert_eq!(q.pop(0), None);
    }

    #[test]
    fn fail_drops_queued_items_and_rejects_new_pushes() {
        let q: ShardDeques<u32> = ShardDeques::new(2, true);
        assert!(q.push(0, 1));
        // queued item was dropped, not left for a (dead) owner
        assert_eq!(q.fail(), vec![1, 0]);
        assert_eq!(q.pop(0), None);
        // late pushes are rejected, not stranded
        assert!(!q.push(0, 2));
        assert_eq!(q.pop(0), None);
        assert_eq!(q.pop(1), None);
    }

    #[test]
    fn dead_shard_is_skipped_by_routing() {
        let q: ShardDeques<u32> = ShardDeques::new(2, true);
        let report = q.mark_dead(0); // empty deque: nothing to move
        assert_eq!(report.total(), 0);
        assert_eq!(q.least_loaded_by(|_| 0.0), 1);
        // pinned pushes to a dead shard still land while stealing is on
        assert!(q.push(0, 7));
        assert_eq!(q.pop(1), Some((7, 0)));
    }

    #[test]
    fn dead_shard_backlog_is_rerouted_eagerly() {
        // Regression: a single death used to leave its backlog for the
        // opportunistic steal poll (steal on) or drop it outright (steal
        // off). Now both modes hand the stranded items to live shards at
        // death-detection time.
        for steal in [true, false] {
            let q: ShardDeques<u32> = ShardDeques::new(3, steal);
            assert!(q.push(0, 1));
            assert!(q.push(0, 2));
            assert!(q.push(0, 3));
            let report = q.mark_dead(0);
            assert_eq!(report.dropped, 0, "live shards exist: nothing drops");
            assert_eq!(report.moved[0], 0, "never re-route onto the corpse");
            assert_eq!(report.moved.iter().sum::<usize>(), 3);
            // the items are immediately poppable from live shards' own
            // deques — no steal involved (from == own wid even at steal off)
            q.close();
            let mut got = Vec::new();
            for wid in 1..3 {
                while let Some((item, from)) = q.pop(wid) {
                    assert_ne!(from, 0, "item should have left the dead deque");
                    got.push(item);
                }
            }
            got.sort_unstable();
            assert_eq!(got, vec![1, 2, 3], "steal={steal}: backlog lost");
        }
    }

    #[test]
    fn dead_shard_without_steal_rejects_new_work() {
        let q: ShardDeques<u32> = ShardDeques::new(2, false);
        assert!(q.push(0, 1));
        let report = q.mark_dead(0);
        assert_eq!(report.moved, vec![0, 1]); // backlog re-routed to shard 1
        // new work aimed at the corpse is rejected rather than stranded
        assert!(!q.push(0, 3));
        assert_eq!(q.least_loaded_by(|_| 0.0), 1);
        q.close();
        assert_eq!(q.pop(1), Some((1, 1)));
        assert_eq!(q.pop(1), None);
    }

    #[test]
    fn last_death_drops_the_backlog() {
        let q: ShardDeques<u32> = ShardDeques::new(2, true);
        assert!(q.push(0, 1));
        assert!(q.push(1, 2));
        let first = q.mark_dead(0);
        assert_eq!(first, DeathReport { moved: vec![0, 1], dropped: 0 });
        // shard 1 now holds both items; when it dies too there is nowhere
        // live left, so the items drop (releasing their channels)
        let last = q.mark_dead(1);
        assert_eq!(last, DeathReport { moved: vec![0, 0], dropped: 2 });
    }

    #[test]
    fn revive_rejoins_routing_and_serves_again() {
        let q: ShardDeques<u32> = ShardDeques::new(2, false);
        q.mark_dead(0);
        assert_eq!(q.least_loaded_by(|_| 0.0), 1);
        assert!(!q.push(0, 1), "dead + no steal rejects work");
        q.revive(0);
        assert_eq!(q.least_loaded_by(|_| 0.0), 0);
        assert!(q.push(0, 2));
        assert_eq!(q.pop(0), Some((2, 0)));
        assert!(!q.is_closed());
        q.close();
        assert!(q.is_closed());
    }

    #[test]
    fn routing_prefers_idle_over_busy_empty_shard() {
        let q: ShardDeques<u32> = ShardDeques::new(2, true);
        assert!(q.push(0, 1));
        // shard 0's owner takes the item and is now executing (busy, deque
        // empty); a genuinely idle shard must win the tie
        assert_eq!(q.pop(0), Some((1, 0)));
        assert_eq!(q.least_loaded_by(|_| 0.0), 1);
    }

    #[test]
    fn concurrent_consumers_conserve_items() {
        const ITEMS: u32 = 500;
        let q: Arc<ShardDeques<u32>> = Arc::new(ShardDeques::new(4, true));
        let mut handles = Vec::new();
        for wid in 0..4 {
            let q = q.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some((item, _from)) = q.pop(wid) {
                    got.push(item);
                }
                got
            }));
        }
        // skewed producer: everything lands on shard 0
        for i in 0..ITEMS {
            q.push(0, i);
        }
        q.close();
        let mut all: Vec<u32> = handles.into_iter().flat_map(|h| h.join().unwrap()).collect();
        all.sort_unstable();
        let want: Vec<u32> = (0..ITEMS).collect();
        assert_eq!(all, want, "items lost or duplicated");
    }
}
