//! Dynamic batcher: coalesce queued requests up to (max_batch, max_wait).
//!
//! The classic serving trade-off: bigger batches amortize dispatch overhead
//! (the AOT artifacts include a batch-8 variant), a deadline bounds the
//! latency a lonely request can pay.
//!
//! The intake channel carries [`Submission`]s rather than bare requests:
//! the `Shutdown` sentinel ends batching deterministically even while
//! detached client handles still hold `Sender` clones. Requests sent before
//! the sentinel are drained first (channel order); the batch being formed
//! when the sentinel arrives is still delivered.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use super::request::{ClassifyRequest, Submission};

#[derive(Debug, Clone)]
pub struct BatcherConfig {
    pub max_batch: usize,
    pub max_wait: Duration,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        // §Perf (EXPERIMENTS.md): max_wait was 2 ms; a synchronous client
        // pays the full wait on every request, dominating RTT. 500 us keeps
        // burst coalescing while capping the solo-client tax.
        BatcherConfig {
            max_batch: 8,
            max_wait: Duration::from_micros(500),
        }
    }
}

/// Pulls from the submission channel, forming batches.
pub struct DynamicBatcher {
    cfg: BatcherConfig,
    rx: mpsc::Receiver<Submission>,
    done: bool,
}

impl DynamicBatcher {
    pub fn new(cfg: BatcherConfig, rx: mpsc::Receiver<Submission>) -> Self {
        DynamicBatcher {
            cfg,
            rx,
            done: false,
        }
    }

    /// Block for the next batch. Returns `None` once the channel is closed
    /// and drained or the shutdown sentinel has been consumed.
    #[allow(clippy::disallowed_methods)] // wall-clock: real request-batching deadline
    pub fn next_batch(&mut self) -> Option<Vec<ClassifyRequest>> {
        if self.done {
            return None;
        }
        // Block for the first request.
        let first = match self.rx.recv() {
            Ok(Submission::Request(r)) => r,
            Ok(Submission::Shutdown) | Err(_) => {
                self.done = true;
                return None;
            }
        };
        let deadline = Instant::now() + self.cfg.max_wait;
        let mut batch = vec![first];
        // Drain whatever is already queued without waiting (burst pickup).
        while batch.len() < self.cfg.max_batch && !self.done {
            match self.rx.try_recv() {
                Ok(Submission::Request(r)) => batch.push(r),
                Ok(Submission::Shutdown) => self.done = true,
                Err(_) => break,
            }
        }
        // Then wait out the deadline only if the batch is not full yet.
        while batch.len() < self.cfg.max_batch && !self.done {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(Submission::Request(r)) => batch.push(r),
                Ok(Submission::Shutdown) => self.done = true,
                Err(_) => break,
            }
        }
        Some(batch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: u64) -> (Submission, mpsc::Receiver<super::super::ClassifyResponse>) {
        let (tx, rx) = mpsc::channel();
        (
            Submission::Request(ClassifyRequest::new(id, vec![0u8; 4], tx)),
            rx,
        )
    }

    #[test]
    fn batches_up_to_max() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig {
                max_batch: 3,
                max_wait: Duration::from_millis(50),
            },
            rx,
        );
        // Retain reply receivers for the test's lifetime (the old
        // `std::mem::forget` leaked them, hiding reply-channel bugs).
        let mut replies = Vec::new();
        for i in 0..5 {
            let (r, keep) = req(i);
            replies.push(keep);
            tx.send(r).unwrap();
        }
        let b1 = b.next_batch().unwrap();
        assert_eq!(b1.len(), 3);
        let b2 = b.next_batch().unwrap();
        assert_eq!(b2.len(), 2);
        assert_eq!(b2[0].id, 3);
        assert_eq!(replies.len(), 5);
    }

    #[test]
    fn deadline_flushes_partial_batch() {
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(5),
            },
            rx,
        );
        let (r, _keep) = req(0); // receiver retained in scope, not leaked
        tx.send(r).unwrap();
        #[allow(clippy::disallowed_methods)] // wall-clock: bounds the flush wait
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(t0.elapsed() < Duration::from_millis(200));
    }

    #[test]
    fn closed_channel_returns_none() {
        let (tx, rx) = mpsc::channel::<Submission>();
        drop(tx);
        let mut b = DynamicBatcher::new(BatcherConfig::default(), rx);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn shutdown_sentinel_flushes_queued_then_ends() {
        // Requests queued before the sentinel are still batched; the
        // sentinel ends batching even though `tx` stays alive (the detached
        // client-handle case).
        let (tx, rx) = mpsc::channel();
        let mut b = DynamicBatcher::new(
            BatcherConfig {
                max_batch: 8,
                max_wait: Duration::from_millis(50),
            },
            rx,
        );
        let mut replies = Vec::new();
        for i in 0..3 {
            let (r, keep) = req(i);
            replies.push(keep);
            tx.send(r).unwrap();
        }
        tx.send(Submission::Shutdown).unwrap();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 3);
        assert!(b.next_batch().is_none(), "sentinel must end batching");
        assert!(b.next_batch().is_none(), "done state must be sticky");
        drop(tx);
    }
}
