//! Request/response types of the classification service.

use std::sync::mpsc;
use std::time::Instant;

/// A classification request: one image, one reply channel.
pub struct ClassifyRequest {
    pub id: u64,
    /// HWC u8 input codes (28*28*1 for the paper's model).
    pub image: Vec<u8>,
    pub submitted: Instant,
    /// Pool batch-clock reading when the dispatcher enqueued this request;
    /// the serving shard's `queue.wait` trace span starts here. Stamped by
    /// the dispatcher only when tracing is on (0 otherwise).
    pub enqueued_at_batch: u64,
    pub reply: mpsc::Sender<ClassifyResponse>,
}

/// What clients push into the server's intake channel. The explicit
/// `Shutdown` sentinel lets the server close deterministically even while
/// detached [`super::ClientHandle`]s still hold `Sender` clones — without
/// it, shutdown would block until every handle was dropped.
pub enum Submission {
    Request(ClassifyRequest),
    Shutdown,
}

/// The classification answer.
#[derive(Debug, Clone)]
pub struct ClassifyResponse {
    pub id: u64,
    pub pred: usize,
    pub logits: Vec<f32>,
    /// Profile that served this request (chosen by the serving shard's own
    /// adaptation step).
    pub profile: String,
    /// Worker shard that executed the batch (its battery paid for this).
    pub shard: usize,
    /// End-to-end latency (queue + batch + execute).
    pub latency_us: u64,
}

impl ClassifyRequest {
    #[allow(clippy::disallowed_methods)] // wall-clock: request latency timestamp
    pub fn new(
        id: u64,
        image: Vec<u8>,
        reply: mpsc::Sender<ClassifyResponse>,
    ) -> Self {
        ClassifyRequest {
            id,
            image,
            submitted: Instant::now(),
            enqueued_at_batch: 0,
            reply,
        }
    }
}
