//! The adaptive inference server: request loop + profile management.
//!
//! One worker thread owns the backend (PJRT executables are not Sync-shared
//! here; single-device edge deployment matches the paper's board). Clients
//! submit via an mpsc channel; the dynamic batcher coalesces; before every
//! batch the Profile Manager re-evaluates the energy state and may switch
//! the active profile (an O(1) reconfiguration — the MDC config word).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use anyhow::Result;

use super::backend::Backend;
use super::batcher::{BatcherConfig, DynamicBatcher};
use super::manager::{EnergyMonitor, ProfileManager};
use super::request::{ClassifyRequest, ClassifyResponse};
use crate::metrics::{Counter, EventLog, Histogram};

#[derive(Debug, Clone, Default)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
}

/// Shared observable state.
#[derive(Default)]
pub struct ServerStats {
    pub requests: Counter,
    pub batches: Counter,
    pub switches: Counter,
    pub latency: Histogram,
    pub events: EventLog,
}

/// Handle to the running server.
pub struct AdaptiveServer {
    tx: mpsc::Sender<ClassifyRequest>,
    worker: Option<JoinHandle<()>>,
    pub stats: Arc<ServerStats>,
    pub energy: Arc<EnergyMonitor>,
    pub manager: Arc<ProfileManager>,
    next_id: AtomicU64,
}

impl AdaptiveServer {
    /// Spawn the worker thread. PJRT handles are not `Send`, so the backend
    /// is constructed *inside* the worker via `backend_factory`; startup
    /// errors (missing profiles, artifact problems) are reported back
    /// synchronously before `start` returns. The backend must contain every
    /// profile the manager can select.
    pub fn start(
        cfg: ServerConfig,
        backend_factory: impl FnOnce() -> Result<Backend> + Send + 'static,
        manager: ProfileManager,
        energy: EnergyMonitor,
    ) -> Result<Self> {
        let (tx, rx) = mpsc::channel::<ClassifyRequest>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let stats = Arc::new(ServerStats::default());
        let energy = Arc::new(energy);
        let manager = Arc::new(manager);

        let w_stats = stats.clone();
        let w_energy = energy.clone();
        let w_manager = manager.clone();
        let batcher = DynamicBatcher::new(cfg.batcher.clone(), rx);
        let profile_names: Vec<String> = manager
            .profiles()
            .iter()
            .map(|p| p.name.clone())
            .collect();
        let worker = std::thread::Builder::new()
            .name("adaptive-engine".into())
            .spawn(move || {
                let backend = match backend_factory().and_then(|b| {
                    for name in &profile_names {
                        b.ensure_profile(name)?;
                    }
                    Ok(b)
                }) {
                    Ok(b) => {
                        let _ = ready_tx.send(Ok(()));
                        b
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                let mut active = w_manager.current().name.clone();
                while let Some(batch) = batcher.next_batch() {
                    // --- profile management step ---
                    let spec = w_manager.select(&w_energy).clone();
                    if spec.name != active {
                        w_stats.switches.inc();
                        w_stats.events.push(format!(
                            "switch {active} -> {} (battery {:.1}%)",
                            spec.name,
                            w_energy.remaining_fraction() * 100.0
                        ));
                        active = spec.name.clone();
                    }
                    // --- execute ---
                    let images: Vec<&[u8]> =
                        batch.iter().map(|r| r.image.as_slice()).collect();
                    let results = match backend.classify(&active, &images) {
                        Ok(r) => r,
                        Err(e) => {
                            w_stats.events.push(format!("batch failed: {e}"));
                            continue;
                        }
                    };
                    w_stats.batches.inc();
                    // --- energy accounting + replies ---
                    for (req, (logits, pred)) in batch.into_iter().zip(results) {
                        w_energy.drain(spec.power_mw, spec.latency_us);
                        let latency_us = req.submitted.elapsed().as_micros() as u64;
                        w_stats.requests.inc();
                        w_stats.latency.record_us(latency_us);
                        let _ = req.reply.send(ClassifyResponse {
                            id: req.id,
                            pred,
                            logits,
                            profile: active.clone(),
                            latency_us,
                        });
                    }
                }
            })?;

        ready_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker died during startup"))??;
        Ok(AdaptiveServer {
            tx,
            worker: Some(worker),
            stats,
            energy,
            manager,
            next_id: AtomicU64::new(0),
        })
    }

    /// Submit one image; returns the reply receiver.
    pub fn submit(&self, image: Vec<u8>) -> mpsc::Receiver<ClassifyResponse> {
        let (rtx, rrx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // Send failure only happens at shutdown; the receiver will read Err.
        let _ = self.tx.send(ClassifyRequest::new(id, image, rtx));
        rrx
    }

    /// Submit and wait.
    pub fn classify(&self, image: Vec<u8>) -> Result<ClassifyResponse> {
        let rx = self.submit(image);
        Ok(rx.recv()?)
    }

    /// Graceful shutdown: close the queue and join the worker.
    pub fn shutdown(mut self) {
        drop(self.tx.clone()); // original tx dropped in Drop below
        if let Some(w) = self.worker.take() {
            // Dropping self.tx happens after; replace it with a dummy by
            // taking ownership: easiest is to drop the whole struct fields.
            drop(std::mem::replace(&mut self.tx, mpsc::channel().0));
            let _ = w.join();
        }
    }
}

impl Drop for AdaptiveServer {
    fn drop(&mut self) {
        // Closing tx unblocks the batcher with None; join if still running.
        drop(std::mem::replace(&mut self.tx, mpsc::channel().0));
        if let Some(w) = self.worker.take() {
            let _ = w.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::manager::{ManagerConfig, ProfileSpec};
    use super::*;
    use crate::qonnx::{read_str, test_model_json};
    use std::collections::BTreeMap;

    /// Returns (factory, input_elems). The factory is Send (models are plain
    /// data); the Backend itself is built inside the worker thread.
    fn sim_backend() -> (impl FnOnce() -> anyhow::Result<Backend> + Send, usize) {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let elems = m.input_shape.elems();
        let mut a = m.clone();
        a.profile = "hi".into();
        let mut b = m;
        b.profile = "lo".into();
        let mut models = BTreeMap::new();
        models.insert("hi".to_string(), a);
        models.insert("lo".to_string(), b);
        (move || Ok(Backend::Sim { models }), elems)
    }

    fn specs() -> Vec<ProfileSpec> {
        vec![
            ProfileSpec {
                name: "hi".into(),
                accuracy: 0.96,
                power_mw: 142.0,
                latency_us: 329.0,
            },
            ProfileSpec {
                name: "lo".into(),
                accuracy: 0.94,
                power_mw: 130.0,
                latency_us: 329.0,
            },
        ]
    }

    #[test]
    fn serves_requests_and_switches_profile() {
        let (backend, elems) = sim_backend();
        // Tiny battery: drains below 50% after a few classifications.
        // Each classification drains 142mW * 329us ~= 4.7e-5 J.
        let energy = EnergyMonitor::new(9.0e-4);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = AdaptiveServer::start(ServerConfig::default(), backend, mgr, energy)
            .unwrap();

        let img = vec![7u8; elems];
        let mut profiles_seen = Vec::new();
        for _ in 0..20 {
            let resp = srv.classify(img.clone()).unwrap();
            profiles_seen.push(resp.profile.clone());
        }
        assert_eq!(srv.stats.requests.get(), 20);
        assert!(profiles_seen.iter().any(|p| p == "hi"));
        assert!(
            profiles_seen.iter().any(|p| p == "lo"),
            "never switched to low-power: battery {:.3}",
            srv.energy.remaining_fraction()
        );
        assert!(srv.stats.switches.get() >= 1);
        // switch order: hi first, then lo (battery only drains)
        let first_lo = profiles_seen.iter().position(|p| p == "lo").unwrap();
        assert!(profiles_seen[..first_lo].iter().all(|p| p == "hi"));
        srv.shutdown();
    }

    #[test]
    fn rejects_manager_profile_missing_from_backend() {
        let (backend, _) = sim_backend();
        let bad_specs = vec![ProfileSpec {
            name: "nope".into(),
            accuracy: 1.0,
            power_mw: 1.0,
            latency_us: 1.0,
        }];
        let mgr = ProfileManager::new(ManagerConfig::default(), bad_specs);
        let energy = EnergyMonitor::new(1.0);
        assert!(
            AdaptiveServer::start(ServerConfig::default(), backend, mgr, energy).is_err()
        );
    }

    #[test]
    fn concurrent_clients() {
        let (backend, elems) = sim_backend();
        let energy = EnergyMonitor::new(1e9);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = Arc::new(
            AdaptiveServer::start(ServerConfig::default(), backend, mgr, energy).unwrap(),
        );
        let mut handles = Vec::new();
        for t in 0..4 {
            let srv = srv.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10 {
                    let img = vec![(t * 10 + i) as u8; elems];
                    let resp = srv.classify(img).unwrap();
                    assert!(resp.pred < 3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.stats.requests.get(), 40);
    }
}
