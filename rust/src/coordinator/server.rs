//! The adaptive inference server: sharded request loop + profile management.
//!
//! Architecture (one dispatcher, N worker shards):
//!
//! ```text
//! clients --mpsc--> DynamicBatcher --(dispatcher thread)--> work queue
//!                        |  select() on shared ProfileManager/EnergyMonitor
//!                        v
//!              WorkItem { batch, profile spec }
//!                        |
//!          +-------------+-------------+
//!          v             v             v
//!      worker 0      worker 1  ...  worker N-1   (each owns a Backend replica)
//! ```
//!
//! The dispatcher owns the batcher and performs the adaptation step once per
//! batch — the Profile Manager re-evaluates the energy state and may switch
//! the active profile (an O(1) reconfiguration — the MDC config word). The
//! chosen [`ProfileSpec`] rides along in the [`WorkItem`], so workers never
//! touch the shared manager. Workers pull from a shared queue (idle shards
//! pick up the next batch first), execute on their own backend replica, and
//! reply per request. Backends are constructed *inside* each worker thread
//! via the factory — PJRT handles are not `Send`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::Result;

use super::backend::Backend;
use super::batcher::{BatcherConfig, DynamicBatcher};
use super::manager::{EnergyMonitor, ProfileManager, ProfileSpec};
use super::request::{ClassifyRequest, ClassifyResponse};
use crate::metrics::{Counter, EventLog, Gauge, Histogram};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    /// Number of worker shards, each owning one backend replica (clamped to
    /// at least 1).
    pub workers: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batcher: BatcherConfig::default(),
            workers: 1,
        }
    }
}

impl ServerConfig {
    pub fn with_workers(workers: usize) -> Self {
        ServerConfig {
            workers,
            ..Default::default()
        }
    }
}

/// Shared observable state.
pub struct ServerStats {
    pub requests: Counter,
    pub batches: Counter,
    pub switches: Counter,
    pub latency: Histogram,
    pub events: EventLog,
    /// Batches handed to the work queue but not yet picked up by a shard.
    pub queue_depth: Gauge,
    /// Batches executed per worker shard; the entries sum to `batches`.
    pub worker_batches: Vec<Counter>,
}

impl ServerStats {
    fn for_workers(n: usize) -> Self {
        ServerStats {
            requests: Counter::default(),
            batches: Counter::default(),
            switches: Counter::default(),
            latency: Histogram::default(),
            events: EventLog::default(),
            queue_depth: Gauge::default(),
            worker_batches: (0..n).map(|_| Counter::default()).collect(),
        }
    }
}

impl Default for ServerStats {
    fn default() -> Self {
        Self::for_workers(1)
    }
}

/// One unit of work: a coalesced batch plus the profile the dispatcher's
/// adaptation step chose for it.
struct WorkItem {
    batch: Vec<ClassifyRequest>,
    spec: ProfileSpec,
}

/// Handle to the running server.
pub struct AdaptiveServer {
    /// Client-facing queue; `None` once closed. Taking it is the single,
    /// deterministic close of the request channel (the old code dropped a
    /// fresh clone — a no-op — and relied on a `mem::replace` dance).
    tx: Option<mpsc::Sender<ClassifyRequest>>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    pub stats: Arc<ServerStats>,
    pub energy: Arc<EnergyMonitor>,
    pub manager: Arc<ProfileManager>,
    next_id: AtomicU64,
}

impl AdaptiveServer {
    /// Spawn the dispatcher and `cfg.workers` worker shards. PJRT handles
    /// are not `Send`, so each worker constructs its own backend replica via
    /// `backend_factory` inside its thread; startup errors (missing
    /// profiles, artifact problems) from any shard are reported back
    /// synchronously before `start` returns. Every backend must contain
    /// every profile the manager can select.
    pub fn start(
        cfg: ServerConfig,
        backend_factory: impl Fn() -> Result<Backend> + Send + Sync + 'static,
        manager: ProfileManager,
        energy: EnergyMonitor,
    ) -> Result<Self> {
        let n_workers = cfg.workers.max(1);
        let (tx, rx) = mpsc::channel::<ClassifyRequest>();
        let (work_tx, work_rx) = mpsc::channel::<WorkItem>();
        // Multi-consumer work queue: shards contend on the mutex only while
        // *waiting*, never while executing a batch.
        let work_rx = Arc::new(Mutex::new(work_rx));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let stats = Arc::new(ServerStats::for_workers(n_workers));
        let energy = Arc::new(energy);
        let manager = Arc::new(manager);
        let factory = Arc::new(backend_factory);
        let profile_names: Vec<String> =
            manager.profiles().iter().map(|p| p.name.clone()).collect();

        let mut workers = Vec::with_capacity(n_workers);
        for wid in 0..n_workers {
            let factory = factory.clone();
            let work_rx = work_rx.clone();
            let ready_tx = ready_tx.clone();
            let w_stats = stats.clone();
            let w_energy = energy.clone();
            let names = profile_names.clone();
            let handle = std::thread::Builder::new()
                .name(format!("adaptive-worker-{wid}"))
                .spawn(move || {
                    let mut backend = match (*factory)().and_then(|b| {
                        for name in &names {
                            b.ensure_profile(name)?;
                        }
                        Ok(b)
                    }) {
                        Ok(b) => {
                            let _ = ready_tx.send(Ok(()));
                            b
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    // Close our readiness sender now so start() never waits
                    // on a long-lived worker.
                    drop(ready_tx);
                    loop {
                        let item = {
                            let queue = work_rx.lock().unwrap();
                            queue.recv()
                        };
                        let Ok(WorkItem { batch, spec }) = item else {
                            break; // dispatcher gone: shutdown
                        };
                        w_stats.queue_depth.dec();
                        let images: Vec<&[u8]> =
                            batch.iter().map(|r| r.image.as_slice()).collect();
                        let results = match backend.classify(&spec.name, &images) {
                            Ok(r) => r,
                            Err(e) => {
                                w_stats
                                    .events
                                    .push(format!("worker {wid}: batch failed: {e}"));
                                continue;
                            }
                        };
                        w_stats.batches.inc();
                        w_stats.worker_batches[wid].inc();
                        for (req, (logits, pred)) in batch.into_iter().zip(results) {
                            w_energy.drain(spec.power_mw, spec.latency_us);
                            let latency_us = req.submitted.elapsed().as_micros() as u64;
                            w_stats.requests.inc();
                            w_stats.latency.record_us(latency_us);
                            let _ = req.reply.send(ClassifyResponse {
                                id: req.id,
                                pred,
                                logits,
                                profile: spec.name.clone(),
                                latency_us,
                            });
                        }
                    }
                })?;
            workers.push(handle);
        }
        drop(ready_tx); // only worker threads hold readiness senders now

        // Dispatcher: batcher + shared adaptation step, fanning out to the
        // shards. Owning `work_tx` exclusively gives shutdown its cascade:
        // client queue closes -> batcher drains to None -> dispatcher exits
        // and drops `work_tx` -> workers drain the work queue and exit.
        let d_stats = stats.clone();
        let d_energy = energy.clone();
        let d_manager = manager.clone();
        let batcher = DynamicBatcher::new(cfg.batcher.clone(), rx);
        let dispatcher = std::thread::Builder::new()
            .name("adaptive-dispatch".into())
            .spawn(move || {
                let mut active = d_manager.current().name.clone();
                while let Some(batch) = batcher.next_batch() {
                    // --- profile management step (shared adaptation state) ---
                    let spec = d_manager.select(&d_energy).clone();
                    if spec.name != active {
                        d_stats.switches.inc();
                        d_stats.events.push(format!(
                            "switch {active} -> {} (battery {:.1}%)",
                            spec.name,
                            d_energy.remaining_fraction() * 100.0
                        ));
                        active = spec.name.clone();
                    }
                    d_stats.queue_depth.inc();
                    if work_tx.send(WorkItem { batch, spec }).is_err() {
                        // Every worker exited; nothing can serve. Undo the
                        // gauge and leave a trace before giving up.
                        d_stats.queue_depth.dec();
                        d_stats
                            .events
                            .push("dispatch failed: all workers exited".to_string());
                        break;
                    }
                }
            })?;

        // Wait for every shard's backend to come up.
        let mut startup_err: Option<anyhow::Error> = None;
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    startup_err.get_or_insert(e);
                }
                Err(_) => {
                    startup_err
                        .get_or_insert(anyhow::anyhow!("worker died during startup"));
                }
            }
        }
        let server = AdaptiveServer {
            tx: Some(tx),
            dispatcher: Some(dispatcher),
            workers,
            stats,
            energy,
            manager,
            next_id: AtomicU64::new(0),
        };
        if let Some(e) = startup_err {
            // Tear the pipeline down (drop joins every thread) before
            // reporting the failure.
            drop(server);
            return Err(e);
        }
        Ok(server)
    }

    /// Number of worker shards.
    pub fn workers(&self) -> usize {
        self.stats.worker_batches.len()
    }

    /// Submit one image; returns the reply receiver.
    pub fn submit(&self, image: Vec<u8>) -> mpsc::Receiver<ClassifyResponse> {
        let (rtx, rrx) = mpsc::channel();
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        // After shutdown (or on send failure) the reply sender is dropped,
        // so the receiver reads a clean Err instead of hanging.
        if let Some(tx) = &self.tx {
            let _ = tx.send(ClassifyRequest::new(id, image, rtx));
        }
        rrx
    }

    /// Submit and wait.
    pub fn classify(&self, image: Vec<u8>) -> Result<ClassifyResponse> {
        let rx = self.submit(image);
        Ok(rx.recv()?)
    }

    /// Graceful shutdown: close the queue once and join every thread.
    pub fn shutdown(mut self) {
        self.close();
    }

    /// Idempotent close: dropping the only client `Sender` closes the
    /// request queue deterministically; the dispatcher drains it and closes
    /// the work queue, which drains the worker shards.
    fn close(&mut self) {
        self.tx.take();
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for AdaptiveServer {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::super::manager::{ManagerConfig, ProfileSpec};
    use super::*;
    use crate::qonnx::{read_str, test_model_json};
    use std::collections::BTreeMap;

    /// Returns (factory, input_elems). The factory is Fn + Send + Sync
    /// (models are plain data, cloned per shard); each Backend replica is
    /// built inside its worker thread.
    fn sim_backend() -> (impl Fn() -> anyhow::Result<Backend> + Send + Sync, usize) {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let elems = m.input_shape.elems();
        let mut models = BTreeMap::new();
        models.insert("hi".to_string(), m.clone());
        models.insert("lo".to_string(), m);
        (move || Ok(Backend::sim_from_models(models.clone())), elems)
    }

    fn specs() -> Vec<ProfileSpec> {
        vec![
            ProfileSpec {
                name: "hi".into(),
                accuracy: 0.96,
                power_mw: 142.0,
                latency_us: 329.0,
            },
            ProfileSpec {
                name: "lo".into(),
                accuracy: 0.94,
                power_mw: 130.0,
                latency_us: 329.0,
            },
        ]
    }

    #[test]
    fn serves_requests_and_switches_profile() {
        let (backend, elems) = sim_backend();
        // Tiny battery: drains below 50% after a few classifications.
        // Each classification drains 142mW * 329us ~= 4.7e-5 J.
        let energy = EnergyMonitor::new(9.0e-4);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = AdaptiveServer::start(ServerConfig::default(), backend, mgr, energy)
            .unwrap();

        let img = vec![7u8; elems];
        let mut profiles_seen = Vec::new();
        for _ in 0..20 {
            let resp = srv.classify(img.clone()).unwrap();
            profiles_seen.push(resp.profile.clone());
        }
        assert_eq!(srv.stats.requests.get(), 20);
        assert!(profiles_seen.iter().any(|p| p == "hi"));
        assert!(
            profiles_seen.iter().any(|p| p == "lo"),
            "never switched to low-power: battery {:.3}",
            srv.energy.remaining_fraction()
        );
        assert!(srv.stats.switches.get() >= 1);
        // switch order: hi first, then lo (battery only drains)
        let first_lo = profiles_seen.iter().position(|p| p == "lo").unwrap();
        assert!(profiles_seen[..first_lo].iter().all(|p| p == "hi"));
        srv.shutdown();
    }

    #[test]
    fn rejects_manager_profile_missing_from_backend() {
        let (backend, _) = sim_backend();
        let bad_specs = vec![ProfileSpec {
            name: "nope".into(),
            accuracy: 1.0,
            power_mw: 1.0,
            latency_us: 1.0,
        }];
        let mgr = ProfileManager::new(ManagerConfig::default(), bad_specs);
        let energy = EnergyMonitor::new(1.0);
        assert!(
            AdaptiveServer::start(ServerConfig::default(), backend, mgr, energy).is_err()
        );
    }

    #[test]
    fn rejects_missing_profile_on_every_shard_count() {
        // The startup error must surface no matter how many shards race to
        // report it.
        for workers in [1, 3] {
            let (backend, _) = sim_backend();
            let mgr = ProfileManager::new(
                ManagerConfig::default(),
                vec![ProfileSpec {
                    name: "nope".into(),
                    accuracy: 1.0,
                    power_mw: 1.0,
                    latency_us: 1.0,
                }],
            );
            let energy = EnergyMonitor::new(1.0);
            assert!(AdaptiveServer::start(
                ServerConfig::with_workers(workers),
                backend,
                mgr,
                energy
            )
            .is_err());
        }
    }

    #[test]
    fn concurrent_clients() {
        let (backend, elems) = sim_backend();
        let energy = EnergyMonitor::new(1e9);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = Arc::new(
            AdaptiveServer::start(ServerConfig::with_workers(2), backend, mgr, energy)
                .unwrap(),
        );
        assert_eq!(srv.workers(), 2);
        let mut handles = Vec::new();
        for t in 0..4 {
            let srv = srv.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10 {
                    let img = vec![(t * 10 + i) as u8; elems];
                    let resp = srv.classify(img).unwrap();
                    assert!(resp.pred < 3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.stats.requests.get(), 40);
    }

    #[test]
    fn sharded_server_conserves_requests_under_load() {
        // 8 client threads hammer a 4-shard server across 2 profiles. Every
        // submit must get exactly one reply (all classify calls return Ok,
        // response ids are unique), per-worker batch counters must sum to
        // the global batch counter, and the queue gauge must drain to 0.
        const THREADS: usize = 8;
        const PER_THREAD: usize = 25;
        const TOTAL: usize = THREADS * PER_THREAD;

        let (backend, elems) = sim_backend();
        // Sized so the 50% threshold crossing lands mid-run (~100 requests
        // at ~4.7e-5 J each), exercising both profiles under load.
        let energy = EnergyMonitor::new(9.3e-3);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = Arc::new(
            AdaptiveServer::start(ServerConfig::with_workers(4), backend, mgr, energy)
                .unwrap(),
        );
        assert_eq!(srv.workers(), 4);

        let ids = Arc::new(Mutex::new(Vec::<u64>::new()));
        let profiles = Arc::new(Mutex::new(Vec::<String>::new()));
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let srv = srv.clone();
            let ids = ids.clone();
            let profiles = profiles.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    let img = vec![(t * PER_THREAD + i) as u8; elems];
                    let resp = srv.classify(img).expect("reply lost");
                    assert!(resp.pred < 3);
                    ids.lock().unwrap().push(resp.id);
                    profiles.lock().unwrap().push(resp.profile);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }

        // conservation: one reply per submit, no duplicates
        let mut ids = Arc::try_unwrap(ids).unwrap().into_inner().unwrap();
        assert_eq!(ids.len(), TOTAL);
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), TOTAL, "duplicate reply ids");
        assert_eq!(srv.stats.requests.get(), TOTAL as u64);

        // both profiles actually served traffic
        let profiles = profiles.lock().unwrap();
        assert!(profiles.iter().any(|p| p == "hi"), "hi never served");
        assert!(
            profiles.iter().any(|p| p == "lo"),
            "lo never served: battery {:.3}",
            srv.energy.remaining_fraction()
        );

        // per-worker counters are consistent with the global counter
        let per_worker: Vec<u64> =
            srv.stats.worker_batches.iter().map(|c| c.get()).collect();
        assert_eq!(
            per_worker.iter().sum::<u64>(),
            srv.stats.batches.get(),
            "per-worker batches {per_worker:?} do not sum to total"
        );
        assert_eq!(srv.stats.queue_depth.get(), 0, "work queue not drained");

        let srv = Arc::try_unwrap(srv).ok().expect("sole owner after join");
        srv.shutdown();
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let (backend, elems) = sim_backend();
        let energy = EnergyMonitor::new(1e9);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = AdaptiveServer::start(
            ServerConfig::with_workers(0),
            backend,
            mgr,
            energy,
        )
        .unwrap();
        assert_eq!(srv.workers(), 1);
        assert!(srv.classify(vec![0u8; elems]).is_ok());
        srv.shutdown();
    }

    #[test]
    fn drop_without_shutdown_joins_cleanly() {
        let (backend, elems) = sim_backend();
        let energy = EnergyMonitor::new(1e9);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        {
            let srv = AdaptiveServer::start(
                ServerConfig::with_workers(2),
                backend,
                mgr,
                energy,
            )
            .unwrap();
            let _ = srv.classify(vec![1u8; elems]).unwrap();
            // falls out of scope here: Drop must close the queue once and
            // join the dispatcher + both shards without hanging
        }
    }
}
