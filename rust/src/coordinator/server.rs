//! The adaptive inference server: sharded request loop + profile management.
//!
//! Architecture (one dispatcher, N worker shards, work stealing):
//!
//! ```text
//! clients --ClientHandle/Ticket--> mpsc --> DynamicBatcher
//!                                               | (dispatcher thread)
//!                                               v  push to least-loaded
//!                  +---------------+---------------+
//!                  v               v               v
//!              deque 0         deque 1    ...  deque N-1
//!                  |               |               |
//!              worker 0 <----- steal ------->  worker N-1
//!              battery 0       battery 1       battery N-1
//! ```
//!
//! Each worker shard owns a Backend replica, a local work deque, *and its
//! own energy monitor* (per-accelerator battery / power cap). The
//! adaptation step runs per shard, per batch: a shard running hot degrades
//! to a cheaper approximate profile while the others stay exact — the
//! profile rides on the reply so clients observe which fidelity served
//! them. Routing is battery-aware: equal deque depths tie-break to the
//! shard with the fullest cell, so a drained accelerator is not fed work an
//! equally idle healthy one could take. Idle shards steal from the back of
//! the busiest deque, so a skewed arrival pattern still saturates the pool
//! without a shared global queue.
//! Backends are constructed *inside* each worker thread via the factory —
//! PJRT handles are not `Send`.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use anyhow::{bail, Result};

use super::backend::Backend;
use super::batcher::{BatcherConfig, DynamicBatcher};
use super::client::{ClientHandle, Ticket};
use super::manager::{EnergyMonitor, ProfileManager};
use super::request::{ClassifyRequest, ClassifyResponse, Submission};
use super::steal::ShardDeques;
use crate::metrics::{Counter, EventLog, FloatGauge, Gauge, Histogram};
use crate::power::EnergySource;

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    /// Number of worker shards, each owning one backend replica (clamped to
    /// at least 1).
    pub workers: usize,
    /// Per-shard battery capacities in joules. `None` splits the global
    /// monitor's capacity evenly across shards; one entry broadcasts to
    /// every shard; `workers` entries set each shard explicitly.
    pub shard_capacity_j: Option<Vec<f64>>,
    /// Per-shard power cap in mW (falls back to the global monitor's cap).
    pub shard_power_cap_mw: Option<f64>,
    /// Recharge source attached to every shard's battery (each shard gets
    /// its own independent copy). The source is integrated on *virtual*
    /// time — the latency the shard's batches accumulate — so recharge,
    /// like drain, is deterministic and wall-clock free.
    pub recharge: EnergySource,
    /// Work stealing: idle shards pull from the back of the busiest deque.
    pub steal: bool,
    /// Route every batch to one shard instead of the least-loaded one
    /// (tests/benches: manufactures a skewed arrival pattern).
    pub pin_dispatch_to: Option<usize>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batcher: BatcherConfig::default(),
            workers: 1,
            shard_capacity_j: None,
            shard_power_cap_mw: None,
            recharge: EnergySource::None,
            steal: true,
            pin_dispatch_to: None,
        }
    }
}

impl ServerConfig {
    pub fn with_workers(workers: usize) -> Self {
        ServerConfig {
            workers,
            ..Default::default()
        }
    }
}

/// Shared observable state.
pub struct ServerStats {
    pub requests: Counter,
    pub batches: Counter,
    /// Profile switches summed over every shard's adaptation step.
    pub switches: Counter,
    pub latency: Histogram,
    pub events: EventLog,
    /// Batches enqueued but not yet picked up, summed over all shards.
    pub queue_depth: Gauge,
    /// Batches executed per worker shard; the entries sum to `batches`.
    pub worker_batches: Vec<Counter>,
    /// Batches each shard stole from another shard's deque.
    pub worker_steals: Vec<Counter>,
    /// Backlog currently sitting in each shard's deque.
    pub shard_depth: Vec<Gauge>,
    /// Remaining battery fraction per shard (updated after each batch).
    pub shard_battery: Vec<FloatGauge>,
    /// Joules each shard has banked from its recharge source (accumulated
    /// after each batch; stays 0 without a source).
    pub shard_recharged_j: Vec<FloatGauge>,
}

impl ServerStats {
    /// True when every queue gauge in the spine reads zero — the aggregate
    /// dispatch gauge and each shard's deque gauge. This is the spine's
    /// gauge-conservation invariant: after all in-flight work is answered
    /// (or dropped with the dead-pool accounting below), it must hold.
    /// The network front end's shed and framing-error paths are
    /// regression-tested against it: a rejected request must leave no
    /// depth increment behind.
    pub fn drained(&self) -> bool {
        self.queue_depth.get() == 0 && self.shard_depth.iter().all(|g| g.get() == 0)
    }

    fn for_workers(n: usize) -> Self {
        ServerStats {
            requests: Counter::default(),
            batches: Counter::default(),
            switches: Counter::default(),
            latency: Histogram::default(),
            events: EventLog::default(),
            queue_depth: Gauge::default(),
            worker_batches: (0..n).map(|_| Counter::default()).collect(),
            worker_steals: (0..n).map(|_| Counter::default()).collect(),
            shard_depth: (0..n).map(|_| Gauge::default()).collect(),
            shard_battery: (0..n).map(|_| FloatGauge::new(1.0)).collect(),
            shard_recharged_j: (0..n).map(|_| FloatGauge::new(0.0)).collect(),
        }
    }
}

impl Default for ServerStats {
    fn default() -> Self {
        Self::for_workers(1)
    }
}

/// Decrements the live-worker count when a worker thread exits — including
/// by panic (e.g. a malformed image tripping an executor assert). The last
/// worker out fails the pool: after a graceful shutdown the deques are
/// already empty, but after a panic cascade this drops any stranded
/// batches so their reply channels release and clients read Err instead of
/// hanging forever.
struct LiveGuard {
    live: Arc<AtomicUsize>,
    pool: Arc<ShardDeques<Vec<ClassifyRequest>>>,
    stats: Arc<ServerStats>,
}

impl Drop for LiveGuard {
    fn drop(&mut self) {
        if self.live.fetch_sub(1, Ordering::SeqCst) == 1 {
            for (i, dropped) in self.pool.fail().into_iter().enumerate() {
                self.stats.queue_depth.add(-(dropped as i64));
                self.stats.shard_depth[i].add(-(dropped as i64));
            }
        }
    }
}

/// Flags its shard dead if the worker leaves abnormally (panic). Disarmed
/// on the clean-shutdown exit path; armed drops mark the shard so routing
/// avoids it and — with stealing off — its stranded backlog is released.
struct ShardGuard {
    pool: Arc<ShardDeques<Vec<ClassifyRequest>>>,
    stats: Arc<ServerStats>,
    wid: usize,
    armed: bool,
}

impl Drop for ShardGuard {
    fn drop(&mut self) {
        if self.armed {
            let dropped = self.pool.mark_dead(self.wid);
            self.stats.queue_depth.add(-(dropped as i64));
            self.stats.shard_depth[self.wid].add(-(dropped as i64));
            self.stats
                .events
                .push(format!("worker {} died; shard marked dead", self.wid));
        }
    }
}

/// Handle to the running server.
pub struct AdaptiveServer {
    /// Client-facing queue; `None` once closed. Closing sends the explicit
    /// `Shutdown` sentinel, so shutdown stays deterministic even while
    /// detached [`ClientHandle`]s hold `Sender` clones.
    tx: Option<mpsc::Sender<Submission>>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    pub stats: Arc<ServerStats>,
    /// One energy monitor per shard (per-accelerator battery / power cap).
    pub shard_energy: Vec<Arc<EnergyMonitor>>,
    pub manager: Arc<ProfileManager>,
    next_id: Arc<AtomicU64>,
}

impl AdaptiveServer {
    /// Spawn the dispatcher and `cfg.workers` worker shards. PJRT handles
    /// are not `Send`, so each worker constructs its own backend replica via
    /// `backend_factory` inside its thread; startup errors (missing
    /// profiles, artifact problems) from any shard are reported back
    /// synchronously before `start` returns. Every backend must contain
    /// every profile the manager can select.
    ///
    /// `energy` describes the *global* budget: its capacity is split evenly
    /// into per-shard monitors unless `cfg.shard_capacity_j` overrides the
    /// split, and its power cap (if any) carries over to every shard unless
    /// `cfg.shard_power_cap_mw` overrides it.
    pub fn start(
        cfg: ServerConfig,
        backend_factory: impl Fn() -> Result<Backend> + Send + Sync + 'static,
        manager: ProfileManager,
        energy: EnergyMonitor,
    ) -> Result<Self> {
        let n_workers = cfg.workers.max(1);
        let caps: Vec<f64> = match &cfg.shard_capacity_j {
            None => vec![energy.capacity_j() / n_workers as f64; n_workers],
            Some(v) if v.len() == 1 => vec![v[0]; n_workers],
            Some(v) if v.len() == n_workers => v.clone(),
            Some(v) => bail!(
                "shard_capacity_j needs 1 or {n_workers} entries, got {}",
                v.len()
            ),
        };
        let cap_mw = cfg.shard_power_cap_mw.or(energy.power_cap_mw());
        let shard_energy: Vec<Arc<EnergyMonitor>> = caps
            .iter()
            .map(|&c| {
                let monitor = match cap_mw {
                    Some(cap) => EnergyMonitor::with_power_cap(c, cap),
                    None => EnergyMonitor::new(c),
                };
                // Every shard integrates its own copy of the recharge
                // source on its own virtual clock.
                Arc::new(monitor.with_source(cfg.recharge.clone()))
            })
            .collect();

        let (tx, rx) = mpsc::channel::<Submission>();
        let pool: Arc<ShardDeques<Vec<ClassifyRequest>>> =
            Arc::new(ShardDeques::new(n_workers, cfg.steal));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let stats = Arc::new(ServerStats::for_workers(n_workers));
        let manager = Arc::new(manager);
        let factory = Arc::new(backend_factory);
        let profile_names: Vec<String> =
            manager.profiles().iter().map(|p| p.name.clone()).collect();
        for (gauge, monitor) in stats.shard_battery.iter().zip(&shard_energy) {
            gauge.set(monitor.remaining_fraction());
        }

        let live = Arc::new(AtomicUsize::new(n_workers));
        let mut workers = Vec::with_capacity(n_workers);
        for (wid, monitor) in shard_energy.iter().enumerate() {
            let factory = factory.clone();
            let pool = pool.clone();
            let ready_tx = ready_tx.clone();
            let w_stats = stats.clone();
            let w_energy = monitor.clone();
            let w_live = live.clone();
            // Fork the shared manager: same policy + profile table, but
            // independent hysteresis state driven by this shard's battery.
            let selector = manager.fork();
            let names = profile_names.clone();
            let handle = std::thread::Builder::new()
                .name(format!("adaptive-worker-{wid}"))
                .spawn(move || {
                    let _live = LiveGuard {
                        live: w_live,
                        pool: pool.clone(),
                        stats: w_stats.clone(),
                    };
                    let mut backend = match (*factory)().and_then(|b| {
                        for name in &names {
                            b.ensure_profile(name)?;
                        }
                        Ok(b)
                    }) {
                        Ok(b) => {
                            let _ = ready_tx.send(Ok(()));
                            b
                        }
                        Err(e) => {
                            let _ = ready_tx.send(Err(e));
                            return;
                        }
                    };
                    // Close our readiness sender now so start() never waits
                    // on a long-lived worker.
                    drop(ready_tx);
                    let mut shard_guard = ShardGuard {
                        pool: pool.clone(),
                        stats: w_stats.clone(),
                        wid,
                        armed: true,
                    };
                    let mut active = selector.current().name.clone();
                    while let Some((batch, from)) = pool.pop(wid) {
                        w_stats.queue_depth.dec();
                        w_stats.shard_depth[from].dec();
                        if from != wid {
                            w_stats.worker_steals[wid].inc();
                        }
                        // --- adaptation step on THIS shard's battery ---
                        let spec = selector.select(&w_energy).clone();
                        if spec.name != active {
                            w_stats.switches.inc();
                            w_stats.events.push(format!(
                                "shard {wid}: switch {active} -> {} (battery {:.1}%)",
                                spec.name,
                                w_energy.remaining_fraction() * 100.0
                            ));
                            active = spec.name.clone();
                        }
                        // Hand the backend the whole batch: the Sim path
                        // executes it batch-major over pre-packed weights
                        // (one warm executor per profile), not image by
                        // image.
                        let imgs: Vec<&[u8]> =
                            batch.iter().map(|r| r.image.as_slice()).collect();
                        let results = match backend.run_batch(&spec.name, &imgs) {
                            Ok(r) => r,
                            Err(e) => {
                                w_stats.events.push(format!("worker {wid}: batch failed: {e}"));
                                continue;
                            }
                        };
                        w_stats.batches.inc();
                        w_stats.worker_batches[wid].inc();
                        let n_served = batch.len();
                        for (req, (logits, pred)) in batch.into_iter().zip(results) {
                            w_energy.drain(spec.power_mw, spec.latency_us);
                            let latency_us = req.submitted.elapsed().as_micros() as u64;
                            w_stats.requests.inc();
                            w_stats.latency.record_us(latency_us);
                            let _ = req.reply.send(ClassifyResponse {
                                id: req.id,
                                pred,
                                logits,
                                profile: spec.name.clone(),
                                shard: wid,
                                latency_us,
                            });
                        }
                        // Recharge on the virtual time this batch occupied
                        // the accelerator (profile latency x batch size) —
                        // deterministic, no wall clock.
                        let banked = w_energy.advance(n_served as f64 * spec.latency_us * 1e-6);
                        if banked > 0.0 {
                            w_stats.shard_recharged_j[wid].add(banked);
                        }
                        w_stats.shard_battery[wid].set(w_energy.remaining_fraction());
                    }
                    // Reached only on the clean pop() == None exit: the
                    // shard is not dead, just shut down.
                    shard_guard.armed = false;
                })?;
            workers.push(handle);
        }
        drop(ready_tx); // only worker threads hold readiness senders now

        // Dispatcher: batcher + routing. Shutdown cascade: the Shutdown
        // sentinel (or all senders dropping) ends the batcher -> dispatcher
        // exits and closes the deque pool -> shards drain and exit.
        let d_stats = stats.clone();
        let d_pool = pool.clone();
        let d_live = live.clone();
        // Battery-aware tiebreak: when deque depths tie, route to the shard
        // with the fullest cell so a drained accelerator is not handed work
        // an equally idle healthy one could take.
        let d_energy = shard_energy.clone();
        let pin = cfg.pin_dispatch_to;
        let mut batcher = DynamicBatcher::new(cfg.batcher.clone(), rx);
        let dispatcher = std::thread::Builder::new()
            .name("adaptive-dispatch".into())
            .spawn(move || {
                while let Some(batch) = batcher.next_batch() {
                    if d_live.load(Ordering::SeqCst) == 0 {
                        // Every shard died (panics, not clean shutdown):
                        // dropping the batch drops its reply senders, so
                        // waiting clients get Err instead of hanging.
                        // (Batches that were already queued are dropped by
                        // the last LiveGuard's pool.fail(), and a push that
                        // races past this check lands on the failed pool,
                        // which also drops it.)
                        d_stats
                            .events
                            .push("dispatch failed: all workers exited".to_string());
                        break;
                    }
                    let routed = pin.unwrap_or_else(|| {
                        d_pool.least_loaded_by(|i| d_energy[i].remaining_fraction())
                    });
                    let target = routed.min(n_workers - 1);
                    d_stats.queue_depth.inc();
                    d_stats.shard_depth[target].inc();
                    if !d_pool.push(target, batch) {
                        // Rejected (pool failed, or target dead with
                        // stealing off): the batch was dropped, so its
                        // clients read Err; undo the gauges.
                        d_stats.queue_depth.dec();
                        d_stats.shard_depth[target].dec();
                    }
                }
                d_pool.close();
            })?;

        // Wait for every shard's backend to come up.
        let mut startup_err: Option<anyhow::Error> = None;
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    startup_err.get_or_insert(e);
                }
                Err(_) => {
                    let died = anyhow::anyhow!("worker died during startup");
                    startup_err.get_or_insert(died);
                }
            }
        }
        let server = AdaptiveServer {
            tx: Some(tx),
            dispatcher: Some(dispatcher),
            workers,
            stats,
            shard_energy,
            manager,
            next_id: Arc::new(AtomicU64::new(0)),
        };
        if let Some(e) = startup_err {
            // Tear the pipeline down (drop joins every thread) before
            // reporting the failure.
            drop(server);
            return Err(e);
        }
        Ok(server)
    }

    /// Number of worker shards.
    pub fn workers(&self) -> usize {
        self.stats.worker_batches.len()
    }

    /// Mean remaining battery fraction over all shards.
    pub fn battery_fraction(&self) -> f64 {
        mean_battery_fraction(&self.shard_energy)
    }

    /// `tx` is `Some` for the whole `&self` lifetime: `close()` runs only
    /// from `shutdown(self)` (consumes the server) or `Drop`.
    fn tx(&self) -> &mpsc::Sender<Submission> {
        self.tx.as_ref().expect("server closed")
    }

    /// A detached, cloneable submit handle (see [`ClientHandle`]). Handles
    /// outliving the server fail cleanly: their tickets resolve to `Err`.
    pub fn client(&self) -> ClientHandle {
        ClientHandle {
            tx: self.tx().clone(),
            next_id: self.next_id.clone(),
        }
    }

    /// Submit one image without blocking; the [`Ticket`] resolves to the
    /// reply (or `Err` if the server shuts down before execution).
    pub fn submit(&self, image: Vec<u8>) -> Ticket {
        super::client::submit_via(self.tx(), &self.next_id, image)
    }

    /// Submit and wait.
    pub fn classify(&self, image: Vec<u8>) -> Result<ClassifyResponse> {
        self.submit(image).await_reply()
    }

    /// Graceful shutdown: send the sentinel once and join every thread.
    pub fn shutdown(mut self) {
        self.close();
    }

    /// Idempotent close: the `Shutdown` sentinel ends the batcher (even if
    /// detached client handles still hold senders); the dispatcher closes
    /// the deque pool, which drains the worker shards.
    fn close(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Submission::Shutdown);
        }
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

impl Drop for AdaptiveServer {
    fn drop(&mut self) {
        self.close();
    }
}

/// Mean remaining fraction over `monitors`. A server with *no* energy
/// monitors is not energy-limited at all, so the empty set reports 1.0
/// (full). (Regression: the old inline mean divided by `len().max(1)`,
/// which silently turned "unlimited energy" into 0.0 — a dead battery —
/// for the empty set.)
pub(crate) fn mean_battery_fraction(monitors: &[Arc<EnergyMonitor>]) -> f64 {
    if monitors.is_empty() {
        return 1.0;
    }
    monitors.iter().map(|e| e.remaining_fraction()).sum::<f64>() / monitors.len() as f64
}

#[cfg(test)]
mod tests {
    use super::super::manager::{ManagerConfig, ProfileSpec};
    use super::*;
    use crate::qonnx::{random_model_json, read_str, test_model_json, RandModelCfg};
    use crate::testkit::Rng;
    use std::collections::BTreeMap;
    use std::sync::Mutex;

    /// Returns (factory, input_elems). The factory is Fn + Send + Sync
    /// (models are plain data, cloned per shard); each Backend replica is
    /// built inside its worker thread.
    fn sim_backend() -> (impl Fn() -> anyhow::Result<Backend> + Send + Sync, usize) {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let elems = m.input_shape.elems();
        let mut models = BTreeMap::new();
        models.insert("hi".to_string(), m.clone());
        models.insert("lo".to_string(), m);
        (move || Ok(Backend::sim_from_models(models.clone())), elems)
    }

    /// Heavier synthetic model (same shape under both profile names) so a
    /// batch takes long enough for backlogs to form: the steal and
    /// per-shard-energy tests need the dispatcher to outrun the workers.
    fn heavy_backend() -> (impl Fn() -> anyhow::Result<Backend> + Send + Sync, usize) {
        let mut rng = Rng::new(11);
        let cfg = RandModelCfg {
            side: 16,
            cin: 3,
            blocks: vec![(16, 8, 8), (32, 8, 8)],
            classes: 10,
        };
        let m = read_str(&random_model_json(&cfg, &mut rng)).unwrap();
        let elems = m.input_shape.elems();
        let mut models = BTreeMap::new();
        models.insert("hi".to_string(), m.clone());
        models.insert("lo".to_string(), m);
        (move || Ok(Backend::sim_from_models(models.clone())), elems)
    }

    fn specs() -> Vec<ProfileSpec> {
        vec![
            ProfileSpec {
                name: "hi".into(),
                accuracy: 0.96,
                power_mw: 142.0,
                latency_us: 329.0,
            },
            ProfileSpec {
                name: "lo".into(),
                accuracy: 0.94,
                power_mw: 130.0,
                latency_us: 329.0,
            },
        ]
    }

    #[test]
    fn serves_requests_and_switches_profile() {
        let (backend, elems) = sim_backend();
        // Tiny battery: drains below 50% after a few classifications.
        // Each classification drains 142mW * 329us ~= 4.7e-5 J.
        let energy = EnergyMonitor::new(9.0e-4);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = AdaptiveServer::start(ServerConfig::default(), backend, mgr, energy).unwrap();

        let img = vec![7u8; elems];
        let mut profiles_seen = Vec::new();
        for _ in 0..20 {
            let resp = srv.classify(img.clone()).unwrap();
            profiles_seen.push(resp.profile.clone());
        }
        assert_eq!(srv.stats.requests.get(), 20);
        assert!(profiles_seen.iter().any(|p| p == "hi"));
        assert!(
            profiles_seen.iter().any(|p| p == "lo"),
            "never switched to low-power: battery {:.3}",
            srv.battery_fraction()
        );
        assert!(srv.stats.switches.get() >= 1);
        // switch order: hi first, then lo (battery only drains)
        let first_lo = profiles_seen.iter().position(|p| p == "lo").unwrap();
        assert!(profiles_seen[..first_lo].iter().all(|p| p == "hi"));
        srv.shutdown();
    }

    #[test]
    fn rejects_manager_profile_missing_from_backend() {
        let (backend, _) = sim_backend();
        let bad_specs = vec![ProfileSpec {
            name: "nope".into(),
            accuracy: 1.0,
            power_mw: 1.0,
            latency_us: 1.0,
        }];
        let mgr = ProfileManager::new(ManagerConfig::default(), bad_specs);
        let energy = EnergyMonitor::new(1.0);
        assert!(AdaptiveServer::start(ServerConfig::default(), backend, mgr, energy).is_err());
    }

    #[test]
    fn rejects_missing_profile_on_every_shard_count() {
        // The startup error must surface no matter how many shards race to
        // report it.
        for workers in [1, 3] {
            let (backend, _) = sim_backend();
            let mgr = ProfileManager::new(
                ManagerConfig::default(),
                vec![ProfileSpec {
                    name: "nope".into(),
                    accuracy: 1.0,
                    power_mw: 1.0,
                    latency_us: 1.0,
                }],
            );
            let energy = EnergyMonitor::new(1.0);
            assert!(AdaptiveServer::start(
                ServerConfig::with_workers(workers),
                backend,
                mgr,
                energy,
            )
            .is_err());
        }
    }

    #[test]
    fn rejects_mismatched_shard_capacity_list() {
        let (backend, _) = sim_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let cfg = ServerConfig {
            workers: 2,
            shard_capacity_j: Some(vec![1.0, 1.0, 1.0]),
            ..Default::default()
        };
        assert!(AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(1.0)).is_err());
    }

    #[test]
    fn concurrent_clients() {
        let (backend, elems) = sim_backend();
        let energy = EnergyMonitor::new(1e9);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = Arc::new(
            AdaptiveServer::start(ServerConfig::with_workers(2), backend, mgr, energy)
                .unwrap(),
        );
        assert_eq!(srv.workers(), 2);
        let mut handles = Vec::new();
        for t in 0..4 {
            let srv = srv.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10 {
                    let img = vec![(t * 10 + i) as u8; elems];
                    let resp = srv.classify(img).unwrap();
                    assert!(resp.pred < 3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.stats.requests.get(), 40);
    }

    #[test]
    fn sharded_server_conserves_requests_under_load() {
        // 8 client threads hammer a 4-shard server across 2 profiles. Every
        // submit must get exactly one reply (all classify calls return Ok,
        // response ids are unique), per-worker batch counters must sum to
        // the global batch counter, and the queue gauges must drain to 0.
        const THREADS: usize = 8;
        const PER_THREAD: usize = 25;
        const TOTAL: usize = THREADS * PER_THREAD;

        let (backend, elems) = sim_backend();
        // Sized so each shard's quarter of the budget crosses the 50%
        // threshold mid-run (~25 of its ~50 requests at ~4.7e-5 J each),
        // exercising both profiles under load.
        let energy = EnergyMonitor::new(9.3e-3);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = Arc::new(
            AdaptiveServer::start(ServerConfig::with_workers(4), backend, mgr, energy)
                .unwrap(),
        );
        assert_eq!(srv.workers(), 4);

        let ids = Arc::new(Mutex::new(Vec::<u64>::new()));
        let profiles = Arc::new(Mutex::new(Vec::<String>::new()));
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let srv = srv.clone();
            let ids = ids.clone();
            let profiles = profiles.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    let img = vec![(t * PER_THREAD + i) as u8; elems];
                    let resp = srv.classify(img).expect("reply lost");
                    assert!(resp.pred < 3);
                    assert!(resp.shard < 4);
                    ids.lock().unwrap().push(resp.id);
                    profiles.lock().unwrap().push(resp.profile);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }

        // conservation: one reply per submit, no duplicates
        let mut ids = Arc::try_unwrap(ids).unwrap().into_inner().unwrap();
        assert_eq!(ids.len(), TOTAL);
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), TOTAL, "duplicate reply ids");
        assert_eq!(srv.stats.requests.get(), TOTAL as u64);

        // both profiles actually served traffic
        let profiles = profiles.lock().unwrap();
        assert!(profiles.iter().any(|p| p == "hi"), "hi never served");
        assert!(
            profiles.iter().any(|p| p == "lo"),
            "lo never served: battery {:.3}",
            srv.battery_fraction()
        );

        // per-worker counters are consistent with the global counter
        let per_worker: Vec<u64> = srv.stats.worker_batches.iter().map(|c| c.get()).collect();
        assert_eq!(
            per_worker.iter().sum::<u64>(),
            srv.stats.batches.get(),
            "per-worker batches {per_worker:?} do not sum to total"
        );
        assert_eq!(srv.stats.queue_depth.get(), 0, "work queue not drained");
        for (i, g) in srv.stats.shard_depth.iter().enumerate() {
            assert_eq!(g.get(), 0, "shard {i} deque not drained");
        }

        let Ok(srv) = Arc::try_unwrap(srv) else {
            panic!("sole owner after join");
        };
        srv.shutdown();
    }

    #[test]
    fn steal_path_rebalances_skewed_arrivals() {
        // Every batch is routed to shard 0 (pinned dispatch). With work
        // stealing on, the other shards must steal and complete a nonzero
        // share, and every stolen batch must show up in their steal
        // counters.
        const N: usize = 128;
        let (backend, elems) = heavy_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let cfg = ServerConfig {
            workers: 4,
            pin_dispatch_to: Some(0),
            ..Default::default()
        };
        let srv = AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(1e9)).unwrap();
        let client = srv.client();
        let images: Vec<Vec<u8>> = (0..N).map(|i| vec![(i % 251) as u8; elems]).collect();
        let tickets = client.submit_many(images);
        assert_eq!(tickets.len(), N);
        let mut ids: Vec<u64> = tickets
            .into_iter()
            .map(|t| t.await_reply().expect("reply lost").id)
            .collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), N, "conservation: one reply per submit");

        let per_worker: Vec<u64> = srv.stats.worker_batches.iter().map(|c| c.get()).collect();
        let steals: Vec<u64> = srv.stats.worker_steals.iter().map(|c| c.get()).collect();
        assert_eq!(
            per_worker.iter().sum::<u64>(),
            srv.stats.batches.get(),
            "per-worker batches {per_worker:?} do not sum to total"
        );
        // Dispatch was pinned to shard 0, so shards 1..3 can only have
        // executed batches they stole.
        let stolen_share: u64 = per_worker[1..].iter().sum();
        assert!(
            stolen_share > 0,
            "no shard stole from the skewed backlog: \
             per-worker {per_worker:?}, steals {steals:?}"
        );
        assert_eq!(
            stolen_share,
            steals[1..].iter().sum::<u64>(),
            "pinned dispatch: every batch on shards 1..3 must be a steal"
        );
        assert_eq!(steals[0], 0, "shard 0 had nothing to steal");
        drop(client);
        srv.shutdown();
    }

    #[test]
    fn depleted_shard_degrades_alone() {
        // Shard 0 is born with an empty battery; shards 1 and 2 are full.
        // Only shard 0's replies may use the degraded profile. Stealing is
        // off so least-loaded routing alone spreads the burst: every shard
        // keeps (and must execute) what it was dealt, making the
        // every-shard-serves assertion deterministic instead of a race
        // against faster thieves.
        const N: usize = 96;
        let (backend, elems) = heavy_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let cfg = ServerConfig {
            workers: 3,
            shard_capacity_j: Some(vec![0.0, 1e9, 1e9]),
            steal: false,
            ..Default::default()
        };
        let srv = AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(1e9)).unwrap();
        assert_eq!(srv.shard_energy.len(), 3);
        assert!(srv.shard_energy[0].depleted());
        let client = srv.client();
        let tickets = client.submit_many((0..N).map(|i| vec![(i % 97) as u8; elems]));
        let mut by_shard = [0usize; 3];
        for t in tickets {
            let resp = t.await_reply().expect("reply lost");
            by_shard[resp.shard] += 1;
            if resp.shard == 0 {
                assert_eq!(resp.profile, "lo", "depleted shard must serve the degraded profile");
            } else {
                assert_eq!(
                    resp.profile,
                    "hi",
                    "healthy shard {} must stay on the exact profile",
                    resp.shard
                );
            }
        }
        assert!(by_shard.iter().all(|&n| n > 0), "every shard must serve a share: {by_shard:?}");
        assert_eq!(srv.stats.shard_battery[0].get(), 0.0);
        assert!(srv.stats.shard_battery[1].get() > 0.99);
        drop(client);
        srv.shutdown();
    }

    #[test]
    fn dispatch_tiebreak_routes_to_the_fullest_cell() {
        // Both shards are idle when the first request arrives (a cold
        // server has executed nothing), so deque depths tie at 0 and the
        // battery tiebreak must decide: the drained shard (capacity 0)
        // loses to the full one regardless of index order.
        for (caps, want_shard) in [(vec![0.0, 1e9], 1usize), (vec![1e9, 0.0], 0usize)] {
            let (backend, elems) = sim_backend();
            let mgr = ProfileManager::new(ManagerConfig::default(), specs());
            let cfg = ServerConfig {
                workers: 2,
                shard_capacity_j: Some(caps),
                steal: false,
                ..Default::default()
            };
            let srv = AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(1e9)).unwrap();
            let resp = srv.classify(vec![5u8; elems]).unwrap();
            assert_eq!(
                resp.shard, want_shard,
                "equal-depth dispatch must pick the fullest cell"
            );
            assert_eq!(resp.profile, "hi", "the full shard serves exact");
            srv.shutdown();
        }
    }

    #[test]
    fn async_client_pipeline_and_ticket_semantics() {
        let (backend, elems) = sim_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = AdaptiveServer::start(
            ServerConfig::with_workers(2),
            backend,
            mgr,
            EnergyMonitor::new(1e9),
        )
        .unwrap();
        let client = srv.client();
        let tickets = client.submit_many((0..40).map(|i| vec![i as u8; elems]));
        assert_eq!(tickets.len(), 40);
        let ids: Vec<u64> = tickets.iter().map(|t| t.id()).collect();
        // ids come from one shared counter, in submission order
        assert_eq!(ids, (0..40).collect::<Vec<u64>>());
        let mut got = Vec::new();
        for t in tickets {
            let resp = t.await_reply().unwrap();
            assert!(resp.pred < 3);
            assert!(resp.shard < 2);
            got.push(resp.id);
        }
        assert_eq!(got, ids, "each ticket resolves to its own request");
        // handles are cloneable across threads and share the id counter
        let c2 = client.clone();
        let h = std::thread::spawn(move || c2.classify(vec![1u8; elems]).unwrap().id);
        assert_eq!(h.join().unwrap(), 40);
        // pipelined convenience: replies in submission order, one per input
        let replies = client.classify_pipelined((0..10).map(|i| vec![i as u8; elems]), 4);
        assert_eq!(replies.len(), 10);
        let pipeline_ids: Vec<u64> = replies.into_iter().map(|r| r.unwrap().id).collect();
        assert_eq!(pipeline_ids, (41..51).collect::<Vec<u64>>());
        drop(client);
        srv.shutdown();
    }

    #[test]
    fn shutdown_ignores_detached_handles_and_fails_late_submits() {
        let (backend, elems) = sim_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = AdaptiveServer::start(
            ServerConfig::default(),
            backend,
            mgr,
            EnergyMonitor::new(1e9),
        )
        .unwrap();
        let client = srv.client();
        let resp = client.submit(vec![3u8; elems]).await_reply().unwrap();
        assert_eq!(resp.id, 0);
        // `client` still holds a live Sender: shutdown must not block on it
        srv.shutdown();
        let dead = client.submit(vec![4u8; elems]);
        assert!(dead.await_reply().is_err(), "post-shutdown submit must resolve to Err, not hang");
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let (backend, elems) = sim_backend();
        let energy = EnergyMonitor::new(1e9);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = AdaptiveServer::start(
            ServerConfig::with_workers(0),
            backend,
            mgr,
            energy,
        )
        .unwrap();
        assert_eq!(srv.workers(), 1);
        assert!(srv.classify(vec![0u8; elems]).is_ok());
        srv.shutdown();
    }

    #[test]
    fn empty_monitor_set_reports_full_battery() {
        // Regression: a server with no energy monitors has unlimited
        // energy — the mean must read 1.0 (full), not 0.0 (dead), which is
        // what the old `len().max(1)` divisor silently produced.
        assert_eq!(super::mean_battery_fraction(&[]), 1.0);
        let half = Arc::new(EnergyMonitor::new(10.0));
        half.drain(1000.0, 5e6); // 5 of 10 J gone
        let full = Arc::new(EnergyMonitor::new(10.0));
        let mean = super::mean_battery_fraction(&[half, full]);
        assert!((mean - 0.75).abs() < 1e-9);
    }

    #[test]
    fn shard_recovers_and_upswitches_under_recharge() {
        // One shard, a recharge source between the two profiles' draws:
        // under continuous load the battery drains on "hi" (1 W draw vs
        // 0.6 W harvest), degrades below the threshold, then *recovers* on
        // "lo" (0.2 W draw) and upswitches back — the full degrade ->
        // recover -> upswitch cycle, all on virtual time.
        let (backend, elems) = sim_backend();
        let profile_specs = vec![
            ProfileSpec {
                name: "hi".into(),
                accuracy: 0.96,
                power_mw: 1000.0,
                latency_us: 329.0,
            },
            ProfileSpec {
                name: "lo".into(),
                accuracy: 0.94,
                power_mw: 200.0,
                latency_us: 329.0,
            },
        ];
        let mgr = ProfileManager::new(ManagerConfig::default(), profile_specs);
        let cfg = ServerConfig {
            recharge: EnergySource::constant(600.0),
            ..Default::default()
        };
        // "hi" nets -400 mW x 329 us ~= -1.3e-4 J per request, so a
        // 1.5e-2 J battery crosses the 48% downswitch after ~60 requests;
        // "lo" nets +400 mW, recovering past 52% in ~5 more.
        let srv = AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(1.5e-2)).unwrap();
        let img = vec![7u8; elems];
        let mut profiles = Vec::new();
        for _ in 0..160 {
            profiles.push(srv.classify(img.clone()).unwrap().profile);
        }
        let first_lo = profiles.iter().position(|p| p == "lo").expect("never degraded");
        assert!(profiles[..first_lo].iter().all(|p| p == "hi"));
        let upswitch = profiles[first_lo..].iter().position(|p| p == "hi");
        assert!(
            upswitch.is_some(),
            "battery recovered but the profile never switched back: {:?}",
            &profiles[first_lo..]
        );
        assert!(srv.stats.switches.get() >= 2, "need a down- and an up-switch");
        assert!(
            srv.stats.shard_recharged_j[0].get() > 0.0,
            "recharge gauge never moved"
        );
        // the drain and recharge books balance on the shard's monitor
        let m = &srv.shard_energy[0];
        let rhs = m.capacity_j() - m.drained_j() + m.recharged_j();
        assert!((m.remaining_j() - rhs).abs() < 1e-12);
        assert!(m.virtual_time_s() > 0.0);
        srv.shutdown();
    }

    #[test]
    fn drop_without_shutdown_joins_cleanly() {
        let (backend, elems) = sim_backend();
        let energy = EnergyMonitor::new(1e9);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        {
            let srv = AdaptiveServer::start(
                ServerConfig::with_workers(2),
                backend,
                mgr,
                energy,
            )
            .unwrap();
            let _ = srv.classify(vec![1u8; elems]).unwrap();
            // falls out of scope here: Drop must close the queue once and
            // join the dispatcher + both shards without hanging
        }
    }
}
