//! The adaptive inference server: sharded request loop + profile management.
//!
//! Architecture (one dispatcher, N worker shards, work stealing):
//!
//! ```text
//! clients --ClientHandle/Ticket--> mpsc --> DynamicBatcher
//!                                               | (dispatcher thread)
//!                                               v  push to least-loaded
//!                  +---------------+---------------+
//!                  v               v               v
//!              deque 0         deque 1    ...  deque N-1
//!                  |               |               |
//!              worker 0 <----- steal ------->  worker N-1
//!              battery 0       battery 1       battery N-1
//! ```
//!
//! Each worker shard owns a Backend replica, a local work deque, *and its
//! own energy monitor* (per-accelerator battery / power cap). The
//! adaptation step runs per shard, per batch: a shard running hot degrades
//! to a cheaper approximate profile while the others stay exact — the
//! profile rides on the reply so clients observe which fidelity served
//! them. Routing is battery-aware: equal deque depths tie-break to the
//! shard with the fullest cell, so a drained accelerator is not fed work an
//! equally idle healthy one could take. Idle shards steal from the back of
//! the busiest deque, so a skewed arrival pattern still saturates the pool
//! without a shared global queue.
//! Backends are constructed *inside* each worker thread via the factory —
//! PJRT handles are not `Send`.
//!
//! The spine is self-healing: when a worker dies (panic, injected fault,
//! brown-out) its shard is marked dead, its stranded deque is re-routed to
//! live shards *eagerly*, and — with `ServerConfig::supervise` on — a
//! supervisor thread respawns the shard with a fresh backend replica after
//! a deterministic backoff measured in served batches, recharging a
//! browned-out cell to `restart_fraction` first. See `docs/robustness.md`
//! for the full state machine and `crate::fault` for deterministic chaos
//! injection.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Result};

use super::backend::Backend;
use super::batcher::{BatcherConfig, DynamicBatcher};
use super::client::{ClientHandle, Ticket};
use super::manager::{EnergyMonitor, ProfileManager};
use super::request::{ClassifyRequest, ClassifyResponse, Submission};
use super::steal::ShardDeques;
use crate::fault::{FaultInjector, ServerFaultKind};
use crate::metrics::{Counter, EventLog, FloatGauge, Gauge, Histogram, MetricsRegistry};
use crate::power::EnergySource;
use crate::trace::{EventKind, SpanKind, TraceCollector};

#[derive(Debug, Clone)]
pub struct ServerConfig {
    pub batcher: BatcherConfig,
    /// Number of worker shards, each owning one backend replica (clamped to
    /// at least 1).
    pub workers: usize,
    /// Per-shard battery capacities in joules. `None` splits the global
    /// monitor's capacity evenly across shards; one entry broadcasts to
    /// every shard; `workers` entries set each shard explicitly.
    pub shard_capacity_j: Option<Vec<f64>>,
    /// Per-shard power cap in mW (falls back to the global monitor's cap).
    pub shard_power_cap_mw: Option<f64>,
    /// Recharge source attached to every shard's battery (each shard gets
    /// its own independent copy). The source is integrated on *virtual*
    /// time — the latency the shard's batches accumulate — so recharge,
    /// like drain, is deterministic and wall-clock free.
    pub recharge: EnergySource,
    /// Work stealing: idle shards pull from the back of the busiest deque.
    pub steal: bool,
    /// Route every batch to one shard instead of the least-loaded one
    /// (tests/benches: manufactures a skewed arrival pattern).
    pub pin_dispatch_to: Option<usize>,
    /// Self-healing: a supervisor thread respawns a dead shard with a fresh
    /// backend replica after `restart_backoff_batches` more batches have
    /// been served pool-wide. Off restores the pre-supervision contract: a
    /// dead shard stays dead and the last death fails the whole pool.
    pub supervise: bool,
    /// Deterministic respawn backoff, measured on the pool-wide batch
    /// counter (virtual time — no wall clock). When *every* shard is down
    /// nothing advances that clock, so the supervisor respawns immediately
    /// instead of waiting on time that cannot pass.
    pub restart_backoff_batches: u64,
    /// Battery fraction a respawning shard is recharged to before it
    /// rejoins — the brown-out recovery contract, mirroring
    /// `power::CycleSimConfig::restart_fraction`. A cell still holding more
    /// than this keeps its charge (the refill never drains).
    pub restart_fraction: f64,
    /// Deterministic chaos: a shared [`FaultInjector`] every worker
    /// consults once per popped batch (see [`crate::fault`]). `None`
    /// injects nothing.
    pub faults: Option<Arc<FaultInjector>>,
    /// Request tracing: a shared [`TraceCollector`] the dispatcher, worker
    /// shards, and supervisor record spans/events into on the pool batch
    /// clock (see [`crate::trace`] and `docs/observability.md`). `None`
    /// (the default) records nothing and costs nothing on the hot path.
    pub trace: Option<Arc<TraceCollector>>,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig {
            batcher: BatcherConfig::default(),
            workers: 1,
            shard_capacity_j: None,
            shard_power_cap_mw: None,
            recharge: EnergySource::None,
            steal: true,
            pin_dispatch_to: None,
            supervise: true,
            restart_backoff_batches: 4,
            restart_fraction: 0.05,
            faults: None,
            trace: None,
        }
    }
}

impl ServerConfig {
    pub fn with_workers(workers: usize) -> Self {
        ServerConfig {
            workers,
            ..Default::default()
        }
    }
}

/// Shared observable state. Every instrument is a named handle in
/// `registry` (e.g. `serve.requests`, `serve.shard_depth.3`), so the whole
/// struct snapshots to JSON through one exposition path
/// ([`MetricsRegistry::snapshot`]) while the hot paths keep their direct
/// lock-free handles.
pub struct ServerStats {
    pub requests: Arc<Counter>,
    pub batches: Arc<Counter>,
    /// Profile switches summed over every shard's adaptation step.
    pub switches: Arc<Counter>,
    pub latency: Arc<Histogram>,
    pub events: EventLog,
    /// Batches enqueued but not yet picked up, summed over all shards.
    pub queue_depth: Arc<Gauge>,
    /// Batches executed per worker shard; the entries sum to `batches`.
    pub worker_batches: Vec<Arc<Counter>>,
    /// Batches each shard stole from another shard's deque.
    pub worker_steals: Vec<Arc<Counter>>,
    /// Backlog currently sitting in each shard's deque.
    pub shard_depth: Vec<Arc<Gauge>>,
    /// Remaining battery fraction per shard (updated after each batch).
    pub shard_battery: Vec<Arc<FloatGauge>>,
    /// Joules each shard has banked from its recharge source (accumulated
    /// after each batch; stays 0 without a source).
    pub shard_recharged_j: Vec<Arc<FloatGauge>>,
    /// Shards the supervisor has respawned after a death (panic or
    /// brown-out).
    pub restarts: Arc<Counter>,
    /// Replies that arrived after their caller stopped listening: the
    /// ticket was consumed by [`Ticket::await_reply_timeout`] expiring (or
    /// simply dropped), so the worker's send landed on a closed channel.
    /// The work was done and `requests` counts it; this counter is the
    /// audit trail for the discarded answer.
    pub late_replies: Arc<Counter>,
    /// The registry every handle above lives in — the JSON exposition path.
    pub registry: Arc<MetricsRegistry>,
}

impl ServerStats {
    /// True when every queue gauge in the spine reads zero — the aggregate
    /// dispatch gauge and each shard's deque gauge. This is the spine's
    /// gauge-conservation invariant: after all in-flight work is answered
    /// (or dropped with the dead-pool accounting below), it must hold.
    /// The network front end's shed and framing-error paths are
    /// regression-tested against it: a rejected request must leave no
    /// depth increment behind.
    pub fn drained(&self) -> bool {
        self.queue_depth.get() == 0 && self.shard_depth.iter().all(|g| g.get() == 0)
    }

    fn for_workers(n: usize) -> Self {
        let registry = Arc::new(MetricsRegistry::default());
        let shard_battery: Vec<Arc<FloatGauge>> = (0..n)
            .map(|i| registry.float_gauge(&format!("serve.shard_battery.{i}")))
            .collect();
        for g in &shard_battery {
            g.set(1.0);
        }
        ServerStats {
            requests: registry.counter("serve.requests"),
            batches: registry.counter("serve.batches"),
            switches: registry.counter("serve.switches"),
            latency: registry.histogram("serve.latency_us"),
            events: EventLog::default(),
            queue_depth: registry.gauge("serve.queue_depth"),
            worker_batches: (0..n)
                .map(|i| registry.counter(&format!("serve.worker_batches.{i}")))
                .collect(),
            worker_steals: (0..n)
                .map(|i| registry.counter(&format!("serve.worker_steals.{i}")))
                .collect(),
            shard_depth: (0..n)
                .map(|i| registry.gauge(&format!("serve.shard_depth.{i}")))
                .collect(),
            shard_battery,
            shard_recharged_j: (0..n)
                .map(|i| registry.float_gauge(&format!("serve.shard_recharged_j.{i}")))
                .collect(),
            restarts: registry.counter("serve.restarts"),
            late_replies: registry.counter("serve.late_replies"),
            registry,
        }
    }
}

impl Default for ServerStats {
    fn default() -> Self {
        Self::for_workers(1)
    }
}

/// Sent by a dying shard's guard to the supervisor thread.
struct DeathNotice {
    wid: usize,
    /// Pool-wide batch count at death; the respawn comes due
    /// `restart_backoff_batches` served batches later.
    at_batch: u64,
}

/// Fail the pool and reconcile the queue gauges for every batch it drops
/// (their reply channels release, so waiting clients read Err instead of
/// hanging forever).
fn fail_pool(pool: &ShardDeques<Vec<ClassifyRequest>>, stats: &ServerStats) {
    for (i, dropped) in pool.fail().into_iter().enumerate() {
        stats.queue_depth.add(-(dropped as i64));
        stats.shard_depth[i].add(-(dropped as i64));
    }
}

/// Decrements the live-worker count when a worker thread exits — including
/// by panic (e.g. a malformed image tripping an executor assert). The last
/// worker out fails the pool — unless a respawn is pending, in which case
/// the supervisor is about to bring a shard back and queued batches must
/// survive to be served by it. (A dying worker registers its pending
/// respawn in its `ShardGuard`, which is declared after this guard and so
/// drops *first*: the registration is always visible here.)
struct LiveGuard {
    live: Arc<AtomicUsize>,
    pool: Arc<ShardDeques<Vec<ClassifyRequest>>>,
    stats: Arc<ServerStats>,
    pending: Arc<AtomicUsize>,
}

impl Drop for LiveGuard {
    fn drop(&mut self) {
        if self.live.fetch_sub(1, Ordering::SeqCst) == 1
            && self.pending.load(Ordering::SeqCst) == 0
        {
            fail_pool(&self.pool, &self.stats);
        }
    }
}

/// Flags its shard dead if the worker leaves abnormally (panic). Disarmed
/// on the clean-shutdown exit path. An armed drop marks the shard so
/// routing avoids it, re-routes its stranded backlog to live shards
/// eagerly (no waiting on the steal poll), and — when supervision is on —
/// files a [`DeathNotice`] so the supervisor respawns the shard.
struct ShardGuard {
    pool: Arc<ShardDeques<Vec<ClassifyRequest>>>,
    stats: Arc<ServerStats>,
    wid: usize,
    armed: bool,
    pending: Arc<AtomicUsize>,
    death_tx: Option<mpsc::Sender<DeathNotice>>,
    trace: Option<Arc<TraceCollector>>,
}

impl Drop for ShardGuard {
    fn drop(&mut self) {
        if !self.armed {
            return;
        }
        let report = self.pool.mark_dead(self.wid);
        // The stranded backlog changed shards: move its depth gauges with
        // it. Whatever the re-route could not place was dropped (those
        // tickets resolve Err), so it leaves the aggregate gauge too.
        self.stats.shard_depth[self.wid].add(-(report.total() as i64));
        for (i, n) in report.moved.iter().enumerate() {
            self.stats.shard_depth[i].add(*n as i64);
        }
        self.stats.queue_depth.add(-(report.dropped as i64));
        let moved: usize = report.moved.iter().sum();
        self.stats.events.push(format!(
            "worker {} died; shard marked dead ({} batches re-routed, {} dropped)",
            self.wid, moved, report.dropped
        ));
        if let Some(t) = &self.trace {
            let at = self.stats.batches.get();
            let lane = t.shard_lane(self.wid);
            t.event(lane, EventKind::Death, at, None, format!("shard {}", self.wid));
            if moved > 0 || report.dropped > 0 {
                t.event(
                    lane,
                    EventKind::Reroute,
                    at,
                    None,
                    format!("{moved} batches re-routed, {} dropped", report.dropped),
                );
            }
        }
        if let Some(tx) = &self.death_tx {
            // Register the pending respawn before our LiveGuard (declared
            // first, dropped after us) can observe live == 0, so a full
            // wipe under supervision does not fail the pool.
            self.pending.fetch_add(1, Ordering::SeqCst);
            let notice = DeathNotice {
                wid: self.wid,
                at_batch: self.stats.batches.get(),
            };
            if tx.send(notice).is_err() {
                // Supervisor already gone (post-close): nobody will respawn
                // this shard, so do not hold the pool open on its account.
                self.pending.fetch_sub(1, Ordering::SeqCst);
            }
        }
    }
}

/// Everything a worker shard thread needs, bundled so the supervisor can
/// respawn a shard with the exact ingredients `start()` used.
struct WorkerCtx {
    wid: usize,
    factory: Arc<dyn Fn() -> Result<Backend> + Send + Sync>,
    pool: Arc<ShardDeques<Vec<ClassifyRequest>>>,
    stats: Arc<ServerStats>,
    monitor: Arc<EnergyMonitor>,
    live: Arc<AtomicUsize>,
    pending: Arc<AtomicUsize>,
    selector: ProfileManager,
    names: Vec<String>,
    faults: Option<Arc<FaultInjector>>,
    death_tx: Option<mpsc::Sender<DeathNotice>>,
    trace: Option<Arc<TraceCollector>>,
}

/// Spawn one worker shard thread. `ready` is `Some` on the initial spawn
/// (`start()` blocks on one readiness message per shard) and `None` on a
/// supervisor respawn — there a factory failure marks the shard dead again
/// and gives up rather than retrying a persistently failing factory.
fn spawn_worker(
    ctx: WorkerCtx,
    ready: Option<mpsc::Sender<Result<()>>>,
) -> std::io::Result<JoinHandle<()>> {
    let WorkerCtx {
        wid,
        factory,
        pool,
        stats,
        monitor,
        live,
        pending,
        selector,
        names,
        faults,
        death_tx,
        trace,
    } = ctx;
    std::thread::Builder::new()
        .name(format!("adaptive-worker-{wid}"))
        .spawn(move || {
            let _live = LiveGuard {
                live,
                pool: pool.clone(),
                stats: stats.clone(),
                pending: pending.clone(),
            };
            // Declared after _live so it drops first: a panicking worker
            // registers its pending respawn before the LiveGuard decides
            // whether the whole pool has failed.
            let mut shard_guard = ShardGuard {
                pool: pool.clone(),
                stats: stats.clone(),
                wid,
                armed: false,
                pending,
                death_tx: None,
                trace: trace.clone(),
            };
            let mut backend = match (*factory)().and_then(|b| {
                for name in &names {
                    b.ensure_profile(name)?;
                }
                Ok(b)
            }) {
                Ok(b) => {
                    if let Some(tx) = &ready {
                        let _ = tx.send(Ok(()));
                    }
                    b
                }
                Err(e) => {
                    match &ready {
                        Some(tx) => {
                            let _ = tx.send(Err(e));
                        }
                        None => {
                            // Respawn path: nobody waits on readiness. Arm
                            // the guard — it marks the shard dead again and
                            // re-routes anything dispatched since revive —
                            // but leave death_tx unset so the supervisor
                            // does not loop on a factory that cannot come
                            // back.
                            stats
                                .events
                                .push(format!("shard {wid}: respawn factory failed: {e}"));
                            shard_guard.armed = true;
                        }
                    }
                    return;
                }
            };
            // Close our readiness sender now so start() never waits on a
            // long-lived worker.
            drop(ready);
            shard_guard.armed = true;
            shard_guard.death_tx = death_tx;
            let mut active = selector.current().name.clone();
            // Reused per-batch when tracing is on: the compiled steps the
            // backend reports, feeding per-layer `kernel.layer` sub-spans.
            let mut layer_steps: Vec<(u32, &'static str)> = Vec::new();
            while let Some((batch, from)) = pool.pop(wid) {
                stats.queue_depth.dec();
                stats.shard_depth[from].dec();
                if from != wid {
                    stats.worker_steals[wid].inc();
                    if let Some(t) = &trace {
                        t.event(
                            t.shard_lane(wid),
                            EventKind::Steal,
                            stats.batches.get(),
                            None,
                            format!("from shard {from}"),
                        );
                    }
                }
                // --- deterministic fault injection (chaos harness) ---
                if let Some(inj) = &faults {
                    for kind in inj.on_batch(wid) {
                        match kind {
                            ServerFaultKind::BrownOut => {
                                // Power loss: force-drain the cell, then
                                // die. The supervisor refills it to the
                                // restart fraction before the shard
                                // rejoins, so it comes back degraded.
                                monitor.deplete();
                                stats.shard_battery[wid].set(monitor.remaining_fraction());
                                if let Some(t) = &trace {
                                    t.event(
                                        t.shard_lane(wid),
                                        EventKind::BrownOut,
                                        stats.batches.get(),
                                        None,
                                        format!("shard {wid}"),
                                    );
                                }
                                panic!("fault injection: shard {wid} brown-out");
                            }
                            ServerFaultKind::Panic => {
                                panic!("fault injection: shard {wid} panic");
                            }
                        }
                    }
                }
                // --- adaptation step on THIS shard's battery ---
                let spec = selector.select(&monitor).clone();
                if spec.name != active {
                    stats.switches.inc();
                    stats.events.push(format!(
                        "shard {wid}: switch {active} -> {} (battery {:.1}%)",
                        spec.name,
                        monitor.remaining_fraction() * 100.0
                    ));
                    if let Some(t) = &trace {
                        // The ladder orders profiles most-accurate first, so
                        // moving to a lower index is an up-switch.
                        let profs = selector.profiles();
                        let pos = |n: &str| profs.iter().position(|p| p.name == n);
                        let kind = match (pos(&active), pos(&spec.name)) {
                            (Some(old), Some(new)) if new < old => EventKind::RungUp,
                            _ => EventKind::RungDown,
                        };
                        t.event(
                            t.shard_lane(wid),
                            kind,
                            stats.batches.get(),
                            None,
                            format!("{active} -> {}", spec.name),
                        );
                    }
                    active = spec.name.clone();
                }
                // Hand the backend the whole batch: the Sim path executes
                // it batch-major over pre-packed weights (one warm executor
                // per profile), not image by image.
                let imgs: Vec<&[u8]> = batch.iter().map(|r| r.image.as_slice()).collect();
                let exec_start = stats.batches.get();
                layer_steps.clear();
                let observer = trace.as_ref().map(|_| &mut layer_steps);
                let results = match backend.run_batch_observed(&spec.name, &imgs, observer) {
                    Ok(r) => r,
                    Err(e) => {
                        stats.events.push(format!("worker {wid}: batch failed: {e}"));
                        continue;
                    }
                };
                stats.batches.inc();
                stats.worker_batches[wid].inc();
                let n_served = batch.len();
                for (req, (logits, pred)) in batch.into_iter().zip(results) {
                    monitor.drain(spec.power_mw, spec.latency_us);
                    let latency_us = req.submitted.elapsed().as_micros() as u64;
                    stats.requests.inc();
                    stats.latency.record_us(latency_us);
                    if let Some(t) = &trace {
                        // One batch tick of virtual time: `queue.wait` runs
                        // from the dispatcher's enqueue stamp to pickup, and
                        // `shard.exec` (with its per-layer sub-spans) spans
                        // the executing tick.
                        let lane = t.shard_lane(wid);
                        let waited = req.enqueued_at_batch;
                        t.span(lane, req.id, SpanKind::QueueWait, waited, exec_start);
                        t.span_detail(
                            lane,
                            req.id,
                            SpanKind::ShardExec,
                            exec_start,
                            exec_start + 1,
                            spec.name.clone(),
                        );
                        for &(layer, op) in &layer_steps {
                            t.layer_span(lane, req.id, layer, op, exec_start, exec_start + 1);
                        }
                    }
                    let sent = req.reply.send(ClassifyResponse {
                        id: req.id,
                        pred,
                        logits,
                        profile: spec.name.clone(),
                        shard: wid,
                        latency_us,
                    });
                    if sent.is_err() {
                        // The caller consumed its ticket (await timed out)
                        // or dropped it: the answer lands on a closed
                        // channel. Audit it instead of losing it silently.
                        stats.late_replies.inc();
                    }
                }
                // Recharge on the virtual time this batch occupied the
                // accelerator (profile latency x batch size) —
                // deterministic, no wall clock.
                let banked = monitor.advance(n_served as f64 * spec.latency_us * 1e-6);
                if banked > 0.0 {
                    stats.shard_recharged_j[wid].add(banked);
                }
                stats.shard_battery[wid].set(monitor.remaining_fraction());
            }
            // Reached only on the clean pop() == None exit: the shard is
            // not dead, just shut down.
            shard_guard.armed = false;
        })
}

/// Handle to the running server.
pub struct AdaptiveServer {
    /// Client-facing queue; `None` once closed. Closing sends the explicit
    /// `Shutdown` sentinel, so shutdown stays deterministic even while
    /// detached [`ClientHandle`]s hold `Sender` clones.
    tx: Option<mpsc::Sender<Submission>>,
    dispatcher: Option<JoinHandle<()>>,
    workers: Vec<JoinHandle<()>>,
    /// `Some` when `cfg.supervise`; owns every respawned worker handle.
    supervisor: Option<JoinHandle<()>>,
    pub stats: Arc<ServerStats>,
    /// One energy monitor per shard (per-accelerator battery / power cap).
    pub shard_energy: Vec<Arc<EnergyMonitor>>,
    pub manager: Arc<ProfileManager>,
    next_id: Arc<AtomicU64>,
}

impl AdaptiveServer {
    /// Spawn the dispatcher and `cfg.workers` worker shards. PJRT handles
    /// are not `Send`, so each worker constructs its own backend replica via
    /// `backend_factory` inside its thread; startup errors (missing
    /// profiles, artifact problems) from any shard are reported back
    /// synchronously before `start` returns. Every backend must contain
    /// every profile the manager can select.
    ///
    /// `energy` describes the *global* budget: its capacity is split evenly
    /// into per-shard monitors unless `cfg.shard_capacity_j` overrides the
    /// split, and its power cap (if any) carries over to every shard unless
    /// `cfg.shard_power_cap_mw` overrides it.
    pub fn start(
        cfg: ServerConfig,
        backend_factory: impl Fn() -> Result<Backend> + Send + Sync + 'static,
        manager: ProfileManager,
        energy: EnergyMonitor,
    ) -> Result<Self> {
        let n_workers = cfg.workers.max(1);
        let caps: Vec<f64> = match &cfg.shard_capacity_j {
            None => vec![energy.capacity_j() / n_workers as f64; n_workers],
            Some(v) if v.len() == 1 => vec![v[0]; n_workers],
            Some(v) if v.len() == n_workers => v.clone(),
            Some(v) => bail!(
                "shard_capacity_j needs 1 or {n_workers} entries, got {}",
                v.len()
            ),
        };
        let cap_mw = cfg.shard_power_cap_mw.or(energy.power_cap_mw());
        let shard_energy: Vec<Arc<EnergyMonitor>> = caps
            .iter()
            .map(|&c| {
                let monitor = match cap_mw {
                    Some(cap) => EnergyMonitor::with_power_cap(c, cap),
                    None => EnergyMonitor::new(c),
                };
                // Every shard integrates its own copy of the recharge
                // source on its own virtual clock.
                Arc::new(monitor.with_source(cfg.recharge.clone()))
            })
            .collect();

        let (tx, rx) = mpsc::channel::<Submission>();
        let pool: Arc<ShardDeques<Vec<ClassifyRequest>>> =
            Arc::new(ShardDeques::new(n_workers, cfg.steal));
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let stats = Arc::new(ServerStats::for_workers(n_workers));
        let manager = Arc::new(manager);
        let factory: Arc<dyn Fn() -> Result<Backend> + Send + Sync> = Arc::new(backend_factory);
        let profile_names: Vec<String> =
            manager.profiles().iter().map(|p| p.name.clone()).collect();
        for (gauge, monitor) in stats.shard_battery.iter().zip(&shard_energy) {
            gauge.set(monitor.remaining_fraction());
        }

        let live = Arc::new(AtomicUsize::new(n_workers));
        // Shards whose death was noticed but whose respawn has not happened
        // yet. While nonzero the pool must not fail and the dispatcher must
        // not give up: a worker is coming back for the queued batches.
        let pending = Arc::new(AtomicUsize::new(0));
        let (death_tx, death_rx) = mpsc::channel::<DeathNotice>();
        let mut workers = Vec::with_capacity(n_workers);
        for (wid, monitor) in shard_energy.iter().enumerate() {
            let ctx = WorkerCtx {
                wid,
                factory: factory.clone(),
                pool: pool.clone(),
                stats: stats.clone(),
                monitor: monitor.clone(),
                live: live.clone(),
                pending: pending.clone(),
                // Fork the shared manager: same policy + profile table, but
                // independent hysteresis state driven by this shard's
                // battery.
                selector: manager.fork(),
                names: profile_names.clone(),
                faults: cfg.faults.clone(),
                death_tx: cfg.supervise.then(|| death_tx.clone()),
                trace: cfg.trace.clone(),
            };
            workers.push(spawn_worker(ctx, Some(ready_tx.clone()))?);
        }
        drop(ready_tx); // only worker threads hold readiness senders now

        // Dispatcher: batcher + routing. Shutdown cascade: the Shutdown
        // sentinel (or all senders dropping) ends the batcher -> dispatcher
        // exits and closes the deque pool -> shards drain and exit.
        let d_stats = stats.clone();
        let d_pool = pool.clone();
        let d_live = live.clone();
        let d_pending = pending.clone();
        // Battery-aware tiebreak: when deque depths tie, route to the shard
        // with the fullest cell so a drained accelerator is not handed work
        // an equally idle healthy one could take.
        let d_energy = shard_energy.clone();
        let d_trace = cfg.trace.clone();
        let pin = cfg.pin_dispatch_to;
        let mut batcher = DynamicBatcher::new(cfg.batcher.clone(), rx);
        let dispatcher = std::thread::Builder::new()
            .name("adaptive-dispatch".into())
            .spawn(move || {
                while let Some(mut batch) = batcher.next_batch() {
                    if d_live.load(Ordering::SeqCst) == 0
                        && d_pending.load(Ordering::SeqCst) == 0
                    {
                        // Every shard died with no respawn pending (panics
                        // without supervision, not clean shutdown):
                        // dropping the batch drops its reply senders, so
                        // waiting clients get Err instead of hanging.
                        // (Batches that were already queued are dropped by
                        // the last LiveGuard's pool.fail(), and a push that
                        // races past this check lands on the failed pool,
                        // which also drops it. With a respawn pending the
                        // dispatcher keeps routing: a dying shard registers
                        // pending before releasing live, so this check
                        // cannot misfire mid-death.)
                        d_stats
                            .events
                            .push("dispatch failed: all workers exited".to_string());
                        break;
                    }
                    let routed = pin.unwrap_or_else(|| {
                        d_pool.least_loaded_by(|i| d_energy[i].remaining_fraction())
                    });
                    let target = routed.min(n_workers - 1);
                    if let Some(t) = &d_trace {
                        // Stamp the batch clock onto each request (the
                        // serving shard's queue.wait span starts here) and
                        // record the enqueue decision.
                        let now = d_stats.batches.get();
                        for req in &mut batch {
                            req.enqueued_at_batch = now;
                            t.span_detail(
                                t.dispatch_lane(),
                                req.id,
                                SpanKind::DispatchEnqueue,
                                now,
                                now,
                                format!("shard {target}"),
                            );
                        }
                    }
                    d_stats.queue_depth.inc();
                    d_stats.shard_depth[target].inc();
                    if !d_pool.push(target, batch) {
                        // Rejected (pool failed, or target dead with
                        // stealing off): the batch was dropped, so its
                        // clients read Err; undo the gauges.
                        d_stats.queue_depth.dec();
                        d_stats.shard_depth[target].dec();
                    }
                }
                d_pool.close();
            })?;

        // Supervisor: revives dead shards after the deterministic backoff
        // on the batch clock. It keeps its own death_tx clone so an empty
        // channel never reads as disconnection; the exit condition is pool
        // closure (shutdown or unsupervised failure).
        let supervisor = if cfg.supervise {
            let s_pool = pool.clone();
            let s_stats = stats.clone();
            let s_live = live.clone();
            let s_pending = pending.clone();
            let s_energy = shard_energy.clone();
            let s_manager = manager.clone();
            let s_factory = factory.clone();
            let s_names = profile_names.clone();
            let s_faults = cfg.faults.clone();
            let s_trace = cfg.trace.clone();
            let restart_fraction = cfg.restart_fraction;
            let backoff = cfg.restart_backoff_batches;
            let keep_tx = death_tx.clone();
            let handle = std::thread::Builder::new()
                .name("adaptive-supervisor".into())
                .spawn(move || {
                    // (wid, batch count the respawn comes due at)
                    let mut due: Vec<(usize, u64)> = Vec::new();
                    let mut spawned: Vec<JoinHandle<()>> = Vec::new();
                    loop {
                        if let Ok(n) = death_rx.recv_timeout(Duration::from_millis(10)) {
                            due.push((n.wid, n.at_batch.saturating_add(backoff)));
                        }
                        while let Ok(n) = death_rx.try_recv() {
                            due.push((n.wid, n.at_batch.saturating_add(backoff)));
                        }
                        if s_pool.is_closed() {
                            // Shutdown: abandon the queue so the pending
                            // books close.
                            while death_rx.try_recv().is_ok() {
                                s_pending.fetch_sub(1, Ordering::SeqCst);
                            }
                            for _ in due.drain(..) {
                                s_pending.fetch_sub(1, Ordering::SeqCst);
                            }
                            break;
                        }
                        let now = s_stats.batches.get();
                        // With every shard down nothing advances the batch
                        // clock: respawn immediately instead of waiting on
                        // time that cannot pass.
                        let all_dead = s_live.load(Ordering::SeqCst) == 0;
                        let mut i = 0;
                        while i < due.len() {
                            if now < due[i].1 && !all_dead {
                                i += 1;
                                continue;
                            }
                            let (wid, _) = due.swap_remove(i);
                            let monitor = s_energy[wid].clone();
                            // Brown-out recovery: recharge to the restart
                            // fraction (a no-op for a cell still holding
                            // more) so the shard rejoins degraded, not
                            // dead-on-arrival.
                            monitor.refill_to_fraction(restart_fraction);
                            s_stats.shard_battery[wid].set(monitor.remaining_fraction());
                            s_pool.revive(wid);
                            s_live.fetch_add(1, Ordering::SeqCst);
                            let ctx = WorkerCtx {
                                wid,
                                factory: s_factory.clone(),
                                pool: s_pool.clone(),
                                stats: s_stats.clone(),
                                monitor,
                                live: s_live.clone(),
                                pending: s_pending.clone(),
                                selector: s_manager.fork(),
                                names: s_names.clone(),
                                faults: s_faults.clone(),
                                death_tx: Some(keep_tx.clone()),
                                trace: s_trace.clone(),
                            };
                            match spawn_worker(ctx, None) {
                                Ok(h) => {
                                    s_stats.restarts.inc();
                                    s_stats.events.push(format!(
                                        "supervisor: shard {wid} respawned (battery {:.1}%)",
                                        s_energy[wid].remaining_fraction() * 100.0
                                    ));
                                    if let Some(t) = &s_trace {
                                        t.event(
                                            t.shard_lane(wid),
                                            EventKind::Respawn,
                                            s_stats.batches.get(),
                                            None,
                                            format!("shard {wid}"),
                                        );
                                    }
                                    spawned.push(h);
                                    s_pending.fetch_sub(1, Ordering::SeqCst);
                                }
                                Err(e) => {
                                    // Thread creation itself failed (OS
                                    // limits). Give up on the shard and,
                                    // if it was the last hope, fail the
                                    // pool like a LiveGuard would.
                                    s_stats.events.push(format!(
                                        "supervisor: shard {wid} respawn failed to spawn: {e}"
                                    ));
                                    s_pending.fetch_sub(1, Ordering::SeqCst);
                                    if s_live.fetch_sub(1, Ordering::SeqCst) == 1
                                        && s_pending.load(Ordering::SeqCst) == 0
                                    {
                                        fail_pool(&s_pool, &s_stats);
                                    }
                                }
                            }
                        }
                    }
                    for h in spawned {
                        let _ = h.join();
                    }
                })?;
            Some(handle)
        } else {
            None
        };

        // Wait for every shard's backend to come up.
        let mut startup_err: Option<anyhow::Error> = None;
        for _ in 0..n_workers {
            match ready_rx.recv() {
                Ok(Ok(())) => {}
                Ok(Err(e)) => {
                    startup_err.get_or_insert(e);
                }
                Err(_) => {
                    let died = anyhow::anyhow!("worker died during startup");
                    startup_err.get_or_insert(died);
                }
            }
        }
        let server = AdaptiveServer {
            tx: Some(tx),
            dispatcher: Some(dispatcher),
            workers,
            supervisor,
            stats,
            shard_energy,
            manager,
            next_id: Arc::new(AtomicU64::new(0)),
        };
        if let Some(e) = startup_err {
            // Tear the pipeline down (drop joins every thread) before
            // reporting the failure.
            drop(server);
            return Err(e);
        }
        Ok(server)
    }

    /// Number of worker shards.
    pub fn workers(&self) -> usize {
        self.stats.worker_batches.len()
    }

    /// Mean remaining battery fraction over all shards.
    pub fn battery_fraction(&self) -> f64 {
        mean_battery_fraction(&self.shard_energy)
    }

    /// `tx` is `Some` for the whole `&self` lifetime: `close()` runs only
    /// from `shutdown(self)` (consumes the server) or `Drop`.
    fn tx(&self) -> &mpsc::Sender<Submission> {
        self.tx.as_ref().expect("server closed")
    }

    /// A detached, cloneable submit handle (see [`ClientHandle`]). Handles
    /// outliving the server fail cleanly: their tickets resolve to `Err`.
    pub fn client(&self) -> ClientHandle {
        ClientHandle {
            tx: self.tx().clone(),
            next_id: self.next_id.clone(),
        }
    }

    /// Submit one image without blocking; the [`Ticket`] resolves to the
    /// reply (or `Err` if the server shuts down before execution).
    pub fn submit(&self, image: Vec<u8>) -> Ticket {
        super::client::submit_via(self.tx(), &self.next_id, image)
    }

    /// Submit and wait.
    pub fn classify(&self, image: Vec<u8>) -> Result<ClassifyResponse> {
        self.submit(image).await_reply()
    }

    /// Graceful shutdown: send the sentinel once and join every thread.
    pub fn shutdown(mut self) {
        self.close();
    }

    /// Idempotent close: the `Shutdown` sentinel ends the batcher (even if
    /// detached client handles still hold senders); the dispatcher closes
    /// the deque pool, which drains the worker shards.
    fn close(&mut self) {
        if let Some(tx) = self.tx.take() {
            let _ = tx.send(Submission::Shutdown);
        }
        if let Some(d) = self.dispatcher.take() {
            let _ = d.join();
        }
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        // Last: the supervisor notices the closed pool, abandons pending
        // respawns, and joins every worker it ever respawned.
        if let Some(s) = self.supervisor.take() {
            let _ = s.join();
        }
    }
}

impl Drop for AdaptiveServer {
    fn drop(&mut self) {
        self.close();
    }
}

/// Mean remaining fraction over `monitors`. A server with *no* energy
/// monitors is not energy-limited at all, so the empty set reports 1.0
/// (full). (Regression: the old inline mean divided by `len().max(1)`,
/// which silently turned "unlimited energy" into 0.0 — a dead battery —
/// for the empty set.)
pub(crate) fn mean_battery_fraction(monitors: &[Arc<EnergyMonitor>]) -> f64 {
    if monitors.is_empty() {
        return 1.0;
    }
    monitors.iter().map(|e| e.remaining_fraction()).sum::<f64>() / monitors.len() as f64
}

#[cfg(test)]
mod tests {
    use super::super::manager::{ManagerConfig, ProfileSpec};
    use super::*;
    use crate::fault::{FaultPlan, ServerFaultEvent};
    use crate::qonnx::{random_model_json, read_str, test_model_json, RandModelCfg};
    use crate::testkit::Rng;
    use std::collections::BTreeMap;
    use std::sync::Mutex;

    /// Poll `cond` for up to ~5 s (supervision acts on a 10 ms tick, so
    /// tests must tolerate a little wall-clock slack).
    #[allow(clippy::disallowed_methods)] // wall-clock: polling the supervisor tick
    fn wait_until(what: &str, cond: impl Fn() -> bool) {
        for _ in 0..500 {
            if cond() {
                return;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        panic!("timed out waiting for {what}");
    }

    /// Returns (factory, input_elems). The factory is Fn + Send + Sync
    /// (models are plain data, cloned per shard); each Backend replica is
    /// built inside its worker thread.
    fn sim_backend() -> (impl Fn() -> anyhow::Result<Backend> + Send + Sync, usize) {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let elems = m.input_shape.elems();
        let mut models = BTreeMap::new();
        models.insert("hi".to_string(), m.clone());
        models.insert("lo".to_string(), m);
        (move || Ok(Backend::sim_from_models(models.clone())), elems)
    }

    /// Heavier synthetic model (same shape under both profile names) so a
    /// batch takes long enough for backlogs to form: the steal and
    /// per-shard-energy tests need the dispatcher to outrun the workers.
    fn heavy_backend() -> (impl Fn() -> anyhow::Result<Backend> + Send + Sync, usize) {
        let mut rng = Rng::new(11);
        let cfg = RandModelCfg {
            side: 16,
            cin: 3,
            blocks: vec![(16, 8, 8), (32, 8, 8)],
            classes: 10,
        };
        let m = read_str(&random_model_json(&cfg, &mut rng)).unwrap();
        let elems = m.input_shape.elems();
        let mut models = BTreeMap::new();
        models.insert("hi".to_string(), m.clone());
        models.insert("lo".to_string(), m);
        (move || Ok(Backend::sim_from_models(models.clone())), elems)
    }

    fn specs() -> Vec<ProfileSpec> {
        vec![
            ProfileSpec {
                name: "hi".into(),
                accuracy: 0.96,
                power_mw: 142.0,
                latency_us: 329.0,
            },
            ProfileSpec {
                name: "lo".into(),
                accuracy: 0.94,
                power_mw: 130.0,
                latency_us: 329.0,
            },
        ]
    }

    #[test]
    fn serves_requests_and_switches_profile() {
        let (backend, elems) = sim_backend();
        // Tiny battery: drains below 50% after a few classifications.
        // Each classification drains 142mW * 329us ~= 4.7e-5 J.
        let energy = EnergyMonitor::new(9.0e-4);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = AdaptiveServer::start(ServerConfig::default(), backend, mgr, energy).unwrap();

        let img = vec![7u8; elems];
        let mut profiles_seen = Vec::new();
        for _ in 0..20 {
            let resp = srv.classify(img.clone()).unwrap();
            profiles_seen.push(resp.profile.clone());
        }
        assert_eq!(srv.stats.requests.get(), 20);
        assert!(profiles_seen.iter().any(|p| p == "hi"));
        assert!(
            profiles_seen.iter().any(|p| p == "lo"),
            "never switched to low-power: battery {:.3}",
            srv.battery_fraction()
        );
        assert!(srv.stats.switches.get() >= 1);
        // switch order: hi first, then lo (battery only drains)
        let first_lo = profiles_seen.iter().position(|p| p == "lo").unwrap();
        assert!(profiles_seen[..first_lo].iter().all(|p| p == "hi"));
        srv.shutdown();
    }

    #[test]
    fn rejects_manager_profile_missing_from_backend() {
        let (backend, _) = sim_backend();
        let bad_specs = vec![ProfileSpec {
            name: "nope".into(),
            accuracy: 1.0,
            power_mw: 1.0,
            latency_us: 1.0,
        }];
        let mgr = ProfileManager::new(ManagerConfig::default(), bad_specs);
        let energy = EnergyMonitor::new(1.0);
        assert!(AdaptiveServer::start(ServerConfig::default(), backend, mgr, energy).is_err());
    }

    #[test]
    fn rejects_missing_profile_on_every_shard_count() {
        // The startup error must surface no matter how many shards race to
        // report it.
        for workers in [1, 3] {
            let (backend, _) = sim_backend();
            let mgr = ProfileManager::new(
                ManagerConfig::default(),
                vec![ProfileSpec {
                    name: "nope".into(),
                    accuracy: 1.0,
                    power_mw: 1.0,
                    latency_us: 1.0,
                }],
            );
            let energy = EnergyMonitor::new(1.0);
            assert!(AdaptiveServer::start(
                ServerConfig::with_workers(workers),
                backend,
                mgr,
                energy,
            )
            .is_err());
        }
    }

    #[test]
    fn rejects_mismatched_shard_capacity_list() {
        let (backend, _) = sim_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let cfg = ServerConfig {
            workers: 2,
            shard_capacity_j: Some(vec![1.0, 1.0, 1.0]),
            ..Default::default()
        };
        assert!(AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(1.0)).is_err());
    }

    #[test]
    fn concurrent_clients() {
        let (backend, elems) = sim_backend();
        let energy = EnergyMonitor::new(1e9);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = Arc::new(
            AdaptiveServer::start(ServerConfig::with_workers(2), backend, mgr, energy)
                .unwrap(),
        );
        assert_eq!(srv.workers(), 2);
        let mut handles = Vec::new();
        for t in 0..4 {
            let srv = srv.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10 {
                    let img = vec![(t * 10 + i) as u8; elems];
                    let resp = srv.classify(img).unwrap();
                    assert!(resp.pred < 3);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(srv.stats.requests.get(), 40);
    }

    #[test]
    fn sharded_server_conserves_requests_under_load() {
        // 8 client threads hammer a 4-shard server across 2 profiles. Every
        // submit must get exactly one reply (all classify calls return Ok,
        // response ids are unique), per-worker batch counters must sum to
        // the global batch counter, and the queue gauges must drain to 0.
        const THREADS: usize = 8;
        const PER_THREAD: usize = 25;
        const TOTAL: usize = THREADS * PER_THREAD;

        let (backend, elems) = sim_backend();
        // Sized so each shard's quarter of the budget crosses the 50%
        // threshold mid-run (~25 of its ~50 requests at ~4.7e-5 J each),
        // exercising both profiles under load.
        let energy = EnergyMonitor::new(9.3e-3);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = Arc::new(
            AdaptiveServer::start(ServerConfig::with_workers(4), backend, mgr, energy)
                .unwrap(),
        );
        assert_eq!(srv.workers(), 4);

        let ids = Arc::new(Mutex::new(Vec::<u64>::new()));
        let profiles = Arc::new(Mutex::new(Vec::<String>::new()));
        let mut handles = Vec::new();
        for t in 0..THREADS {
            let srv = srv.clone();
            let ids = ids.clone();
            let profiles = profiles.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..PER_THREAD {
                    let img = vec![(t * PER_THREAD + i) as u8; elems];
                    let resp = srv.classify(img).expect("reply lost");
                    assert!(resp.pred < 3);
                    assert!(resp.shard < 4);
                    ids.lock().unwrap().push(resp.id);
                    profiles.lock().unwrap().push(resp.profile);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }

        // conservation: one reply per submit, no duplicates
        let mut ids = Arc::try_unwrap(ids).unwrap().into_inner().unwrap();
        assert_eq!(ids.len(), TOTAL);
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), TOTAL, "duplicate reply ids");
        assert_eq!(srv.stats.requests.get(), TOTAL as u64);

        // both profiles actually served traffic
        let profiles = profiles.lock().unwrap();
        assert!(profiles.iter().any(|p| p == "hi"), "hi never served");
        assert!(
            profiles.iter().any(|p| p == "lo"),
            "lo never served: battery {:.3}",
            srv.battery_fraction()
        );

        // per-worker counters are consistent with the global counter
        let per_worker: Vec<u64> = srv.stats.worker_batches.iter().map(|c| c.get()).collect();
        assert_eq!(
            per_worker.iter().sum::<u64>(),
            srv.stats.batches.get(),
            "per-worker batches {per_worker:?} do not sum to total"
        );
        assert_eq!(srv.stats.queue_depth.get(), 0, "work queue not drained");
        for (i, g) in srv.stats.shard_depth.iter().enumerate() {
            assert_eq!(g.get(), 0, "shard {i} deque not drained");
        }

        let Ok(srv) = Arc::try_unwrap(srv) else {
            panic!("sole owner after join");
        };
        srv.shutdown();
    }

    #[test]
    fn steal_path_rebalances_skewed_arrivals() {
        // Every batch is routed to shard 0 (pinned dispatch). With work
        // stealing on, the other shards must steal and complete a nonzero
        // share, and every stolen batch must show up in their steal
        // counters.
        const N: usize = 128;
        let (backend, elems) = heavy_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let cfg = ServerConfig {
            workers: 4,
            pin_dispatch_to: Some(0),
            ..Default::default()
        };
        let srv = AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(1e9)).unwrap();
        let client = srv.client();
        let images: Vec<Vec<u8>> = (0..N).map(|i| vec![(i % 251) as u8; elems]).collect();
        let tickets = client.submit_many(images);
        assert_eq!(tickets.len(), N);
        let mut ids: Vec<u64> = tickets
            .into_iter()
            .map(|t| t.await_reply().expect("reply lost").id)
            .collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), N, "conservation: one reply per submit");

        let per_worker: Vec<u64> = srv.stats.worker_batches.iter().map(|c| c.get()).collect();
        let steals: Vec<u64> = srv.stats.worker_steals.iter().map(|c| c.get()).collect();
        assert_eq!(
            per_worker.iter().sum::<u64>(),
            srv.stats.batches.get(),
            "per-worker batches {per_worker:?} do not sum to total"
        );
        // Dispatch was pinned to shard 0, so shards 1..3 can only have
        // executed batches they stole.
        let stolen_share: u64 = per_worker[1..].iter().sum();
        assert!(
            stolen_share > 0,
            "no shard stole from the skewed backlog: \
             per-worker {per_worker:?}, steals {steals:?}"
        );
        assert_eq!(
            stolen_share,
            steals[1..].iter().sum::<u64>(),
            "pinned dispatch: every batch on shards 1..3 must be a steal"
        );
        assert_eq!(steals[0], 0, "shard 0 had nothing to steal");
        drop(client);
        srv.shutdown();
    }

    #[test]
    fn depleted_shard_degrades_alone() {
        // Shard 0 is born with an empty battery; shards 1 and 2 are full.
        // Only shard 0's replies may use the degraded profile. Stealing is
        // off so least-loaded routing alone spreads the burst: every shard
        // keeps (and must execute) what it was dealt, making the
        // every-shard-serves assertion deterministic instead of a race
        // against faster thieves.
        const N: usize = 96;
        let (backend, elems) = heavy_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let cfg = ServerConfig {
            workers: 3,
            shard_capacity_j: Some(vec![0.0, 1e9, 1e9]),
            steal: false,
            ..Default::default()
        };
        let srv = AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(1e9)).unwrap();
        assert_eq!(srv.shard_energy.len(), 3);
        assert!(srv.shard_energy[0].depleted());
        let client = srv.client();
        let tickets = client.submit_many((0..N).map(|i| vec![(i % 97) as u8; elems]));
        let mut by_shard = [0usize; 3];
        for t in tickets {
            let resp = t.await_reply().expect("reply lost");
            by_shard[resp.shard] += 1;
            if resp.shard == 0 {
                assert_eq!(resp.profile, "lo", "depleted shard must serve the degraded profile");
            } else {
                assert_eq!(
                    resp.profile,
                    "hi",
                    "healthy shard {} must stay on the exact profile",
                    resp.shard
                );
            }
        }
        assert!(by_shard.iter().all(|&n| n > 0), "every shard must serve a share: {by_shard:?}");
        assert_eq!(srv.stats.shard_battery[0].get(), 0.0);
        assert!(srv.stats.shard_battery[1].get() > 0.99);
        drop(client);
        srv.shutdown();
    }

    #[test]
    fn dispatch_tiebreak_routes_to_the_fullest_cell() {
        // Both shards are idle when the first request arrives (a cold
        // server has executed nothing), so deque depths tie at 0 and the
        // battery tiebreak must decide: the drained shard (capacity 0)
        // loses to the full one regardless of index order.
        for (caps, want_shard) in [(vec![0.0, 1e9], 1usize), (vec![1e9, 0.0], 0usize)] {
            let (backend, elems) = sim_backend();
            let mgr = ProfileManager::new(ManagerConfig::default(), specs());
            let cfg = ServerConfig {
                workers: 2,
                shard_capacity_j: Some(caps),
                steal: false,
                ..Default::default()
            };
            let srv = AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(1e9)).unwrap();
            let resp = srv.classify(vec![5u8; elems]).unwrap();
            assert_eq!(
                resp.shard, want_shard,
                "equal-depth dispatch must pick the fullest cell"
            );
            assert_eq!(resp.profile, "hi", "the full shard serves exact");
            srv.shutdown();
        }
    }

    #[test]
    fn async_client_pipeline_and_ticket_semantics() {
        let (backend, elems) = sim_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = AdaptiveServer::start(
            ServerConfig::with_workers(2),
            backend,
            mgr,
            EnergyMonitor::new(1e9),
        )
        .unwrap();
        let client = srv.client();
        let tickets = client.submit_many((0..40).map(|i| vec![i as u8; elems]));
        assert_eq!(tickets.len(), 40);
        let ids: Vec<u64> = tickets.iter().map(|t| t.id()).collect();
        // ids come from one shared counter, in submission order
        assert_eq!(ids, (0..40).collect::<Vec<u64>>());
        let mut got = Vec::new();
        for t in tickets {
            let resp = t.await_reply().unwrap();
            assert!(resp.pred < 3);
            assert!(resp.shard < 2);
            got.push(resp.id);
        }
        assert_eq!(got, ids, "each ticket resolves to its own request");
        // handles are cloneable across threads and share the id counter
        let c2 = client.clone();
        let h = std::thread::spawn(move || c2.classify(vec![1u8; elems]).unwrap().id);
        assert_eq!(h.join().unwrap(), 40);
        // pipelined convenience: replies in submission order, one per input
        let replies = client.classify_pipelined((0..10).map(|i| vec![i as u8; elems]), 4);
        assert_eq!(replies.len(), 10);
        let pipeline_ids: Vec<u64> = replies.into_iter().map(|r| r.unwrap().id).collect();
        assert_eq!(pipeline_ids, (41..51).collect::<Vec<u64>>());
        drop(client);
        srv.shutdown();
    }

    #[test]
    fn shutdown_ignores_detached_handles_and_fails_late_submits() {
        let (backend, elems) = sim_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = AdaptiveServer::start(
            ServerConfig::default(),
            backend,
            mgr,
            EnergyMonitor::new(1e9),
        )
        .unwrap();
        let client = srv.client();
        let resp = client.submit(vec![3u8; elems]).await_reply().unwrap();
        assert_eq!(resp.id, 0);
        // `client` still holds a live Sender: shutdown must not block on it
        srv.shutdown();
        let dead = client.submit(vec![4u8; elems]);
        assert!(dead.await_reply().is_err(), "post-shutdown submit must resolve to Err, not hang");
    }

    #[test]
    fn zero_workers_clamps_to_one() {
        let (backend, elems) = sim_backend();
        let energy = EnergyMonitor::new(1e9);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = AdaptiveServer::start(
            ServerConfig::with_workers(0),
            backend,
            mgr,
            energy,
        )
        .unwrap();
        assert_eq!(srv.workers(), 1);
        assert!(srv.classify(vec![0u8; elems]).is_ok());
        srv.shutdown();
    }

    #[test]
    fn empty_monitor_set_reports_full_battery() {
        // Regression: a server with no energy monitors has unlimited
        // energy — the mean must read 1.0 (full), not 0.0 (dead), which is
        // what the old `len().max(1)` divisor silently produced.
        assert_eq!(super::mean_battery_fraction(&[]), 1.0);
        let half = Arc::new(EnergyMonitor::new(10.0));
        half.drain(1000.0, 5e6); // 5 of 10 J gone
        let full = Arc::new(EnergyMonitor::new(10.0));
        let mean = super::mean_battery_fraction(&[half, full]);
        assert!((mean - 0.75).abs() < 1e-9);
    }

    #[test]
    fn shard_recovers_and_upswitches_under_recharge() {
        // One shard, a recharge source between the two profiles' draws:
        // under continuous load the battery drains on "hi" (1 W draw vs
        // 0.6 W harvest), degrades below the threshold, then *recovers* on
        // "lo" (0.2 W draw) and upswitches back — the full degrade ->
        // recover -> upswitch cycle, all on virtual time.
        let (backend, elems) = sim_backend();
        let profile_specs = vec![
            ProfileSpec {
                name: "hi".into(),
                accuracy: 0.96,
                power_mw: 1000.0,
                latency_us: 329.0,
            },
            ProfileSpec {
                name: "lo".into(),
                accuracy: 0.94,
                power_mw: 200.0,
                latency_us: 329.0,
            },
        ];
        let mgr = ProfileManager::new(ManagerConfig::default(), profile_specs);
        let cfg = ServerConfig {
            recharge: EnergySource::constant(600.0),
            ..Default::default()
        };
        // "hi" nets -400 mW x 329 us ~= -1.3e-4 J per request, so a
        // 1.5e-2 J battery crosses the 48% downswitch after ~60 requests;
        // "lo" nets +400 mW, recovering past 52% in ~5 more.
        let srv = AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(1.5e-2)).unwrap();
        let img = vec![7u8; elems];
        let mut profiles = Vec::new();
        for _ in 0..160 {
            profiles.push(srv.classify(img.clone()).unwrap().profile);
        }
        let first_lo = profiles.iter().position(|p| p == "lo").expect("never degraded");
        assert!(profiles[..first_lo].iter().all(|p| p == "hi"));
        let upswitch = profiles[first_lo..].iter().position(|p| p == "hi");
        assert!(
            upswitch.is_some(),
            "battery recovered but the profile never switched back: {:?}",
            &profiles[first_lo..]
        );
        assert!(srv.stats.switches.get() >= 2, "need a down- and an up-switch");
        assert!(
            srv.stats.shard_recharged_j[0].get() > 0.0,
            "recharge gauge never moved"
        );
        // the drain and recharge books balance on the shard's monitor
        let m = &srv.shard_energy[0];
        let rhs = m.capacity_j() - m.drained_j() + m.recharged_j();
        assert!((m.remaining_j() - rhs).abs() < 1e-12);
        assert!(m.virtual_time_s() > 0.0);
        srv.shutdown();
    }

    #[test]
    fn panicked_shard_is_respawned_and_serves_again() {
        let (backend, elems) = sim_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let plan = FaultPlan {
            seed: 0,
            server: vec![ServerFaultEvent {
                at_batch: 1,
                shard: 0,
                kind: ServerFaultKind::Panic,
            }],
            wire: vec![],
        };
        let cfg = ServerConfig {
            faults: Some(Arc::new(plan.injector())),
            ..Default::default()
        };
        let srv = AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(1e9)).unwrap();
        let img = vec![7u8; elems];
        // The first batch is taken down with the worker: its ticket
        // resolves Err — typed, immediate, no hang.
        assert!(
            srv.classify(img.clone()).is_err(),
            "in-hand batch must die with the shard"
        );
        // With the sole shard down, the supervisor respawns it immediately
        // (the all-dead fast path skips the batch-clock backoff) and the
        // same server serves again.
        for _ in 0..5 {
            assert!(srv.classify(img.clone()).is_ok(), "respawned shard must serve");
        }
        assert_eq!(srv.stats.restarts.get(), 1);
        assert!(srv.stats.drained(), "gauges must conserve across death + respawn");
        srv.shutdown();
    }

    #[test]
    fn browned_out_shard_rejoins_degraded_at_restart_fraction() {
        let (backend, elems) = sim_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let plan = FaultPlan {
            seed: 0,
            server: vec![ServerFaultEvent {
                at_batch: 1,
                shard: 0,
                kind: ServerFaultKind::BrownOut,
            }],
            wire: vec![],
        };
        let cfg = ServerConfig {
            faults: Some(Arc::new(plan.injector())),
            ..Default::default()
        };
        let srv = AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(10.0)).unwrap();
        let img = vec![7u8; elems];
        assert!(srv.classify(img.clone()).is_err());
        let resp = srv.classify(img.clone()).unwrap();
        assert_eq!(
            resp.profile, "lo",
            "a shard revived at 5% battery must serve the degraded profile"
        );
        assert_eq!(srv.stats.restarts.get(), 1);
        let m = &srv.shard_energy[0];
        assert!(
            m.remaining_fraction() <= 0.05 + 1e-9,
            "restart fraction is a ceiling, got {}",
            m.remaining_fraction()
        );
        assert!(m.remaining_fraction() > 0.04, "the cell was recharged, not left empty");
        assert!(m.recharged_j() >= 0.5 - 1e-9, "the refill must be booked as recharge");
        // The brown-out books balance: remaining = capacity - drained + recharged.
        let rhs = m.capacity_j() - m.drained_j() + m.recharged_j();
        assert!((m.remaining_j() - rhs).abs() < 1e-9);
        srv.shutdown();
    }

    #[test]
    fn dead_shards_stranded_backlog_is_rerouted_eagerly() {
        // Pin every batch to shard 0 with stealing AND supervision off,
        // then kill shard 0 on its 4th batch. The backlog stranded on its
        // deque can only reach shard 1 through the eager re-route on death
        // — no thieves, no respawn — so shard 1 serving anything proves
        // the rescue (pre-fix, stealing off dropped the whole backlog).
        const N: usize = 32;
        let (backend, elems) = heavy_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let plan = FaultPlan {
            seed: 0,
            server: vec![ServerFaultEvent {
                at_batch: 4,
                shard: 0,
                kind: ServerFaultKind::Panic,
            }],
            wire: vec![],
        };
        let cfg = ServerConfig {
            workers: 2,
            steal: false,
            supervise: false,
            pin_dispatch_to: Some(0),
            batcher: BatcherConfig {
                max_batch: 1,
                ..Default::default()
            },
            faults: Some(Arc::new(plan.injector())),
            ..Default::default()
        };
        let srv = AdaptiveServer::start(cfg, backend, mgr, EnergyMonitor::new(1e9)).unwrap();
        let client = srv.client();
        let tickets = client.submit_many((0..N).map(|i| vec![(i % 251) as u8; elems]));
        let (mut oks, mut errs) = (0usize, 0usize);
        let mut by_shard = [0usize; 2];
        for t in tickets {
            match t.await_reply() {
                Ok(r) => {
                    oks += 1;
                    by_shard[r.shard] += 1;
                }
                Err(_) => errs += 1,
            }
        }
        assert_eq!(oks + errs, N, "every ticket must resolve");
        assert!(errs >= 1, "the in-hand batch dies with the shard");
        assert!(
            by_shard[1] > 0,
            "stranded backlog must be re-routed to the live shard, \
             not wait for thieves: {by_shard:?}"
        );
        assert!(srv.stats.drained(), "gauges must conserve after the re-route");
        drop(client);
        srv.shutdown();
    }

    #[test]
    fn timed_out_await_counts_the_late_reply() {
        let (backend, elems) = heavy_backend();
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let srv = AdaptiveServer::start(
            ServerConfig::default(),
            backend,
            mgr,
            EnergyMonitor::new(1e9),
        )
        .unwrap();
        // A zero deadline expires while the (heavy) batch still executes;
        // consuming the ticket closes its reply channel.
        let t = srv.submit(vec![1u8; elems]);
        assert!(t.await_reply_timeout(Duration::from_millis(0)).is_err());
        // The worker still finishes the work and must book the discarded
        // answer instead of losing it silently.
        wait_until("late reply accounting", || srv.stats.late_replies.get() == 1);
        assert_eq!(srv.stats.requests.get(), 1, "the work itself is still counted");
        srv.shutdown();
    }

    #[test]
    fn drop_without_shutdown_joins_cleanly() {
        let (backend, elems) = sim_backend();
        let energy = EnergyMonitor::new(1e9);
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        {
            let srv = AdaptiveServer::start(
                ServerConfig::with_workers(2),
                backend,
                mgr,
                energy,
            )
            .unwrap();
            let _ = srv.classify(vec![1u8; elems]).unwrap();
            // falls out of scope here: Drop must close the queue once and
            // join the dispatcher + both shards without hanging
        }
    }
}
