//! L3 coordinator: the adaptive inference engine + Profile Manager.
//!
//! The paper's runtime architecture (Fig. 4 left): a CPS infrastructure with
//! two cooperating parts —
//!
//! * the **Adaptive Inference Engine** executes classifications on the
//!   currently selected execution profile; switching profile is a
//!   configuration-word write on the merged MDC datapath (here: an O(1)
//!   executable swap — no recompilation, mirroring "no re-synthesis");
//! * the **Profile Manager** monitors the energy state and the
//!   user/application constraints and selects the most suitable profile
//!   (threshold policy with hysteresis on the battery level, never
//!   violating the accuracy floor while energy allows).
//!
//! Requests flow through a dynamic batcher (channel-fed, size/deadline
//! bounded) into a dispatcher thread that runs the adaptation step once per
//! batch and fans batches out to a configurable pool of worker shards. Each
//! shard owns its own backend replica — either the PJRT runtime (AOT
//! artifacts) or the integer dataflow engine (bit-exact simulator, with a
//! per-profile cached executor), selected at construction — while the
//! Profile Manager and Energy Monitor remain the single shared adaptation
//! state. See `server.rs` for the pipeline diagram.

mod backend;
mod batcher;
mod manager;
mod request;
mod server;

pub use backend::{Backend, BackendKind};
pub use batcher::{BatcherConfig, DynamicBatcher};
pub use manager::{EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec};
pub use request::{ClassifyRequest, ClassifyResponse};
pub use server::{AdaptiveServer, ServerConfig, ServerStats};
