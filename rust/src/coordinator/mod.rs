//! L3 coordinator: the adaptive inference engine + Profile Manager.
//!
//! The paper's runtime architecture (Fig. 4 left): a CPS infrastructure with
//! two cooperating parts —
//!
//! * the **Adaptive Inference Engine** executes classifications on the
//!   currently selected execution profile; switching profile is a
//!   configuration-word write on the merged MDC datapath (here: an O(1)
//!   executable swap — no recompilation, mirroring "no re-synthesis");
//! * the **Profile Manager** monitors the energy state and the
//!   user/application constraints and selects the most suitable profile
//!   (threshold policy with hysteresis on the battery level, never
//!   violating the accuracy floor while energy allows).
//!
//! Requests flow through the async client API ([`ClientHandle`] /
//! [`Ticket`]) into a dynamic batcher, then a dispatcher thread routes each
//! batch to the least-loaded worker shard's local deque; idle shards steal
//! from the busiest. Each shard owns its own backend replica — either the
//! PJRT runtime (AOT artifacts) or the integer dataflow engine (bit-exact
//! simulator, with a per-profile cached executor) — *and its own energy
//! monitor*: the adaptation step runs per shard, so a replica running hot
//! degrades to a cheaper profile while the others stay exact. Monitors can
//! carry an [`EnergySource`] (constant / duty-cycle / solar-like recharge)
//! integrated on the shard's virtual batch time, so a degraded shard
//! recovers and the manager's hysteresis upswitch restores the accurate
//! profile. See `server.rs` for the pipeline diagram and `steal.rs` for
//! the deque discipline.
//!
//! Remote clients reach the same spine through the TCP front end in
//! [`crate::net`]: its acceptor threads decode length-prefixed frames,
//! apply admission control (shedding with a typed `Overloaded` reply
//! before the dispatcher ever sees the request), and submit through the
//! same [`ClientHandle`] in-process callers use.

mod backend;
mod batcher;
mod client;
mod manager;
mod request;
mod server;
mod steal;

pub use backend::{Backend, BackendKind};
pub use batcher::{BatcherConfig, DynamicBatcher};
pub use client::{ClientHandle, Ticket};
pub use manager::{EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec};
pub use request::{ClassifyRequest, ClassifyResponse, Submission};
pub use server::{AdaptiveServer, ServerConfig, ServerStats};
// The recharge-source type lives in `power` but is part of the server
// configuration surface; re-exported for callers wiring `ServerConfig`.
pub use crate::power::EnergySource;
