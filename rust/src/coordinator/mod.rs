//! L3 coordinator: the adaptive inference engine + Profile Manager.
//!
//! The paper's runtime architecture (Fig. 4 left): a CPS infrastructure with
//! two cooperating parts —
//!
//! * the **Adaptive Inference Engine** executes classifications on the
//!   currently selected execution profile; switching profile is a
//!   configuration-word write on the merged MDC datapath (here: an O(1)
//!   executable swap — no recompilation, mirroring "no re-synthesis");
//! * the **Profile Manager** monitors the energy state and the
//!   user/application constraints and selects the most suitable profile
//!   (threshold policy with hysteresis on the battery level, never
//!   violating the accuracy floor while energy allows).
//!
//! Requests flow through a dynamic batcher (channel-fed, size/deadline
//! bounded) into a worker thread that owns the backend — either the PJRT
//! runtime (AOT artifacts) or the integer dataflow engine (bit-exact
//! simulator), selected at construction.

mod backend;
mod batcher;
mod manager;
mod request;
mod server;

pub use backend::{Backend, BackendKind};
pub use batcher::{BatcherConfig, DynamicBatcher};
pub use manager::{EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec};
pub use request::{ClassifyRequest, ClassifyResponse};
pub use server::{AdaptiveServer, ServerConfig, ServerStats};
