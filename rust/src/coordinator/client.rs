//! Async submit/await client API in front of the mpsc spine.
//!
//! [`ClientHandle`] is a cheap, cloneable, `Send` handle detached from the
//! server value: callers keep a pipeline of in-flight [`Ticket`]s instead
//! of blocking a thread per request.
//!
//! ```no_run
//! # use onnx2hw::coordinator::*;
//! # fn demo(srv: &AdaptiveServer, images: Vec<Vec<u8>>) -> anyhow::Result<()> {
//! let client = srv.client();
//! let tickets = client.submit_many(images); // returns immediately
//! for t in tickets {
//!     let reply = t.await_reply()?; // overlap: later requests already execute
//!     println!("#{} -> class {} via {}", reply.id, reply.pred, reply.profile);
//! }
//! # Ok(()) }
//! ```
//!
//! Shutdown safety: the server closes via an explicit sentinel, so
//! outstanding handles never block shutdown; submissions after shutdown
//! produce tickets whose `await_reply` returns a clean `Err`.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc};
use std::time::Duration;

use anyhow::Result;

use super::request::{ClassifyRequest, ClassifyResponse, Submission};

/// A pending reply. Dropping the ticket drops the reply channel; the
/// serving shard's send lands on a closed channel (the request is still
/// counted, and the discarded answer shows up in
/// `ServerStats::late_replies`).
pub struct Ticket {
    id: u64,
    rx: mpsc::Receiver<ClassifyResponse>,
}

impl Ticket {
    pub(crate) fn new(id: u64, rx: mpsc::Receiver<ClassifyResponse>) -> Self {
        Ticket { id, rx }
    }

    /// Request id this ticket resolves (matches the reply's `id`).
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Block until the reply arrives. Errs if the server dropped the
    /// request (shutdown before execution).
    pub fn await_reply(self) -> Result<ClassifyResponse> {
        Ok(self.rx.recv()?)
    }

    /// Like [`await_reply`](Self::await_reply) with a deadline.
    ///
    /// Timeout semantics: the ticket is *consumed* either way, so a reply
    /// arriving after the deadline has nobody left to receive it. The
    /// request is not cancelled — the shard still executes it and counts
    /// it in `ServerStats::requests` — but the answer is discarded at the
    /// closed channel and audited in `ServerStats::late_replies`. Callers
    /// that might want the answer later should poll
    /// [`try_reply`](Self::try_reply) instead of timing out.
    pub fn await_reply_timeout(self, timeout: Duration) -> Result<ClassifyResponse> {
        Ok(self.rx.recv_timeout(timeout)?)
    }

    /// Non-blocking poll: `Some` once the reply is in.
    pub fn try_reply(&self) -> Option<ClassifyResponse> {
        self.rx.try_recv().ok()
    }
}

/// The one submission path shared by [`ClientHandle`] and the server's own
/// `submit`: allocate an id, send the request, hand back the ticket. A
/// failed send (server gone) drops the reply sender, so awaiting the ticket
/// reads a clean Err instead of hanging.
pub(crate) fn submit_via(
    tx: &mpsc::Sender<Submission>,
    next_id: &AtomicU64,
    image: Vec<u8>,
) -> Ticket {
    let (rtx, rrx) = mpsc::channel();
    let id = next_id.fetch_add(1, Ordering::Relaxed);
    let _ = tx.send(Submission::Request(ClassifyRequest::new(id, image, rtx)));
    Ticket::new(id, rrx)
}

/// Cloneable submit handle onto the adaptive server.
#[derive(Clone)]
pub struct ClientHandle {
    pub(crate) tx: mpsc::Sender<Submission>,
    pub(crate) next_id: Arc<AtomicU64>,
}

impl ClientHandle {
    /// Enqueue one image without blocking; the returned [`Ticket`] resolves
    /// to the reply.
    pub fn submit(&self, image: Vec<u8>) -> Ticket {
        submit_via(&self.tx, &self.next_id, image)
    }

    /// Enqueue a burst; tickets come back in submission order.
    pub fn submit_many(&self, images: impl IntoIterator<Item = Vec<u8>>) -> Vec<Ticket> {
        images.into_iter().map(|img| self.submit(img)).collect()
    }

    /// Synchronous convenience: submit and wait.
    pub fn classify(&self, image: Vec<u8>) -> Result<ClassifyResponse> {
        self.submit(image).await_reply()
    }

    /// Pipelined classify: keep up to `window` requests in flight, awaiting
    /// the oldest as new ones are submitted. Results come back in
    /// submission order (one per input — zip them against whatever tags the
    /// caller kept), so a caller gets request overlap without hand-rolling
    /// the ticket window.
    pub fn classify_pipelined(
        &self,
        images: impl IntoIterator<Item = Vec<u8>>,
        window: usize,
    ) -> Vec<Result<ClassifyResponse>> {
        let window = window.max(1);
        let mut out = Vec::new();
        let mut inflight = VecDeque::new();
        for img in images {
            inflight.push_back(self.submit(img));
            if inflight.len() >= window {
                out.push(inflight.pop_front().unwrap().await_reply());
            }
        }
        for t in inflight {
            out.push(t.await_reply());
        }
        out
    }
}
