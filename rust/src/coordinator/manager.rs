//! Profile Manager: self-adaptive profile selection (paper Fig. 4 left,
//! following the CERBERO self-adaptation loop [17]).
//!
//! Inputs: the (simulated) energy monitor and the application constraints
//! (accuracy floor, optional power cap). Output: the profile the adaptive
//! engine should run. Policy: the profile table is a *ladder*, sorted by
//! accuracy at construction (auto-generated Pareto frontiers arrive
//! unsorted — see `approx`). While energy is plentiful the top rung runs;
//! below `low_energy_threshold` the remaining battery range is split into
//! evenly spaced bands, one per lower rung, so a long ladder degrades
//! gradually instead of jumping straight to the cheapest profile (and
//! climbs back rung by rung as the battery recovers). Hysteresis holds the
//! current rung near every band edge, preventing flapping; the accuracy
//! floor and power cap restrict the eligible rungs, each negotiated away
//! if nothing satisfies it — the paper's "if they can be negotiated". With
//! two profiles this reduces exactly to the original
//! accurate-above/low-power-below threshold policy.

use std::sync::Mutex;

use crate::power::EnergySource;

/// Static description of one execution profile (from Table 1 / the HLS +
/// power reports).
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileSpec {
    pub name: String,
    pub accuracy: f64,
    pub power_mw: f64,
    pub latency_us: f64,
}

/// Mutable battery state behind one mutex so drain/recharge accounting is
/// atomic: the conservation invariant
/// `remaining == capacity - drained + recharged` holds at every instant.
#[derive(Debug)]
struct EnergyState {
    remaining_j: f64,
    /// Virtual time (s) the monitor has been advanced through; the source
    /// integral is evaluated on this clock, never wall time.
    time_s: f64,
    /// Joules actually drained (post-clamp: draining an empty battery
    /// removes nothing and reports nothing).
    drained_j: f64,
    /// Joules actually banked from the source (post-saturation: harvest
    /// offered to a full battery is discarded, not counted).
    recharged_j: f64,
}

/// Simulated battery the manager monitors (energy in joules), optionally
/// carrying a power cap — the per-accelerator constraint of a sharded
/// deployment where each replica has its own supply rail — and an
/// [`EnergySource`] that recharges it as virtual time advances.
#[derive(Debug)]
pub struct EnergyMonitor {
    capacity_j: f64,
    state: Mutex<EnergyState>,
    power_cap_mw: Option<f64>,
    source: EnergySource,
}

impl EnergyMonitor {
    pub fn new(capacity_j: f64) -> Self {
        EnergyMonitor {
            capacity_j,
            state: Mutex::new(EnergyState {
                remaining_j: capacity_j,
                time_s: 0.0,
                drained_j: 0.0,
                recharged_j: 0.0,
            }),
            power_cap_mw: None,
            source: EnergySource::None,
        }
    }

    /// Battery plus a hard power cap (mW): profiles drawing more are never
    /// selected while any capped profile exists.
    pub fn with_power_cap(capacity_j: f64, cap_mw: f64) -> Self {
        EnergyMonitor {
            power_cap_mw: Some(cap_mw),
            ..Self::new(capacity_j)
        }
    }

    /// Attach a recharge source (builder style). The source is integrated
    /// over the virtual time passed to [`EnergyMonitor::advance`].
    pub fn with_source(mut self, source: EnergySource) -> Self {
        self.source = source;
        self
    }

    pub fn capacity_j(&self) -> f64 {
        self.capacity_j
    }

    pub fn power_cap_mw(&self) -> Option<f64> {
        self.power_cap_mw
    }

    pub fn source(&self) -> &EnergySource {
        &self.source
    }

    /// Drain energy for one classification: P * t. Returns the joules
    /// *actually* removed — clamped at empty, so callers (and the recharge
    /// accounting) can never double-count past depletion.
    pub fn drain(&self, power_mw: f64, duration_us: f64) -> f64 {
        let want = (power_mw * 1e-3 * duration_us * 1e-6).max(0.0);
        let mut st = self.state.lock().unwrap();
        let got = want.min(st.remaining_j).max(0.0);
        st.remaining_j -= got;
        st.drained_j += got;
        got
    }

    /// Advance the monitor's virtual clock by `elapsed_s` seconds, banking
    /// whatever the source delivers over that interval. Saturates at
    /// capacity; returns the joules *actually* added. The server loop
    /// calls this per batch with the batch's accumulated `latency_us`, so
    /// recharge is deterministic (no wall clock anywhere).
    pub fn advance(&self, elapsed_s: f64) -> f64 {
        if elapsed_s <= 0.0 {
            return 0.0;
        }
        let mut st = self.state.lock().unwrap();
        let t0 = st.time_s;
        let t1 = t0 + elapsed_s;
        let offered = self.source.energy_between(t0, t1);
        let banked = offered.min(self.capacity_j - st.remaining_j).max(0.0);
        st.remaining_j += banked;
        st.recharged_j += banked;
        st.time_s = t1;
        banked
    }

    pub fn remaining_fraction(&self) -> f64 {
        if self.capacity_j <= 0.0 {
            // A zero-capacity battery is depleted from birth. Without this
            // guard 0/0 returns NaN, every threshold comparison in
            // `ProfileManager::select` is false, and profile switching is
            // silently disabled.
            return 0.0;
        }
        self.state.lock().unwrap().remaining_j / self.capacity_j
    }

    pub fn remaining_j(&self) -> f64 {
        self.state.lock().unwrap().remaining_j
    }

    /// Total joules actually drained over the monitor's lifetime.
    pub fn drained_j(&self) -> f64 {
        self.state.lock().unwrap().drained_j
    }

    /// Total joules actually banked from the source over the lifetime.
    pub fn recharged_j(&self) -> f64 {
        self.state.lock().unwrap().recharged_j
    }

    /// The monitor's virtual clock (seconds of accumulated batch latency).
    pub fn virtual_time_s(&self) -> f64 {
        self.state.lock().unwrap().time_s
    }

    pub fn depleted(&self) -> bool {
        self.remaining_j() <= 0.0
    }

    /// Force-drain everything left (a brown-out / power-loss fault).
    /// Counted in `drained_j`, so conservation holds; returns the joules
    /// removed.
    pub fn deplete(&self) -> f64 {
        let mut st = self.state.lock().unwrap();
        let got = st.remaining_j.max(0.0);
        st.remaining_j -= got;
        st.drained_j += got;
        got
    }

    /// Brown-out restart: raise the battery to `fraction` of capacity if it
    /// is below that level, crediting the added joules to `recharged_j` so
    /// `remaining == capacity - drained + recharged` still holds (the
    /// supervisor's analogue of `power::CycleSimConfig::restart_fraction`).
    /// Returns the joules added; a cell already above the level is left
    /// untouched.
    pub fn refill_to_fraction(&self, fraction: f64) -> f64 {
        let target = self.capacity_j * fraction.clamp(0.0, 1.0);
        let mut st = self.state.lock().unwrap();
        let added = (target - st.remaining_j).max(0.0);
        st.remaining_j += added;
        st.recharged_j += added;
        added
    }
}

#[derive(Debug, Clone)]
pub struct ManagerConfig {
    /// Battery fraction below which the low-power profile is selected.
    pub low_energy_threshold: f64,
    /// Hysteresis band around the threshold (fraction).
    pub hysteresis: f64,
    /// Application accuracy floor (fraction, e.g. 0.93).
    pub accuracy_floor: f64,
}

impl Default for ManagerConfig {
    fn default() -> Self {
        ManagerConfig {
            low_energy_threshold: 0.5,
            hysteresis: 0.02,
            accuracy_floor: 0.0,
        }
    }
}

/// The Profile Manager.
pub struct ProfileManager {
    cfg: ManagerConfig,
    /// The ladder, sorted most accurate first (enforced at construction).
    profiles: Vec<ProfileSpec>,
    /// Currently selected profile index (hysteresis state).
    current: Mutex<usize>,
}

impl ProfileManager {
    /// `profiles` must be non-empty; any order is accepted. The ladder walk
    /// in [`ProfileManager::select`] indexes rungs by accuracy rank, so the
    /// table is sorted here — most accurate first, power then name as
    /// deterministic tie-breaks — instead of silently mis-selecting on an
    /// unsorted auto-generated frontier. Rungs that are strictly dominated
    /// on (accuracy, power) are pruned: a ladder position is an energy
    /// promise, so walking *down* must never cost more power for less
    /// accuracy. Explorer frontiers are already Pareto (no-op); hand-written
    /// tables are not always, and the old policy's low-battery guarantee
    /// (lowest power wins) only survives the rank walk on a pruned table,
    /// where power strictly decreases down the ladder.
    pub fn new(cfg: ManagerConfig, mut profiles: Vec<ProfileSpec>) -> Self {
        assert!(!profiles.is_empty(), "ProfileManager needs >= 1 profile");
        profiles.sort_by(|a, b| {
            b.accuracy
                .total_cmp(&a.accuracy)
                .then(a.power_mw.total_cmp(&b.power_mw))
                .then(a.name.cmp(&b.name))
        });
        let dominated = |q: &ProfileSpec| {
            profiles.iter().any(|p| {
                p.accuracy >= q.accuracy
                    && p.power_mw <= q.power_mw
                    && (p.accuracy > q.accuracy || p.power_mw < q.power_mw)
            })
        };
        let profiles: Vec<ProfileSpec> =
            profiles.iter().filter(|&q| !dominated(q)).cloned().collect();
        // The sort places the (max accuracy, min power) profile first and
        // nothing strictly dominates it, so the pruned ladder is never
        // empty. Rung 0 — the startup profile — is the most accurate
        // overall, which is also the most accurate meeting any satisfiable
        // floor.
        ProfileManager {
            cfg,
            profiles,
            current: Mutex::new(0),
        }
    }

    /// Clone policy + profile table with *fresh, independent* hysteresis
    /// state. Each worker shard forks the shared manager so its adaptation
    /// step tracks its own battery, not a global one.
    pub fn fork(&self) -> ProfileManager {
        ProfileManager {
            cfg: self.cfg.clone(),
            profiles: self.profiles.clone(),
            current: Mutex::new(*self.current.lock().unwrap()),
        }
    }

    /// The eligible ladder (profile indices, accuracy order preserved):
    /// profiles within the power cap and meeting the accuracy floor. Each
    /// constraint is negotiated away rather than leaving nothing to run —
    /// a cap excluding every profile is ignored, and if no capped profile
    /// meets the floor the floor yields (the paper's "if they can be
    /// negotiated").
    fn eligible(&self, cap: Option<f64>) -> Vec<usize> {
        let all: Vec<usize> = (0..self.profiles.len()).collect();
        let capped: Vec<usize> = match cap {
            Some(c) => {
                let within: Vec<usize> = all
                    .iter()
                    .copied()
                    .filter(|&i| self.profiles[i].power_mw <= c)
                    .collect();
                if within.is_empty() {
                    all
                } else {
                    within
                }
            }
            None => all,
        };
        let floor = self.cfg.accuracy_floor;
        let floored: Vec<usize> = capped
            .iter()
            .copied()
            .filter(|&i| self.profiles[i].accuracy >= floor)
            .collect();
        if floored.is_empty() {
            capped
        } else {
            floored
        }
    }

    /// Map a battery fraction onto a ladder rung: 0 (most accurate) at or
    /// above `threshold`, then the range `(0, threshold)` split into
    /// `rungs - 1` equal bands, reaching the cheapest rung as the battery
    /// empties. A two-rung ladder reduces to the original single-threshold
    /// policy.
    fn rung_of(frac: f64, threshold: f64, rungs: usize) -> usize {
        if rungs <= 1 || threshold <= 0.0 || frac >= threshold {
            return 0;
        }
        let step = threshold / (rungs - 1) as f64;
        let r = ((threshold - frac.max(0.0)) / step).ceil() as usize;
        r.clamp(1, rungs - 1)
    }

    /// Decide the profile for the current energy state: clamp the held
    /// rung into the hysteresis interval `[rung(frac + h), rung(frac - h)]`
    /// over the eligible ladder. Inside a band edge's hysteresis the held
    /// rung wins (no flapping); a monotone battery walk therefore steps
    /// through the ladder monotonically, one adaptation at a time.
    pub fn select(&self, energy: &EnergyMonitor) -> &ProfileSpec {
        let frac = energy.remaining_fraction();
        let mut cur = self.current.lock().unwrap();
        let ladder = self.eligible(energy.power_cap_mw());
        let t = self.cfg.low_energy_threshold;
        let h = self.cfg.hysteresis;
        let lo = Self::rung_of(frac + h, t, ladder.len());
        let hi = Self::rung_of(frac - h, t, ladder.len());
        let target = match ladder.iter().position(|&i| i == *cur) {
            Some(pos) => ladder[pos.clamp(lo, hi)],
            // Held profile no longer eligible (cap or floor changed the
            // ladder): re-enter at the pessimistic rung for this charge.
            None => ladder[hi],
        };
        *cur = target;
        &self.profiles[target]
    }

    pub fn profiles(&self) -> &[ProfileSpec] {
        &self.profiles
    }

    pub fn current(&self) -> &ProfileSpec {
        &self.profiles[*self.current.lock().unwrap()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::power::EnergySource;
    use crate::testkit;

    fn specs() -> Vec<ProfileSpec> {
        vec![
            ProfileSpec {
                name: "A8-W8".into(),
                accuracy: 0.96,
                power_mw: 142.0,
                latency_us: 329.0,
            },
            ProfileSpec {
                name: "Mixed".into(),
                accuracy: 0.945,
                power_mw: 135.0,
                latency_us: 329.0,
            },
        ]
    }

    #[test]
    fn selects_accurate_when_full_low_power_when_low() {
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let full = EnergyMonitor::new(100.0);
        assert_eq!(mgr.select(&full).name, "A8-W8");
        let low = EnergyMonitor::new(100.0);
        low.drain(1000.0, 60.0 * 1e6); // 60 J drained
        assert!(low.remaining_fraction() < 0.45);
        assert_eq!(mgr.select(&low).name, "Mixed");
    }

    #[test]
    fn hysteresis_holds_inside_band() {
        let cfg = ManagerConfig {
            low_energy_threshold: 0.5,
            hysteresis: 0.05,
            accuracy_floor: 0.0,
        };
        let mgr = ProfileManager::new(cfg, specs());
        // 1 W constant source: advance(x) banks x joules while below cap.
        let e = EnergyMonitor::new(100.0).with_source(EnergySource::constant(1000.0));
        e.drain(1000.0, 52.0 * 1e6); // 48% remaining: inside [0.45, 0.55]
        let frac = e.remaining_fraction();
        assert!(frac > 0.45 && frac < 0.55);
        // started on the accurate profile -> holds it inside the band
        assert_eq!(mgr.select(&e).name, "A8-W8");
        e.drain(1000.0, 10.0 * 1e6); // now 38% -> switches
        assert_eq!(mgr.select(&e).name, "Mixed");
        // recharge back inside the band from below -> holds Mixed (no flap)
        e.advance(10.0); // -> 48%
        let frac = e.remaining_fraction();
        assert!(frac > 0.45 && frac < 0.55);
        assert_eq!(mgr.select(&e).name, "Mixed");
        // recharge above the band -> the recovery upswitch fires
        e.advance(10.0); // -> 58%
        assert_eq!(mgr.select(&e).name, "A8-W8");
    }

    #[test]
    fn accuracy_floor_respected_while_energy_allows() {
        let cfg = ManagerConfig {
            low_energy_threshold: 0.5,
            hysteresis: 0.0,
            accuracy_floor: 0.95, // only A8-W8 meets it
        };
        let mgr = ProfileManager::new(cfg, specs());
        let low = EnergyMonitor::new(100.0);
        low.drain(1000.0, 80.0 * 1e6);
        // even at low energy, Mixed (0.945) violates the floor -> stays A8-W8
        assert_eq!(mgr.select(&low).name, "A8-W8");
    }

    #[test]
    fn floor_negotiated_when_impossible() {
        let cfg = ManagerConfig {
            low_energy_threshold: 0.5,
            hysteresis: 0.0,
            accuracy_floor: 0.99, // nothing meets it
        };
        let mgr = ProfileManager::new(cfg, specs());
        let low = EnergyMonitor::new(100.0);
        low.drain(1000.0, 80.0 * 1e6);
        // negotiated: lowest power overall
        assert_eq!(mgr.select(&low).name, "Mixed");
    }

    #[test]
    fn never_selects_below_floor_with_energy_property() {
        testkit::check("floor respected above threshold", |rng| {
            let floor = rng.f64(0.9, 0.97);
            let cfg = ManagerConfig {
                low_energy_threshold: 0.5,
                hysteresis: 0.0,
                accuracy_floor: floor,
            };
            let mgr = ProfileManager::new(cfg, specs());
            let e = EnergyMonitor::new(100.0);
            // any drain leaving > 50%
            e.drain(1000.0, rng.f64(0.0, 49.0) * 1e6);
            let sel = mgr.select(&e);
            let meets = specs().iter().any(|p| p.accuracy >= floor);
            if meets {
                crate::prop_assert!(
                    sel.accuracy >= floor,
                    "selected {} acc {} < floor {floor}",
                    sel.name,
                    sel.accuracy
                );
            }
            Ok(())
        });
    }

    #[test]
    fn zero_capacity_battery_selects_low_power_not_nan() {
        // Regression: capacity 0 used to make remaining_fraction() NaN,
        // freezing select() on the startup profile forever.
        let e = EnergyMonitor::new(0.0);
        assert_eq!(e.remaining_fraction(), 0.0);
        assert!(e.remaining_fraction().is_finite());
        assert!(e.depleted());
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        // depleted-from-birth: must immediately pick the low-power profile
        assert_eq!(mgr.select(&e).name, "Mixed");
        // draining a dead battery stays well-defined
        e.drain(1000.0, 1e6);
        assert_eq!(e.remaining_fraction(), 0.0);
    }

    #[test]
    fn power_cap_excludes_hot_profiles() {
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        // Cap below A8-W8 (142 mW) but above Mixed (135 mW): even on a full
        // battery, only Mixed qualifies.
        let capped = EnergyMonitor::with_power_cap(100.0, 140.0);
        assert_eq!(capped.power_cap_mw(), Some(140.0));
        assert_eq!(mgr.select(&capped).name, "Mixed");
        // Cap below every profile: negotiated away (something must run).
        let mgr2 = ProfileManager::new(ManagerConfig::default(), specs());
        let tiny_cap = EnergyMonitor::with_power_cap(100.0, 1.0);
        assert_eq!(mgr2.select(&tiny_cap).name, "A8-W8");
    }

    #[test]
    fn fork_gives_independent_hysteresis_state() {
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let fork = mgr.fork();
        assert_eq!(fork.current().name, mgr.current().name);
        assert_eq!(fork.profiles(), mgr.profiles());
        // Drain only the fork's battery: the fork switches, the original
        // (selecting against a full battery) does not.
        let low = EnergyMonitor::new(100.0);
        low.drain(1000.0, 60.0 * 1e6);
        let full = EnergyMonitor::new(100.0);
        assert_eq!(fork.select(&low).name, "Mixed");
        assert_eq!(mgr.select(&full).name, "A8-W8");
        assert_eq!(mgr.current().name, "A8-W8");
        assert_eq!(fork.current().name, "Mixed");
    }

    #[test]
    fn capacity_getter_reports_construction_value() {
        assert_eq!(EnergyMonitor::new(2.5).capacity_j(), 2.5);
        assert_eq!(EnergyMonitor::new(2.5).power_cap_mw(), None);
    }

    #[test]
    fn energy_monitor_drains_exactly() {
        let e = EnergyMonitor::new(10.0);
        let got = e.drain(1000.0, 1e6); // 1 W for 1 s = 1 J
        assert!((got - 1.0).abs() < 1e-9);
        assert!((e.remaining_j() - 9.0).abs() < 1e-9);
        // overdrain clamps at 0 and reports only what was actually left
        let got = e.drain(1e9, 1e9);
        assert!((got - 9.0).abs() < 1e-9);
        assert_eq!(e.remaining_j(), 0.0);
        assert!(e.depleted());
        // draining a dead battery removes (and reports) nothing
        assert_eq!(e.drain(1000.0, 1e6), 0.0);
        assert!((e.drained_j() - 10.0).abs() < 1e-9);
        // conservation after every clamp
        let rhs = e.capacity_j() - e.drained_j() + e.recharged_j();
        assert!((e.remaining_j() - rhs).abs() < 1e-9);
    }

    #[test]
    fn deplete_and_refill_preserve_conservation() {
        let e = EnergyMonitor::new(10.0);
        e.drain(1000.0, 2e6); // 2 J out
        let lost = e.deplete();
        assert!((lost - 8.0).abs() < 1e-9);
        assert!(e.depleted());
        // restart at 5% of capacity, like the cycle simulator's brown-out
        let added = e.refill_to_fraction(0.05);
        assert!((added - 0.5).abs() < 1e-9);
        assert!((e.remaining_j() - 0.5).abs() < 1e-9);
        // already above the level: a refill is a no-op, never a drain
        assert_eq!(e.refill_to_fraction(0.01), 0.0);
        assert!((e.remaining_j() - 0.5).abs() < 1e-9);
        let rhs = e.capacity_j() - e.drained_j() + e.recharged_j();
        assert!((e.remaining_j() - rhs).abs() < 1e-9, "conservation broken");
        // deplete again: exactly the refilled joules come back out
        assert!((e.deplete() - 0.5).abs() < 1e-9);
        // an empty cell has nothing left to remove
        assert_eq!(e.deplete(), 0.0);
        assert_eq!(e.refill_to_fraction(0.0), 0.0);
    }

    #[test]
    fn monitor_recharges_saturating_at_capacity() {
        let e = EnergyMonitor::new(10.0).with_source(EnergySource::constant(2000.0)); // 2 W
        assert_eq!(e.advance(1.0), 0.0, "a full battery banks nothing");
        assert!((e.remaining_j() - 10.0).abs() < 1e-12);
        e.drain(1000.0, 5e6); // 1 W x 5 s -> 5 J left... of 10
        let banked = e.advance(2.0); // 4 J offered, all fits
        assert!((banked - 4.0).abs() < 1e-9);
        assert!((e.remaining_j() - 9.0).abs() < 1e-9);
        let banked = e.advance(10.0); // 20 J offered, 1 J of headroom
        assert!((banked - 1.0).abs() < 1e-9);
        assert!((e.remaining_j() - 10.0).abs() < 1e-9);
        assert!((e.virtual_time_s() - 13.0).abs() < 1e-12);
        // conservation: remaining == capacity - drained + recharged
        let rhs = e.capacity_j() - e.drained_j() + e.recharged_j();
        assert!((e.remaining_j() - rhs).abs() < 1e-9);
        // a source is attached but a plain monitor has none
        assert_eq!(EnergyMonitor::new(1.0).source(), &EnergySource::None);
        assert_ne!(e.source(), &EnergySource::None);
    }

    #[test]
    fn duty_cycle_recharge_tracks_virtual_time() {
        // 1 W for 1 s on / 1 s off; the monitor advances in 0.5 s steps
        // and must see exactly the on-phase energy regardless of how the
        // steps straddle the edges.
        let e = EnergyMonitor::new(100.0).with_source(EnergySource::duty_cycle(1000.0, 1.0, 1.0));
        e.drain(1000.0, 50e6); // 50 J out -> plenty of headroom
        let banked: f64 = (0..8).map(|_| e.advance(0.5)).sum(); // 4 s of schedule
        assert!((banked - 2.0).abs() < 1e-9, "2 of 4 seconds are on: got {banked}");
        assert!((e.virtual_time_s() - 4.0).abs() < 1e-12);
    }

    #[test]
    fn drain_recharge_conservation_property() {
        testkit::check("energy is conserved through drain/advance", |rng| {
            let cap = rng.f64(1.0, 50.0);
            let src = EnergySource::constant(rng.f64(0.0, 5000.0));
            let e = EnergyMonitor::new(cap).with_source(src);
            for _ in 0..40 {
                if rng.u64(0, 1) == 0 {
                    e.drain(rng.f64(0.0, 3000.0), rng.f64(0.0, 5e6));
                } else {
                    e.advance(rng.f64(0.0, 3.0));
                }
                let lhs = e.remaining_j();
                let rhs = e.capacity_j() - e.drained_j() + e.recharged_j();
                crate::prop_assert!(
                    (lhs - rhs).abs() < 1e-6,
                    "conservation violated: remaining {lhs} != cap - drained + recharged {rhs}"
                );
                crate::prop_assert!(
                    lhs >= 0.0 && lhs <= e.capacity_j() + 1e-9,
                    "remaining out of bounds: {lhs} of {cap}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn recharged_battery_upswitches_through_hysteresis() {
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let e = EnergyMonitor::new(100.0).with_source(EnergySource::constant(1000.0));
        e.drain(1e6, 60.0 * 1e3); // 60 J out -> 40% remaining
        assert_eq!(mgr.select(&e).name, "Mixed");
        // recover into the hysteresis band: still held on Mixed (no flap)
        e.advance(10.0); // -> 50%
        assert_eq!(mgr.select(&e).name, "Mixed");
        // recover past threshold + hysteresis: the upswitch fires
        e.advance(5.0); // -> 55% > 0.52
        assert_eq!(mgr.select(&e).name, "A8-W8");
    }

    #[test]
    fn oscillation_inside_hysteresis_band_never_flaps_property() {
        testkit::check("no flapping inside the band", |rng| {
            let cfg = ManagerConfig {
                low_energy_threshold: 0.5,
                hysteresis: 0.05,
                accuracy_floor: 0.0,
            };
            let mgr = ProfileManager::new(cfg, specs());
            // 1 W source: advance(x) banks x J; drain(1e6, x * 1e3) takes x J.
            let e = EnergyMonitor::new(100.0).with_source(EnergySource::constant(1000.0));
            // enter the band from below (degraded) or from above (accurate)
            let from_below = rng.u64(0, 1) == 0;
            if from_below {
                e.drain(1e6, 60.0 * 1e3); // 40% -> selects Mixed
            } else {
                e.drain(1e6, 30.0 * 1e3); // 70% -> stays accurate
            }
            let held = mgr.select(&e).name.clone();
            // drift to mid-band, then jitter without leaving (45.5, 54.5)
            let mid = 50.0 - e.remaining_j();
            if mid > 0.0 {
                e.advance(mid);
            } else {
                e.drain(1e6, -mid * 1e3);
            }
            for _ in 0..50 {
                let room_up = (54.5 - e.remaining_j()).max(0.0);
                let room_down = (e.remaining_j() - 45.5).max(0.0);
                if rng.u64(0, 1) == 0 {
                    e.advance(rng.f64(0.0, room_up));
                } else {
                    e.drain(1e6, rng.f64(0.0, room_down) * 1e3);
                }
                let frac = e.remaining_fraction();
                crate::prop_assert!(frac > 0.45 && frac < 0.55, "jitter left the band: {frac}");
                let sel = mgr.select(&e).name.clone();
                crate::prop_assert!(
                    sel == held,
                    "flapped from {held} to {sel} at battery {frac}"
                );
            }
            Ok(())
        });
    }

    #[test]
    fn full_drain_recharge_cycle_ends_on_accurate_property() {
        testkit::check("drain -> recharge cycle restores the accurate profile", |rng| {
            let mgr = ProfileManager::new(ManagerConfig::default(), specs());
            let e = EnergyMonitor::new(100.0).with_source(EnergySource::constant(1000.0));
            // drain somewhere below the band, possibly to full depletion
            e.drain(1e6, rng.f64(55.0, 120.0) * 1e3);
            let sel = mgr.select(&e).name.clone();
            crate::prop_assert!(sel == "Mixed", "expected the degraded profile, got {sel}");
            // recharge to full (saturating at capacity)
            e.advance(rng.f64(100.0, 200.0));
            crate::prop_assert!(
                (e.remaining_fraction() - 1.0).abs() < 1e-9,
                "not full after recharge: {}",
                e.remaining_fraction()
            );
            let sel = mgr.select(&e).name.clone();
            crate::prop_assert!(
                sel == "A8-W8",
                "cycle ended on {sel}, not the accurate profile"
            );
            Ok(())
        });
    }

    /// A 5-rung auto-generated-style ladder (accuracy down, power down).
    fn ladder5() -> Vec<ProfileSpec> {
        (0..5)
            .map(|i| ProfileSpec {
                name: format!("apx-{i}"),
                accuracy: 0.96 - 0.02 * i as f64,
                power_mw: 150.0 - 10.0 * i as f64,
                latency_us: 329.0,
            })
            .collect()
    }

    #[test]
    fn unsorted_ladder_is_sorted_at_construction() {
        // Regression: auto-generated frontiers arrive in search order, not
        // accuracy order. The ladder walk indexes rungs by accuracy rank,
        // so an unsorted table used to mis-select (rung 1 could be *more*
        // accurate than rung 0). Construction must sort.
        let mut shuffled = ladder5();
        shuffled.swap(0, 3);
        shuffled.swap(1, 4);
        let mgr = ProfileManager::new(ManagerConfig::default(), shuffled);
        let names: Vec<&str> = mgr.profiles().iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, ["apx-0", "apx-1", "apx-2", "apx-3", "apx-4"]);
        for w in mgr.profiles().windows(2) {
            assert!(w[0].accuracy >= w[1].accuracy, "ladder not sorted by accuracy");
        }
        // startup = top rung; a dead battery = bottom rung
        assert_eq!(mgr.current().name, "apx-0");
        let dead = EnergyMonitor::new(0.0);
        assert_eq!(mgr.select(&dead).name, "apx-4");
    }

    #[test]
    fn dominated_rungs_are_pruned_at_construction() {
        // "bad" is strictly worse than "mid" on both axes: less accurate
        // AND hungrier. Rank-walking an unpruned table would serve it near
        // empty — draining fastest exactly when energy is critical, which
        // the old lowest-power policy never did.
        let specs = vec![
            ProfileSpec {
                name: "top".into(),
                accuracy: 0.96,
                power_mw: 150.0,
                latency_us: 329.0,
            },
            ProfileSpec {
                name: "bad".into(),
                accuracy: 0.90,
                power_mw: 140.0,
                latency_us: 329.0,
            },
            ProfileSpec {
                name: "mid".into(),
                accuracy: 0.93,
                power_mw: 120.0,
                latency_us: 329.0,
            },
            ProfileSpec {
                name: "eco".into(),
                accuracy: 0.88,
                power_mw: 100.0,
                latency_us: 329.0,
            },
        ];
        let mgr = ProfileManager::new(ManagerConfig::default(), specs);
        let names: Vec<&str> = mgr.profiles().iter().map(|p| p.name.as_str()).collect();
        assert_eq!(names, ["top", "mid", "eco"], "dominated rung must be pruned");
        // Power strictly decreases down the pruned ladder, so the bottom
        // rung is the lowest-power profile — the old low-battery guarantee.
        for w in mgr.profiles().windows(2) {
            assert!(w[0].power_mw > w[1].power_mw);
        }
        let dead = EnergyMonitor::new(0.0);
        assert_eq!(mgr.select(&dead).name, "eco");
    }

    #[test]
    fn five_rung_bands_are_evenly_spaced() {
        // t = 0.5, h = 0: bands below the threshold are 0.125 wide.
        let cfg = ManagerConfig {
            low_energy_threshold: 0.5,
            hysteresis: 0.0,
            accuracy_floor: 0.0,
        };
        for (charge_j, want) in [
            (100.0, "apx-0"),
            (55.0, "apx-0"),
            (45.0, "apx-1"),
            (30.0, "apx-2"),
            (20.0, "apx-3"),
            (5.0, "apx-4"),
            (0.0, "apx-4"),
        ] {
            let mgr = ProfileManager::new(cfg.clone(), ladder5());
            let e = EnergyMonitor::new(100.0);
            e.drain(1e6, (100.0 - charge_j) * 1e3); // leave charge_j joules
            assert_eq!(mgr.select(&e).name, want, "battery at {charge_j}%");
        }
    }

    #[test]
    fn multi_tier_ladder_walks_monotonically_property() {
        // Drain an auto-generated-style 5+ rung ladder in random steps: the
        // selected rung may only move down the ladder; recharge back up and
        // it may only move up, ending on the top rung. Extends the PR 4
        // two-profile cycle tests to deep ladders.
        testkit::check("ladder walk is monotone under drain and recharge", |rng| {
            let n_rungs = rng.usize(5, 8);
            let specs: Vec<ProfileSpec> = (0..n_rungs)
                .map(|i| ProfileSpec {
                    name: format!("apx-{i}"),
                    accuracy: 0.99 - 0.015 * i as f64,
                    power_mw: 200.0 - 12.0 * i as f64,
                    latency_us: 329.0,
                })
                .collect();
            let mgr = ProfileManager::new(ManagerConfig::default(), specs);
            let rung = |name: &str| -> usize {
                mgr.profiles().iter().position(|p| p.name == name).unwrap()
            };
            // 1 W source so advance(x) banks x J; drain(1e6, x*1e3) takes x J.
            let e = EnergyMonitor::new(100.0).with_source(EnergySource::constant(1000.0));
            let mut prev = rung(&mgr.select(&e).name);
            crate::prop_assert!(prev == 0, "full battery must start on the top rung");
            while e.remaining_j() > 0.0 {
                e.drain(1e6, rng.f64(0.5, 9.0) * 1e3);
                let now = rung(&mgr.select(&e).name);
                crate::prop_assert!(
                    now >= prev,
                    "drain walked back up: rung {prev} -> {now} at {}",
                    e.remaining_fraction()
                );
                prev = now;
            }
            crate::prop_assert!(
                prev == mgr.profiles().len() - 1,
                "empty battery must end on the bottom rung, got {prev}"
            );
            // f64 saturation can stop one ulp short of 1.0: stop just shy.
            while e.remaining_fraction() < 1.0 - 1e-9 {
                e.advance(rng.f64(0.5, 9.0));
                let now = rung(&mgr.select(&e).name);
                crate::prop_assert!(
                    now <= prev,
                    "recharge walked back down: rung {prev} -> {now} at {}",
                    e.remaining_fraction()
                );
                prev = now;
            }
            crate::prop_assert!(prev == 0, "full battery must recover the top rung");
            Ok(())
        });
    }

    #[test]
    fn ladder_respects_floor_and_cap_together() {
        // Floor admits rungs 0..=2, cap admits rungs 2..=4: the eligible
        // ladder is the single rung 2 at any charge.
        let cfg = ManagerConfig {
            low_energy_threshold: 0.5,
            hysteresis: 0.0,
            accuracy_floor: 0.915, // apx-0 (.96), apx-1 (.94), apx-2 (~.92)
        };
        let mgr = ProfileManager::new(cfg, ladder5());
        let capped = EnergyMonitor::with_power_cap(100.0, 130.0); // <= apx-2..4
        assert_eq!(mgr.select(&capped).name, "apx-2");
        capped.drain(1e6, 90.0 * 1e3); // 10% left: still the only eligible rung
        assert_eq!(mgr.select(&capped).name, "apx-2");
    }
}
