//! Profile Manager: self-adaptive profile selection (paper Fig. 4 left,
//! following the CERBERO self-adaptation loop [17]).
//!
//! Inputs: the (simulated) energy monitor and the application constraints
//! (accuracy floor, optional power cap). Output: the profile the adaptive
//! engine should run. Policy: among profiles meeting the constraints, pick
//! the most accurate while energy is plentiful; once the remaining battery
//! fraction drops below `low_energy_threshold`, pick the lowest-power
//! profile still meeting the accuracy floor (negotiating the floor away if
//! nothing meets it — the paper's "if they can be negotiated"). Hysteresis
//! prevents flapping around the threshold.

use std::sync::Mutex;

/// Static description of one execution profile (from Table 1 / the HLS +
/// power reports).
#[derive(Debug, Clone, PartialEq)]
pub struct ProfileSpec {
    pub name: String,
    pub accuracy: f64,
    pub power_mw: f64,
    pub latency_us: f64,
}

/// Simulated battery the manager monitors (energy in joules).
#[derive(Debug)]
pub struct EnergyMonitor {
    capacity_j: f64,
    remaining_j: Mutex<f64>,
}

impl EnergyMonitor {
    pub fn new(capacity_j: f64) -> Self {
        EnergyMonitor {
            capacity_j,
            remaining_j: Mutex::new(capacity_j),
        }
    }

    /// Drain energy for one classification: P * t.
    pub fn drain(&self, power_mw: f64, duration_us: f64) {
        let j = power_mw * 1e-3 * duration_us * 1e-6;
        let mut rem = self.remaining_j.lock().unwrap();
        *rem = (*rem - j).max(0.0);
    }

    pub fn remaining_fraction(&self) -> f64 {
        if self.capacity_j <= 0.0 {
            // A zero-capacity battery is depleted from birth. Without this
            // guard 0/0 returns NaN, every threshold comparison in
            // `ProfileManager::select` is false, and profile switching is
            // silently disabled.
            return 0.0;
        }
        *self.remaining_j.lock().unwrap() / self.capacity_j
    }

    pub fn remaining_j(&self) -> f64 {
        *self.remaining_j.lock().unwrap()
    }

    pub fn depleted(&self) -> bool {
        self.remaining_j() <= 0.0
    }
}

#[derive(Debug, Clone)]
pub struct ManagerConfig {
    /// Battery fraction below which the low-power profile is selected.
    pub low_energy_threshold: f64,
    /// Hysteresis band around the threshold (fraction).
    pub hysteresis: f64,
    /// Application accuracy floor (fraction, e.g. 0.93).
    pub accuracy_floor: f64,
}

impl Default for ManagerConfig {
    fn default() -> Self {
        ManagerConfig {
            low_energy_threshold: 0.5,
            hysteresis: 0.02,
            accuracy_floor: 0.0,
        }
    }
}

/// The Profile Manager.
pub struct ProfileManager {
    cfg: ManagerConfig,
    profiles: Vec<ProfileSpec>,
    /// Currently selected profile index (hysteresis state).
    current: Mutex<usize>,
}

impl ProfileManager {
    /// `profiles` must be non-empty; order does not matter.
    pub fn new(cfg: ManagerConfig, profiles: Vec<ProfileSpec>) -> Self {
        assert!(!profiles.is_empty(), "ProfileManager needs >= 1 profile");
        let start = Self::most_accurate_meeting(&profiles, cfg.accuracy_floor);
        ProfileManager {
            cfg,
            profiles,
            current: Mutex::new(start),
        }
    }

    fn most_accurate_meeting(profiles: &[ProfileSpec], floor: f64) -> usize {
        // Most accurate among floor-meeting, else most accurate overall.
        let mut best: Option<usize> = None;
        for (i, p) in profiles.iter().enumerate() {
            if p.accuracy >= floor
                && best.is_none_or(|b| p.accuracy > profiles[b].accuracy)
            {
                best = Some(i);
            }
        }
        best.unwrap_or_else(|| {
            profiles
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.accuracy.total_cmp(&b.1.accuracy))
                .map(|(i, _)| i)
                .unwrap()
        })
    }

    fn lowest_power_meeting(profiles: &[ProfileSpec], floor: f64) -> usize {
        let mut best: Option<usize> = None;
        for (i, p) in profiles.iter().enumerate() {
            if p.accuracy >= floor
                && best.is_none_or(|b| p.power_mw < profiles[b].power_mw)
            {
                best = Some(i);
            }
        }
        // Negotiate the floor away if nothing meets it: lowest power overall.
        best.unwrap_or_else(|| {
            profiles
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.power_mw.total_cmp(&b.1.power_mw))
                .map(|(i, _)| i)
                .unwrap()
        })
    }

    /// Decide the profile for the current energy state.
    pub fn select(&self, energy: &EnergyMonitor) -> &ProfileSpec {
        let frac = energy.remaining_fraction();
        let mut cur = self.current.lock().unwrap();
        let hi_idx = Self::most_accurate_meeting(&self.profiles, self.cfg.accuracy_floor);
        let lo_idx = Self::lowest_power_meeting(&self.profiles, self.cfg.accuracy_floor);
        let t = self.cfg.low_energy_threshold;
        let h = self.cfg.hysteresis;
        let target = if frac < t - h {
            lo_idx
        } else if frac > t + h {
            hi_idx
        } else {
            *cur // inside the hysteresis band: hold
        };
        *cur = target;
        &self.profiles[target]
    }

    pub fn profiles(&self) -> &[ProfileSpec] {
        &self.profiles
    }

    pub fn current(&self) -> &ProfileSpec {
        &self.profiles[*self.current.lock().unwrap()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit;

    fn specs() -> Vec<ProfileSpec> {
        vec![
            ProfileSpec {
                name: "A8-W8".into(),
                accuracy: 0.96,
                power_mw: 142.0,
                latency_us: 329.0,
            },
            ProfileSpec {
                name: "Mixed".into(),
                accuracy: 0.945,
                power_mw: 135.0,
                latency_us: 329.0,
            },
        ]
    }

    #[test]
    fn selects_accurate_when_full_low_power_when_low() {
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        let full = EnergyMonitor::new(100.0);
        assert_eq!(mgr.select(&full).name, "A8-W8");
        let low = EnergyMonitor::new(100.0);
        low.drain(1000.0, 60.0 * 1e6); // 60 J drained
        assert!(low.remaining_fraction() < 0.45);
        assert_eq!(mgr.select(&low).name, "Mixed");
    }

    #[test]
    fn hysteresis_holds_inside_band() {
        let cfg = ManagerConfig {
            low_energy_threshold: 0.5,
            hysteresis: 0.05,
            accuracy_floor: 0.0,
        };
        let mgr = ProfileManager::new(cfg, specs());
        let e = EnergyMonitor::new(100.0);
        e.drain(1000.0, 52.0 * 1e6); // 48% remaining: inside [0.45, 0.55]
        let frac = e.remaining_fraction();
        assert!(frac > 0.45 && frac < 0.55);
        // started on the accurate profile -> holds it inside the band
        assert_eq!(mgr.select(&e).name, "A8-W8");
        e.drain(1000.0, 10.0 * 1e6); // now 38% -> switches
        assert_eq!(mgr.select(&e).name, "Mixed");
        // back inside the band from below -> holds Mixed (no flap)
        // (cannot recharge; just verify it stays on Mixed)
        assert_eq!(mgr.select(&e).name, "Mixed");
    }

    #[test]
    fn accuracy_floor_respected_while_energy_allows() {
        let cfg = ManagerConfig {
            low_energy_threshold: 0.5,
            hysteresis: 0.0,
            accuracy_floor: 0.95, // only A8-W8 meets it
        };
        let mgr = ProfileManager::new(cfg, specs());
        let low = EnergyMonitor::new(100.0);
        low.drain(1000.0, 80.0 * 1e6);
        // even at low energy, Mixed (0.945) violates the floor -> stays A8-W8
        assert_eq!(mgr.select(&low).name, "A8-W8");
    }

    #[test]
    fn floor_negotiated_when_impossible() {
        let cfg = ManagerConfig {
            low_energy_threshold: 0.5,
            hysteresis: 0.0,
            accuracy_floor: 0.99, // nothing meets it
        };
        let mgr = ProfileManager::new(cfg, specs());
        let low = EnergyMonitor::new(100.0);
        low.drain(1000.0, 80.0 * 1e6);
        // negotiated: lowest power overall
        assert_eq!(mgr.select(&low).name, "Mixed");
    }

    #[test]
    fn never_selects_below_floor_with_energy_property() {
        testkit::check("floor respected above threshold", |rng| {
            let floor = rng.f64(0.9, 0.97);
            let cfg = ManagerConfig {
                low_energy_threshold: 0.5,
                hysteresis: 0.0,
                accuracy_floor: floor,
            };
            let mgr = ProfileManager::new(cfg, specs());
            let e = EnergyMonitor::new(100.0);
            // any drain leaving > 50%
            e.drain(1000.0, rng.f64(0.0, 49.0) * 1e6);
            let sel = mgr.select(&e);
            let meets = specs().iter().any(|p| p.accuracy >= floor);
            if meets {
                crate::prop_assert!(
                    sel.accuracy >= floor,
                    "selected {} acc {} < floor {floor}",
                    sel.name,
                    sel.accuracy
                );
            }
            Ok(())
        });
    }

    #[test]
    fn zero_capacity_battery_selects_low_power_not_nan() {
        // Regression: capacity 0 used to make remaining_fraction() NaN,
        // freezing select() on the startup profile forever.
        let e = EnergyMonitor::new(0.0);
        assert_eq!(e.remaining_fraction(), 0.0);
        assert!(e.remaining_fraction().is_finite());
        assert!(e.depleted());
        let mgr = ProfileManager::new(ManagerConfig::default(), specs());
        // depleted-from-birth: must immediately pick the low-power profile
        assert_eq!(mgr.select(&e).name, "Mixed");
        // draining a dead battery stays well-defined
        e.drain(1000.0, 1e6);
        assert_eq!(e.remaining_fraction(), 0.0);
    }

    #[test]
    fn energy_monitor_drains_exactly() {
        let e = EnergyMonitor::new(10.0);
        e.drain(1000.0, 1e6); // 1 W for 1 s = 1 J
        assert!((e.remaining_j() - 9.0).abs() < 1e-9);
        e.drain(1e9, 1e9); // overdrain clamps at 0
        assert_eq!(e.remaining_j(), 0.0);
        assert!(e.depleted());
    }
}
