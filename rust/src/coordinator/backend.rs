//! Inference backends of the adaptive engine.
//!
//! * `Pjrt` — the production path: AOT HLO artifacts on the PJRT CPU client.
//! * `Sim`  — the bit-exact integer dataflow engine (no artifacts needed);
//!   also what the FPGA would compute, so cross-checking the two backends
//!   per-request is the paper's functional-equivalence argument.
//!
//! Each worker shard of the sharded server owns one `Backend` replica. The
//! Sim variant pre-packs every profile into a [`CompiledModel`] at load
//! time (blocked weight tiles, fused bias/requant params) and keeps a
//! per-profile [`BatchExecutor`] cache, so the hot path pays packing, shape
//! inference, and arena allocation once per profile, not once per batch;
//! switching profiles stays O(1) — a cache lookup, mirroring the MDC
//! configuration-word write. Batches execute batch-major/layer-major via
//! [`Backend::run_batch`]; the scalar `dataflow::exec` path remains the
//! bit-exactness oracle the packed results are checked against in the
//! bench/test suites.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::dataflow::{self, BatchExecutor, CompiledModel};
use crate::qonnx::QonnxModel;
use crate::runtime::{ArtifactStore, PjrtEngine};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Pjrt,
    Sim,
}

/// A multi-profile inference backend.
pub enum Backend {
    Pjrt {
        engine: PjrtEngine,
    },
    Sim {
        /// Per-profile models pre-packed at load time (see
        /// `dataflow::kernels`).
        models: BTreeMap<String, Arc<CompiledModel>>,
        /// Per-profile batch executors (lazily built on first use; their
        /// arenas warm up once and are then allocation-free per batch).
        executors: BTreeMap<String, BatchExecutor>,
    },
}

/// `Vec::dedup` only removes *adjacent* duplicates; (profile, batch) pairs
/// from interleaved batch-1/batch-8 artifact loads are not guaranteed to
/// arrive grouped by profile, so sort before deduplicating.
fn dedup_profiles(mut ps: Vec<String>) -> Vec<String> {
    ps.sort();
    ps.dedup();
    ps
}

impl Backend {
    /// Build a PJRT backend with `profiles` loaded at batch sizes 1 and 8.
    pub fn pjrt(store: &ArtifactStore, profiles: &[&str]) -> Result<Self> {
        let mut engine = PjrtEngine::new()?;
        for p in profiles {
            engine.load(store, p, 1)?;
            // batch-8 variant is optional (older artifact sets may lack it)
            let _ = engine.load(store, p, 8);
        }
        Ok(Backend::Pjrt { engine })
    }

    /// Build the integer dataflow backend from QONNX artifacts. Weights
    /// are packed into their blocked execution layout here, at load time.
    pub fn sim(store: &ArtifactStore, profiles: &[&str]) -> Result<Self> {
        let mut models = BTreeMap::new();
        for p in profiles {
            let compiled = CompiledModel::compile(Arc::new(store.qonnx(p)?));
            models.insert(p.to_string(), Arc::new(compiled));
        }
        Ok(Backend::Sim {
            models,
            executors: BTreeMap::new(),
        })
    }

    /// Build the Sim backend from in-memory models (tests, benches,
    /// synthetic workloads); packs them exactly like [`Backend::sim`].
    pub fn sim_from_models(models: BTreeMap<String, QonnxModel>) -> Self {
        Backend::Sim {
            models: models
                .into_iter()
                .map(|(name, m)| (name, Arc::new(CompiledModel::compile(Arc::new(m)))))
                .collect(),
            executors: BTreeMap::new(),
        }
    }

    pub fn kind(&self) -> BackendKind {
        match self {
            Backend::Pjrt { .. } => BackendKind::Pjrt,
            Backend::Sim { .. } => BackendKind::Sim,
        }
    }

    pub fn profiles(&self) -> Vec<String> {
        match self {
            Backend::Pjrt { engine } => {
                dedup_profiles(engine.loaded().into_iter().map(|(p, _)| p).collect())
            }
            Backend::Sim { models, .. } => models.keys().cloned().collect(),
        }
    }

    /// Classify a whole batch on `profile` — the true batch entry point the
    /// server shards call. Returns (logits_f32, pred) per image, in order.
    ///
    /// Takes `&mut self`: the Sim arm reuses (and lazily populates) its
    /// per-profile executor cache. Each server worker owns its replica, so
    /// no locking is involved. The Sim path hands the *whole batch* to the
    /// packed batch-major engine rather than looping images; its integers
    /// are asserted equal to the scalar oracle (`dataflow::exec::execute`)
    /// on every bench reply and across the property suite.
    pub fn run_batch(
        &mut self,
        profile: &str,
        images: &[&[u8]],
    ) -> Result<Vec<(Vec<f32>, usize)>> {
        self.run_batch_observed(profile, images, None)
    }

    /// [`Self::run_batch`] with an optional per-layer step observer — the
    /// tracing hook behind `kernel.layer` sub-spans. The Sim arm threads it
    /// to [`BatchExecutor::run_batch_observed`]; the PJRT arm executes an
    /// opaque AOT artifact and reports no steps. `None` costs nothing.
    pub fn run_batch_observed(
        &mut self,
        profile: &str,
        images: &[&[u8]],
        observer: Option<&mut Vec<(u32, &'static str)>>,
    ) -> Result<Vec<(Vec<f32>, usize)>> {
        match self {
            Backend::Pjrt { engine } => engine.classify_batch(profile, images),
            Backend::Sim { models, executors } => {
                if !executors.contains_key(profile) {
                    let compiled = models
                        .get(profile)
                        .with_context(|| format!("profile '{profile}' not loaded"))?;
                    let ex = BatchExecutor::new(compiled.clone());
                    executors.insert(profile.to_string(), ex);
                }
                let ex = executors.get_mut(profile).unwrap();
                let k = ex.out_features();
                let logits = ex.run_batch_observed(images, observer);
                Ok((0..images.len())
                    .map(|i| {
                        let row = &logits[i * k..(i + 1) * k];
                        let pred = dataflow::exec::argmax(row);
                        (row.iter().map(|&v| v as f32).collect(), pred)
                    })
                    .collect())
            }
        }
    }

    /// Verify a profile is available.
    pub fn ensure_profile(&self, profile: &str) -> Result<()> {
        if self.profiles().iter().any(|p| p == profile) {
            Ok(())
        } else {
            bail!(
                "profile '{profile}' unavailable (loaded: {:?})",
                self.profiles()
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::{read_str, test_model_json};

    #[test]
    fn sim_backend_classifies() {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let mut models = BTreeMap::new();
        models.insert("T".to_string(), m.clone());
        let mut b = Backend::sim_from_models(models);
        let img: Vec<u8> = (0..m.input_shape.elems()).map(|i| i as u8).collect();
        let out = b.run_batch("T", &[&img, &img]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1, out[1].1);
        assert!(b.run_batch("missing", &[&img]).is_err());
        assert!(b.ensure_profile("T").is_ok());
        assert!(b.ensure_profile("missing").is_err());
    }

    #[test]
    fn cached_executor_stays_bit_exact() {
        let m = read_str(&test_model_json(2, 3)).unwrap();
        let elems = m.input_shape.elems();
        let img_a: Vec<u8> = (0..elems).map(|i| (i * 7 % 256) as u8).collect();
        let img_b: Vec<u8> = (0..elems).map(|i| (i * 13 % 256) as u8).collect();
        let want_a: Vec<f32> = dataflow::exec::execute(&m, &img_a)
            .iter()
            .map(|&v| v as f32)
            .collect();
        let want_b: Vec<f32> = dataflow::exec::execute(&m, &img_b)
            .iter()
            .map(|&v| v as f32)
            .collect();
        let mut models = BTreeMap::new();
        models.insert("T".to_string(), m);
        let mut b = Backend::sim_from_models(models);
        // Repeated batches hit the cached executor; logits must stay equal
        // to the one-shot `exec::execute` reference on every call.
        for _ in 0..3 {
            let out = b.run_batch("T", &[&img_a, &img_b]).unwrap();
            assert_eq!(out[0].0, want_a);
            assert_eq!(out[1].0, want_b);
        }
        if let Backend::Sim { executors, .. } = &b {
            assert_eq!(executors.len(), 1, "one cached executor per profile");
        }
    }

    #[test]
    fn run_batch_is_bit_exact_vs_scalar_oracle_across_batch_sizes() {
        // cout=11 forces a remainder weight tile; batch sizes cover the
        // batcher's envelope (solo request, partial batch, full batch-8).
        let m = read_str(&test_model_json(3, 11)).unwrap();
        let elems = m.input_shape.elems();
        let mut models = BTreeMap::new();
        models.insert("T".to_string(), m.clone());
        let mut b = Backend::sim_from_models(models);
        for &batch in &[1usize, 3, 8] {
            let images: Vec<Vec<u8>> = (0..batch)
                .map(|k| (0..elems).map(|i| ((i * 7 + k * 29) % 256) as u8).collect())
                .collect();
            let refs: Vec<&[u8]> = images.iter().map(Vec::as_slice).collect();
            let out = b.run_batch("T", &refs).unwrap();
            assert_eq!(out.len(), batch);
            for (img, (logits, pred)) in images.iter().zip(&out) {
                let want = dataflow::exec::execute(&m, img);
                let want_f: Vec<f32> = want.iter().map(|&v| v as f32).collect();
                assert_eq!(logits, &want_f);
                assert_eq!(*pred, dataflow::exec::argmax(&want));
            }
        }
    }

    #[test]
    fn profiles_dedup_handles_non_adjacent_duplicates() {
        // Regression: the Pjrt arm used to call dedup() without sorting, so
        // interleaved (profile, batch) loads left duplicates behind.
        let got = dedup_profiles(vec![
            "B".to_string(),
            "A".to_string(),
            "B".to_string(),
            "A".to_string(),
        ]);
        assert_eq!(got, vec!["A".to_string(), "B".to_string()]);
    }
}
