//! Inference backends of the adaptive engine.
//!
//! * `Pjrt` — the production path: AOT HLO artifacts on the PJRT CPU client.
//! * `Sim`  — the bit-exact integer dataflow engine (no artifacts needed);
//!   also what the FPGA would compute, so cross-checking the two backends
//!   per-request is the paper's functional-equivalence argument.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

use crate::dataflow::{self, Executor};
use crate::qonnx::QonnxModel;
use crate::runtime::{ArtifactStore, PjrtEngine};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    Pjrt,
    Sim,
}

/// A multi-profile inference backend.
pub enum Backend {
    Pjrt {
        engine: PjrtEngine,
    },
    Sim {
        models: BTreeMap<String, QonnxModel>,
    },
}

impl Backend {
    /// Build a PJRT backend with `profiles` loaded at batch sizes 1 and 8.
    pub fn pjrt(store: &ArtifactStore, profiles: &[&str]) -> Result<Self> {
        let mut engine = PjrtEngine::new()?;
        for p in profiles {
            engine.load(store, p, 1)?;
            // batch-8 variant is optional (older artifact sets may lack it)
            let _ = engine.load(store, p, 8);
        }
        Ok(Backend::Pjrt { engine })
    }

    /// Build the integer dataflow backend from QONNX artifacts.
    pub fn sim(store: &ArtifactStore, profiles: &[&str]) -> Result<Self> {
        let mut models = BTreeMap::new();
        for p in profiles {
            models.insert(p.to_string(), store.qonnx(p)?);
        }
        Ok(Backend::Sim { models })
    }

    pub fn kind(&self) -> BackendKind {
        match self {
            Backend::Pjrt { .. } => BackendKind::Pjrt,
            Backend::Sim { .. } => BackendKind::Sim,
        }
    }

    pub fn profiles(&self) -> Vec<String> {
        match self {
            Backend::Pjrt { engine } => {
                let mut ps: Vec<String> =
                    engine.loaded().into_iter().map(|(p, _)| p).collect();
                ps.dedup();
                ps
            }
            Backend::Sim { models } => models.keys().cloned().collect(),
        }
    }

    /// Classify a batch on `profile`. Returns (logits_f32, pred) per image.
    pub fn classify(
        &self,
        profile: &str,
        images: &[&[u8]],
    ) -> Result<Vec<(Vec<f32>, usize)>> {
        match self {
            Backend::Pjrt { engine } => engine.classify_batch(profile, images),
            Backend::Sim { models } => {
                let model = models
                    .get(profile)
                    .with_context(|| format!("profile '{profile}' not loaded"))?;
                let mut ex = Executor::new(model);
                Ok(images
                    .iter()
                    .map(|img| {
                        let logits = ex.run(img);
                        let pred = dataflow::exec::argmax(&logits);
                        (logits.iter().map(|&v| v as f32).collect(), pred)
                    })
                    .collect())
            }
        }
    }

    /// Verify a profile is available.
    pub fn ensure_profile(&self, profile: &str) -> Result<()> {
        if self.profiles().iter().any(|p| p == profile) {
            Ok(())
        } else {
            bail!(
                "profile '{profile}' unavailable (loaded: {:?})",
                self.profiles()
            )
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::{read_str, test_model_json};

    #[test]
    fn sim_backend_classifies() {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let mut models = BTreeMap::new();
        models.insert("T".to_string(), m.clone());
        let b = Backend::Sim { models };
        let img: Vec<u8> = (0..m.input_shape.elems()).map(|i| i as u8).collect();
        let out = b.classify("T", &[&img, &img]).unwrap();
        assert_eq!(out.len(), 2);
        assert_eq!(out[0].1, out[1].1);
        assert!(b.classify("missing", &[&img]).is_err());
        assert!(b.ensure_profile("T").is_ok());
        assert!(b.ensure_profile("missing").is_err());
    }
}
