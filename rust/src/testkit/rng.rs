//! xorshift64* PRNG + convenience generators (no `rand` offline).

/// Deterministic 64-bit PRNG (xorshift64*). Not cryptographic; used only for
/// test-case and workload generation.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // Avoid the all-zero fixed point.
        Rng {
            state: seed ^ 0x9E37_79B9_7F4A_7C15 | 1,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in [lo, hi] (inclusive).
    pub fn u64(&mut self, lo: u64, hi: u64) -> u64 {
        assert!(lo <= hi);
        let span = hi - lo + 1;
        if span == 0 {
            return self.next_u64(); // full range
        }
        lo + self.next_u64() % span
    }

    pub fn i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi as i128 - lo as i128 + 1) as u64;
        (lo as i128 + (self.next_u64() % span) as i128) as i64
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.u64(lo as u64, hi as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64_unit(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    pub fn f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64_unit() * (hi - lo)
    }

    pub fn bool(&mut self, p_true: f64) -> bool {
        self.f64_unit() < p_true
    }

    /// Pick a random element of a slice.
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.usize(0, xs.len() - 1)]
    }

    /// Vector of integer codes in [lo, hi].
    pub fn i64_vec(&mut self, len: usize, lo: i64, hi: i64) -> Vec<i64> {
        (0..len).map(|_| self.i64(lo, hi)).collect()
    }

    /// Random ASCII-ish string (printable, plus some escapes-needing chars).
    pub fn string(&mut self, max_len: usize) -> String {
        let len = self.usize(0, max_len);
        (0..len)
            .map(|_| {
                let c = self.u64(0, 99);
                match c {
                    0..=89 => (self.u64(0x20, 0x7E) as u8) as char,
                    90..=93 => '"',
                    94..=96 => '\\',
                    97 => '\n',
                    98 => '\t',
                    _ => 'é',
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bounds_respected() {
        let mut rng = Rng::new(7);
        for _ in 0..1000 {
            let v = rng.i64(-5, 5);
            assert!((-5..=5).contains(&v));
            let u = rng.u64(10, 20);
            assert!((10..=20).contains(&u));
            let f = rng.f64(-1.0, 1.0);
            assert!((-1.0..1.0).contains(&f));
        }
    }

    #[test]
    fn rough_uniformity() {
        let mut rng = Rng::new(123);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[rng.usize(0, 9)] += 1;
        }
        for &c in &counts {
            assert!(c > 700 && c < 1300, "bucket count {c} far from uniform");
        }
    }
}
