//! In-house property-testing substrate (proptest is unavailable offline).
//!
//! A deterministic xorshift PRNG drives value generators; `check` runs a
//! property over N generated cases and reports the failing seed so a run is
//! reproducible with `TESTKIT_SEED=<seed>`. Shrinking is intentionally
//! simple (halving retries on integers/vectors) — enough to produce small
//! counterexamples for the invariants in DESIGN.md §7.

mod rng;

pub use rng::Rng;

/// Number of cases per property (override with TESTKIT_CASES).
pub fn default_cases() -> u64 {
    std::env::var("TESTKIT_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("TESTKIT_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE_F00D_D00D)
}

/// Run `prop` over `default_cases()` seeded cases; panic with the seed of the
/// first failing case.
pub fn check<F: Fn(&mut Rng) -> Result<(), String>>(name: &str, prop: F) {
    let cases = default_cases();
    let base = base_seed();
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!(
                "property '{name}' failed (case {case}, TESTKIT_SEED={seed}): {msg}"
            );
        }
    }
}

/// Assert helper producing `Result<(), String>` for use inside properties.
#[macro_export]
macro_rules! prop_assert {
    ($cond:expr, $($fmt:tt)+) => {
        if !$cond {
            return Err(format!($($fmt)+));
        }
    };
    ($cond:expr) => {
        if !$cond {
            return Err(format!("assertion failed: {}", stringify!($cond)));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn check_passes_trivially() {
        check("tautology", |rng| {
            let x = rng.u64(0, 100);
            prop_assert!(x <= 100);
            Ok(())
        });
    }

    #[test]
    #[should_panic(expected = "property 'must_fail' failed")]
    fn check_reports_failure() {
        check("must_fail", |rng| {
            let x = rng.u64(0, 100);
            prop_assert!(x > 1000, "x was {x}");
            Ok(())
        });
    }

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }
}
