//! onnx2hw: ONNX-to-Hardware design flow for adaptive NN inference —
//! reproduction of Manca/Ratto/Palumbo (SAMOS 2024) as a three-layer
//! Rust + JAX + Pallas stack. See DESIGN.md for the system inventory.

pub mod analysis;
pub mod approx;
pub mod bench_harness;
pub mod cli;
pub mod coordinator;
pub mod dataflow;
pub mod fault;
pub mod flow;
pub mod hls;
pub mod loadgen;
pub mod mdc;
pub mod net;
pub mod power;
pub mod writer;
pub mod json;
pub mod metrics;
pub mod qonnx;
pub mod runtime;
pub mod testkit;
pub mod trace;
