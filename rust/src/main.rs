//! onnx2hw — leader binary of the ONNX-to-Hardware design flow.
//!
//! Subcommands mirror the paper's flow and evaluation:
//!   table1     regenerate Table 1 (per-profile accuracy/latency/LUT/BRAM/power)
//!   fig3       regenerate Fig. 3 (accuracy-vs-power series incl. Mixed)
//!   fig4       regenerate Fig. 4 (adaptive engine merge + battery sim)
//!   flow       run the design flow for one profile (writer + HLS report)
//!   explore    auto-generate a Pareto profile ladder (approximation explorer)
//!   check      statically verify a model or frontier JSON (range/width analysis)
//!   classify   classify test images on the PJRT runtime
//!   serve      run the adaptive inference server (in-process workload, or
//!              --listen for the TCP wire-protocol front end; --trace-out
//!              writes a Chrome trace-event JSON of every request)
//!   loadgen    open-loop load generator (virtual-time model / live server)
//!   trace      record a span trace of an offline scenario (load | chaos)
//!   verify     cross-check rust dataflow vs python vectors vs PJRT runtime

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use onnx2hw::analysis::{self, Severity};
use onnx2hw::approx::{CalibSet, Explorer, ExplorerConfig, Frontier};
use onnx2hw::cli::Spec;
use onnx2hw::coordinator::{
    AdaptiveServer, Backend, EnergyMonitor, ManagerConfig, ProfileManager, ProfileSpec,
    ServerConfig,
};
use onnx2hw::fault::{FaultPlan, FaultSpec};
use onnx2hw::flow::{self, FlowConfig};
use onnx2hw::json::{self, Value};
use onnx2hw::loadgen;
use onnx2hw::mdc;
use onnx2hw::net::{NetClient, NetReply, NetServer, NetServerConfig};
use onnx2hw::trace::TraceCollector;
use onnx2hw::power::{
    run_fixed, simulate_battery, simulate_battery_cycles, AdaptivePolicy, BatteryModel,
    CycleSimConfig, EnergySource,
};
use onnx2hw::runtime::{ArtifactStore, PjrtEngine};
use onnx2hw::writer;

const TABLE1_PROFILES: [&str; 5] = ["A16-W8", "A16-W4", "A8-W8", "A8-W4", "A4-W4"];
const ALL_PROFILES: [&str; 6] = ["A16-W8", "A16-W4", "A8-W8", "A8-W4", "A4-W4", "Mixed"];

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sub = args.first().map(String::as_str).unwrap_or("help");
    let rest = if args.is_empty() { &[][..] } else { &args[1..] };
    let code = match run(sub, rest) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(sub: &str, argv: &[String]) -> Result<()> {
    match sub {
        "table1" => cmd_table1(argv),
        "fig3" => cmd_fig3(argv),
        "fig4" => cmd_fig4(argv),
        "flow" => cmd_flow(argv),
        "explore" => cmd_explore(argv),
        "check" => cmd_check(argv),
        "classify" => cmd_classify(argv),
        "serve" => cmd_serve(argv),
        "loadgen" => cmd_loadgen(argv),
        "trace" => cmd_trace(argv),
        "verify" => cmd_verify(argv),
        "help" | "--help" | "-h" => {
            println!(
                "onnx2hw — ONNX-to-Hardware design flow (SAMOS 2024 reproduction)\n\n\
                 USAGE: onnx2hw \
                 <table1|fig3|fig4|flow|explore|check|classify|serve|loadgen|trace|verify> \
                 [options]\n\
                 Run a subcommand with --help for its options."
            );
            Ok(())
        }
        other => bail!("unknown subcommand '{other}' (see `onnx2hw help`)"),
    }
}

fn parse_or_usage(spec: Spec, argv: &[String]) -> Result<onnx2hw::cli::Args> {
    spec.parse(argv).map_err(|e| anyhow::anyhow!("{e}"))
}

fn cmd_table1(argv: &[String]) -> Result<()> {
    let spec = Spec::new("onnx2hw table1", "regenerate Table 1")
        .opt("profiles", &TABLE1_PROFILES.join(","), "comma-separated profiles")
        .opt("power-images", "4", "images simulated for the power estimate")
        .flag("json", "emit JSON instead of the text table");
    let a = parse_or_usage(spec, argv)?;
    let store = ArtifactStore::discover()?;
    let cfg = FlowConfig {
        power_images: a.parse_num("power-images")?,
        ..FlowConfig::default()
    };
    let profiles: Vec<&str> = a.get("profiles").unwrap().split(',').collect();
    let rows = flow::table1(&store, &profiles, &cfg)?;
    if a.flag("json") {
        let arr = Value::Array(
            rows.iter()
                .map(|r| {
                    Value::obj(vec![
                        ("profile", r.profile.as_str().into()),
                        ("accuracy_pct", r.accuracy_pct.into()),
                        ("latency_us", r.latency_us.into()),
                        ("lut_pct", r.lut_pct.into()),
                        ("bram_pct", r.bram_pct.into()),
                        ("power_mw", r.power_mw.into()),
                    ])
                })
                .collect(),
        );
        println!("{}", json::to_string_pretty(&arr));
    } else {
        let mut t = onnx2hw::bench_harness::Table::new(&[
            "Datatype", "Accuracy [%]", "Latency [us]", "LUT [%]", "BRAM [%]", "Power [mW]",
        ]);
        for r in &rows {
            t.row(&[
                r.profile.clone(),
                format!("{:.1}", r.accuracy_pct),
                format!("{:.0}", r.latency_us),
                format!("{:.0}", r.lut_pct),
                format!("{:.0}", r.bram_pct),
                format!("{:.0}", r.power_mw),
            ]);
        }
        println!("{}", t.render());
    }
    Ok(())
}

fn cmd_fig3(argv: &[String]) -> Result<()> {
    let spec = Spec::new("onnx2hw fig3", "accuracy-vs-power profile chart (Fig. 3)")
        .opt("profiles", &ALL_PROFILES.join(","), "profiles to plot");
    let a = parse_or_usage(spec, argv)?;
    let store = ArtifactStore::discover()?;
    let cfg = FlowConfig::default();
    let profiles: Vec<&str> = a.get("profiles").unwrap().split(',').collect();
    let rows = flow::table1(&store, &profiles, &cfg)?;
    println!("# Fig. 3: accuracy vs power (one point per profile)");
    println!("{:<10} {:>12} {:>12}", "profile", "power_mW", "accuracy_%");
    for r in &rows {
        println!("{:<10} {:>12.1} {:>12.2}", r.profile, r.power_mw, r.accuracy_pct);
    }
    println!("\n{}", ascii_scatter(&rows));
    Ok(())
}

fn ascii_scatter(rows: &[flow::ProfileReport]) -> String {
    let (w, h) = (60usize, 16usize);
    let xmin = rows.iter().map(|r| r.power_mw).fold(f64::MAX, f64::min) - 1.0;
    let xmax = rows.iter().map(|r| r.power_mw).fold(f64::MIN, f64::max) + 1.0;
    let ymin = rows.iter().map(|r| r.accuracy_pct).fold(f64::MAX, f64::min) - 0.2;
    let ymax = rows.iter().map(|r| r.accuracy_pct).fold(f64::MIN, f64::max) + 0.2;
    let mut grid = vec![vec![' '; w + 1]; h + 1];
    for (i, r) in rows.iter().enumerate() {
        let x = ((r.power_mw - xmin) / (xmax - xmin) * w as f64) as usize;
        let y = h - (((r.accuracy_pct - ymin) / (ymax - ymin) * h as f64) as usize).min(h);
        grid[y][x.min(w)] = char::from(b'A' + (i as u8 % 26));
    }
    let mut s = String::new();
    for row in &grid {
        s.push_str(&row.iter().collect::<String>());
        s.push('\n');
    }
    s.push_str(&format!("x: {xmin:.0}..{xmax:.0} mW | y: {ymin:.1}..{ymax:.1} % | "));
    for (i, r) in rows.iter().enumerate() {
        s.push_str(&format!("{}={} ", char::from(b'A' + (i as u8 % 26)), r.profile));
    }
    s
}

fn cmd_fig4(argv: &[String]) -> Result<()> {
    let spec = Spec::new("onnx2hw fig4", "adaptive engine merge + battery (Fig. 4)")
        .opt("pair", "A8-W8,Mixed", "profiles merged into the adaptive engine")
        .opt("battery-ah", "10", "battery capacity in Ah")
        .opt("switch-at", "0.5", "battery fraction at which to switch profile")
        .opt("recharge-mw", "", "also project an N-phase drain/recharge cycle at this harvest")
        .opt("horizon-h", "24", "horizon (hours) for the drain/recharge projection");
    let a = parse_or_usage(spec, argv)?;
    let store = ArtifactStore::discover()?;
    let cfg = FlowConfig::default();
    let pair: Vec<&str> = a.get("pair").unwrap().split(',').collect();
    if pair.len() != 2 {
        bail!("--pair needs exactly two profiles");
    }

    // --- top of Fig. 4: MDC merge + resources of the adaptive engine ---
    let nets: Vec<mdc::Network> = pair
        .iter()
        .map(|p| Ok(mdc::build_network(&store.qonnx(p)?, &cfg.fold)))
        .collect::<Result<_>>()?;
    let md = mdc::merge(&nets)?;
    let merged = mdc::merged_estimate(&md, &cfg.cal);
    let rows = flow::table1(&store, &pair, &cfg)?;
    println!("== Adaptive inference engine: {} (+) {} ==", pair[0], pair[1]);
    println!(
        "shared actors: {}/{} slots | sbox overhead: {} LUTs",
        md.n_shared(),
        md.instances.len(),
        merged.sbox_luts
    );
    println!(
        "merged resources: {} LUTs ({:.1}%), {:.1} BRAM36 ({:.1}%)",
        merged.luts,
        cfg.device.lut_pct(merged.luts),
        merged.bram36,
        cfg.device.bram_pct(merged.bram36)
    );
    for r in &rows {
        println!(
            "  profile {:<8} accuracy {:>6.2}% power {:>6.1} mW latency {:>5.0} us",
            r.profile, r.accuracy_pct, r.power_mw, r.latency_us
        );
    }
    let lut_overhead = merged.luts as f64 / rows.iter().map(|r| r.luts).max().unwrap_or(1) as f64;
    println!("overhead vs largest non-adaptive engine: x{lut_overhead:.2} LUTs");

    // --- right of Fig. 4: battery duration + classifications ---
    let bat = BatteryModel {
        capacity_ah: a.parse_num("battery-ah")?,
        voltage_v: 5.0,
    };
    let policy = AdaptivePolicy {
        switch_at_fraction: a.parse_num("switch-at")?,
    };
    let acc = &rows[0];
    let low = &rows[1];
    let fixed = run_fixed(
        &acc.profile,
        &bat,
        acc.power_mw,
        acc.latency_us,
        acc.accuracy_pct / 100.0,
    );
    let adaptive = simulate_battery(
        &bat,
        &policy,
        (&acc.profile, acc.power_mw, acc.latency_us, acc.accuracy_pct / 100.0),
        (&low.profile, low.power_mw, low.latency_us, low.accuracy_pct / 100.0),
    );
    println!("\n== Battery simulation ({} Ah @ 5 V) ==", bat.capacity_ah);
    for run in [&fixed, &adaptive] {
        println!(
            "  {:<24} {:>8.1} h {:>14} classifications (mean acc {:.2}%)",
            run.label, run.duration_h, run.classifications, run.mean_accuracy * 100.0
        );
    }
    println!(
        "adaptive extends battery by {:.1}% and classifications by {:.1}%",
        (adaptive.duration_h / fixed.duration_h - 1.0) * 100.0,
        (adaptive.classifications as f64 / fixed.classifications as f64 - 1.0) * 100.0
    );

    // --- optional: N-phase drain/recharge cycle projection ---
    let src = parse_recharge(a.opt_str("recharge-mw"), None)?;
    if src != EnergySource::None {
        let horizon_h: f64 = a.parse_num("horizon-h")?;
        let run = simulate_battery_cycles(
            &bat,
            &policy,
            (&acc.profile, acc.power_mw, acc.latency_us, acc.accuracy_pct / 100.0),
            (&low.profile, low.power_mw, low.latency_us, low.accuracy_pct / 100.0),
            &src,
            &CycleSimConfig {
                horizon_s: horizon_h * 3600.0,
                hysteresis: 0.02,
                ..Default::default()
            },
        );
        println!(
            "\n== Drain/recharge cycle projection ({} over {horizon_h} h) ==",
            src.label()
        );
        for (name, hours, c) in &run.phases {
            println!("  {name:<8} {hours:>8.2} h {c:>14} classifications");
        }
        println!(
            "  total: {} classifications over {} phases, mean accuracy {:.2}%",
            run.classifications,
            run.phases.len(),
            run.mean_accuracy * 100.0
        );
    }
    Ok(())
}

fn cmd_flow(argv: &[String]) -> Result<()> {
    let spec = Spec::new("onnx2hw flow", "run the design flow for one profile")
        .opt("profile", "A8-W8", "profile to run")
        .opt("emit", "", "directory to write generated C++/TCL into");
    let a = parse_or_usage(spec, argv)?;
    let store = ArtifactStore::discover()?;
    let cfg = FlowConfig::default();
    let profile = a.get("profile").unwrap();
    let model = store.qonnx(profile)?;
    let out = writer::write_engine(&model, &cfg.fold);
    if let Some(dir) = a.opt_str("emit") {
        std::fs::create_dir_all(dir)?;
        let base = std::path::Path::new(dir);
        std::fs::write(base.join(format!("{profile}_engine.cpp")), &out.cpp)?;
        std::fs::write(base.join("engine.h"), &out.header)?;
        std::fs::write(base.join(format!("build_{profile}.tcl")), &out.tcl)?;
        println!("wrote HLS project files to {dir}");
    }
    let rep = flow::utilization_report(&store, profile, &cfg)?;
    println!("{}", rep.render());
    Ok(())
}

fn cmd_explore(argv: &[String]) -> Result<()> {
    let spec = Spec::new(
        "onnx2hw explore",
        "auto-generate a Pareto profile ladder from one base model",
    )
    .opt("profile", "A8-W8", "base profile to explore (artifact store)")
    .opt("calib", "96", "calibration images to score candidates on")
    .opt("power-images", "2", "images simulated per candidate for the power estimate")
    .opt("min-accuracy", "0", "stop the greedy descent below this accuracy")
    .opt("eps", "0", "epsilon-dominance accuracy band for thinning the ladder")
    .opt("max-rungs", "0", "cap the ladder length (0 = keep every Pareto rung)")
    .opt("uniform-rungs", "4", "uniform-precision baseline rungs to compare against")
    .opt("seed", "7", "seed for the synthetic model / calibration workload")
    .opt("out", "", "write the frontier JSON here")
    .flag("synthetic", "explore a deterministic synthetic model (no artifacts needed)");
    let a = parse_or_usage(spec, argv)?;
    let calib_n: usize = a.parse_num("calib")?;
    let seed: u64 = a.parse_num("seed")?;
    let (base, calib) = if a.flag("synthetic") {
        let mut rng = onnx2hw::testkit::Rng::new(seed);
        let cfg = onnx2hw::qonnx::RandModelCfg {
            side: 8,
            cin: 1,
            blocks: vec![(4, 8, 8), (8, 8, 8)],
            classes: 5,
        };
        let json_text = onnx2hw::qonnx::random_model_json(&cfg, &mut rng);
        let model = onnx2hw::qonnx::read_str(&json_text).map_err(|e| anyhow::anyhow!("{e}"))?;
        let calib = CalibSet::self_labeled(&model, calib_n, seed ^ 0x5EED);
        (model, calib)
    } else {
        let store = ArtifactStore::discover()?;
        let model = store.qonnx(a.get("profile").unwrap())?;
        let testset = store.testset()?;
        let calib = CalibSet::from_testset(&testset, calib_n);
        (model, calib)
    };
    // A base model that fails the static verifier would poison every
    // candidate; refuse it up front with the typed diagnostics.
    let base_analysis = analysis::analyze(&base);
    for d in &base_analysis.diags {
        eprintln!("{d}");
    }
    if base_analysis.has_errors() {
        bail!(
            "base model '{}' fails the static verifier ({} error(s) above)",
            base.profile,
            base_analysis.errors().count()
        );
    }
    let mut explorer = Explorer::new(
        &base,
        &calib,
        ExplorerConfig {
            power_images: a.parse_num("power-images")?,
            min_accuracy: a.parse_num("min-accuracy")?,
            eps_accuracy: a.parse_num("eps")?,
            max_rungs: a.parse_num("max-rungs")?,
            uniform_rungs: a.parse_num("uniform-rungs")?,
            ..Default::default()
        },
    );
    let frontier = explorer.explore();
    let baseline = explorer.uniform_baseline();
    println!(
        "explored {} ({}) on {} calibration images: {} candidates -> {} rungs \
         ({} statically pruned)\n",
        base.profile,
        base.precision_signature(),
        calib.len(),
        explorer.evaluations(),
        frontier.len(),
        explorer.pruned_static()
    );
    let mut table = onnx2hw::bench_harness::Table::new(&[
        "rung", "profile", "precisions", "accuracy", "power", "latency", "energy/inf",
    ]);
    for (i, p) in frontier.points.iter().enumerate() {
        table.row(&[
            i.to_string(),
            p.name.clone(),
            p.model.precision_signature(),
            format!("{:.1}%", p.accuracy * 100.0),
            format!("{:.1} mW", p.power_mw),
            format!("{:.0} us", p.latency_us),
            format!("{:.2} uJ", p.energy_uj),
        ]);
    }
    println!("{}", table.render());
    let mut strict = 0usize;
    for (k, b) in baseline.iter().enumerate() {
        let covered = frontier.weakly_dominates(b.accuracy, b.energy_uj, b.latency_us);
        let beaten = frontier.strictly_dominates(b.accuracy, b.energy_uj, b.latency_us);
        strict += beaten as usize;
        println!(
            "uniform rung {}: accuracy {:.1}% energy {:.2} uJ -> {}",
            k + 1,
            b.accuracy * 100.0,
            b.energy_uj,
            if beaten {
                "strictly dominated"
            } else if covered {
                "covered"
            } else {
                "NOT covered"
            }
        );
    }
    println!(
        "\nfrontier strictly dominates {strict}/{} uniform-precision baseline rungs",
        baseline.len()
    );
    if let Some(path) = a.opt_str("out") {
        std::fs::write(path, json::to_string_pretty(&frontier.to_json()))?;
        println!("wrote frontier JSON to {path}");
    }
    Ok(())
}

fn cmd_check(argv: &[String]) -> Result<()> {
    let spec = Spec::new(
        "onnx2hw check",
        "statically verify a model or frontier JSON (range/width analysis)",
    )
    .pos("path", true, "QONNX model JSON, frontier JSON, or bench report")
    .opt("profile", "", "artifact-store profile providing the frontier's base model")
    .opt("seed", "659918", "seed for the synthetic base model")
    .flag("synthetic", "check frontiers against the deterministic synthetic base model")
    .flag("bounds", "print the proven per-layer error-bound table for every frontier rung");
    let a = parse_or_usage(spec, argv)?;
    let path = a.pos(0).unwrap();
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("cannot read {path}: {e}"))?;
    let doc = json::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;

    // Three shapes are accepted: a frontier document, a bench report that
    // nests one under "frontier", and a bare QONNX model.
    let frontier_doc = if doc.get("schema").is_some() {
        Some(&doc)
    } else {
        doc.get("frontier").filter(|f| f.get("schema").is_some())
    };
    if let Some(fdoc) = frontier_doc {
        let base = check_base_model(&a)?;
        let report = Frontier::check_json(fdoc, &base)?;
        let mut errors = 0usize;
        for (name, diags) in &report {
            for d in diags {
                errors += (d.severity == Severity::Error) as usize;
                println!("{name}: {d}");
            }
        }
        if a.flag("bounds") {
            print_bound_table(fdoc, &base)?;
        }
        if errors > 0 {
            bail!("{errors} error diagnostic(s) across {} frontier point(s)", report.len());
        }
        println!("check OK: {} frontier point(s), no error diagnostics", report.len());
        return Ok(());
    }

    if a.flag("bounds") {
        bail!("--bounds re-proves frontier certificates; '{path}' is not a frontier document");
    }
    let model = onnx2hw::qonnx::read_str(&text).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    let analysis = analysis::analyze(&model);
    for d in &analysis.diags {
        println!("{d}");
    }
    let narrow = analysis.conv_narrow.iter().filter(|&&n| n).count();
    println!(
        "arena: {} + {} elems | conv accumulators: {narrow}/{} provably i32",
        analysis.arena.a_elems,
        analysis.arena.b_elems,
        analysis.conv_narrow.len()
    );
    if analysis.has_errors() {
        bail!("{} error diagnostic(s) in {path}", analysis.errors().count());
    }
    println!("check OK: model '{}' is clean", model.profile);
    Ok(())
}

/// `check --bounds`: render the proven deviation table, one row per
/// (rung, layer). Per-layer cells summarize the channel-wise deviation
/// intervals at their widest; the per-rung summary line carries the
/// end-to-end certificate (worst-case logit deviation, stability margin,
/// exactness). Illegal configs were already reported by the checker's
/// diagnostics and are skipped here.
fn print_bound_table(fdoc: &Value, base: &onnx2hw::qonnx::QonnxModel) -> Result<()> {
    let rows = fdoc.get("points").and_then(Value::as_array).context("frontier points")?;
    let mut table = onnx2hw::bench_harness::Table::new(&[
        "rung", "layer", "op", "acc deviation", "act deviation", "act scale",
    ]);
    let mut summaries = Vec::new();
    for row in rows {
        let name = row.get("name").and_then(Value::as_str).context("point name")?;
        let config: Vec<u32> = row
            .get("config")
            .and_then(Value::to_i64_vec)
            .context("point config")?
            .into_iter()
            .map(|x| u32::try_from(x).ok().context("point config value out of range"))
            .collect::<Result<Vec<u32>>>()?;
        if !analysis::config_is_legal(base, &config) {
            summaries.push(format!("{name}: skipped (illegal config, see diagnostics above)"));
            continue;
        }
        let report = analysis::analyze_error(base, &config);
        let span = |ivs: &[analysis::Interval]| {
            let lo = ivs.iter().map(|iv| iv.lo).min().unwrap_or(0);
            let hi = ivs.iter().map(|iv| iv.hi).max().unwrap_or(0);
            format!("[{lo}, {hi}]")
        };
        for (layer, dev) in base.layers.iter().zip(&report.layers) {
            table.row(&[
                name.to_string(),
                dev.name.clone(),
                layer.kind().as_str().to_string(),
                span(&dev.acc_dev),
                span(&dev.act_dev),
                format!("2^{}", dev.act_scale_log2),
            ]);
        }
        summaries.push(format!(
            "{name}: proven logit bound {}, stability margin {}{}",
            report.logit_bound,
            report.stable_margin,
            if report.certified_exact {
                " (certified exact: top-1 provably unchanged)"
            } else {
                ""
            },
        ));
    }
    println!("{}", table.render());
    for s in summaries {
        println!("{s}");
    }
    println!();
    Ok(())
}

/// Base model a frontier JSON is checked against: `--synthetic [--seed N]`
/// mirrors `explore --synthetic`, otherwise `--profile` reads the store.
fn check_base_model(a: &onnx2hw::cli::Args) -> Result<onnx2hw::qonnx::QonnxModel> {
    if a.flag("synthetic") {
        let seed: u64 = a.parse_num("seed")?;
        let mut rng = onnx2hw::testkit::Rng::new(seed);
        let cfg = onnx2hw::qonnx::RandModelCfg {
            side: 8,
            cin: 1,
            blocks: vec![(4, 8, 8), (8, 8, 8)],
            classes: 5,
        };
        let text = onnx2hw::qonnx::random_model_json(&cfg, &mut rng);
        return onnx2hw::qonnx::read_str(&text).map_err(|e| anyhow::anyhow!("{e}"));
    }
    if let Some(profile) = a.opt_str("profile") {
        let store = ArtifactStore::discover()?;
        return store.qonnx(profile);
    }
    bail!("frontier checking needs a base model: pass --profile <P> or --synthetic [--seed N]")
}

fn cmd_classify(argv: &[String]) -> Result<()> {
    let spec = Spec::new("onnx2hw classify", "classify test images on the PJRT runtime")
        .opt("profile", "A8-W8", "profile to run")
        .opt("n", "16", "number of test images");
    let a = parse_or_usage(spec, argv)?;
    let store = ArtifactStore::discover()?;
    let testset = store.testset()?;
    let n: usize = a.parse_num("n")?;
    let profile = a.get("profile").unwrap();
    let mut engine = PjrtEngine::new()?;
    let dt = engine.load(&store, profile, 1)?;
    println!("platform {} | compiled {} in {:?}", engine.platform(), profile, dt);
    let mut correct = 0;
    for i in 0..n.min(testset.len()) {
        let (_logits, pred) = engine.classify_one(profile, testset.image(i))?;
        let label = testset.labels[i] as usize;
        if pred == label {
            correct += 1;
        }
        println!("image {i}: pred {pred} label {label}");
    }
    println!("accuracy {}/{}", correct, n.min(testset.len()));
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let spec = Spec::new("onnx2hw serve", "adaptive server on a synthetic workload")
        .opt("requests", "256", "number of requests to push")
        .opt("backend", "sim", "sim | pjrt")
        .opt("battery-j", "0.05", "global battery energy in joules (split across shards)")
        .opt("shard-capacity", "", "per-shard battery in joules (overrides the split)")
        .opt("power-cap", "", "per-shard power cap in mW")
        .opt("recharge-mw", "", "constant per-shard recharge source in mW")
        .opt("duty-cycle", "", "duty-cycled recharge 'mw:on_s:off_s' (per shard)")
        .opt("pair", "A8-W8,Mixed", "accurate,low-power profiles")
        .opt("workers", "2", "inference worker shards (backend replicas)")
        .opt("clients", "2", "concurrent synthetic client threads")
        .opt("listen", "", "serve the TCP wire protocol on this address (e.g. 127.0.0.1:7070)")
        .opt("admission-depth", "256", "shed requests past this aggregate in-flight depth (--listen)")
        .opt("net-window", "32", "per-connection in-flight window (--listen)")
        .opt("max-requests", "0", "with --listen: exit after this many replies (0 = serve forever)")
        .opt("trace-out", "", "write a Chrome trace-event JSON of every request to this file")
        .flag("synthetic", "with --listen: serve the deterministic synthetic model (no artifacts)")
        .flag("no-steal", "disable work stealing between shards");
    let a = parse_or_usage(spec, argv)?;
    if let Some(addr) = a.opt_str("listen") {
        return serve_listen(&a, addr);
    }
    if a.flag("synthetic") {
        bail!("--synthetic only applies to the network front end: pass --listen <addr>");
    }
    let store = ArtifactStore::discover()?;
    let testset = store.testset()?;
    let pair: Vec<String> = a.get("pair").unwrap().split(',').map(String::from).collect();
    let cfg = FlowConfig::default();
    let rows = flow::table1(
        &store,
        &pair.iter().map(String::as_str).collect::<Vec<_>>(),
        &cfg,
    )?;
    let specs: Vec<ProfileSpec> = rows
        .iter()
        .map(|r| ProfileSpec {
            name: r.profile.clone(),
            accuracy: r.accuracy_pct / 100.0,
            power_mw: r.power_mw,
            latency_us: r.latency_us,
        })
        .collect();
    let manager = ProfileManager::new(ManagerConfig::default(), specs);
    let energy = EnergyMonitor::new(a.parse_num("battery-j")?);
    let backend_kind = a.get("backend").unwrap().to_string();
    let workers: usize = a.parse_num("workers")?;
    let clients: usize = std::cmp::max(1, a.parse_num("clients")?);
    let shard_capacity_j = a
        .opt_str("shard-capacity")
        .map(|s| {
            s.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--shard-capacity: cannot parse '{s}'"))
        })
        .transpose()?
        .map(|j| vec![j]);
    let shard_power_cap_mw = a
        .opt_str("power-cap")
        .map(|s| {
            s.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--power-cap: cannot parse '{s}'"))
        })
        .transpose()?;
    let recharge = parse_recharge(a.opt_str("recharge-mw"), a.opt_str("duty-cycle"))?;
    let store2 = store.clone();
    let pair2 = pair.clone();
    let trace_out = a.opt_str("trace-out").map(String::from);
    let trace = trace_out.as_ref().map(|_| Arc::new(TraceCollector::new(workers)));
    // No Arc needed: client threads hold detached ClientHandles, not the
    // server value.
    let srv = AdaptiveServer::start(
        ServerConfig {
            workers,
            shard_capacity_j,
            shard_power_cap_mw,
            recharge: recharge.clone(),
            steal: !a.flag("no-steal"),
            trace: trace.clone(),
            ..Default::default()
        },
        move || {
            let names: Vec<&str> = pair2.iter().map(String::as_str).collect();
            match backend_kind.as_str() {
                "pjrt" => Backend::pjrt(&store2, &names),
                _ => Backend::sim(&store2, &names),
            }
        },
        manager,
        energy,
    )?;
    let n: usize = a.parse_num("requests")?;
    let testset = Arc::new(testset);
    #[allow(clippy::disallowed_methods)] // wall-clock: measured serving throughput
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        // Async client API: pipelined submission keeps a window of
        // requests in flight instead of blocking per request.
        let client = srv.client();
        let testset = testset.clone();
        handles.push(std::thread::spawn(move || -> Result<usize> {
            let idxs: Vec<usize> = (c..n).step_by(clients).map(|i| i % testset.len()).collect();
            let replies = client
                .classify_pipelined(idxs.iter().map(|&i| testset.image(i).to_vec()), 16);
            let mut correct = 0usize;
            for (&idx, reply) in idxs.iter().zip(replies) {
                if reply?.pred == testset.labels[idx] as usize {
                    correct += 1;
                }
            }
            Ok(correct)
        }));
    }
    let mut correct = 0usize;
    for h in handles {
        correct += h.join().expect("client thread panicked")?;
    }
    let wall = t0.elapsed();
    println!(
        "served {} requests on {} shards x {} clients in {:.2}s ({:.0} req/s)",
        srv.stats.requests.get(),
        srv.workers(),
        clients,
        wall.as_secs_f64(),
        n as f64 / wall.as_secs_f64().max(1e-9)
    );
    println!(
        "accuracy {:.1}% | batches {} | switches {} | \
         p50 {}us p95 {}us | mean battery left {:.1}%",
        100.0 * correct as f64 / n as f64,
        srv.stats.batches.get(),
        srv.stats.switches.get(),
        srv.stats.latency.quantile_us(0.5),
        srv.stats.latency.quantile_us(0.95),
        srv.battery_fraction() * 100.0
    );
    if recharge != EnergySource::None {
        println!("recharge source per shard: {}", recharge.label());
    }
    for (i, e) in srv.shard_energy.iter().enumerate() {
        println!(
            "  shard {i}: {} batches ({} stolen) | battery {:.1}% of {:.3} mJ | \
             recharged {:.3} mJ over {:.3} s virtual",
            srv.stats.worker_batches[i].get(),
            srv.stats.worker_steals[i].get(),
            e.remaining_fraction() * 100.0,
            e.capacity_j() * 1e3,
            srv.stats.shard_recharged_j[i].get() * 1e3,
            e.virtual_time_s()
        );
    }
    println!("queue depth now: {}", srv.stats.queue_depth.get());
    for ev in srv.stats.events.snapshot() {
        println!("  event: {ev}");
    }
    srv.shutdown();
    if let (Some(path), Some(t)) = (&trace_out, &trace) {
        write_trace(path, t)?;
    }
    Ok(())
}

/// Dump a collector's snapshot as Chrome trace-event JSON (open in
/// Perfetto / chrome://tracing) and report what was captured.
fn write_trace(path: &str, trace: &TraceCollector) -> Result<()> {
    let snap = trace.snapshot();
    std::fs::write(path, json::to_string(&snap.to_chrome_json()))
        .with_context(|| format!("write trace {path}"))?;
    println!(
        "trace: {} spans, {} events ({} dropped) -> {path}",
        snap.spans.len(),
        snap.events.len(),
        snap.dropped
    );
    Ok(())
}

type BackendFactory = Box<dyn Fn() -> Result<Backend> + Send + Sync>;

/// `serve --listen`: put the TCP wire-protocol front end ([`NetServer`]) in
/// front of the adaptive spine and block until `--max-requests` replies have
/// been written (0 = serve until killed). `--synthetic` serves the
/// deterministic synthetic model under "hi"/"lo" profiles so no artifact
/// store is needed — the loopback twin of `explore --synthetic`.
fn serve_listen(a: &onnx2hw::cli::Args, addr: &str) -> Result<()> {
    let workers: usize = a.parse_num("workers")?;
    let admission_depth: usize = a.parse_num("admission-depth")?;
    let window: usize = a.parse_num("net-window")?;
    let max_requests: u64 = a.parse_num("max-requests")?;
    let recharge = parse_recharge(a.opt_str("recharge-mw"), a.opt_str("duty-cycle"))?;
    let shard_capacity_j = a
        .opt_str("shard-capacity")
        .map(|s| {
            s.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--shard-capacity: cannot parse '{s}'"))
        })
        .transpose()?
        .map(|j| vec![j]);
    let shard_power_cap_mw = a
        .opt_str("power-cap")
        .map(|s| {
            s.parse::<f64>()
                .map_err(|_| anyhow::anyhow!("--power-cap: cannot parse '{s}'"))
        })
        .transpose()?;

    let (factory, specs, image_len): (BackendFactory, Vec<ProfileSpec>, usize) =
        if a.flag("synthetic") {
            let model = onnx2hw::qonnx::read_str(&onnx2hw::qonnx::test_model_json(1, 2))
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            let image_len = model.input_shape.elems();
            let models: std::collections::BTreeMap<String, onnx2hw::qonnx::QonnxModel> =
                [("hi".to_string(), model.clone()), ("lo".to_string(), model)]
                    .into_iter()
                    .collect();
            let specs = vec![
                ProfileSpec {
                    name: "hi".into(),
                    accuracy: 0.96,
                    power_mw: 142.0,
                    latency_us: 329.0,
                },
                ProfileSpec {
                    name: "lo".into(),
                    accuracy: 0.94,
                    power_mw: 76.0,
                    latency_us: 329.0,
                },
            ];
            let factory: BackendFactory =
                Box::new(move || Ok(Backend::sim_from_models(models.clone())));
            (factory, specs, image_len)
        } else {
            let store = ArtifactStore::discover()?;
            let pair: Vec<String> = a.get("pair").unwrap().split(',').map(String::from).collect();
            let cfg = FlowConfig::default();
            let rows = flow::table1(
                &store,
                &pair.iter().map(String::as_str).collect::<Vec<_>>(),
                &cfg,
            )?;
            let specs: Vec<ProfileSpec> = rows
                .iter()
                .map(|r| ProfileSpec {
                    name: r.profile.clone(),
                    accuracy: r.accuracy_pct / 100.0,
                    power_mw: r.power_mw,
                    latency_us: r.latency_us,
                })
                .collect();
            let image_len = store.qonnx(&pair[0])?.input_shape.elems();
            let backend_kind = a.get("backend").unwrap().to_string();
            let factory: BackendFactory = Box::new(move || {
                let names: Vec<&str> = pair.iter().map(String::as_str).collect();
                match backend_kind.as_str() {
                    "pjrt" => Backend::pjrt(&store, &names),
                    _ => Backend::sim(&store, &names),
                }
            });
            (factory, specs, image_len)
        };

    let manager = ProfileManager::new(ManagerConfig::default(), specs);
    let energy = EnergyMonitor::new(a.parse_num("battery-j")?);
    let trace_out = a.opt_str("trace-out").map(String::from);
    let trace = trace_out.as_ref().map(|_| Arc::new(TraceCollector::new(workers)));
    let srv = AdaptiveServer::start(
        ServerConfig {
            workers,
            shard_capacity_j,
            shard_power_cap_mw,
            recharge,
            steal: !a.flag("no-steal"),
            trace: trace.clone(),
            ..Default::default()
        },
        factory,
        manager,
        energy,
    )?;
    let net = NetServer::start(
        NetServerConfig {
            addr: addr.to_string(),
            admission_depth,
            window,
            expected_image_len: Some(image_len),
            spine_registry: Some(srv.stats.registry.clone()),
            trace: trace.clone(),
            ..Default::default()
        },
        srv.client(),
    )?;
    println!(
        "listening on {} | image payload {image_len} bytes | {} shards | \
         admission depth {admission_depth} | window {window}",
        net.addr(),
        srv.workers()
    );
    loop {
        #[allow(clippy::disallowed_methods)] // wall-clock: stats-reporting tick of a live server
        std::thread::sleep(std::time::Duration::from_millis(50));
        let replies = net.stats.served.get()
            + net.stats.failed.get()
            + net.stats.shed.get()
            + net.stats.bad_requests.get();
        if max_requests > 0 && replies >= max_requests {
            break;
        }
    }
    println!(
        "draining: served {} | shed {} | bad requests {} | frame errors {} | \
         connections {} | p50 {}us p99 {}us | battery {:.1}%",
        net.stats.served.get(),
        net.stats.shed.get(),
        net.stats.bad_requests.get(),
        net.stats.frame_errors.get(),
        net.stats.connections.get(),
        srv.stats.latency.quantile_us(0.5),
        srv.stats.latency.quantile_us(0.99),
        srv.battery_fraction() * 100.0
    );
    net.shutdown();
    srv.shutdown();
    if let (Some(path), Some(t)) = (&trace_out, &trace) {
        write_trace(path, t)?;
    }
    Ok(())
}

fn cmd_loadgen(argv: &[String]) -> Result<()> {
    let spec = Spec::new(
        "onnx2hw loadgen",
        "open-loop load generator: virtual-time queue model, or drive a live server",
    )
    .opt("rate", "6000", "offered arrival rate in requests/s")
    .opt("requests", "4000", "arrivals in the schedule")
    .opt("pattern", "poisson", "arrival schedule: poisson | uniform")
    .opt("seed", "7", "seed for the Poisson schedule")
    .opt(
        "trace",
        "",
        "arrival trace JSON file (phases of rate/duration/pattern; \
         overrides --rate/--requests/--pattern/--seed)",
    )
    .opt("shards", "4", "worker shards (model mode)")
    .opt("service-us", "329", "per-request service time in us (model mode)")
    .opt("admission", "64", "admission-control depth")
    .opt("json", "", "write the report JSON here")
    .opt("connect", "", "drive a live `serve --listen` server at this address")
    .opt("image-len", "0", "request payload bytes (required with --connect)")
    .opt("window", "32", "in-flight window per connection (--connect)");
    let a = parse_or_usage(spec, argv)?;
    let mut rate: f64 = a.parse_num("rate")?;
    if !rate.is_finite() || rate <= 0.0 {
        bail!("--rate must be finite and > 0, got {rate}");
    }
    let n: usize = a.parse_num("requests")?;
    let mut seed: u64 = a.parse_num("seed")?;
    let (arrivals, pattern) = if let Some(path) = a.opt_str("trace") {
        let src = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("read trace {path}: {e}"))?;
        let parsed =
            json::parse(&src).map_err(|e| anyhow::anyhow!("parse trace {path}: {e}"))?;
        let trace =
            loadgen::TraceSpec::from_json(&parsed).map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
        let arrivals = trace.arrivals();
        // Report the trace's own seed and average offered rate.
        seed = trace.seed;
        rate = arrivals.len() as f64 / trace.horizon_s().max(f64::MIN_POSITIVE);
        (arrivals, "trace")
    } else {
        let arrivals = match a.get("pattern").unwrap() {
            "poisson" => loadgen::poisson_arrivals(rate, n, seed),
            "uniform" => loadgen::uniform_arrivals(rate, n),
            other => bail!("unknown --pattern '{other}' (want poisson|uniform)"),
        };
        (arrivals, a.get("pattern").unwrap())
    };
    if let Some(addr) = a.opt_str("connect") {
        return loadgen_live(&a, addr, &arrivals, rate);
    }

    let cfg = loadgen::OpenLoopConfig {
        shards: a.parse_num("shards")?,
        service_us: a.parse_num("service-us")?,
        admission_depth: a.parse_num("admission")?,
    };
    let report = loadgen::simulate(&arrivals, &cfg);
    println!(
        "== open-loop model: {} arrivals at {rate:.0}/s over {:.3}s virtual \
         ({} shards x {:.0}us service, depth {}) ==",
        report.offered, report.horizon_s, cfg.shards, cfg.service_us, cfg.admission_depth
    );
    println!(
        "served {} | shed {} ({:.2}%) | p50 {}us p99 {}us p999 {}us max {}us | mean {:.0}us",
        report.served,
        report.shed,
        report.shed_fraction * 100.0,
        report.p50_us,
        report.p99_us,
        report.p999_us,
        report.max_us,
        report.mean_us
    );
    println!(
        "per-shard depth high-water: {:?} (ceiling {})",
        report.max_depth, cfg.admission_depth
    );
    if let Some(path) = a.opt_str("json") {
        let row = Value::obj(vec![
            ("mode", "model".into()),
            ("pattern", pattern.into()),
            ("rate_per_s", rate.into()),
            ("seed", (seed as i64).into()),
            ("shards", cfg.shards.into()),
            ("service_us", cfg.service_us.into()),
            ("admission_depth", cfg.admission_depth.into()),
            ("offered", report.offered.into()),
            ("served", report.served.into()),
            ("shed", report.shed.into()),
            ("shed_fraction", report.shed_fraction.into()),
            ("p50_us", (report.p50_us as i64).into()),
            ("p99_us", (report.p99_us as i64).into()),
            ("p999_us", (report.p999_us as i64).into()),
            ("max_us", (report.max_us as i64).into()),
            ("mean_us", report.mean_us.into()),
            ("horizon_s", report.horizon_s.into()),
            (
                "max_depth",
                Value::Array(report.max_depth.iter().map(|&d| d.into()).collect()),
            ),
        ]);
        std::fs::write(path, json::to_string_pretty(&row))?;
        println!("wrote report to {path}");
    }
    Ok(())
}

/// Drive a live `serve --listen` server with the arrival schedule on the
/// wall clock: sleep to each arrival instant, submit, and read replies in
/// submission order whenever the window is full. Overloaded denials count
/// as shed, exactly like the virtual-time model.
#[allow(clippy::disallowed_methods)] // wall-clock: pacing a live open-loop run
fn loadgen_live(
    a: &onnx2hw::cli::Args,
    addr: &str,
    arrivals: &[f64],
    rate: f64,
) -> Result<()> {
    use std::time::{Duration, Instant};

    let image_len: usize = a.parse_num("image-len")?;
    if image_len == 0 {
        bail!("--connect needs --image-len (serve --listen prints the expected payload size)");
    }
    let window: usize = std::cmp::max(1, a.parse_num("window")?);
    let mut client = NetClient::connect(addr)?;
    let images: Vec<Vec<u8>> = (0..8)
        .map(|k| (0..image_len).map(|i| ((i * 31 + k * 17) % 256) as u8).collect())
        .collect();

    let mut send_times: std::collections::VecDeque<Instant> = std::collections::VecDeque::new();
    let mut latencies: Vec<u64> = Vec::new();
    let mut shed = 0usize;
    let mut failed = 0usize;
    // Replies arrive in submission order (per-connection guarantee), so the
    // oldest send time always matches the next reply.
    let drain_one = |client: &mut NetClient,
                         send_times: &mut std::collections::VecDeque<Instant>,
                         latencies: &mut Vec<u64>,
                         shed: &mut usize,
                         failed: &mut usize|
     -> Result<()> {
        let sent = send_times.pop_front().expect("a reply implies a send");
        match client.recv()? {
            NetReply::Response(_) => latencies.push(sent.elapsed().as_micros() as u64),
            NetReply::Denied {
                code: onnx2hw::net::ErrCode::Overloaded,
                ..
            } => *shed += 1,
            NetReply::Denied { .. } => *failed += 1,
        }
        Ok(())
    };

    let t0 = Instant::now();
    for (i, &at) in arrivals.iter().enumerate() {
        let target = t0 + Duration::from_secs_f64(at);
        let now = Instant::now();
        if target > now {
            std::thread::sleep(target - now);
        }
        while send_times.len() >= window {
            drain_one(&mut client, &mut send_times, &mut latencies, &mut shed, &mut failed)?;
        }
        client.submit(&images[i % images.len()])?;
        send_times.push_back(Instant::now());
    }
    while !send_times.is_empty() {
        drain_one(&mut client, &mut send_times, &mut latencies, &mut shed, &mut failed)?;
    }
    let wall = t0.elapsed().as_secs_f64();

    latencies.sort_unstable();
    let offered = arrivals.len();
    let served = latencies.len();
    let p50 = onnx2hw::metrics::exact_quantile_us(&latencies, 0.50);
    let p99 = onnx2hw::metrics::exact_quantile_us(&latencies, 0.99);
    let p999 = onnx2hw::metrics::exact_quantile_us(&latencies, 0.999);
    let max = latencies.last().copied().unwrap_or(0);
    println!(
        "== open-loop live run against {addr}: {offered} arrivals at {rate:.0}/s \
         over {wall:.3}s wall (window {window}) ==",
    );
    println!(
        "served {served} | shed {shed} | other denials {failed} | \
         p50 {p50}us p99 {p99}us p999 {p999}us max {max}us"
    );
    println!(
        "note: the in-flight window bounds this client, so offered load is \
         windowed open-loop, not pure open-loop — the virtual-time model \
         (without --connect) is the unthrottled reference"
    );
    if let Some(path) = a.opt_str("json") {
        let row = Value::obj(vec![
            ("mode", "live".into()),
            ("addr", addr.into()),
            ("rate_per_s", rate.into()),
            ("offered", offered.into()),
            ("served", served.into()),
            ("shed", shed.into()),
            ("other_denials", failed.into()),
            ("wall_s", wall.into()),
            ("p50_us", (p50 as i64).into()),
            ("p99_us", (p99 as i64).into()),
            ("p999_us", (p999 as i64).into()),
            ("max_us", (max as i64).into()),
        ]);
        std::fs::write(path, json::to_string_pretty(&row))?;
        println!("wrote report to {path}");
    }
    Ok(())
}

/// Build the per-shard recharge source from `--recharge-mw` / `--duty-cycle`
/// (mutually exclusive; both absent means the battery only drains).
fn parse_recharge(recharge_mw: Option<&str>, duty: Option<&str>) -> Result<EnergySource> {
    let recharge_mw = recharge_mw.filter(|s| !s.is_empty());
    let duty = duty.filter(|s| !s.is_empty());
    match (recharge_mw, duty) {
        (Some(_), Some(_)) => bail!("--recharge-mw and --duty-cycle are mutually exclusive"),
        (Some(mw), None) => {
            let mw: f64 = mw
                .parse()
                .map_err(|_| anyhow::anyhow!("--recharge-mw: cannot parse '{mw}'"))?;
            if !mw.is_finite() || mw < 0.0 {
                bail!("--recharge-mw must be finite and >= 0, got {mw}");
            }
            Ok(EnergySource::constant(mw))
        }
        (None, Some(spec)) => {
            let parts: Vec<f64> = spec
                .split(':')
                .map(|p| {
                    p.parse::<f64>()
                        .map_err(|_| anyhow::anyhow!("--duty-cycle: cannot parse '{p}'"))
                })
                .collect::<Result<_>>()?;
            if parts.len() != 3 {
                bail!("--duty-cycle wants 'mw:on_s:off_s', got '{spec}'");
            }
            let (mw, on_s, off_s) = (parts[0], parts[1], parts[2]);
            if !mw.is_finite() || mw < 0.0 {
                bail!("--duty-cycle power must be finite and >= 0, got {mw}");
            }
            // NaN/inf must fail here with a usage error, not trip the
            // library assert downstream.
            if !on_s.is_finite() || !off_s.is_finite() || on_s < 0.0 || off_s < 0.0 {
                bail!("--duty-cycle needs finite on_s, off_s >= 0, got {on_s}:{off_s}");
            }
            if on_s + off_s <= 0.0 {
                bail!("--duty-cycle needs a positive period (on_s + off_s > 0)");
            }
            Ok(EnergySource::duty_cycle(mw, on_s, off_s))
        }
        (None, None) => Ok(EnergySource::None),
    }
}

/// `onnx2hw trace`: record a span trace of an offline scenario and write it
/// as Chrome trace-event JSON (open in Perfetto / chrome://tracing).
///
/// * `load`  — the virtual-time open-loop model with tracing on. Fully
///   deterministic: the same seed yields byte-identical trace JSON (the
///   determinism half of the `trace_conservation` gate).
/// * `chaos` — the live in-process spine (synthetic model) under a seeded
///   [`FaultPlan`], tracing on: real worker threads leave dispatch /
///   queue-wait / shard-exec spans with per-layer kernel sub-spans, plus
///   death / respawn / steal / brown-out events.
fn cmd_trace(argv: &[String]) -> Result<()> {
    let spec = Spec::new("onnx2hw trace", "record a span trace of an offline scenario")
        .opt("scenario", "load", "load | chaos")
        .opt("out", "trace.json", "write the Chrome trace-event JSON here")
        .opt("seed", "7", "schedule / fault-plan seed")
        .opt("requests", "2000", "arrivals (load) or requests pushed (chaos)")
        .opt("rate", "6000", "offered arrival rate in requests/s (load)")
        .opt("shards", "4", "worker shards")
        .opt("service-us", "329", "per-request service time in us (load)")
        .opt("admission", "64", "admission-control depth (load)");
    let a = parse_or_usage(spec, argv)?;
    let out = a.get("out").unwrap().to_string();
    let seed: u64 = a.parse_num("seed")?;
    let n: usize = a.parse_num("requests")?;
    let shards: usize = std::cmp::max(1, a.parse_num("shards")?);
    match a.get("scenario").unwrap() {
        "load" => {
            let cfg = loadgen::OpenLoopConfig {
                shards,
                service_us: a.parse_num("service-us")?,
                admission_depth: a.parse_num("admission")?,
            };
            let arrivals = loadgen::poisson_arrivals(a.parse_num("rate")?, n, seed);
            let tc = TraceCollector::new(shards);
            let report = loadgen::simulate_traced(&arrivals, &cfg, &tc);
            println!(
                "load scenario: {} offered, {} served, {} shed (seed {seed})",
                report.offered, report.served, report.shed
            );
            write_trace(&out, &tc)
        }
        "chaos" => trace_chaos(&out, seed, n, shards),
        other => bail!("unknown --scenario '{other}' (want load|chaos)"),
    }
}

/// The chaos half of `onnx2hw trace`: synthetic spine + seeded fault plan,
/// every request pushed through the real worker threads with tracing on.
fn trace_chaos(out: &str, seed: u64, n: usize, shards: usize) -> Result<()> {
    let model = onnx2hw::qonnx::read_str(&onnx2hw::qonnx::test_model_json(1, 2))
        .map_err(|e| anyhow::anyhow!("{e}"))?;
    let elems = model.input_shape.elems();
    let models: std::collections::BTreeMap<String, onnx2hw::qonnx::QonnxModel> =
        [("hi".to_string(), model.clone()), ("lo".to_string(), model)]
            .into_iter()
            .collect();
    let specs = vec![
        ProfileSpec {
            name: "hi".into(),
            accuracy: 0.96,
            power_mw: 142.0,
            latency_us: 329.0,
        },
        ProfileSpec {
            name: "lo".into(),
            accuracy: 0.94,
            power_mw: 76.0,
            latency_us: 329.0,
        },
    ];
    let plan = FaultPlan::seeded(
        seed,
        &FaultSpec {
            shards,
            horizon_batches: (n as u64 / 8).max(8),
            horizon_requests: n as u64,
            resets: 0,
            corruptions: 0,
            ..FaultSpec::default()
        },
    );
    println!("fault plan: {}", json::to_string(&plan.to_json()));
    // Fault-injection panics are the plan doing its job; keep the output
    // readable by muting exactly those.
    let default_hook = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let injected = info
            .payload()
            .downcast_ref::<String>()
            .is_some_and(|m| m.contains("fault injection"));
        if !injected {
            default_hook(info);
        }
    }));
    let injector = Arc::new(plan.injector());
    let trace = Arc::new(TraceCollector::new(shards));
    let manager = ProfileManager::new(ManagerConfig::default(), specs);
    let energy = EnergyMonitor::new(0.05);
    let srv = AdaptiveServer::start(
        ServerConfig {
            workers: shards,
            faults: Some(injector.clone()),
            trace: Some(trace.clone()),
            ..Default::default()
        },
        move || Ok(Backend::sim_from_models(models.clone())),
        manager,
        energy,
    )?;
    let client = srv.client();
    let img: Vec<u8> = (0..elems).map(|i| (i * 31 % 256) as u8).collect();
    let replies = client.classify_pipelined((0..n).map(|_| img.clone()), 32);
    let served = replies.iter().filter(|r| r.is_ok()).count();
    srv.shutdown();
    let snap = trace.snapshot();
    println!(
        "chaos scenario: {served} served, {} dropped, {} deaths, {} respawns (seed {seed})",
        n - served,
        snap.count_events(onnx2hw::trace::EventKind::Death),
        snap.count_events(onnx2hw::trace::EventKind::Respawn)
    );
    write_trace(out, &trace)
}

fn cmd_verify(argv: &[String]) -> Result<()> {
    let spec = Spec::new(
        "onnx2hw verify",
        "cross-check dataflow sim vs python vectors vs PJRT",
    )
    .opt("profiles", &ALL_PROFILES.join(","), "profiles to verify")
    .opt("n", "16", "PJRT images to cross-check")
    .flag(
        "allow-missing-pjrt",
        "skip (instead of fail) the PJRT cross-check when the runtime is unavailable",
    );
    let a = parse_or_usage(spec, argv)?;
    let store = ArtifactStore::discover()?;
    let testset = store.testset()?;
    let n: usize = a.parse_num("n")?;
    // The PJRT cross-check is part of verify's gate: an unavailable runtime
    // fails loudly unless the caller explicitly opts into skipping it
    // (offline builds vendor an xla stub). The bit-exact sim-vs-python
    // check below always runs and always gates.
    let mut engine = match PjrtEngine::new() {
        Ok(e) => Some(e),
        Err(e) if a.flag("allow-missing-pjrt") => {
            eprintln!("note: PJRT unavailable ({e}); skipping runtime cross-check");
            None
        }
        Err(e) => {
            return Err(e.context(
                "PJRT runtime unavailable (pass --allow-missing-pjrt to skip the cross-check)",
            ));
        }
    };
    for profile in a.get("profiles").unwrap().split(',') {
        let model = store.qonnx(profile)?;
        let vectors = store.vectors(profile)?;
        let mut ex = onnx2hw::dataflow::Executor::new(&model);
        let mut exact = 0usize;
        for (i, want) in vectors.logits.iter().enumerate() {
            let got = ex.run(testset.image(i));
            if &got == want {
                exact += 1;
            }
        }
        let mut pjrt_report = "skipped".to_string();
        if let Some(engine) = engine.as_mut() {
            engine.load(&store, profile, 1)?;
            let mut agree = 0usize;
            for i in 0..n.min(testset.len()) {
                let logits = ex.run(testset.image(i));
                let sim_pred = onnx2hw::dataflow::exec::argmax(&logits);
                let (_l, pjrt_pred) = engine.classify_one(profile, testset.image(i))?;
                if sim_pred == pjrt_pred {
                    agree += 1;
                }
            }
            pjrt_report = format!("{agree}/{}", n.min(testset.len()));
        }
        println!(
            "{profile}: rust-vs-python bit-exact {exact}/{} | rust-vs-PJRT argmax {pjrt_report}",
            vectors.logits.len()
        );
        if exact != vectors.logits.len() {
            bail!("{profile}: dataflow engine diverges from python intref");
        }
    }
    println!("verify OK");
    Ok(())
}
