//! HLS Writer: the target-dependent back half of the ONNXParser.
//!
//! In the paper the Writer emits (a) C++ instantiations of the streaming
//! actor templates with `ap_fixed`/`ap_uint` arbitrary-precision types and
//! (b) TCL scripts that drive Vitis HLS. Our substitution keeps both
//! outputs — the generated C++/TCL text is what a user would hand to a real
//! Vitis installation — while the in-repo flow consumes the same layer
//! descriptions through `hls::estimate` and `dataflow::sim` instead of RTL.
//!
//! Emitting real template instantiations keeps this module honest: tests
//! assert the emitted types/pragmas reflect the QONNX precisions exactly.

mod hlscpp;
mod tcl;

pub use hlscpp::{emit_cpp, emit_header};
pub use tcl::emit_tcl;

use crate::dataflow::FoldingConfig;
use crate::qonnx::QonnxModel;

/// Everything the Writer produces for one profile.
#[derive(Debug, Clone)]
pub struct WriterOutput {
    /// `<profile>_engine.cpp` — top-level dataflow function.
    pub cpp: String,
    /// `<profile>_engine.h` — actor template header.
    pub header: String,
    /// `build_<profile>.tcl` — Vitis HLS batch script.
    pub tcl: String,
}

/// Run the Writer on a parsed model.
pub fn write_engine(model: &QonnxModel, fold: &FoldingConfig) -> WriterOutput {
    WriterOutput {
        cpp: emit_cpp(model, fold),
        header: emit_header(),
        tcl: emit_tcl(model),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::{read_str, test_model_json};

    #[test]
    fn writer_emits_all_three_artifacts() {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let out = write_engine(&m, &FoldingConfig::default());
        assert!(out.cpp.contains("void engine_T"));
        assert!(out.header.contains("template"));
        assert!(out.tcl.contains("open_project"));
    }
}
