//! Human/machine-readable utilization reports (the Vitis HLS report file).

use super::device::DeviceModel;
use super::estimate::EngineEstimate;
use crate::json::Value;

/// Rendered utilization report for one engine on one device.
#[derive(Debug, Clone)]
pub struct UtilizationReport {
    pub profile: String,
    pub device: String,
    pub luts: u64,
    pub lut_pct: f64,
    pub ffs: u64,
    pub ff_pct: f64,
    pub bram36: f64,
    pub bram_pct: f64,
    pub dsp: u64,
    pub dsp_pct: f64,
    pub latency_cycles: u64,
    pub latency_us: f64,
    pub clock_mhz: f64,
    pub per_actor: Vec<(String, u64, u64, u64)>, // (name, luts, bram18, ii)
}

impl UtilizationReport {
    pub fn new(profile: &str, est: &EngineEstimate, dev: &DeviceModel) -> Self {
        UtilizationReport {
            profile: profile.to_string(),
            device: dev.name.clone(),
            luts: est.luts,
            lut_pct: dev.lut_pct(est.luts),
            ffs: est.ffs,
            ff_pct: dev.ff_pct(est.ffs),
            bram36: est.bram36,
            bram_pct: dev.bram_pct(est.bram36),
            dsp: est.dsp,
            dsp_pct: dev.dsp_pct(est.dsp),
            latency_cycles: est.latency_cycles,
            latency_us: est.latency_us(dev.clock_mhz),
            clock_mhz: dev.clock_mhz,
            per_actor: est
                .actors
                .iter()
                .map(|a| (a.name.clone(), a.luts, a.bram18, a.ii))
                .collect(),
        }
    }

    /// Fixed-width text table (the `vitis_hls` report look).
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "== Utilization: profile {} on {} @ {:.0} MHz ==\n",
            self.profile, self.device, self.clock_mhz
        ));
        s.push_str(&format!(
            "  LUT  {:>8}  ({:>5.1}%)\n  FF   {:>8}  ({:>5.1}%)\n  BRAM {:>8.1}  ({:>5.1}%)\n  DSP  {:>8}  ({:>5.1}%)\n",
            self.luts, self.lut_pct, self.ffs, self.ff_pct, self.bram36, self.bram_pct,
            self.dsp, self.dsp_pct
        ));
        s.push_str(&format!(
            "  latency {} cycles = {:.1} us\n  {:<18} {:>8} {:>8} {:>6}\n",
            self.latency_cycles, self.latency_us, "actor", "LUT", "BRAM18", "II"
        ));
        for (name, luts, bram18, ii) in &self.per_actor {
            s.push_str(&format!("  {name:<18} {luts:>8} {bram18:>8} {ii:>6}\n"));
        }
        s
    }

    pub fn to_json(&self) -> Value {
        Value::obj(vec![
            ("profile", self.profile.as_str().into()),
            ("device", self.device.as_str().into()),
            ("luts", (self.luts as i64).into()),
            ("lut_pct", self.lut_pct.into()),
            ("ffs", (self.ffs as i64).into()),
            ("bram36", self.bram36.into()),
            ("bram_pct", self.bram_pct.into()),
            ("dsp", (self.dsp as i64).into()),
            ("latency_cycles", (self.latency_cycles as i64).into()),
            ("latency_us", self.latency_us.into()),
            ("clock_mhz", self.clock_mhz.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::FoldingConfig;
    use crate::hls::{estimate_engine, Calibration};
    use crate::qonnx::{read_str, test_model_json};

    #[test]
    fn renders_and_serializes() {
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let est = estimate_engine(&m, &FoldingConfig::default(), &Calibration::default());
        let dev = DeviceModel::kria_kv260();
        let rep = UtilizationReport::new("T", &est, &dev);
        let text = rep.render();
        assert!(text.contains("LUT"));
        assert!(text.contains("conv1"));
        let j = rep.to_json();
        assert_eq!(j.get("profile").unwrap().as_str(), Some("T"));
        // round-trip through the json substrate
        let back = crate::json::parse(&crate::json::to_string(&j)).unwrap();
        assert_eq!(back.get("luts"), j.get("luts"));
    }
}
