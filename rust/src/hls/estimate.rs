//! Per-actor scheduling + binding estimation (the Vitis HLS report).

use super::calib::Calibration;
use crate::dataflow::FoldingConfig;
use crate::qonnx::{infer_shapes, ConvLayer, DenseLayer, Layer, QonnxModel, TensorShape};

/// Resource + schedule estimate for one actor of the streaming engine.
#[derive(Debug, Clone, PartialEq)]
pub struct ActorEstimate {
    pub name: String,
    pub luts: u64,
    pub ffs: u64,
    /// BRAM18 halves (reports aggregate as BRAM36 = bram18 / 2).
    pub bram18: u64,
    pub dsp: u64,
    /// Initiation interval: cycles between consecutive outputs.
    pub ii: u64,
    /// Pipeline depth (fill latency contribution), cycles.
    pub depth: u64,
    /// Number of output tokens this actor produces per image.
    pub tokens: u64,
}

/// Whole-engine estimate: per-actor breakdown + totals + analytic latency.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineEstimate {
    pub actors: Vec<ActorEstimate>,
    pub luts: u64,
    pub ffs: u64,
    pub bram36: f64,
    pub dsp: u64,
    /// Analytic latency in cycles: bottleneck II * its token count + the
    /// pipeline fill depth. Cross-checked against the dataflow simulator.
    pub latency_cycles: u64,
}

impl EngineEstimate {
    pub fn latency_us(&self, clock_mhz: f64) -> f64 {
        self.latency_cycles as f64 / clock_mhz
    }
}

fn mul_luts(cal: &Calibration, a_bits: u32, w_bits: u32) -> (f64, u64) {
    // DSP binding when both operands exceed the threshold: LUT cost drops to
    // glue logic, one DSP per MAC unit.
    if a_bits > cal.dsp_threshold_bits && w_bits > cal.dsp_threshold_bits {
        (6.0, 1)
    } else {
        (
            cal.k_mul_w * w_bits as f64 + cal.k_mul_a * a_bits as f64 + cal.k_mul_base,
            0,
        )
    }
}

/// Accumulator width for a conv: product bits + log2(taps) guard bits.
fn acc_bits(a_bits: u32, w_bits: u32, taps: usize) -> u32 {
    a_bits + w_bits + (64 - (taps as u64).leading_zeros())
}

fn conv_estimate(
    cal: &Calibration,
    c: &ConvLayer,
    shape_in: TensorShape,
    pe: usize,
    simd: usize,
    in_bits: u32,
) -> (ActorEstimate, ActorEstimate) {
    // --- line buffer actor: 2 full rows + 3x3 window regs, in BRAM ---
    let row_bits = (shape_in.w * shape_in.c) as u64 * in_bits as u64;
    let lb_bram18 = (2 * row_bits).div_ceil(cal.bram18_bits).max(1);
    let lb = ActorEstimate {
        name: format!("{}_linebuf", c.name),
        luts: (cal.k_actor_ctrl + 9.0 * shape_in.c as f64) as u64,
        ffs: (9 * shape_in.c) as u64 * in_bits as u64,
        bram18: lb_bram18,
        dsp: 0,
        ii: 1,
        depth: (shape_in.w + 2) as u64, // one row + margin to form windows
        tokens: (shape_in.h * shape_in.w) as u64,
    };

    // --- conv MAC actor: PE x SIMD multipliers + adder trees + requant ---
    let taps = 9 * c.cin;
    let (lut_per_mac, dsp_per_mac) = mul_luts(cal, in_bits, c.weight_bits);
    let units = (pe * simd) as f64;
    let acc_w = acc_bits(in_bits, c.weight_bits, taps) as f64;
    let luts = units * lut_per_mac
        + pe as f64 * acc_w * cal.k_acc_bit
        + pe as f64 * cal.k_requant
        + cal.k_actor_ctrl;
    // weight ROM: taps*cout words of w_bits, partitioned over the PE lanes
    // (each PE streams its own output channels' weights, as in FINN)
    let lanes = pe as u64;
    let total_w_bits = (taps * c.cout) as u64 * c.weight_bits as u64;
    let per_lane_bits = total_w_bits.div_ceil(lanes);
    let bram18 = lanes * per_lane_bits.div_ceil(cal.bram18_bits);
    // With few bits/lane Vitis uses LUTRAM instead: model as min against a
    // LUTRAM binding (64 bits/LUT).
    let lutram_cost = total_w_bits as f64 / 64.0;
    let (bram18, luts) = if (bram18 * cal.bram18_bits) as f64 > 4.0 * total_w_bits as f64 {
        (0, luts + lutram_cost)
    } else {
        (bram18, luts)
    };
    // window FIFO between line buffer and MAC array (deep tokens).
    let win_fifo_bits = 8 * (taps as u64) * in_bits as u64;
    let bram18 = bram18 + win_fifo_bits.div_ceil(cal.bram18_bits);
    let ii = (c.cout.div_ceil(pe) * taps.div_ceil(simd)) as u64;
    let mac = ActorEstimate {
        name: c.name.clone(),
        luts: luts as u64,
        ffs: (luts * cal.k_ff_per_lut) as u64,
        bram18,
        dsp: (units * dsp_per_mac as f64) as u64,
        ii: ii.max(1),
        depth: (taps.div_ceil(simd) + 4) as u64, // adder tree + requant regs
        tokens: (shape_in.h * shape_in.w) as u64,
    };
    (lb, mac)
}

fn pool_estimate(cal: &Calibration, name: &str, shape_in: TensorShape, bits: u32) -> ActorEstimate {
    // one pooled row of partial maxima in flops/LUTRAM
    let row_bits = (shape_in.w / 2 * shape_in.c) as u64 * bits as u64;
    ActorEstimate {
        name: name.to_string(),
        luts: (cal.k_actor_ctrl + shape_in.c as f64 * bits as f64 * 0.6) as u64,
        ffs: row_bits,
        bram18: 0,
        dsp: 0,
        ii: 1,
        depth: (shape_in.w / 2 + 2) as u64,
        tokens: (shape_in.h * shape_in.w / 4) as u64,
    }
}

fn gemm_estimate(
    cal: &Calibration,
    d: &DenseLayer,
    c_per_token: usize,
    pe: usize,
    simd: usize,
    in_bits: u32,
) -> ActorEstimate {
    let (lut_per_mac, dsp_per_mac) = mul_luts(cal, in_bits, d.weight_bits);
    let units = (pe * simd) as f64;
    let acc_w = acc_bits(in_bits, d.weight_bits, d.in_features) as f64;
    let luts = units * lut_per_mac
        + d.out_features as f64 * acc_w * cal.k_acc_bit
        + cal.k_actor_ctrl;
    let total_w_bits = (d.in_features * d.out_features) as u64 * d.weight_bits as u64;
    let lanes = pe as u64;
    let per_lane_bits = total_w_bits.div_ceil(lanes);
    let bram18 = lanes * per_lane_bits.div_ceil(cal.bram18_bits);
    let n_tokens = (d.in_features / c_per_token) as u64;
    let ii = (c_per_token.div_ceil(simd) * d.out_features.div_ceil(pe)) as u64;
    ActorEstimate {
        name: d.name.clone(),
        luts: luts as u64,
        ffs: (luts * cal.k_ff_per_lut) as u64,
        bram18,
        dsp: (units * dsp_per_mac as f64) as u64,
        ii: ii.max(1),
        depth: 8,
        tokens: n_tokens, // consumes tokens; produces 1 logits token at end
    }
}

/// Estimate the full streaming engine for `model` under `fold`.
pub fn estimate_engine(
    model: &QonnxModel,
    fold: &FoldingConfig,
    cal: &Calibration,
) -> EngineEstimate {
    let shapes = infer_shapes(model);
    let mut actors = Vec::new();
    let mut conv_idx = 0usize;
    let mut cur_bits = model.input_bits;
    let mut stream_c = model.input_shape.c;
    for (i, layer) in model.layers.iter().enumerate() {
        let shape_in = shapes[i];
        match layer {
            Layer::Conv(c) => {
                let (pe, simd) = if conv_idx == 0 {
                    (fold.conv1_pe, fold.conv1_simd)
                } else {
                    (fold.conv2_pe, fold.conv2_simd)
                };
                let (lb, mac) = conv_estimate(cal, c, shape_in, pe, simd, cur_bits);
                actors.push(lb);
                actors.push(mac);
                cur_bits = c.act_bits;
                stream_c = c.cout;
                conv_idx += 1;
            }
            Layer::Pool(p) => {
                actors.push(pool_estimate(cal, &p.name, shape_in, cur_bits));
            }
            Layer::Flatten { .. } => {}
            Layer::Dense(d) => {
                actors.push(gemm_estimate(
                    cal,
                    d,
                    stream_c,
                    fold.dense_pe,
                    fold.dense_simd,
                    cur_bits,
                ));
            }
        }
    }

    // Analytic latency: in a streaming pipeline every actor processes its
    // token stream concurrently; the makespan is the slowest actor's
    // (tokens * II) plus the total fill depth of the chain.
    let bottleneck = actors.iter().map(|a| a.tokens * a.ii).max().unwrap_or(0);
    let fill: u64 = actors.iter().map(|a| a.depth).sum();
    let latency_cycles = bottleneck + fill;

    EngineEstimate {
        luts: actors.iter().map(|a| a.luts).sum(),
        ffs: actors.iter().map(|a| a.ffs).sum(),
        bram36: actors.iter().map(|a| a.bram18).sum::<u64>() as f64 / 2.0,
        dsp: actors.iter().map(|a| a.dsp).sum(),
        latency_cycles,
        actors,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::{read_str, test_model_json};
    use crate::testkit;

    fn tiny() -> QonnxModel {
        read_str(&test_model_json(2, 4)).unwrap()
    }

    #[test]
    fn estimate_is_positive_and_consistent() {
        let m = tiny();
        let est = estimate_engine(&m, &FoldingConfig::default(), &Calibration::default());
        assert!(est.luts > 0);
        assert!(est.latency_cycles > 0);
        assert_eq!(est.luts, est.actors.iter().map(|a| a.luts).sum::<u64>());
    }

    #[test]
    fn luts_monotone_in_weight_bits() {
        // Table-1 invariant: resources monotone non-decreasing in bit-width.
        let m4 = tiny(); // weight_bits=4 in the generator
        let json8 = test_model_json(2, 4).replace("\"weight_bits\":4", "\"weight_bits\":8");
        let m8 = read_str(&json8).unwrap();
        let cal = Calibration::default();
        let f = FoldingConfig::default();
        let e4 = estimate_engine(&m4, &f, &cal);
        let e8 = estimate_engine(&m8, &f, &cal);
        assert!(e8.luts > e4.luts, "w8 {} <= w4 {}", e8.luts, e4.luts);
    }

    #[test]
    fn latency_independent_of_bits_property() {
        testkit::check("latency is bit-independent", |rng| {
            let cfg = crate::qonnx::RandModelCfg::gen(rng);
            let json = crate::qonnx::random_model_json(&cfg, rng);
            let m = read_str(&json).map_err(|e| e.to_string())?;
            // change all bit-widths, keep shapes/folding
            let json_wide = json
                .replace("\"act_bits\":4", "\"act_bits\":16")
                .replace("\"act_bits\":8", "\"act_bits\":16")
                .replace("\"weight_bits\":4", "\"weight_bits\":8");
            let m_wide = read_str(&json_wide).map_err(|e| e.to_string())?;
            let cal = Calibration::default();
            let f = FoldingConfig::default();
            let a = estimate_engine(&m, &f, &cal).latency_cycles;
            let b = estimate_engine(&m_wide, &f, &cal).latency_cycles;
            crate::prop_assert!(a == b, "latency changed with bits: {a} vs {b}");
            Ok(())
        });
    }

    #[test]
    fn analytic_latency_tracks_simulated_latency() {
        let m = tiny();
        let f = FoldingConfig::default();
        let est = estimate_engine(&m, &f, &Calibration::default());
        let img: Vec<u8> = (0..m.input_shape.elems()).map(|i| (i * 17 % 256) as u8).collect();
        let sim = crate::dataflow::simulate_image(&m, &f, &img);
        let a = est.latency_cycles as f64;
        let s = sim.cycles as f64;
        let ratio = a.max(s) / a.min(s);
        assert!(ratio < 1.6, "analytic {a} vs simulated {s} diverge (x{ratio:.2})");
    }
}
