//! FPGA device models (resource envelopes).

/// Resource envelope of the target FPGA.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceModel {
    pub name: String,
    pub luts: u64,
    pub ffs: u64,
    /// BRAM36 blocks (one BRAM36 = two independent BRAM18).
    pub bram36: u64,
    pub dsp: u64,
    /// Default fabric clock for latency conversion.
    pub clock_mhz: f64,
}

impl DeviceModel {
    /// AMD KRIA KV260 (Zynq UltraScale+ XCK26-SFVC784-2LV-C) — the paper's
    /// evaluation board.
    pub fn kria_kv260() -> Self {
        DeviceModel {
            name: "KRIA KV260 (XCK26)".to_string(),
            luts: 117_120,
            ffs: 234_240,
            bram36: 144,
            dsp: 1_248,
            clock_mhz: 100.0,
        }
    }

    /// Smaller edge device (Zynq-7020, PYNQ-Z2 class) — used by ablation
    /// benches to show the flow retargets.
    pub fn zynq_7020() -> Self {
        DeviceModel {
            name: "Zynq-7020".to_string(),
            luts: 53_200,
            ffs: 106_400,
            bram36: 140,
            dsp: 220,
            clock_mhz: 100.0,
        }
    }

    pub fn lut_pct(&self, luts: u64) -> f64 {
        100.0 * luts as f64 / self.luts as f64
    }

    pub fn bram_pct(&self, bram36: f64) -> f64 {
        100.0 * bram36 / self.bram36 as f64
    }

    pub fn ff_pct(&self, ffs: u64) -> f64 {
        100.0 * ffs as f64 / self.ffs as f64
    }

    pub fn dsp_pct(&self, dsp: u64) -> f64 {
        100.0 * dsp as f64 / self.dsp as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv260_percentages() {
        let d = DeviceModel::kria_kv260();
        assert!((d.lut_pct(14_054) - 12.0).abs() < 0.1);
        assert!((d.bram_pct(26.0) - 18.05).abs() < 0.1);
    }
}
