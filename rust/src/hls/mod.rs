//! HLS estimator: the Vitis-HLS substitution (DESIGN.md §2).
//!
//! Vitis HLS turns the C++ actor templates into RTL and reports resources
//! (LUT/FF/BRAM/DSP), initiation intervals and latency. We reproduce the
//! *behaviour that matters for the paper's evaluation*:
//!
//! * operations are scheduled by data dependencies; the streaming II is set
//!   by folding (PE/SIMD), **not** by operand bit-width — hence Table 1's
//!   constant latency across precisions;
//! * wider operators bind to more logic: LUT cost of a MAC grows with the
//!   weight/activation bit-widths (LUT-mapped multipliers below the DSP
//!   threshold, DSP48E2 above);
//! * memories bind to BRAM18/BRAM36 granules, partitioned across PE lanes —
//!   which is why the paper's BRAM column barely moves with precision.
//!
//! Cost coefficients are calibrated against the paper's Table 1 (KRIA
//! KV260 / XCK26 device, Vitis HLS 2022-era) — see `calib` for every
//! constant and the fit.

mod calib;
mod device;
mod estimate;
mod report;

pub use calib::Calibration;
pub use device::DeviceModel;
pub use estimate::{estimate_engine, ActorEstimate, EngineEstimate};
pub use report::UtilizationReport;
