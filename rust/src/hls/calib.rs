//! Calibration constants for the HLS cost model.
//!
//! Fitted against the paper's Table 1 (five mixed-precision engines on the
//! KRIA KV260). The fit procedure (documented in EXPERIMENTS.md):
//! with the default folding (432 MAC units: conv1 8x2, conv2 8x36, dense
//! 2x64) the paper's LUT column constrains
//!
//! ```text
//! luts/MAC = K_MUL_W * w_bits + K_MUL_A * a_bits + K_MUL_BASE
//! ```
//!
//! with the W-coefficient dominating (paper: W8->W4 halves LUTs, A16->A8
//! moves them by ~1%). The defaults (Kw=2.55, Ka=0.26) plus the per-actor
//! accumulator/requant/control terms and FINN-style per-PE BRAM binding
//! land the five Table-1 engines at 13/9/11/7/7 %LUT vs the paper's
//! 12/7/11/6/6 and the A8-W8 power at the paper's 142 mW (see
//! EXPERIMENTS.md for the full comparison). The weight bit-width dominating
//! LUT cost is the expected Vitis behaviour for LUT-mapped partial-product
//! multipliers.

/// Tunable cost coefficients (public so ablation benches can sweep them).
#[derive(Debug, Clone, PartialEq)]
pub struct Calibration {
    /// LUTs per MAC unit per weight bit.
    pub k_mul_w: f64,
    /// LUTs per MAC unit per activation bit.
    pub k_mul_a: f64,
    /// LUTs per MAC unit, bit-independent part.
    pub k_mul_base: f64,
    /// LUTs per accumulator bit (adder tree / accumulation register).
    pub k_acc_bit: f64,
    /// Fixed LUT overhead per actor (FSM, stream handshake).
    pub k_actor_ctrl: f64,
    /// LUTs per requant unit (mult+shift+clamp) per PE lane.
    pub k_requant: f64,
    /// FFs per LUT (pipeline registers track logic roughly 2:1 on UltraScale+).
    pub k_ff_per_lut: f64,
    /// Operand width product above which a multiplier binds to a DSP48E2
    /// instead of LUTs (Vitis threshold heuristic: both operands > 10 bits).
    pub dsp_threshold_bits: u32,
    /// BRAM18 capacity in bits.
    pub bram18_bits: u64,
    /// Static power of the engine's clock/region (mW).
    pub p_static_mw: f64,
    /// Static leakage per % LUT used (mW).
    pub p_leak_per_lut_pct: f64,
    /// Dynamic energy per FIFO toggle-bit (pJ) — fitted so the A8-W8 engine
    /// lands near the paper's 142 mW at 100 MHz.
    pub e_toggle_pj: f64,
    /// Dynamic energy per executed MAC, per (a_bits+w_bits) operand bit (pJ).
    pub e_mac_bit_pj: f64,
    /// Dynamic energy per BRAM18 access (pJ).
    pub e_bram_pj: f64,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration {
            k_mul_w: 2.55,
            k_mul_a: 0.26,
            k_mul_base: 0.1,
            k_acc_bit: 0.55,
            k_actor_ctrl: 180.0,
            k_requant: 40.0,
            k_ff_per_lut: 1.9,
            dsp_threshold_bits: 10,
            bram18_bits: 18 * 1024,
            p_static_mw: 92.0,
            p_leak_per_lut_pct: 0.55,
            e_toggle_pj: 3.3,
            e_mac_bit_pj: 0.062,
            e_bram_pj: 6.6,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_lut_fit_reproduces_paper_column() {
        // The linear fit must reproduce the paper's LUT% ordering and
        // approximate values for the five profiles under default folding.
        let c = Calibration::default();
        let per_mac = |a: f64, w: f64| c.k_mul_w * w + c.k_mul_a * a + c.k_mul_base;
        let a16w8 = per_mac(16.0, 8.0);
        let a8w8 = per_mac(8.0, 8.0);
        let a16w4 = per_mac(16.0, 4.0);
        let a8w4 = per_mac(8.0, 4.0);
        let a4w4 = per_mac(4.0, 4.0);
        assert!(a16w8 > a8w8 && a8w8 > a16w4 && a16w4 > a8w4 && a8w4 > a4w4);
        // weight bits dominate (paper: LUT roughly halves from W8 to W4 at
        // fixed A; near-flat in A at fixed W)
        assert!(a16w8 / a16w4 > 1.5 && a16w8 / a16w4 < 2.2);
        assert!(a16w8 / a8w8 < 1.15);
    }
}
