//! Minimal-but-complete JSON substrate (std-only).
//!
//! The QONNX interchange (`artifacts/*.qonnx.json`), evaluation records,
//! test vectors, and all report outputs flow through this module. Offline
//! builds in this environment cannot pull `serde`/`serde_json`, so the
//! parser/serializer is in-house (DESIGN.md §3). It supports the full JSON
//! grammar: nested containers, all escapes, scientific-notation numbers,
//! unicode escapes (including surrogate pairs).

mod parser;
mod value;
mod writer;

pub use parser::{parse, ParseError};
pub use value::Value;
pub use writer::{to_string, to_string_pretty};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": true, "d": null}, "e": "hi\n"}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&to_string(&v)).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn numbers() {
        assert_eq!(parse("42").unwrap().as_i64(), Some(42));
        assert_eq!(parse("-17").unwrap().as_i64(), Some(-17));
        assert_eq!(parse("2.5").unwrap().as_f64(), Some(2.5));
        assert_eq!(parse("1e3").unwrap().as_f64(), Some(1000.0));
        assert_eq!(parse("-1.5e-2").unwrap().as_f64(), Some(-0.015));
        // i64 range boundaries stay integral
        assert_eq!(parse("9223372036854775807").unwrap().as_i64(), Some(i64::MAX));
    }

    #[test]
    fn strings_and_escapes() {
        let v = parse(r#""A\t\\\"é""#).unwrap();
        assert_eq!(v.as_str(), Some("A\t\\\"é"));
        // surrogate pair (U+1F600)
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("tru").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
        assert!(parse("nan").is_err());
    }

    #[test]
    fn deep_nesting_ok() {
        let mut s = String::new();
        for _ in 0..200 {
            s.push('[');
        }
        for _ in 0..200 {
            s.push(']');
        }
        assert!(parse(&s).is_ok());
    }

    #[test]
    fn pretty_round_trip() {
        let v = parse(r#"{"rows": [[1,2],[3,4]], "name": "t"}"#).unwrap();
        let v2 = parse(&to_string_pretty(&v)).unwrap();
        assert_eq!(v, v2);
    }
}
