//! JSON serializer (compact + pretty). Floats use shortest round-trip
//! formatting via Rust's `{}`/`{:?}` (which is exact for f64).

use super::Value;

/// Compact serialization.
pub fn to_string(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, None, 0);
    out
}

/// Two-space-indented serialization (for human-facing reports).
pub fn to_string_pretty(v: &Value) -> String {
    let mut out = String::new();
    write_value(v, &mut out, Some(2), 0);
    out
}

fn write_value(v: &Value, out: &mut String, indent: Option<usize>, level: usize) {
    match v {
        Value::Null => out.push_str("null"),
        Value::Bool(true) => out.push_str("true"),
        Value::Bool(false) => out.push_str("false"),
        Value::Int(i) => out.push_str(&i.to_string()),
        Value::Float(f) => write_f64(*f, out),
        Value::Str(s) => write_string(s, out),
        Value::Array(items) => {
            if items.is_empty() {
                out.push_str("[]");
                return;
            }
            out.push('[');
            for (i, item) in items.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, level + 1);
                write_value(item, out, indent, level + 1);
            }
            newline_indent(out, indent, level);
            out.push(']');
        }
        Value::Object(map) => {
            if map.is_empty() {
                out.push_str("{}");
                return;
            }
            out.push('{');
            for (i, (k, val)) in map.iter().enumerate() {
                if i > 0 {
                    out.push(',');
                }
                newline_indent(out, indent, level + 1);
                write_string(k, out);
                out.push(':');
                if indent.is_some() {
                    out.push(' ');
                }
                write_value(val, out, indent, level + 1);
            }
            newline_indent(out, indent, level);
            out.push('}');
        }
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, level: usize) {
    if let Some(n) = indent {
        out.push('\n');
        for _ in 0..n * level {
            out.push(' ');
        }
    }
}

fn write_f64(f: f64, out: &mut String) {
    if f.is_finite() {
        let s = format!("{f:?}"); // shortest round-trip repr
        out.push_str(&s);
        // `{:?}` may print "1.0" (fine) but never bare "1" for floats.
    } else {
        // JSON has no Inf/NaN; emit null like most tolerant writers.
        out.push_str("null");
    }
}

fn write_string(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{0008}' => out.push_str("\\b"),
            '\u{000C}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

#[cfg(test)]
mod tests {
    use super::super::parse;
    use super::*;
    use crate::json::Value;

    #[test]
    fn float_round_trip_exact() {
        for &f in &[0.1, 1.0 / 3.0, 1e-300, 2f64.powi(-24), 329e-6] {
            let v = Value::Float(f);
            let back = parse(&to_string(&v)).unwrap();
            assert_eq!(back.as_f64(), Some(f));
        }
    }

    #[test]
    fn escapes_control_chars() {
        let v = Value::Str("\u{0001}x".to_string());
        assert_eq!(to_string(&v), "\"\\u0001x\"");
        assert_eq!(parse(&to_string(&v)).unwrap(), v);
    }
}
