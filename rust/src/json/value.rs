//! JSON value tree with typed accessors.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Numbers keep integer identity when possible
/// (weight codes must survive the round trip exactly).
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Null,
    Bool(bool),
    /// Integral number that fits i64 (no decimal point / exponent loss).
    Int(i64),
    /// Any other finite number.
    Float(f64),
    Str(String),
    Array(Vec<Value>),
    /// BTreeMap keeps key order deterministic for byte-stable outputs.
    Object(BTreeMap<String, Value>),
}

impl Value {
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            Value::Float(f) if f.fract() == 0.0 && f.abs() < 2f64.powi(53) => {
                Some(*f as i64)
            }
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Int(i) => Some(*i as f64),
            Value::Float(f) => Some(*f),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Value]> {
        match self {
            Value::Array(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&BTreeMap<String, Value>> {
        match self {
            Value::Object(o) => Some(o),
            _ => None,
        }
    }

    /// Object field access: `v.get("nodes")`.
    pub fn get(&self, key: &str) -> Option<&Value> {
        self.as_object().and_then(|o| o.get(key))
    }

    /// Array index access.
    pub fn idx(&self, i: usize) -> Option<&Value> {
        self.as_array().and_then(|a| a.get(i))
    }

    /// Collect an array of integers (errors if any element is not integral).
    pub fn to_i64_vec(&self) -> Option<Vec<i64>> {
        self.as_array()?.iter().map(|v| v.as_i64()).collect()
    }

    /// Collect an array of floats.
    pub fn to_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_array()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn from_i64_slice(xs: &[i64]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Int(x)).collect())
    }

    pub fn from_f64_slice(xs: &[f64]) -> Value {
        Value::Array(xs.iter().map(|&x| Value::Float(x)).collect())
    }

    /// Build an object from (key, value) pairs — the ergonomic constructor
    /// used by report writers.
    pub fn obj(pairs: Vec<(&str, Value)>) -> Value {
        Value::Object(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", super::to_string(self))
    }
}

impl From<&str> for Value {
    fn from(s: &str) -> Self {
        Value::Str(s.to_string())
    }
}

impl From<String> for Value {
    fn from(s: String) -> Self {
        Value::Str(s)
    }
}

impl From<i64> for Value {
    fn from(i: i64) -> Self {
        Value::Int(i)
    }
}

impl From<usize> for Value {
    fn from(i: usize) -> Self {
        Value::Int(i as i64)
    }
}

impl From<f64> for Value {
    fn from(f: f64) -> Self {
        Value::Float(f)
    }
}

impl From<bool> for Value {
    fn from(b: bool) -> Self {
        Value::Bool(b)
    }
}
