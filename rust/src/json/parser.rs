//! Recursive-descent JSON parser (iterative for containers, so deep inputs
//! do not overflow the stack beyond a configured depth guard).

use std::collections::BTreeMap;
use std::fmt;

use super::Value;

/// Parse failure with byte offset and a short message.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

const MAX_DEPTH: usize = 512;

/// Parse a complete JSON document (trailing whitespace allowed, nothing else).
pub fn parse(src: &str) -> Result<Value, ParseError> {
    let mut p = Parser {
        bytes: src.as_bytes(),
        pos: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            message: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Value, ParseError> {
        if self.depth >= MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        match self.peek() {
            Some(b'{') => {
                self.depth += 1;
                let v = self.object();
                self.depth -= 1;
                v
            }
            Some(b'[') => {
                self.depth += 1;
                let v = self.array();
                self.depth -= 1;
                v
            }
            Some(b'"') => Ok(Value::Str(self.string()?)),
            Some(b't') => self.literal(b"true", Value::Bool(true)),
            Some(b'f') => self.literal(b"false", Value::Bool(false)),
            Some(b'n') => self.literal(b"null", Value::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn literal(&mut self, lit: &[u8], v: Value) -> Result<Value, ParseError> {
        if self.bytes[self.pos..].starts_with(lit) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn object(&mut self) -> Result<Value, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Value::Object(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Value::Object(map)),
                _ => return Err(self.err("expected ',' or '}' in object")),
            }
        }
    }

    fn array(&mut self) -> Result<Value, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Value::Array(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Value::Array(items)),
                _ => return Err(self.err("expected ',' or ']' in array")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{0008}'),
                    Some(b'f') => out.push('\u{000C}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let hi = self.hex4()?;
                        let ch = if (0xD800..0xDC00).contains(&hi) {
                            // surrogate pair
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("unpaired surrogate"));
                            }
                            let lo = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&lo) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                            char::from_u32(c).ok_or_else(|| self.err("bad codepoint"))?
                        } else if (0xDC00..0xE000).contains(&hi) {
                            return Err(self.err("unexpected low surrogate"));
                        } else {
                            char::from_u32(hi).ok_or_else(|| self.err("bad codepoint"))?
                        };
                        out.push(ch);
                    }
                    _ => return Err(self.err("invalid escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("control char in string")),
                Some(b) => {
                    // Re-assemble UTF-8 multibyte sequences from raw bytes.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let len = utf8_len(b).ok_or_else(|| self.err("bad utf-8"))?;
                        let start = self.pos - 1;
                        let end = start + len;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("bad utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("eof in \\u escape"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex digit"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Value, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // integer part
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
            }
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("invalid number")),
        }
        let mut is_float = false;
        if self.peek() == Some(b'.') {
            is_float = true;
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit expected after '.'"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            is_float = true;
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("digit expected in exponent"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        if !is_float {
            if let Ok(i) = text.parse::<i64>() {
                return Ok(Value::Int(i));
            }
            // overflow -> fall through to float
        }
        text.parse::<f64>()
            .map(Value::Float)
            .map_err(|_| self.err("number out of range"))
    }
}

fn utf8_len(first: u8) -> Option<usize> {
    match first {
        0xC2..=0xDF => Some(2),
        0xE0..=0xEF => Some(3),
        0xF0..=0xF4 => Some(4),
        _ => None,
    }
}
