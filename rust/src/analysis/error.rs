//! Affine-arithmetic error-bound analysis: proven deviation intervals
//! between a base model and a bit-sliced variant.
//!
//! The interval engine ([`super::analyze`]) bounds what *one* model can
//! compute. This second layer bounds how far a knob-vector variant
//! ([`crate::approx::derive_model`]) can drift from its reference, for
//! *every* input in the analyzed domain, by propagating per-channel
//! deviation terms through the pipeline:
//!
//! * **Alignment.** A variant activation code `y'` emitted after an
//!   activation drop of `j` bits represents the base-scale value `y' * 2^j`;
//!   a variant accumulator after weight drop `k` on a `j_in`-coarse input
//!   stream represents `acc' * 2^(k + j_in)`. All deviations are tracked in
//!   these aligned base-code units, so "zero deviation" means "bit-identical
//!   after rescaling".
//! * **Conv/dense transfer.** With `ew = w' * 2^k - w` (the exact integer
//!   rounding error of each weight code) and `eb` the bias analogue, the
//!   aligned accumulator deviation is `e = eb + sum_taps(ew * x_aligned' +
//!   w * d_in)` where `x_aligned'` is the variant input interval (from the
//!   interval engine) and `d_in` the propagated input deviation. Conv taps
//!   are widened with 0 (SAME padding feeds zeros to both models).
//! * **Requant transfer.** The slicer's `(mult, shift)` rebase is exact in
//!   the reals, so the pre-clamp deviation is `e * mult / 2^shift` plus
//!   rounding slack: zero extra slack when the output scale is unchanged and
//!   both sides round the same way (the floor-shift lemma makes the bound
//!   `[floor(e_lo*m/2^s), ceil(e_hi*m/2^s)]` exact — identity and
//!   even-code drops prove `[0, 0]`), else `T + 1` codes of slack where
//!   `T = 2^j`. Clamping widens by the difference of the aligned clamp
//!   ceilings.
//! * **Certificate.** From the per-class logit deviation intervals `E_c`,
//!   `stable_margin = max(0, max_{c != d}(E_d.hi - E_c.lo))`: on any input
//!   where the base winner leads every other logit by *more* than this
//!   margin, the variant's argmax provably equals the base's. A zero margin
//!   forces every `E_c` to one shared point, i.e. the variant's logits are a
//!   uniform shift of the base's on **all** inputs — argmax (including the
//!   lowest-index tie-break) can never differ, so the variant's accuracy
//!   equals the reference's exactly ([`ErrorReport::certified_exact`]).
//!
//! Soundness is property-tested: every element-wise deviation the scalar
//! oracle observes lies inside the proven interval, and a certified-exact
//! variant never flips a top-1 empirically. The explorer uses the
//! certificate to skip accuracy evaluations and the logit bound to discard
//! over-tolerance candidates ([`crate::approx::ExplorerConfig`]); frontier
//! JSON stores the bounds and [`crate::approx::Frontier::from_json`]
//! re-proves them on load.

use crate::qonnx::{ConvLayer, DenseLayer, Layer, QonnxModel};

use super::interval::{saturate, Interval};

/// Proven deviation intervals of one layer, aligned with `model.layers`.
#[derive(Debug, Clone)]
pub struct LayerDeviation {
    pub name: String,
    /// Aligned pre-requant accumulator deviation per output channel
    /// (`acc' * 2^acc_scale_log2 - acc`); empty for pool/flatten.
    pub acc_dev: Vec<Interval>,
    /// Aligned output activation deviation per channel
    /// (`y' * 2^act_scale_log2 - y`).
    pub act_dev: Vec<Interval>,
    /// `log2` of the accumulator alignment factor (`k + j_in`).
    pub acc_scale_log2: u32,
    /// `log2` of the activation alignment factor (the stream's cumulative
    /// activation drop `j`).
    pub act_scale_log2: u32,
}

/// Result of one [`analyze_error`] pass over a (base, knob vector) pair.
#[derive(Debug, Clone)]
pub struct ErrorReport {
    pub layers: Vec<LayerDeviation>,
    /// Aligned logit deviation interval per class (empty without a dense
    /// head).
    pub logit_dev: Vec<Interval>,
    /// Largest proven absolute logit deviation across all classes — the
    /// end-to-end worst-case error in base logit units.
    pub logit_bound: i64,
    /// Proven logit margin under which the top-1 cannot flip: any input
    /// where the base winner leads every other logit by more than this is
    /// classified identically by the variant.
    pub stable_margin: i64,
    /// The bounds prove the variant's argmax equals the base's on every
    /// input (zero margin — all logit deviations are one shared constant),
    /// so its accuracy is exactly the reference's.
    pub certified_exact: bool,
    /// Narrow-accumulator verdict per conv layer of the *variant* (the
    /// interval engine's [`super::Analysis::conv_narrow`]) — carried here so
    /// callers that already pay for the variant analysis need not rerun it.
    pub conv_narrow: Vec<bool>,
}

/// Wide working interval: exact `i128` endpoints, saturated into
/// [`Interval`] only for reporting (mirrors the interval engine's policy).
#[derive(Debug, Clone, Copy)]
struct Iv {
    lo: i128,
    hi: i128,
}

impl Iv {
    const ZERO: Iv = Iv { lo: 0, hi: 0 };

    fn point(v: i128) -> Iv {
        Iv { lo: v, hi: v }
    }

    fn add(self, o: Iv) -> Iv {
        Iv {
            lo: self.lo + o.lo,
            hi: self.hi + o.hi,
        }
    }

    /// Multiply by a scalar (endpoints swap under a negative factor).
    fn scale(self, f: i128) -> Iv {
        let (a, b) = (self.lo * f, self.hi * f);
        Iv {
            lo: a.min(b),
            hi: a.max(b),
        }
    }

    /// Widen with 0 (conv taps: SAME padding feeds zeros to both models).
    fn union0(self) -> Iv {
        Iv {
            lo: self.lo.min(0),
            hi: self.hi.max(0),
        }
    }

    fn to_interval(self) -> Interval {
        Interval::new(saturate(self.lo), saturate(self.hi))
    }
}

fn floor_div(a: i128, b: i128) -> i128 {
    debug_assert!(b > 0);
    let q = a / b;
    if a % b != 0 && a < 0 {
        q - 1
    } else {
        q
    }
}

fn ceil_div(a: i128, b: i128) -> i128 {
    -floor_div(-a, b)
}

/// Per-layer (k, j, j_in) drops plus both layers' parameters — the aligned
/// pair the transfer functions consume.
struct ConvPair<'a> {
    base: &'a ConvLayer,
    var: &'a ConvLayer,
    k: u32,
    j: u32,
    j_in: u32,
}

/// Aligned accumulator deviation of one conv/dense-style layer.
///
/// `w_at(tap, co)` / `vw_at` index base and variant weight codes, `taps` is
/// the contraction length, `widen_taps` enables the conv-only 0-union, and
/// `input` carries per-input-channel (aligned variant interval, deviation
/// interval) pairs; tap `t` reads input channel `t % input.len()`.
#[allow(clippy::too_many_arguments)]
fn acc_deviation(
    cout: usize,
    taps: usize,
    input: &[(Iv, Iv)],
    w_at: impl Fn(usize, usize) -> i128,
    vw_at: impl Fn(usize, usize) -> i128,
    base_bias: &[i64],
    var_bias: &[i64],
    k: u32,
    j_in: u32,
    widen_taps: bool,
) -> Vec<Iv> {
    let s = 1i128 << (k + j_in);
    let wk = 1i128 << k;
    let nch = input.len();
    let mut out = Vec::with_capacity(cout);
    for co in 0..cout {
        let eb = var_bias[co] as i128 * s - base_bias[co] as i128;
        let mut e = Iv::point(eb);
        for t in 0..taps {
            let w = w_at(t, co);
            let ew = vw_at(t, co) * wk - w;
            let (xv, dx) = input[t % nch];
            let mut term = xv.scale(ew).add(dx.scale(w));
            if widen_taps {
                term = term.union0();
            }
            e = e.add(term);
        }
        out.push(e);
    }
    out
}

/// Aligned post-requant deviation of one conv output channel.
///
/// `e` is the aligned accumulator deviation; `(m, s)` / `(vm, vs)` are the
/// base and variant requant pairs (the slicer's rebase makes their real
/// ratio exact); `act_bits` is the *base* activation width and `j` the
/// layer's activation drop. Falls back to the full aligned clamp range for
/// non-monotone or out-of-range requants (the interval engine flags those
/// separately).
fn requant_deviation(e: Iv, m: i64, s: i64, vm: i64, vs: i64, act_bits: u32, j: u32) -> Iv {
    let t = 1i128 << j;
    let qb: i128 = if act_bits >= 63 {
        i64::MAX as i128
    } else {
        (1i128 << act_bits) - 1
    };
    // Aligned variant clamp ceiling: (2^(act_bits - j) - 1) * 2^j.
    let qv_t: i128 = if act_bits >= 63 {
        i64::MAX as i128
    } else {
        (1i128 << act_bits) - (1i128 << j)
    };
    let full = Iv { lo: -qb, hi: qv_t };
    if m < 0 || vm < 0 || !(0..=62).contains(&s) || !(0..=62).contains(&vs) || act_bits < j {
        return full;
    }
    let div = 1i128 << s;
    let fdiv = floor_div(e.lo * m as i128, div);
    let cdiv = ceil_div(e.hi * m as i128, div);
    // Same output scale and same rounding mode on both sides: the rebase is
    // exact in the reals and both floors see the same fractional offset, so
    // the floor-shift lemma gives the bound with no extra slack (exact
    // [0, 0] for identity and even-code weight drops). Otherwise pay T + 1
    // codes of coarser-grid + rounding slack.
    let (dlo, dhi) = if t == 1 && (s > 0) == (vs > 0) {
        (fdiv, cdiv)
    } else {
        (fdiv - (t + 1), cdiv + (t + 1))
    };
    // Clamping is monotone and 1-Lipschitz; differing ceilings widen by
    // their gap, and the result can never leave the aligned clamp ranges.
    let lo = (dlo.min(0) - (qb - qv_t).max(0)).max(full.lo);
    let hi = (dhi.max(0) + (qv_t - qb).max(0)).min(full.hi);
    Iv { lo, hi }
}

/// Propagate deviation bounds between `base` and its `config`-derived
/// variant. `config` must be range-legal for `base` (the same contract as
/// [`crate::approx::derive_model`], which this calls); semantic illegality
/// (e.g. a const-output variant) is fine — the bounds stay sound.
pub fn analyze_error(base: &QonnxModel, config: &[u32]) -> ErrorReport {
    let variant = crate::approx::derive_model(base, config, "error-bound");
    let drops = crate::approx::layer_drops(base, config);
    let var_an = super::analyze(&variant);

    // Per input channel of the current layer: (aligned variant activation
    // interval, aligned deviation interval). Input codes are shared
    // verbatim by both models: deviation 0, scale 1.
    let in_max = ((1i64 << base.input_bits.min(8)) - 1).min(255) as i128;
    let mut stream: Vec<(Iv, Iv)> =
        vec![(Iv { lo: 0, hi: in_max }, Iv::ZERO); base.input_shape.c];
    let mut cur_j = 0u32;

    let mut layers = Vec::with_capacity(base.layers.len());
    let mut logit_dev: Vec<Interval> = Vec::new();
    for (i, (layer, vlayer)) in base.layers.iter().zip(&variant.layers).enumerate() {
        match (layer, vlayer) {
            (Layer::Conv(c), Layer::Conv(vc)) => {
                let d = drops[i].expect("conv layers carry drops");
                let pair = ConvPair {
                    base: c,
                    var: vc,
                    k: d.k,
                    j: d.j,
                    j_in: d.j_in,
                };
                let acc = acc_deviation(
                    c.cout,
                    9 * c.cin,
                    &stream,
                    |t, co| pair.base.w_codes[t * c.cout + co] as i128,
                    |t, co| pair.var.w_codes[t * c.cout + co] as i128,
                    &c.b_codes,
                    &vc.b_codes,
                    pair.k,
                    pair.j_in,
                    true,
                );
                let act: Vec<Iv> = acc
                    .iter()
                    .enumerate()
                    .map(|(co, &e)| {
                        requant_deviation(
                            e,
                            c.mult[co],
                            c.shift[co],
                            vc.mult[co],
                            vc.shift[co],
                            c.act_bits,
                            pair.j,
                        )
                    })
                    .collect();
                layers.push(LayerDeviation {
                    name: c.name.clone(),
                    acc_dev: acc.iter().map(|e| e.to_interval()).collect(),
                    act_dev: act.iter().map(|e| e.to_interval()).collect(),
                    acc_scale_log2: pair.k + pair.j_in,
                    act_scale_log2: pair.j,
                });
                // Next layer's input: the variant's proven activation
                // intervals (aligned) and the post-requant deviations.
                let var_acts = &var_an.facts[i].act;
                let tj = 1i128 << pair.j;
                stream = var_acts
                    .iter()
                    .zip(&act)
                    .map(|(iv, &dv)| {
                        (
                            Iv {
                                lo: iv.lo as i128 * tj,
                                hi: iv.hi as i128 * tj,
                            },
                            dv,
                        )
                    })
                    .collect();
                cur_j = pair.j;
            }
            (Layer::Dense(dn), Layer::Dense(vd)) => {
                let d = drops[i].expect("dense layers carry drops");
                let acc = dense_deviation(dn, vd, &stream, d.k, d.j_in);
                let saturated: Vec<Interval> = acc.iter().map(|e| e.to_interval()).collect();
                logit_dev = saturated.clone();
                layers.push(LayerDeviation {
                    name: dn.name.clone(),
                    acc_dev: saturated.clone(),
                    act_dev: saturated.clone(),
                    acc_scale_log2: d.k + d.j_in,
                    act_scale_log2: d.k + d.j_in,
                });
                // Dense output feeds nothing in the supported pipelines;
                // keep the raw deviations flowing for robustness.
                stream = acc
                    .iter()
                    .map(|&e| {
                        (
                            Iv {
                                lo: i64::MIN as i128,
                                hi: i64::MAX as i128,
                            },
                            e,
                        )
                    })
                    .collect();
            }
            // Max-pool is channel-wise, monotone, and commutes with the
            // positive alignment scaling; per-channel deviation intervals
            // pass through unchanged. Flatten only reinterprets layout.
            (Layer::Pool(p), _) => {
                layers.push(LayerDeviation {
                    name: p.name.clone(),
                    acc_dev: Vec::new(),
                    act_dev: stream.iter().map(|&(_, d)| d.to_interval()).collect(),
                    acc_scale_log2: 0,
                    act_scale_log2: cur_j,
                });
            }
            (Layer::Flatten { name }, _) => {
                layers.push(LayerDeviation {
                    name: name.clone(),
                    acc_dev: Vec::new(),
                    act_dev: stream.iter().map(|&(_, d)| d.to_interval()).collect(),
                    acc_scale_log2: 0,
                    act_scale_log2: cur_j,
                });
            }
            _ => unreachable!("derive_model preserves layer kinds"),
        }
    }

    let logit_bound = logit_dev
        .iter()
        .map(|e| e.lo.unsigned_abs().max(e.hi.unsigned_abs()))
        .max()
        .unwrap_or(0)
        .min(i64::MAX as u64) as i64;
    let mut margin: i64 = 0;
    for (c, ec) in logit_dev.iter().enumerate() {
        for (d, ed) in logit_dev.iter().enumerate() {
            if c != d {
                margin = margin.max(ed.hi.saturating_sub(ec.lo));
            }
        }
    }
    ErrorReport {
        layers,
        logit_dev,
        logit_bound,
        stable_margin: margin,
        certified_exact: margin == 0,
        conv_narrow: var_an.conv_narrow,
    }
}

/// Dense head deviation: feature `f` reads input channel `f % stream.len()`
/// (HWC flattening, as in the interval engine); no 0-widening — dense sees
/// no padding.
fn dense_deviation(
    base: &DenseLayer,
    var: &DenseLayer,
    stream: &[(Iv, Iv)],
    k: u32,
    j_in: u32,
) -> Vec<Iv> {
    let kt = base.out_features;
    acc_deviation(
        kt,
        base.in_features,
        stream,
        |f, c| base.w_codes[f * kt + c] as i128,
        |f, c| var.w_codes[f * kt + c] as i128,
        &base.b_codes,
        &var.b_codes,
        k,
        j_in,
        false,
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::{bound_stress_model_json, read_str, test_model_json};

    fn tiny() -> QonnxModel {
        read_str(&test_model_json(2, 3)).unwrap()
    }

    fn stress() -> QonnxModel {
        read_str(&bound_stress_model_json()).unwrap()
    }

    fn all_zero(r: &ErrorReport) -> bool {
        r.layers.iter().all(|l| {
            l.acc_dev.iter().chain(&l.act_dev).all(|iv| iv.lo == 0 && iv.hi == 0)
        })
    }

    #[test]
    fn identity_config_proves_zero_deviation_everywhere() {
        let m = tiny();
        let zeros = vec![0u32; crate::approx::knobs_for(&m).len()];
        let r = analyze_error(&m, &zeros);
        assert!(all_zero(&r), "identity must prove [0, 0]: {:?}", r.layers);
        assert_eq!(r.logit_bound, 0);
        assert_eq!(r.stable_margin, 0);
        assert!(r.certified_exact);
        assert_eq!(r.layers.len(), m.layers.len());
        assert_eq!(r.logit_dev.len(), 3);
    }

    #[test]
    fn even_code_weight_drops_are_certified_exact() {
        // The stress model's conv codes are multiples of 4 with zero biases:
        // one- and two-bit weight drops rescale exactly (ew = 0, same real
        // requant ratio), so the variant is provably bit-identical.
        let m = stress();
        for k in [1u32, 2] {
            let r = analyze_error(&m, &[k, 0, 0]);
            assert!(all_zero(&r), "k = {k} must be exact: {:?}", r.layers);
            assert!(r.certified_exact, "k = {k} must be certified");
            assert_eq!(r.logit_bound, 0);
        }
        // Three bits round 4 -> 1 (ew = 4): no longer exact.
        let r = analyze_error(&m, &[3, 0, 0]);
        assert!(!r.certified_exact);
        assert!(r.logit_bound > 0);
    }

    #[test]
    fn activation_drops_carry_requant_slack() {
        // j = 1 leaves the weights untouched but pays coarser-grid slack at
        // the requant, which nonzero dense weights propagate to the logits.
        let m = stress();
        let r = analyze_error(&m, &[0, 1, 0]);
        assert!(!r.certified_exact);
        assert!(r.logit_bound > 0, "requant slack must reach the logits");
        assert!(r.stable_margin > 0);
        let conv = &r.layers[0];
        assert_eq!(conv.act_scale_log2, 1);
        assert!(
            conv.acc_dev.iter().all(|iv| iv.lo == 0 && iv.hi == 0),
            "accumulators are untouched by a pure act drop"
        );
        assert!(conv.act_dev.iter().any(|iv| iv.lo < 0 || iv.hi > 0));
    }

    #[test]
    fn stability_margin_bounds_the_pairwise_deviation_spread() {
        // margin = max over class pairs of E_d.hi - E_c.lo; a dense weight
        // drop on the tiny model produces asymmetric per-class deviations.
        let m = tiny();
        let r = analyze_error(&m, &[0, 0, 1]);
        let mut want: i64 = 0;
        for (c, ec) in r.logit_dev.iter().enumerate() {
            for (d, ed) in r.logit_dev.iter().enumerate() {
                if c != d {
                    want = want.max(ed.hi - ec.lo);
                }
            }
        }
        assert_eq!(r.stable_margin, want.max(0));
        assert!(!r.certified_exact);
        let bound = r
            .logit_dev
            .iter()
            .map(|e| e.lo.abs().max(e.hi.abs()))
            .max()
            .unwrap();
        assert_eq!(r.logit_bound, bound);
    }

    #[test]
    fn floor_and_ceil_division_round_toward_the_right_infinity() {
        assert_eq!(floor_div(7, 2), 3);
        assert_eq!(floor_div(-7, 2), -4);
        assert_eq!(floor_div(-8, 2), -4);
        assert_eq!(ceil_div(7, 2), 4);
        assert_eq!(ceil_div(-7, 2), -3);
        assert_eq!(ceil_div(8, 2), 4);
    }

    #[test]
    fn requant_deviation_is_exact_for_zero_error_same_scale() {
        // T == 1, matching rounding modes, e = [0, 0]: no slack at all.
        let d = requant_deviation(Iv::ZERO, 16384, 15, 16384, 14, 8, 0);
        assert_eq!((d.lo, d.hi), (0, 0));
        // An activation drop always pays coarser-grid slack.
        let d = requant_deviation(Iv::ZERO, 16384, 15, 16384, 16, 8, 1);
        assert!(d.lo < 0 && d.hi > 0);
        // Negative multipliers fall back to the full aligned clamp range.
        let d = requant_deviation(Iv::ZERO, -3, 15, -3, 15, 8, 0);
        assert_eq!((d.lo, d.hi), (-255, 255));
    }
}
