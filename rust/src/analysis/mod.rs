//! Static IR verifier: abstract interpretation over the QONNX pipeline.
//!
//! One pass propagates per-channel integer value intervals through the
//! model (requant clamps are the transfer functions) and proves, per layer:
//!
//! * **Accumulator width** — whether every conv product and partial sum
//!   provably fits `i32` (the packed engine's narrow MAC path) and whether
//!   the worst case fits `i64` at all ([`RULE_ACC_OVERFLOW`]).
//! * **Requant legality** — `(mult, shift)` products applied to the
//!   worst-case accumulator stay inside `i64` ([`RULE_REQUANT_OVERFLOW`]).
//! * **Output liveness** — a classifier whose logits are all statically
//!   constant can never depend on its input ([`RULE_CONST_OUTPUT`]); this
//!   is how over-aggressive bit drops that zero a whole tensor surface.
//! * **Arena sizing** — exact ping/pong high-water marks ([`ArenaPlan`]).
//!
//! The pass is the single source of truth for its consumers: the packed
//! kernels take their narrow/wide accumulator choice from it, the scratch
//! planners take the arena sizes, the approximation explorer statically
//! rejects illegal knob vectors before paying for an evaluation
//! ([`check_config`]), frontier loading validates untrusted configs through
//! it, and `onnx2hw check` surfaces it on the command line.
//!
//! Soundness contract (property-tested against the scalar oracle): for any
//! input image, every activation and accumulator the executor observes lies
//! inside the analysis interval of its channel, and a layer proven narrow
//! never sees `|acc| > i32::MAX`.

mod arena;
mod error;
mod interval;

use std::fmt;

use crate::qonnx::{Layer, QonnxModel};

pub use arena::ArenaPlan;
pub use error::{analyze_error, ErrorReport, LayerDeviation};
pub use interval::Interval;

use interval::{conv_bounds, dense_bounds, requant_interval, saturate};

/// Requant `(mult, shift)` can overflow the executor's `i64` arithmetic, or
/// the shift is outside the supported `[0, 62]` range.
pub const RULE_REQUANT_OVERFLOW: &str = "requant-overflow";
/// A worst-case (partial) accumulator can leave `i64`.
pub const RULE_ACC_OVERFLOW: &str = "acc-overflow";
/// Every logit is statically constant: the classifier cannot depend on its
/// input (typically a bit-drop zeroed an entire weight tensor).
pub const RULE_CONST_OUTPUT: &str = "const-output";
/// A knob vector's length does not match the base model's knob count.
pub const RULE_CONFIG_ARITY: &str = "config-arity";
/// A knob value exceeds the layer's headroom.
pub const RULE_CONFIG_RANGE: &str = "config-range";
/// Conv activation width above 31 bits: the packed engine falls back to the
/// scalar path (legal, but the fast path is lost).
pub const RULE_ACT_WIDTH: &str = "act-width";
/// A dense layer that is not the final layer: unsupported by the packed
/// plan (scalar fallback).
pub const RULE_DENSE_NONTERMINAL: &str = "dense-nonterminal";
/// A frontier point's stored logit-deviation bound is below what the
/// error-bound analyzer proves: the stored certificate is falsified.
pub const RULE_ERROR_BOUND: &str = "error-bound";
/// A frontier point's stored stability margin is below the proven one
/// (claims top-1 stability the bounds cannot back).
pub const RULE_MARGIN_UNSOUND: &str = "margin-unsound";
/// A frontier point's stored per-layer accumulator-width verdicts disagree
/// with the interval engine's proof for the derived variant.
pub const RULE_ACC_NARROW_STALE: &str = "acc-narrow-stale";

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Severity {
    Error,
    Warning,
}

/// One structured finding of the verifier.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diagnostic {
    pub severity: Severity,
    /// Stable rule code (one of the `RULE_*` constants).
    pub rule: &'static str,
    /// Index into `model.layers` when the rule anchors to a layer.
    pub layer: Option<usize>,
    /// Op kind of the offending layer ("conv", "dense", ... — "" for
    /// model-level and knob-level rules), so rendered messages are
    /// actionable without opening the model JSON.
    pub op: &'static str,
    /// Name of the offending layer or knob ("" for model-level rules).
    pub layer_name: String,
    pub message: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let sev = match self.severity {
            Severity::Error => "error",
            Severity::Warning => "warning",
        };
        write!(f, "{sev}[{}]", self.rule)?;
        if let Some(i) = self.layer {
            write!(f, " layer {i}")?;
        }
        match (self.op, self.layer_name.as_str()) {
            ("", "") => {}
            ("", name) => write!(f, " '{name}'")?,
            (op, "") => write!(f, " ({op})")?,
            (op, name) => write!(f, " ({op} '{name}')")?,
        }
        write!(f, ": {}", self.message)
    }
}

/// Per-layer facts proven by [`analyze`], aligned with `model.layers`.
#[derive(Debug, Clone)]
pub struct LayerFacts {
    pub name: String,
    /// Pre-requant accumulator interval per output channel (conv), or raw
    /// logit interval per class (dense); empty for pool/flatten.
    pub acc: Vec<Interval>,
    /// Post-layer activation interval per output channel.
    pub act: Vec<Interval>,
    /// Conv layers only: the `i32` MAC path is provably overflow-free.
    pub narrow: Option<bool>,
}

/// Result of one [`analyze`] pass.
#[derive(Debug, Clone)]
pub struct Analysis {
    pub facts: Vec<LayerFacts>,
    /// Final dense logit intervals (empty if the model has no dense head).
    pub logits: Vec<Interval>,
    /// Narrow-accumulator verdict per conv layer, in layer order.
    pub conv_narrow: Vec<bool>,
    pub arena: ArenaPlan,
    pub diags: Vec<Diagnostic>,
}

impl Analysis {
    pub fn errors(&self) -> impl Iterator<Item = &Diagnostic> {
        self.diags.iter().filter(|d| d.severity == Severity::Error)
    }

    pub fn has_errors(&self) -> bool {
        self.errors().next().is_some()
    }
}

/// Abstract-interpret `model` from the input byte range down to the logits.
pub fn analyze(model: &QonnxModel) -> Analysis {
    let arena = ArenaPlan::of(model);
    let mut diags = Vec::new();
    let mut facts = Vec::with_capacity(model.layers.len());
    let mut conv_narrow = Vec::new();
    let mut logits: Vec<Interval> = Vec::new();
    // Input codes arrive as u8, further clipped by the declared precision.
    let in_max = ((1i64 << model.input_bits.min(8)) - 1).min(255);
    let mut acts = vec![Interval::new(0, in_max); model.input_shape.c];
    let last = model.layers.len().saturating_sub(1);
    for (i, layer) in model.layers.iter().enumerate() {
        match layer {
            Layer::Conv(c) => {
                let b = conv_bounds(c, &acts);
                check_i64_overflow(&mut diags, i, "conv", &c.name, &b.abs_sum);
                if c.act_bits > 31 {
                    diags.push(Diagnostic {
                        severity: Severity::Warning,
                        rule: RULE_ACT_WIDTH,
                        layer: Some(i),
                        op: "conv",
                        layer_name: c.name.clone(),
                        message: format!(
                            "activation width {} > 31 bits: packed engine falls back to scalar",
                            c.act_bits
                        ),
                    });
                }
                let qmax = if c.act_bits >= 63 {
                    i64::MAX
                } else {
                    (1i64 << c.act_bits) - 1
                };
                let mut out = Vec::with_capacity(c.cout);
                for (co, &(lo, hi)) in b.acc.iter().enumerate() {
                    let (mult, shift) = (c.mult[co], c.shift[co]);
                    if !(0..=62).contains(&shift) {
                        let d = Diagnostic {
                            severity: Severity::Error,
                            rule: RULE_REQUANT_OVERFLOW,
                            layer: Some(i),
                            op: "conv",
                            layer_name: c.name.clone(),
                            message: format!(
                                "channel {co}: shift {shift} outside the supported range [0, 62]"
                            ),
                        };
                        push_once(&mut diags, d);
                        out.push(Interval::new(0, qmax));
                        continue;
                    }
                    let half = if shift > 0 { 1i128 << (shift - 1) } else { 0 };
                    for endpoint in [lo, hi] {
                        let product = endpoint * mult as i128 + half;
                        if product < i64::MIN as i128 || product > i64::MAX as i128 {
                            let d = Diagnostic {
                                severity: Severity::Error,
                                rule: RULE_REQUANT_OVERFLOW,
                                layer: Some(i),
                                op: "conv",
                                layer_name: c.name.clone(),
                                message: format!(
                                    "channel {co}: worst-case accumulator {endpoint} * mult {mult} \
                                     overflows i64 during requantization"
                                ),
                            };
                            push_once(&mut diags, d);
                        }
                    }
                    if mult < 0 {
                        // Non-monotone map; fall back to the full clamp range.
                        out.push(Interval::new(0, qmax));
                    } else {
                        out.push(requant_interval(lo, hi, mult, shift, c.act_bits));
                    }
                }
                conv_narrow.push(b.narrow);
                facts.push(LayerFacts {
                    name: c.name.clone(),
                    acc: b
                        .acc
                        .iter()
                        .map(|&(l, h)| Interval::new(saturate(l), saturate(h)))
                        .collect(),
                    act: out.clone(),
                    narrow: Some(b.narrow),
                });
                acts = out;
            }
            Layer::Pool(p) => {
                // Max-pool is channel-wise and monotone: intervals pass through.
                facts.push(LayerFacts {
                    name: p.name.clone(),
                    acc: Vec::new(),
                    act: acts.clone(),
                    narrow: None,
                });
            }
            Layer::Flatten { name } => {
                facts.push(LayerFacts {
                    name: name.clone(),
                    acc: Vec::new(),
                    act: acts.clone(),
                    narrow: None,
                });
            }
            Layer::Dense(d) => {
                if i != last {
                    diags.push(Diagnostic {
                        severity: Severity::Warning,
                        rule: RULE_DENSE_NONTERMINAL,
                        layer: Some(i),
                        op: "dense",
                        layer_name: d.name.clone(),
                        message: "dense layer is not terminal: packed engine falls back to scalar"
                            .to_string(),
                    });
                }
                let b = dense_bounds(d, &acts);
                check_i64_overflow(&mut diags, i, "dense", &d.name, &b.abs_sum);
                let out: Vec<Interval> = b
                    .acc
                    .iter()
                    .map(|&(l, h)| Interval::new(saturate(l), saturate(h)))
                    .collect();
                if i == last && !out.is_empty() && out.iter().all(|iv| iv.is_point()) {
                    diags.push(Diagnostic {
                        severity: Severity::Error,
                        rule: RULE_CONST_OUTPUT,
                        layer: Some(i),
                        op: "dense",
                        layer_name: d.name.clone(),
                        message: "every logit is statically constant: the classifier cannot \
                                  depend on its input"
                            .to_string(),
                    });
                }
                logits = out.clone();
                facts.push(LayerFacts {
                    name: d.name.clone(),
                    acc: out.clone(),
                    act: out.clone(),
                    narrow: None,
                });
                acts = out;
            }
        }
    }
    Analysis {
        facts,
        logits,
        conv_narrow,
        arena,
        diags,
    }
}

/// Emit [`RULE_ACC_OVERFLOW`] if any channel's absolute partial-sum bound
/// can leave `i64` (one diagnostic per layer — the first offending channel).
fn check_i64_overflow(
    diags: &mut Vec<Diagnostic>,
    layer: usize,
    op: &'static str,
    name: &str,
    abs_sum: &[i128],
) {
    for (co, &mag) in abs_sum.iter().enumerate() {
        if mag > i64::MAX as i128 {
            diags.push(Diagnostic {
                severity: Severity::Error,
                rule: RULE_ACC_OVERFLOW,
                layer: Some(layer),
                op,
                layer_name: name.to_string(),
                message: format!(
                    "channel {co}: worst-case partial sum magnitude {mag} exceeds i64"
                ),
            });
            return;
        }
    }
}

/// Deduplicate per-layer diagnostics: keep the first finding per
/// (rule, layer) pair so a 64-channel layer reports once, not 64 times.
fn push_once(diags: &mut Vec<Diagnostic>, d: Diagnostic) {
    if !diags.iter().any(|x| x.rule == d.rule && x.layer == d.layer) {
        diags.push(d);
    }
}

/// Statically validate a knob vector against `base`: arity and per-knob
/// range first (so [`crate::approx::derive_model`] can never panic on
/// checked input), then the full abstract-interpretation pass over the
/// derived model. Returns every diagnostic; the config is legal iff none is
/// an error.
pub fn check_config(base: &QonnxModel, config: &[u32]) -> Vec<Diagnostic> {
    let knobs = crate::approx::knobs_for(base);
    if config.len() != knobs.len() {
        return vec![Diagnostic {
            severity: Severity::Error,
            rule: RULE_CONFIG_ARITY,
            layer: None,
            op: "",
            layer_name: String::new(),
            message: format!(
                "config has {} knobs, the base model has {}",
                config.len(),
                knobs.len()
            ),
        }];
    }
    let mut diags = Vec::new();
    for (i, (v, knob)) in config.iter().zip(&knobs).enumerate() {
        if *v > knob.max {
            diags.push(Diagnostic {
                severity: Severity::Error,
                rule: RULE_CONFIG_RANGE,
                layer: None,
                op: "",
                layer_name: knob.layer.clone(),
                message: format!(
                    "knob {i} ({:?} of '{}'): drop {v} exceeds headroom {}",
                    knob.kind, knob.layer, knob.max
                ),
            });
        }
    }
    if !diags.is_empty() {
        return diags;
    }
    analyze(&crate::approx::derive_model(base, config, "check")).diags
}

/// `true` iff [`check_config`] reports no error diagnostics.
pub fn config_is_legal(base: &QonnxModel, config: &[u32]) -> bool {
    !check_config(base, config)
        .iter()
        .any(|d| d.severity == Severity::Error)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::{prune_stress_model_json, read_str, test_model_json, QonnxModel};

    fn tiny(cin: usize, cout: usize) -> QonnxModel {
        read_str(&test_model_json(cin, cout)).unwrap()
    }

    fn stress() -> QonnxModel {
        read_str(&prune_stress_model_json()).unwrap()
    }

    #[test]
    fn tiny_model_is_clean_and_narrow_with_exact_logit_bounds() {
        // Cross-checked against the Python lattice scan: tiny(2, 3) logits
        // are [-3060, 0], [1, 1], [-1, 3059] and the conv is i32-narrow.
        let a = analyze(&tiny(2, 3));
        assert!(!a.has_errors(), "diags: {:?}", a.diags);
        assert_eq!(a.conv_narrow, vec![true]);
        assert_eq!(a.logits.len(), 3);
        assert_eq!((a.logits[0].lo, a.logits[0].hi), (-3060, 0));
        assert_eq!((a.logits[1].lo, a.logits[1].hi), (1, 1));
        assert_eq!((a.logits[2].lo, a.logits[2].hi), (-1, 3059));
        assert_eq!(a.facts.len(), 4);
        assert_eq!(a.facts[0].narrow, Some(true));
        assert_eq!(a.facts[1].narrow, None);
    }

    #[test]
    fn dense_weight_wipeout_is_a_const_output_error() {
        // Dropping 2 of the dense head's 4 weight bits leaves wmax = 1 and
        // rounds every {-1, 0, 1} code to 0: the logits collapse to the
        // rescaled biases. The checker must prove the classifier dead.
        let diags = check_config(&tiny(2, 3), &[0, 0, 2]);
        assert!(
            diags.iter().any(|d| d.rule == RULE_CONST_OUTPUT && d.severity == Severity::Error),
            "expected const-output, got {diags:?}"
        );
        let msg = diags.iter().find(|d| d.rule == RULE_CONST_OUTPUT).unwrap().to_string();
        assert!(msg.contains("dense"), "diagnostic must name the layer: {msg}");
    }

    #[test]
    fn arity_and_range_violations_are_typed() {
        let base = tiny(1, 2);
        let diags = check_config(&base, &[0, 0]);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, RULE_CONFIG_ARITY);

        let diags = check_config(&base, &[9, 0, 0]);
        assert_eq!(diags.len(), 1);
        assert_eq!(diags[0].rule, RULE_CONFIG_RANGE);
        assert_eq!(diags[0].layer_name, "conv1");
        assert!(diags[0].to_string().contains("conv1"));
    }

    #[test]
    fn stress_model_region_matches_the_python_scan() {
        // prune_stress_model_json's legal region (verified exhaustively by
        // the offline lattice scan): k <= 2, j <= j_alive[k], dk <= 1 with
        // j_alive = {0: 2, 1: 3, 2: 3}.
        let m = stress();
        let a = analyze(&m);
        assert!(!a.has_errors(), "root must be legal: {:?}", a.diags);
        assert_eq!(a.conv_narrow, vec![true]);
        assert_eq!((a.logits[0].lo, a.logits[0].hi), (-24, 0));
        assert_eq!((a.logits[1].lo, a.logits[1].hi), (1, 1));
        assert_eq!((a.logits[2].lo, a.logits[2].hi), (-1, 23));

        let legal = |cfg: &[u32]| config_is_legal(&m, cfg);
        assert!(legal(&[0, 0, 0]));
        assert!(legal(&[1, 1, 1]), "uniform(1) must be legal");
        assert!(legal(&[1, 3, 0]));
        assert!(legal(&[2, 3, 1]));
        assert!(!legal(&[2, 2, 2]), "uniform(2) must be illegal (dk = 2)");
        assert!(!legal(&[3, 0, 0]), "k = 3 wipes the conv weights");
        assert!(!legal(&[0, 3, 0]), "j = 3 starves the dense head at k = 0");
        assert!(!legal(&[6, 6, 2]), "the lattice bottom is illegal");
    }

    #[test]
    fn shift_out_of_range_is_a_requant_error() {
        let mut m = tiny(1, 2);
        if let Layer::Conv(c) = &mut m.layers[0] {
            c.shift[0] = 63;
        }
        let a = analyze(&m);
        assert!(a.errors().any(|d| d.rule == RULE_REQUANT_OVERFLOW));
    }

    #[test]
    fn huge_mult_is_a_requant_overflow_error() {
        let mut m = tiny(1, 2);
        if let Layer::Conv(c) = &mut m.layers[0] {
            c.mult[0] = i64::MAX / 2;
        }
        let a = analyze(&m);
        assert!(
            a.errors().any(|d| d.rule == RULE_REQUANT_OVERFLOW),
            "diags: {:?}",
            a.diags
        );
    }

    #[test]
    fn wide_bias_defeats_the_narrow_verdict_without_errors() {
        // Mirror of the kernels.rs wide-bias test model: a bias beyond
        // i32::MAX forces the i64 MAC path but is still executable.
        let mut m = tiny(1, 2);
        if let Layer::Conv(c) = &mut m.layers[0] {
            c.b_codes[0] = 3_000_000_000;
        }
        let a = analyze(&m);
        assert!(!a.has_errors(), "diags: {:?}", a.diags);
        assert_eq!(a.conv_narrow, vec![false]);
    }

    #[test]
    fn act_width_over_31_is_a_warning_not_an_error() {
        let mut m = tiny(1, 2);
        if let Layer::Conv(c) = &mut m.layers[0] {
            c.act_bits = 32;
        }
        let a = analyze(&m);
        assert!(!a.has_errors(), "diags: {:?}", a.diags);
        assert!(a.diags.iter().any(|d| d.rule == RULE_ACT_WIDTH));
    }

    #[test]
    fn diagnostics_render_rule_layer_op_and_name() {
        let d = Diagnostic {
            severity: Severity::Error,
            rule: RULE_ACC_OVERFLOW,
            layer: Some(2),
            op: "conv",
            layer_name: "conv2".to_string(),
            message: "boom".to_string(),
        };
        assert_eq!(
            d.to_string(),
            "error[acc-overflow] layer 2 (conv 'conv2'): boom"
        );
        // Knob- and model-level rules omit what they don't know.
        let d = Diagnostic {
            severity: Severity::Error,
            rule: RULE_CONFIG_RANGE,
            layer: None,
            op: "",
            layer_name: "conv1".to_string(),
            message: "drop 9 exceeds headroom 2".to_string(),
        };
        assert_eq!(
            d.to_string(),
            "error[config-range] 'conv1': drop 9 exceeds headroom 2"
        );
        let d = Diagnostic {
            severity: Severity::Error,
            rule: RULE_CONFIG_ARITY,
            layer: None,
            op: "",
            layer_name: String::new(),
            message: "config has 2 knobs, the base model has 3".to_string(),
        };
        assert_eq!(
            d.to_string(),
            "error[config-arity]: config has 2 knobs, the base model has 3"
        );
    }
}
