//! Ping/pong arena planning: exact high-water sizes of the two activation
//! buffers from a liveness walk over the inferred shapes.
//!
//! This is the single source of truth for scratch sizing — the scalar
//! executor ([`crate::dataflow::exec`]) and the packed batch engine
//! ([`crate::dataflow::kernels`]) both derive their buffers from it, so the
//! two paths can never disagree about where an activation lives or how big
//! a buffer must be.

use crate::qonnx::{infer_shapes, Layer, QonnxModel, TensorShape};

/// The double-buffer plan of one model: per-layer tensor shapes plus the
/// high-water element counts of the two ping/pong arenas.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArenaPlan {
    /// `layers.len() + 1` shapes: input, then one per layer output.
    pub shapes: Vec<TensorShape>,
    /// High-water element count of buffer A (holds the input first).
    pub a_elems: usize,
    /// High-water element count of buffer B.
    pub b_elems: usize,
}

impl ArenaPlan {
    /// Walk the pipeline tracking which buffer holds each activation:
    /// flatten is a no-op on the HWC layout (no buffer flip), every other
    /// layer writes the opposite buffer. Each buffer is sized by the widest
    /// tensor it will *actually* hold — not the global max, which
    /// over-allocates whenever the widest activation lands in only one of
    /// the two.
    pub fn of(model: &QonnxModel) -> ArenaPlan {
        let shapes = infer_shapes(model);
        let mut a_elems = shapes[0].elems();
        let mut b_elems = 0;
        let mut in_a = true;
        for (i, layer) in model.layers.iter().enumerate() {
            match layer {
                Layer::Flatten { .. } => {}
                Layer::Conv(_) | Layer::Pool(_) | Layer::Dense(_) => {
                    in_a = !in_a;
                    let elems = shapes[i + 1].elems();
                    if in_a {
                        a_elems = a_elems.max(elems);
                    } else {
                        b_elems = b_elems.max(elems);
                    }
                }
            }
        }
        ArenaPlan {
            shapes,
            a_elems,
            b_elems,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::qonnx::{read_str, test_model_json};

    #[test]
    fn plan_matches_the_documented_tiny_walk() {
        // tiny(1, 2): input 4x4x1 (16, A) -> conv 4x4x2 (32, B) -> pool
        // 2x2x2 (8, A) -> flatten (no flip) -> dense 3 (B).
        let m = read_str(&test_model_json(1, 2)).unwrap();
        let plan = ArenaPlan::of(&m);
        assert_eq!(plan.shapes.len(), m.layers.len() + 1);
        assert_eq!(plan.a_elems, 16);
        assert_eq!(plan.b_elems, 32);
    }
}
