//! The abstract domain: per-channel integer intervals, and the transfer
//! functions of the QONNX layer set.
//!
//! Everything is computed in `i128` so the *analysis* can never overflow
//! while reasoning about computations that might; results saturate into
//! [`Interval`] (i64 endpoints) only after the overflow rules have seen the
//! exact values.

use crate::qonnx::{ConvLayer, DenseLayer};

/// Inclusive integer interval `[lo, hi]` — the abstract value of one
/// activation channel or accumulator lane.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Interval {
    pub lo: i64,
    pub hi: i64,
}

impl Interval {
    pub fn new(lo: i64, hi: i64) -> Self {
        debug_assert!(lo <= hi, "interval [{lo}, {hi}] is empty");
        Interval { lo, hi }
    }

    pub fn contains(&self, v: i64) -> bool {
        self.lo <= v && v <= self.hi
    }

    /// Degenerate interval: the value is statically known.
    pub fn is_point(&self) -> bool {
        self.lo == self.hi
    }
}

pub(crate) fn saturate(v: i128) -> i64 {
    v.clamp(i64::MIN as i128, i64::MAX as i128) as i64
}

/// Exact worst-case accumulator bounds of one conv/dense layer, before any
/// saturation: per-output-channel value interval, plus the absolute-sum
/// bound that proves no *partial* accumulation (any term order) can leave
/// `i64`.
pub(crate) struct AccBounds {
    /// Per output channel: exact `[lo, hi]` of the final accumulator.
    pub acc: Vec<(i128, i128)>,
    /// Per output channel: `|bias| + sum of max |term endpoint|` — an upper
    /// bound on the magnitude of every partial sum in every order.
    pub abs_sum: Vec<i128>,
    /// All products and per-channel intervals fit `i32` (conv only): the
    /// packed 32-bit MAC path is provably overflow-free.
    pub narrow: bool,
}

/// Transfer function of a 3x3 SAME conv, `input` = per-input-channel
/// activation intervals. Each tap's product range is widened with 0 because
/// SAME padding feeds zeros at the borders (and the executors skip
/// zero-valued activations), so every per-tap interval contains 0 — which
/// also makes every partial accumulation stay inside the final interval.
pub(crate) fn conv_bounds(c: &ConvLayer, input: &[Interval]) -> AccBounds {
    assert_eq!(input.len(), c.cin, "conv '{}' input channel mismatch", c.name);
    let i32max = i32::MAX as i128;
    let mut acc = Vec::with_capacity(c.cout);
    let mut abs_sum = Vec::with_capacity(c.cout);
    let mut narrow = true;
    for co in 0..c.cout {
        let bias = c.b_codes[co] as i128;
        let (mut lo, mut hi) = (bias, bias);
        let mut mag = bias.abs();
        for tap in 0..9 * c.cin {
            let w = c.w_codes[tap * c.cout + co] as i128;
            let iv = input[tap % c.cin];
            let (a, b) = (w * iv.lo as i128, w * iv.hi as i128);
            let tl = 0.min(a).min(b);
            let th = 0.max(a).max(b);
            lo += tl;
            hi += th;
            mag += (-tl).max(th);
            if -tl > i32max || th > i32max {
                narrow = false; // a single product can overflow an i32 MAC
            }
        }
        if lo < i32::MIN as i128 || hi > i32max {
            narrow = false;
        }
        acc.push((lo, hi));
        abs_sum.push(mag);
    }
    AccBounds {
        acc,
        abs_sum,
        narrow,
    }
}

/// Transfer function of the dense head: input feature `f` carries the
/// interval of flattened channel `f % input.len()` (HWC layout). No 0
/// widening here — dense layers see no padding, and a skipped zero
/// activation can only occur when 0 is already inside the input interval.
pub(crate) fn dense_bounds(d: &DenseLayer, input: &[Interval]) -> AccBounds {
    assert!(!input.is_empty(), "dense '{}' has no input intervals", d.name);
    assert_eq!(
        d.in_features % input.len(),
        0,
        "dense '{}' features do not tile the input channels",
        d.name
    );
    let k_total = d.out_features;
    let mut acc = Vec::with_capacity(k_total);
    let mut abs_sum = Vec::with_capacity(k_total);
    for k in 0..k_total {
        let bias = d.b_codes[k] as i128;
        let (mut lo, mut hi) = (bias, bias);
        let mut mag = bias.abs();
        for f in 0..d.in_features {
            let w = d.w_codes[f * k_total + k] as i128;
            let iv = input[f % input.len()];
            let (a, b) = (w * iv.lo as i128, w * iv.hi as i128);
            let (tl, th) = (a.min(b), a.max(b));
            lo += tl;
            hi += th;
            mag += tl.abs().max(th.abs());
        }
        acc.push((lo, hi));
        abs_sum.push(mag);
    }
    AccBounds {
        acc,
        abs_sum,
        narrow: false, // dense always accumulates in i64
    }
}

/// Requantization endpoints: `q(v) = clamp((v*mult + half) >> shift, 0,
/// 2^act_bits - 1)`. For `mult >= 0` the map is monotone in the
/// accumulator, so the image of `[lo, hi]` is `[q(lo), q(hi)]`; a negative
/// multiplier flips the endpoints. Exact in `i128` — the caller checks the
/// executor's `i64` product separately ([`super::RULE_REQUANT_OVERFLOW`]).
pub(crate) fn requant_interval(
    lo: i128,
    hi: i128,
    mult: i64,
    shift: i64,
    act_bits: u32,
) -> Interval {
    let qmax = if act_bits >= 63 {
        i64::MAX as i128
    } else {
        (1i128 << act_bits) - 1
    };
    let half = if shift > 0 { 1i128 << (shift - 1) } else { 0 };
    let q = |v: i128| ((v * mult as i128 + half) >> shift).clamp(0, qmax);
    let (a, b) = (q(lo), q(hi));
    Interval::new(a.min(b) as i64, a.max(b) as i64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataflow::exec;

    #[test]
    fn interval_basics() {
        let iv = Interval::new(-3, 7);
        assert!(iv.contains(-3) && iv.contains(0) && iv.contains(7));
        assert!(!iv.contains(8) && !iv.contains(-4));
        assert!(!iv.is_point());
        assert!(Interval::new(5, 5).is_point());
    }

    #[test]
    fn requant_interval_matches_the_executor_on_endpoints_and_interior() {
        // The abstract requant must agree with exec::requant pointwise and
        // bound every interior accumulator (monotonicity).
        for &(lo, hi, mult, shift, bits) in &[
            (-5000i64, 9000i64, 16384i64, 15i64, 8u32),
            (0, 6885, 1, 11, 8),
            (-100, 100, 3, 0, 4),
            (i32::MAX as i64, i32::MAX as i64 + 9, 7, 3, 16),
        ] {
            let iv = requant_interval(lo as i128, hi as i128, mult, shift, bits);
            for acc in [lo, lo + (hi - lo) / 2, hi] {
                let q = exec::requant(acc, mult, shift, bits);
                assert!(
                    iv.contains(q),
                    "requant({acc}, {mult}, {shift}, {bits}) = {q} outside {iv:?}"
                );
            }
        }
    }

    #[test]
    fn negative_mult_flips_endpoints() {
        let iv = requant_interval(0, 100, -2, 0, 16);
        // q(0) = 0, q(100) = -200 -> clamp 0; the interval stays ordered
        assert!(iv.lo <= iv.hi);
        assert!(iv.contains(0));
    }
}
