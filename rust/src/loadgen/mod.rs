//! Open-loop load generation on *virtual time*.
//!
//! The closed-loop benches (`throughput_workers` etc.) are self-limiting:
//! a client waits for a reply before submitting again, so offered load
//! collapses exactly when the server slows down — tail latency under
//! pressure is invisible by construction. An **open-loop** workload fixes
//! the arrival schedule up front (requests arrive whether or not earlier
//! ones finished), which is how real traffic behaves and the standard way
//! to measure p99/p999 honestly.
//!
//! Everything here runs on virtual time — seeded RNG, no wall clock,
//! consistent with the repo-wide `clippy.toml` ban — so the reports are
//! bit-for-bit reproducible and CI-gateable without retries:
//!
//! * [`poisson_arrivals`] — exponential inter-arrivals via inverse-CDF on
//!   the seeded xorshift64* [`Rng`]; [`uniform_arrivals`] for a paced
//!   schedule; any caller-supplied trace (sorted seconds) works too.
//! * [`simulate`] — a discrete-event model of the serving spine:
//!   join-shortest-queue routing over `shards` deterministic servers with
//!   fixed `service_us`, plus the front end's shed-at-aggregate-depth
//!   admission control. Emits exact p50/p99/p999 (every latency retained,
//!   not bucketed), served/shed fractions, and per-shard depth high-water
//!   marks.
//!
//! The model is the *planning* half; `onnx2hw loadgen --connect` and the
//! `load_open_loop` bench drive the same schedules through the real TCP
//! front end to keep the model honest.

use std::collections::VecDeque;

use crate::metrics::exact_quantile_us;
use crate::testkit::Rng;

/// Deterministic Poisson process: `n` arrival times (seconds, ascending)
/// at `rate_per_s`, by inverse-CDF exponential inter-arrivals on the
/// seeded generator.
pub fn poisson_arrivals(rate_per_s: f64, n: usize, seed: u64) -> Vec<f64> {
    assert!(
        rate_per_s.is_finite() && rate_per_s > 0.0,
        "rate must be finite and > 0, got {rate_per_s}"
    );
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            // u in [0,1) so 1-u in (0,1]: ln never sees 0.
            let u = rng.f64_unit();
            t += -(1.0 - u).ln() / rate_per_s;
            t
        })
        .collect()
}

/// Evenly paced arrivals at `rate_per_s` (the deterministic trace twin of
/// [`poisson_arrivals`]).
pub fn uniform_arrivals(rate_per_s: f64, n: usize) -> Vec<f64> {
    assert!(
        rate_per_s.is_finite() && rate_per_s > 0.0,
        "rate must be finite and > 0, got {rate_per_s}"
    );
    (1..=n).map(|i| i as f64 / rate_per_s).collect()
}

/// The serving spine as the open-loop model sees it.
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    /// Parallel servers (worker shards).
    pub shards: usize,
    /// Deterministic per-request service time in microseconds.
    pub service_us: f64,
    /// Aggregate queued-or-in-service ceiling: an arrival finding this many
    /// requests outstanding is shed (mirrors `NetServerConfig::admission_depth`).
    pub admission_depth: usize,
}

/// What a fixed offered rate did to the modeled spine.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    /// Arrivals offered (the schedule length).
    pub offered: usize,
    pub served: usize,
    pub shed: usize,
    pub shed_fraction: f64,
    /// Served latencies in microseconds, ascending (arrival -> completion).
    pub latencies_us: Vec<u64>,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub max_us: u64,
    pub mean_us: f64,
    /// Queue-depth high-water mark per shard (queued + in service).
    pub max_depth: Vec<usize>,
    /// Arrival indices that were admitted, in arrival order (lets callers
    /// replay exactly the admitted subset through a real server).
    pub served_ids: Vec<usize>,
    /// Last arrival time (seconds of virtual time).
    pub horizon_s: f64,
}

/// Discrete-event simulation of the spine under a fixed arrival schedule
/// (`arrivals` in ascending seconds). Admission first (aggregate depth),
/// then join-shortest-queue routing (ties to the lowest shard index —
/// deterministic), then FIFO service at `cfg.service_us` per request.
pub fn simulate(arrivals: &[f64], cfg: &OpenLoopConfig) -> OpenLoopReport {
    let shards = cfg.shards.max(1);
    assert!(
        cfg.service_us.is_finite() && cfg.service_us > 0.0,
        "service_us must be finite and > 0, got {}",
        cfg.service_us
    );
    let service_s = cfg.service_us * 1e-6;
    // Per-shard FIFO of completion times; front = oldest outstanding.
    let mut queues: Vec<VecDeque<f64>> = vec![VecDeque::new(); shards];
    let mut free_at = vec![0.0f64; shards];
    let mut max_depth = vec![0usize; shards];
    let mut latencies: Vec<u64> = Vec::new();
    let mut served_ids: Vec<usize> = Vec::new();
    let mut shed = 0usize;
    let mut last_t = f64::NEG_INFINITY;
    for (idx, &t) in arrivals.iter().enumerate() {
        assert!(
            t >= last_t && t.is_finite(),
            "arrivals must be finite and ascending: arrival {idx} at {t} after {last_t}"
        );
        last_t = t;
        // Retire everything that completed by now.
        for q in queues.iter_mut() {
            while q.front().is_some_and(|&done| done <= t) {
                q.pop_front();
            }
        }
        let depth: usize = queues.iter().map(VecDeque::len).sum();
        if depth >= cfg.admission_depth {
            shed += 1;
            continue;
        }
        // Join the shortest queue; min_by_key keeps the first (lowest
        // index) minimum, so routing is deterministic.
        let tgt = (0..shards)
            .min_by_key(|&i| queues[i].len())
            .expect("at least one shard");
        let start = if free_at[tgt] > t { free_at[tgt] } else { t };
        let done = start + service_s;
        free_at[tgt] = done;
        queues[tgt].push_back(done);
        max_depth[tgt] = max_depth[tgt].max(queues[tgt].len());
        latencies.push(((done - t) * 1e6).round() as u64);
        served_ids.push(idx);
    }
    latencies.sort_unstable();
    let served = latencies.len();
    let offered = arrivals.len();
    let mean_us = if served == 0 {
        0.0
    } else {
        latencies.iter().sum::<u64>() as f64 / served as f64
    };
    OpenLoopReport {
        offered,
        served,
        shed,
        shed_fraction: if offered == 0 {
            0.0
        } else {
            shed as f64 / offered as f64
        },
        p50_us: exact_quantile_us(&latencies, 0.50),
        p99_us: exact_quantile_us(&latencies, 0.99),
        p999_us: exact_quantile_us(&latencies, 0.999),
        max_us: latencies.last().copied().unwrap_or(0),
        mean_us,
        latencies_us: latencies,
        max_depth,
        served_ids,
        horizon_s: arrivals.last().copied().unwrap_or(0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_deterministic_and_calibrated() {
        let a = poisson_arrivals(1000.0, 10_000, 42);
        let b = poisson_arrivals(1000.0, 10_000, 42);
        assert_eq!(a, b, "same seed must give the same schedule");
        let c = poisson_arrivals(1000.0, 10_000, 43);
        assert_ne!(a, c, "different seeds must differ");
        assert!(a.windows(2).all(|w| w[1] >= w[0]), "ascending");
        // mean inter-arrival ~ 1/rate = 1 ms; 10k samples => within 5%
        let mean = a.last().unwrap() / a.len() as f64;
        assert!(
            (mean - 1e-3).abs() < 5e-5,
            "mean inter-arrival {mean} far from 1e-3"
        );
    }

    #[test]
    fn uniform_paces_exactly() {
        let a = uniform_arrivals(100.0, 5);
        for (i, t) in a.iter().enumerate() {
            assert!((t - (i + 1) as f64 * 0.01).abs() < 1e-12);
        }
    }

    #[test]
    fn single_shard_backlog_is_exact() {
        // 3 simultaneous arrivals, 1 shard, 100 us service: latencies are
        // exactly 100/200/300 us.
        let report = simulate(
            &[0.0, 0.0, 0.0],
            &OpenLoopConfig {
                shards: 1,
                service_us: 100.0,
                admission_depth: 10,
            },
        );
        assert_eq!(report.latencies_us, vec![100, 200, 300]);
        assert_eq!(report.shed, 0);
        assert_eq!(report.max_depth, vec![3]);
        assert_eq!(report.served_ids, vec![0, 1, 2]);
    }

    #[test]
    fn admission_depth_sheds_and_conserves() {
        // 5 simultaneous arrivals but only 2 may be outstanding.
        let report = simulate(
            &[0.0; 5],
            &OpenLoopConfig {
                shards: 1,
                service_us: 100.0,
                admission_depth: 2,
            },
        );
        assert_eq!(report.served, 2);
        assert_eq!(report.shed, 3);
        assert_eq!(report.served + report.shed, report.offered);
        assert!((report.shed_fraction - 0.6).abs() < 1e-12);
        // served latency stays bounded by the depth
        assert_eq!(report.max_us, 200);
    }

    #[test]
    fn depth_zero_sheds_everything() {
        let report = simulate(
            &uniform_arrivals(1000.0, 50),
            &OpenLoopConfig {
                shards: 4,
                service_us: 100.0,
                admission_depth: 0,
            },
        );
        assert_eq!(report.served, 0);
        assert_eq!(report.shed, 50);
        assert_eq!(report.shed_fraction, 1.0);
        assert_eq!(report.p99_us, 0);
    }

    #[test]
    fn below_capacity_nothing_sheds_and_tails_are_bounded() {
        // 4 shards x (1/329us) ~ 12.2k/s capacity; offer 6k/s.
        let cfg = OpenLoopConfig {
            shards: 4,
            service_us: 329.0,
            admission_depth: 64,
        };
        let report = simulate(&poisson_arrivals(6000.0, 4000, 7), &cfg);
        assert_eq!(report.shed, 0, "below capacity nothing may shed");
        assert_eq!(report.served, 4000);
        assert!(report.p50_us >= 329, "p50 can't beat the service time");
        // Anything outstanding is bounded by the admission depth, so
        // latency is bounded by (depth/shards + 1) service times.
        let bound = (cfg.service_us * (cfg.admission_depth as f64 / cfg.shards as f64 + 1.0)) as u64;
        assert!(
            report.max_us <= bound,
            "max {} exceeds the depth bound {bound}",
            report.max_us
        );
        assert!(report.p999_us >= report.p99_us && report.p99_us >= report.p50_us);
    }

    #[test]
    fn overload_sheds_but_served_tail_stays_bounded() {
        let cfg = OpenLoopConfig {
            shards: 4,
            service_us: 329.0,
            admission_depth: 64,
        };
        // 30k/s offered into ~12.2k/s capacity: most arrivals shed, but
        // the ones admitted still complete within the depth bound.
        let report = simulate(&poisson_arrivals(30_000.0, 6000, 7), &cfg);
        assert!(
            report.shed_fraction > 0.3,
            "overload must shed (got {:.3})",
            report.shed_fraction
        );
        assert_eq!(report.served + report.shed, report.offered);
        let bound = (cfg.service_us * (cfg.admission_depth as f64 / cfg.shards as f64 + 1.0)) as u64;
        assert!(report.max_us <= bound);
        for (i, &d) in report.max_depth.iter().enumerate() {
            assert!(
                d <= cfg.admission_depth,
                "shard {i} depth {d} above the admission ceiling"
            );
        }
    }

    #[test]
    fn simulate_is_deterministic() {
        let cfg = OpenLoopConfig {
            shards: 3,
            service_us: 200.0,
            admission_depth: 16,
        };
        let arrivals = poisson_arrivals(9000.0, 2000, 99);
        let a = simulate(&arrivals, &cfg);
        let b = simulate(&arrivals, &cfg);
        assert_eq!(a.latencies_us, b.latencies_us);
        assert_eq!(a.served_ids, b.served_ids);
        assert_eq!(a.max_depth, b.max_depth);
    }
}
