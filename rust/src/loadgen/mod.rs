//! Open-loop load generation on *virtual time*.
//!
//! The closed-loop benches (`throughput_workers` etc.) are self-limiting:
//! a client waits for a reply before submitting again, so offered load
//! collapses exactly when the server slows down — tail latency under
//! pressure is invisible by construction. An **open-loop** workload fixes
//! the arrival schedule up front (requests arrive whether or not earlier
//! ones finished), which is how real traffic behaves and the standard way
//! to measure p99/p999 honestly.
//!
//! Everything here runs on virtual time — seeded RNG, no wall clock,
//! consistent with the repo-wide `clippy.toml` ban — so the reports are
//! bit-for-bit reproducible and CI-gateable without retries:
//!
//! * [`poisson_arrivals`] — exponential inter-arrivals via inverse-CDF on
//!   the seeded xorshift64* [`Rng`]; [`uniform_arrivals`] for a paced
//!   schedule; any caller-supplied trace (sorted seconds) works too.
//! * [`TraceSpec`] — multi-phase schedules (bursty spikes, diurnal ramps)
//!   loaded from a JSON trace file: the `loadgen --trace` input, one seed,
//!   reproducible across phase boundaries.
//! * [`simulate`] — a discrete-event model of the serving spine:
//!   join-shortest-queue routing over `shards` deterministic servers with
//!   fixed `service_us`, plus the front end's shed-at-aggregate-depth
//!   admission control. Emits exact p50/p99/p999 (every latency retained,
//!   not bucketed), served/shed fractions, and per-shard depth high-water
//!   marks.
//!
//! The model is the *planning* half; `onnx2hw loadgen --connect` and the
//! `load_open_loop` bench drive the same schedules through the real TCP
//! front end to keep the model honest.

use std::collections::VecDeque;

use crate::metrics::exact_quantile_us;
use crate::testkit::Rng;
use crate::trace::{EventKind, SpanKind, TraceCollector};

/// Deterministic Poisson process: `n` arrival times (seconds, ascending)
/// at `rate_per_s`, by inverse-CDF exponential inter-arrivals on the
/// seeded generator.
pub fn poisson_arrivals(rate_per_s: f64, n: usize, seed: u64) -> Vec<f64> {
    assert!(
        rate_per_s.is_finite() && rate_per_s > 0.0,
        "rate must be finite and > 0, got {rate_per_s}"
    );
    let mut rng = Rng::new(seed);
    let mut t = 0.0f64;
    (0..n)
        .map(|_| {
            // u in [0,1) so 1-u in (0,1]: ln never sees 0.
            let u = rng.f64_unit();
            t += -(1.0 - u).ln() / rate_per_s;
            t
        })
        .collect()
}

/// Evenly paced arrivals at `rate_per_s` (the deterministic trace twin of
/// [`poisson_arrivals`]).
pub fn uniform_arrivals(rate_per_s: f64, n: usize) -> Vec<f64> {
    assert!(
        rate_per_s.is_finite() && rate_per_s > 0.0,
        "rate must be finite and > 0, got {rate_per_s}"
    );
    (1..=n).map(|i| i as f64 / rate_per_s).collect()
}

/// Arrival pattern inside one [`TracePhase`].
#[derive(Debug, Clone, PartialEq)]
pub enum TracePattern {
    /// Exponential inter-arrivals at the phase rate.
    Poisson,
    /// Evenly paced at the phase rate.
    Uniform,
    /// Clumps of `burst` simultaneous arrivals at Poisson-spaced instants;
    /// the *instant* rate is `rate_per_s / burst`, so the phase still
    /// offers `rate_per_s` requests per second on average — same load,
    /// much spikier queue depth.
    Bursty { burst: usize },
}

/// One segment of a trace: offer `rate_per_s` for `duration_s` seconds of
/// virtual time with the given arrival [`TracePattern`].
#[derive(Debug, Clone)]
pub struct TracePhase {
    pub rate_per_s: f64,
    pub duration_s: f64,
    pub pattern: TracePattern,
}

/// A multi-phase arrival schedule (bursty spikes, diurnal ramps) loaded
/// from a JSON trace file — the `loadgen --trace` input. Everything stays
/// on virtual time and the single seeded [`Rng`] runs *across* phases, so
/// a trace is one reproducible schedule, not a concatenation of
/// independently seeded ones.
///
/// The on-disk shape:
///
/// ```json
/// {"seed": 7, "phases": [
///   {"rate_per_s": 6000.0, "duration_s": 0.5, "pattern": "poisson"},
///   {"rate_per_s": 20000.0, "duration_s": 0.1, "pattern": "bursty", "burst": 8},
///   {"rate_per_s": 2000.0, "duration_s": 0.5, "pattern": "uniform"}
/// ]}
/// ```
#[derive(Debug, Clone)]
pub struct TraceSpec {
    pub seed: u64,
    pub phases: Vec<TracePhase>,
}

impl TraceSpec {
    /// Parse the documented JSON shape; `seed` defaults to 7 when absent.
    /// Errors name the offending field so a bad trace file fails loudly at
    /// the CLI instead of producing a silently wrong schedule.
    pub fn from_json(v: &crate::json::Value) -> Result<TraceSpec, String> {
        let seed = match v.get("seed") {
            None => 7,
            Some(s) => s
                .as_f64()
                .filter(|s| s.fract() == 0.0 && *s >= 0.0)
                .ok_or("trace: seed must be a non-negative integer")?
                as u64,
        };
        let phases_v = v
            .get("phases")
            .and_then(|p| p.as_array())
            .ok_or("trace: missing \"phases\" array")?;
        if phases_v.is_empty() {
            return Err("trace: \"phases\" must not be empty".into());
        }
        let mut phases = Vec::with_capacity(phases_v.len());
        for (i, p) in phases_v.iter().enumerate() {
            let num = |key: &str| -> Result<f64, String> {
                p.get(key)
                    .and_then(|x| x.as_f64())
                    .filter(|x| x.is_finite() && *x > 0.0)
                    .ok_or(format!("trace: phase {i}: {key} must be finite and > 0"))
            };
            let rate_per_s = num("rate_per_s")?;
            let duration_s = num("duration_s")?;
            let pattern = match p.get("pattern").and_then(|x| x.as_str()) {
                Some("poisson") => TracePattern::Poisson,
                Some("uniform") => TracePattern::Uniform,
                Some("bursty") => {
                    let burst = p
                        .get("burst")
                        .and_then(|x| x.as_f64())
                        .filter(|b| b.fract() == 0.0 && *b >= 1.0)
                        .ok_or(format!(
                            "trace: phase {i}: bursty needs an integer burst >= 1"
                        ))? as usize;
                    TracePattern::Bursty { burst }
                }
                Some(other) => {
                    return Err(format!(
                        "trace: phase {i}: unknown pattern {other:?} \
                         (poisson | uniform | bursty)"
                    ))
                }
                None => return Err(format!("trace: phase {i}: missing pattern")),
            };
            phases.push(TracePhase {
                rate_per_s,
                duration_s,
                pattern,
            });
        }
        Ok(TraceSpec { seed, phases })
    }

    /// Total virtual-time span of the trace in seconds.
    pub fn horizon_s(&self) -> f64 {
        self.phases.iter().map(|p| p.duration_s).sum()
    }

    /// Materialize the schedule: arrival times in seconds, ascending across
    /// phase boundaries, reproducible from the seed alone. Feed the result
    /// straight into [`simulate`] or replay it against the TCP front end.
    pub fn arrivals(&self) -> Vec<f64> {
        let mut rng = Rng::new(self.seed);
        let mut out = Vec::new();
        let mut start = 0.0f64;
        for phase in &self.phases {
            let end = start + phase.duration_s;
            match phase.pattern {
                TracePattern::Poisson => {
                    let mut t = start;
                    loop {
                        let u = rng.f64_unit();
                        t += -(1.0 - u).ln() / phase.rate_per_s;
                        if t >= end {
                            break;
                        }
                        out.push(t);
                    }
                }
                TracePattern::Uniform => {
                    // Index-based (not `t += step`) so float drift cannot
                    // shift the count at the phase boundary.
                    let step = 1.0 / phase.rate_per_s;
                    for i in 1.. {
                        let t = start + i as f64 * step;
                        if t >= end {
                            break;
                        }
                        out.push(t);
                    }
                }
                TracePattern::Bursty { burst } => {
                    let burst = burst.max(1);
                    // Poisson-spaced burst *instants* at rate/burst keep the
                    // phase's average offered rate at rate_per_s.
                    let instant_rate = phase.rate_per_s / burst as f64;
                    let mut t = start;
                    loop {
                        let u = rng.f64_unit();
                        t += -(1.0 - u).ln() / instant_rate;
                        if t >= end {
                            break;
                        }
                        for _ in 0..burst {
                            out.push(t);
                        }
                    }
                }
            }
            start = end;
        }
        out
    }
}

/// The serving spine as the open-loop model sees it.
#[derive(Debug, Clone)]
pub struct OpenLoopConfig {
    /// Parallel servers (worker shards).
    pub shards: usize,
    /// Deterministic per-request service time in microseconds.
    pub service_us: f64,
    /// Aggregate queued-or-in-service ceiling: an arrival finding this many
    /// requests outstanding is shed (mirrors `NetServerConfig::admission_depth`).
    pub admission_depth: usize,
}

/// What a fixed offered rate did to the modeled spine.
#[derive(Debug, Clone)]
pub struct OpenLoopReport {
    /// Arrivals offered (the schedule length).
    pub offered: usize,
    pub served: usize,
    pub shed: usize,
    pub shed_fraction: f64,
    /// Served latencies in microseconds, ascending (arrival -> completion).
    pub latencies_us: Vec<u64>,
    pub p50_us: u64,
    pub p99_us: u64,
    pub p999_us: u64,
    pub max_us: u64,
    pub mean_us: f64,
    /// Queue-depth high-water mark per shard (queued + in service).
    pub max_depth: Vec<usize>,
    /// Arrival indices that were admitted, in arrival order (lets callers
    /// replay exactly the admitted subset through a real server).
    pub served_ids: Vec<usize>,
    /// Last arrival time (seconds of virtual time).
    pub horizon_s: f64,
}

/// Discrete-event simulation of the spine under a fixed arrival schedule
/// (`arrivals` in ascending seconds). Admission first (aggregate depth),
/// then join-shortest-queue routing (ties to the lowest shard index —
/// deterministic), then FIFO service at `cfg.service_us` per request.
pub fn simulate(arrivals: &[f64], cfg: &OpenLoopConfig) -> OpenLoopReport {
    simulate_inner(arrivals, cfg, None)
}

/// [`simulate`] with request tracing: every modeled request leaves a full
/// span tree (`net.read → admission → dispatch.enqueue → queue.wait →
/// shard.exec → net.write`) on `trace`, timestamped in virtual
/// microseconds; shed arrivals leave a denied-key tree plus a `shed`
/// instant event. The model is single-threaded and seed-driven, so two
/// runs over the same schedule produce **byte-identical** trace JSON —
/// the determinism half of the `trace_conservation` gate (the live TCP
/// path asserts the schedule-independent invariants instead).
pub fn simulate_traced(
    arrivals: &[f64],
    cfg: &OpenLoopConfig,
    trace: &TraceCollector,
) -> OpenLoopReport {
    simulate_inner(arrivals, cfg, Some(trace))
}

fn simulate_inner(
    arrivals: &[f64],
    cfg: &OpenLoopConfig,
    trace: Option<&TraceCollector>,
) -> OpenLoopReport {
    let shards = cfg.shards.max(1);
    assert!(
        cfg.service_us.is_finite() && cfg.service_us > 0.0,
        "service_us must be finite and > 0, got {}",
        cfg.service_us
    );
    let service_s = cfg.service_us * 1e-6;
    // Per-shard FIFO of completion times; front = oldest outstanding.
    let mut queues: Vec<VecDeque<f64>> = vec![VecDeque::new(); shards];
    let mut free_at = vec![0.0f64; shards];
    let mut max_depth = vec![0usize; shards];
    let mut latencies: Vec<u64> = Vec::new();
    let mut served_ids: Vec<usize> = Vec::new();
    let mut shed = 0usize;
    let mut last_t = f64::NEG_INFINITY;
    for (idx, &t) in arrivals.iter().enumerate() {
        assert!(
            t >= last_t && t.is_finite(),
            "arrivals must be finite and ascending: arrival {idx} at {t} after {last_t}"
        );
        last_t = t;
        // Retire everything that completed by now.
        for q in queues.iter_mut() {
            while q.front().is_some_and(|&done| done <= t) {
                q.pop_front();
            }
        }
        let depth: usize = queues.iter().map(VecDeque::len).sum();
        if depth >= cfg.admission_depth {
            shed += 1;
            if let Some(tc) = trace {
                let arr_us = (t * 1e6).round() as u64;
                let key = tc.denied_key();
                let lane = tc.net_lane();
                tc.span(lane, key, SpanKind::NetRead, arr_us, arr_us);
                tc.span_detail(lane, key, SpanKind::Admission, arr_us, arr_us, "shed");
                tc.event(lane, EventKind::Shed, arr_us, Some(key), "admission depth");
                tc.span(lane, key, SpanKind::NetWrite, arr_us, arr_us);
            }
            continue;
        }
        // Join the shortest queue; min_by_key keeps the first (lowest
        // index) minimum, so routing is deterministic.
        let tgt = (0..shards)
            .min_by_key(|&i| queues[i].len())
            .expect("at least one shard");
        let start = if free_at[tgt] > t { free_at[tgt] } else { t };
        let done = start + service_s;
        free_at[tgt] = done;
        queues[tgt].push_back(done);
        max_depth[tgt] = max_depth[tgt].max(queues[tgt].len());
        latencies.push(((done - t) * 1e6).round() as u64);
        served_ids.push(idx);
        if let Some(tc) = trace {
            let arr_us = (t * 1e6).round() as u64;
            let start_us = (start * 1e6).round() as u64;
            let done_us = (done * 1e6).round() as u64;
            let req = idx as u64;
            let net = tc.net_lane();
            let shard = tc.shard_lane(tgt);
            tc.span(net, req, SpanKind::NetRead, arr_us, arr_us);
            tc.span_detail(net, req, SpanKind::Admission, arr_us, arr_us, "admitted");
            let d = tc.dispatch_lane();
            let label = format!("shard {tgt}");
            tc.span_detail(d, req, SpanKind::DispatchEnqueue, arr_us, arr_us, label);
            tc.span(shard, req, SpanKind::QueueWait, arr_us, start_us);
            tc.span(shard, req, SpanKind::ShardExec, start_us, done_us);
            tc.span(net, req, SpanKind::NetWrite, done_us, done_us);
        }
    }
    latencies.sort_unstable();
    let served = latencies.len();
    let offered = arrivals.len();
    let mean_us = if served == 0 {
        0.0
    } else {
        latencies.iter().sum::<u64>() as f64 / served as f64
    };
    OpenLoopReport {
        offered,
        served,
        shed,
        shed_fraction: if offered == 0 {
            0.0
        } else {
            shed as f64 / offered as f64
        },
        p50_us: exact_quantile_us(&latencies, 0.50),
        p99_us: exact_quantile_us(&latencies, 0.99),
        p999_us: exact_quantile_us(&latencies, 0.999),
        max_us: latencies.last().copied().unwrap_or(0),
        mean_us,
        latencies_us: latencies,
        max_depth,
        served_ids,
        horizon_s: arrivals.last().copied().unwrap_or(0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_deterministic_and_calibrated() {
        let a = poisson_arrivals(1000.0, 10_000, 42);
        let b = poisson_arrivals(1000.0, 10_000, 42);
        assert_eq!(a, b, "same seed must give the same schedule");
        let c = poisson_arrivals(1000.0, 10_000, 43);
        assert_ne!(a, c, "different seeds must differ");
        assert!(a.windows(2).all(|w| w[1] >= w[0]), "ascending");
        // mean inter-arrival ~ 1/rate = 1 ms; 10k samples => within 5%
        let mean = a.last().unwrap() / a.len() as f64;
        assert!(
            (mean - 1e-3).abs() < 5e-5,
            "mean inter-arrival {mean} far from 1e-3"
        );
    }

    #[test]
    fn uniform_paces_exactly() {
        let a = uniform_arrivals(100.0, 5);
        for (i, t) in a.iter().enumerate() {
            assert!((t - (i + 1) as f64 * 0.01).abs() < 1e-12);
        }
    }

    #[test]
    fn single_shard_backlog_is_exact() {
        // 3 simultaneous arrivals, 1 shard, 100 us service: latencies are
        // exactly 100/200/300 us.
        let report = simulate(
            &[0.0, 0.0, 0.0],
            &OpenLoopConfig {
                shards: 1,
                service_us: 100.0,
                admission_depth: 10,
            },
        );
        assert_eq!(report.latencies_us, vec![100, 200, 300]);
        assert_eq!(report.shed, 0);
        assert_eq!(report.max_depth, vec![3]);
        assert_eq!(report.served_ids, vec![0, 1, 2]);
    }

    #[test]
    fn admission_depth_sheds_and_conserves() {
        // 5 simultaneous arrivals but only 2 may be outstanding.
        let report = simulate(
            &[0.0; 5],
            &OpenLoopConfig {
                shards: 1,
                service_us: 100.0,
                admission_depth: 2,
            },
        );
        assert_eq!(report.served, 2);
        assert_eq!(report.shed, 3);
        assert_eq!(report.served + report.shed, report.offered);
        assert!((report.shed_fraction - 0.6).abs() < 1e-12);
        // served latency stays bounded by the depth
        assert_eq!(report.max_us, 200);
    }

    #[test]
    fn depth_zero_sheds_everything() {
        let report = simulate(
            &uniform_arrivals(1000.0, 50),
            &OpenLoopConfig {
                shards: 4,
                service_us: 100.0,
                admission_depth: 0,
            },
        );
        assert_eq!(report.served, 0);
        assert_eq!(report.shed, 50);
        assert_eq!(report.shed_fraction, 1.0);
        assert_eq!(report.p99_us, 0);
    }

    #[test]
    fn below_capacity_nothing_sheds_and_tails_are_bounded() {
        // 4 shards x (1/329us) ~ 12.2k/s capacity; offer 6k/s.
        let cfg = OpenLoopConfig {
            shards: 4,
            service_us: 329.0,
            admission_depth: 64,
        };
        let report = simulate(&poisson_arrivals(6000.0, 4000, 7), &cfg);
        assert_eq!(report.shed, 0, "below capacity nothing may shed");
        assert_eq!(report.served, 4000);
        assert!(report.p50_us >= 329, "p50 can't beat the service time");
        // Anything outstanding is bounded by the admission depth, so
        // latency is bounded by (depth/shards + 1) service times.
        let bound = (cfg.service_us * (cfg.admission_depth as f64 / cfg.shards as f64 + 1.0)) as u64;
        assert!(
            report.max_us <= bound,
            "max {} exceeds the depth bound {bound}",
            report.max_us
        );
        assert!(report.p999_us >= report.p99_us && report.p99_us >= report.p50_us);
    }

    #[test]
    fn overload_sheds_but_served_tail_stays_bounded() {
        let cfg = OpenLoopConfig {
            shards: 4,
            service_us: 329.0,
            admission_depth: 64,
        };
        // 30k/s offered into ~12.2k/s capacity: most arrivals shed, but
        // the ones admitted still complete within the depth bound.
        let report = simulate(&poisson_arrivals(30_000.0, 6000, 7), &cfg);
        assert!(
            report.shed_fraction > 0.3,
            "overload must shed (got {:.3})",
            report.shed_fraction
        );
        assert_eq!(report.served + report.shed, report.offered);
        let bound = (cfg.service_us * (cfg.admission_depth as f64 / cfg.shards as f64 + 1.0)) as u64;
        assert!(report.max_us <= bound);
        for (i, &d) in report.max_depth.iter().enumerate() {
            assert!(
                d <= cfg.admission_depth,
                "shard {i} depth {d} above the admission ceiling"
            );
        }
    }

    #[test]
    fn trace_parses_generates_and_reproduces() {
        let src = r#"{"seed": 7, "phases": [
            {"rate_per_s": 6000.0, "duration_s": 0.5, "pattern": "poisson"},
            {"rate_per_s": 20000.0, "duration_s": 0.1, "pattern": "bursty", "burst": 8},
            {"rate_per_s": 2000.0, "duration_s": 0.5, "pattern": "uniform"}
        ]}"#;
        let spec = TraceSpec::from_json(&crate::json::parse(src).unwrap()).unwrap();
        assert_eq!(spec.seed, 7);
        assert_eq!(spec.phases.len(), 3);
        assert_eq!(spec.phases[1].pattern, TracePattern::Bursty { burst: 8 });
        assert!((spec.horizon_s() - 1.1).abs() < 1e-12);

        let a = spec.arrivals();
        let b = spec.arrivals();
        assert_eq!(a, b, "a trace is one reproducible schedule");
        assert!(
            a.windows(2).all(|w| w[1] >= w[0]),
            "ascending across phase boundaries"
        );
        assert!(a.iter().all(|&t| t >= 0.0 && t < spec.horizon_s()));
        // ~6000*0.5 + 20000*0.1 + 2000*0.5 - 1 = 5999 expected; Poisson
        // phases fluctuate, so only sanity-bound the count.
        assert!(
            (5000..7000).contains(&a.len()),
            "offered count {} far from the ~6000 the trace encodes",
            a.len()
        );
        // The uniform tail is exactly paced from the phase boundary (cut
        // strictly past it so a bursty straggler at ~0.6 cannot leak in).
        let tail: Vec<f64> = a.iter().copied().filter(|&t| t >= 0.6003).collect();
        assert_eq!(tail.len(), 999);
        assert!((tail[0] - 0.6005).abs() < 1e-9);
    }

    #[test]
    fn bursty_phases_arrive_in_clumps_at_the_same_average_rate() {
        let spec = TraceSpec {
            seed: 11,
            phases: vec![TracePhase {
                rate_per_s: 10_000.0,
                duration_s: 1.0,
                pattern: TracePattern::Bursty { burst: 8 },
            }],
        };
        let a = spec.arrivals();
        assert_eq!(a.len() % 8, 0, "arrivals come in whole clumps");
        for clump in a.chunks(8) {
            assert!(
                clump.iter().all(|&t| t == clump[0]),
                "every clump is simultaneous"
            );
        }
        // Average offered rate stays ~rate_per_s despite the clumping.
        let rate = a.len() as f64 / 1.0;
        assert!(
            (7000.0..13_000.0).contains(&rate),
            "offered rate {rate} far from 10k"
        );
        // The spiky schedule still feeds simulate() fine.
        let report = simulate(
            &a,
            &OpenLoopConfig {
                shards: 4,
                service_us: 100.0,
                admission_depth: 64,
            },
        );
        assert_eq!(report.served + report.shed, report.offered);
    }

    #[test]
    fn trace_rejects_malformed_specs_loudly() {
        let cases = [
            (r#"{"seed": 7}"#, "phases"),
            (r#"{"phases": []}"#, "empty"),
            (
                r#"{"phases": [{"rate_per_s": 0.0, "duration_s": 1.0, "pattern": "poisson"}]}"#,
                "rate_per_s",
            ),
            (
                r#"{"phases": [{"rate_per_s": 10.0, "duration_s": 1.0, "pattern": "diurnal"}]}"#,
                "pattern",
            ),
            (
                r#"{"phases": [{"rate_per_s": 10.0, "duration_s": 1.0, "pattern": "bursty"}]}"#,
                "burst",
            ),
        ];
        for (src, needle) in cases {
            let err = TraceSpec::from_json(&crate::json::parse(src).unwrap())
                .expect_err(src);
            assert!(
                err.contains(needle),
                "error {err:?} for {src} should mention {needle:?}"
            );
        }
    }

    #[test]
    fn simulate_traced_is_byte_identical_and_conserves() {
        let cfg = OpenLoopConfig {
            shards: 2,
            service_us: 150.0,
            admission_depth: 4,
        };
        // Hot enough to shed: 20 simultaneous arrivals into depth 4.
        let arrivals = vec![0.0; 20];
        let run = |arrivals: &[f64]| {
            let tc = TraceCollector::new(cfg.shards);
            let report = simulate_inner(arrivals, &cfg, Some(&tc));
            (report, tc.snapshot())
        };
        let (report, snap) = run(&arrivals);
        let (report2, snap2) = run(&arrivals);
        assert_eq!(
            snap.to_chrome_json().to_string(),
            snap2.to_chrome_json().to_string(),
            "same schedule must emit byte-identical trace JSON"
        );
        assert_eq!(report.served, report2.served);
        // Conservation: every served id has a complete tree, every shed
        // arrival a denied tree + shed event, and nothing else exists.
        for &id in &report.served_ids {
            assert!(snap.served_tree_complete(id as u64), "request {id} tree incomplete");
        }
        assert_eq!(snap.count_events(EventKind::Shed), report.shed);
        let denied: Vec<u64> = snap
            .spans
            .iter()
            .map(|s| s.req)
            .filter(|&r| r >= crate::trace::DENIED_KEY_OFFSET)
            .collect();
        let mut uniq = denied.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), report.shed, "one denied tree per shed arrival");
        for &k in &uniq {
            assert!(snap.denied_tree_complete(k));
        }
        assert_eq!(report.served + report.shed, report.offered);
        // The untraced path must compute the identical report.
        let plain = simulate(&arrivals, &cfg);
        assert_eq!(plain.latencies_us, report.latencies_us);
        assert_eq!(plain.served_ids, report.served_ids);
    }

    #[test]
    fn simulate_is_deterministic() {
        let cfg = OpenLoopConfig {
            shards: 3,
            service_us: 200.0,
            admission_depth: 16,
        };
        let arrivals = poisson_arrivals(9000.0, 2000, 99);
        let a = simulate(&arrivals, &cfg);
        let b = simulate(&arrivals, &cfg);
        assert_eq!(a.latencies_us, b.latencies_us);
        assert_eq!(a.served_ids, b.served_ids);
        assert_eq!(a.max_depth, b.max_depth);
    }
}
